//! Property-based invariants over randomized inputs (mini-prop harness;
//! see `cvlr::util::prop`). These are the structural guarantees the
//! paper's correctness rests on: factorization error bounds, dumbbell
//! algebra identities, graph-machinery round trips, metric bounds.

use cvlr::data::synth::{generate, random_dag, DataKind, SynthConfig};
use cvlr::data::Dataset;
use cvlr::graph::pdag::dag_to_cpdag;
use cvlr::graph::{normalized_shd, skeleton_f1};
use cvlr::kernel::{center_gram, gram, median_heuristic, Kernel};
use cvlr::linalg::Mat;
use cvlr::lowrank::{center_factor, factorize, FactorMethod, LowRankConfig, Method};
use cvlr::prop_assert;
use cvlr::score::cores::{cond_fold, pair_cores, SetCores};
use cvlr::score::cvlr::{split_center, CvLrKernel, NativeCvLrKernel};
use cvlr::score::folds::{stride_folds, CvParams};
use cvlr::stream::FactorState;
use cvlr::util::prop::check;
use cvlr::util::Pcg64;

fn random_mat(rng: &mut Pcg64, n: usize, m: usize) -> Mat {
    let mut x = Mat::zeros(n, m);
    for v in &mut x.data {
        *v = rng.normal();
    }
    x
}

/// Algorithm 1 (ICL): ‖ΛΛᵀ − K‖ ≤ η whenever the returned rank is below
/// the cap (the paper's precision guarantee).
#[test]
fn prop_icl_error_bound() {
    check("icl_error_bound", 25, |rng| {
        let n = 20 + rng.below(60);
        let dim = 1 + rng.below(3);
        let x = random_mat(rng, n, dim);
        let sigma = median_heuristic(&x, 2.0);
        let kern = Kernel::Rbf { sigma };
        let cfg = LowRankConfig { max_rank: n, eta: 1e-6, ..Default::default() };
        let lr = factorize(kern, &x, false, &cfg);
        let k = gram(kern, &x);
        let approx = lr.lambda.matmul_t(&lr.lambda);
        let err = (&k - &approx).frob_norm();
        prop_assert!(
            err < 1e-4,
            "ICL reconstruction error {err} too large at n={n}, rank={}",
            lr.rank
        );
        Ok(())
    });
}

/// Algorithm 2: exact reconstruction for discrete data (Lemma 4.3) and
/// rank bounded by the number of distinct values (Lemma 4.1).
#[test]
fn prop_discrete_decomposition_exact() {
    check("discrete_exact", 25, |rng| {
        let n = 20 + rng.below(80);
        let levels = 2 + rng.below(5);
        let mut x = Mat::zeros(n, 1);
        for r in 0..n {
            x[(r, 0)] = rng.below(levels) as f64;
        }
        let kern = Kernel::Rbf { sigma: 1.0 };
        let lr = factorize(kern, &x, true, &LowRankConfig::default());
        prop_assert!(lr.method == Method::Discrete, "should use Algorithm 2");
        prop_assert!(
            lr.rank <= levels,
            "rank {} exceeds distinct values {levels}",
            lr.rank
        );
        let k = gram(kern, &x);
        let err = (&k - &lr.lambda.matmul_t(&lr.lambda)).max_abs();
        prop_assert!(err < 1e-9, "discrete decomposition not exact: {err}");
        Ok(())
    });
}

/// Centered factor reproduces the centered kernel: Λ̃Λ̃ᵀ ≈ HKH.
#[test]
fn prop_center_factor_matches_centered_gram() {
    check("center_factor", 20, |rng| {
        let n = 15 + rng.below(50);
        let x = random_mat(rng, n, 2);
        let kern = Kernel::Rbf { sigma: median_heuristic(&x, 2.0) };
        let cfg = LowRankConfig { max_rank: n, eta: 1e-8, ..Default::default() };
        let lr = factorize(kern, &x, false, &cfg);
        let lam_c = center_factor(&lr.lambda);
        let want = center_gram(&gram(kern, &x));
        let got = lam_c.matmul_t(&lam_c);
        let err = (&want - &got).max_abs();
        prop_assert!(err < 1e-5, "centered factor mismatch: {err}");
        Ok(())
    });
}

/// The dumbbell-form conditional score is invariant under orthogonal
/// rotation of the factor columns (ΛR with RRᵀ = I leaves ΛΛᵀ, hence the
/// score, unchanged) — a strong algebraic check on the §5 rewriting.
#[test]
fn prop_score_invariant_under_factor_rotation() {
    check("rotation_invariance", 15, |rng| {
        let n = 60 + rng.below(60);
        let m = 3 + rng.below(4);
        let lx = random_mat(rng, n, m);
        let lz = random_mat(rng, n, m);
        // random Givens rotation on columns (i, j)
        let rotate = |mat: &Mat, i: usize, j: usize, th: f64| {
            let (c, s) = (th.cos(), th.sin());
            let mut out = mat.clone();
            for r in 0..mat.rows {
                let (a, b) = (mat[(r, i)], mat[(r, j)]);
                out[(r, i)] = c * a - s * b;
                out[(r, j)] = s * a + c * b;
            }
            out
        };
        let th = rng.uniform_in(0.0, std::f64::consts::TAU);
        let (i, j) = (0, 1 + rng.below(m - 1));
        let folds = stride_folds(n, 5);
        let (test, train) = &folds[0];
        let p = CvParams::default();
        let k = NativeCvLrKernel;
        let (lx0, lx1) = split_center(&lx, test, train);
        let (lz0, lz1) = split_center(&lz, test, train);
        let lxr = rotate(&lx, i, j, th);
        let (lxr0, lxr1) = split_center(&lxr, test, train);
        let a = k.score_cond(&lx0, &lx1, &lz0, &lz1, &p);
        let b = k.score_cond(&lxr0, &lxr1, &lz0, &lz1, &p);
        prop_assert!(
            ((a - b) / a).abs() < 1e-9,
            "rotation changed the score: {a} vs {b}"
        );
        Ok(())
    });
}

/// Zero-row padding invariance: appending zero rows to *post-centering*
/// factors leaves Gram cores, hence the score, unchanged — the invariance
/// the fixed-shape artifacts rely on (DESIGN.md §2).
#[test]
fn prop_zero_row_padding_invariance() {
    check("zero_row_padding", 15, |rng| {
        let n = 50 + rng.below(50);
        let m = 2 + rng.below(4);
        let lx = random_mat(rng, n, m);
        let lz = random_mat(rng, n, m);
        let folds = stride_folds(n, 5);
        let (test, train) = &folds[1];
        let p = CvParams::default();
        let k = NativeCvLrKernel;
        let (lx0, lx1) = split_center(&lx, test, train);
        let (lz0, lz1) = split_center(&lz, test, train);
        let padr = |mat: &Mat| mat.pad_to(mat.rows + 13, mat.cols);
        let a = k.score_cond(&lx0, &lx1, &lz0, &lz1, &p);
        // NOTE: n₀/n₁ enter as explicit scalars via CvParams-independent
        // row counts, so row padding must go through the kernel API that
        // receives true sizes. The native kernel reads rows from the Mat:
        // padding rows *changes* n — so instead verify the Gram-core
        // identity directly: cores from padded factors match unpadded.
        let cores_match = {
            let c1 = lx1.t_matmul(&lz1);
            let c2 = padr(&lx1).t_matmul(&padr(&lz1));
            (&c1 - &c2).max_abs() < 1e-12
        };
        prop_assert!(cores_match, "zero rows changed a Gram core");
        let _ = a;
        Ok(())
    });
}

/// The fold-core engine invariant: for every fold, the downdated
/// provider cores (`score::cores` — one full-data Gram pass, per-fold
/// test-block downdates, rank-one mean corrections) must give the same
/// CV-LR scores as the retained straight-line reference (`split_center`
/// + direct `t_matmul` cores), across continuous / discrete / mixed
/// data, rank-capped factors, thread counts, and Q ∈ {2, 5, 10}.
/// Tolerance 1e-9 relative; 1e-12 on the all-discrete path (Algorithm 2
/// factors, where the paper's Lemma 4.3 exactness must survive the
/// downdating arithmetic).
#[test]
fn prop_fold_cores_match_reference() {
    check("fold_cores_vs_reference", 18, |rng| {
        let q = [2usize, 5, 10][rng.below(3)];
        let n = 2 * q + 30 + rng.below(80);
        // 0 = continuous, 1 = discrete, 2 = mixed (cont + level codes)
        let kind = rng.below(3);
        let discrete = kind == 1;
        let block = |rng: &mut Pcg64| -> Mat {
            match kind {
                0 => random_mat(rng, n, 1 + rng.below(2)),
                1 => {
                    let levels = 2 + rng.below(4);
                    let mut m = Mat::zeros(n, 1);
                    for r in 0..n {
                        m[(r, 0)] = rng.below(levels) as f64;
                    }
                    m
                }
                _ => {
                    let cont = random_mat(rng, n, 1);
                    let levels = 2 + rng.below(3);
                    let mut disc = Mat::zeros(n, 1);
                    for r in 0..n {
                        disc[(r, 0)] = rng.below(levels) as f64;
                    }
                    cont.hcat(&disc)
                }
            }
        };
        let xb = block(rng);
        let zb = block(rng);
        // rank-capped factors half the time: the provider must agree
        // with the reference whatever factor the cap produced
        let cap = if rng.below(2) == 1 { 6 + rng.below(10) } else { n };
        let cfg = LowRankConfig { max_rank: cap, eta: 1e-9, ..Default::default() };
        let kern = |b: &Mat| {
            if discrete {
                Kernel::Rbf { sigma: 1.0 }
            } else {
                Kernel::Rbf { sigma: median_heuristic(b, 2.0) }
            }
        };
        let lx = factorize(kern(&xb), &xb, discrete, &cfg).lambda;
        let lz = factorize(kern(&zb), &zb, discrete, &cfg).lambda;

        let folds = stride_folds(n, q);
        let threads = 1 + rng.below(4);
        let x_cores = SetCores::build(&lx, &folds, threads);
        let z_cores = SetCores::build(&lz, &folds, threads);
        let pc = pair_cores(&z_cores, &x_cores, threads);

        let p = CvParams::default();
        let k = NativeCvLrKernel;
        let tol = if discrete { 1e-12 } else { 1e-9 };
        for (f, (test, train)) in folds.iter().enumerate() {
            let (lx0, lx1) = split_center(&lx, test, train);
            let (lz0, lz1) = split_center(&lz, test, train);
            let cond_ref = k.score_cond(&lx0, &lx1, &lz0, &lz1, &p);
            let cond_got = k.score_cond_cores(&cond_fold(&x_cores, &z_cores, &pc, f), &p);
            let rel = ((cond_got - cond_ref) / cond_ref).abs();
            prop_assert!(
                rel < tol,
                "cond fold {f} (q={q}, kind={kind}, cap={cap}): downdated {cond_got} \
                 vs reference {cond_ref} (rel {rel})"
            );
            let marg_ref = k.score_marg(&lx0, &lx1, &p);
            let marg_got = k.score_marg_cores(&x_cores.marg_fold(f), &p);
            let relm = ((marg_got - marg_ref) / marg_ref).abs();
            prop_assert!(
                relm < tol,
                "marg fold {f} (q={q}, kind={kind}, cap={cap}): downdated {marg_got} \
                 vs reference {marg_ref} (rel {relm})"
            );
        }
        Ok(())
    });
}

/// Streaming appends (the `stream` subsystem invariant): across random
/// chunk splits, append-then-score equals refactorize-then-score within
/// 1e-6 for both continuous (ICL) and discrete (Algorithm 2) variables
/// — and when the appended-residual budget forces a re-pivot, the
/// factor is bit-for-bit the cold refactorization.
#[test]
fn prop_stream_append_matches_refactorize() {
    check("stream_append_vs_refactorize", 16, |rng| {
        let n = 60 + rng.below(80);
        let discrete = rng.below(2) == 1;
        let x = if discrete {
            let levels = 2 + rng.below(5);
            let mut m = Mat::zeros(n, 1);
            for r in 0..n {
                m[(r, 0)] = rng.below(levels) as f64;
            }
            m
        } else {
            random_mat(rng, n, 1)
        };
        let kern = if discrete {
            Kernel::Rbf { sigma: 1.0 }
        } else {
            Kernel::Rbf { sigma: median_heuristic(&x, 2.0) }
        };
        // tight η keeps both factorizations within 1e-9 of K, so the
        // 1e-6 score comparison has headroom whichever pivots greedy
        // selection lands on
        let cfg = LowRankConfig { max_rank: n, eta: 1e-9, ..Default::default() };

        // random 3-way chunk split
        let c1 = n / 3 + rng.below(n / 4);
        let c2 = c1 + 1 + rng.below(n - c1 - 1);
        let head = x.select_rows(&(0..c1).collect::<Vec<_>>());
        let mid = x.select_rows(&(c1..c2).collect::<Vec<_>>());
        let tail = x.select_rows(&(c2..n).collect::<Vec<_>>());

        let mut st = FactorState::new(kern, &head, discrete, &cfg);
        let part = x.select_rows(&(0..c2).collect::<Vec<_>>());
        let out1 = st.append(&mid, &|| part.clone());
        let out2 = st.append(&tail, &|| x.clone());
        prop_assert!(st.lambda().rows == n, "all rows folded in");

        let cold = FactorState::new(kern, &x, discrete, &cfg);
        if out2.repivoted {
            // a re-pivot on the final chunk IS the cold factorization
            prop_assert!(
                st.lambda().data == cold.lambda().data,
                "re-pivoted factor must equal the cold one bit-for-bit"
            );
        }

        // score comparison through one CV fold of the conditional score
        // (X | X lagged by using the same factor for x and z is
        // degenerate, so score X against an independent random factor)
        let folds = stride_folds(n, 5);
        let (test, train) = &folds[0];
        let lz = random_mat(rng, n, 2);
        let p = CvParams::default();
        let k = NativeCvLrKernel;
        let (lz0, lz1) = split_center(&lz, test, train);
        let streamed_lam = st.lambda();
        let (sx0, sx1) = split_center(&streamed_lam, test, train);
        let cold_lam = cold.lambda();
        let (cx0, cx1) = split_center(&cold_lam, test, train);
        let s_stream = k.score_cond(&sx0, &sx1, &lz0, &lz1, &p);
        let s_cold = k.score_cond(&cx0, &cx1, &lz0, &lz1, &p);
        let rel = ((s_stream - s_cold) / s_cold).abs();
        prop_assert!(
            rel < 1e-6,
            "append-then-score {s_stream} vs refactorize-then-score {s_cold} \
             (rel {rel}, discrete={discrete}, repivoted={})",
            out1.repivoted || out2.repivoted
        );

        if discrete && !out1.repivoted && !out2.repivoted {
            // Algorithm 2 stays exact across appends
            let err = (&st.lambda().matmul_t(&st.lambda()) - &gram(kern, &x)).max_abs();
            prop_assert!(err < 1e-9, "discrete append lost exactness: {err}");
        }
        Ok(())
    });
}

/// RFF reconstruction error stays inside the Hoeffding Monte-Carlo
/// bound across the feature-count ladder m ∈ {50, 100, 200}: each
/// (ΛΛᵀ)_ij is the mean of m terms 2·cos·cos ∈ [−2, 2], so
/// `P(|K_ij − (ΛΛᵀ)_ij| > t) ≤ 2·exp(−m·t²/8)`; a union bound over the
/// n(n+1)/2 distinct entries at failure mass δ = 1e-6 gives
/// `t = √(8·ln(2·pairs/δ)/m)`. The bound is loose (it assumes nothing
/// about the kernel), which is exactly why it must never be violated.
#[test]
fn prop_rff_reconstruction_within_mc_bound() {
    check("rff_mc_bound", 10, |rng| {
        let n = 30 + rng.below(30);
        let dim = 1 + rng.below(2);
        let x = random_mat(rng, n, dim);
        let kern = Kernel::Rbf { sigma: median_heuristic(&x, 2.0) };
        let k = gram(kern, &x);
        let pairs = (n * (n + 1) / 2) as f64;
        let mut errs = Vec::new();
        for m in [50usize, 100, 200] {
            let cfg = LowRankConfig {
                max_rank: m,
                method: FactorMethod::Rff,
                rff_seed: rng.next_u64(),
                ..Default::default()
            };
            let lr = factorize(kern, &x, false, &cfg);
            prop_assert!(lr.method == Method::Rff, "dispatch must pick RFF at m={m}");
            prop_assert!(lr.rank == m, "RFF uses the full feature budget");
            prop_assert!(!lr.fell_back, "RBF kernels never fall back");
            let err = (&k - &lr.lambda.matmul_t(&lr.lambda)).max_abs();
            let bound = (8.0 * (2.0 * pairs / 1e-6).ln() / m as f64).sqrt();
            prop_assert!(
                err < bound,
                "m={m}: max entry error {err} exceeds the Monte-Carlo bound {bound}"
            );
            errs.push(err);
        }
        // the O(1/√m) trend: quadrupling m must not grow the error by
        // more than the Monte-Carlo noise allows (generous 1.5× slack)
        prop_assert!(
            errs[2] < 1.5 * errs[0],
            "error failed to shrink along m ∈ {{50,100,200}}: {errs:?}"
        );
        Ok(())
    });
}

/// dag → cpdag → consistent-extension dag round trip stays in the same
/// equivalence class (identical CPDAG re-completion).
#[test]
fn prop_cpdag_roundtrip() {
    check("cpdag_roundtrip", 30, |rng| {
        let d = 4 + rng.below(5);
        let dag = random_dag(d, 0.2 + 0.6 * rng.uniform(), rng);
        let cpdag = dag_to_cpdag(&dag);
        let dag2 = match cpdag.to_dag() {
            Some(g) => g,
            None => return Err("CPDAG has no consistent extension".into()),
        };
        let cpdag2 = dag_to_cpdag(&dag2);
        prop_assert!(cpdag == cpdag2, "round trip left the equivalence class");
        Ok(())
    });
}

/// Metric bounds: 0 ≤ F1 ≤ 1, 0 ≤ nSHD; perfect estimate ⇒ F1 = 1 and
/// nSHD = 0.
#[test]
fn prop_metric_bounds() {
    check("metric_bounds", 30, |rng| {
        let d = 4 + rng.below(5);
        let truth = random_dag(d, 0.2 + 0.6 * rng.uniform(), rng);
        let est_dag = random_dag(d, 0.2 + 0.6 * rng.uniform(), rng);
        let est = dag_to_cpdag(&est_dag);
        let f1 = skeleton_f1(&est, &truth);
        let shd = normalized_shd(&est, &truth);
        prop_assert!((0.0..=1.0).contains(&f1), "F1 out of range: {f1}");
        prop_assert!(shd >= 0.0, "SHD negative: {shd}");
        let perfect = dag_to_cpdag(&truth);
        prop_assert!(skeleton_f1(&perfect, &truth) == 1.0, "perfect F1 != 1");
        prop_assert!(normalized_shd(&perfect, &truth) == 0.0, "perfect SHD != 0");
        Ok(())
    });
}

/// stride_folds is a partition: every sample appears in exactly one test
/// fold, and test ∪ train = all samples in every fold.
#[test]
fn prop_folds_partition() {
    check("folds_partition", 30, |rng| {
        let q = 2 + rng.below(9);
        let n = 2 * q + rng.below(300);
        let folds = stride_folds(n, q);
        prop_assert!(folds.len() == q, "wrong fold count");
        let mut test_seen = vec![0usize; n];
        for (test, train) in &folds {
            prop_assert!(test.len() + train.len() == n, "fold does not cover data");
            let mut all: Vec<usize> = test.iter().chain(train.iter()).cloned().collect();
            all.sort_unstable();
            prop_assert!(all == (0..n).collect::<Vec<_>>(), "fold not a partition");
            for &t in test {
                test_seen[t] += 1;
            }
        }
        prop_assert!(
            test_seen.iter().all(|&c| c == 1),
            "samples must be tested exactly once"
        );
        Ok(())
    });
}

/// Synthetic generator invariants: requested density is met, data shape
/// matches, discrete flags are consistent with integer levels.
#[test]
fn prop_synth_generator_shape() {
    check("synth_shape", 15, |rng| {
        let density = 0.2 + 0.6 * rng.uniform();
        let kind = match rng.below(3) {
            0 => DataKind::Continuous,
            1 => DataKind::Mixed,
            _ => DataKind::MultiDim,
        };
        let cfg = SynthConfig {
            n: 60 + rng.below(100),
            num_vars: 5 + rng.below(3),
            density,
            kind,
            seed: rng.next_u64(),
        };
        let (ds, dag) = generate(&cfg);
        prop_assert!(ds.n() == cfg.n, "sample count mismatch");
        prop_assert!(ds.d() == cfg.num_vars, "variable count mismatch");
        let max_edges = cfg.num_vars * (cfg.num_vars - 1) / 2;
        let want = (density * max_edges as f64).round() as usize;
        prop_assert!(
            dag.num_edges() == want.min(max_edges),
            "edge count {} != requested {}",
            dag.num_edges(),
            want
        );
        prop_assert!(dag.topological_order().is_some(), "generator emitted a cyclic graph");
        Ok(())
    });
}

/// The marginal dumbbell score equals the conditional score algebra in
/// the limit of an (almost) zero conditional factor — consistency between
/// the |z|=0 and |z|≠0 code paths.
#[test]
fn prop_marginal_consistent_with_tiny_z() {
    check("marg_vs_cond_limit", 10, |rng| {
        let n = 60 + rng.below(40);
        let m = 2 + rng.below(3);
        let lx = random_mat(rng, n, m);
        // a near-zero Z factor: K̃_Z ≈ 0 so the regression on Z predicts
        // the mean, matching the marginal model up to the γ-scaled terms.
        let lz = random_mat(rng, n, 1).scale(1e-9);
        let folds = stride_folds(n, 5);
        let (test, train) = &folds[0];
        let p = CvParams::default();
        let k = NativeCvLrKernel;
        let (lx0, lx1) = split_center(&lx, test, train);
        let (lz0, lz1) = split_center(&lz, test, train);
        let cond = k.score_cond(&lx0, &lx1, &lz0, &lz1, &p);
        let marg = k.score_marg(&lx0, &lx1, &p);
        // The two scores differ in their λ-vs-γ normalization; what must
        // match is the *ordering scale*: they agree to ~1% of magnitude.
        prop_assert!(
            ((cond - marg) / marg).abs() < 0.05,
            "cond with Z≈0 ({cond}) should approach marg ({marg})"
        );
        Ok(())
    });
}

/// Dataset.block_multi stacks the right columns in sorted-var order and
/// standardization yields zero mean / unit variance.
#[test]
fn prop_dataset_blocks() {
    check("dataset_blocks", 20, |rng| {
        let n = 30 + rng.below(80);
        let d = 3 + rng.below(4);
        let data = random_mat(rng, n, d);
        let orig = data.clone();
        let ds = Dataset::from_columns(data, &vec![false; d]);
        let idx = vec![0, d - 1];
        let block = ds.block_multi(&idx);
        prop_assert!(block.rows == n, "block rows");
        // dataset may standardize columns internally; verify shape and
        // that single-var blocks agree with block_multi columns.
        let b0 = ds.block(0);
        for r in 0..n {
            prop_assert!(
                (block[(r, 0)] - b0[(r, 0)]).abs() < 1e-12,
                "block_multi and block disagree"
            );
        }
        let _ = orig;
        Ok(())
    });
}

/// Kernel Gram matrices are symmetric PSD (up to jitter) for RBF on
/// random data — ICL and Cholesky correctness depends on it.
#[test]
fn prop_rbf_gram_symmetric_psd() {
    check("rbf_gram_psd", 15, |rng| {
        let n = 10 + rng.below(30);
        let x = random_mat(rng, n, 2);
        let k = gram(Kernel::Rbf { sigma: median_heuristic(&x, 2.0) }, &x);
        prop_assert!(k.is_symmetric(1e-12), "gram not symmetric");
        // diagonal of an RBF gram is exactly 1
        for i in 0..n {
            prop_assert!((k[(i, i)] - 1.0).abs() < 1e-12, "diag not 1");
        }
        // PSD check via Cholesky with tiny jitter
        let chol = cvlr::linalg::Cholesky::new(&k.add_diag(1e-10));
        prop_assert!(chol.is_some(), "gram + 1e-10 I not PD");
        Ok(())
    });
}

// ---- PDAG machinery tier (see `graph::pdag`'s debug hooks; the
// schedule explorer in `util::model` covers the concurrency side) ----

/// `meek_closure` is idempotent: once the R1-R4 fixpoint is reached, a
/// second closure over the result changes nothing — over random CPDAGs
/// with extra random (acyclicity-respecting) orientations layered on.
#[test]
fn prop_meek_closure_idempotent() {
    check("meek_closure_idempotent", 30, |rng| {
        let d = 4 + rng.below(5);
        let dag = random_dag(d, 0.2 + 0.6 * rng.uniform(), rng);
        let order = dag.topological_order().expect("random_dag is a DAG");
        let mut p = dag_to_cpdag(&dag);
        // orient a few undirected edges along the DAG's topological
        // order, so the input stays extendable and cycle-free
        let mut pos = vec![0usize; d];
        for (i, &v) in order.iter().enumerate() {
            pos[v] = i;
        }
        for a in 0..d {
            for b in 0..d {
                if p.undirected(a, b) && pos[a] < pos[b] && rng.below(3) == 0 {
                    p.orient(a, b);
                }
            }
        }
        p.meek_closure();
        let closed = p.clone();
        p.meek_closure();
        prop_assert!(p == closed, "second meek_closure changed the graph");
        Ok(())
    });
}

/// `dag_to_cpdag` produces a valid CPDAG: same skeleton as the DAG,
/// every v-structure kept directed, and an acyclic directed part.
#[test]
fn prop_dag_to_cpdag_is_valid_cpdag() {
    check("dag_to_cpdag_valid", 30, |rng| {
        let d = 4 + rng.below(5);
        let dag = random_dag(d, 0.2 + 0.6 * rng.uniform(), rng);
        let c = dag_to_cpdag(&dag);
        for i in 0..d {
            for j in (i + 1)..d {
                let in_dag = dag.has_edge(i, j) || dag.has_edge(j, i);
                prop_assert!(
                    c.adjacent(i, j) == in_dag,
                    "skeleton differs at ({i},{j})"
                );
            }
        }
        // v-structures x→z←y (x,y nonadjacent) are compelled
        for z in 0..d {
            let parents = dag.parents(z);
            for (a, &x) in parents.iter().enumerate() {
                for &y in parents.iter().skip(a + 1) {
                    if !dag.has_edge(x, y) && !dag.has_edge(y, x) {
                        prop_assert!(
                            c.directed(x, z) && c.directed(y, z),
                            "v-structure {x}\u{2192}{z}\u{2190}{y} lost"
                        );
                    }
                }
            }
        }
        prop_assert!(c.directed_part_acyclic(), "CPDAG directed part has a cycle");
        Ok(())
    });
}

/// `orient` refuses to flip a compelled (already directed) edge — the
/// debug hook panics rather than corrupting the equivalence class.
#[test]
fn prop_orient_rejects_compelled_flip() {
    use cvlr::graph::pdag::Pdag;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    check("orient_rejects_flip", 20, |rng| {
        let d = 3 + rng.below(4);
        let i = rng.below(d);
        let j = (i + 1 + rng.below(d - 1)) % d;
        let mut p = Pdag::new(d);
        p.add_directed(i, j);
        let flipped = catch_unwind(AssertUnwindSafe(|| {
            let mut q = p.clone();
            q.orient(j, i);
        }));
        prop_assert!(flipped.is_err(), "orient({j},{i}) over {i}\u{2192}{j} must panic");
        // the legal direction is a no-op re-orientation, not a panic
        let kept = catch_unwind(AssertUnwindSafe(|| {
            let mut q = p.clone();
            q.orient(i, j);
            q
        }));
        match kept {
            Ok(q) => prop_assert!(q.directed(i, j), "re-orientation dropped the edge"),
            Err(_) => return Err("orienting the existing direction must not panic".into()),
        }
        Ok(())
    });
}
