//! Property tests for the server's strict JSON codec (mini-prop
//! harness; see `cvlr::util::prop`): encode∘parse round trips on
//! generated values, encoder determinism, and malformed-input rejection
//! without panics.

use cvlr::prop_assert;
use cvlr::server::json::{parse, Json};
use cvlr::util::prop::check;
use cvlr::util::Pcg64;

fn gen_string(rng: &mut Pcg64) -> String {
    let len = rng.below(12);
    (0..len)
        .map(|_| match rng.below(8) {
            0 => '"',
            1 => '\\',
            2 => '/',
            // control characters must be escaped by the encoder
            3 => char::from_u32(rng.below(0x20) as u32).unwrap(),
            // multi-byte code points
            4 => 'π',
            5 => '😀',
            _ => (b'a' + rng.below(26) as u8) as char,
        })
        .collect()
}

fn gen_num(rng: &mut Pcg64) -> f64 {
    match rng.below(5) {
        0 => rng.below(2000) as f64 - 1000.0,
        1 => rng.normal() * 1e-9,
        2 => rng.normal() * 1e12,
        3 => rng.uniform(),
        _ => 0.0,
    }
}

fn gen_value(rng: &mut Pcg64, depth: usize) -> Json {
    let top = if depth == 0 { 4 } else { 6 };
    match rng.below(top) {
        0 => Json::Null,
        1 => Json::Bool(rng.bernoulli(0.5)),
        2 => Json::Num(gen_num(rng)),
        3 => Json::Str(gen_string(rng)),
        4 => Json::Arr((0..rng.below(5)).map(|_| gen_value(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.below(5))
                .map(|i| (format!("k{i}_{}", gen_string(rng).len()), gen_value(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    check("json_roundtrip", 300, |rng| {
        let v = gen_value(rng, 4);
        let enc = v.encode();
        let back = match parse(&enc) {
            Ok(b) => b,
            Err(e) => return Err(format!("parse of own encoding {enc:?} failed: {e}")),
        };
        prop_assert!(back == v, "roundtrip mismatch for {enc:?}");
        // a second trip is byte-stable (deterministic encoder)
        prop_assert!(back.encode() == enc, "re-encode of {enc:?} not stable");
        Ok(())
    });
}

#[test]
fn prop_json_trailing_garbage_rejected() {
    check("json_trailing_garbage", 200, |rng| {
        let v = gen_value(rng, 3);
        let enc = v.encode() + "x";
        prop_assert!(parse(&enc).is_err(), "{enc:?} must be rejected");
        Ok(())
    });
}

#[test]
fn prop_json_mutations_never_panic() {
    check("json_mutations", 400, |rng| {
        let v = gen_value(rng, 3);
        let enc = v.encode();
        let bytes = enc.as_bytes();
        // truncate at a random char boundary, or splice a random ASCII
        // byte at a random position — the strict parser must reject or
        // accept without panicking, never crash
        let mutated: String = if rng.bernoulli(0.5) && !enc.is_empty() {
            let mut cut = rng.below(bytes.len());
            while !enc.is_char_boundary(cut) {
                cut -= 1;
            }
            enc[..cut].to_string()
        } else {
            let pos_chars: Vec<usize> =
                (0..=enc.len()).filter(|&i| enc.is_char_boundary(i)).collect();
            let at = pos_chars[rng.below(pos_chars.len())];
            let splice = (b' ' + rng.below(95) as u8) as char;
            format!("{}{}{}", &enc[..at], splice, &enc[at..])
        };
        // accepted mutations (e.g. inserted whitespace) must still
        // round-trip through the encoder
        if let Ok(v2) = parse(&mutated) {
            prop_assert!(
                parse(&v2.encode()).is_ok(),
                "accepted mutation {mutated:?} does not re-parse"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_json_malformed_corpus_rejected() {
    // deterministic spot checks shared with the unit suite, run through
    // the harness so failures print the offending case
    let corpus = [
        "{", "}", "[", "]", ",", ":", "{]", "[}", "nulll x", "truefalse", "0x10", "01", "-",
        "1e+", "\"\\u12\"", "\"\\ud800\\ud800\"", "{\"a\":}", "{:1}", "[,]", "\u{0}",
    ];
    for bad in corpus {
        assert!(parse(bad).is_err(), "must reject {bad:?}");
    }
}
