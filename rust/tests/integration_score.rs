//! Score-layer integration: the CV-LR score against the exact CV score
//! (the Table-1 anchor), on every data type of §7.4, plus consistency
//! checks across all five score functions on shared datasets.

use std::sync::Arc;

use cvlr::data::synth::{generate, DataKind, SynthConfig};
use cvlr::data::{networks, Dataset};
use cvlr::linalg::Mat;
use cvlr::lowrank::LowRankConfig;
use cvlr::score::bdeu::BdeuScore;
use cvlr::score::bic::BicScore;
use cvlr::score::cv_exact::CvExactScore;
use cvlr::score::cvlr::{CvLrScore, NativeCvLrKernel};
use cvlr::score::folds::CvParams;
use cvlr::score::sc::ScScore;
use cvlr::coordinator::ScoreService;
use cvlr::score::{graph_score, LocalScore};
use cvlr::util::Pcg64;

fn rel_err(a: f64, b: f64) -> f64 {
    ((a - b) / a).abs()
}

/// Table 1, continuous rows: CV-LR vs CV with m=100 must stay within
/// 0.5% relative error, both with |Z| = 0 and a nonempty conditional set.
#[test]
fn table1_continuous_rel_error() {
    let (ds, _) = generate(&SynthConfig {
        n: 200,
        num_vars: 7,
        density: 0.5,
        kind: DataKind::Continuous,
        seed: 11,
    });
    let ds = Arc::new(ds);
    let exact = CvExactScore::new(ds.clone(), CvParams::default());
    let lr = CvLrScore::native(ds);
    for (target, parents) in [
        (0usize, vec![]),
        (0, vec![1, 2]),
        (3, vec![0, 1, 2, 4, 5, 6]), // |Z| = 6, the paper's hard setting
    ] {
        let se = exact.local_score(target, &parents);
        let sl = lr.local_score(target, &parents);
        assert!(
            rel_err(se, sl) < 5e-3,
            "target {target} |Z|={}: exact {se} vs lr {sl}",
            parents.len()
        );
    }
}

/// Table 1, discrete rows: Algorithm 2 is exact (Lemma 4.3), so the
/// scores must agree to floating-point precision.
#[test]
fn table1_discrete_exact_agreement() {
    let net = networks::sachs();
    let ds = Arc::new(networks::forward_sample(&net, 200, 7));
    let exact = CvExactScore::new(ds.clone(), CvParams::default());
    let lr = CvLrScore::native(ds);
    for (target, parents) in [(0usize, vec![]), (8, vec![2, 7]), (1, vec![0, 8])] {
        let se = exact.local_score(target, &parents);
        let sl = lr.local_score(target, &parents);
        assert!(
            rel_err(se, sl) < 1e-8,
            "discrete target {target}: exact {se} vs lr {sl}"
        );
    }
}

/// Mixed continuous/discrete data (§7.4 middle panels).
#[test]
fn cvlr_matches_cv_on_mixed_data() {
    let (ds, _) = generate(&SynthConfig {
        n: 150,
        num_vars: 7,
        density: 0.4,
        kind: DataKind::Mixed,
        seed: 3,
    });
    let ds = Arc::new(ds);
    let exact = CvExactScore::new(ds.clone(), CvParams::default());
    let lr = CvLrScore::native(ds);
    for (target, parents) in [(0usize, vec![]), (1, vec![0]), (4, vec![2, 3])] {
        let se = exact.local_score(target, &parents);
        let sl = lr.local_score(target, &parents);
        assert!(rel_err(se, sl) < 1e-2, "mixed: exact {se} vs lr {sl}");
    }
}

/// Multi-dimensional variables (§7.4 right panels): variables span
/// several columns; scores must still agree.
#[test]
fn cvlr_matches_cv_on_multidim_data() {
    let (ds, _) = generate(&SynthConfig {
        n: 150,
        num_vars: 5,
        density: 0.4,
        kind: DataKind::MultiDim,
        seed: 4,
    });
    let ds = Arc::new(ds);
    let exact = CvExactScore::new(ds.clone(), CvParams::default());
    let lr = CvLrScore::native(ds);
    for (target, parents) in [(0usize, vec![]), (2, vec![0, 1])] {
        let se = exact.local_score(target, &parents);
        let sl = lr.local_score(target, &parents);
        assert!(rel_err(se, sl) < 1e-2, "multidim: exact {se} vs lr {sl}");
    }
}

/// §7.2 m-sweep: raising the rank cap must not make the approximation
/// worse on continuous data (monotone-ish; we assert the m=100 error is
/// no worse than the m=10 error).
#[test]
fn rank_cap_improves_approximation() {
    let (ds, _) = generate(&SynthConfig {
        n: 200,
        num_vars: 7,
        density: 0.5,
        kind: DataKind::Continuous,
        seed: 5,
    });
    let ds = Arc::new(ds);
    let exact = CvExactScore::new(ds.clone(), CvParams::default());
    let se = exact.local_score(3, &[0, 1, 2, 4, 5, 6]);
    let err_at = |m: usize| {
        let lr = CvLrScore::with_backend(
            ds.clone(),
            CvParams::default(),
            LowRankConfig { max_rank: m, eta: 1e-6, ..Default::default() },
            NativeCvLrKernel,
        );
        rel_err(se, lr.local_score(3, &[0, 1, 2, 4, 5, 6]))
    };
    let e10 = err_at(10);
    let e100 = err_at(100);
    assert!(
        e100 <= e10 + 1e-12,
        "m=100 must not be worse than m=10: {e100} vs {e10}"
    );
    assert!(e100 < 5e-3, "m=100 must satisfy the paper's 0.5% bound: {e100}");
}

/// Local consistency (Definition 6.1) holds for both CV and CV-LR on a
/// strongly-dependent pair: the true parent improves the score, and the
/// direction of the inequality agrees between the two scores.
#[test]
fn local_consistency_cv_and_cvlr_agree() {
    let mut rng = Pcg64::new(9);
    let n = 300;
    let mut data = Mat::zeros(n, 3);
    for r in 0..n {
        let x = rng.normal();
        let y = (1.5 * x).tanh() + 0.3 * rng.normal();
        let w = rng.normal();
        data[(r, 0)] = x;
        data[(r, 1)] = y;
        data[(r, 2)] = w;
    }
    let ds = Arc::new(Dataset::from_columns(data, &[false; 3]));
    let exact = CvExactScore::new(ds.clone(), CvParams::default());
    let lr = CvLrScore::native(ds);
    for score in [&exact as &dyn LocalScore, &lr as &dyn LocalScore] {
        let with_parent = score.local_score(1, &[0]);
        let marginal = score.local_score(1, &[]);
        assert!(
            with_parent > marginal,
            "dependent parent must raise the score: {with_parent} vs {marginal}"
        );
    }
}

/// graph_score decomposability: the DAG score is the sum of local
/// scores for every score function (Eq. 31).
#[test]
fn graph_score_decomposes_for_all_scores() {
    let (ds, dag) = generate(&SynthConfig {
        n: 150,
        num_vars: 5,
        density: 0.4,
        kind: DataKind::Continuous,
        seed: 6,
    });
    let ds = Arc::new(ds);
    let parents = dag.parent_list();
    let scores: Vec<Box<dyn LocalScore>> = vec![
        Box::new(CvLrScore::native(ds.clone())),
        Box::new(BicScore::new(ds.clone())),
        Box::new(ScScore::new(ds.clone())),
    ];
    for s in &scores {
        let total = graph_score(s.as_ref(), &parents);
        let manual: f64 = parents
            .iter()
            .enumerate()
            .map(|(i, pa)| {
                let mut p = pa.clone();
                p.sort_unstable();
                s.local_score(i, &p)
            })
            .sum();
        assert!(
            (total - manual).abs() < 1e-9,
            "decomposability violated: {total} vs {manual}"
        );
    }
}

/// BDeu on discrete network data prefers the true parents over the
/// empty set for a high-signal child.
#[test]
fn bdeu_prefers_true_parents() {
    let net = networks::child();
    let ds = Arc::new(networks::forward_sample(&net, 800, 13));
    let bdeu = BdeuScore::new(ds);
    // find a node with parents in the true network
    let truth = &net.dag;
    let mut checked = 0;
    for v in 0..truth.parent_list().len() {
        let pa = truth.parents(v);
        if pa.is_empty() {
            continue;
        }
        let mut pa_sorted = pa.clone();
        pa_sorted.sort_unstable();
        let with = bdeu.local_score(v, &pa_sorted);
        let without = bdeu.local_score(v, &[]);
        if with > without {
            checked += 1;
        }
    }
    assert!(
        checked >= 15,
        "BDeu should prefer true parents for most CHILD nodes, got {checked}"
    );
}

/// The service's memo cache returns bit-identical values and actually
/// avoids re-evaluation of the expensive CV-LR score.
#[test]
fn cached_cvlr_identical_and_hits() {
    let (ds, _) = generate(&SynthConfig {
        n: 150,
        num_vars: 5,
        density: 0.4,
        kind: DataKind::Continuous,
        seed: 8,
    });
    let cached = ScoreService::new(Arc::new(CvLrScore::native(Arc::new(ds))), 1);
    let a = cached.local_score(2, &[0, 1]);
    let b = cached.local_score(2, &[1, 0]);
    assert_eq!(a, b, "cache must canonicalize the parent order");
    let st = cached.stats();
    assert_eq!((st.cache_hits, st.evaluations), (1, 1));
    assert!(st.consistent(), "{st:?}");
}

/// Score is invariant to permuting the samples (both CV folds use
/// strided assignment, so a global permutation changes fold membership;
/// instead we check invariance of the underlying factor Gram products
/// by scoring two datasets with identical rows in the same order twice).
#[test]
fn score_is_deterministic() {
    let (ds, _) = generate(&SynthConfig {
        n: 150,
        num_vars: 5,
        density: 0.4,
        kind: DataKind::Continuous,
        seed: 10,
    });
    let ds = Arc::new(ds);
    let s1 = CvLrScore::native(ds.clone());
    let s2 = CvLrScore::native(ds);
    let a = s1.local_score(1, &[0, 3]);
    let b = s2.local_score(1, &[0, 3]);
    assert_eq!(a, b, "same data, same params → bit-identical score");
}

/// Larger conditioning sets reduce the residual trace but pay a
/// complexity penalty: a fully-spurious 4-parent set should not beat the
/// true single parent on strongly-coupled data.
#[test]
fn spurious_parents_do_not_dominate() {
    let mut rng = Pcg64::new(12);
    let n = 300;
    let mut data = Mat::zeros(n, 6);
    for r in 0..n {
        let x = rng.normal();
        let y = (2.0 * x).sin() + 0.2 * rng.normal();
        data[(r, 0)] = x;
        data[(r, 1)] = y;
        for c in 2..6 {
            data[(r, c)] = rng.normal();
        }
    }
    let ds = Arc::new(Dataset::from_columns(data, &[false; 6]));
    let lr = CvLrScore::native(ds);
    let true_parent = lr.local_score(1, &[0]);
    let spurious = lr.local_score(1, &[2, 3, 4, 5]);
    assert!(
        true_parent > spurious,
        "true parent {true_parent} must beat 4 spurious parents {spurious}"
    );
}
