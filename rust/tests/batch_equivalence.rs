//! Batch/scalar equivalence invariants (mini-prop harness, see
//! `cvlr::util::prop`):
//!
//! * `score_batch(reqs)[i]` is **bit-for-bit** equal to
//!   `local_score(reqs[i])` across every backend — CV-LR native, exact
//!   CV, BIC, BDeu, SC — on permuted/duplicated parent-set inputs,
//!   with and without the service's cache/worker layers on top;
//! * batched GES (service-routed, collect-then-submit) returns the
//!   same CPDAG as the serial scalar-scored search on fixed synthetic
//!   seeds — the regression pin for the batch-first search rework;
//! * the `ServiceStats` accounting identity holds end to end and GES
//!   actually drives wide batches (`batches > 0`, `max_batch > 1`).

use std::sync::Arc;

use cvlr::coordinator::ScoreService;
use cvlr::data::synth::{generate, DataKind, SynthConfig};
use cvlr::prop_assert;
use cvlr::score::bdeu::BdeuScore;
use cvlr::score::bic::BicScore;
use cvlr::score::cv_exact::CvExactScore;
use cvlr::score::cvlr::CvLrScore;
use cvlr::score::folds::CvParams;
use cvlr::score::sc::ScScore;
use cvlr::score::{LocalScore, ScalarBackend, ScoreBackend, ScoreRequest};
use cvlr::search::ges::{ges, GesConfig};
use cvlr::util::prop::check;
use cvlr::util::Pcg64;

/// A random GES-like batch: small parent sets in random order, with
/// duplicated entries and duplicated whole requests.
fn random_batch(rng: &mut Pcg64, d: usize, len: usize) -> Vec<ScoreRequest> {
    let mut reqs = Vec::with_capacity(len);
    for _ in 0..len {
        if !reqs.is_empty() && rng.bernoulli(0.2) {
            // duplicate an earlier request verbatim
            let i = rng.below(reqs.len());
            let dup = reqs[i].clone();
            reqs.push(dup);
            continue;
        }
        let t = rng.below(d);
        let k = rng.below(3);
        // sampled with replacement: duplicates and arbitrary order
        let pa: Vec<usize> = (0..k)
            .map(|_| {
                let mut v = rng.below(d);
                while v == t {
                    v = rng.below(d);
                }
                v
            })
            .collect();
        reqs.push(ScoreRequest::new(t, &pa));
    }
    reqs
}

/// Assert `backend.score_batch == scalar local_score`, bit for bit, for
/// the raw backend and for the service-wrapped backend at 1 and 3
/// workers.
fn assert_batch_scalar_equal<B, S>(
    backend: &B,
    scalar: &S,
    reqs: &[ScoreRequest],
    label: &str,
) -> Result<(), String>
where
    B: ScoreBackend,
    S: LocalScore,
{
    let batch = backend.score_batch(reqs);
    for (i, r) in reqs.iter().enumerate() {
        let want = scalar.local_score(r.target, &r.parents);
        prop_assert!(
            batch[i] == want,
            "{label}: batch[{i}] = {} != scalar {} for ({}, {:?})",
            batch[i],
            want,
            r.target,
            r.parents
        );
    }
    Ok(())
}

#[test]
fn prop_batch_matches_scalar_continuous_backends() {
    let (ds, _) = generate(&SynthConfig {
        n: 60,
        num_vars: 5,
        density: 0.4,
        kind: DataKind::Continuous,
        seed: 77,
    });
    let ds = Arc::new(ds);
    let cvlr = CvLrScore::native(ds.clone());
    let exact = CvExactScore::new(ds.clone(), CvParams::default());
    let bic = BicScore::new(ds.clone());
    let sc = ScScore::new(ds.clone());
    check("batch_scalar_continuous", 8, |rng| {
        let reqs = random_batch(rng, 5, 12);
        // CV-LR implements ScoreBackend natively (shared fold splits)
        assert_batch_scalar_equal(&cvlr, &cvlr, &reqs, "cv-lr native")?;
        assert_batch_scalar_equal(&ScalarBackend(&exact), &exact, &reqs, "cv exact")?;
        assert_batch_scalar_equal(&ScalarBackend(&bic), &bic, &reqs, "bic")?;
        assert_batch_scalar_equal(&ScalarBackend(&sc), &sc, &reqs, "sc")?;
        Ok(())
    });
}

#[test]
fn prop_batch_matches_scalar_discrete_backends() {
    let (ds, _) = generate(&SynthConfig {
        n: 80,
        num_vars: 4,
        density: 0.4,
        kind: DataKind::Mixed,
        seed: 78,
    });
    let ds = Arc::new(ds);
    let cvlr = CvLrScore::native(ds.clone());
    check("batch_scalar_mixed_cvlr", 6, |rng| {
        let reqs = random_batch(rng, 4, 10);
        assert_batch_scalar_equal(&cvlr, &cvlr, &reqs, "cv-lr mixed")?;
        Ok(())
    });

    // fully-discrete data for BDeu
    let mut rng = Pcg64::new(5);
    let n = 200;
    let mut data = cvlr::linalg::Mat::zeros(n, 4);
    for r in 0..n {
        for c in 0..4 {
            data[(r, c)] = rng.below(3) as f64;
        }
    }
    let dds = Arc::new(cvlr::data::Dataset::from_columns(data, &[true; 4]));
    let bdeu = BdeuScore::new(dds);
    check("batch_scalar_bdeu", 8, |rng| {
        let reqs = random_batch(rng, 4, 10);
        assert_batch_scalar_equal(&ScalarBackend(&bdeu), &bdeu, &reqs, "bdeu")?;
        Ok(())
    });
}

/// The service layers (cache, intra-batch dedup, worker pool) must not
/// change a single bit of any score.
#[test]
fn prop_service_layers_preserve_values() {
    let (ds, _) = generate(&SynthConfig {
        n: 80,
        num_vars: 5,
        density: 0.4,
        kind: DataKind::Continuous,
        seed: 79,
    });
    let ds = Arc::new(ds);
    let raw = CvLrScore::native(ds.clone());
    check("service_preserves_values", 5, |rng| {
        let reqs = random_batch(rng, 5, 16);
        let want = raw.score_batch(&reqs);
        for workers in [1usize, 3] {
            let svc = ScoreService::new(Arc::new(CvLrScore::native(ds.clone())), workers);
            let got = svc.score_batch(&reqs);
            prop_assert!(got == want, "service(workers={workers}) diverged from raw backend");
            // and again: the fully-cached pass must be identical too
            let again = svc.score_batch(&reqs);
            prop_assert!(again == want, "cached re-batch diverged (workers={workers})");
            let st = svc.stats();
            prop_assert!(st.consistent(), "stats identity violated: {st:?}");
        }
        Ok(())
    });
}

/// Regression pin for the batch-first GES rework: the batched,
/// service-routed search learns exactly the same CPDAG as the serial
/// scalar-scored search on fixed seeds, while actually driving wide
/// batches through the service.
#[test]
fn ges_batched_matches_serial_cpdag() {
    for seed in [1u64, 7, 23] {
        let (ds, _) = generate(&SynthConfig {
            n: 300,
            num_vars: 6,
            density: 0.4,
            kind: DataKind::Continuous,
            seed,
        });
        let ds = Arc::new(ds);
        // serial reference: scalar adapter, no cache, no batching wins
        let serial = ges(&ScalarBackend(BicScore::new(ds.clone())), &GesConfig::default());
        // batched: the production path (service + worker pool)
        let svc = ScoreService::scalar(BicScore::new(ds.clone()), 4);
        let batched = ges(&svc, &GesConfig::default());
        assert_eq!(
            serial.cpdag, batched.cpdag,
            "batched GES must learn the serial CPDAG (seed {seed})"
        );
        assert_eq!(serial.forward_steps, batched.forward_steps);
        assert_eq!(serial.backward_steps, batched.backward_steps);
        let st = svc.stats();
        assert!(st.batches > 0, "GES must submit batches (seed {seed})");
        assert!(st.max_batch > 1, "sweep batches must be wide (seed {seed})");
        assert!(st.consistent(), "stats identity violated: {st:?}");
    }
}

/// Same pin for the paper's score: CV-LR through the batched service
/// equals CV-LR scored serially, on a small fixed instance.
#[test]
fn ges_batched_matches_serial_cpdag_cvlr() {
    let (ds, _) = generate(&SynthConfig {
        n: 120,
        num_vars: 4,
        density: 0.4,
        kind: DataKind::Continuous,
        seed: 11,
    });
    let ds = Arc::new(ds);
    let serial = ges(&CvLrScore::native(ds.clone()), &GesConfig::default());
    let svc = ScoreService::new(Arc::new(CvLrScore::native(ds)), 2);
    let batched = ges(&svc, &GesConfig::default());
    assert_eq!(serial.cpdag, batched.cpdag, "CV-LR batched GES must match serial");
    let st = svc.stats();
    assert!(st.batches > 0 && st.max_batch > 1);
    assert!(st.consistent(), "{st:?}");
}
