//! Integration tests of the observability subsystem end to end: a
//! sharded discovery recorded as one Chrome trace with coordinator AND
//! follower-attributed spans, the Prometheus `/v1/metrics` exposition,
//! and the `/v1/trace` endpoint.
//!
//! The span recorder is process-global, so every test that toggles it
//! serializes on a file-local lock (tests in this binary run in
//! parallel threads; other test binaries are separate processes).

use std::net::SocketAddr;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use cvlr::coordinator::Discovery;
use cvlr::data::synth::{generate, SynthConfig};
use cvlr::obs::trace;
use cvlr::server::http::{request, request_raw};
use cvlr::server::json::{self, Json};
use cvlr::server::{Server, ServerConfig};

fn trace_lock() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

fn start_follower() -> Server {
    Server::start(ServerConfig {
        port: 0,
        job_workers: 1,
        builtin_n: 40,
        cache_capacity: Some(1 << 16),
        ..Default::default()
    })
    .expect("follower starts")
}

fn events_of(doc: &Json) -> Vec<Json> {
    doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array").to_vec()
}

fn names_of(events: &[Json]) -> Vec<String> {
    events
        .iter()
        .filter_map(|e| e.get("name").and_then(Json::as_str).map(str::to_string))
        .collect()
}

/// The PR's acceptance shape: one sharded discovery, traced, must land
/// coordinator stage spans (pid 1) and follower stage spans merged
/// under per-follower synthetic pids (≥ 2) in a single Perfetto-valid
/// document.
#[test]
fn sharded_discovery_trace_attributes_follower_spans() {
    let _guard = trace_lock().lock().unwrap();
    trace::disable();
    trace::clear();

    let (ds, _) = generate(&SynthConfig {
        num_vars: 5,
        density: 0.5,
        n: 120,
        seed: 11,
        ..Default::default()
    });
    let ds = Arc::new(ds);
    let f1 = start_follower();
    let f2 = start_follower();

    trace::enable();
    let out = Discovery::builder(ds)
        .method("cv-lr")
        .shards([f1.addr().to_string(), f2.addr().to_string()])
        .shard_dataset("it-obs")
        .run()
        .expect("sharded run");
    trace::disable();
    f1.stop();
    f2.stop();
    assert!(out.score_stats.expect("stats").shard_dispatches > 0, "fleet saw no work");

    let doc = json::parse(&trace::export_json()).expect("trace JSON parses");
    let events = events_of(&doc);
    let names = names_of(&events);
    for want in ["ges-forward-sweep", "score-batch", "shard-batch", "shard-dispatch"] {
        assert!(names.iter().any(|n| n == want), "coordinator span `{want}` missing");
    }
    // follower stage timings came back over the wire and merged under
    // synthetic pids ≥ 2 (pid 1 is the coordinator process)
    let remote: Vec<&Json> = events
        .iter()
        .filter(|e| {
            e.get("ph").and_then(Json::as_str) == Some("X")
                && e.get("pid").and_then(Json::as_u64).is_some_and(|p| p >= 2)
        })
        .collect();
    assert!(
        !remote.is_empty(),
        "no follower-attributed spans were merged into the coordinator trace"
    );
    // every follower pid referenced by a span carries process_name
    // metadata, so Perfetto shows "follower <addr>" tracks
    for ev in &remote {
        let pid = ev.get("pid").and_then(Json::as_u64).unwrap();
        assert!(
            events.iter().any(|m| {
                m.get("ph").and_then(Json::as_str) == Some("M")
                    && m.get("name").and_then(Json::as_str) == Some("process_name")
                    && m.get("pid").and_then(Json::as_u64) == Some(pid)
            }),
            "follower pid {pid} has no process_name metadata"
        );
    }
    trace::clear();
}

fn poll_until_done(addr: SocketAddr, id: u64) {
    let t0 = Instant::now();
    loop {
        let (status, job) =
            request(addr, "GET", &format!("/v1/jobs/{id}"), None).expect("poll");
        assert_eq!(status, 200, "{job:?}");
        let state = job.get("state").and_then(Json::as_str).expect("state").to_string();
        if state == "done" {
            return;
        }
        assert!(
            state == "queued" || state == "running",
            "job {id} ended in `{state}`: {job:?}"
        );
        assert!(t0.elapsed() < Duration::from_secs(120), "job {id} stuck in `{state}`");
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn submit_builtin_job(addr: SocketAddr) -> u64 {
    let body = Json::obj(vec![
        ("dataset", Json::str("synth")),
        ("method", Json::str("cv-lr")),
    ]);
    let (status, resp) = request(addr, "POST", "/v1/jobs", Some(&body)).expect("submit");
    assert_eq!(status, 202, "{resp:?}");
    resp.get("id").and_then(Json::as_u64).expect("job id")
}

/// `/v1/metrics` speaks the Prometheus text exposition: parseable
/// line format, the well-known `cvlr_*` schema present even before
/// traffic, and real counts after a job ran.
#[test]
fn metrics_endpoint_serves_prometheus_text() {
    let server = Server::start(ServerConfig {
        port: 0,
        job_workers: 1,
        builtin_n: 60,
        cache_capacity: Some(1 << 16),
        ..Default::default()
    })
    .expect("server starts");
    let addr = server.addr();

    poll_until_done(addr, submit_builtin_job(addr));

    let (status, text) = request_raw(addr, "GET", "/v1/metrics", None).expect("scrape");
    assert_eq!(status, 200);
    for series in [
        "cvlr_score_batch_seconds_bucket",
        "cvlr_ges_sweep_seconds_bucket",
        "cvlr_requests_total",
        "cvlr_cache_hits_total",
        "cvlr_evaluations_total",
        "cvlr_shard_dispatches_total",
        "cvlr_shard_degraded_total",
        "cvlr_stream_repivots_total",
        "cvlr_services",
        "cvlr_jobs_done",
    ] {
        assert!(text.contains(series), "series `{series}` missing from:\n{text}");
    }
    // every sample line is `name[{labels}] value` with a numeric value
    let mut samples = 0usize;
    for line in text.lines() {
        if line.starts_with('#') || line.is_empty() {
            continue;
        }
        let (_, value) = line.rsplit_once(' ').expect("sample line has a value");
        assert!(value.parse::<f64>().is_ok(), "non-numeric sample: {line}");
        samples += 1;
    }
    assert!(samples > 20, "suspiciously few samples:\n{text}");
    // metrics are process-global and always on: a cv-lr job must have
    // moved the stage counters
    let field = |name: &str| -> f64 {
        text.lines()
            .find(|l| l.split(' ').next() == Some(name))
            .and_then(|l| l.rsplit_once(' '))
            .and_then(|(_, v)| v.parse().ok())
            .unwrap_or_else(|| panic!("series `{name}` missing"))
    };
    assert!(field("cvlr_requests_total") > 0.0);
    assert!(field("cvlr_evaluations_total") > 0.0);
    assert!(field("cvlr_score_batch_seconds_count") > 0.0);
    assert!(field("cvlr_ges_sweep_seconds_count") > 0.0);
    assert!(field("cvlr_jobs_done") >= 1.0);

    server.stop();
}

/// Fleet federation: `GET /v1/metrics?fleet=1` on a coordinator merges
/// every follower's exposition with a `follower="host:port"` label per
/// sample, and a dead follower degrades to a
/// `cvlr_fleet_scrape_stale{follower=…} 1` marker instead of failing
/// the scrape.
#[test]
fn federated_metrics_merge_followers_and_mark_stale() {
    let f1 = start_follower();
    let f2 = start_follower();
    let (a1, a2) = (f1.addr().to_string(), f2.addr().to_string());
    let coord = Server::start(ServerConfig {
        port: 0,
        job_workers: 1,
        builtin_n: 60,
        cache_capacity: Some(1 << 16),
        shards: vec![a1.clone(), a2.clone()],
        ..Default::default()
    })
    .expect("coordinator starts");
    let addr = coord.addr();

    // without ?fleet=1 the coordinator serves local-only exposition
    let (status, text) = request_raw(addr, "GET", "/v1/metrics", None).expect("plain scrape");
    assert_eq!(status, 200);
    assert!(
        !text.contains("follower=\""),
        "unfederated scrape must not carry follower-labeled series"
    );

    // federated: both followers' series appear, relabeled, fresh
    let (status, text) =
        request_raw(addr, "GET", "/v1/metrics?fleet=1", None).expect("fleet scrape");
    assert_eq!(status, 200);
    for a in [&a1, &a2] {
        assert!(
            text.contains(&format!("cvlr_requests_total{{follower=\"{a}\"}}")),
            "follower {a} series missing from:\n{text}"
        );
        assert!(
            text.contains(&format!("cvlr_fleet_scrape_stale{{follower=\"{a}\"}} 0")),
            "follower {a} should be marked fresh:\n{text}"
        );
    }
    if cvlr::obs::mem::enabled() {
        assert!(
            text.contains("cvlr_mem_peak_bytes{scope="),
            "per-scope memory gauges missing from the federated exposition"
        );
    }
    // every sample line still parses: strip an exemplar suffix first,
    // then the last space-separated token must be numeric
    for line in text.lines() {
        if line.starts_with('#') || line.is_empty() {
            continue;
        }
        let sample = line.split(" # ").next().unwrap();
        let (_, value) = sample.rsplit_once(' ').expect("sample line has a value");
        assert!(value.parse::<f64>().is_ok(), "non-numeric sample: {line}");
    }

    // kill one follower: its samples drop out, the stale marker flips,
    // the healthy follower keeps federating and the scrape still 200s
    f2.stop();
    let (status, text) =
        request_raw(addr, "GET", "/v1/metrics?fleet=1", None).expect("degraded scrape");
    assert_eq!(status, 200);
    assert!(
        text.contains(&format!("cvlr_fleet_scrape_stale{{follower=\"{a2}\"}} 1")),
        "dead follower {a2} not marked stale:\n{text}"
    );
    assert!(
        !text.contains(&format!("cvlr_requests_total{{follower=\"{a2}\"}}")),
        "dead follower {a2} still contributes relabeled series"
    );
    assert!(
        text.contains(&format!("cvlr_requests_total{{follower=\"{a1}\"}}")),
        "healthy follower {a1} dropped out of the federated exposition"
    );
    assert!(text.contains(&format!("cvlr_fleet_scrape_stale{{follower=\"{a1}\"}} 0")));

    coord.stop();
    f1.stop();
}

/// `GET /v1/trace`: the first scrape attaches the recorder, later
/// scrapes return a Chrome trace-event document covering the traffic
/// in between.
#[test]
fn trace_endpoint_records_between_scrapes() {
    let _guard = trace_lock().lock().unwrap();
    trace::disable();
    trace::clear();
    let server = Server::start(ServerConfig {
        port: 0,
        job_workers: 1,
        builtin_n: 60,
        cache_capacity: Some(1 << 16),
        ..Default::default()
    })
    .expect("server starts");
    let addr = server.addr();

    // first scrape: attaches the recorder, returns a valid (possibly
    // empty) document
    let (status, first) = request(addr, "GET", "/v1/trace", None).expect("first scrape");
    assert_eq!(status, 200);
    assert!(first.get("traceEvents").and_then(Json::as_arr).is_some(), "{first:?}");

    poll_until_done(addr, submit_builtin_job(addr));

    let (status, doc) = request(addr, "GET", "/v1/trace", None).expect("second scrape");
    assert_eq!(status, 200);
    let names = names_of(&events_of(&doc));
    for want in ["ges-forward-sweep", "score-batch"] {
        assert!(names.iter().any(|n| n == want), "span `{want}` missing after a job ran");
    }

    server.stop();
    trace::disable();
    trace::clear();
}
