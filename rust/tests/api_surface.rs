//! Cross-cutting API-surface tests: exercises the public substrate APIs
//! (linalg, util, kernel, data, graph) on targeted edge cases that the
//! per-module unit tests do not reach.

use std::sync::Arc;

use cvlr::data::synth::{generate, DataKind, SynthConfig};
use cvlr::data::Dataset;
use cvlr::graph::pdag::dag_to_cpdag;
use cvlr::graph::{normalized_shd, skeleton_f1, Dag, Pdag};
use cvlr::kernel::{gram, gram_cross, median_heuristic, Kernel};
use cvlr::linalg::{expm, sym_eig, Cholesky, Lu, Mat};
use cvlr::score::bdeu::BdeuScore;
use cvlr::score::bic::BicScore;
use cvlr::score::folds::stride_folds;
use cvlr::score::LocalScore;
use cvlr::util::cli::Args;
use cvlr::util::special::{chi2_cdf, erf, gamma_cdf, gamma_sf, ln_gamma, norm_cdf};
use cvlr::util::stats::{mean, median, pearson, ranks, spearman, variance};
use cvlr::util::Pcg64;

// ---------------------------------------------------------------- linalg

#[test]
fn lu_det_and_inverse_roundtrip() {
    let a = Mat::from_rows(&[&[4.0, 2.0, 0.6], &[2.0, 3.0, 0.4], &[0.6, 0.4, 2.0]]);
    let lu = Lu::new(&a).expect("nonsingular");
    // det of this SPD matrix computed by cofactor expansion
    let d = lu.det();
    assert!(d > 0.0);
    let inv = lu.inverse();
    let id = a.matmul(&inv);
    assert!((&id - &Mat::eye(3)).max_abs() < 1e-12);
    assert!((lu.log_abs_det() - d.ln()).abs() < 1e-12);
}

#[test]
fn lu_detects_singularity() {
    let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]); // rank 1
    assert!(Lu::new(&a).is_none() || Lu::new(&a).unwrap().det().abs() < 1e-12);
}

#[test]
fn cholesky_rejects_indefinite() {
    let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, −1
    assert!(Cholesky::new(&a).is_none());
}

#[test]
fn cholesky_solve_matches_inverse() {
    let mut rng = Pcg64::new(1);
    let b = {
        let mut m = Mat::zeros(5, 5);
        for v in &mut m.data {
            *v = rng.normal();
        }
        m.matmul_t(&m).add_diag(5.0)
    };
    let ch = Cholesky::new(&b).unwrap();
    let rhs = Mat::col_vec(&[1.0, -2.0, 0.5, 3.0, -1.0]);
    let x = ch.solve(&rhs);
    let want = ch.inverse().matmul(&rhs);
    assert!((&x - &want).max_abs() < 1e-10);
}

#[test]
fn expm_of_zero_is_identity_and_nilpotent_is_exact() {
    assert!((&expm(&Mat::zeros(3, 3)) - &Mat::eye(3)).max_abs() < 1e-14);
    // strictly upper-triangular N (N² = 0): e^N = I + N exactly
    let mut n = Mat::zeros(2, 2);
    n[(0, 1)] = 3.0;
    let want = {
        let mut w = Mat::eye(2);
        w[(0, 1)] = 3.0;
        w
    };
    assert!((&expm(&n) - &want).max_abs() < 1e-12);
}

#[test]
fn sym_eig_reconstructs_matrix() {
    let a = Mat::from_rows(&[&[2.0, 1.0, 0.0], &[1.0, 3.0, 0.5], &[0.0, 0.5, 1.5]]);
    let (w, v) = sym_eig(&a);
    // A = V diag(w) Vᵀ
    let mut rec = Mat::zeros(3, 3);
    for k in 0..3 {
        for i in 0..3 {
            for j in 0..3 {
                rec[(i, j)] += w[k] * v[(i, k)] * v[(j, k)];
            }
        }
    }
    assert!((&a - &rec).max_abs() < 1e-9);
    // eigenvalues sorted descending
    assert!(w.windows(2).all(|p| p[0] >= p[1] - 1e-12));
}

// ----------------------------------------------------------------- util

#[test]
fn special_function_anchors() {
    // Γ(5) = 24
    assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-10);
    // erf(0) = 0, erf(∞) → 1
    assert!(erf(0.0).abs() < 1e-12);
    assert!((erf(3.0) - 1.0).abs() < 1e-4);
    // Φ(0) = 0.5, Φ(1.96) ≈ 0.975
    assert!((norm_cdf(0.0) - 0.5).abs() < 1e-12);
    assert!((norm_cdf(1.959964) - 0.975).abs() < 1e-4);
    // χ²(k=2) cdf at x=2: 1 − e^{−1}
    assert!((chi2_cdf(2.0, 2.0) - (1.0 - (-1.0f64).exp())).abs() < 1e-8);
    // gamma cdf + sf = 1
    let (x, k, th) = (2.7, 1.8, 0.9);
    assert!((gamma_cdf(x, k, th) + gamma_sf(x, k, th) - 1.0).abs() < 1e-10);
}

#[test]
fn stats_anchors() {
    let xs = [1.0, 2.0, 3.0, 4.0];
    assert_eq!(mean(&xs), 2.5);
    assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
    assert_eq!(median(&xs), 2.5);
    assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
    // ranks with ties get midranks
    let r = ranks(&[10.0, 20.0, 20.0, 30.0]);
    assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    // perfect monotone nonlinear relation: spearman 1, pearson < 1
    let x: Vec<f64> = (1..=20).map(|i| i as f64).collect();
    let y: Vec<f64> = x.iter().map(|v| v.exp()).collect();
    assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
    assert!(pearson(&x, &y) < 0.95);
}

#[test]
fn rng_is_deterministic_and_fork_diverges() {
    let mut a = Pcg64::new(7);
    let mut b = Pcg64::new(7);
    let va: Vec<u64> = (0..5).map(|_| a.next_u64()).collect();
    let vb: Vec<u64> = (0..5).map(|_| b.next_u64()).collect();
    assert_eq!(va, vb);
    let mut f = a.fork();
    assert_ne!(a.next_u64(), f.next_u64());
}

#[test]
fn rng_distributions_are_sane() {
    let mut rng = Pcg64::new(11);
    let n = 20_000;
    let m: f64 = (0..n).map(|_| rng.normal()).sum::<f64>() / n as f64;
    assert!(m.abs() < 0.05, "normal mean {m}");
    let p: f64 = (0..n).map(|_| rng.bernoulli(0.3) as u8 as f64).sum::<f64>() / n as f64;
    assert!((p - 0.3).abs() < 0.02, "bernoulli {p}");
    let probs = rng.dirichlet(4, 1.0);
    assert!((probs.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    assert!(probs.iter().all(|&q| q >= 0.0));
}

#[test]
fn cli_parse_corner_cases() {
    let args = Args::parse(
        ["--a=1", "--flag", "--b", "2", "pos1", "--trailing"]
            .iter()
            .map(|s| s.to_string()),
    );
    assert_eq!(args.usize_or("a", 0), 1);
    assert_eq!(args.usize_or("b", 0), 2);
    assert!(args.flag("flag"));
    assert!(args.flag("trailing"));
    assert_eq!(args.positional, vec!["pos1"]);
    assert_eq!(args.get("missing"), None);
    // malformed numeric falls back to the default
    let bad = Args::parse(["--n", "xyz"].iter().map(|s| s.to_string()));
    assert_eq!(bad.usize_or("n", 42), 42);
}

// --------------------------------------------------------------- kernel

#[test]
fn rbf_kernel_basics() {
    let k = Kernel::Rbf { sigma: 2.0 };
    assert!((k.eval(&[1.0, 2.0], &[1.0, 2.0]) - 1.0).abs() < 1e-12);
    // symmetric and decaying
    let near = k.eval(&[0.0], &[0.1]);
    let far = k.eval(&[0.0], &[3.0]);
    assert!(near > far && far > 0.0);
    assert_eq!(k.eval(&[0.0], &[1.5]), k.eval(&[1.5], &[0.0]));
}

#[test]
fn gram_cross_consistent_with_gram() {
    let mut rng = Pcg64::new(3);
    let mut x = Mat::zeros(8, 2);
    for v in &mut x.data {
        *v = rng.normal();
    }
    let k = Kernel::Rbf { sigma: 1.3 };
    let g = gram(k, &x);
    let gc = gram_cross(k, &x, &x);
    assert!((&g - &gc).max_abs() < 1e-14);
}

#[test]
fn median_heuristic_scales_with_width_factor() {
    let mut rng = Pcg64::new(4);
    let mut x = Mat::zeros(50, 1);
    for v in &mut x.data {
        *v = rng.normal();
    }
    let m1 = median_heuristic(&x, 1.0);
    let m2 = median_heuristic(&x, 2.0);
    assert!((m2 / m1 - 2.0).abs() < 1e-9);
    // degenerate data falls back to a positive default
    let z = Mat::zeros(10, 1);
    assert!(median_heuristic(&z, 2.0) > 0.0);
}

// ----------------------------------------------------------------- data

#[test]
fn dataset_head_and_levels() {
    let (ds, _) = generate(&SynthConfig {
        n: 50,
        num_vars: 4,
        density: 0.4,
        kind: DataKind::Mixed,
        seed: 9,
    });
    let head = ds.head(10);
    assert_eq!(head.n(), 10);
    assert_eq!(head.d(), ds.d());
    // discrete flags preserved
    for i in 0..ds.d() {
        assert_eq!(head.vars[i].discrete, ds.vars[i].discrete);
    }
}

#[test]
fn multidim_dataset_blocks_have_right_width() {
    let (ds, _) = generate(&SynthConfig {
        n: 40,
        num_vars: 4,
        density: 0.4,
        kind: DataKind::MultiDim,
        seed: 10,
    });
    let total: usize = (0..ds.d()).map(|i| ds.block(i).cols).sum();
    assert_eq!(total, ds.data.cols, "per-variable blocks must tile the data");
    assert!((1..=5).contains(&ds.block(0).cols));
}

// ---------------------------------------------------------------- graph

#[test]
fn meek_rule_orients_chain_tail() {
    // a → b — c with a, c non-adjacent must orient b → c (Meek rule 1)
    let mut p = Pdag::new(3);
    p.add_directed(0, 1);
    p.add_undirected(1, 2);
    p.meek_closure();
    assert!(p.directed(1, 2), "Meek R1 must orient 1→2");
}

#[test]
fn cpdag_of_full_dag_keeps_v_structures_only() {
    // collider a → c ← b: both arcs compelled; a chain a → b → c: none
    let collider = dag_to_cpdag(&Dag::from_edges(3, &[(0, 2), (1, 2)]));
    assert!(collider.directed(0, 2) && collider.directed(1, 2));
    let chain = dag_to_cpdag(&Dag::from_edges(3, &[(0, 1), (1, 2)]));
    assert!(chain.undirected(0, 1) && chain.undirected(1, 2));
}

#[test]
fn shd_counts_reversals_less_than_misses() {
    let truth = Dag::from_edges(3, &[(0, 1), (1, 2)]);
    // same skeleton, wrong orientation (as a fully directed PDAG)
    let mut reversed = Pdag::new(3);
    reversed.add_directed(1, 0);
    reversed.add_directed(2, 1);
    let mut empty = Pdag::new(3);
    empty.meek_closure();
    let shd_rev = normalized_shd(&reversed, &truth);
    let shd_empty = normalized_shd(&empty, &truth);
    assert!(shd_rev > 0.0);
    assert!(shd_empty >= shd_rev, "missing edges cost at least as much: {shd_empty} vs {shd_rev}");
    assert_eq!(skeleton_f1(&reversed, &truth), 1.0);
}

// ---------------------------------------------------------------- folds

#[test]
#[should_panic(expected = "need n >= 2q")]
fn folds_reject_tiny_samples() {
    let _ = stride_folds(9, 5);
}

// --------------------------------------------------------------- scores

#[test]
fn bic_penalizes_extra_parents_on_independent_data() {
    let mut rng = Pcg64::new(12);
    let n = 400;
    let mut data = Mat::zeros(n, 3);
    for v in &mut data.data {
        *v = rng.normal();
    }
    let bic = BicScore::new(Arc::new(Dataset::from_columns(data, &[false; 3])));
    let empty = bic.local_score(0, &[]);
    let one = bic.local_score(0, &[1]);
    let two = bic.local_score(0, &[1, 2]);
    assert!(empty > one && one > two, "BIC must order {empty} > {one} > {two}");
}

#[test]
fn bdeu_is_exchangeable_in_parent_order() {
    let mut rng = Pcg64::new(13);
    let n = 300;
    let mut data = Mat::zeros(n, 3);
    for r in 0..n {
        data[(r, 0)] = rng.below(2) as f64;
        data[(r, 1)] = rng.below(3) as f64;
        data[(r, 2)] = ((r as u64 + rng.below(2) as u64) % 2) as f64;
    }
    let bdeu = BdeuScore::new(Arc::new(Dataset::from_columns(data, &[true; 3])));
    // equal up to summation order (configurations are enumerated in
    // parent order, so the FP reduction order differs)
    let a = bdeu.local_score(2, &[0, 1]);
    let b = bdeu.local_score(2, &[1, 0]);
    assert!(((a - b) / a).abs() < 1e-12, "{a} vs {b}");
}
