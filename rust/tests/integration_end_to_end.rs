//! End-to-end integration over all three layers: synthetic data → low-rank
//! factorization (L3) → AOT XLA score artifacts (L2/L1 via PJRT) → GES →
//! CPDAG, compared against the all-native path.
//!
//! Requires `artifacts/` (run `make artifacts` first — `make test` does).

use std::sync::Arc;

use cvlr::coordinator::engine::{discover, DiscoveryConfig, EngineKind, Method};
use cvlr::coordinator::service::ScoreService;
use cvlr::data::synth::{generate, DataKind, SynthConfig};
use cvlr::data::networks;
use cvlr::graph::skeleton_f1;
use cvlr::runtime::pjrt_kernel::PjrtCvLrKernel;
use cvlr::runtime::Runtime;
use cvlr::score::cvlr::CvLrScore;
use cvlr::score::folds::CvParams;
use cvlr::score::{ScoreBackend, ScoreRequest};

fn artifacts_dir() -> String {
    std::env::var("CVLR_ARTIFACTS")
        .unwrap_or_else(|_| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")))
}

fn pjrt_config(method: Method) -> DiscoveryConfig {
    DiscoveryConfig {
        method,
        engine: EngineKind::Pjrt,
        artifacts_dir: artifacts_dir(),
        ..Default::default()
    }
}

/// The three-layer hot path and the native path learn the same
/// equivalence class on continuous synthetic data.
#[test]
fn pjrt_and_native_engines_agree() {
    let (ds, dag) = generate(&SynthConfig {
        n: 200,
        num_vars: 5,
        density: 0.3,
        kind: DataKind::Continuous,
        seed: 41,
    });
    let ds = Arc::new(ds);
    let native = discover(
        ds.clone(),
        &DiscoveryConfig { method: Method::CvLr, ..Default::default() },
    )
    .unwrap();
    let pjrt = discover(ds, &pjrt_config(Method::CvLr)).unwrap();
    assert_eq!(
        native.cpdag, pjrt.cpdag,
        "native and PJRT engines must learn the same CPDAG"
    );
    let f1 = skeleton_f1(&pjrt.cpdag, &dag);
    assert!(f1 >= 0.5, "PJRT CV-LR skeleton F1 too low: {f1}");
}

/// Full pipeline on a discrete benchmark network through PJRT.
#[test]
fn pjrt_engine_on_sachs() {
    let net = networks::sachs();
    let ds = Arc::new(networks::forward_sample(&net, 300, 42));
    let out = discover(ds, &pjrt_config(Method::CvLr)).unwrap();
    let f1 = skeleton_f1(&out.cpdag, &net.dag);
    assert!(f1 >= 0.45, "PJRT CV-LR on SACHS F1 too low: {f1}");
}

/// The score service fans batched requests over worker threads and
/// returns bit-identical results to sequential evaluation, with the
/// PJRT-backed CV-LR score underneath.
#[test]
fn score_service_parallel_matches_sequential() {
    let (ds, _) = generate(&SynthConfig {
        n: 200,
        num_vars: 6,
        density: 0.4,
        kind: DataKind::Continuous,
        seed: 43,
    });
    let ds = Arc::new(ds);
    let rt = Arc::new(Runtime::load(artifacts_dir()).expect("run `make artifacts`"));
    let mk = || -> Arc<dyn ScoreBackend> {
        Arc::new(CvLrScore::with_backend(
            ds.clone(),
            CvParams::default(),
            Default::default(),
            PjrtCvLrKernel::new(rt.clone()),
        ))
    };
    let reqs: Vec<ScoreRequest> = vec![
        ScoreRequest::new(0, &[]),
        ScoreRequest::new(1, &[0]),
        ScoreRequest::new(2, &[0, 1]),
        ScoreRequest::new(3, &[]),
        ScoreRequest::new(4, &[3]),
        ScoreRequest::new(5, &[0, 4]),
    ];
    let seq = ScoreService::new(mk(), 1).score_batch(&reqs);
    let par = ScoreService::new(mk(), 4).score_batch(&reqs);
    for (a, b) in seq.iter().zip(&par) {
        assert!(
            (a - b).abs() < 1e-12,
            "parallel batch diverged: {a} vs {b}"
        );
    }
}

/// Runtime execution counter: a full GES run through PJRT performs many
/// artifact executions, all from the rust hot path (no python).
#[test]
fn pjrt_run_executes_artifacts() {
    let (ds, _) = generate(&SynthConfig {
        n: 150,
        num_vars: 4,
        density: 0.3,
        kind: DataKind::Continuous,
        seed: 44,
    });
    let rt = Arc::new(Runtime::load(artifacts_dir()).expect("run `make artifacts`"));
    let score = CvLrScore::with_backend(
        Arc::new(ds),
        CvParams::default(),
        Default::default(),
        PjrtCvLrKernel::new(rt.clone()),
    );
    let before = rt.executions();
    let service = ScoreService::new(Arc::new(score), 1);
    let res = cvlr::search::ges::ges(&service, &Default::default());
    let executed = rt.executions() - before;
    // every unique (cache-missed) local score runs one artifact
    // execution per CV fold (10 by default)
    let unique = service.stats().evaluations;
    assert!(
        executed >= 10 * unique,
        "GES must route scores through the artifacts: {executed} execs for \
         {unique} unique evaluations ({} requests)",
        res.score_calls
    );
}

/// Cache effectiveness on the end-to-end path: across a GES run the
/// service converts a large share of requests into hits (the coordinator
/// perf target of DESIGN.md §8).
#[test]
fn cache_hit_rate_on_e2e_run() {
    let (ds, _) = generate(&SynthConfig {
        n: 250,
        num_vars: 7,
        density: 0.4,
        kind: DataKind::Continuous,
        seed: 45,
    });
    let out = discover(
        Arc::new(ds),
        &DiscoveryConfig { method: Method::CvLr, ..Default::default() },
    )
    .unwrap();
    let st = out.score_stats.unwrap();
    let hit_rate = st.cache_hits as f64 / st.requests.max(1) as f64;
    assert!(
        hit_rate > 0.6,
        "e2e cache hit rate should exceed 60%, got {:.2} ({} / {})",
        hit_rate,
        st.cache_hits,
        st.requests
    );
    // the hot path is batch-first: GES submits wide sweeps, never
    // per-candidate scalar calls
    assert!(st.batches > 0, "GES must route through score_batch");
    assert!(st.max_batch > 1, "sweep batches must contain many candidates");
    assert!(st.consistent(), "stats identity must hold: {st:?}");
}

/// Mixed data end-to-end through PJRT (exercises Algorithm 1 and
/// Algorithm 2 factorization paths in one run).
#[test]
fn pjrt_engine_on_mixed_data() {
    let (ds, dag) = generate(&SynthConfig {
        n: 200,
        num_vars: 5,
        density: 0.3,
        kind: DataKind::Mixed,
        seed: 46,
    });
    let out = discover(Arc::new(ds), &pjrt_config(Method::CvLr)).unwrap();
    let f1 = skeleton_f1(&out.cpdag, &dag);
    assert!(f1 >= 0.4, "PJRT mixed-data F1 too low: {f1}");
}

/// Bad artifacts directory surfaces as an error, not a panic.
#[test]
fn missing_artifacts_is_an_error() {
    let (ds, _) = generate(&SynthConfig {
        n: 100,
        num_vars: 3,
        density: 0.3,
        kind: DataKind::Continuous,
        seed: 47,
    });
    let cfg = DiscoveryConfig {
        method: Method::CvLr,
        engine: EngineKind::Pjrt,
        artifacts_dir: "/nonexistent/artifacts".into(),
        ..Default::default()
    };
    assert!(discover(Arc::new(ds), &cfg).is_err());
}
