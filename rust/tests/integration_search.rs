//! Search-layer integration: GES with each score, the constraint-based
//! baselines (PC/KCI, MM-MB/KCI), and the unified discovery engine, on
//! synthetic FCM data and the discrete benchmark networks.

use std::sync::Arc;

use cvlr::ci::Kci;
use cvlr::coordinator::engine::{discover, DiscoveryConfig, Method};
use cvlr::coordinator::ScoreService;
use cvlr::data::synth::{generate, DataKind, SynthConfig};
use cvlr::data::networks;
use cvlr::graph::pdag::dag_to_cpdag;
use cvlr::graph::{normalized_shd, skeleton_f1, Dag};
use cvlr::score::bic::BicScore;
use cvlr::score::cvlr::CvLrScore;
use cvlr::search::ges::{ges, GesConfig};
use cvlr::search::mmmb::{mmmb, MmConfig};
use cvlr::search::pc::{pc, PcConfig};

/// GES + CV-LR recovers most of a sparse nonlinear 7-node graph
/// (the Fig. 2-4 setting, smoke scale).
#[test]
fn ges_cvlr_recovers_synthetic_graph() {
    let (ds, dag) = generate(&SynthConfig {
        n: 300,
        num_vars: 7,
        density: 0.25,
        kind: DataKind::Continuous,
        seed: 21,
    });
    let score = ScoreService::new(Arc::new(CvLrScore::native(Arc::new(ds))), 1);
    let res = ges(&score, &GesConfig::default());
    assert!(res.batches > 0, "GES must submit batches");
    let f1 = skeleton_f1(&res.cpdag, &dag);
    assert!(f1 >= 0.6, "CV-LR skeleton F1 too low: {f1}");
    let shd = normalized_shd(&res.cpdag, &dag);
    assert!(shd <= 0.4, "CV-LR normalized SHD too high: {shd}");
}

/// GES output is always a valid CPDAG (a DAG-extendable PDAG whose
/// re-completion is itself) regardless of the score.
#[test]
fn ges_output_is_cpdag_across_scores() {
    let (ds, _) = generate(&SynthConfig {
        n: 250,
        num_vars: 6,
        density: 0.4,
        kind: DataKind::Continuous,
        seed: 22,
    });
    let ds = Arc::new(ds);
    for res in [
        ges(&ScoreService::scalar(BicScore::new(ds.clone()), 1), &GesConfig::default()),
        ges(&ScoreService::new(Arc::new(CvLrScore::native(ds.clone())), 1), &GesConfig::default()),
    ] {
        let dag = res.cpdag.to_dag().expect("GES output must extend to a DAG");
        assert_eq!(
            dag_to_cpdag(&dag),
            res.cpdag,
            "GES output must be a completed PDAG"
        );
    }
}

/// CV and CV-LR drive GES to (near-)identical equivalence classes —
/// the headline accuracy claim, checked structurally instead of via
/// score values. Small n keeps the O(n³) exact CV affordable.
#[test]
fn ges_cv_and_cvlr_agree_structurally() {
    let (ds, dag) = generate(&SynthConfig {
        n: 150,
        num_vars: 5,
        density: 0.3,
        kind: DataKind::Continuous,
        seed: 23,
    });
    let ds = Arc::new(ds);
    let out_lr = discover(
        ds.clone(),
        &DiscoveryConfig { method: Method::CvLr, ..Default::default() },
    )
    .unwrap();
    let out_cv = discover(
        ds,
        &DiscoveryConfig { method: Method::Cv, ..Default::default() },
    )
    .unwrap();
    let f1_lr = skeleton_f1(&out_lr.cpdag, &dag);
    let f1_cv = skeleton_f1(&out_cv.cpdag, &dag);
    assert!(
        (f1_lr - f1_cv).abs() <= 0.35,
        "CV-LR ({f1_lr}) and CV ({f1_cv}) should be comparable"
    );
}

/// PC with KCI finds the skeleton of an easy sparse graph.
#[test]
fn pc_kci_finds_sparse_skeleton() {
    let (ds, dag) = generate(&SynthConfig {
        n: 250,
        num_vars: 5,
        density: 0.2,
        kind: DataKind::Continuous,
        seed: 24,
    });
    let kci = Kci::new(Arc::new(ds));
    let res = pc(&kci, &PcConfig { alpha: 0.05, max_cond: None });
    let f1 = skeleton_f1(&res.cpdag, &dag);
    assert!(f1 >= 0.5, "PC skeleton F1 too low: {f1}");
    assert!(kci.calls() > 0, "PC must run CI tests");
}

/// MM-MB with KCI produces a sane graph on the same data.
#[test]
fn mmmb_kci_runs_and_is_sane() {
    let (ds, dag) = generate(&SynthConfig {
        n: 250,
        num_vars: 5,
        density: 0.2,
        kind: DataKind::Continuous,
        seed: 25,
    });
    let kci = Kci::new(Arc::new(ds));
    let res = mmmb(&kci, &MmConfig { alpha: 0.05, max_cond: 3 });
    let f1 = skeleton_f1(&res.cpdag, &dag);
    assert!(f1 >= 0.4, "MM skeleton F1 too low: {f1}");
}

/// The engine runs every method end-to-end on the same small dataset
/// without error and reports coherent statistics.
#[test]
fn engine_all_methods_run() {
    let (ds, _) = generate(&SynthConfig {
        n: 120,
        num_vars: 4,
        density: 0.3,
        kind: DataKind::Continuous,
        seed: 26,
    });
    let ds = Arc::new(ds);
    for method in [Method::CvLr, Method::Bic, Method::Sc, Method::Pc, Method::Mm] {
        let out = discover(ds.clone(), &DiscoveryConfig { method, ..Default::default() })
            .unwrap_or_else(|e| panic!("{method:?} failed: {e}"));
        assert!(out.seconds >= 0.0);
        match method {
            Method::Pc | Method::Mm => {
                assert!(out.ci_tests.unwrap() > 0, "{method:?} must test CIs")
            }
            _ => assert!(
                out.score_stats.as_ref().unwrap().evaluations > 0,
                "{method:?} must evaluate scores"
            ),
        }
    }
}

/// GES + BDeu on forward-sampled SACHS recovers a good share of the
/// skeleton (Fig. 5 setting, smoke scale).
#[test]
fn ges_bdeu_on_sachs() {
    let net = networks::sachs();
    let ds = Arc::new(networks::forward_sample(&net, 600, 31));
    let out = discover(ds, &DiscoveryConfig { method: Method::Bdeu, ..Default::default() })
        .unwrap();
    let f1 = skeleton_f1(&out.cpdag, &net.dag);
    assert!(f1 >= 0.5, "BDeu on SACHS F1 too low: {f1}");
}

/// GES + CV-LR on forward-sampled SACHS — the paper's headline
/// real-world configuration (Fig. 5), smoke scale.
#[test]
fn ges_cvlr_on_sachs() {
    let net = networks::sachs();
    let ds = Arc::new(networks::forward_sample(&net, 400, 32));
    let out = discover(ds, &DiscoveryConfig { method: Method::CvLr, ..Default::default() })
        .unwrap();
    let f1 = skeleton_f1(&out.cpdag, &net.dag);
    assert!(f1 >= 0.5, "CV-LR on SACHS F1 too low: {f1}");
    let stats = out.score_stats.unwrap();
    let hit_rate = stats.cache_hits as f64 / stats.requests.max(1) as f64;
    assert!(
        hit_rate > 0.5,
        "GES should hit the score cache heavily, got {hit_rate:.2}"
    );
}

/// Increasing sample size does not degrade CHILD skeleton recovery
/// (Fig. 5 trend, coarse two-point check).
#[test]
fn child_f1_improves_with_n() {
    let net = networks::child();
    let f1_at = |n: usize| {
        let ds = Arc::new(networks::forward_sample(&net, n, 33));
        let out = discover(ds, &DiscoveryConfig { method: Method::Bdeu, ..Default::default() })
            .unwrap();
        skeleton_f1(&out.cpdag, &net.dag)
    };
    let small = f1_at(150);
    let large = f1_at(900);
    assert!(
        large >= small - 0.05,
        "CHILD F1 should not degrade with n: {small} -> {large}"
    );
}

/// Metrics sanity on hand-built graphs: perfect recovery gives F1 = 1,
/// SHD = 0; the empty graph gives F1 = 0 against a non-empty truth.
#[test]
fn metrics_ground_truth_anchors() {
    let truth = Dag::from_edges(4, &[(0, 1), (1, 2), (0, 3)]);
    let perfect = dag_to_cpdag(&truth);
    assert_eq!(skeleton_f1(&perfect, &truth), 1.0);
    assert_eq!(normalized_shd(&perfect, &truth), 0.0);
    let empty = cvlr::graph::Pdag::new(4);
    assert_eq!(skeleton_f1(&empty, &truth), 0.0);
    assert!(normalized_shd(&empty, &truth) > 0.0);
}

/// max_parents cap is respected by GES.
#[test]
fn ges_respects_parent_cap() {
    let (ds, _) = generate(&SynthConfig {
        n: 300,
        num_vars: 6,
        density: 0.7,
        kind: DataKind::Continuous,
        seed: 27,
    });
    let score = ScoreService::scalar(BicScore::new(Arc::new(ds)), 1);
    let cfg = GesConfig { max_parents: Some(2), ..Default::default() };
    let res = ges(&score, &cfg);
    let dag = res.cpdag.to_dag().expect("valid CPDAG");
    for v in 0..6 {
        assert!(
            dag.parents(v).len() <= 2 + 2, // CPDAG extension may orient undirected edges inward
            "node {v} has too many parents"
        );
    }
}
