//! Streaming discovery end-to-end: a dataset replayed in chunks must
//! land on the same answer as a cold full-batch run — the factor-level
//! incremental correctness, the session-level CPDAG agreement, and the
//! observability counters that make cache reuse visible.

use std::sync::Arc;

use cvlr::coordinator::{discover, DiscoveryConfig, Method};
use cvlr::data::Dataset;
use cvlr::kernel::{median_heuristic, Kernel};
use cvlr::linalg::Mat;
use cvlr::lowrank::{factorize, FactorMethod, LowRankConfig};
use cvlr::score::cvlr::{split_center, CvLrKernel, NativeCvLrKernel};
use cvlr::score::folds::{stride_folds, CvParams};
use cvlr::stream::{FactorState, StreamBackend, StreamConfig, StreamingDiscovery};
use cvlr::util::Pcg64;

/// Strongly identified chain X1 → X2 → X3 plus isolated X4, as raw
/// rows for chunk replay.
fn chain_rows(n: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::new(seed);
    let mut data = Mat::zeros(n, 4);
    for r in 0..n {
        let x1 = rng.normal();
        let x2 = 1.2 * x1 + 0.4 * rng.normal();
        let x3 = -0.9 * x2 + 0.4 * rng.normal();
        let x4 = rng.normal();
        data[(r, 0)] = x1;
        data[(r, 1)] = x2;
        data[(r, 2)] = x3;
        data[(r, 3)] = x4;
    }
    data
}

fn rows_range(m: &Mat, lo: usize, hi: usize) -> Mat {
    m.select_rows(&(lo..hi).collect::<Vec<_>>())
}

/// CV-LR score of one fold configuration straight from a factor — the
/// factor-level oracle the append/refactorize comparison uses.
fn score_from_factor(lx: &Mat, lz: &Mat, p: &CvParams) -> f64 {
    let folds = stride_folds(lx.rows, p.folds);
    let k = NativeCvLrKernel;
    folds
        .iter()
        .map(|(test, train)| {
            let (lx0, lx1) = split_center(lx, test, train);
            let (lz0, lz1) = split_center(lz, test, train);
            k.score_cond(&lx0, &lx1, &lz0, &lz1, p)
        })
        .sum::<f64>()
        / folds.len() as f64
}

/// Incremental correctness at the factor level: streamed in k chunks
/// vs refactorized from scratch with the same pinned kernel, the CV-LR
/// scores agree within 1e-6 (continuous/ICL path).
#[test]
fn streamed_factors_score_like_refactorized_continuous() {
    let data = chain_rows(240, 1);
    let p = CvParams::default();
    // tight η: both factors then approximate K to 1e-9, so the 1e-6
    // score agreement has headroom regardless of which pivots the
    // streamed vs cold greedy selections landed on
    let cfg = LowRankConfig { max_rank: 100, eta: 1e-9, ..Default::default() };
    let bx = data.select_rows(&(0..data.rows).collect::<Vec<_>>());
    let x_col = |lo: usize, hi: usize, c: usize| {
        Mat::from_vec(hi - lo, 1, (lo..hi).map(|r| bx[(r, c)]).collect())
    };
    for (xc, zc) in [(1usize, 0usize), (2, 1)] {
        let full_x = x_col(0, 240, xc);
        let full_z = x_col(0, 240, zc);
        let kx = Kernel::Rbf { sigma: median_heuristic(&x_col(0, 80, xc), p.width_factor) };
        let kz = Kernel::Rbf { sigma: median_heuristic(&x_col(0, 80, zc), p.width_factor) };

        let mut sx = FactorState::new(kx, &x_col(0, 80, xc), false, &cfg);
        let mut sz = FactorState::new(kz, &x_col(0, 80, zc), false, &cfg);
        for (lo, hi) in [(80, 160), (160, 240)] {
            let part_x = x_col(0, hi, xc);
            let part_z = x_col(0, hi, zc);
            sx.append(&x_col(lo, hi, xc), &|| part_x.clone());
            sz.append(&x_col(lo, hi, zc), &|| part_z.clone());
        }
        assert_eq!(sx.lambda().rows, 240);

        let cold_x = FactorState::new(kx, &full_x, false, &cfg);
        let cold_z = FactorState::new(kz, &full_z, false, &cfg);

        let streamed = score_from_factor(&sx.lambda(), &sz.lambda(), &p);
        let cold = score_from_factor(&cold_x.lambda(), &cold_z.lambda(), &p);
        let rel = ((streamed - cold) / cold).abs();
        assert!(
            rel < 1e-6,
            "X{xc}|X{zc}: streamed {streamed} vs refactorized {cold} (rel {rel})"
        );
    }
}

/// The discrete path is exact: streamed scores match the cold run
/// bit-for-bit when no re-pivot fires (same pivots in first-appearance
/// order, same forward substitutions).
#[test]
fn streamed_factors_exact_discrete() {
    let mut rng = Pcg64::new(2);
    let n = 180;
    let mut col = Mat::zeros(n, 1);
    for r in 0..n {
        col[(r, 0)] = rng.below(4) as f64;
    }
    let kern = Kernel::Rbf { sigma: 1.0 };
    let cfg = LowRankConfig::default();
    let mut st = FactorState::new(kern, &rows_range(&col, 0, 60), true, &cfg);
    for (lo, hi) in [(60, 120), (120, 180)] {
        let part = rows_range(&col, 0, hi);
        let out = st.append(&rows_range(&col, lo, hi), &|| part.clone());
        assert!(!out.repivoted, "discrete appends must not re-pivot");
    }
    let cold = FactorState::new(kern, &col, true, &cfg);
    // Pivot order is first-appearance for both paths. Basis growth can
    // make the streamed factor *wider* only if the head missed a level;
    // either way the factors must agree bit-for-bit when the head saw
    // every level (overwhelmingly likely at 60 draws of 4 levels).
    if st.rank() == cold.rank() {
        assert_eq!(
            st.lambda().data,
            cold.lambda().data,
            "discrete streamed factor must equal the cold factorization bit-for-bit"
        );
    }
    let err = (&st.lambda().matmul_t(&st.lambda())
        - &cold.lambda().matmul_t(&cold.lambda()))
        .max_abs();
    assert!(err < 1e-9, "ΛΛᵀ must agree exactly: {err}");
}

/// Session-level acceptance: a 3-chunk stream ends on the same CPDAG
/// as a cold full-batch CV-LR discovery of the full data, with the
/// invalidation/warm-start counters live and the factors exact.
#[test]
fn streamed_session_matches_cold_discovery() {
    let data = chain_rows(240, 3);
    let full = Dataset::from_columns(data.clone(), &[false; 4]);

    // cold full-batch run (native CV-LR through the engine)
    let cold = discover(
        Arc::new(full.clone()),
        &DiscoveryConfig { method: Method::CvLr, ..Default::default() },
    )
    .unwrap();

    // streamed: seed with 80 rows, two appends of 80
    let mut sess = StreamingDiscovery::new(full.head(80));
    let first = sess.discover();
    assert!(!first.warm_started);
    let mut last = first.clone();
    for (lo, hi) in [(80, 160), (160, 240)] {
        let ast = sess.append(&rows_range(&data, lo, hi)).unwrap();
        assert_eq!(ast.rows, 80);
        assert!(ast.invalidated > 0, "appends must invalidate cached scores");
        last = sess.discover();
        assert!(last.warm_started, "re-discovery must warm-start");
    }
    assert_eq!(sess.n(), 240);
    assert_eq!(
        last.cpdag, cold.cpdag,
        "streamed discovery must land on the cold full-batch CPDAG"
    );

    let st = sess.stats();
    assert!(st.invalidations > 0, "{st:?}");
    assert_eq!(st.warm_start_hits, 2, "{st:?}");
    assert!(st.consistent(), "{st:?}");
    // exactness was maintained (or repaired by re-pivots) across
    // appends — the bound is the factorization's own cold-run quality
    // (rank-capped ICL states keep their residual), not stream drift
    assert!(
        sess.backend().max_reconstruction_error() < 1e-2,
        "factor reconstruction drifted: {}",
        sess.backend().max_reconstruction_error()
    );
}

/// Regression for the fold-core cache: scoring populates the downdated
/// core cache, an append must invalidate it (scores depend on every
/// row), and the re-score must match a refactorized cold backend. Run
/// on discrete data where Algorithm 2 is exact and the pinned kernel
/// width is split-stable, so the agreement is tight.
#[test]
fn append_rescore_matches_refactorize_through_core_cache() {
    let mut rng = Pcg64::new(11);
    let n = 140;
    let mut data = Mat::zeros(n, 3);
    for r in 0..n {
        let a = rng.below(3);
        let b = if rng.bernoulli(0.8) { a } else { rng.below(3) };
        let c = rng.below(2);
        data[(r, 0)] = a as f64;
        data[(r, 1)] = b as f64;
        data[(r, 2)] = c as f64;
    }
    let full = Dataset::from_columns(data.clone(), &[true, true, true]);
    use cvlr::score::{ScoreBackend, ScoreRequest};
    let reqs = [
        ScoreRequest::new(1, &[0]),
        ScoreRequest::new(1, &[0, 2]),
        ScoreRequest::new(0, &[]),
    ];

    let streamed = StreamBackend::new(full.head(90), CvParams::default(), LowRankConfig::default());
    let before = streamed.score_batch(&reqs); // factors + fold cores cached
    let again = streamed.score_batch(&reqs);
    assert_eq!(before, again, "cached cores must reproduce scores bit-for-bit");

    streamed.append(&rows_range(&data, 90, n)).unwrap();
    let after = streamed.score_batch(&reqs);
    assert_ne!(before, after, "append must invalidate the fold-core cache");

    let cold = StreamBackend::new(full, CvParams::default(), LowRankConfig::default());
    let want = cold.score_batch(&reqs);
    for (g, w) in after.iter().zip(&want) {
        let rel = ((g - w) / w).abs();
        assert!(
            rel < 1e-9,
            "append + re-score {g} vs refactorize {w} must agree (rel {rel})"
        );
    }
}

/// The RFF invariant (the data-independent twin of
/// `prop_stream_append_matches_refactorize`): streamed RFF factors
/// equal a cold refactorization over the full data **bit for bit** —
/// no tolerance, because the feature map is a pure function of the
/// pinned kernel — and the re-pivot counter stays pinned at 0.
#[test]
fn streamed_rff_append_matches_refactorize_bit_for_bit() {
    let data = chain_rows(240, 9);
    let cfg = LowRankConfig::with_method(FactorMethod::Rff);
    for c in 0..4usize {
        let col = |lo: usize, hi: usize| {
            Mat::from_vec(hi - lo, 1, (lo..hi).map(|r| data[(r, c)]).collect())
        };
        let kern =
            Kernel::Rbf { sigma: median_heuristic(&col(0, 80), CvParams::default().width_factor) };
        let mut st = FactorState::new(kern, &col(0, 80), false, &cfg);
        for (lo, hi) in [(80, 150), (150, 240)] {
            let out = st.append(&col(lo, hi), &|| {
                panic!("RFF appends must never materialize the full block")
            });
            assert!(!out.repivoted);
        }
        assert_eq!(st.repivots(), 0, "RFF has no re-pivot path");
        assert_eq!(st.lambda().rows, 240);
        let cold = factorize(kern, &col(0, 240), false, &cfg);
        assert_eq!(
            st.lambda().data,
            cold.lambda.data,
            "column {c}: streamed RFF factor must equal the cold refactorization bit-for-bit"
        );
    }
}

/// Session-level RFF streaming: appends fold in at O(m) per row with
/// zero re-pivots, the score cache invalidates, re-discovery
/// warm-starts, and streamed scores match a cold RFF backend whose
/// kernels were pinned the same way.
#[test]
fn rff_session_streams_without_repivots() {
    let data = chain_rows(240, 10);
    let full = Dataset::from_columns(data.clone(), &[false; 4]);
    let cfg = StreamConfig {
        lowrank: LowRankConfig::with_method(FactorMethod::Rff),
        ..Default::default()
    };
    let mut sess = StreamingDiscovery::with_config(full.head(80), cfg);
    let first = sess.discover();
    assert!(!first.warm_started);
    for (lo, hi) in [(80, 160), (160, 240)] {
        let ast = sess.append(&rows_range(&data, lo, hi)).unwrap();
        assert_eq!(ast.repivots, 0, "RFF appends never re-pivot: {ast:?}");
        assert!(ast.invalidated > 0, "appends must invalidate cached scores");
        let next = sess.discover();
        assert!(next.warm_started);
    }
    assert_eq!(sess.backend().total_repivots(), 0);
    assert_eq!(sess.n(), 240);

    // streamed scores == cold backend scores bit-for-bit when the cold
    // backend pins its kernels on the same head rows (the feature maps
    // are then identical by construction)
    use cvlr::score::{ScoreBackend, ScoreRequest};
    let reqs = [ScoreRequest::new(1, &[0]), ScoreRequest::new(2, &[1]), ScoreRequest::new(3, &[])];
    let cold = StreamBackend::new(
        full.head(80),
        CvParams::default(),
        LowRankConfig::with_method(FactorMethod::Rff),
    );
    let _ = cold.score_batch(&reqs); // pin kernels on the head rows
    cold.append(&rows_range(&data, 80, 240)).unwrap();
    let want = cold.score_batch(&reqs);
    let got = sess.backend().score_batch(&reqs);
    assert_eq!(got, want, "streamed RFF scores must be bit-for-bit reproducible");
}

/// The forced re-pivot path: with a zero appended-residual budget every
/// chunk refactorizes, and the session still converges to the cold
/// answer (re-pivot = cold factorization by construction).
#[test]
fn forced_repivots_repair_exactness() {
    let data = chain_rows(160, 4);
    let full = Dataset::from_columns(data.clone(), &[false; 4]);
    let backend = StreamBackend::new(
        full.head(80),
        CvParams::default(),
        LowRankConfig { max_rank: 100, eta: 0.0, ..Default::default() },
    );
    use cvlr::score::{ScoreBackend, ScoreRequest};
    let reqs = [ScoreRequest::new(1, &[0]), ScoreRequest::new(2, &[1])];
    let _ = backend.score_batch(&reqs); // materialize factor states
    let ast = backend.append(&rows_range(&data, 80, 160)).unwrap();
    assert!(ast.repivots > 0, "η = 0 must force re-pivots: {ast:?}");
    assert!(backend.total_repivots() > 0);
    // re-pivot = cold factorization over all rows: exactness repaired
    assert!(
        backend.max_reconstruction_error() < 1e-6,
        "re-pivot must repair exactness: {}",
        backend.max_reconstruction_error()
    );

    // post-re-pivot scores equal a cold backend over the full data with
    // the same per-state kernels — which the re-pivot reproduces
    // exactly, so the comparison is at full precision, not 1e-6: the
    // kernels were pinned on the *head*, so pin the cold ones the same
    // way by seeding it with the head and appending before scoring
    let cold = StreamBackend::new(
        full.head(80),
        CvParams::default(),
        LowRankConfig { max_rank: 100, eta: 0.0, ..Default::default() },
    );
    let _ = cold.score_batch(&reqs);
    cold.append(&rows_range(&data, 80, 160)).unwrap();
    let a = backend.score_batch(&reqs);
    let b = cold.score_batch(&reqs);
    assert_eq!(a, b, "re-pivoted scores must be bit-for-bit reproducible");
}
