//! Integration tests of the discovery server over real TCP: the full
//! lifecycle (register CSV dataset → submit → poll progress → fetch
//! result → cancel a second job mid-run → shutdown) plus a
//! concurrent-client stress test asserting no deadlock and cross-job
//! cache hits.

use std::net::SocketAddr;
use std::time::{Duration, Instant};

use cvlr::coordinator::register_score_method;
use cvlr::score::{LocalScore, ScalarBackend};
use cvlr::server::http::request;
use cvlr::server::json::Json;
use cvlr::server::{Server, ServerConfig};
use cvlr::util::Pcg64;

fn start_server(job_workers: usize) -> Server {
    Server::start(ServerConfig {
        port: 0, // ephemeral
        job_workers,
        builtin_n: 120,
        cache_capacity: Some(1 << 18),
        ..Default::default()
    })
    .expect("server starts")
}

/// A CSV chain a→b→c (continuous) plus an independent discrete column.
fn chain_csv(n: usize) -> String {
    let mut rng = Pcg64::new(7);
    let mut s = String::from("a,b,c,grp\n");
    for _ in 0..n {
        let a = rng.normal();
        let b = 1.3 * a + 0.3 * rng.normal();
        let c = -1.1 * b + 0.3 * rng.normal();
        let g = rng.below(3);
        s.push_str(&format!("{a:.6},{b:.6},{c:.6},{g}\n"));
    }
    s
}

fn get(addr: SocketAddr, path: &str) -> (u16, Json) {
    request(addr, "GET", path, None).expect("GET")
}

fn post(addr: SocketAddr, path: &str, body: Json) -> (u16, Json) {
    request(addr, "POST", path, Some(&body)).expect("POST")
}

fn state_of(job: &Json) -> String {
    job.get("state").and_then(Json::as_str).expect("state").to_string()
}

/// Poll until the job is terminal; panics on timeout.
fn poll_until_terminal(addr: SocketAddr, id: u64, timeout: Duration) -> Json {
    let t0 = Instant::now();
    loop {
        let (status, job) = get(addr, &format!("/v1/jobs/{id}"));
        assert_eq!(status, 200, "{job:?}");
        let state = state_of(&job);
        if state == "done" || state == "failed" || state == "cancelled" {
            return job;
        }
        assert!(t0.elapsed() < timeout, "job {id} stuck in `{state}`: {job:?}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn submit_job(addr: SocketAddr, dataset: &str, method: &str) -> u64 {
    let (status, resp) = post(
        addr,
        "/v1/jobs",
        Json::obj(vec![("dataset", Json::str(dataset)), ("method", Json::str(method))]),
    );
    assert_eq!(status, 202, "{resp:?}");
    assert_eq!(resp.get("state").and_then(Json::as_str), Some("queued"));
    resp.get("id").and_then(Json::as_u64).expect("job id")
}

#[test]
fn full_lifecycle_over_tcp() {
    // a deliberately slow score so cancellation reliably lands mid-run;
    // rewards inserts so GES sweeps many times
    register_score_method("it-slow", &[], |ds, _| {
        struct Slow(std::sync::Arc<cvlr::data::Dataset>);
        impl LocalScore for Slow {
            fn local_score(&self, t: usize, p: &[usize]) -> f64 {
                std::thread::sleep(Duration::from_millis(10));
                t as f64 * 0.01 + p.len() as f64
            }
            fn num_vars(&self) -> usize {
                self.0.d()
            }
        }
        Ok(std::sync::Arc::new(ScalarBackend(Slow(ds))))
    });

    let server = start_server(2);
    let addr = server.addr();

    // --- register a CSV dataset, types inferred per column
    let (status, reg) = post(
        addr,
        "/v1/datasets",
        Json::obj(vec![("name", Json::str("chain")), ("csv", Json::str(chain_csv(400)))]),
    );
    assert_eq!(status, 201, "{reg:?}");
    assert_eq!(reg.get("n").and_then(Json::as_u64), Some(400));
    assert_eq!(reg.get("d").and_then(Json::as_u64), Some(4));
    let vars = reg.get("vars").and_then(Json::as_arr).expect("vars");
    assert_eq!(vars[0].get("name").and_then(Json::as_str), Some("a"));
    assert_eq!(vars[0].get("discrete").and_then(Json::as_bool), Some(false));
    assert_eq!(vars[3].get("discrete").and_then(Json::as_bool), Some(true));
    assert_eq!(vars[3].get("cardinality").and_then(Json::as_u64), Some(3));

    // --- submit a discovery job and poll it to completion
    let id = submit_job(addr, "chain", "bic");
    let job = poll_until_terminal(addr, id, Duration::from_secs(120));
    assert_eq!(state_of(&job), "done", "{job:?}");
    let progress = job.get("progress").expect("progress");
    assert!(progress.get("sweeps").and_then(Json::as_u64).unwrap() > 0);
    assert!(progress.get("candidates").and_then(Json::as_u64).unwrap() > 0);
    let result = job.get("result").expect("done job carries a result");
    let edges = result.get("edges").and_then(Json::as_arr).expect("edges");
    assert!(!edges.is_empty(), "the chain has structure: {result:?}");
    // SHD-ready adjacency: d×d 0/1 matrix; the a—b and b—c links exist
    let adj = result.get("adjacency").and_then(Json::as_arr).expect("adjacency");
    assert_eq!(adj.len(), 4);
    let at = |i: usize, j: usize| adj[i].as_arr().unwrap()[j].as_f64().unwrap();
    assert!(at(0, 1) + at(1, 0) > 0.0, "a—b missing: {result:?}");
    assert!(at(1, 2) + at(2, 1) > 0.0, "b—c missing: {result:?}");
    // service stats travel with the result, including eviction counters
    let stats = result.get("stats").expect("score job carries stats");
    assert_eq!(stats.get("consistent").and_then(Json::as_bool), Some(true));
    assert!(stats.get("evictions").and_then(Json::as_f64).is_some());
    assert!(stats.get("evaluations").and_then(Json::as_u64).unwrap() > 0);

    // --- an identical job is served from the shared score cache
    let id2 = submit_job(addr, "chain", "bic");
    let job2 = poll_until_terminal(addr, id2, Duration::from_secs(120));
    assert_eq!(state_of(&job2), "done");
    let p2 = job2.get("progress").expect("progress");
    assert_eq!(
        p2.get("evaluations").and_then(Json::as_u64),
        Some(0),
        "identical job must re-evaluate nothing: {job2:?}"
    );
    assert!(p2.get("cache_hits").and_then(Json::as_u64).unwrap() > 0, "{job2:?}");

    // --- cancel a slow job mid-run
    let slow = submit_job(addr, "chain", "it-slow");
    let t0 = Instant::now();
    loop {
        let (_, j) = get(addr, &format!("/v1/jobs/{slow}"));
        let started = state_of(&j) == "running"
            && j.get("progress").and_then(|p| p.get("candidates")).and_then(Json::as_u64).unwrap()
                > 0;
        if started {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(60), "slow job never started: {j:?}");
        std::thread::sleep(Duration::from_millis(10));
    }
    let (status, cancel) =
        request(addr, "DELETE", &format!("/v1/jobs/{slow}"), None).expect("DELETE");
    assert_eq!(status, 200, "{cancel:?}");
    let cancelled = poll_until_terminal(addr, slow, Duration::from_secs(60));
    assert_eq!(state_of(&cancelled), "cancelled", "{cancelled:?}");
    assert!(cancelled.get("result").is_none(), "cancelled job publishes no result");

    // --- server-wide stats: jobs by state + per-service cache counters
    let (status, stats) = get(addr, "/v1/stats");
    assert_eq!(status, 200);
    let jobs = stats.get("jobs").expect("job counts");
    assert_eq!(jobs.get("done").and_then(Json::as_u64), Some(2));
    assert_eq!(jobs.get("cancelled").and_then(Json::as_u64), Some(1));
    let services = stats.get("services").and_then(Json::as_arr).expect("services");
    let bic = services
        .iter()
        .find(|s| s.get("method").and_then(Json::as_str) == Some("bic"))
        .expect("bic service pooled");
    let st = bic.get("stats").expect("stats");
    assert!(
        st.get("cache_hits").and_then(Json::as_u64).unwrap() > 0,
        "cross-job cache hits must show up in /v1/stats: {st:?}"
    );
    assert_eq!(st.get("consistent").and_then(Json::as_bool), Some(true));

    // --- strict validation and routing errors
    let (status, _) = get(addr, "/nope");
    assert_eq!(status, 404);
    let (status, e) = post(
        addr,
        "/v1/jobs",
        Json::obj(vec![("dataset", Json::str("chain")), ("method", Json::str("nope"))]),
    );
    assert_eq!(status, 400, "{e:?}");
    let (status, e) = post(
        addr,
        "/v1/jobs",
        Json::obj(vec![
            ("dataset", Json::str("chain")),
            ("method", Json::str("bic")),
            ("typo_field", Json::Bool(true)),
        ]),
    );
    assert_eq!(status, 400, "unknown fields must be rejected: {e:?}");
    let (status, _) =
        request(addr, "DELETE", "/v1/jobs/999999", None).expect("DELETE unknown");
    assert_eq!(status, 404);

    // --- deleting a dataset retires it and its pooled services
    let (status, del) =
        request(addr, "DELETE", "/v1/datasets/chain", None).expect("DELETE dataset");
    assert_eq!(status, 200, "{del:?}");
    let (_, list) = get(addr, "/v1/datasets");
    let names: Vec<&str> = list
        .get("datasets")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .filter_map(|d| d.get("name").and_then(Json::as_str))
        .collect();
    assert!(!names.contains(&"chain"), "{list:?}");
    let (status, e) = post(
        addr,
        "/v1/jobs",
        Json::obj(vec![("dataset", Json::str("chain")), ("method", Json::str("bic"))]),
    );
    assert_eq!(status, 400, "jobs on a deleted dataset must fail at submit: {e:?}");
    let (_, stats2) = get(addr, "/v1/stats");
    let services2 = stats2.get("services").and_then(Json::as_arr).unwrap();
    assert!(
        services2.iter().all(|s| s.get("dataset").and_then(Json::as_str) != Some("chain")),
        "pooled services must be retired with the dataset: {stats2:?}"
    );
    let (status, _) =
        request(addr, "DELETE", "/v1/datasets/chain", None).expect("DELETE again");
    assert_eq!(status, 404, "double delete is a 404");

    // --- graceful shutdown over the wire
    let (status, bye) = post(addr, "/v1/shutdown", Json::obj(vec![]));
    assert_eq!(status, 200, "{bye:?}");
    server.wait(); // returns once the accept loop drained and jobs stopped
}

#[test]
fn concurrent_clients_stress() {
    // slow method for the cancelling clients (same shape as `it-slow`,
    // registered here so this test is self-contained)
    register_score_method("stress-slow", &[], |ds, _| {
        struct Slow(std::sync::Arc<cvlr::data::Dataset>);
        impl LocalScore for Slow {
            fn local_score(&self, t: usize, p: &[usize]) -> f64 {
                std::thread::sleep(Duration::from_millis(4));
                t as f64 * 0.01 + p.len() as f64
            }
            fn num_vars(&self) -> usize {
                self.0.d()
            }
        }
        Ok(std::sync::Arc::new(ScalarBackend(Slow(ds))))
    });

    let server = start_server(3);
    let addr = server.addr();
    let clients = 8;
    let t0 = Instant::now();
    let ids: Vec<u64> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..clients {
            handles.push(scope.spawn(move || {
                // overlapping workloads: everyone hammers the same
                // (dataset, method) pair; even clients run a second
                // method, odd clients cancel a slow job mid-run
                let id = submit_job(addr, "synth", "bic");
                if c % 2 == 0 {
                    let id2 = submit_job(addr, "synth", "sc");
                    let job2 = poll_until_terminal(addr, id2, Duration::from_secs(180));
                    assert_eq!(state_of(&job2), "done", "client {c}: {job2:?}");
                } else {
                    // a private dataset per cancelling client keeps its
                    // slow job's cache cold, so the cancel always lands
                    // while work is still in flight
                    let ds_name = format!("synth-c{c}");
                    let (status, resp) = post(
                        addr,
                        "/v1/datasets",
                        Json::obj(vec![
                            ("name", Json::str(ds_name.clone())),
                            ("builtin", Json::str("synth")),
                            ("n", Json::Num(150.0)),
                            ("seed", Json::Num(c as f64)),
                        ]),
                    );
                    assert_eq!(status, 201, "client {c}: {resp:?}");
                    let slow = submit_job(addr, &ds_name, "stress-slow");
                    let t0 = Instant::now();
                    loop {
                        let (_, j) = get(addr, &format!("/v1/jobs/{slow}"));
                        let candidates = j
                            .get("progress")
                            .and_then(|p| p.get("candidates"))
                            .and_then(Json::as_u64)
                            .unwrap();
                        if state_of(&j) == "running" && candidates > 0 {
                            break;
                        }
                        assert!(
                            t0.elapsed() < Duration::from_secs(120),
                            "client {c}: slow job never started: {j:?}"
                        );
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    let (status, _) = request(addr, "DELETE", &format!("/v1/jobs/{slow}"), None)
                        .expect("DELETE");
                    assert_eq!(status, 200);
                    let jc = poll_until_terminal(addr, slow, Duration::from_secs(120));
                    assert_eq!(state_of(&jc), "cancelled", "client {c}: {jc:?}");
                }
                let job = poll_until_terminal(addr, id, Duration::from_secs(180));
                assert_eq!(state_of(&job), "done", "client {c}: {job:?}");
                id
            }));
        }
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });
    assert_eq!(ids.len(), clients);
    assert!(t0.elapsed() < Duration::from_secs(300), "no deadlock under concurrency");

    let (status, stats) = get(addr, "/v1/stats");
    assert_eq!(status, 200);
    let jobs = stats.get("jobs").expect("jobs");
    let done = jobs.get("done").and_then(Json::as_u64).unwrap();
    let cancelled = jobs.get("cancelled").and_then(Json::as_u64).unwrap();
    assert_eq!(done as usize, clients + clients / 2, "{stats:?}");
    assert_eq!(cancelled as usize, clients / 2, "{stats:?}");
    // overlapping jobs on one pooled service ⇒ cross-job cache hits,
    // and the stats identity survives concurrency
    let services = stats.get("services").and_then(Json::as_arr).expect("services");
    assert!(!services.is_empty());
    for svc in services {
        let st = svc.get("stats").expect("stats");
        assert_eq!(st.get("consistent").and_then(Json::as_bool), Some(true), "{svc:?}");
    }
    let bic = services
        .iter()
        .find(|s| s.get("method").and_then(Json::as_str) == Some("bic"))
        .expect("pooled bic service");
    let hits = bic.get("stats").and_then(|s| s.get("cache_hits")).and_then(Json::as_u64).unwrap();
    assert!(hits > 0, "8 identical jobs must share the cache: {bic:?}");

    server.stop();
}

#[test]
fn streaming_append_and_warm_start_over_tcp() {
    let server = start_server(2);
    let addr = server.addr();

    // register a CSV dataset and run a cold job to populate the pooled
    // service (cache + warm-start CPDAG)
    let (status, resp) = post(
        addr,
        "/v1/datasets",
        Json::obj(vec![("name", Json::str("streamed")), ("csv", Json::str(chain_csv(150)))]),
    );
    assert_eq!(status, 201, "{resp:?}");
    let cold_id = submit_job(addr, "streamed", "bic");
    let cold = poll_until_terminal(addr, cold_id, Duration::from_secs(120));
    assert_eq!(state_of(&cold), "done", "{cold:?}");
    let cold_edges = cold
        .get("result")
        .and_then(|r| r.get("num_edges"))
        .and_then(Json::as_u64)
        .expect("num_edges");

    // append rows in internal coordinates: continuous columns are
    // z-scored at ingestion, so 0 = column mean; `grp` levels are codes
    let (status, resp) = post(
        addr,
        "/v1/datasets/streamed/rows",
        Json::obj(vec![("csv", Json::str("0.0,0.0,0.0,1\n0.1,0.1,-0.1,0\n"))]),
    );
    assert_eq!(status, 200, "{resp:?}");
    assert_eq!(resp.get("appended").and_then(Json::as_u64), Some(2), "{resp:?}");
    assert_eq!(resp.get("n").and_then(Json::as_u64), Some(152), "{resp:?}");
    assert_eq!(resp.get("row_version").and_then(Json::as_u64), Some(1), "{resp:?}");
    let invalidated = resp.get("invalidated").and_then(Json::as_u64).unwrap();
    assert!(invalidated > 0, "the cold job's cached scores must be invalidated: {resp:?}");

    // malformed appends are rejected with a clear error
    for bad in ["1,2\n", "a,b,c,d\n", "0.0,inf,0.0,1\n", "0.0,0.0,0.0,0.5\n"] {
        let (status, err) = post(
            addr,
            "/v1/datasets/streamed/rows",
            Json::obj(vec![("csv", Json::str(bad))]),
        );
        assert_eq!(status, 400, "`{bad}` must be rejected: {err:?}");
    }
    let (status, err) = post(
        addr,
        "/v1/datasets/nope/rows",
        Json::obj(vec![("csv", Json::str("1\n"))]),
    );
    assert_eq!(status, 404, "{err:?}");

    // warm_start re-discovery on the appended dataset
    let (status, resp) = post(
        addr,
        "/v1/jobs",
        Json::obj(vec![
            ("dataset", Json::str("streamed")),
            ("method", Json::str("bic")),
            ("warm_start", Json::Bool(true)),
        ]),
    );
    assert_eq!(status, 202, "{resp:?}");
    let warm_id = resp.get("id").and_then(Json::as_u64).unwrap();
    let warm = poll_until_terminal(addr, warm_id, Duration::from_secs(120));
    assert_eq!(state_of(&warm), "done", "{warm:?}");
    let warm_edges = warm
        .get("result")
        .and_then(|r| r.get("num_edges"))
        .and_then(Json::as_u64)
        .expect("num_edges");
    assert_eq!(warm_edges, cold_edges, "two near-mean rows must not change the structure");

    // the pool entry survived the append and reports both counters
    let (_, stats) = get(addr, "/v1/stats");
    let services = stats.get("services").and_then(Json::as_arr).expect("services");
    let svc = services
        .iter()
        .find(|s| s.get("dataset").and_then(Json::as_str) == Some("streamed"))
        .expect("pooled service for `streamed`");
    let st = svc.get("stats").expect("stats");
    assert!(st.get("invalidations").and_then(Json::as_u64).unwrap() > 0, "{svc:?}");
    assert!(st.get("warm_start_hits").and_then(Json::as_u64).unwrap() >= 1, "{svc:?}");
    assert_eq!(st.get("consistent").and_then(Json::as_bool), Some(true), "{svc:?}");

    server.stop();
}

#[test]
fn lowrank_job_option_pools_separate_services() {
    let server = start_server(2);
    let addr = server.addr();

    // cv-lr with the default (icl) and the rff factorization: both run
    // to done, and land on SEPARATE pooled services — their factors
    // (and therefore every memoized score) differ
    for lowrank in ["icl", "rff"] {
        let (status, resp) = post(
            addr,
            "/v1/jobs",
            Json::obj(vec![
                ("dataset", Json::str("synth")),
                ("method", Json::str("cv-lr")),
                ("lowrank", Json::str(lowrank)),
            ]),
        );
        assert_eq!(status, 202, "{resp:?}");
        let id = resp.get("id").and_then(Json::as_u64).unwrap();
        let job = poll_until_terminal(addr, id, Duration::from_secs(300));
        assert_eq!(state_of(&job), "done", "lowrank={lowrank}: {job:?}");
    }

    let (_, stats) = get(addr, "/v1/stats");
    let services = stats.get("services").and_then(Json::as_arr).expect("services");
    let mut methods: Vec<String> = services
        .iter()
        .filter(|s| s.get("method").and_then(Json::as_str) == Some("cv-lr"))
        .map(|s| s.get("lowrank").and_then(Json::as_str).expect("lowrank key").to_string())
        .collect();
    methods.sort();
    assert_eq!(methods, vec!["icl", "rff"], "one pooled service per factorization");
    for svc in services.iter() {
        if svc.get("method").and_then(Json::as_str) != Some("cv-lr") {
            continue;
        }
        let st = svc.get("stats").expect("stats");
        // the fold-core cache counters are live for CV-LR services
        assert!(
            st.get("core_cache_entries").and_then(Json::as_u64).unwrap() > 0,
            "{svc:?}"
        );
        assert_eq!(st.get("consistent").and_then(Json::as_bool), Some(true), "{svc:?}");
    }

    // unknown factorizations fail loudly at submit
    let (status, err) = post(
        addr,
        "/v1/jobs",
        Json::obj(vec![
            ("dataset", Json::str("synth")),
            ("method", Json::str("cv-lr")),
            ("lowrank", Json::str("nope")),
        ]),
    );
    assert_eq!(status, 400, "{err:?}");

    server.stop();
}
