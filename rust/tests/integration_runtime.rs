//! Runtime integration: the AOT-compiled XLA artifacts must agree with
//! the native rust implementation to floating-point precision, across
//! buckets and padding configurations.
//!
//! Requires `artifacts/` (run `make artifacts` first — `make test` does).

use std::sync::Arc;

use cvlr::data::Dataset;
use cvlr::linalg::Mat;
use cvlr::runtime::pjrt_kernel::{PjrtCvLrKernel, PjrtExactScorer};
use cvlr::runtime::Runtime;
use cvlr::score::cv_exact::CvExactScore;
use cvlr::score::cvlr::{split_center, CvLrKernel, CvLrScore, NativeCvLrKernel};
use cvlr::score::folds::{stride_folds, CvParams};
use cvlr::score::LocalScore;
use cvlr::util::Pcg64;

fn artifacts_dir() -> String {
    std::env::var("CVLR_ARTIFACTS").unwrap_or_else(|_| {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    })
}

fn runtime() -> Arc<Runtime> {
    Arc::new(Runtime::load(artifacts_dir()).expect("run `make artifacts` first"))
}

fn random_factors(n: usize, m: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::new(seed);
    let mut f = Mat::zeros(n, m);
    for v in &mut f.data {
        *v = rng.normal();
    }
    f
}

#[test]
fn pjrt_cond_matches_native_across_buckets() {
    let rt = runtime();
    let pjrt = PjrtCvLrKernel::new(rt);
    let native = NativeCvLrKernel;
    let p = CvParams::default();
    for (n, mx, mz, seed) in [(100usize, 7usize, 5usize, 1u64), (300, 30, 18, 2), (900, 100, 100, 3)] {
        let lx = random_factors(n, mx, seed);
        let lz = random_factors(n, mz, seed + 10);
        let folds = stride_folds(n, 10);
        let (test, train) = &folds[0];
        let (lx0, lx1) = split_center(&lx, test, train);
        let (lz0, lz1) = split_center(&lz, test, train);
        let want = native.score_cond(&lx0, &lx1, &lz0, &lz1, &p);
        let got = pjrt.score_cond(&lx0, &lx1, &lz0, &lz1, &p);
        let rel = ((want - got) / want).abs();
        assert!(rel < 1e-9, "n={n}: native {want} vs pjrt {got} (rel {rel})");
    }
}

#[test]
fn pjrt_marg_matches_native() {
    let rt = runtime();
    let pjrt = PjrtCvLrKernel::new(rt);
    let native = NativeCvLrKernel;
    let p = CvParams::default();
    for (n, mx, seed) in [(80usize, 4usize, 4u64), (500, 64, 5)] {
        let lx = random_factors(n, mx, seed);
        let folds = stride_folds(n, 10);
        let (test, train) = &folds[2];
        let (lx0, lx1) = split_center(&lx, test, train);
        let want = native.score_marg(&lx0, &lx1, &p);
        let got = pjrt.score_marg(&lx0, &lx1, &p);
        let rel = ((want - got) / want).abs();
        assert!(rel < 1e-9, "n={n}: native {want} vs pjrt {got} (rel {rel})");
    }
}

#[test]
fn pjrt_full_local_score_matches_native() {
    // end-to-end: CvLrScore with the PJRT backend == native backend
    let mut rng = Pcg64::new(7);
    let n = 150;
    let mut data = Mat::zeros(n, 3);
    for r in 0..n {
        let x1 = rng.normal();
        let x2 = (1.3 * x1).tanh() + 0.3 * rng.normal();
        let x3 = rng.normal();
        data[(r, 0)] = x1;
        data[(r, 1)] = x2;
        data[(r, 2)] = x3;
    }
    let ds = Arc::new(Dataset::from_columns(data, &[false; 3]));
    let native = CvLrScore::native(ds.clone());
    let pjrt = CvLrScore::with_backend(
        ds,
        CvParams::default(),
        cvlr::lowrank::LowRankConfig::default(),
        PjrtCvLrKernel::new(runtime()),
    );
    for (t, pa) in [(1usize, vec![0usize]), (0, vec![]), (2, vec![0, 1])] {
        let a = native.local_score(t, &pa);
        let b = pjrt.local_score(t, &pa);
        let rel = ((a - b) / a).abs();
        assert!(rel < 1e-9, "({t},{pa:?}): native {a} pjrt {b}");
    }
}

#[test]
fn pjrt_exact_matches_rust_exact() {
    // the exact_cond_n200 artifact vs score::cv_exact on one fold
    let mut rng = Pcg64::new(9);
    let n = 200;
    let mut data = Mat::zeros(n, 2);
    for r in 0..n {
        let x1 = rng.normal();
        let x2 = (x1).sin() + 0.4 * rng.normal();
        data[(r, 0)] = x1;
        data[(r, 1)] = x2;
    }
    let ds = Arc::new(Dataset::from_columns(data, &[false, false]));
    let p = CvParams::default();

    // rust exact: fold 0 score via the module's internals is private —
    // use the public local_score (10-fold average) and compare against
    // the PJRT average over the same folds.
    let exact = CvExactScore::new(ds.clone(), p);
    let want = exact.local_score(1, &[0]);

    let rt = runtime();
    let scorer = PjrtExactScorer::new(rt);
    let xb = ds.block(1);
    let zb = ds.block(0);
    let sigx = cvlr::kernel::median_heuristic(&xb, p.width_factor);
    let sigz = cvlr::kernel::median_heuristic(&zb, p.width_factor);
    let folds = stride_folds(n, 10);
    let mut total = 0.0;
    for (test, train) in &folds {
        let x0 = xb.select_rows(test);
        let x1 = xb.select_rows(train);
        let z0 = zb.select_rows(test);
        let z1 = zb.select_rows(train);
        total += scorer.fold_cond(&x0, &x1, &z0, &z1, sigx, sigz, &p).unwrap();
    }
    let got = total / 10.0;
    let rel = ((want - got) / want).abs();
    assert!(rel < 1e-8, "exact rust {want} vs exact pjrt {got} (rel {rel})");
}

#[test]
fn bucket_selection() {
    let rt = runtime();
    assert_eq!(rt.bucket_for(100).unwrap(), 256);
    assert_eq!(rt.bucket_for(256).unwrap(), 256);
    assert_eq!(rt.bucket_for(257).unwrap(), 512);
    assert_eq!(rt.bucket_for(3600).unwrap(), 4096);
    assert!(rt.bucket_for(5000).is_err());
}

#[test]
fn execution_counter_increments() {
    let rt = runtime();
    let pjrt = PjrtCvLrKernel::new(rt.clone());
    let p = CvParams::default();
    let lx = random_factors(60, 3, 11);
    let folds = stride_folds(60, 10);
    let (test, train) = &folds[0];
    let (lx0, lx1) = split_center(&lx, test, train);
    let before = rt.executions();
    let _ = pjrt.score_marg(&lx0, &lx1, &p);
    assert_eq!(rt.executions(), before + 1);
}
