//! Integration tests of distributed sharded scoring over real TCP:
//! a coordinator fanning GES score batches out across follower
//! `cvlr serve` processes (in-process [`Server`] instances here).
//!
//! The property under test is the module's core invariant: **sharded
//! results are bit-identical to local scoring** — through healthy
//! fleets, a follower killed mid-sweep, and a follower dead from the
//! start — and every failure surfaces in the shard counters rather
//! than in the CPDAG.

use std::net::SocketAddr;
use std::sync::Arc;
use std::time::{Duration, Instant};

use cvlr::coordinator::Discovery;
use cvlr::data::synth::{generate, SynthConfig};
use cvlr::distrib::wire;
use cvlr::distrib::ShardSpec;
use cvlr::score::ScoreRequest;
use cvlr::server::http::request;
use cvlr::server::json::Json;
use cvlr::server::{Server, ServerConfig};
use cvlr::util::Pcg64;

fn start_follower() -> Server {
    Server::start(ServerConfig {
        port: 0, // ephemeral
        job_workers: 1,
        builtin_n: 40,
        cache_capacity: Some(1 << 16),
        ..Default::default()
    })
    .expect("follower starts")
}

fn get(addr: SocketAddr, path: &str) -> (u16, Json) {
    request(addr, "GET", path, None).expect("GET")
}

fn post(addr: SocketAddr, path: &str, body: Json) -> (u16, Json) {
    request(addr, "POST", path, Some(&body)).expect("POST")
}

/// A CSV chain a→b→c (continuous) plus an independent discrete column.
fn chain_csv(n: usize) -> String {
    let mut rng = Pcg64::new(7);
    let mut s = String::from("a,b,c,grp\n");
    for _ in 0..n {
        let a = rng.normal();
        let b = 1.3 * a + 0.3 * rng.normal();
        let c = -1.1 * b + 0.3 * rng.normal();
        let g = rng.below(3);
        s.push_str(&format!("{a:.6},{b:.6},{c:.6},{g}\n"));
    }
    s
}

/// Poll until the job is terminal; panics on timeout.
fn poll_until_terminal(addr: SocketAddr, id: u64, timeout: Duration) -> Json {
    let t0 = Instant::now();
    loop {
        let (status, job) = get(addr, &format!("/v1/jobs/{id}"));
        assert_eq!(status, 200, "{job:?}");
        let state = job.get("state").and_then(Json::as_str).expect("state").to_string();
        if state == "done" || state == "failed" || state == "cancelled" {
            return job;
        }
        assert!(t0.elapsed() < timeout, "job {id} stuck in `{state}`: {job:?}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Submit a job with extra body entries; returns the finished job JSON.
fn run_job(addr: SocketAddr, dataset: &str, extra: Vec<(&str, Json)>) -> Json {
    let mut body = vec![("dataset", Json::str(dataset)), ("method", Json::str("cv-lr"))];
    body.extend(extra);
    let (status, resp) = post(addr, "/v1/jobs", Json::obj(body));
    assert_eq!(status, 202, "{resp:?}");
    let id = resp.get("id").and_then(Json::as_u64).expect("job id");
    let job = poll_until_terminal(addr, id, Duration::from_secs(180));
    assert_eq!(job.get("state").and_then(Json::as_str), Some("done"), "{job:?}");
    job
}

/// Flatten a job result's adjacency matrix to f64s for exact compare.
fn adjacency_of(job: &Json) -> Vec<f64> {
    let adj = job
        .get("result")
        .and_then(|r| r.get("adjacency"))
        .and_then(Json::as_arr)
        .expect("adjacency");
    adj.iter()
        .flat_map(|row| row.as_arr().expect("row").iter().map(|v| v.as_f64().expect("cell")))
        .collect()
}

/// End-to-end over the `Discovery` builder: a two-follower fleet must
/// reproduce the local CPDAG exactly — with both followers healthy,
/// with one killed mid-sweep, and with one dead from the first dispatch
/// (connection refused → retry/hop/degrade, never corruption).
#[test]
fn sharded_discovery_is_bit_identical_and_survives_follower_loss() {
    let (ds, _) = generate(&SynthConfig {
        num_vars: 5,
        density: 0.5,
        n: 120,
        seed: 11,
        ..Default::default()
    });
    let ds = Arc::new(ds);

    let baseline = Discovery::builder(ds.clone()).method("cv-lr").run().expect("local run");

    let f1 = start_follower();
    let f2 = start_follower();
    let (a1, a2) = (f1.addr().to_string(), f2.addr().to_string());

    // --- healthy fleet: identical CPDAG, and the fleet saw real work
    let sharded = Discovery::builder(ds.clone())
        .method("cv-lr")
        .shards([a1.clone(), a2.clone()])
        .shard_dataset("it-distrib")
        .run()
        .expect("sharded run");
    assert_eq!(sharded.cpdag, baseline.cpdag, "sharded CPDAG must match local exactly");
    let st = sharded.score_stats.expect("score stats");
    assert!(st.shard_dispatches > 0, "no sub-batch ever reached the fleet");

    // --- kill follower 2 while a sharded sweep is (likely) in flight
    let (ds2, b1, b2) = (ds.clone(), a1.clone(), a2.clone());
    let running = std::thread::spawn(move || {
        Discovery::builder(ds2)
            .method("cv-lr")
            .shards([b1, b2])
            .shard_dataset("it-distrib")
            .run()
            .expect("sharded run with mid-sweep kill")
    });
    std::thread::sleep(Duration::from_millis(25));
    f2.stop();
    let killed = running.join().expect("sweep survives the kill");
    assert_eq!(killed.cpdag, baseline.cpdag, "mid-sweep follower loss corrupted the CPDAG");

    // --- follower 2 stays dead: every dispatch to it is refused, so the
    // lane retries onto follower 1 (or degrades locally) — visible in
    // the counters, invisible in the result
    let dead = Discovery::builder(ds.clone())
        .method("cv-lr")
        .shards([a1, a2])
        .shard_dataset("it-distrib")
        .run()
        .expect("sharded run with a dead follower");
    assert_eq!(dead.cpdag, baseline.cpdag, "dead follower corrupted the CPDAG");
    let st = dead.score_stats.expect("score stats");
    assert!(st.shard_dispatches > 0, "live follower still serves");
    assert!(
        st.shard_retries + st.shard_degraded > 0,
        "a dead follower must surface as retries or degradation"
    );

    f1.stop();
}

/// The server as coordinator: `ServerConfig::shards` turns jobs into
/// sharded sweeps, a per-job `"shards": []` override forces local
/// scoring, the two results agree bit-for-bit, and `/v1/stats` exposes
/// the per-follower counters.
#[test]
fn coordinator_server_shards_jobs_and_reports_follower_stats() {
    let f1 = start_follower();
    let f2 = start_follower();
    let fleet = vec![f1.addr().to_string(), f2.addr().to_string()];
    let coord = Server::start(ServerConfig {
        port: 0,
        job_workers: 2,
        builtin_n: 40,
        cache_capacity: Some(1 << 16),
        shards: fleet.clone(),
        ..Default::default()
    })
    .expect("coordinator starts");
    let addr = coord.addr();

    let (status, reg) = post(
        addr,
        "/v1/datasets",
        Json::obj(vec![("name", Json::str("chain")), ("csv", Json::str(chain_csv(200)))]),
    );
    assert_eq!(status, 201, "{reg:?}");

    // default fleet from the server config vs an explicit local override
    let sharded = run_job(addr, "chain", vec![]);
    let local = run_job(addr, "chain", vec![("shards", Json::Arr(vec![]))]);
    assert_eq!(
        adjacency_of(&sharded),
        adjacency_of(&local),
        "sharded job result must be bit-identical to the local job"
    );

    // the sharded service (non-empty shards key) reports fleet counters
    let (status, stats) = get(addr, "/v1/stats");
    assert_eq!(status, 200, "{stats:?}");
    let services = stats.get("services").and_then(Json::as_arr).expect("services");
    let sharded_svc = services
        .iter()
        .find(|s| s.get("shards").and_then(Json::as_str).is_some_and(|v| !v.is_empty()))
        .expect("a sharded service is pooled");
    let st = sharded_svc.get("stats").expect("stats");
    assert!(st.get("shard_dispatches").and_then(Json::as_u64).unwrap() > 0, "{st:?}");
    let followers = st.get("followers").and_then(Json::as_arr).expect("followers");
    assert_eq!(followers.len(), 2, "{st:?}");
    let mut dispatched = 0u64;
    for f in followers {
        let fa = f.get("addr").and_then(Json::as_str).expect("addr");
        assert!(fleet.iter().any(|a| a == fa), "unknown follower {fa}");
        assert!(f.get("healthy").and_then(Json::as_bool).is_some());
        assert!(f.get("ewma_ms").and_then(Json::as_f64).is_some());
        dispatched += f.get("dispatches").and_then(Json::as_u64).expect("dispatches");
    }
    assert!(dispatched > 0, "per-follower dispatch counters never moved: {st:?}");
    // the local service coexists under its own key (empty shards)
    assert!(
        services
            .iter()
            .any(|s| s.get("shards").and_then(Json::as_str) == Some("")
                && s.get("dataset").and_then(Json::as_str) == Some("chain")),
        "{services:?}"
    );

    coord.stop();
    f1.stop();
    f2.stop();
}

/// The wire protocol of `POST /v1/score_batch` itself: 404 before the
/// dataset push, 409 on a stale version pin, 400 on an unknown method,
/// then bit-stable scores once registered.
#[test]
fn score_batch_endpoint_protocol() {
    let f = start_follower();
    let addr = f.addr();
    let (ds, _) =
        generate(&SynthConfig { num_vars: 4, n: 80, seed: 9, ..Default::default() });
    let spec = |dataset: &str, method: &str| ShardSpec {
        dataset: dataset.to_string(),
        method: method.to_string(),
        engine: "native".to_string(),
        lowrank: "icl".to_string(),
    };
    let reqs =
        vec![ScoreRequest::new(0, &[]), ScoreRequest::new(1, &[0]), ScoreRequest::new(2, &[0, 1])];

    // unknown dataset: the follower asks for the raw push
    let body = wire::score_batch_body(&spec("nope", "cv-lr"), None, None, &reqs);
    let (status, resp) = post(addr, "/v1/score_batch", body);
    assert_eq!(status, 404, "{resp:?}");

    // raw push in internal coordinates; the follower assigns a version
    let (status, reg) = post(addr, "/v1/datasets", wire::dataset_body("wiretest", &ds));
    assert_eq!(status, 201, "{reg:?}");
    let version = reg.get("version").and_then(Json::as_u64).expect("version");

    // stale version pin: the coordinator must re-push, not get stale bits
    let (status, resp) = post(
        addr,
        "/v1/score_batch",
        wire::score_batch_body(&spec("wiretest", "cv-lr"), Some(version + 1), None, &reqs),
    );
    assert_eq!(status, 409, "{resp:?}");

    // unknown method
    let (status, resp) = post(
        addr,
        "/v1/score_batch",
        wire::score_batch_body(&spec("wiretest", "nope"), Some(version), None, &reqs),
    );
    assert_eq!(status, 400, "{resp:?}");

    // a correct pin scores; a repeat is bit-identical (memoized or not)
    let body = wire::score_batch_body(&spec("wiretest", "cv-lr"), Some(version), None, &reqs);
    let (status, resp) = post(addr, "/v1/score_batch", body.clone());
    assert_eq!(status, 200, "{resp:?}");
    assert_eq!(resp.get("version").and_then(Json::as_u64), Some(version));
    let scores = wire::parse_scores(&resp, reqs.len()).expect("scores");
    assert!(scores.iter().all(|s| s.is_finite()), "{scores:?}");
    let (status, resp) = post(addr, "/v1/score_batch", body);
    assert_eq!(status, 200, "{resp:?}");
    let again = wire::parse_scores(&resp, reqs.len()).expect("scores");
    for (a, b) in scores.iter().zip(&again) {
        assert_eq!(a.to_bits(), b.to_bits(), "follower scoring must be bit-stable");
    }

    f.stop();
}
