//! Chaos property tests — randomized failpoint schedules over a
//! two-follower sharded fleet (requires `--features fail-inject`).
//!
//! The property under test is the robustness contract of the serving
//! stack: **under any injected fault schedule, a discovery run
//! terminates within its wall-clock bound and either returns the
//! bit-identical CPDAG of a fault-free local run or fails with a typed
//! error** — never a hang, never a silently wrong graph.
//!
//! The failpoint registry is process-global, so every test here
//! serializes on one mutex; schedules are derived from a fixed PCG
//! seed so a failing round reproduces exactly.

#![cfg(feature = "fail-inject")]

use std::net::SocketAddr;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use cvlr::coordinator::Discovery;
use cvlr::data::synth::{generate, SynthConfig};
use cvlr::obs::fail;
use cvlr::server::http::request;
use cvlr::server::json::Json;
use cvlr::server::{Server, ServerConfig};
use cvlr::util::{DeadlineExceeded, Pcg64};

/// Serializes tests against the process-global failpoint registry.
static CHAOS_LOCK: Mutex<()> = Mutex::new(());

/// Guard that disarms every failpoint when a test (or an assert inside
/// it) exits, so one failing round can't poison the next test.
struct ClearOnDrop;
impl Drop for ClearOnDrop {
    fn drop(&mut self) {
        fail::clear();
    }
}

fn start_follower() -> Server {
    Server::start(ServerConfig {
        port: 0, // ephemeral
        job_workers: 1,
        builtin_n: 40,
        cache_capacity: Some(1 << 16),
        ..Default::default()
    })
    .expect("follower starts")
}

fn post(addr: SocketAddr, path: &str, body: Json) -> (u16, Json) {
    request(addr, "POST", path, Some(&body)).expect("POST")
}

fn get(addr: SocketAddr, path: &str) -> (u16, Json) {
    request(addr, "GET", path, None).expect("GET")
}

/// The sites a coordinator-side schedule may arm. `jobs.worker` and
/// `stream.append` never fire on this path, and `panic` is excluded
/// because only the job worker contains panics — dispatch lanes are
/// expected to stay panic-free, which `error`/`corrupt`/`delay`
/// already exercise end to end.
const CHAOS_SITES: &[&str] = &["distrib.dispatch", "distrib.reply", "wire.dataset_push"];
const CHAOS_ACTIONS: &[&str] = &["error", "corrupt", "delay(40)"];

/// Randomized schedules: each round arms one or two (site, action)
/// pairs, runs a sharded discovery under a slack deadline, and demands
/// the robustness contract — termination well inside the wall-clock
/// bound, and a result that is either bit-identical to the fault-free
/// baseline (injected faults degrade to local scoring) or a typed
/// error naming the injected fault.
#[test]
fn randomized_fault_schedules_terminate_with_identical_cpdag_or_typed_error() {
    let _serial = CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _disarm = ClearOnDrop;
    fail::clear();

    let (ds, _) = generate(&SynthConfig {
        num_vars: 5,
        density: 0.5,
        n: 120,
        seed: 11,
        ..Default::default()
    });
    let ds = Arc::new(ds);
    let baseline = Discovery::builder(ds.clone()).method("cv-lr").run().expect("local baseline");

    let f1 = start_follower();
    let f2 = start_follower();
    let fleet = [f1.addr().to_string(), f2.addr().to_string()];

    let mut rng = Pcg64::new(0xc4a0_5031);
    for round in 0..8 {
        let mut spec = String::new();
        for _ in 0..(1 + rng.below(2)) {
            let site = CHAOS_SITES[rng.below(CHAOS_SITES.len())];
            let action = CHAOS_ACTIONS[rng.below(CHAOS_ACTIONS.len())];
            if !spec.is_empty() {
                spec.push(';');
            }
            spec.push_str(&format!("{site}={action}"));
        }
        fail::configure(&spec).expect("schedule parses");

        let t0 = Instant::now();
        let run = Discovery::builder(ds.clone())
            .method("cv-lr")
            .shards(fleet.clone())
            .shard_dataset("prop-chaos")
            .deadline_ms(120_000)
            .run();
        let elapsed = t0.elapsed();
        fail::clear();
        assert!(
            elapsed < Duration::from_secs(90),
            "round {round} [{spec}] blew the wall-clock bound: {elapsed:?}"
        );
        match run {
            Ok(out) => assert_eq!(
                out.cpdag, baseline.cpdag,
                "round {round} [{spec}] returned a corrupted CPDAG"
            ),
            Err(e) => assert!(
                e.downcast_ref::<DeadlineExceeded>().is_some()
                    || format!("{e:#}").contains(fail::INJECTED),
                "round {round} [{spec}] failed with an untyped error: {e:#}"
            ),
        }
    }

    f1.stop();
    f2.stop();
}

/// Persistent hard faults: with every dispatch (or every dataset push)
/// failing for the whole run, the backend must degrade to local scoring
/// and still return the exact baseline CPDAG — follower loss is a
/// wall-clock event, never a correctness event.
#[test]
fn persistent_fault_degrades_to_local_with_identical_cpdag() {
    let _serial = CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _disarm = ClearOnDrop;
    fail::clear();

    let (ds, _) = generate(&SynthConfig {
        num_vars: 4,
        density: 0.5,
        n: 100,
        seed: 3,
        ..Default::default()
    });
    let ds = Arc::new(ds);
    let baseline = Discovery::builder(ds.clone()).method("cv-lr").run().expect("local baseline");

    let f1 = start_follower();
    let f2 = start_follower();
    let fleet = [f1.addr().to_string(), f2.addr().to_string()];

    for spec in ["distrib.dispatch=error", "wire.dataset_push=error", "distrib.reply=corrupt"] {
        fail::configure(spec).expect("schedule parses");
        let out = Discovery::builder(ds.clone())
            .method("cv-lr")
            .shards(fleet.clone())
            .shard_dataset("prop-chaos-hard")
            .deadline_ms(120_000)
            .run()
            .unwrap_or_else(|e| panic!("[{spec}] must degrade to local, got: {e:#}"));
        fail::clear();
        assert_eq!(out.cpdag, baseline.cpdag, "[{spec}] corrupted the CPDAG");
    }

    f1.stop();
    f2.stop();
}

/// A straggler fleet against a tight deadline: replies delayed past the
/// whole budget must end the run quickly with either a typed
/// `DeadlineExceeded` or a (degraded-to-local) baseline-identical graph
/// — the one forbidden outcome is hanging for the full delay schedule.
#[test]
fn tight_deadline_against_stragglers_never_hangs() {
    let _serial = CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _disarm = ClearOnDrop;
    fail::clear();

    let (ds, _) = generate(&SynthConfig {
        num_vars: 4,
        density: 0.5,
        n: 100,
        seed: 3,
        ..Default::default()
    });
    let ds = Arc::new(ds);
    let baseline = Discovery::builder(ds.clone()).method("cv-lr").run().expect("local baseline");

    let f1 = start_follower();
    let f2 = start_follower();
    let fleet = [f1.addr().to_string(), f2.addr().to_string()];

    fail::configure("distrib.dispatch=delay(3000)").expect("schedule parses");
    let t0 = Instant::now();
    let run = Discovery::builder(ds.clone())
        .method("cv-lr")
        .shards(fleet)
        .shard_dataset("prop-chaos-straggler")
        .deadline_ms(400)
        .run();
    let elapsed = t0.elapsed();
    fail::clear();
    // Generous bound: far above the 400ms budget (local degrade still
    // has to score), far below what honoring every injected 3s delay
    // per dispatch would cost.
    assert!(elapsed < Duration::from_secs(60), "straggler run hung: {elapsed:?}");
    match run {
        Ok(out) => assert_eq!(out.cpdag, baseline.cpdag, "straggler run corrupted the CPDAG"),
        Err(e) => assert!(
            e.downcast_ref::<DeadlineExceeded>().is_some(),
            "expected DeadlineExceeded, got: {e:#}"
        ),
    }

    f1.stop();
    f2.stop();
}

/// The HTTP chaos surface end to end: `POST /v1/failpoints` arms and
/// clears schedules, rejects bad specs whole, and an armed
/// `jobs.worker` fault — including a panic — turns into a typed failed
/// job while the worker thread survives to run the next one.
#[test]
fn http_failpoints_control_jobs_worker_faults() {
    let _serial = CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _disarm = ClearOnDrop;
    fail::clear();

    let srv = start_follower();
    let addr = srv.addr();

    // arm via HTTP; the reply lists the armed schedule
    let (status, resp) = post(
        addr,
        "/v1/failpoints",
        Json::obj(vec![("spec", Json::str("jobs.worker=error"))]),
    );
    assert_eq!(status, 200, "{resp:?}");
    let armed = resp.get("armed").and_then(Json::as_arr).expect("armed");
    assert_eq!(armed.len(), 1, "{resp:?}");
    assert_eq!(armed[0].get("site").and_then(Json::as_str), Some("jobs.worker"));

    // a bad spec is rejected whole and changes nothing
    let (status, resp) = post(
        addr,
        "/v1/failpoints",
        Json::obj(vec![("spec", Json::str("jobs.worker=off;bogus.site=error"))]),
    );
    assert_eq!(status, 400, "{resp:?}");
    assert_eq!(fail::list().len(), 1, "failed spec must change nothing");

    // the armed fault fails the job with the injected-fault marker
    let mut csv = String::from("a,b\n");
    let mut rng = Pcg64::new(5);
    for _ in 0..60 {
        let a = rng.normal();
        csv.push_str(&format!("{a:.6},{:.6}\n", 0.8 * a + 0.5 * rng.normal()));
    }
    let (status, resp) = post(
        addr,
        "/v1/datasets",
        Json::obj(vec![("name", Json::str("chaos")), ("csv", Json::str(csv))]),
    );
    assert_eq!(status, 201, "{resp:?}");

    let submit = |addr| {
        let (status, resp) = post(
            addr,
            "/v1/jobs",
            Json::obj(vec![("dataset", Json::str("chaos")), ("method", Json::str("bic"))]),
        );
        assert_eq!(status, 202, "{resp:?}");
        resp.get("id").and_then(Json::as_u64).expect("job id")
    };
    let poll = |addr, id: u64| {
        let t0 = Instant::now();
        loop {
            let (status, job) = get(addr, &format!("/v1/jobs/{id}"));
            assert_eq!(status, 200, "{job:?}");
            let state = job.get("state").and_then(Json::as_str).expect("state").to_string();
            if state == "done" || state == "failed" || state == "cancelled" {
                return job;
            }
            assert!(t0.elapsed() < Duration::from_secs(60), "job {id} hung in `{state}`");
            std::thread::sleep(Duration::from_millis(10));
        }
    };

    let job = poll(addr, submit(addr));
    assert_eq!(job.get("state").and_then(Json::as_str), Some("failed"), "{job:?}");
    let err = job.get("error").and_then(Json::as_str).expect("error");
    assert!(err.contains(fail::INJECTED), "untyped job error: {err}");

    // a worker panic is contained: the job fails, the thread survives
    let (status, resp) = post(
        addr,
        "/v1/failpoints",
        Json::obj(vec![("spec", Json::str("jobs.worker=panic"))]),
    );
    assert_eq!(status, 200, "{resp:?}");
    let job = poll(addr, submit(addr));
    assert_eq!(job.get("state").and_then(Json::as_str), Some("failed"), "{job:?}");
    let err = job.get("error").and_then(Json::as_str).expect("error");
    assert!(err.contains("panicked"), "panic not surfaced as a typed failure: {err}");

    // clear via HTTP; the same worker thread now finishes a job
    let (status, resp) = post(addr, "/v1/failpoints", Json::obj(vec![("clear", Json::Bool(true))]));
    assert_eq!(status, 200, "{resp:?}");
    assert!(resp.get("armed").and_then(Json::as_arr).expect("armed").is_empty(), "{resp:?}");
    let job = poll(addr, submit(addr));
    assert_eq!(
        job.get("state").and_then(Json::as_str),
        Some("done"),
        "worker thread did not survive the contained panic: {job:?}"
    );

    srv.stop();
}
