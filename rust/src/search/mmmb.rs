//! Max-min parents-and-children / Markov-blanket search (Tsamardinos et
//! al. 2003) with symmetry correction — the "MM" baseline of §7.1.
//!
//! For every target T, MMPC grows a candidate parent/children set with
//! the max-min association heuristic and shrinks it with conditional
//! tests; the global skeleton keeps an edge i−j only if each endpoint is
//! in the other's set (symmetry correction). Orientation then proceeds
//! as in PC (v-structures from separating sets + Meek closure).

use std::collections::HashMap;

use crate::ci::CiTest;
use crate::graph::pdag::Pdag;

#[derive(Clone, Copy, Debug)]
pub struct MmConfig {
    /// Significance level α (paper: 0.05).
    pub alpha: f64,
    /// Cap on conditioning-subset size inside MMPC (cost control).
    pub max_cond: usize,
}

impl Default for MmConfig {
    fn default() -> Self {
        MmConfig { alpha: 0.05, max_cond: 3 }
    }
}

pub struct MmResult {
    pub cpdag: Pdag,
    pub tests_run: u64,
}

fn subsets_up_to(pool: &[usize], maxk: usize) -> Vec<Vec<usize>> {
    let mut out = vec![vec![]];
    let k = pool.len().min(12);
    for mask in 1u64..(1u64 << k) {
        if (mask.count_ones() as usize) > maxk {
            continue;
        }
        let mut s = vec![];
        for (bit, &v) in pool.iter().enumerate().take(k) {
            if mask >> bit & 1 == 1 {
                s.push(v);
            }
        }
        out.push(s);
    }
    out
}

/// MMPC for one target: returns the candidate parents/children set.
fn mmpc<T: CiTest + ?Sized>(test: &T, target: usize, cfg: &MmConfig) -> Vec<usize> {
    let d = test.num_vars();
    let mut cpc: Vec<usize> = vec![];

    // forward: max-min heuristic
    loop {
        let mut best: Option<(usize, f64)> = None; // (var, min-assoc = max p)
        for v in 0..d {
            if v == target || cpc.contains(&v) {
                continue;
            }
            // min association over subsets = max p-value
            let mut worst_p = 0.0f64;
            for s in subsets_up_to(&cpc, cfg.max_cond) {
                let p = test.pvalue(target, v, &s);
                worst_p = worst_p.max(p);
                if worst_p > cfg.alpha {
                    break; // already independent given some subset
                }
            }
            if worst_p <= cfg.alpha {
                // candidate still associated under every subset
                let assoc = 1.0 - worst_p;
                if best.map(|(_, a)| assoc > a).unwrap_or(true) {
                    best = Some((v, assoc));
                }
            }
        }
        match best {
            Some((v, _)) => cpc.push(v),
            None => break,
        }
    }

    // backward: drop members independent given a subset of the others
    let mut keep = cpc.clone();
    for &v in &cpc {
        let others: Vec<usize> = keep.iter().cloned().filter(|&o| o != v).collect();
        let mut independent = false;
        for s in subsets_up_to(&others, cfg.max_cond) {
            if test.pvalue(target, v, &s) > cfg.alpha {
                independent = true;
                break;
            }
        }
        if independent {
            keep.retain(|&o| o != v);
        }
    }
    keep
}

/// Global causal discovery by MMPC per node + symmetry correction +
/// PC-style orientation.
pub fn mmmb<T: CiTest + ?Sized>(test: &T, cfg: &MmConfig) -> MmResult {
    let d = test.num_vars();
    let sets: Vec<Vec<usize>> = (0..d).map(|t| mmpc(test, t, cfg)).collect();

    // symmetry-corrected skeleton
    let mut g = Pdag::new(d);
    for i in 0..d {
        for &j in &sets[i] {
            if j > i && sets[j].contains(&i) {
                g.add_undirected(i, j);
            }
        }
    }

    // find separating sets for nonadjacent pairs (search over subsets of
    // either endpoint's neighbors) and orient v-structures
    let mut sepsets: HashMap<(usize, usize), Vec<usize>> = HashMap::new();
    for i in 0..d {
        for j in (i + 1)..d {
            if g.adjacent(i, j) {
                continue;
            }
            'outer: for &side in &[i, j] {
                let pool: Vec<usize> =
                    g.adjacencies(side).into_iter().filter(|&v| v != i && v != j).collect();
                for s in subsets_up_to(&pool, cfg.max_cond) {
                    if test.pvalue(i, j, &s) > cfg.alpha {
                        sepsets.insert((i, j), s);
                        break 'outer;
                    }
                }
            }
        }
    }
    for i in 0..d {
        for j in (i + 1)..d {
            if g.adjacent(i, j) {
                continue;
            }
            let empty = vec![];
            let sep = sepsets.get(&(i, j)).unwrap_or(&empty);
            for k in 0..d {
                if k != i
                    && k != j
                    && g.adjacent(i, k)
                    && g.adjacent(j, k)
                    && !sep.contains(&k)
                {
                    if g.undirected(i, k) {
                        g.orient(i, k);
                    }
                    if g.undirected(j, k) {
                        g.orient(j, k);
                    }
                }
            }
        }
    }
    g.meek_closure();

    MmResult { cpdag: g, tests_run: 0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ci::Kci;
    use crate::data::Dataset;
    use crate::graph::dag::Dag;
    use crate::graph::metrics::skeleton_f1;
    use crate::linalg::Mat;
    use crate::util::Pcg64;
    use std::sync::Arc;

    #[test]
    fn recovers_chain_skeleton() {
        let mut rng = Pcg64::new(1);
        let n = 300;
        let mut data = Mat::zeros(n, 3);
        for r in 0..n {
            let x = rng.normal();
            let y = 1.4 * x + 0.3 * rng.normal();
            let z = 1.4 * y + 0.3 * rng.normal();
            data[(r, 0)] = x;
            data[(r, 1)] = y;
            data[(r, 2)] = z;
        }
        let ds = Arc::new(Dataset::from_columns(data, &[false; 3]));
        let kci = Kci::new(ds);
        let res = mmmb(&kci, &MmConfig::default());
        let truth = Dag::from_edges(3, &[(0, 1), (1, 2)]);
        assert_eq!(skeleton_f1(&res.cpdag, &truth), 1.0);
    }

    #[test]
    fn subsets_cap_respected() {
        let s = subsets_up_to(&[1, 2, 3, 4], 2);
        assert!(s.iter().all(|x| x.len() <= 2));
        assert!(s.contains(&vec![]));
    }
}
