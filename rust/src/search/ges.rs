//! Greedy equivalence search (GES, Chickering 2002) over CPDAGs with a
//! decomposable local score — the search procedure of paper §6 — driven
//! **batch-first** through [`ScoreBackend`].
//!
//! Forward phase: repeatedly apply the best valid `Insert(X, Y, T)`;
//! backward phase: repeatedly apply the best valid `Delete(X, Y, H)`.
//! Operator validity and score deltas follow Chickering's Theorems 15-17:
//!
//! * Insert valid ⟺ `NA_{Y,X} ∪ T` is a clique and every semi-directed
//!   path Y⇝X crosses `NA_{Y,X} ∪ T`;
//!   Δ = s(Y, NA∪T∪Pa(Y)∪{X}) − s(Y, NA∪T∪Pa(Y)).
//! * Delete valid ⟺ `NA_{Y,X} \ H` is a clique;
//!   Δ = s(Y, (NA\H)∪Pa(Y)\{X}) − s(Y, (NA\H)∪Pa(Y)∪{X}).
//!
//! Each sweep is a **collect-then-submit** loop: operator validity is
//! purely graphical, so all candidate (target, parent-set) pairs of a
//! sweep are gathered first and submitted to the backend as one wide
//! [`ScoreBackend::score_batch`] — hundreds of serial scalar calls per
//! step become a handful of batches the backend can deduplicate, cache
//! and fan out. Candidate order and the strictly-greater best-delta
//! rule are identical to the historical serial sweep, so the learned
//! CPDAG is unchanged (pinned by `tests/batch_equivalence.rs`).
//!
//! After each operator the PDAG is re-completed to a CPDAG via
//! Dor–Tarsi consistent extension + Chickering edge labeling.

use crate::graph::pdag::{dag_to_cpdag, Pdag};
use crate::obs::{metrics, trace};
use crate::score::{ScoreBackend, ScoreRequest};

/// GES configuration.
#[derive(Clone, Copy, Debug)]
pub struct GesConfig {
    /// Minimum score improvement to accept an operator.
    pub min_improvement: f64,
    /// Cap on the size of the T/H subsets enumerated per pair (the
    /// number of subsets is 2^|candidates|; candidates above the cap are
    /// truncated — graphs in the paper's experiments are small enough
    /// that the cap never binds at 12).
    pub max_subset_vars: usize,
    /// Optional cap on parent-set size (None = unlimited, the paper's
    /// setting).
    pub max_parents: Option<usize>,
}

impl Default for GesConfig {
    fn default() -> Self {
        GesConfig { min_improvement: 1e-9, max_subset_vars: 12, max_parents: None }
    }
}

/// Search outcome.
pub struct GesResult {
    /// The learned Markov equivalence class.
    pub cpdag: Pdag,
    /// Number of accepted forward / backward operators.
    pub forward_steps: usize,
    pub backward_steps: usize,
    /// Total local-score evaluations requested (pre-cache).
    pub score_calls: usize,
    /// Score batches submitted to the backend (one per sweep).
    pub batches: usize,
}

fn union_sorted(a: &[usize], b: &[usize]) -> Vec<usize> {
    let mut v: Vec<usize> = a.iter().chain(b.iter()).cloned().collect();
    v.sort_unstable();
    v.dedup();
    v
}

fn subsets(candidates: &[usize], cap_vars: usize) -> Vec<Vec<usize>> {
    let c: Vec<usize> = candidates.iter().cloned().take(cap_vars).collect();
    let k = c.len();
    let mut out = Vec::with_capacity(1 << k);
    for mask in 0u64..(1u64 << k) {
        let mut s = Vec::new();
        for (bit, &v) in c.iter().enumerate() {
            if mask >> bit & 1 == 1 {
                s.push(v);
            }
        }
        out.push(s);
    }
    // smaller subsets first — cheaper scores land earlier in the batch
    out.sort_by_key(|s| s.len());
    out
}

/// One candidate operator: the graphical move plus the two parent sets
/// whose score difference is its Δ.
struct Candidate {
    x: usize,
    y: usize,
    set: Vec<usize>, // T for insert, H for delete
    /// Parent set *without* x.
    base: Vec<usize>,
    /// Parent set *with* x.
    with_x: Vec<usize>,
}

/// Score a sweep's candidates in one batch and pick the first best
/// operator whose Δ clears `min_improvement` (strictly-greater best,
/// matching the serial sweep's tie-breaking). `forward` flips the Δ
/// orientation: Insert improves by s(with_x) − s(base), Delete by
/// s(base) − s(with_x).
fn best_candidate<B: ScoreBackend + ?Sized>(
    backend: &B,
    cands: &[Candidate],
    forward: bool,
    min_improvement: f64,
) -> Option<usize> {
    let mut reqs = Vec::with_capacity(2 * cands.len());
    for c in cands {
        reqs.push(ScoreRequest::new(c.y, &c.with_x));
        reqs.push(ScoreRequest::new(c.y, &c.base));
    }
    let scores = backend.score_batch(&reqs);
    let mut best: Option<(usize, f64)> = None;
    for i in 0..cands.len() {
        let (s_with, s_base) = (scores[2 * i], scores[2 * i + 1]);
        let delta = if forward { s_with - s_base } else { s_base - s_with };
        if delta > min_improvement && best.map(|(_, bd)| delta > bd).unwrap_or(true) {
            best = Some((i, delta));
        }
    }
    best.map(|(i, _)| i)
}

/// Run GES from the empty graph. The backend is typically the
/// coordinator's `ScoreService` (memoized, worker-pooled); any
/// [`ScoreBackend`] works, including `ScalarBackend`-wrapped scores.
pub fn ges<B: ScoreBackend + ?Sized>(backend: &B, cfg: &GesConfig) -> GesResult {
    ges_from(backend, cfg, None)
}

/// Run GES warm-started from `init` — the previous equivalence class of
/// a streaming session or a server re-discovery after a dataset append.
///
/// * `init = None` (or a CPDAG with the wrong variable count) is
///   exactly the historical cold run: one forward phase to convergence,
///   then one backward phase.
/// * With a warm start, the two phases **alternate until a full round
///   applies no operator** (bounded by [`MAX_WARM_ROUNDS`]): shifted
///   data may require deletes before new inserts become valid, which
///   the single forward-then-backward pass of the cold run cannot
///   express.
pub fn ges_from<B: ScoreBackend + ?Sized>(
    backend: &B,
    cfg: &GesConfig,
    init: Option<&Pdag>,
) -> GesResult {
    let d = backend.num_vars();
    let warm = matches!(init, Some(p) if p.d == d);
    let mut state = if warm { init.unwrap().clone() } else { Pdag::new(d) };
    let mut score_calls = 0usize;
    let mut batches = 0usize;
    let mut forward_steps = 0usize;
    let mut backward_steps = 0usize;

    let mut rounds = 0usize;
    loop {
        let f = forward_phase(backend, cfg, &mut state, &mut score_calls, &mut batches);
        let b = backward_phase(backend, cfg, &mut state, &mut score_calls, &mut batches);
        forward_steps += f;
        backward_steps += b;
        rounds += 1;
        if !warm || (f == 0 && b == 0) || rounds >= MAX_WARM_ROUNDS {
            break;
        }
    }

    GesResult { cpdag: state, forward_steps, backward_steps, score_calls, batches }
}

/// Cap on warm-start forward/backward rounds. For a perfectly
/// score-equivalent score each accepted operator strictly improves the
/// total and the alternation terminates on its own; approximate scores
/// (CV-LR local deltas after recompletion are not exactly
/// equivalence-consistent) could in principle oscillate between two
/// classes, so the rounds are bounded — the result at the cap is still
/// a valid CPDAG, just not a local optimum of the alternation.
const MAX_WARM_ROUNDS: usize = 8;

/// Forward phase: repeatedly apply the best valid Insert until no
/// operator clears `min_improvement`. Returns the number of operators
/// applied.
fn forward_phase<B: ScoreBackend + ?Sized>(
    backend: &B,
    cfg: &GesConfig,
    state: &mut Pdag,
    score_calls: &mut usize,
    batches: &mut usize,
) -> usize {
    let d = state.d;
    let mut steps = 0usize;
    loop {
        let sweep = trace::span("ges-forward-sweep", "search");
        let sw = crate::util::Stopwatch::start();
        // collect every valid Insert(x, y, T) of this sweep
        let mut cands: Vec<Candidate> = vec![];
        for y in 0..d {
            let pa_y = state.parents(y);
            if let Some(maxp) = cfg.max_parents {
                if pa_y.len() >= maxp {
                    continue;
                }
            }
            for x in 0..d {
                if x == y || state.adjacent(x, y) {
                    continue;
                }
                let na = state.na(y, x);
                let t0: Vec<usize> = state
                    .neighbors(y)
                    .into_iter()
                    .filter(|&n| n != x && !state.adjacent(n, x))
                    .collect();
                for t in subsets(&t0, cfg.max_subset_vars) {
                    let nat = union_sorted(&na, &t);
                    if !state.is_clique(&nat) {
                        continue;
                    }
                    if !state.all_semi_directed_paths_blocked(y, x, &nat) {
                        continue;
                    }
                    let base = union_sorted(&nat, &pa_y);
                    if let Some(maxp) = cfg.max_parents {
                        if base.len() + 1 > maxp {
                            continue;
                        }
                    }
                    let with_x = union_sorted(&base, &[x]);
                    cands.push(Candidate { x, y, set: t, base, with_x });
                }
            }
        }
        if cands.is_empty() {
            break;
        }
        let _sweep = sweep.arg("candidates", cands.len().to_string());
        // one wide batch per sweep
        *score_calls += 2 * cands.len();
        *batches += 1;
        let best = best_candidate(backend, &cands, true, cfg.min_improvement);
        let applied = if let Some(i) = best {
            // apply Insert(x, y, T)
            let c = &cands[i];
            state.add_directed(c.x, c.y);
            for &t in &c.set {
                state.orient(t, c.y);
            }
            *state = recomplete(state);
            steps += 1;
            true
        } else {
            false
        };
        metrics::ges_sweep_seconds().observe(sw.secs());
        if !applied {
            break;
        }
    }
    steps
}

/// Backward phase: repeatedly apply the best valid Delete until no
/// operator clears `min_improvement`. Returns the number of operators
/// applied.
fn backward_phase<B: ScoreBackend + ?Sized>(
    backend: &B,
    cfg: &GesConfig,
    state: &mut Pdag,
    score_calls: &mut usize,
    batches: &mut usize,
) -> usize {
    let d = state.d;
    let mut steps = 0usize;
    loop {
        let sweep = trace::span("ges-backward-sweep", "search");
        let sw = crate::util::Stopwatch::start();
        let mut cands: Vec<Candidate> = vec![];
        for y in 0..d {
            let pa_y = state.parents(y);
            for x in 0..d {
                if x == y || !(state.directed(x, y) || state.undirected(x, y)) {
                    continue;
                }
                let na = state.na(y, x);
                for h in subsets(&na, cfg.max_subset_vars) {
                    let na_minus_h: Vec<usize> =
                        na.iter().cloned().filter(|v| !h.contains(v)).collect();
                    if !state.is_clique(&na_minus_h) {
                        continue;
                    }
                    let pa_wo_x: Vec<usize> =
                        pa_y.iter().cloned().filter(|&p| p != x).collect();
                    let base = union_sorted(&na_minus_h, &pa_wo_x);
                    let with_x = union_sorted(&base, &[x]);
                    cands.push(Candidate { x, y, set: h, base, with_x });
                }
            }
        }
        if cands.is_empty() {
            break;
        }
        let _sweep = sweep.arg("candidates", cands.len().to_string());
        *score_calls += 2 * cands.len();
        *batches += 1;
        let best = best_candidate(backend, &cands, false, cfg.min_improvement);
        let applied = if let Some(i) = best {
            // apply Delete(x, y, H)
            let c = &cands[i];
            state.remove_edge(c.x, c.y);
            for &h in &c.set {
                if state.undirected(c.y, h) {
                    state.orient(c.y, h);
                }
                if state.undirected(c.x, h) {
                    state.orient(c.x, h);
                }
            }
            *state = recomplete(state);
            steps += 1;
            true
        } else {
            false
        };
        metrics::ges_sweep_seconds().observe(sw.secs());
        if !applied {
            break;
        }
    }
    steps
}

/// Re-complete a PDAG to the CPDAG of its equivalence class
/// (consistent-extension DAG → Chickering labeling). Falls back to Meek
/// closure if no consistent extension exists (should not happen for
/// valid operators).
fn recomplete(p: &Pdag) -> Pdag {
    match p.to_dag() {
        Some(dag) => dag_to_cpdag(&dag),
        None => {
            let mut q = p.clone();
            q.meek_closure();
            q
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::graph::dag::Dag;
    use crate::graph::metrics::{normalized_shd, skeleton_f1};
    use crate::linalg::Mat;
    use crate::score::bdeu::BdeuScore;
    use crate::score::bic::BicScore;
    use crate::score::ScalarBackend;
    use crate::util::Pcg64;
    use std::sync::Arc;

    fn linear_chain_ds(n: usize, seed: u64) -> Arc<Dataset> {
        // X1 → X2 → X3, plus isolated X4
        let mut rng = Pcg64::new(seed);
        let mut data = Mat::zeros(n, 4);
        for r in 0..n {
            let x1 = rng.normal();
            let x2 = 1.2 * x1 + 0.4 * rng.normal();
            let x3 = -0.9 * x2 + 0.4 * rng.normal();
            let x4 = rng.normal();
            data[(r, 0)] = x1;
            data[(r, 1)] = x2;
            data[(r, 2)] = x3;
            data[(r, 3)] = x4;
        }
        Arc::new(Dataset::from_columns(data, &[false; 4]))
    }

    #[test]
    fn recovers_linear_chain_with_bic() {
        let ds = linear_chain_ds(800, 1);
        let score = ScalarBackend(BicScore::new(ds));
        let res = ges(&score, &GesConfig::default());
        let truth = Dag::from_edges(4, &[(0, 1), (1, 2)]);
        assert_eq!(skeleton_f1(&res.cpdag, &truth), 1.0, "skeleton must be exact");
        assert_eq!(normalized_shd(&res.cpdag, &truth), 0.0, "equivalence class must match");
        assert!(res.forward_steps >= 2);
        assert!(res.batches >= res.forward_steps, "one batch per sweep");
    }

    #[test]
    fn recovers_collider_with_bic() {
        // X1 → X3 ← X2 — compelled v-structure.
        let mut rng = Pcg64::new(2);
        let n = 800;
        let mut data = Mat::zeros(n, 3);
        for r in 0..n {
            let x1 = rng.normal();
            let x2 = rng.normal();
            let x3 = x1 + x2 + 0.4 * rng.normal();
            data[(r, 0)] = x1;
            data[(r, 1)] = x2;
            data[(r, 2)] = x3;
        }
        let ds = Arc::new(Dataset::from_columns(data, &[false; 3]));
        let score = ScalarBackend(BicScore::new(ds));
        let res = ges(&score, &GesConfig::default());
        assert!(res.cpdag.directed(0, 2), "v-structure arm 0→2");
        assert!(res.cpdag.directed(1, 2), "v-structure arm 1→2");
        assert!(!res.cpdag.adjacent(0, 1));
    }

    #[test]
    fn recovers_discrete_chain_with_bdeu() {
        let mut rng = Pcg64::new(3);
        let n = 1500;
        let mut data = Mat::zeros(n, 3);
        for r in 0..n {
            let a = rng.below(3);
            let b = if rng.bernoulli(0.85) { a } else { rng.below(3) };
            let c = if rng.bernoulli(0.85) { b } else { rng.below(3) };
            data[(r, 0)] = a as f64;
            data[(r, 1)] = b as f64;
            data[(r, 2)] = c as f64;
        }
        let ds = Arc::new(Dataset::from_columns(data, &[true; 3]));
        let score = ScalarBackend(BdeuScore::new(ds));
        let res = ges(&score, &GesConfig::default());
        let truth = Dag::from_edges(3, &[(0, 1), (1, 2)]);
        assert_eq!(skeleton_f1(&res.cpdag, &truth), 1.0);
    }

    #[test]
    fn empty_data_gives_empty_graph() {
        // independent variables: GES must return the empty CPDAG
        let mut rng = Pcg64::new(4);
        let n = 500;
        let mut data = Mat::zeros(n, 3);
        for v in &mut data.data {
            *v = rng.normal();
        }
        let ds = Arc::new(Dataset::from_columns(data, &[false; 3]));
        let score = ScalarBackend(BicScore::new(ds));
        let res = ges(&score, &GesConfig::default());
        assert_eq!(res.cpdag.num_edges(), 0);
    }

    #[test]
    fn output_is_valid_cpdag() {
        let ds = linear_chain_ds(400, 5);
        let score = ScalarBackend(BicScore::new(ds));
        let res = ges(&score, &GesConfig::default());
        // a valid CPDAG has a consistent extension whose CPDAG is itself
        let dag = res.cpdag.to_dag().expect("CPDAG must extend to a DAG");
        assert_eq!(dag_to_cpdag(&dag), res.cpdag);
    }

    #[test]
    fn warm_start_from_own_result_is_a_fixed_point() {
        let ds = linear_chain_ds(800, 1);
        let score = ScalarBackend(BicScore::new(ds));
        let cold = ges(&score, &GesConfig::default());
        let warm = ges_from(&score, &GesConfig::default(), Some(&cold.cpdag));
        assert_eq!(warm.cpdag, cold.cpdag, "re-running from the optimum must not move");
        assert_eq!(warm.forward_steps, 0);
        assert_eq!(warm.backward_steps, 0);
        assert!(
            warm.score_calls < cold.score_calls,
            "a warm fixed-point run sweeps less than the cold search \
             ({} vs {})",
            warm.score_calls,
            cold.score_calls
        );
    }

    #[test]
    fn warm_start_with_wrong_dimension_falls_back_to_cold() {
        let ds = linear_chain_ds(600, 7);
        let score = ScalarBackend(BicScore::new(ds));
        let cold = ges(&score, &GesConfig::default());
        let stale = Pdag::new(9); // wrong variable count
        let warm = ges_from(&score, &GesConfig::default(), Some(&stale));
        assert_eq!(warm.cpdag, cold.cpdag);
        assert_eq!(warm.forward_steps, cold.forward_steps);
    }

    #[test]
    fn warm_start_repairs_a_stale_edge() {
        // start from a graph wrongly claiming X4 depends on X1: the
        // warm run must delete it and still find the chain
        let ds = linear_chain_ds(800, 3);
        let score = ScalarBackend(BicScore::new(ds));
        let cold = ges(&score, &GesConfig::default());
        let mut stale = cold.cpdag.clone();
        stale.add_directed(0, 3);
        let warm = ges_from(&score, &GesConfig::default(), Some(&stale));
        assert_eq!(warm.cpdag, cold.cpdag, "warm start must repair the spurious edge");
        assert!(warm.backward_steps >= 1, "the spurious edge is removed by a Delete");
    }

    #[test]
    fn subsets_enumeration() {
        let s = subsets(&[1, 2], 12);
        assert_eq!(s.len(), 4);
        assert!(s.contains(&vec![]));
        assert!(s.contains(&vec![1, 2]));
        // cap respected
        let s = subsets(&[1, 2, 3, 4], 2);
        assert_eq!(s.len(), 4);
    }
}
