//! The PC algorithm (Spirtes et al.) with the *stable* skeleton phase,
//! v-structure orientation from separating sets, and Meek closure —
//! the constraint-based baseline "PC" of §7.1 (paired with KCI).

use std::collections::HashMap;

use crate::ci::CiTest;
use crate::graph::pdag::Pdag;

/// PC configuration.
#[derive(Clone, Copy, Debug)]
pub struct PcConfig {
    /// Significance level α (paper: 0.05).
    pub alpha: f64,
    /// Cap on conditioning-set size (None = up to adjacency size).
    pub max_cond: Option<usize>,
}

impl Default for PcConfig {
    fn default() -> Self {
        PcConfig { alpha: 0.05, max_cond: None }
    }
}

/// PC result: the CPDAG plus the separating sets found.
pub struct PcResult {
    pub cpdag: Pdag,
    pub sepsets: HashMap<(usize, usize), Vec<usize>>,
    pub tests_run: u64,
}

fn combinations(pool: &[usize], k: usize) -> Vec<Vec<usize>> {
    if k == 0 {
        return vec![vec![]];
    }
    if pool.len() < k {
        return vec![];
    }
    let mut out = vec![];
    let mut idx: Vec<usize> = (0..k).collect();
    loop {
        out.push(idx.iter().map(|&i| pool[i]).collect());
        // next combination
        let mut i = k;
        loop {
            if i == 0 {
                return out;
            }
            i -= 1;
            if idx[i] != i + pool.len() - k {
                break;
            }
            if i == 0 {
                return out;
            }
        }
        idx[i] += 1;
        for j in (i + 1)..k {
            idx[j] = idx[j - 1] + 1;
        }
    }
}

/// Run PC-stable.
pub fn pc<T: CiTest + ?Sized>(test: &T, cfg: &PcConfig) -> PcResult {
    let d = test.num_vars();
    // adjacency matrix of the working skeleton (complete graph start)
    let mut adj = vec![true; d * d];
    for i in 0..d {
        adj[i * d + i] = false;
    }
    let mut sepsets: HashMap<(usize, usize), Vec<usize>> = HashMap::new();
    let mut tests_run = 0u64;

    let mut level = 0usize;
    loop {
        if let Some(mc) = cfg.max_cond {
            if level > mc {
                break;
            }
        }
        // PC-stable: snapshot adjacencies at the start of the level
        let snapshot = adj.clone();
        let neighbors = |a: &Vec<bool>, i: usize| -> Vec<usize> {
            (0..d).filter(|&j| a[i * d + j]).collect()
        };
        let mut any_candidate = false;
        let mut removals: Vec<(usize, usize, Vec<usize>)> = vec![];
        for i in 0..d {
            for j in (i + 1)..d {
                if !adj[i * d + j] {
                    continue;
                }
                // subsets from both sides (standard PC)
                let mut found = false;
                for &(from, other) in &[(i, j), (j, i)] {
                    let mut pool = neighbors(&snapshot, from);
                    pool.retain(|&v| v != other);
                    if pool.len() >= level {
                        any_candidate = true;
                    }
                    for s in combinations(&pool, level) {
                        tests_run += 1;
                        if test.pvalue(i, j, &s) > cfg.alpha {
                            removals.push((i, j, s));
                            found = true;
                            break;
                        }
                    }
                    if found {
                        break;
                    }
                }
            }
        }
        for (i, j, s) in removals {
            adj[i * d + j] = false;
            adj[j * d + i] = false;
            sepsets.insert((i, j), s.clone());
            sepsets.insert((j, i), s);
        }
        if !any_candidate {
            break;
        }
        level += 1;
    }

    // orientation: v-structures i→k←j for nonadjacent i,j with common
    // neighbor k ∉ sepset(i,j)
    let mut g = Pdag::new(d);
    for i in 0..d {
        for j in (i + 1)..d {
            if adj[i * d + j] {
                g.add_undirected(i, j);
            }
        }
    }
    for i in 0..d {
        for j in (i + 1)..d {
            if adj[i * d + j] {
                continue;
            }
            let empty = vec![];
            let sep = sepsets.get(&(i, j)).unwrap_or(&empty);
            for k in 0..d {
                if k != i && k != j && adj[i * d + k] && adj[j * d + k] && !sep.contains(&k) {
                    // orient i→k and j→k (only if still undirected —
                    // conflicting v-structures keep the first orientation)
                    if g.undirected(i, k) {
                        g.orient(i, k);
                    }
                    if g.undirected(j, k) {
                        g.orient(j, k);
                    }
                }
            }
        }
    }
    g.meek_closure();

    PcResult { cpdag: g, sepsets, tests_run }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ci::Kci;
    use crate::data::Dataset;
    use crate::graph::dag::Dag;
    use crate::graph::metrics::skeleton_f1;
    use crate::linalg::Mat;
    use crate::util::Pcg64;
    use std::sync::Arc;

    #[test]
    fn combinations_enumerate() {
        let c = combinations(&[1, 2, 3], 2);
        assert_eq!(c, vec![vec![1, 2], vec![1, 3], vec![2, 3]]);
        assert_eq!(combinations(&[1, 2], 0), vec![Vec::<usize>::new()]);
        assert!(combinations(&[1], 2).is_empty());
    }

    #[test]
    fn recovers_collider_with_kci() {
        let mut rng = Pcg64::new(1);
        let n = 250;
        let mut data = Mat::zeros(n, 3);
        for r in 0..n {
            let x = rng.normal();
            let y = rng.normal();
            let z = (x + y).tanh() + 0.2 * rng.normal();
            data[(r, 0)] = x;
            data[(r, 1)] = y;
            data[(r, 2)] = z;
        }
        let ds = Arc::new(Dataset::from_columns(data, &[false; 3]));
        let kci = Kci::new(ds);
        let res = pc(&kci, &PcConfig::default());
        let truth = Dag::from_edges(3, &[(0, 2), (1, 2)]);
        assert_eq!(skeleton_f1(&res.cpdag, &truth), 1.0, "skeleton exact");
        assert!(res.cpdag.directed(0, 2) && res.cpdag.directed(1, 2), "collider oriented");
    }

    #[test]
    fn removes_mediated_edge() {
        let mut rng = Pcg64::new(2);
        let n = 300;
        let mut data = Mat::zeros(n, 3);
        for r in 0..n {
            let x = rng.normal();
            let y = 1.3 * x + 0.3 * rng.normal();
            let z = 1.3 * y + 0.3 * rng.normal();
            data[(r, 0)] = x;
            data[(r, 1)] = y;
            data[(r, 2)] = z;
        }
        let ds = Arc::new(Dataset::from_columns(data, &[false; 3]));
        let kci = Kci::new(ds);
        let res = pc(&kci, &PcConfig::default());
        assert!(!res.cpdag.adjacent(0, 2), "X−Z edge must be removed given Y");
        assert!(res.cpdag.adjacent(0, 1) && res.cpdag.adjacent(1, 2));
    }
}
