//! Causal structure search algorithms.
//!
//! * [`ges`] — greedy equivalence search (Chickering 2002), the search
//!   procedure the paper pairs with the CV-LR score (§6). Batch-first:
//!   each sweep's candidates are scored through one
//!   `ScoreBackend::score_batch` submission;
//! * [`pc`] — the PC algorithm (constraint-based baseline, §7.1);
//! * [`mmmb`] — max-min Markov-blanket search with symmetry correction
//!   (constraint-based baseline, §7.1).

pub mod ges;
pub mod pc;
pub mod mmmb;

pub use ges::{ges, GesConfig, GesResult};
