//! Causal structure search algorithms.
//!
//! * [`ges`] — greedy equivalence search (Chickering 2002), the search
//!   procedure the paper pairs with the CV-LR score (§6). Batch-first:
//!   each sweep's candidates are scored through one
//!   `ScoreBackend::score_batch` submission;
//! * [`pc`] — the PC algorithm (constraint-based baseline, §7.1);
//! * [`mmmb`] — max-min Markov-blanket search with symmetry correction
//!   (constraint-based baseline, §7.1).
//!
//! Score-based searches are pluggable through [`SearchMethod`], whose
//! [`SearchMethod::run_from`] hook is the **warm-start** entry point:
//! streaming sessions and server re-discoveries start at the previous
//! equivalence class instead of the empty graph.

pub mod ges;
pub mod pc;
pub mod mmmb;

use crate::graph::Pdag;
use crate::score::ScoreBackend;

pub use ges::{ges, ges_from, GesConfig, GesResult};

/// A pluggable score-based structure search.
pub trait SearchMethod: Send + Sync {
    /// Cold run from the empty graph.
    fn run(&self, backend: &dyn ScoreBackend, cfg: &GesConfig) -> GesResult {
        self.run_from(backend, cfg, None)
    }

    /// Run warm-started from `init` when given (implementations fall
    /// back to a cold run when `init` is absent or its variable count
    /// does not match the backend).
    fn run_from(
        &self,
        backend: &dyn ScoreBackend,
        cfg: &GesConfig,
        init: Option<&Pdag>,
    ) -> GesResult;
}

/// Batched GES as a [`SearchMethod`].
pub struct GesSearch;

impl SearchMethod for GesSearch {
    fn run_from(
        &self,
        backend: &dyn ScoreBackend,
        cfg: &GesConfig,
        init: Option<&Pdag>,
    ) -> GesResult {
        ges_from(backend, cfg, init)
    }
}
