//! Datasets and data generation.
//!
//! * [`dataset`] — the `Dataset` type: an n×D sample matrix partitioned
//!   into variables (column blocks; multi-dimensional variables per paper
//!   §7.4 have width > 1), each continuous or discrete.
//! * [`synth`] — the post-nonlinear functional causal model generator of
//!   Appendix A.1 (continuous / mixed / multi-dimensional).
//! * [`networks`] — the SACHS and CHILD benchmark networks with
//!   random-CPT forward sampling, plus a continuous-SACHS SEM
//!   (substitutions documented in DESIGN.md §7).

pub mod dataset;
pub mod synth;
pub mod networks;

pub use dataset::{Dataset, Variable};
pub use networks::{child, forward_sample, sachs, sachs_continuous, DiscreteNetwork};
pub use synth::{generate, random_dag, DataKind, SynthConfig};

