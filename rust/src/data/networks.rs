//! The SACHS and CHILD benchmark networks (§7.5) and samplers.
//!
//! Substitution (DESIGN.md §7): the paper samples the bnlearn datasets;
//! offline we hard-code the published network *structures* and
//! cardinalities and draw the CPTs from a Dirichlet prior with a fixed
//! seed, sharpened towards deterministic rows so that the conditional
//! dependencies are strong (as in the real networks). The continuous
//! SACHS variant (App. B.3) is simulated as a nonlinear SEM over the
//! same DAG with n = 853.

use super::dataset::{Dataset, Variable};
use crate::graph::dag::Dag;
use crate::linalg::Mat;
use crate::util::Pcg64;

/// A discrete Bayesian network: structure + per-variable cardinalities.
pub struct DiscreteNetwork {
    pub name: &'static str,
    pub dag: Dag,
    pub cards: Vec<usize>,
    pub var_names: Vec<&'static str>,
}

/// The SACHS protein-signalling network: 11 variables, 17 edges.
pub fn sachs() -> DiscreteNetwork {
    // 0 Raf, 1 Mek, 2 Plcg, 3 PIP2, 4 PIP3, 5 Erk, 6 Akt, 7 PKA, 8 PKC,
    // 9 P38, 10 Jnk  (bnlearn's consensus structure)
    let names = ["Raf", "Mek", "Plcg", "PIP2", "PIP3", "Erk", "Akt", "PKA", "PKC", "P38", "Jnk"];
    let edges = [
        (8, 0),  // PKC → Raf
        (8, 1),  // PKC → Mek
        (8, 10), // PKC → Jnk
        (8, 9),  // PKC → P38
        (8, 7),  // PKC → PKA
        (7, 0),  // PKA → Raf
        (7, 1),  // PKA → Mek
        (7, 5),  // PKA → Erk
        (7, 6),  // PKA → Akt
        (7, 10), // PKA → Jnk
        (7, 9),  // PKA → P38
        (0, 1),  // Raf → Mek
        (1, 5),  // Mek → Erk
        (5, 6),  // Erk → Akt
        (2, 3),  // Plcg → PIP2
        (2, 4),  // Plcg → PIP3
        (4, 3),  // PIP3 → PIP2
    ];
    let dag = Dag::from_edges(11, &edges);
    assert_eq!(dag.num_edges(), 17);
    DiscreteNetwork { name: "SACHS", dag, cards: vec![3; 11], var_names: names.to_vec() }
}

/// The CHILD network: 20 variables, 25 edges.
pub fn child() -> DiscreteNetwork {
    // bnlearn CHILD structure + cardinalities
    let names = [
        "BirthAsphyxia", // 0 (2)
        "Disease",       // 1 (6)
        "Age",           // 2 (3)
        "LVH",           // 3 (2)
        "DuctFlow",      // 4 (3)
        "CardiacMixing", // 5 (4)
        "LungParench",   // 6 (3)
        "LungFlow",      // 7 (3)
        "Sick",          // 8 (2)
        "LVHreport",     // 9 (2)
        "Grunting",      // 10 (2)
        "HypDistrib",    // 11 (2)
        "HypoxiaInO2",   // 12 (3)
        "CO2",           // 13 (3)
        "ChestXray",     // 14 (5)
        "GruntingReport",// 15 (2)
        "LowerBodyO2",   // 16 (3)
        "RUQO2",         // 17 (3)
        "CO2Report",     // 18 (2)
        "XrayReport",    // 19 (5)
    ];
    let cards = vec![2, 6, 3, 2, 3, 4, 3, 3, 2, 2, 2, 2, 3, 3, 5, 2, 3, 3, 2, 5];
    let edges = [
        (0, 1),   // BirthAsphyxia → Disease
        (1, 2),   // Disease → Age
        (1, 3),   // Disease → LVH
        (1, 4),   // Disease → DuctFlow
        (1, 5),   // Disease → CardiacMixing
        (1, 6),   // Disease → LungParench
        (1, 7),   // Disease → LungFlow
        (1, 8),   // Disease → Sick
        (3, 9),   // LVH → LVHreport
        (4, 11),  // DuctFlow → HypDistrib
        (5, 11),  // CardiacMixing → HypDistrib
        (5, 12),  // CardiacMixing → HypoxiaInO2
        (6, 12),  // LungParench → HypoxiaInO2
        (6, 13),  // LungParench → CO2
        (6, 14),  // LungParench → ChestXray
        (6, 10),  // LungParench → Grunting
        (7, 14),  // LungFlow → ChestXray
        (8, 10),  // Sick → Grunting
        (8, 2),   // Sick → Age
        (10, 15), // Grunting → GruntingReport
        (11, 16), // HypDistrib → LowerBodyO2
        (12, 16), // HypoxiaInO2 → LowerBodyO2
        (12, 17), // HypoxiaInO2 → RUQO2
        (13, 18), // CO2 → CO2Report
        (14, 19), // ChestXray → XrayReport
    ];
    let dag = Dag::from_edges(20, &edges);
    assert_eq!(dag.num_edges(), 25);
    DiscreteNetwork { name: "CHILD", dag, cards, var_names: names.to_vec() }
}

/// Random CPTs from a sharpened Dirichlet prior (one strongly-preferred
/// outcome per parent configuration — mimicking the near-deterministic
/// rows of the real networks) and forward sampling in topological order.
pub fn forward_sample(net: &DiscreteNetwork, n: usize, seed: u64) -> Dataset {
    let d = net.dag.d;
    let mut rng = Pcg64::new(seed ^ 0xBEEF);
    let topo = net.dag.topological_order().unwrap();

    // CPTs: per variable, a table of parent-config → distribution
    let mut cpts: Vec<Vec<Vec<f64>>> = Vec::with_capacity(d);
    for v in 0..d {
        let parents = net.dag.parents(v);
        let q: usize = parents.iter().map(|&p| net.cards[p]).product::<usize>().max(1);
        let mut table = Vec::with_capacity(q);
        for _ in 0..q {
            // Dirichlet(0.5) + sharpening: boost one random outcome
            let mut probs = rng.dirichlet(net.cards[v], 0.5);
            let fav = rng.below(net.cards[v]);
            probs[fav] += 1.5;
            let s: f64 = probs.iter().sum();
            for p in &mut probs {
                *p /= s;
            }
            table.push(probs);
        }
        cpts.push(table);
    }

    let mut data = Mat::zeros(n, d);
    for r in 0..n {
        for &v in &topo {
            let parents = net.dag.parents(v);
            let mut cfg_idx = 0usize;
            for &p in &parents {
                cfg_idx = cfg_idx * net.cards[p] + data[(r, p)] as usize;
            }
            let level = rng.categorical(&cpts[v][cfg_idx]);
            data[(r, v)] = level as f64;
        }
    }

    let vars = (0..d)
        .map(|i| Variable {
            name: net.var_names[i].to_string(),
            col_start: i,
            dim: 1,
            discrete: true,
            cardinality: net.cards[i],
        })
        .collect();
    Dataset::new(data, vars)
}

/// Continuous SACHS substitute (App. B.3): nonlinear SEM over the SACHS
/// DAG, n samples (the paper's dataset has n = 853).
pub fn sachs_continuous(n: usize, seed: u64) -> (Dataset, Dag) {
    let net = sachs();
    let mut rng = Pcg64::new(seed ^ 0xCAFE);
    let topo = net.dag.topological_order().unwrap();
    let d = net.dag.d;
    // per-edge weights and per-node mechanism
    let mut w = vec![0.0; d * d];
    for (i, j) in net.dag.edges() {
        w[i * d + j] = rng.uniform_in(0.7, 1.3) * if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
    }
    let mech: Vec<usize> = (0..d).map(|_| rng.below(3)).collect();
    let mut data = Mat::zeros(n, d);
    for r in 0..n {
        for &v in &topo {
            let parents = net.dag.parents(v);
            let val = if parents.is_empty() {
                rng.normal()
            } else {
                let s: f64 = parents.iter().map(|&p| w[p * d + v] * data[(r, p)]).sum();
                let f = match mech[v] {
                    0 => s.tanh(),
                    1 => s.sin(),
                    _ => s,
                };
                f + 0.3 * rng.normal()
            };
            data[(r, v)] = val;
        }
    }
    let mut ds = Dataset::from_columns(data, &vec![false; d]);
    ds.standardize();
    (ds, net.dag)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sachs_structure() {
        let net = sachs();
        assert_eq!(net.dag.d, 11);
        assert_eq!(net.dag.num_edges(), 17);
        assert!(net.dag.topological_order().is_some());
    }

    #[test]
    fn child_structure() {
        let net = child();
        assert_eq!(net.dag.d, 20);
        assert_eq!(net.dag.num_edges(), 25);
        assert_eq!(net.cards.len(), 20);
        assert!(net.dag.topological_order().is_some());
        assert!(net.cards.iter().all(|&c| (2..=6).contains(&c)));
    }

    #[test]
    fn forward_sampling_respects_cardinalities() {
        let net = child();
        let ds = forward_sample(&net, 300, 1);
        assert_eq!(ds.n(), 300);
        assert_eq!(ds.d(), 20);
        for (i, v) in ds.vars.iter().enumerate() {
            assert!(v.discrete);
            for r in 0..ds.n() {
                let lvl = ds.level(i, r);
                assert!(lvl < net.cards[i], "level {lvl} out of range for var {i}");
            }
        }
    }

    #[test]
    fn forward_sampling_creates_dependence() {
        // child of an edge should be statistically dependent on parent
        let net = sachs();
        let ds = forward_sample(&net, 2000, 2);
        // PKC → PKA edge (8 → 7): mutual information proxy via Spearman on codes
        let a: Vec<f64> = (0..ds.n()).map(|r| ds.data[(r, 8)]).collect();
        let b: Vec<f64> = (0..ds.n()).map(|r| ds.data[(r, 7)]).collect();
        // chi-square style: compare joint vs product on a coarse table
        let mut joint = [[0f64; 3]; 3];
        for r in 0..ds.n() {
            joint[a[r] as usize][b[r] as usize] += 1.0;
        }
        let n = ds.n() as f64;
        let mut chi2 = 0.0;
        for i in 0..3 {
            for j in 0..3 {
                let pi: f64 = joint[i].iter().sum::<f64>() / n;
                let pj: f64 = (0..3).map(|k| joint[k][j]).sum::<f64>() / n;
                let e = pi * pj * n;
                if e > 0.0 {
                    chi2 += (joint[i][j] - e).powi(2) / e;
                }
            }
        }
        assert!(chi2 > 20.0, "PKC→PKA dependence too weak: chi2={chi2}");
    }

    #[test]
    fn continuous_sachs_shape() {
        let (ds, dag) = sachs_continuous(853, 1);
        assert_eq!(ds.n(), 853);
        assert_eq!(ds.d(), 11);
        assert_eq!(dag.num_edges(), 17);
        assert!(ds.data.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn sampling_deterministic() {
        let net = sachs();
        let a = forward_sample(&net, 50, 9);
        let b = forward_sample(&net, 50, 9);
        assert_eq!(a.data.data, b.data.data);
    }
}
