//! Synthetic data generation — the post-nonlinear functional causal
//! model of paper Appendix A.1:
//!
//! ```text
//!   X_i = g_i( f_i(Pa_i) + ε_i )
//! ```
//!
//! * `f_i` uniformly from {linear (w ∈ [0,1.5]), sin, cos, tanh, log};
//! * `g_i` uniformly from {linear (w ∈ [1,2]), exp, x^α (α ∈ {1,2,3})};
//! * `ε_i` from U(−0.25, 0.25) or N(0, 0.5) with equal probability;
//! * roots from N(0,1) or U(−0.5, 0.5) with equal probability.
//!
//! Three data kinds (§7.4): continuous; mixed (50% of variables
//! equal-frequency discretized to 5 levels); multi-dimensional (each
//! variable gets a random dimension in 1..=5; parents are mapped to the
//! child's dimension by an all-ones matrix).

use super::dataset::{Dataset, Variable};
use crate::graph::dag::Dag;
use crate::linalg::Mat;
use crate::util::Pcg64;

/// The three synthetic data kinds of §7.4.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DataKind {
    Continuous,
    Mixed,
    MultiDim,
}

/// Generator configuration (paper defaults).
#[derive(Clone, Copy, Debug)]
pub struct SynthConfig {
    pub num_vars: usize,
    /// Edge density: |E| / (d(d−1)/2), paper range 0.2–0.8.
    pub density: f64,
    pub n: usize,
    pub kind: DataKind,
    pub seed: u64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig { num_vars: 7, density: 0.4, n: 500, kind: DataKind::Continuous, seed: 0 }
    }
}

/// A random DAG with the requested density: random topological order,
/// then a uniform sample of the forward pairs.
pub fn random_dag(d: usize, density: f64, rng: &mut Pcg64) -> Dag {
    let max_edges = d * (d - 1) / 2;
    let target = ((density * max_edges as f64).round() as usize).min(max_edges);
    let mut order: Vec<usize> = (0..d).collect();
    rng.shuffle(&mut order);
    let mut pairs: Vec<(usize, usize)> = vec![];
    for a in 0..d {
        for b in (a + 1)..d {
            pairs.push((order[a], order[b]));
        }
    }
    rng.shuffle(&mut pairs);
    Dag::from_edges(d, &pairs[..target])
}

#[derive(Clone, Copy)]
enum Mech {
    Linear(f64),
    Sin,
    Cos,
    Tanh,
    Log,
}

impl Mech {
    fn sample(rng: &mut Pcg64) -> Mech {
        match rng.below(5) {
            0 => Mech::Linear(rng.uniform_in(0.0, 1.5)),
            1 => Mech::Sin,
            2 => Mech::Cos,
            3 => Mech::Tanh,
            _ => Mech::Log,
        }
    }

    fn apply(&self, s: f64) -> f64 {
        match *self {
            Mech::Linear(w) => w * s,
            Mech::Sin => s.sin(),
            Mech::Cos => s.cos(),
            Mech::Tanh => s.tanh(),
            Mech::Log => (s.abs() + 1.0).ln() * s.signum(),
        }
    }
}

#[derive(Clone, Copy)]
enum PostNl {
    Linear(f64),
    Exp,
    Power(i32),
}

impl PostNl {
    fn sample(rng: &mut Pcg64) -> PostNl {
        match rng.below(3) {
            0 => PostNl::Linear(rng.uniform_in(1.0, 2.0)),
            1 => PostNl::Exp,
            _ => PostNl::Power(1 + rng.below(3) as i32),
        }
    }

    fn apply(&self, s: f64) -> f64 {
        match *self {
            PostNl::Linear(w) => w * s,
            // clamp the exponent so exp never overflows for deep graphs
            PostNl::Exp => s.clamp(-6.0, 6.0).exp(),
            PostNl::Power(a) => s.signum() * s.abs().powi(a),
        }
    }
}

fn sample_noise(rng: &mut Pcg64) -> (bool, f64) {
    (rng.bernoulli(0.5), 0.0) // (is_uniform, unused)
}

/// Generate a dataset + its ground-truth DAG.
pub fn generate(cfg: &SynthConfig) -> (Dataset, Dag) {
    let mut rng = Pcg64::new(cfg.seed);
    let d = cfg.num_vars;
    let dag = random_dag(d, cfg.density, &mut rng);
    let topo = dag.topological_order().unwrap();

    // dimensions per variable
    let dims: Vec<usize> = match cfg.kind {
        DataKind::MultiDim => (0..d).map(|_| 1 + rng.below(5)).collect(),
        _ => vec![1; d],
    };
    let col_start: Vec<usize> = {
        let mut cs = vec![0usize; d];
        let mut acc = 0;
        for i in 0..d {
            cs[i] = acc;
            acc += dims[i];
        }
        cs
    };
    let total_cols: usize = dims.iter().sum();
    let mut data = Mat::zeros(cfg.n, total_cols);

    // per-variable mechanisms (fixed across samples)
    let mechs: Vec<Mech> = (0..d).map(|_| Mech::sample(&mut rng)).collect();
    let posts: Vec<PostNl> = (0..d).map(|_| PostNl::sample(&mut rng)).collect();
    let noise_uniform: Vec<bool> = (0..d).map(|_| sample_noise(&mut rng).0).collect();
    let root_uniform: Vec<bool> = (0..d).map(|_| rng.bernoulli(0.5)).collect();

    for r in 0..cfg.n {
        for &v in &topo {
            let parents = dag.parents(v);
            for k in 0..dims[v] {
                let val = if parents.is_empty() {
                    if root_uniform[v] {
                        rng.uniform_in(-0.5, 0.5)
                    } else {
                        rng.normal()
                    }
                } else {
                    // all-ones mapping: sum over every dim of every parent
                    let mut s = 0.0;
                    for &p in &parents {
                        for kk in 0..dims[p] {
                            s += data[(r, col_start[p] + kk)];
                        }
                    }
                    let eps = if noise_uniform[v] {
                        rng.uniform_in(-0.25, 0.25)
                    } else {
                        rng.normal_with(0.0, 0.5)
                    };
                    posts[v].apply(mechs[v].apply(s) + eps)
                };
                data[(r, col_start[v] + k)] = val;
            }
        }
    }

    // assemble variables; mixed kind discretizes half the variables
    let discretize: Vec<bool> = match cfg.kind {
        DataKind::Mixed => (0..d).map(|_| rng.bernoulli(0.5)).collect(),
        _ => vec![false; d],
    };
    let mut vars = Vec::with_capacity(d);
    for i in 0..d {
        let mut card = 0;
        if discretize[i] {
            card = 5;
            for k in 0..dims[i] {
                equal_frequency_discretize(&mut data, col_start[i] + k, 5);
            }
        }
        vars.push(Variable {
            name: format!("X{}", i + 1),
            col_start: col_start[i],
            dim: dims[i],
            discrete: discretize[i],
            cardinality: card,
        });
    }
    let mut ds = Dataset::new(data, vars);
    ds.standardize();
    (ds, dag)
}

/// Equal-frequency discretization of one column into `levels` values
/// 0..levels-1 (paper: values 1..5 — the shift is irrelevant to kernels
/// and counts).
fn equal_frequency_discretize(data: &mut Mat, col: usize, levels: usize) {
    let n = data.rows;
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| data[(a, col)].partial_cmp(&data[(b, col)]).unwrap());
    for (rank, &r) in idx.iter().enumerate() {
        data[(r, col)] = ((rank * levels) / n).min(levels - 1) as f64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn density_controls_edge_count() {
        let mut rng = Pcg64::new(1);
        for &dens in &[0.2, 0.5, 0.8] {
            let g = random_dag(7, dens, &mut rng);
            let expect = (dens * 21.0).round() as usize;
            assert_eq!(g.num_edges(), expect);
            assert!(g.topological_order().is_some());
        }
    }

    #[test]
    fn continuous_generation_shape() {
        let (ds, dag) = generate(&SynthConfig { n: 100, seed: 3, ..Default::default() });
        assert_eq!(ds.n(), 100);
        assert_eq!(ds.d(), 7);
        assert_eq!(dag.d, 7);
        assert!(ds.vars.iter().all(|v| !v.discrete && v.dim == 1));
        assert!(ds.data.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn mixed_generation_has_discrete_vars() {
        let (ds, _) = generate(&SynthConfig {
            kind: DataKind::Mixed,
            n: 200,
            seed: 7,
            ..Default::default()
        });
        let n_disc = ds.vars.iter().filter(|v| v.discrete).count();
        assert!(n_disc >= 1 && n_disc <= 6, "~50% of 7 vars discrete, got {n_disc}");
        for v in ds.vars.iter().filter(|v| v.discrete) {
            let b = ds.block(v.col_start); // col index == var index here
            let distinct = crate::lowrank::distinct_rows(&b).len();
            assert!(distinct <= 5);
        }
    }

    #[test]
    fn multidim_generation_dims_in_range() {
        let (ds, _) = generate(&SynthConfig {
            kind: DataKind::MultiDim,
            n: 50,
            seed: 11,
            ..Default::default()
        });
        assert!(ds.vars.iter().all(|v| (1..=5).contains(&v.dim)));
        let total: usize = ds.vars.iter().map(|v| v.dim).sum();
        assert_eq!(ds.data.cols, total);
        assert!(ds.data.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn deterministic_by_seed() {
        let cfg = SynthConfig { n: 30, seed: 42, ..Default::default() };
        let (a, ga) = generate(&cfg);
        let (b, gb) = generate(&cfg);
        assert_eq!(a.data.data, b.data.data);
        assert_eq!(ga, gb);
    }

    #[test]
    fn child_actually_depends_on_parent() {
        // Statistical sanity: generated child correlates (in ranks) with
        // its parent for a dense graph.
        let (ds, dag) = generate(&SynthConfig { density: 0.8, n: 800, seed: 5, ..Default::default() });
        let mut found_dep = 0;
        let mut checked = 0;
        for (i, j) in dag.edges() {
            let xi: Vec<f64> = (0..ds.n()).map(|r| ds.data[(r, i)]).collect();
            let xj: Vec<f64> = (0..ds.n()).map(|r| ds.data[(r, j)]).collect();
            let rho = crate::util::stats::spearman(&xi, &xj).abs();
            checked += 1;
            if rho > 0.1 {
                found_dep += 1;
            }
        }
        assert!(
            found_dep * 2 >= checked,
            "at least half of the edges should show monotone dependence ({found_dep}/{checked})"
        );
    }

    #[test]
    fn equal_frequency_levels_balanced() {
        let mut m = Mat::from_vec(100, 1, (0..100).map(|i| (i as f64).sin()).collect());
        equal_frequency_discretize(&mut m, 0, 5);
        let mut counts = [0usize; 5];
        for r in 0..100 {
            counts[m[(r, 0)] as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 20), "{counts:?}");
    }
}
