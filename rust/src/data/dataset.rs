//! The `Dataset` type shared by every score function and search algorithm.
//!
//! Datasets are **appendable**: [`Dataset::append_rows`] validates and
//! folds new sample rows in place and bumps a monotonic row
//! [`Dataset::version`], which is what lets factor and score caches
//! detect staleness (see the `stream` module and the server's
//! `POST /v1/datasets/{name}/rows`).

use anyhow::bail;

use crate::linalg::Mat;

/// One random variable = a block of columns of the sample matrix.
#[derive(Clone, Debug)]
pub struct Variable {
    pub name: String,
    /// First column of the block.
    pub col_start: usize,
    /// Block width (≥ 1; multi-dimensional variables have width > 1).
    pub dim: usize,
    /// Discrete variables enable the exact Algorithm-2 factorization and
    /// the BDeu score.
    pub discrete: bool,
    /// Number of categories for discrete variables (0 for continuous).
    pub cardinality: usize,
}

/// n samples of d variables stored as one n × D row-major matrix.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub data: Mat,
    pub vars: Vec<Variable>,
    /// Monotonic row version: 0 at construction, bumped by every
    /// [`Dataset::append_rows`].
    version: u64,
}

impl Dataset {
    /// Build from an explicit sample matrix and variable layout
    /// (`vars` block offsets must tile the columns of `data`).
    pub fn new(data: Mat, vars: Vec<Variable>) -> Dataset {
        Dataset { data, vars, version: 0 }
    }

    /// Build from a matrix where each variable is a single column, with
    /// `discrete[i]` marking discrete columns.
    pub fn from_columns(data: Mat, discrete: &[bool]) -> Dataset {
        assert_eq!(data.cols, discrete.len());
        let vars = discrete
            .iter()
            .enumerate()
            .map(|(i, &d)| {
                let card = if d {
                    let mut vals: Vec<i64> = (0..data.rows).map(|r| data[(r, i)] as i64).collect();
                    vals.sort();
                    vals.dedup();
                    vals.len()
                } else {
                    0
                };
                Variable {
                    name: format!("X{}", i + 1),
                    col_start: i,
                    dim: 1,
                    discrete: d,
                    cardinality: card,
                }
            })
            .collect();
        Dataset::new(data, vars)
    }

    /// Number of samples.
    pub fn n(&self) -> usize {
        self.data.rows
    }

    /// Monotonic row version: bumped by every [`Dataset::append_rows`],
    /// so factor/score caches built over a snapshot can detect
    /// staleness.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Number of variables.
    pub fn d(&self) -> usize {
        self.vars.len()
    }

    /// The n × dim block of variable `i`.
    pub fn block(&self, i: usize) -> Mat {
        let v = &self.vars[i];
        let mut out = Mat::zeros(self.n(), v.dim);
        for r in 0..self.n() {
            out.row_mut(r)
                .copy_from_slice(&self.data.row(r)[v.col_start..v.col_start + v.dim]);
        }
        out
    }

    /// Concatenated block of several variables (in the given order) —
    /// the conditioning-set matrix Z for a parent set.
    pub fn block_multi(&self, idxs: &[usize]) -> Mat {
        let total: usize = idxs.iter().map(|&i| self.vars[i].dim).sum();
        let mut out = Mat::zeros(self.n(), total);
        let mut c0 = 0;
        for &i in idxs {
            let v = &self.vars[i];
            for r in 0..self.n() {
                out.row_mut(r)[c0..c0 + v.dim]
                    .copy_from_slice(&self.data.row(r)[v.col_start..v.col_start + v.dim]);
            }
            c0 += v.dim;
        }
        out
    }

    /// Are all the given variables discrete?
    pub fn all_discrete(&self, idxs: &[usize]) -> bool {
        idxs.iter().all(|&i| self.vars[i].discrete)
    }

    /// Discrete level of variable `i` at row `r` (assumes integer coding).
    pub fn level(&self, i: usize, r: usize) -> usize {
        debug_assert!(self.vars[i].discrete);
        self.data[(r, self.vars[i].col_start)] as usize
    }

    /// Restrict to the first `n` samples (for sample-size sweeps).
    /// Keeps the full variable schema (names, discreteness,
    /// cardinalities), so a head used to seed a streaming session never
    /// re-codes levels when the remaining rows arrive.
    pub fn head(&self, n: usize) -> Dataset {
        assert!(n <= self.n());
        let mut data = Mat::zeros(n, self.data.cols);
        for r in 0..n {
            data.row_mut(r).copy_from_slice(self.data.row(r));
        }
        Dataset::new(data, self.vars.clone())
    }

    /// Append sample rows in place (the streaming ingestion primitive).
    ///
    /// Validates before mutating anything: the column count must match,
    /// every value must be finite, and discrete variables only accept
    /// **contiguous** level codes — an existing code `0..k`, or exactly
    /// `k` to introduce the next new level (which grows the
    /// cardinality). Skipping codes is rejected: phantom states would
    /// silently skew count-based scores like BDeu. Bumps
    /// [`Dataset::version`] and returns the number of rows appended.
    pub fn append_rows(&mut self, rows: &Mat) -> anyhow::Result<usize> {
        if rows.cols != self.data.cols {
            bail!(
                "append: rows have {} columns, dataset has {}",
                rows.cols,
                self.data.cols
            );
        }
        // validate against a working copy of the cardinalities so a
        // chunk introducing several new levels stays contiguous row by
        // row, and a failed append mutates nothing
        let mut cards: Vec<usize> = self.vars.iter().map(|v| v.cardinality).collect();
        for r in 0..rows.rows {
            for (vi, v) in self.vars.iter().enumerate() {
                for c in v.col_start..v.col_start + v.dim {
                    let x = rows[(r, c)];
                    if !x.is_finite() {
                        bail!(
                            "append: non-finite value `{x}` at row {}, column {} (`{}`)",
                            r + 1,
                            c + 1,
                            v.name
                        );
                    }
                    if !v.discrete {
                        continue;
                    }
                    if x < 0.0 || x.fract() != 0.0 {
                        bail!(
                            "append: discrete variable `{}` needs a non-negative \
                             integer level code, got `{x}` at row {}",
                            v.name,
                            r + 1
                        );
                    }
                    let code = x as usize;
                    if code > cards[vi] {
                        bail!(
                            "append: discrete variable `{}` got level code {code} at \
                             row {} but has {} levels (codes are contiguous 0..k; \
                             the next new level must be {})",
                            v.name,
                            r + 1,
                            cards[vi],
                            cards[vi]
                        );
                    }
                    if code == cards[vi] {
                        cards[vi] += 1;
                    }
                }
            }
        }
        for (v, card) in self.vars.iter_mut().zip(cards) {
            if v.discrete {
                v.cardinality = card;
            }
        }
        self.data.append_rows(rows);
        self.version += 1;
        Ok(rows.rows)
    }

    /// Extract the concatenated variable block (same column layout as
    /// [`Dataset::block_multi`]) from an *external* row matrix laid out
    /// like `self.data` — used to restrict an appended chunk to one
    /// variable set without touching the stored samples.
    pub fn rows_block_multi(&self, rows: &Mat, idxs: &[usize]) -> Mat {
        assert_eq!(rows.cols, self.data.cols, "row layout mismatch");
        let total: usize = idxs.iter().map(|&i| self.vars[i].dim).sum();
        let mut out = Mat::zeros(rows.rows, total);
        let mut c0 = 0;
        for &i in idxs {
            let v = &self.vars[i];
            for r in 0..rows.rows {
                out.row_mut(r)[c0..c0 + v.dim]
                    .copy_from_slice(&rows.row(r)[v.col_start..v.col_start + v.dim]);
            }
            c0 += v.dim;
        }
        out
    }

    /// Z-score standardize continuous columns (in place); leaves discrete
    /// columns untouched. Stabilizes kernel widths across mechanisms.
    pub fn standardize(&mut self) {
        for v in &self.vars {
            if v.discrete {
                continue;
            }
            for c in v.col_start..v.col_start + v.dim {
                let n = self.n();
                let mut mean = 0.0;
                for r in 0..n {
                    mean += self.data[(r, c)];
                }
                mean /= n as f64;
                let mut var = 0.0;
                for r in 0..n {
                    let d = self.data[(r, c)] - mean;
                    var += d * d;
                }
                var /= n as f64;
                let sd = var.sqrt().max(1e-12);
                for r in 0..n {
                    self.data[(r, c)] = (self.data[(r, c)] - mean) / sd;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        // 3 samples, X1 continuous (1 col), X2 discrete (1 col)
        let data = Mat::from_rows(&[&[0.5, 1.0], &[1.5, 0.0], &[2.5, 1.0]]);
        Dataset::from_columns(data, &[false, true])
    }

    #[test]
    fn block_extraction() {
        let ds = toy();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.d(), 2);
        let b = ds.block(1);
        assert_eq!(b.data, vec![1.0, 0.0, 1.0]);
        assert_eq!(ds.vars[1].cardinality, 2);
    }

    #[test]
    fn block_multi_concatenates() {
        let ds = toy();
        let b = ds.block_multi(&[1, 0]);
        assert_eq!(b.cols, 2);
        assert_eq!(b.row(0), &[1.0, 0.5]);
    }

    #[test]
    fn all_discrete_flag() {
        let ds = toy();
        assert!(ds.all_discrete(&[1]));
        assert!(!ds.all_discrete(&[0, 1]));
        assert!(ds.all_discrete(&[]));
    }

    #[test]
    fn head_truncates() {
        let ds = toy();
        let h = ds.head(2);
        assert_eq!(h.n(), 2);
        assert_eq!(h.d(), 2);
    }

    #[test]
    fn append_rows_validates_and_bumps_version() {
        let mut ds = toy();
        assert_eq!(ds.version(), 0);
        let ok = Mat::from_rows(&[&[3.5, 2.0]]);
        assert_eq!(ds.append_rows(&ok).unwrap(), 1);
        assert_eq!(ds.n(), 4);
        assert_eq!(ds.version(), 1);
        // new top level 2 grows cardinality 2 → 3
        assert_eq!(ds.vars[1].cardinality, 3);

        // wrong arity
        assert!(ds.append_rows(&Mat::from_rows(&[&[1.0]])).is_err());
        // non-finite
        assert!(ds.append_rows(&Mat::from_rows(&[&[f64::NAN, 0.0]])).is_err());
        // fractional level code for a discrete variable
        assert!(ds.append_rows(&Mat::from_rows(&[&[1.0, 0.5]])).is_err());
        // negative level code
        assert!(ds.append_rows(&Mat::from_rows(&[&[1.0, -1.0]])).is_err());
        // non-contiguous level code (next new level must be 3, not 9)
        assert!(ds.append_rows(&Mat::from_rows(&[&[1.0, 9.0]])).is_err());
        // failed appends mutate nothing
        assert_eq!(ds.n(), 4);
        assert_eq!(ds.version(), 1);
        assert_eq!(ds.vars[1].cardinality, 3);
        // two new levels in one chunk stay contiguous (3 then 4)
        assert_eq!(
            ds.append_rows(&Mat::from_rows(&[&[0.0, 3.0], &[0.0, 4.0]])).unwrap(),
            2
        );
        assert_eq!(ds.vars[1].cardinality, 5);
    }

    #[test]
    fn rows_block_multi_matches_block_multi_layout() {
        let ds = toy();
        let ext = Mat::from_rows(&[&[9.0, 1.0], &[8.0, 0.0]]);
        let b = ds.rows_block_multi(&ext, &[1, 0]);
        assert_eq!(b.cols, 2);
        assert_eq!(b.row(0), &[1.0, 9.0]);
        assert_eq!(b.row(1), &[0.0, 8.0]);
    }

    #[test]
    fn standardize_continuous_only() {
        let mut ds = toy();
        ds.standardize();
        let b = ds.block(0);
        let mean: f64 = b.data.iter().sum::<f64>() / 3.0;
        assert!(mean.abs() < 1e-12);
        // discrete column unchanged
        assert_eq!(ds.block(1).data, vec![1.0, 0.0, 1.0]);
    }
}
