//! The `Dataset` type shared by every score function and search algorithm.

use crate::linalg::Mat;

/// One random variable = a block of columns of the sample matrix.
#[derive(Clone, Debug)]
pub struct Variable {
    pub name: String,
    /// First column of the block.
    pub col_start: usize,
    /// Block width (≥ 1; multi-dimensional variables have width > 1).
    pub dim: usize,
    /// Discrete variables enable the exact Algorithm-2 factorization and
    /// the BDeu score.
    pub discrete: bool,
    /// Number of categories for discrete variables (0 for continuous).
    pub cardinality: usize,
}

/// n samples of d variables stored as one n × D row-major matrix.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub data: Mat,
    pub vars: Vec<Variable>,
}

impl Dataset {
    /// Build from a matrix where each variable is a single column, with
    /// `discrete[i]` marking discrete columns.
    pub fn from_columns(data: Mat, discrete: &[bool]) -> Dataset {
        assert_eq!(data.cols, discrete.len());
        let vars = discrete
            .iter()
            .enumerate()
            .map(|(i, &d)| {
                let card = if d {
                    let mut vals: Vec<i64> = (0..data.rows).map(|r| data[(r, i)] as i64).collect();
                    vals.sort();
                    vals.dedup();
                    vals.len()
                } else {
                    0
                };
                Variable {
                    name: format!("X{}", i + 1),
                    col_start: i,
                    dim: 1,
                    discrete: d,
                    cardinality: card,
                }
            })
            .collect();
        Dataset { data, vars }
    }

    /// Number of samples.
    pub fn n(&self) -> usize {
        self.data.rows
    }

    /// Number of variables.
    pub fn d(&self) -> usize {
        self.vars.len()
    }

    /// The n × dim block of variable `i`.
    pub fn block(&self, i: usize) -> Mat {
        let v = &self.vars[i];
        let mut out = Mat::zeros(self.n(), v.dim);
        for r in 0..self.n() {
            out.row_mut(r)
                .copy_from_slice(&self.data.row(r)[v.col_start..v.col_start + v.dim]);
        }
        out
    }

    /// Concatenated block of several variables (in the given order) —
    /// the conditioning-set matrix Z for a parent set.
    pub fn block_multi(&self, idxs: &[usize]) -> Mat {
        let total: usize = idxs.iter().map(|&i| self.vars[i].dim).sum();
        let mut out = Mat::zeros(self.n(), total);
        let mut c0 = 0;
        for &i in idxs {
            let v = &self.vars[i];
            for r in 0..self.n() {
                out.row_mut(r)[c0..c0 + v.dim]
                    .copy_from_slice(&self.data.row(r)[v.col_start..v.col_start + v.dim]);
            }
            c0 += v.dim;
        }
        out
    }

    /// Are all the given variables discrete?
    pub fn all_discrete(&self, idxs: &[usize]) -> bool {
        idxs.iter().all(|&i| self.vars[i].discrete)
    }

    /// Discrete level of variable `i` at row `r` (assumes integer coding).
    pub fn level(&self, i: usize, r: usize) -> usize {
        debug_assert!(self.vars[i].discrete);
        self.data[(r, self.vars[i].col_start)] as usize
    }

    /// Restrict to the first `n` samples (for sample-size sweeps).
    pub fn head(&self, n: usize) -> Dataset {
        assert!(n <= self.n());
        let mut data = Mat::zeros(n, self.data.cols);
        for r in 0..n {
            data.row_mut(r).copy_from_slice(self.data.row(r));
        }
        Dataset { data, vars: self.vars.clone() }
    }

    /// Z-score standardize continuous columns (in place); leaves discrete
    /// columns untouched. Stabilizes kernel widths across mechanisms.
    pub fn standardize(&mut self) {
        for v in &self.vars {
            if v.discrete {
                continue;
            }
            for c in v.col_start..v.col_start + v.dim {
                let n = self.n();
                let mut mean = 0.0;
                for r in 0..n {
                    mean += self.data[(r, c)];
                }
                mean /= n as f64;
                let mut var = 0.0;
                for r in 0..n {
                    let d = self.data[(r, c)] - mean;
                    var += d * d;
                }
                var /= n as f64;
                let sd = var.sqrt().max(1e-12);
                for r in 0..n {
                    self.data[(r, c)] = (self.data[(r, c)] - mean) / sd;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        // 3 samples, X1 continuous (1 col), X2 discrete (1 col)
        let data = Mat::from_rows(&[&[0.5, 1.0], &[1.5, 0.0], &[2.5, 1.0]]);
        Dataset::from_columns(data, &[false, true])
    }

    #[test]
    fn block_extraction() {
        let ds = toy();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.d(), 2);
        let b = ds.block(1);
        assert_eq!(b.data, vec![1.0, 0.0, 1.0]);
        assert_eq!(ds.vars[1].cardinality, 2);
    }

    #[test]
    fn block_multi_concatenates() {
        let ds = toy();
        let b = ds.block_multi(&[1, 0]);
        assert_eq!(b.cols, 2);
        assert_eq!(b.row(0), &[1.0, 0.5]);
    }

    #[test]
    fn all_discrete_flag() {
        let ds = toy();
        assert!(ds.all_discrete(&[1]));
        assert!(!ds.all_discrete(&[0, 1]));
        assert!(ds.all_discrete(&[]));
    }

    #[test]
    fn head_truncates() {
        let ds = toy();
        let h = ds.head(2);
        assert_eq!(h.n(), 2);
        assert_eq!(h.d(), 2);
    }

    #[test]
    fn standardize_continuous_only() {
        let mut ds = toy();
        ds.standardize();
        let b = ds.block(0);
        let mean: f64 = b.data.iter().sum::<f64>() / 3.0;
        assert!(mean.abs() < 1e-12);
        // discrete column unchanged
        assert_eq!(ds.block(1).data, vec![1.0, 0.0, 1.0]);
    }
}
