//! LU factorization with partial pivoting — general (non-symmetric)
//! solves, determinants and inverses. Needed by DAGMA's log-det acyclicity
//! function (sI − W∘W is an M-matrix, not symmetric) and by the discrete
//! pivot solve in Algorithm 2 when kernels are not PSD to precision.

use super::mat::Mat;

/// P·A = L·U factorization (Doolittle with partial pivoting).
pub struct Lu {
    /// Combined LU storage: U on/above diagonal, L (unit diag) below.
    lu: Mat,
    /// Row permutation.
    piv: Vec<usize>,
    /// Sign of the permutation (for determinants).
    sign: f64,
}

impl Lu {
    /// Factor a square matrix; returns None if singular to precision.
    pub fn new(a: &Mat) -> Option<Lu> {
        assert_eq!(a.rows, a.cols);
        let n = a.rows;
        let mut lu = a.clone();
        let mut piv: Vec<usize> = (0..n).collect();
        let mut sign = 1.0;
        for k in 0..n {
            // pivot
            let mut p = k;
            let mut best = lu[(k, k)].abs();
            for i in (k + 1)..n {
                let v = lu[(i, k)].abs();
                if v > best {
                    best = v;
                    p = i;
                }
            }
            if best < 1e-300 {
                return None;
            }
            if p != k {
                for j in 0..n {
                    let t = lu[(k, j)];
                    lu[(k, j)] = lu[(p, j)];
                    lu[(p, j)] = t;
                }
                piv.swap(k, p);
                sign = -sign;
            }
            let pivot = lu[(k, k)];
            for i in (k + 1)..n {
                let f = lu[(i, k)] / pivot;
                lu[(i, k)] = f;
                if f == 0.0 {
                    continue;
                }
                for j in (k + 1)..n {
                    let u = lu[(k, j)];
                    lu[(i, j)] -= f * u;
                }
            }
        }
        Some(Lu { lu, piv, sign })
    }

    /// Determinant.
    pub fn det(&self) -> f64 {
        let mut d = self.sign;
        for i in 0..self.lu.rows {
            d *= self.lu[(i, i)];
        }
        d
    }

    /// log|det| (absolute value) — used for DAGMA's −logdet(sI − W∘W).
    pub fn log_abs_det(&self) -> f64 {
        (0..self.lu.rows).map(|i| self.lu[(i, i)].abs().ln()).sum()
    }

    /// Solve A X = B.
    pub fn solve(&self, b: &Mat) -> Mat {
        let n = self.lu.rows;
        assert_eq!(b.rows, n);
        // apply permutation
        let mut x = Mat::zeros(n, b.cols);
        for i in 0..n {
            x.row_mut(i).copy_from_slice(b.row(self.piv[i]));
        }
        // forward solve L y = Pb (unit lower)
        for i in 0..n {
            for k in 0..i {
                let f = self.lu[(i, k)];
                if f == 0.0 {
                    continue;
                }
                let (head, tail) = x.data.split_at_mut(i * x.cols);
                let xk = &head[k * x.cols..(k + 1) * x.cols];
                let xi = &mut tail[..x.cols];
                for c in 0..x.cols {
                    xi[c] -= f * xk[c];
                }
            }
        }
        // back solve U x = y
        for i in (0..n).rev() {
            for k in (i + 1)..n {
                let f = self.lu[(i, k)];
                if f == 0.0 {
                    continue;
                }
                let (head, tail) = x.data.split_at_mut(k * x.cols);
                let xi = &mut head[i * x.cols..(i + 1) * x.cols];
                let xk = &tail[..x.cols];
                for c in 0..x.cols {
                    xi[c] -= f * xk[c];
                }
            }
            let d = self.lu[(i, i)];
            for c in 0..x.cols {
                x[(i, c)] /= d;
            }
        }
        x
    }

    /// A⁻¹.
    pub fn inverse(&self) -> Mat {
        self.solve(&Mat::eye(self.lu.rows))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solves_known_system() {
        let a = Mat::from_rows(&[&[2.0, 1.0, 1.0], &[1.0, 3.0, 2.0], &[1.0, 0.0, 0.0]]);
        let b = Mat::col_vec(&[4.0, 5.0, 6.0]);
        let x = Lu::new(&a).unwrap().solve(&b);
        // By construction x = [6, 15, -23]
        assert!((x[(0, 0)] - 6.0).abs() < 1e-10);
        assert!((x[(1, 0)] - 15.0).abs() < 1e-10);
        assert!((x[(2, 0)] + 23.0).abs() < 1e-10);
    }

    #[test]
    fn det_matches_2x2() {
        let a = Mat::from_rows(&[&[3.0, 1.0], &[2.0, 4.0]]);
        assert!((Lu::new(&a).unwrap().det() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn inverse_random() {
        let mut rng = crate::util::Pcg64::new(42);
        let n = 10;
        let mut a = Mat::zeros(n, n);
        for x in &mut a.data {
            *x = rng.normal();
        }
        a = a.add_diag(5.0);
        let inv = Lu::new(&a).unwrap().inverse();
        assert!((&a.matmul(&inv) - &Mat::eye(n)).max_abs() < 1e-9);
    }

    #[test]
    fn singular_detected() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(Lu::new(&a).is_none());
    }

    #[test]
    fn log_abs_det_consistent() {
        let a = Mat::from_rows(&[&[3.0, 1.0], &[2.0, 4.0]]);
        let lu = Lu::new(&a).unwrap();
        assert!((lu.log_abs_det() - lu.det().abs().ln()).abs() < 1e-12);
    }
}
