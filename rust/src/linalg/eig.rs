//! Cyclic Jacobi eigensolver for real symmetric matrices.
//!
//! Used by the KCI conditional-independence test to obtain the eigenvalues
//! of centered kernel matrices for the weighted-chi-square null
//! approximation. O(n³) per sweep, a handful of sweeps to converge —
//! adequate for the n ≤ ~1200 matrices PC/MM-MB evaluate.

use super::mat::Mat;

/// Eigen-decomposition of a symmetric matrix: returns (eigenvalues,
/// eigenvectors) with `a ≈ V diag(w) Vᵀ`, eigenvalues sorted descending.
pub fn sym_eig(a: &Mat) -> (Vec<f64>, Mat) {
    assert_eq!(a.rows, a.cols);
    debug_assert!(a.is_symmetric(1e-8 * (1.0 + a.max_abs())), "sym_eig needs symmetric input");
    let n = a.rows;
    let mut m = a.clone();
    let mut v = Mat::eye(n);
    let max_sweeps = 30;
    for _sweep in 0..max_sweeps {
        // off-diagonal Frobenius norm
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-11 * (1.0 + m.max_abs()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    -1.0 / (-theta + (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // rotate rows/cols p,q of m
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                // accumulate eigenvectors
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    let mut w: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    // sort descending, permuting eigenvectors accordingly
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| w[b].partial_cmp(&w[a]).unwrap());
    let ws: Vec<f64> = order.iter().map(|&i| w[i]).collect();
    let mut vs = Mat::zeros(n, n);
    for (newj, &oldj) in order.iter().enumerate() {
        for i in 0..n {
            vs[(i, newj)] = v[(i, oldj)];
        }
    }
    w = ws;
    (w, vs)
}

/// Only the eigenvalues (descending).
pub fn sym_eigvals(a: &Mat) -> Vec<f64> {
    sym_eig(a).0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagonal_matrix() {
        let a = Mat::from_rows(&[&[3.0, 0.0], &[0.0, 1.0]]);
        let (w, _) = sym_eig(&a);
        assert!((w[0] - 3.0).abs() < 1e-10);
        assert!((w[1] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3, 1
        let a = Mat::from_rows(&[&[2.0, 1.0], &[1.0, 2.0]]);
        let (w, v) = sym_eig(&a);
        assert!((w[0] - 3.0).abs() < 1e-10);
        assert!((w[1] - 1.0).abs() < 1e-10);
        // check A v = w v
        for j in 0..2 {
            let col = Mat::from_vec(2, 1, vec![v[(0, j)], v[(1, j)]]);
            let av = a.matmul(&col);
            for i in 0..2 {
                assert!((av[(i, 0)] - w[j] * col[(i, 0)]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn reconstruction_random_psd() {
        let mut rng = crate::util::Pcg64::new(5);
        let n = 20;
        let mut b = Mat::zeros(n, 8);
        for x in &mut b.data {
            *x = rng.normal();
        }
        let a = b.matmul_t(&b); // PSD, rank ≤ 8
        let (w, v) = sym_eig(&a);
        // reconstruct
        let mut rec = Mat::zeros(n, n);
        for k in 0..n {
            for i in 0..n {
                for j in 0..n {
                    rec[(i, j)] += w[k] * v[(i, k)] * v[(j, k)];
                }
            }
        }
        assert!((&rec - &a).max_abs() < 1e-8 * (1.0 + a.max_abs()));
        // rank deficiency: eigenvalues beyond 8 are ~0
        for &wi in w.iter().skip(8) {
            assert!(wi.abs() < 1e-8 * (1.0 + a.max_abs()));
        }
        // trace preserved
        let tr_w: f64 = w.iter().sum();
        assert!((tr_w - a.trace()).abs() < 1e-8 * (1.0 + a.trace().abs()));
    }

    #[test]
    fn eigvals_sorted_descending() {
        let mut rng = crate::util::Pcg64::new(9);
        let n = 12;
        let mut b = Mat::zeros(n, n);
        for x in &mut b.data {
            *x = rng.normal();
        }
        let a = b.t_matmul(&b);
        let w = sym_eigvals(&a);
        for k in 1..n {
            assert!(w[k - 1] >= w[k] - 1e-12);
        }
    }
}
