//! Dense f64 linear algebra built in-tree (no nalgebra/faer offline).
//!
//! Provides exactly what the library needs:
//! * [`Mat`] — row-major dense matrix with arithmetic and blocked matmul;
//! * Cholesky factorization (+ solves, log-determinant) for the kernel
//!   score functions;
//! * LU with partial pivoting (+ solve / inverse / determinant) for the
//!   non-symmetric systems in DAGMA;
//! * cyclic Jacobi symmetric eigensolver for the KCI null distribution;
//! * matrix exponential (scaling & squaring) for the NOTEARS acyclicity
//!   function.

pub mod mat;
pub mod chol;
pub mod lu;
pub mod eig;
pub mod expm;

pub use chol::{psd_factor, Cholesky};
pub use eig::{sym_eig, sym_eigvals};
pub use expm::expm;
pub use lu::Lu;
pub use mat::Mat;
