//! Cholesky factorization and the solves/log-determinants built on it.
//!
//! This is the workhorse of both the exact CV score (n×n systems) and the
//! dumbbell-form CV-LR score (m×m cores): `A = L·Lᵀ`, `log|A| = 2Σ log L_ii`
//! (exactly the computation the paper describes for `log|n₁βB + I|`).

use super::mat::Mat;

/// Lower-triangular Cholesky factor of an SPD matrix.
pub struct Cholesky {
    pub l: Mat,
}

impl Cholesky {
    /// Factor an SPD matrix. Returns `None` if a non-positive pivot is hit
    /// (matrix not positive definite to working precision).
    pub fn new(a: &Mat) -> Option<Cholesky> {
        assert_eq!(a.rows, a.cols, "cholesky needs square input");
        let n = a.rows;
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = a[(i, j)];
                for k in 0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if s <= 0.0 {
                        return None;
                    }
                    l[(i, i)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Some(Cholesky { l })
    }

    /// log|A| = 2 Σ log L_ii.
    pub fn log_det(&self) -> f64 {
        (0..self.l.rows).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    /// Solve A X = B for matrix RHS (forward + back substitution).
    pub fn solve(&self, b: &Mat) -> Mat {
        let y = self.forward_sub(b);
        self.back_sub(&y)
    }

    /// Solve L Y = B.
    pub fn forward_sub(&self, b: &Mat) -> Mat {
        let n = self.l.rows;
        assert_eq!(b.rows, n);
        let mut y = b.clone();
        for i in 0..n {
            for k in 0..i {
                let lik = self.l[(i, k)];
                if lik == 0.0 {
                    continue;
                }
                // y[i,:] -= lik * y[k,:]
                let (head, tail) = y.data.split_at_mut(i * y.cols);
                let yk = &head[k * y.cols..(k + 1) * y.cols];
                let yi = &mut tail[..y.cols];
                for c in 0..y.cols {
                    yi[c] -= lik * yk[c];
                }
            }
            let d = self.l[(i, i)];
            for c in 0..y.cols {
                y[(i, c)] /= d;
            }
        }
        y
    }

    /// Solve Lᵀ X = Y.
    pub fn back_sub(&self, y: &Mat) -> Mat {
        let n = self.l.rows;
        assert_eq!(y.rows, n);
        let mut x = y.clone();
        for i in (0..n).rev() {
            for k in (i + 1)..n {
                let lki = self.l[(k, i)];
                if lki == 0.0 {
                    continue;
                }
                let (head, tail) = x.data.split_at_mut(k * x.cols);
                let xi = &mut head[i * x.cols..(i + 1) * x.cols];
                let xk = &tail[..x.cols];
                for c in 0..x.cols {
                    xi[c] -= lki * xk[c];
                }
            }
            let d = self.l[(i, i)];
            for c in 0..x.cols {
                x[(i, c)] /= d;
            }
        }
        x
    }

    /// A⁻¹ via solves against the identity.
    pub fn inverse(&self) -> Mat {
        self.solve(&Mat::eye(self.l.rows))
    }

    /// Solve Xᵀ such that X·A = B, i.e. returns B·A⁻¹ (A symmetric).
    pub fn solve_right(&self, b: &Mat) -> Mat {
        self.solve(&b.transpose()).transpose()
    }
}

/// Convenience: log|A| of an SPD matrix, panicking if not SPD.
pub fn spd_log_det(a: &Mat) -> f64 {
    Cholesky::new(a).expect("matrix not SPD in spd_log_det").log_det()
}

/// Pivoted semidefinite Cholesky: a symmetric **PSD** matrix `a`
/// (possibly singular) → an n×r factor `L` with `L·Lᵀ ≈ a`, where `r`
/// is the numerical rank at pivot threshold `tol · max(diag(a), 1)`.
/// Greedy diagonal pivoting — the same scheme ICL applies to implicit
/// kernel matrices, here run on a precomputed matrix — so the result is
/// deterministic and rounding-stable for PSD inputs where plain
/// [`Cholesky`] would reject a zero pivot. O(n²·r).
///
/// Used to synthesize low-row surrogate factors from m×m Gram cores
/// (`runtime::pjrt_kernel`): `Lᵀ` is an r×n matrix whose Gram is `a`.
pub fn psd_factor(a: &Mat, tol: f64) -> Mat {
    assert_eq!(a.rows, a.cols, "psd_factor needs square input");
    let n = a.rows;
    let mut d: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
    let scale = d.iter().fold(1.0f64, |m, &x| m.max(x));
    let thresh = tol * scale;
    let mut l = Mat::zeros(n, n);
    let mut used = vec![false; n];
    let mut rank = 0usize;
    for k in 0..n {
        // largest remaining residual diagonal above the threshold
        let mut p = usize::MAX;
        let mut best = thresh;
        for (i, &di) in d.iter().enumerate() {
            if !used[i] && di > best {
                best = di;
                p = i;
            }
        }
        if p == usize::MAX {
            break;
        }
        used[p] = true;
        let root = d[p].sqrt();
        l[(p, k)] = root;
        for i in 0..n {
            if used[i] {
                continue;
            }
            let mut s = a[(i, p)];
            for j in 0..k {
                s -= l[(i, j)] * l[(p, j)];
            }
            let v = s / root;
            l[(i, k)] = v;
            d[i] -= v * v;
        }
        rank = k + 1;
    }
    if rank == 0 {
        // numerically zero input: one zero column keeps downstream
        // shapes non-degenerate (L·Lᵀ = 0 = a)
        return Mat::zeros(n, 1);
    }
    let mut out = Mat::zeros(n, rank);
    for i in 0..n {
        out.row_mut(i).copy_from_slice(&l.row(i)[..rank]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = crate::util::Pcg64::new(seed);
        let mut b = Mat::zeros(n, n);
        for x in &mut b.data {
            *x = rng.normal();
        }
        let mut a = b.t_matmul(&b);
        for i in 0..n {
            a[(i, i)] += n as f64;
        }
        a
    }

    #[test]
    fn reconstructs_matrix() {
        let a = spd(8, 1);
        let ch = Cholesky::new(&a).unwrap();
        let rec = ch.l.matmul_t(&ch.l);
        assert!((&rec - &a).max_abs() < 1e-9);
    }

    #[test]
    fn solve_matches_direct() {
        let a = spd(6, 2);
        let b = Mat::from_vec(6, 2, (0..12).map(|i| i as f64).collect());
        let ch = Cholesky::new(&a).unwrap();
        let x = ch.solve(&b);
        let back = a.matmul(&x);
        assert!((&back - &b).max_abs() < 1e-8);
    }

    #[test]
    fn log_det_matches_2x2() {
        let a = Mat::from_rows(&[&[4.0, 1.0], &[1.0, 3.0]]);
        let ch = Cholesky::new(&a).unwrap();
        assert!((ch.log_det() - (11.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn inverse_is_inverse() {
        let a = spd(5, 3);
        let inv = Cholesky::new(&a).unwrap().inverse();
        let eye = a.matmul(&inv);
        assert!((&eye - &Mat::eye(5)).max_abs() < 1e-9);
    }

    #[test]
    fn non_spd_rejected() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // indefinite
        assert!(Cholesky::new(&a).is_none());
    }

    #[test]
    fn psd_factor_reconstructs_full_rank() {
        let a = spd(7, 5);
        let l = psd_factor(&a, 1e-12);
        assert_eq!(l.cols, 7, "SPD input is full rank");
        assert!((&l.matmul_t(&l) - &a).max_abs() < 1e-8);
    }

    #[test]
    fn psd_factor_handles_singular_and_zero() {
        // rank-2 PSD: B·Bᵀ with B 6×2
        let mut rng = crate::util::Pcg64::new(9);
        let mut b = Mat::zeros(6, 2);
        for x in &mut b.data {
            *x = rng.normal();
        }
        let a = b.matmul_t(&b);
        let l = psd_factor(&a, 1e-10);
        assert!(l.cols <= 2, "rank must not exceed 2 (got {})", l.cols);
        assert!((&l.matmul_t(&l) - &a).max_abs() < 1e-8);
        // zero matrix: a single zero column, exact reconstruction
        let z = psd_factor(&Mat::zeros(4, 4), 1e-10);
        assert_eq!((z.rows, z.cols), (4, 1));
        assert!(z.max_abs() == 0.0);
    }

    #[test]
    fn solve_right_matches() {
        let a = spd(4, 4);
        let b = Mat::from_vec(3, 4, (0..12).map(|i| (i as f64).sin()).collect());
        let ch = Cholesky::new(&a).unwrap();
        let x = ch.solve_right(&b); // x = b a^{-1}
        assert!((&x.matmul(&a) - &b).max_abs() < 1e-8);
    }
}
