//! Matrix exponential via scaling-and-squaring with a Taylor core.
//!
//! Used by NOTEARS' acyclicity function h(W) = tr(e^{W∘W}) − d and its
//! gradient (e^{W∘W})ᵀ ∘ 2W. The matrices are tiny (d ≤ 20 nodes), so a
//! 18-term Taylor series after scaling ‖A‖ below 0.5 reaches full f64
//! precision.

use super::mat::Mat;

/// e^A for a square matrix.
pub fn expm(a: &Mat) -> Mat {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    // scaling: find s with ‖A/2^s‖_inf <= 0.5
    let norm = (0..n)
        .map(|i| a.row(i).iter().map(|x| x.abs()).sum::<f64>())
        .fold(0.0f64, f64::max);
    let s = if norm > 0.5 { (norm / 0.5).log2().ceil() as u32 } else { 0 };
    let scaled = a.scale(1.0 / (1u64 << s) as f64);

    // Taylor: I + A + A²/2! + ... (18 terms)
    let mut result = Mat::eye(n);
    let mut term = Mat::eye(n);
    for k in 1..=18u64 {
        term = term.matmul(&scaled).scale(1.0 / k as f64);
        result = &result + &term;
        if term.max_abs() < 1e-18 {
            break;
        }
    }
    // squaring
    for _ in 0..s {
        result = result.matmul(&result);
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp_zero_is_identity() {
        let e = expm(&Mat::zeros(3, 3));
        assert!((&e - &Mat::eye(3)).max_abs() < 1e-14);
    }

    #[test]
    fn exp_diagonal() {
        let a = Mat::from_rows(&[&[1.0, 0.0], &[0.0, 2.0]]);
        let e = expm(&a);
        assert!((e[(0, 0)] - 1.0f64.exp()).abs() < 1e-12);
        assert!((e[(1, 1)] - 2.0f64.exp()).abs() < 1e-11);
        assert!(e[(0, 1)].abs() < 1e-14);
    }

    #[test]
    fn exp_nilpotent() {
        // strictly upper triangular N: e^N = I + N + N²/2
        let n = Mat::from_rows(&[&[0.0, 1.0, 2.0], &[0.0, 0.0, 3.0], &[0.0, 0.0, 0.0]]);
        let e = expm(&n);
        let n2 = n.matmul(&n);
        let expect = &(&Mat::eye(3) + &n) + &n2.scale(0.5);
        assert!((&e - &expect).max_abs() < 1e-12);
    }

    #[test]
    fn trace_of_dag_weight_exp_equals_d() {
        // For a DAG adjacency (nilpotent W∘W), tr(e^{W∘W}) = d exactly.
        let w = Mat::from_rows(&[&[0.0, 0.5, 0.0], &[0.0, 0.0, -1.2], &[0.0, 0.0, 0.0]]);
        let mut ww = w.clone();
        for x in &mut ww.data {
            *x = *x * *x;
        }
        let h = expm(&ww).trace() - 3.0;
        assert!(h.abs() < 1e-12);
    }

    #[test]
    fn large_norm_scaling_path() {
        let a = Mat::from_rows(&[&[0.0, 6.0], &[-6.0, 0.0]]); // rotation generator
        let e = expm(&a);
        // e^A = [[cos6, sin6], [-sin6, cos6]]
        assert!((e[(0, 0)] - 6.0f64.cos()).abs() < 1e-10);
        assert!((e[(0, 1)] - 6.0f64.sin()).abs() < 1e-10);
    }
}
