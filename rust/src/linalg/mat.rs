//! Row-major dense matrix with the arithmetic the score functions need.
//!
//! Matmul uses an i-k-j loop order with a transposed-B fast path; on the
//! single-core bench box this is within ~2-3x of an optimized BLAS for the
//! sizes the library touches (n ≤ 4096, m ≤ 128), and the hot path of the
//! system goes through the AOT XLA artifacts anyway.
//!
//! Gram products (the O(n·m²) inner loop of the fold-core provider,
//! `score::cores`) get two dedicated upgrades:
//!
//! * [`Mat::syrk`] — selfᵀ·self at **half** the flops of
//!   `t_matmul(self)`: only the upper triangle is accumulated (then
//!   mirrored), streaming 4-row panels so each output row is touched
//!   once per panel instead of once per sample row;
//! * [`Mat::par_syrk`] / [`Mat::par_t_matmul`] — the row-partitioned
//!   parallel path: rows are split into contiguous chunks evaluated
//!   under `std::thread::scope`, partial Grams summed in chunk order
//!   (deterministic for a fixed thread count). Gated on the
//!   `parallelism` knob threaded through `DiscoveryConfig`; `threads
//!   <= 1` (or too few rows) falls back to the serial kernels.

use std::fmt;
use std::ops::{Add, Index, IndexMut, Mul, Sub};

/// Minimum rows per worker before the parallel Gram paths split: below
/// this, thread spawn/join overhead beats the arithmetic saved.
const PAR_MIN_ROWS: usize = 128;

/// Dense row-major f64 matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            let row: Vec<String> =
                (0..self.cols.min(8)).map(|j| format!("{:10.4}", self[(i, j)])).collect();
            writeln!(f, "  {}{}", row.join(" "), if self.cols > 8 { " ..." } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity.
    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// From a row-major Vec.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    /// From nested rows (tests/readability).
    pub fn from_rows(rows: &[&[f64]]) -> Mat {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c);
            data.extend_from_slice(row);
        }
        Mat::from_vec(r, c, data)
    }

    /// Column vector from a slice.
    pub fn col_vec(xs: &[f64]) -> Mat {
        Mat::from_vec(xs.len(), 1, xs.to_vec())
    }

    /// Heap bytes held by the element buffer (capacity, not length):
    /// the per-matrix term of the byte-accurate cache accounting
    /// surfaced in `/v1/stats` and the `cvlr_service_*_bytes` gauges.
    pub fn resident_bytes(&self) -> u64 {
        (self.data.capacity() * std::mem::size_of::<f64>()) as u64
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// self * other.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        // i-k-j order: streams over `other` rows; good locality row-major.
        for i in 0..m {
            let arow = self.row(i);
            let orow = out.row_mut(i);
            for (p, &a) in arow.iter().enumerate().take(k) {
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[p * n..(p + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    /// selfᵀ * other — the Gram-product hot path (n×m ᵀ · n×m → m×m)
    /// computed without materializing the transpose.
    pub fn t_matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        let mut out = Mat::zeros(self.cols, other.cols);
        self.t_matmul_range_into(other, 0, self.rows, &mut out);
        out
    }

    /// Accumulate selfᵀ·other over the row range `lo..hi` into `out`
    /// (the chunk kernel shared by [`Mat::t_matmul`] and
    /// [`Mat::par_t_matmul`]).
    fn t_matmul_range_into(&self, other: &Mat, lo: usize, hi: usize, out: &mut Mat) {
        let mb = other.cols;
        for r in lo..hi {
            let arow = self.row(r);
            let brow = other.row(r);
            for (i, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * mb..(i + 1) * mb];
                for j in 0..mb {
                    orow[j] += a * brow[j];
                }
            }
        }
    }

    /// selfᵀ·self — the symmetric Gram (rank-k update) at half the
    /// flops of `t_matmul(self)`: only the upper triangle is
    /// accumulated, streaming blocked 4-row panels of `self` (each
    /// output row loaded once per panel, 4 products per accumulate),
    /// then mirrored.
    pub fn syrk(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.cols);
        self.syrk_range_upper(0, self.rows, &mut out);
        out.mirror_upper();
        out
    }

    /// Accumulate the upper triangle of selfᵀ·self over rows `lo..hi`
    /// into `out` (the chunk kernel shared by [`Mat::syrk`] and
    /// [`Mat::par_syrk`]). Caller mirrors once at the end.
    fn syrk_range_upper(&self, lo: usize, hi: usize, out: &mut Mat) {
        let m = self.cols;
        let mut r = lo;
        while r + 4 <= hi {
            let (a0, a1) = (self.row(r), self.row(r + 1));
            let (a2, a3) = (self.row(r + 2), self.row(r + 3));
            for i in 0..m {
                let (x0, x1, x2, x3) = (a0[i], a1[i], a2[i], a3[i]);
                if x0 == 0.0 && x1 == 0.0 && x2 == 0.0 && x3 == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * m..(i + 1) * m];
                for j in i..m {
                    orow[j] += x0 * a0[j] + x1 * a1[j] + x2 * a2[j] + x3 * a3[j];
                }
            }
            r += 4;
        }
        while r < hi {
            let a = self.row(r);
            for i in 0..m {
                let x = a[i];
                if x == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * m..(i + 1) * m];
                for j in i..m {
                    orow[j] += x * a[j];
                }
            }
            r += 1;
        }
    }

    /// Copy the upper triangle onto the lower (square matrices whose
    /// upper half was accumulated by a `*_range_upper` kernel, or
    /// rank-one-corrected cores assembled triangle-first so they stay
    /// exactly symmetric — see `score::cores`).
    pub(crate) fn mirror_upper(&mut self) {
        let n = self.cols;
        for i in 0..self.rows {
            for j in (i + 1)..n {
                self.data[j * n + i] = self.data[i * n + j];
            }
        }
    }

    /// How many workers a parallel Gram over `rows` rows should use.
    fn par_workers(rows: usize, threads: usize) -> usize {
        threads.min(rows / PAR_MIN_ROWS).max(1)
    }

    /// Row-partitioned parallel selfᵀ·self: rows split into `threads`
    /// contiguous chunks evaluated under `std::thread::scope`, partial
    /// upper-triangle Grams summed in chunk order (deterministic for a
    /// fixed thread count). `threads <= 1` — or too few rows to
    /// amortize a spawn — is exactly [`Mat::syrk`].
    pub fn par_syrk(&self, threads: usize) -> Mat {
        let workers = Self::par_workers(self.rows, threads);
        if workers <= 1 {
            return self.syrk();
        }
        let m = self.cols;
        let chunk = self.rows.div_ceil(workers);
        let parts: Vec<Mat> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|w| {
                    scope.spawn(move || {
                        let lo = w * chunk;
                        let hi = ((w + 1) * chunk).min(self.rows);
                        let mut part = Mat::zeros(m, m);
                        if lo < hi {
                            self.syrk_range_upper(lo, hi, &mut part);
                        }
                        part
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("gram worker panicked")).collect()
        });
        let mut out = Mat::zeros(m, m);
        for part in &parts {
            for (o, p) in out.data.iter_mut().zip(&part.data) {
                *o += p;
            }
        }
        out.mirror_upper();
        out
    }

    /// Row-partitioned parallel selfᵀ·other (same contract as
    /// [`Mat::par_syrk`]: chunk-order summation, serial fallback).
    pub fn par_t_matmul(&self, other: &Mat, threads: usize) -> Mat {
        assert_eq!(self.rows, other.rows, "par_t_matmul shape mismatch");
        let workers = Self::par_workers(self.rows, threads);
        if workers <= 1 {
            return self.t_matmul(other);
        }
        let (ma, mb) = (self.cols, other.cols);
        let chunk = self.rows.div_ceil(workers);
        let parts: Vec<Mat> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|w| {
                    scope.spawn(move || {
                        let lo = w * chunk;
                        let hi = ((w + 1) * chunk).min(self.rows);
                        let mut part = Mat::zeros(ma, mb);
                        if lo < hi {
                            self.t_matmul_range_into(other, lo, hi, &mut part);
                        }
                        part
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("gram worker panicked")).collect()
        });
        let mut out = Mat::zeros(ma, mb);
        for part in &parts {
            for (o, p) in out.data.iter_mut().zip(&part.data) {
                *o += p;
            }
        }
        out
    }

    /// self * otherᵀ.
    pub fn matmul_t(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let arow = self.row(i);
            for j in 0..n {
                let brow = other.row(j);
                let mut s = 0.0;
                for p in 0..k {
                    s += arow[p] * brow[p];
                }
                out[(i, j)] = s;
            }
        }
        out
    }

    pub fn scale(&self, s: f64) -> Mat {
        let mut out = self.clone();
        for x in &mut out.data {
            *x *= s;
        }
        out
    }

    /// self + s·I (must be square).
    pub fn add_diag(&self, s: f64) -> Mat {
        assert_eq!(self.rows, self.cols);
        let mut out = self.clone();
        for i in 0..self.rows {
            out[(i, i)] += s;
        }
        out
    }

    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self[(i, i)]).sum()
    }

    /// Tr(self · otherᵀ) = Σ_ij self_ij · other_ij (entrywise; both same
    /// shape) — evaluates Tr(A·B) when called as `a.dot_t(&b.transpose())`,
    /// but most call sites use the entrywise identity directly.
    pub fn frob_dot(&self, other: &Mat) -> f64 {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        self.data.iter().zip(&other.data).map(|(a, b)| a * b).sum()
    }

    /// Tr(self · other) for square conformable matrices, O(n²).
    pub fn trace_prod(&self, other: &Mat) -> f64 {
        assert_eq!(self.cols, other.rows);
        assert_eq!(self.rows, other.cols);
        let mut s = 0.0;
        for i in 0..self.rows {
            for j in 0..self.cols {
                s += self[(i, j)] * other[(j, i)];
            }
        }
        s
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }

    /// Center columns: subtract each column's mean (the H·Λ operation of
    /// the paper, computed in O(nm)).
    pub fn center_columns(&self) -> Mat {
        let mut out = self.clone();
        for j in 0..self.cols {
            let mut mean = 0.0;
            for i in 0..self.rows {
                mean += self[(i, j)];
            }
            mean /= self.rows as f64;
            for i in 0..self.rows {
                out[(i, j)] -= mean;
            }
        }
        out
    }

    /// Rows selected by `idx` (gather).
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// Append the rows of `other` in place (column counts must match).
    /// Amortized O(rows · cols) of the appended block — the backing
    /// storage grows like a `Vec`, which is what makes row streaming
    /// cheap.
    pub fn append_rows(&mut self, other: &Mat) {
        assert_eq!(self.cols, other.cols, "append_rows: column count mismatch");
        self.data.extend_from_slice(&other.data);
        self.rows += other.rows;
    }

    /// Horizontal concatenation.
    pub fn hcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows);
        let mut out = Mat::zeros(self.rows, self.cols + other.cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
            out.row_mut(i)[self.cols..].copy_from_slice(other.row(i));
        }
        out
    }

    /// Pad with zero rows/cols up to (rows, cols).
    pub fn pad_to(&self, rows: usize, cols: usize) -> Mat {
        assert!(rows >= self.rows && cols >= self.cols);
        let mut out = Mat::zeros(rows, cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
        }
        out
    }

    /// Is symmetric to tolerance?
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl Add for &Mat {
    type Output = Mat;
    fn add(self, rhs: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&rhs.data) {
            *a += b;
        }
        out
    }
}

impl Sub for &Mat {
    type Output = Mat;
    fn sub(self, rhs: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&rhs.data) {
            *a -= b;
        }
        out
    }
}

impl Mul for &Mat {
    type Output = Mat;
    fn mul(self, rhs: &Mat) -> Mat {
        self.matmul(rhs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn t_matmul_matches_naive() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        let b = Mat::from_rows(&[&[1.0, 0.0, 2.0], &[0.0, 1.0, 1.0], &[1.0, 1.0, 0.0]]);
        let fast = a.t_matmul(&b);
        let slow = a.transpose().matmul(&b);
        assert!((&fast - &slow).max_abs() < 1e-14);
    }

    fn random_mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = crate::util::Pcg64::new(seed);
        let mut m = Mat::zeros(rows, cols);
        for v in &mut m.data {
            *v = rng.normal();
        }
        m
    }

    #[test]
    fn syrk_matches_t_matmul() {
        // sizes straddling the 4-row panel boundary and the zero fast path
        for (n, m, seed) in [(1usize, 3usize, 1u64), (4, 5, 2), (7, 6, 3), (33, 9, 4)] {
            let mut a = random_mat(n, m, seed);
            a[(0, 0)] = 0.0; // exercise the skip-zero branch
            let fast = a.syrk();
            let slow = a.t_matmul(&a);
            assert!((&fast - &slow).max_abs() < 1e-12, "n={n} m={m}");
            assert!(fast.is_symmetric(0.0), "syrk output must be exactly symmetric");
        }
    }

    #[test]
    fn par_syrk_matches_serial() {
        // above the PAR_MIN_ROWS gate so chunks really run in parallel
        let a = random_mat(700, 11, 5);
        let serial = a.syrk();
        for threads in [1usize, 2, 3, 8] {
            let par = a.par_syrk(threads);
            assert!(
                (&par - &serial).max_abs() < 1e-10,
                "threads={threads} diverged from serial"
            );
        }
        // tiny inputs fall back to the serial kernel bit-for-bit
        let small = random_mat(20, 4, 6);
        assert_eq!(small.par_syrk(8).data, small.syrk().data);
    }

    #[test]
    fn par_t_matmul_matches_serial() {
        let a = random_mat(700, 7, 7);
        let b = random_mat(700, 5, 8);
        let serial = a.t_matmul(&b);
        for threads in [2usize, 4] {
            let par = a.par_t_matmul(&b, threads);
            assert!((&par - &serial).max_abs() < 1e-10, "threads={threads}");
        }
        let small = random_mat(30, 3, 9);
        let sb = random_mat(30, 2, 10);
        assert_eq!(small.par_t_matmul(&sb, 8).data, small.t_matmul(&sb).data);
    }

    #[test]
    fn matmul_t_matches_naive() {
        let a = Mat::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        let b = Mat::from_rows(&[&[1.0, 1.0, 1.0], &[2.0, 0.0, 1.0]]);
        let fast = a.matmul_t(&b);
        let slow = a.matmul(&b.transpose());
        assert!((&fast - &slow).max_abs() < 1e-14);
    }

    #[test]
    fn center_columns_zero_mean() {
        let a = Mat::from_rows(&[&[1.0, 10.0], &[2.0, 20.0], &[3.0, 30.0]]);
        let c = a.center_columns();
        for j in 0..2 {
            let s: f64 = (0..3).map(|i| c[(i, j)]).sum();
            assert!(s.abs() < 1e-12);
        }
    }

    #[test]
    fn trace_prod_matches_matmul() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[0.5, -1.0], &[2.0, 1.5]]);
        assert!((a.trace_prod(&b) - a.matmul(&b).trace()).abs() < 1e-12);
    }

    #[test]
    fn pad_preserves_gram() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        let p = a.pad_to(7, 5);
        let g1 = a.t_matmul(&a);
        let g2 = p.t_matmul(&p);
        // top-left block equal, rest zero
        for i in 0..5 {
            for j in 0..5 {
                let expect = if i < 2 && j < 2 { g1[(i, j)] } else { 0.0 };
                assert!((g2[(i, j)] - expect).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn hcat_and_select_rows() {
        let a = Mat::from_rows(&[&[1.0], &[2.0]]);
        let b = Mat::from_rows(&[&[3.0], &[4.0]]);
        let h = a.hcat(&b);
        assert_eq!(h.row(0), &[1.0, 3.0]);
        let s = h.select_rows(&[1]);
        assert_eq!(s.row(0), &[2.0, 4.0]);
    }
}
