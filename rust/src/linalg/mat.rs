//! Row-major dense matrix with the arithmetic the score functions need.
//!
//! Matmul uses an i-k-j loop order with a transposed-B fast path; on the
//! single-core bench box this is within ~2-3x of an optimized BLAS for the
//! sizes the library touches (n ≤ 4096, m ≤ 128), and the hot path of the
//! system goes through the AOT XLA artifacts anyway.

use std::fmt;
use std::ops::{Add, Index, IndexMut, Mul, Sub};

/// Dense row-major f64 matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            let row: Vec<String> =
                (0..self.cols.min(8)).map(|j| format!("{:10.4}", self[(i, j)])).collect();
            writeln!(f, "  {}{}", row.join(" "), if self.cols > 8 { " ..." } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity.
    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// From a row-major Vec.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    /// From nested rows (tests/readability).
    pub fn from_rows(rows: &[&[f64]]) -> Mat {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c);
            data.extend_from_slice(row);
        }
        Mat::from_vec(r, c, data)
    }

    /// Column vector from a slice.
    pub fn col_vec(xs: &[f64]) -> Mat {
        Mat::from_vec(xs.len(), 1, xs.to_vec())
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// self * other.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        // i-k-j order: streams over `other` rows; good locality row-major.
        for i in 0..m {
            let arow = self.row(i);
            let orow = out.row_mut(i);
            for (p, &a) in arow.iter().enumerate().take(k) {
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[p * n..(p + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    /// selfᵀ * other — the Gram-product hot path (n×m ᵀ · n×m → m×m)
    /// computed without materializing the transpose.
    pub fn t_matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        let (n, ma, mb) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(ma, mb);
        for r in 0..n {
            let arow = self.row(r);
            let brow = other.row(r);
            for (i, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * mb..(i + 1) * mb];
                for j in 0..mb {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    /// self * otherᵀ.
    pub fn matmul_t(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let arow = self.row(i);
            for j in 0..n {
                let brow = other.row(j);
                let mut s = 0.0;
                for p in 0..k {
                    s += arow[p] * brow[p];
                }
                out[(i, j)] = s;
            }
        }
        out
    }

    pub fn scale(&self, s: f64) -> Mat {
        let mut out = self.clone();
        for x in &mut out.data {
            *x *= s;
        }
        out
    }

    /// self + s·I (must be square).
    pub fn add_diag(&self, s: f64) -> Mat {
        assert_eq!(self.rows, self.cols);
        let mut out = self.clone();
        for i in 0..self.rows {
            out[(i, i)] += s;
        }
        out
    }

    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self[(i, i)]).sum()
    }

    /// Tr(self · otherᵀ) = Σ_ij self_ij · other_ij (entrywise; both same
    /// shape) — evaluates Tr(A·B) when called as `a.dot_t(&b.transpose())`,
    /// but most call sites use the entrywise identity directly.
    pub fn frob_dot(&self, other: &Mat) -> f64 {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        self.data.iter().zip(&other.data).map(|(a, b)| a * b).sum()
    }

    /// Tr(self · other) for square conformable matrices, O(n²).
    pub fn trace_prod(&self, other: &Mat) -> f64 {
        assert_eq!(self.cols, other.rows);
        assert_eq!(self.rows, other.cols);
        let mut s = 0.0;
        for i in 0..self.rows {
            for j in 0..self.cols {
                s += self[(i, j)] * other[(j, i)];
            }
        }
        s
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }

    /// Center columns: subtract each column's mean (the H·Λ operation of
    /// the paper, computed in O(nm)).
    pub fn center_columns(&self) -> Mat {
        let mut out = self.clone();
        for j in 0..self.cols {
            let mut mean = 0.0;
            for i in 0..self.rows {
                mean += self[(i, j)];
            }
            mean /= self.rows as f64;
            for i in 0..self.rows {
                out[(i, j)] -= mean;
            }
        }
        out
    }

    /// Rows selected by `idx` (gather).
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// Append the rows of `other` in place (column counts must match).
    /// Amortized O(rows · cols) of the appended block — the backing
    /// storage grows like a `Vec`, which is what makes row streaming
    /// cheap.
    pub fn append_rows(&mut self, other: &Mat) {
        assert_eq!(self.cols, other.cols, "append_rows: column count mismatch");
        self.data.extend_from_slice(&other.data);
        self.rows += other.rows;
    }

    /// Horizontal concatenation.
    pub fn hcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows);
        let mut out = Mat::zeros(self.rows, self.cols + other.cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
            out.row_mut(i)[self.cols..].copy_from_slice(other.row(i));
        }
        out
    }

    /// Pad with zero rows/cols up to (rows, cols).
    pub fn pad_to(&self, rows: usize, cols: usize) -> Mat {
        assert!(rows >= self.rows && cols >= self.cols);
        let mut out = Mat::zeros(rows, cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
        }
        out
    }

    /// Is symmetric to tolerance?
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl Add for &Mat {
    type Output = Mat;
    fn add(self, rhs: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&rhs.data) {
            *a += b;
        }
        out
    }
}

impl Sub for &Mat {
    type Output = Mat;
    fn sub(self, rhs: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&rhs.data) {
            *a -= b;
        }
        out
    }
}

impl Mul for &Mat {
    type Output = Mat;
    fn mul(self, rhs: &Mat) -> Mat {
        self.matmul(rhs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn t_matmul_matches_naive() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        let b = Mat::from_rows(&[&[1.0, 0.0, 2.0], &[0.0, 1.0, 1.0], &[1.0, 1.0, 0.0]]);
        let fast = a.t_matmul(&b);
        let slow = a.transpose().matmul(&b);
        assert!((&fast - &slow).max_abs() < 1e-14);
    }

    #[test]
    fn matmul_t_matches_naive() {
        let a = Mat::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        let b = Mat::from_rows(&[&[1.0, 1.0, 1.0], &[2.0, 0.0, 1.0]]);
        let fast = a.matmul_t(&b);
        let slow = a.matmul(&b.transpose());
        assert!((&fast - &slow).max_abs() < 1e-14);
    }

    #[test]
    fn center_columns_zero_mean() {
        let a = Mat::from_rows(&[&[1.0, 10.0], &[2.0, 20.0], &[3.0, 30.0]]);
        let c = a.center_columns();
        for j in 0..2 {
            let s: f64 = (0..3).map(|i| c[(i, j)]).sum();
            assert!(s.abs() < 1e-12);
        }
    }

    #[test]
    fn trace_prod_matches_matmul() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[0.5, -1.0], &[2.0, 1.5]]);
        assert!((a.trace_prod(&b) - a.matmul(&b).trace()).abs() < 1e-12);
    }

    #[test]
    fn pad_preserves_gram() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        let p = a.pad_to(7, 5);
        let g1 = a.t_matmul(&a);
        let g2 = p.t_matmul(&p);
        // top-left block equal, rest zero
        for i in 0..5 {
            for j in 0..5 {
                let expect = if i < 2 && j < 2 { g1[(i, j)] } else { 0.0 };
                assert!((g2[(i, j)] - expect).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn hcat_and_select_rows() {
        let a = Mat::from_rows(&[&[1.0], &[2.0]]);
        let b = Mat::from_rows(&[&[3.0], &[4.0]]);
        let h = a.hcat(&b);
        assert_eq!(h.row(0), &[1.0, 3.0]);
        let s = h.select_rows(&[1]);
        assert_eq!(s.row(0), &[2.0, 4.0]);
    }
}
