//! Wire schema of the shard protocol, over the crate's own JSON codec.
//!
//! Two message families, both stateless:
//!
//! * **`POST /v1/score_batch`** — a scoring sub-batch: dataset name (+
//!   a pinned follower-side registry version), method/engine/lowrank,
//!   and the request list. The reply is `{"scores": [...], "version"}`
//!   in request order. The codec's f64 `Display` prints the shortest
//!   round-trip decimal, so scores cross the wire **bit-identical** —
//!   the whole distributed design leans on that.
//! * **raw dataset push** — the coordinator serializes its dataset in
//!   *internal coordinates* (the already-z-scored/recoded sample matrix
//!   plus the variable layout) and registers it on a follower through
//!   the `raw` mode of `POST /v1/datasets`. Re-ingesting CSV text would
//!   z-score a second time; the raw mode reconstructs the exact matrix,
//!   so follower fold algebra runs on the same bits as the coordinator.

use anyhow::{bail, Context, Result};

use crate::data::{Dataset, Variable};
use crate::linalg::Mat;
use crate::obs::trace::SpanEvent;
use crate::score::ScoreRequest;
use crate::server::json::Json;

/// What a follower needs to resolve (or build) the right pooled score
/// service: the named dataset plus the method/engine/lowrank triple the
/// coordinator is running. Serialized into every `score_batch` request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    /// Registry name of the dataset on the follower.
    pub dataset: String,
    /// Canonical method key (e.g. `"cv-lr"`).
    pub method: String,
    /// `"native"` or `"pjrt"`.
    pub engine: String,
    /// `"icl"` or `"rff"`.
    pub lowrank: String,
}

fn num(x: u64) -> Json {
    Json::Num(x as f64)
}

/// Body of a `POST /v1/score_batch` request. `version`, when known,
/// pins the follower's registry version of the dataset so a concurrent
/// re-registration can never serve scores from different bits — the
/// follower answers `409` on a mismatch and the coordinator re-pushes.
/// `deadline_ms`, when set, is the coordinator's remaining budget at
/// dispatch time; the follower cancels its chunked evaluation
/// cooperatively once it runs out (old followers ignore the field —
/// the protocol stays backward compatible in both directions).
pub fn score_batch_body(
    spec: &ShardSpec,
    version: Option<u64>,
    deadline_ms: Option<u64>,
    reqs: &[ScoreRequest],
) -> Json {
    let requests: Vec<Json> = reqs
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("target", num(r.target as u64)),
                ("parents", Json::Arr(r.parents.iter().map(|&p| num(p as u64)).collect())),
            ])
        })
        .collect();
    let mut fields = vec![("dataset", Json::str(spec.dataset.clone()))];
    if let Some(v) = version {
        fields.push(("version", num(v)));
    }
    if let Some(d) = deadline_ms {
        fields.push(("deadline_ms", num(d)));
    }
    fields.push(("method", Json::str(spec.method.clone())));
    fields.push(("engine", Json::str(spec.engine.clone())));
    fields.push(("lowrank", Json::str(spec.lowrank.clone())));
    fields.push(("requests", Json::Arr(requests)));
    Json::obj(fields)
}

/// A decoded `score_batch` request body.
#[derive(Clone, Debug)]
pub struct ScoreBatchMsg {
    pub spec: ShardSpec,
    pub version: Option<u64>,
    /// Remaining coordinator budget at dispatch, in milliseconds.
    pub deadline_ms: Option<u64>,
    pub reqs: Vec<ScoreRequest>,
}

/// Follower-side decode of a `score_batch` body.
pub fn parse_score_batch(body: &Json) -> Result<ScoreBatchMsg> {
    let dataset = body
        .get("dataset")
        .and_then(Json::as_str)
        .context("`dataset` (string) is required")?
        .to_string();
    let method = body
        .get("method")
        .and_then(Json::as_str)
        .context("`method` (string) is required")?
        .to_string();
    let engine = body
        .get("engine")
        .and_then(Json::as_str)
        .unwrap_or("native")
        .to_string();
    let lowrank = body
        .get("lowrank")
        .and_then(Json::as_str)
        .unwrap_or("icl")
        .to_string();
    let version = match body.get("version") {
        Some(v) => Some(v.as_u64().context("`version` must be a non-negative integer")?),
        None => None,
    };
    let deadline_ms = match body.get("deadline_ms") {
        Some(v) => Some(v.as_u64().context("`deadline_ms` must be a non-negative integer")?),
        None => None,
    };
    let raw = body
        .get("requests")
        .and_then(Json::as_arr)
        .context("`requests` (array) is required")?;
    let mut reqs = Vec::with_capacity(raw.len());
    for (i, r) in raw.iter().enumerate() {
        let target = r
            .get("target")
            .and_then(Json::as_u64)
            .with_context(|| format!("request {i}: `target` must be a non-negative integer"))?
            as usize;
        let parents = r
            .get("parents")
            .and_then(Json::as_arr)
            .with_context(|| format!("request {i}: `parents` (array) is required"))?;
        let mut p = Vec::with_capacity(parents.len());
        for v in parents {
            p.push(
                v.as_u64()
                    .with_context(|| format!("request {i}: parents must be integers"))?
                    as usize,
            );
        }
        reqs.push(ScoreRequest::new(target, &p));
    }
    Ok(ScoreBatchMsg {
        spec: ShardSpec { dataset, method, engine, lowrank },
        version,
        deadline_ms,
        reqs,
    })
}

/// Coordinator-side decode of a `score_batch` reply; `expect` guards
/// against truncated/reordered replies.
pub fn parse_scores(body: &Json, expect: usize) -> Result<Vec<f64>> {
    let arr = body
        .get("scores")
        .and_then(Json::as_arr)
        .context("reply has no `scores` array")?;
    if arr.len() != expect {
        bail!("reply has {} scores, expected {expect}", arr.len());
    }
    arr.iter()
        .enumerate()
        .map(|(i, v)| v.as_f64().with_context(|| format!("score {i} is not a finite number")))
        .collect()
}

/// The optional `timings` array of a `score_batch` reply: the
/// follower's stage spans for this sub-batch, timestamps re-based to
/// the start of its evaluation (a `trace::capture`). Old followers
/// simply omit the field — the protocol stays backward compatible in
/// both directions (old coordinators ignore unknown reply fields).
pub fn timings_json(events: &[SpanEvent]) -> Json {
    Json::Arr(
        events
            .iter()
            .map(|ev| {
                Json::obj(vec![
                    ("name", Json::str(ev.name.clone())),
                    ("cat", Json::str(ev.cat.clone())),
                    ("ts", num(ev.ts_us)),
                    ("dur", num(ev.dur_us)),
                    ("tid", num(ev.tid)),
                    ("instant", Json::Bool(ev.instant)),
                ])
            })
            .collect(),
    )
}

/// Coordinator-side decode of a reply's `timings` field. Tolerant by
/// design: an absent field (old follower) or malformed entries yield an
/// empty/partial list — timing merge is observability, never worth
/// failing a scoring reply over. `pid` is left 0 for the caller to
/// re-assign (`trace::remote_pid`).
pub fn parse_timings(reply: &Json) -> Vec<SpanEvent> {
    let Some(arr) = reply.get("timings").and_then(Json::as_arr) else {
        return Vec::new();
    };
    arr.iter()
        .filter_map(|e| {
            Some(SpanEvent {
                name: e.get("name")?.as_str()?.to_string(),
                cat: e.get("cat").and_then(Json::as_str).unwrap_or("remote").to_string(),
                ts_us: e.get("ts").and_then(Json::as_u64)?,
                dur_us: e.get("dur").and_then(Json::as_u64).unwrap_or(0),
                pid: 0,
                tid: e.get("tid").and_then(Json::as_u64).unwrap_or(1),
                instant: e.get("instant").and_then(Json::as_bool).unwrap_or(false),
                id: 0,
                args: Vec::new(),
            })
        })
        .collect()
}

/// `POST /v1/datasets` body registering `ds` on a follower in raw
/// internal coordinates (no CSV re-ingestion, bit-exact round trip).
pub fn dataset_body(name: &str, ds: &Dataset) -> Json {
    let vars: Vec<Json> = ds
        .vars
        .iter()
        .map(|v| {
            Json::obj(vec![
                ("name", Json::str(v.name.clone())),
                ("col_start", num(v.col_start as u64)),
                ("dim", num(v.dim as u64)),
                ("discrete", Json::Bool(v.discrete)),
                ("cardinality", num(v.cardinality as u64)),
            ])
        })
        .collect();
    let raw = Json::obj(vec![
        ("rows", num(ds.data.rows as u64)),
        ("cols", num(ds.data.cols as u64)),
        ("data", Json::Arr(ds.data.data.iter().map(|&x| Json::Num(x)).collect())),
        ("vars", Json::Arr(vars)),
    ]);
    Json::obj(vec![("name", Json::str(name)), ("raw", raw)])
}

/// Follower-side decode of the `raw` dataset mode: reconstruct the
/// sample matrix and variable layout exactly as serialized.
pub fn parse_raw_dataset(raw: &Json) -> Result<Dataset> {
    let rows = raw.get("rows").and_then(Json::as_u64).context("`raw.rows` is required")? as usize;
    let cols = raw.get("cols").and_then(Json::as_u64).context("`raw.cols` is required")? as usize;
    let data = raw.get("data").and_then(Json::as_arr).context("`raw.data` is required")?;
    if data.len() != rows * cols {
        bail!("`raw.data` has {} values, expected {rows}×{cols}", data.len());
    }
    let mut flat = Vec::with_capacity(data.len());
    for (i, v) in data.iter().enumerate() {
        flat.push(v.as_f64().with_context(|| format!("raw.data[{i}] is not a finite number"))?);
    }
    let raw_vars = raw.get("vars").and_then(Json::as_arr).context("`raw.vars` is required")?;
    let mut vars = Vec::with_capacity(raw_vars.len());
    for (i, v) in raw_vars.iter().enumerate() {
        let ctx = |f: &str| format!("raw.vars[{i}]: `{f}` is required");
        vars.push(Variable {
            name: v
                .get("name")
                .and_then(Json::as_str)
                .with_context(|| ctx("name"))?
                .to_string(),
            col_start: v.get("col_start").and_then(Json::as_u64).with_context(|| ctx("col_start"))?
                as usize,
            dim: v.get("dim").and_then(Json::as_u64).with_context(|| ctx("dim"))? as usize,
            discrete: v.get("discrete").and_then(Json::as_bool).with_context(|| ctx("discrete"))?,
            cardinality: v
                .get("cardinality")
                .and_then(Json::as_u64)
                .with_context(|| ctx("cardinality"))? as usize,
        });
    }
    // the variable blocks must tile the columns
    let mut seen = 0usize;
    for v in &vars {
        if v.dim == 0 || v.col_start != seen {
            bail!("raw.vars do not tile the columns (at `{}`)", v.name);
        }
        seen += v.dim;
    }
    if seen != cols {
        bail!("raw.vars cover {seen} columns, matrix has {cols}");
    }
    Ok(Dataset::new(Mat::from_vec(rows, cols, flat), vars))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::json;

    #[test]
    fn score_batch_roundtrips() {
        let spec = ShardSpec {
            dataset: "synth".into(),
            method: "cv-lr".into(),
            engine: "native".into(),
            lowrank: "rff".into(),
        };
        let reqs = vec![ScoreRequest::new(2, &[0, 1]), ScoreRequest::new(0, &[])];
        let body = score_batch_body(&spec, Some(3), Some(750), &reqs);
        let parsed = json::parse(&body.encode()).unwrap();
        let msg = parse_score_batch(&parsed).unwrap();
        assert_eq!(msg.spec, spec);
        assert_eq!(msg.version, Some(3));
        assert_eq!(msg.deadline_ms, Some(750));
        assert_eq!(msg.reqs, reqs);
        // absent deadline (old coordinator) decodes as unlimited
        let body = score_batch_body(&spec, None, None, &reqs);
        let msg = parse_score_batch(&json::parse(&body.encode()).unwrap()).unwrap();
        assert_eq!(msg.version, None);
        assert_eq!(msg.deadline_ms, None);
    }

    #[test]
    fn scores_roundtrip_bit_identical() {
        // adversarial f64s: shortest round-trip Display must preserve bits
        let scores = [-1234.567890123456789, 1e-300, -0.0, f64::MIN_POSITIVE, 2.0 / 3.0];
        let body = Json::obj(vec![(
            "scores",
            Json::Arr(scores.iter().map(|&s| Json::Num(s)).collect()),
        )]);
        let parsed = json::parse(&body.encode()).unwrap();
        let back = parse_scores(&parsed, scores.len()).unwrap();
        for (a, b) in scores.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(parse_scores(&parsed, 4).is_err(), "length mismatch must fail");
    }

    #[test]
    fn timings_roundtrip_and_tolerate_absence() {
        let events = vec![
            SpanEvent {
                name: "score-segment".into(),
                cat: "score".into(),
                ts_us: 120,
                dur_us: 4500,
                pid: 1,
                tid: 3,
                instant: false,
                id: 0,
                args: vec![("requests".into(), "64".into())],
            },
            SpanEvent {
                name: "re-pivot".into(),
                cat: "stream".into(),
                ts_us: 9000,
                dur_us: 0,
                pid: 1,
                tid: 3,
                instant: true,
                id: 0,
                args: Vec::new(),
            },
        ];
        let reply = Json::obj(vec![("scores", Json::Arr(vec![])), ("timings", timings_json(&events))]);
        let parsed = json::parse(&reply.encode()).unwrap();
        let back = parse_timings(&parsed);
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].name, "score-segment");
        assert_eq!((back[0].ts_us, back[0].dur_us, back[0].tid), (120, 4500, 3));
        assert!(!back[0].instant);
        assert!(back[1].instant);
        assert_eq!(back[0].pid, 0, "pid is re-assigned by the coordinator");
        // absent field (old follower) → empty, not an error
        let old = json::parse(r#"{"scores":[1.0],"version":2}"#).unwrap();
        assert!(parse_timings(&old).is_empty());
        // malformed entries are skipped, valid ones survive
        let mixed = json::parse(
            r#"{"timings":[{"cat":"x"},{"name":"ok","ts":5},"nonsense"]}"#,
        )
        .unwrap();
        let kept = parse_timings(&mixed);
        assert_eq!(kept.len(), 1);
        assert_eq!(kept[0].name, "ok");
    }

    #[test]
    fn raw_dataset_roundtrips_exactly() {
        let (ds, _) = crate::data::synth::generate(&crate::data::synth::SynthConfig {
            n: 40,
            seed: 11,
            ..Default::default()
        });
        let body = dataset_body("synth", &ds);
        let parsed = json::parse(&body.encode()).unwrap();
        let back = parse_raw_dataset(parsed.get("raw").unwrap()).unwrap();
        assert_eq!(back.data.rows, ds.data.rows);
        assert_eq!(back.data.cols, ds.data.cols);
        for (a, b) in ds.data.data.iter().zip(&back.data.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "raw push must be bit-exact");
        }
        assert_eq!(back.vars.len(), ds.vars.len());
        for (a, b) in ds.vars.iter().zip(&back.vars) {
            assert_eq!((a.col_start, a.dim, a.discrete, a.cardinality),
                       (b.col_start, b.dim, b.discrete, b.cardinality));
        }
    }

    #[test]
    fn raw_dataset_rejects_bad_shapes() {
        let (ds, _) = crate::data::synth::generate(&crate::data::synth::SynthConfig {
            n: 5,
            seed: 1,
            ..Default::default()
        });
        let body = dataset_body("x", &ds);
        let raw = body.get("raw").unwrap();
        // truncate the data array
        if let Json::Obj(kvs) = raw {
            let mut kvs = kvs.clone();
            for (k, v) in &mut kvs {
                if k == "data" {
                    if let Json::Arr(xs) = v {
                        xs.pop();
                    }
                }
            }
            assert!(parse_raw_dataset(&Json::Obj(kvs)).is_err());
        } else {
            panic!("raw must be an object");
        }
    }
}
