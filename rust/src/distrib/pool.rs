//! The follower pool: per-follower health and latency tracking.
//!
//! Each follower carries a tiny circuit breaker driven by three
//! signals:
//!
//! * **EWMA latency** — an exponentially-weighted moving average of
//!   successful request latency (α = 0.2). It feeds the hedge delay:
//!   a sub-batch still in flight after `hedge_mult ×` the follower's
//!   EWMA (floored at `hedge_floor`) is re-dispatched elsewhere.
//! * **Consecutive-failure trip wire** — `trip_failures` failures in a
//!   row take the follower out of rotation.
//! * **Periodic re-probe** — after `reprobe_after`, a tripped follower
//!   is handed exactly one half-open probe; success rejoins it,
//!   failure re-arms the trip timer.
//!
//! Retries back off exponentially with multiplicative jitter drawn
//! from the crate's own seeded [`Pcg64`] — deterministic per pool,
//! no dependency on wall-clock entropy.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::score::{FollowerStat, ShardCounters};
use crate::util::lockorder::Mutex;
use crate::util::{Backoff, Pcg64};

use super::client::ShardClient;

/// EWMA smoothing factor for latency samples.
const EWMA_ALPHA: f64 = 0.2;

/// Knobs of the shard dispatch layer. The defaults suit LAN followers;
/// tests shrink the timeouts to keep failure paths fast.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    /// Per-request socket timeout (connect, read, write each).
    pub timeout: Duration,
    /// Re-dispatch attempts after the first failure of a sub-batch.
    pub max_retries: u32,
    /// Base of the exponential retry backoff.
    pub backoff: Duration,
    /// Backoff ceiling.
    pub backoff_cap: Duration,
    /// Minimum time before a straggler sub-batch is hedged.
    pub hedge_floor: Duration,
    /// Hedge a sub-batch once it exceeds this multiple of the
    /// follower's EWMA latency (subject to `hedge_floor`).
    pub hedge_mult: f64,
    /// Consecutive failures that trip a follower unhealthy.
    pub trip_failures: u32,
    /// How long a tripped follower sits out before a half-open probe.
    pub reprobe_after: Duration,
    /// Batches smaller than this score locally — the wire overhead
    /// beats the fan-out win.
    pub min_remote: usize,
    /// Seed of the jitter generator (deterministic backoff schedule).
    pub seed: u64,
    /// Response-body cap for follower replies (bytes).
    pub body_cap: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            timeout: Duration::from_secs(10),
            max_retries: 2,
            backoff: Duration::from_millis(50),
            backoff_cap: Duration::from_secs(1),
            hedge_floor: Duration::from_millis(300),
            hedge_mult: 4.0,
            trip_failures: 3,
            reprobe_after: Duration::from_secs(2),
            min_remote: 8,
            seed: 0x5eed,
            body_cap: super::client::DEFAULT_BODY_CAP,
        }
    }
}

/// The health half of a follower, as a pure state machine (time is
/// injected, so the trip wire and re-probe logic are unit-testable
/// without sleeping).
#[derive(Debug)]
pub(crate) struct Health {
    ewma_ms: f64,
    consecutive_failures: u32,
    /// When the trip wire fired; `None` while healthy.
    tripped_at: Option<Instant>,
    /// When the current half-open probe was granted; no further
    /// traffic until it resolves — or until `reprobe_after` passes
    /// without a resolution, at which point a fresh probe is granted.
    /// (A granted probe only resolves if the dispatch layer actually
    /// routes a request to this follower; under light or hedged
    /// traffic it may never do so, and a plain `bool` here left the
    /// follower out of rotation *forever*. Time-stamping the grant
    /// makes the probe self-healing.)
    probing_since: Option<Instant>,
}

impl Health {
    fn new() -> Health {
        Health { ewma_ms: 0.0, consecutive_failures: 0, tripped_at: None, probing_since: None }
    }

    pub(crate) fn on_success(&mut self, ms: f64) {
        self.ewma_ms =
            if self.ewma_ms == 0.0 { ms } else { (1.0 - EWMA_ALPHA) * self.ewma_ms + EWMA_ALPHA * ms };
        self.consecutive_failures = 0;
        self.tripped_at = None;
        self.probing_since = None;
    }

    /// Returns true when this failure tripped the wire.
    pub(crate) fn on_failure(&mut self, trip_failures: u32, now: Instant) -> bool {
        self.consecutive_failures = self.consecutive_failures.saturating_add(1);
        if self.probing_since.is_some() {
            // failed half-open probe: re-arm the full sit-out
            self.probing_since = None;
            self.tripped_at = Some(now);
            return false;
        }
        if self.tripped_at.is_none() && self.consecutive_failures >= trip_failures {
            self.tripped_at = Some(now);
            return true;
        }
        false
    }

    /// May this follower take traffic at `now`? Grants one half-open
    /// probe per `reprobe_after` while tripped; an unresolved grant
    /// (no success/failure recorded) expires after another
    /// `reprobe_after` and is re-issued rather than starving the
    /// follower out of rotation.
    pub(crate) fn available(&mut self, reprobe_after: Duration, now: Instant) -> bool {
        let Some(tripped) = self.tripped_at else {
            return true;
        };
        match self.probing_since {
            None if now.duration_since(tripped) >= reprobe_after => {
                self.probing_since = Some(now);
                true
            }
            Some(granted) if now.duration_since(granted) >= reprobe_after => {
                self.probing_since = Some(now);
                true
            }
            _ => false,
        }
    }

    pub(crate) fn healthy(&self) -> bool {
        self.tripped_at.is_none()
    }

    pub(crate) fn ewma_ms(&self) -> f64 {
        self.ewma_ms
    }
}

/// One follower `cvlr serve` process: its persistent client, health
/// state, counters, and the pinned registry version of the pushed
/// dataset.
pub struct Follower {
    pub client: ShardClient,
    pub(crate) health: Mutex<Health>,
    /// Follower-side registry version of the coordinator's dataset,
    /// set by auto-registration; `None` until the first push.
    pub version: Mutex<Option<u64>>,
    pub dispatches: AtomicU64,
    pub successes: AtomicU64,
    pub failures: AtomicU64,
    pub retries: AtomicU64,
    pub hedges: AtomicU64,
    pub degraded: AtomicU64,
}

impl Follower {
    fn new(addr: &str, timeout: Duration, body_cap: usize) -> Follower {
        let mut client = ShardClient::new(addr, timeout);
        client.set_body_cap(body_cap);
        Follower {
            client,
            health: Mutex::new("pool.health", Health::new()),
            version: Mutex::new("pool.version", None),
            dispatches: AtomicU64::new(0),
            successes: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            hedges: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
        }
    }

    pub fn addr(&self) -> &str {
        self.client.addr()
    }

    fn stat(&self) -> FollowerStat {
        let h = self.health.lock();
        FollowerStat {
            addr: self.addr().to_string(),
            healthy: h.healthy(),
            ewma_ms: h.ewma_ms(),
            dispatches: self.dispatches.load(Ordering::Relaxed),
            successes: self.successes.load(Ordering::Relaxed),
            failures: self.failures.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            hedges: self.hedges.load(Ordering::Relaxed),
            degraded: self.degraded.load(Ordering::Relaxed),
        }
    }
}

/// The follower fleet of one sharding backend.
pub struct FollowerPool {
    followers: Vec<Arc<Follower>>,
    pub cfg: PoolConfig,
    rng: Mutex<Pcg64>,
    /// Local fallbacks not attributable to one follower (whole batches
    /// degraded because no follower was available).
    pub unattributed_degraded: AtomicU64,
}

impl FollowerPool {
    pub fn new(addrs: &[String], cfg: PoolConfig) -> FollowerPool {
        let followers = addrs
            .iter()
            .map(|a| Arc::new(Follower::new(a, cfg.timeout, cfg.body_cap)))
            .collect();
        let rng = Mutex::new("pool.rng", Pcg64::new(cfg.seed));
        FollowerPool { followers, cfg, rng, unattributed_degraded: AtomicU64::new(0) }
    }

    pub fn len(&self) -> usize {
        self.followers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.followers.is_empty()
    }

    /// Followers allowed to take traffic now: the healthy ones plus at
    /// most one half-open probe per tripped follower.
    pub fn available(&self) -> Vec<Arc<Follower>> {
        let now = Instant::now();
        self.followers
            .iter()
            .filter(|f| f.health.lock().available(self.cfg.reprobe_after, now))
            .cloned()
            .collect()
    }

    /// A healthy follower other than `not` (for retries and hedges).
    /// Deliberately skips half-open probes: a retry landing on a
    /// follower that just tripped would likely fail again.
    pub fn pick_other(&self, not: &str) -> Option<Arc<Follower>> {
        self.followers
            .iter()
            .find(|f| f.addr() != not && f.health.lock().healthy())
            .cloned()
    }

    /// Record a successful request and its latency.
    pub fn success(&self, f: &Follower, elapsed: Duration) {
        f.successes.fetch_add(1, Ordering::Relaxed);
        f.health.lock().on_success(elapsed.as_secs_f64() * 1e3);
    }

    /// Record a failed request; trips the wire after
    /// `trip_failures` consecutive failures.
    pub fn failure(&self, f: &Follower) {
        f.failures.fetch_add(1, Ordering::Relaxed);
        crate::obs::metrics::shard_failures_total().inc();
        f.health.lock().on_failure(self.cfg.trip_failures, Instant::now());
    }

    /// Jittered exponential backoff before retry `attempt` (1-based),
    /// via the crate-wide [`Backoff`] policy: `backoff × 2^(attempt−1)`,
    /// capped, scaled by a uniform factor in [0.5, 1). Jitter comes
    /// from the pool's seeded generator.
    pub fn backoff(&self, attempt: u32) -> Duration {
        Backoff::new(self.cfg.backoff, self.cfg.backoff_cap)
            .delay(attempt, &mut self.rng.lock())
    }

    /// How long to wait on `f` before hedging a sub-batch elsewhere.
    pub fn hedge_delay(&self, f: &Follower) -> Duration {
        let ewma = f.health.lock().ewma_ms();
        let by_latency = Duration::from_secs_f64(self.cfg.hedge_mult * ewma / 1e3);
        by_latency.max(self.cfg.hedge_floor)
    }

    /// Aggregate dispatch counters across the fleet.
    pub fn counters(&self) -> ShardCounters {
        let mut c = ShardCounters {
            degraded: self.unattributed_degraded.load(Ordering::Relaxed),
            ..ShardCounters::default()
        };
        for f in &self.followers {
            c.dispatches += f.dispatches.load(Ordering::Relaxed);
            c.retries += f.retries.load(Ordering::Relaxed);
            c.hedges += f.hedges.load(Ordering::Relaxed);
            c.degraded += f.degraded.load(Ordering::Relaxed);
        }
        c
    }

    /// Per-follower snapshots for `/v1/stats`.
    pub fn snapshots(&self) -> Vec<FollowerStat> {
        self.followers.iter().map(|f| f.stat()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(base: Instant, ms: u64) -> Instant {
        base + Duration::from_millis(ms)
    }

    #[test]
    fn trip_wire_fires_after_consecutive_failures() {
        let base = Instant::now();
        let mut h = Health::new();
        assert!(!h.on_failure(3, t(base, 0)));
        assert!(!h.on_failure(3, t(base, 1)));
        assert!(h.healthy());
        assert!(h.on_failure(3, t(base, 2)), "third consecutive failure trips");
        assert!(!h.healthy());
        // a success anywhere in between resets the count
        let mut h = Health::new();
        assert!(!h.on_failure(3, t(base, 0)));
        assert!(!h.on_failure(3, t(base, 1)));
        h.on_success(5.0);
        assert!(!h.on_failure(3, t(base, 2)));
        assert!(h.healthy(), "success resets the consecutive count");
    }

    #[test]
    fn tripped_follower_reprobes_half_open() {
        let base = Instant::now();
        let reprobe = Duration::from_millis(100);
        let mut h = Health::new();
        for i in 0..3 {
            h.on_failure(3, t(base, i));
        }
        assert!(!h.available(reprobe, t(base, 50)), "sits out before reprobe_after");
        assert!(h.available(reprobe, t(base, 150)), "half-open probe granted");
        assert!(!h.available(reprobe, t(base, 151)), "only ONE probe until it resolves");
        // failed probe re-arms the sit-out from the failure time
        h.on_failure(3, t(base, 160));
        assert!(!h.available(reprobe, t(base, 200)));
        assert!(h.available(reprobe, t(base, 270)), "probe granted again after re-arm");
        // successful probe fully rejoins
        h.on_success(7.0);
        assert!(h.healthy());
        assert!(h.available(reprobe, t(base, 271)));
        assert!(h.available(reprobe, t(base, 272)), "healthy follower has no probe budget");
    }

    #[test]
    fn unresolved_probe_regrants_instead_of_starving() {
        // Regression: `available` used to set a plain `probing` flag
        // when granting the half-open probe. If dispatch never routed
        // a request to the follower (light traffic, hedges landing
        // elsewhere), no on_success/on_failure ever cleared the flag
        // and the follower stayed out of rotation permanently. The
        // grant is now time-stamped and expires after `reprobe_after`.
        let base = Instant::now();
        let reprobe = Duration::from_millis(100);
        let mut h = Health::new();
        for i in 0..3 {
            h.on_failure(3, t(base, i));
        }
        assert!(h.available(reprobe, t(base, 150)), "probe granted");
        assert!(!h.available(reprobe, t(base, 200)), "grant still pending");
        assert!(
            h.available(reprobe, t(base, 260)),
            "unresolved grant expires after reprobe_after and is re-issued"
        );
        assert!(!h.available(reprobe, t(base, 261)), "…as a single probe again");
        // and the re-issued probe resolves normally
        h.on_success(3.0);
        assert!(h.healthy());
    }

    #[test]
    fn ewma_tracks_latency() {
        let mut h = Health::new();
        h.on_success(100.0);
        assert_eq!(h.ewma_ms(), 100.0, "first sample seeds the average");
        h.on_success(50.0);
        assert!((h.ewma_ms() - 90.0).abs() < 1e-12, "0.8·100 + 0.2·50");
    }

    #[test]
    fn backoff_is_bounded_and_grows() {
        let pool = FollowerPool::new(
            &["127.0.0.1:1".to_string()],
            PoolConfig {
                backoff: Duration::from_millis(50),
                backoff_cap: Duration::from_millis(400),
                ..Default::default()
            },
        );
        for attempt in 1..=8u32 {
            let nominal = Duration::from_millis(50 * (1 << (attempt - 1).min(10)) as u64)
                .min(Duration::from_millis(400));
            for _ in 0..32 {
                let d = pool.backoff(attempt);
                assert!(d >= nominal / 2, "attempt {attempt}: {d:?} below jitter floor");
                assert!(d <= nominal, "attempt {attempt}: {d:?} above cap");
            }
        }
    }

    #[test]
    fn hedge_delay_follows_ewma_with_floor() {
        let pool = FollowerPool::new(
            &["127.0.0.1:1".to_string()],
            PoolConfig {
                hedge_floor: Duration::from_millis(300),
                hedge_mult: 4.0,
                ..Default::default()
            },
        );
        let avail = pool.available();
        let f = &avail[0];
        assert_eq!(pool.hedge_delay(f), Duration::from_millis(300), "no sample: floor");
        pool.success(f, Duration::from_millis(200));
        assert_eq!(pool.hedge_delay(f), Duration::from_millis(800), "4 × 200ms EWMA");
    }

    #[test]
    fn pick_other_skips_unhealthy_and_self() {
        let pool = FollowerPool::new(
            &["127.0.0.1:1".to_string(), "127.0.0.1:2".to_string()],
            PoolConfig { trip_failures: 1, ..Default::default() },
        );
        let avail = pool.available();
        let (a, b) = (avail[0].clone(), avail[1].clone());
        assert_eq!(pool.pick_other(a.addr()).unwrap().addr(), b.addr());
        pool.failure(&b); // trips at 1
        assert!(pool.pick_other(a.addr()).is_none(), "tripped follower is skipped");
        assert_eq!(pool.pick_other(b.addr()).unwrap().addr(), a.addr());
    }
}
