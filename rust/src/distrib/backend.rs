//! [`ShardScoreBackend`]: a [`ScoreBackend`] that partitions each score
//! batch across the follower fleet.
//!
//! The shape of one `score_batch` call:
//!
//! 1. Batches below `min_remote`, or with no follower available, score
//!    **locally** on the wrapped backend — same bits, no wire.
//! 2. Otherwise the batch splits into contiguous sub-batches, one per
//!    available follower, and a detached *controller* thread drives
//!    each: a primary lane posts the sub-batch; if nothing lands within
//!    the follower's hedge delay, a **hedge lane** re-dispatches the
//!    same sub-batch to another healthy follower (first reply wins);
//!    failed lanes retry with jittered backoff, hopping followers.
//! 3. A controller whose lanes all die **degrades**: it scores its
//!    sub-batch on the local backend. Every path produces scores, so
//!    one slow or dead follower can never stall a sweep — and every
//!    path computes the identical CV fold algebra on the identical
//!    sample matrix (the raw dataset push is bit-exact, the JSON codec
//!    transports f64 bit-exact), so the result is byte-for-byte the
//!    scores a local run yields.
//!
//! Lane threads are never joined — a lane wedged in a socket read
//! (bounded by the socket timeout anyway) cannot hold the sweep
//! hostage. The controller waits on a channel with deadlines instead.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::data::Dataset;
use crate::obs::{fail, metrics, trace};
use crate::score::{FollowerStat, ScoreBackend, ScoreRequest, ShardCounters};
use crate::server::json::Json;
use crate::util::lockorder::Mutex;
use crate::util::Budget;

use super::pool::{Follower, FollowerPool, PoolConfig};
use super::wire::{self, ShardSpec};

/// Contiguous partition of `n` items into `k` parts whose sizes differ
/// by at most one: the lengths of the parts, in order.
pub fn partition(n: usize, k: usize) -> Vec<usize> {
    assert!(k >= 1, "partition needs at least one part");
    let base = n / k;
    let rem = n % k;
    (0..k).map(|i| base + usize::from(i < rem)).collect()
}

/// Shared state of one sharding backend: the local fallback, the
/// follower pool, the spec stamped on every request, and the prebuilt
/// raw dataset push for auto-registration.
struct ShardInner {
    local: Arc<dyn ScoreBackend>,
    pool: FollowerPool,
    spec: ShardSpec,
    /// `POST /v1/datasets` body (raw mode) pushing the coordinator's
    /// dataset to a follower that does not have it yet.
    push: Json,
    /// The deadline budget the current run/job executes under; re-armed
    /// per run via [`ScoreBackend::set_budget`] (pooled services
    /// outlive one job). Copy-cheap, read at every dispatch decision.
    budget: Mutex<Budget>,
}

impl ShardInner {
    fn budget(&self) -> Budget {
        *self.budget.lock()
    }
}

/// The coordinator-side sharding backend. Cheap to clone (all state is
/// behind one `Arc`), so the `ScoreService` and job pool can share it.
pub struct ShardScoreBackend {
    inner: Arc<ShardInner>,
}

impl ShardScoreBackend {
    /// Wrap `local`, sharding batches across `shards` (host:port). The
    /// spec names what followers must resolve: the dataset (pushed on
    /// demand in raw coordinates) and the method/engine/lowrank triple.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        local: Arc<dyn ScoreBackend>,
        ds: &Dataset,
        dataset: &str,
        method: &str,
        engine: &str,
        lowrank: &str,
        shards: &[String],
        cfg: PoolConfig,
    ) -> ShardScoreBackend {
        let spec = ShardSpec {
            dataset: dataset.to_string(),
            method: method.to_string(),
            engine: engine.to_string(),
            lowrank: lowrank.to_string(),
        };
        let push = wire::dataset_body(dataset, ds);
        let pool = FollowerPool::new(shards, cfg);
        ShardScoreBackend {
            inner: Arc::new(ShardInner {
                local,
                pool,
                spec,
                push,
                budget: Mutex::new("distrib.budget", Budget::none()),
            }),
        }
    }
}

impl ScoreBackend for ShardScoreBackend {
    fn score_batch(&self, reqs: &[ScoreRequest]) -> Vec<f64> {
        let inner = &self.inner;
        // an exhausted deadline can't afford wire round-trips: the
        // local path is the fastest remaining route to exact scores
        // (the caller's chunked cancel loop turns the expiry into a
        // typed error; this layer only guarantees "never hang")
        if inner.budget().expired() {
            if !inner.pool.is_empty() && !reqs.is_empty() {
                inner.pool.unattributed_degraded.fetch_add(1, Ordering::Relaxed);
                metrics::shard_degraded_total().inc();
            }
            return inner.local.score_batch(reqs);
        }
        let avail = inner.pool.available();
        if reqs.len() < inner.pool.cfg.min_remote || avail.is_empty() {
            if avail.is_empty() && !inner.pool.is_empty() && !reqs.is_empty() {
                inner.pool.unattributed_degraded.fetch_add(1, Ordering::Relaxed);
                metrics::shard_degraded_total().inc();
            }
            return inner.local.score_batch(reqs);
        }
        // per-coordinator sharded-batch id, stamped on the batch span
        // and every dispatch span so follower timings attribute back
        static NEXT_BATCH: AtomicU64 = AtomicU64::new(1);
        let batch_id = NEXT_BATCH.fetch_add(1, Ordering::Relaxed);
        let k = avail.len().min(reqs.len());
        let _span = trace::span("shard-batch", "distrib")
            .arg("batch", batch_id.to_string())
            .arg("requests", reqs.len().to_string())
            .arg("shards", k.to_string());
        let parts = partition(reqs.len(), k);
        let (tx, rx) = mpsc::channel::<(usize, Vec<f64>)>();
        let mut offset = 0usize;
        for (i, &len) in parts.iter().enumerate() {
            let sub: Arc<Vec<ScoreRequest>> = Arc::new(reqs[offset..offset + len].to_vec());
            offset += len;
            let follower = avail[i].clone();
            let inner = self.inner.clone();
            let tx = tx.clone();
            std::thread::Builder::new()
                .name("cvlr-shard-ctl".to_string())
                .spawn(move || {
                    let scores = run_shard(&inner, follower, sub);
                    let _ = tx.send((i, scores));
                })
                .expect("spawning shard controller");
        }
        drop(tx);
        let mut out: Vec<Option<Vec<f64>>> = (0..k).map(|_| None).collect();
        while let Ok((i, scores)) = rx.recv() {
            out[i] = Some(scores);
        }
        // a controller that panicked never sent: fill its part locally
        // (belt and braces — run_shard itself degrades on lane failure)
        let mut result = Vec::with_capacity(reqs.len());
        let mut offset = 0usize;
        for (i, &len) in parts.iter().enumerate() {
            match out[i].take() {
                Some(s) => result.extend(s),
                None => {
                    inner.pool.unattributed_degraded.fetch_add(1, Ordering::Relaxed);
                    metrics::shard_degraded_total().inc();
                    result.extend(inner.local.score_batch(&reqs[offset..offset + len]));
                }
            }
            offset += len;
        }
        result
    }

    fn num_vars(&self) -> usize {
        self.inner.local.num_vars()
    }

    fn core_cache_stats(&self) -> Option<(u64, u64)> {
        self.inner.local.core_cache_stats()
    }

    fn core_cache_bytes(&self) -> Option<u64> {
        self.inner.local.core_cache_bytes()
    }

    fn shard_counters(&self) -> Option<ShardCounters> {
        Some(self.inner.pool.counters())
    }

    fn follower_stats(&self) -> Vec<FollowerStat> {
        self.inner.pool.snapshots()
    }

    fn set_budget(&self, budget: Budget) {
        *self.inner.budget.lock() = budget;
        self.inner.local.set_budget(budget);
    }
}

/// Drive one sub-batch to completion: primary lane, hedge lane on
/// straggle, local fallback when every lane dies. Always returns
/// scores.
fn run_shard(
    inner: &Arc<ShardInner>,
    assigned: Arc<Follower>,
    reqs: Arc<Vec<ScoreRequest>>,
) -> Vec<f64> {
    let cfg = &inner.pool.cfg;
    // every lane is bounded: ≤ max_retries+1 attempts, each ≤ roughly
    // 3 socket timeouts (connect/write/read) + one capped backoff —
    // further clamped by whatever end-to-end deadline budget remains
    let lane_budget = (cfg.timeout * 3 + cfg.backoff_cap) * (cfg.max_retries + 1);
    let mut deadline = Instant::now() + lane_budget;
    if let Some(d) = inner.budget().deadline() {
        deadline = deadline.min(d);
    }
    let (tx, rx) = mpsc::channel::<Option<Vec<f64>>>();
    spawn_lane(inner, assigned.clone(), reqs.clone(), tx.clone());
    let mut lanes = 1usize;
    let mut finished = 0usize;
    let mut hedged = false;
    loop {
        let remaining = deadline.saturating_duration_since(Instant::now());
        let wait = if hedged { remaining } else { inner.pool.hedge_delay(&assigned).min(remaining) };
        match rx.recv_timeout(wait) {
            Ok(Some(scores)) => return scores,
            Ok(None) => {
                finished += 1;
                if finished == lanes {
                    break;
                }
            }
            Err(RecvTimeoutError::Timeout) if !hedged => {
                // the primary is straggling: re-dispatch the same
                // sub-batch to another healthy follower, first wins
                hedged = true;
                assigned.hedges.fetch_add(1, Ordering::Relaxed);
                metrics::shard_hedges_total().inc();
                trace::instant(
                    "shard-hedge",
                    "distrib",
                    vec![("follower".to_string(), assigned.addr().to_string())],
                );
                if let Some(other) = inner.pool.pick_other(assigned.addr()) {
                    spawn_lane(inner, other, reqs.clone(), tx.clone());
                    lanes += 1;
                }
            }
            Err(_) => break, // overall deadline or all senders gone
        }
    }
    assigned.degraded.fetch_add(1, Ordering::Relaxed);
    metrics::shard_degraded_total().inc();
    trace::instant(
        "shard-degrade",
        "distrib",
        vec![("follower".to_string(), assigned.addr().to_string())],
    );
    inner.local.score_batch(&reqs)
}

/// Detached dispatch lane: up to `max_retries` re-attempts with
/// jittered backoff, hopping to another healthy follower when one is
/// free. Sends `Some(scores)` on success, `None` when exhausted.
fn spawn_lane(
    inner: &Arc<ShardInner>,
    follower: Arc<Follower>,
    reqs: Arc<Vec<ScoreRequest>>,
    tx: mpsc::Sender<Option<Vec<f64>>>,
) {
    let inner = inner.clone();
    let _ = std::thread::Builder::new().name("cvlr-shard-lane".to_string()).spawn(move || {
        let mut f = follower;
        for attempt in 0..=inner.pool.cfg.max_retries {
            if attempt > 0 {
                let pause = inner.pool.backoff(attempt);
                // a retry is only worth its backoff plus the candidate
                // follower's expected latency; when the remaining
                // budget can't cover that, stop burning it and let the
                // controller degrade to local scoring
                let expected =
                    Duration::from_secs_f64(f.health.lock().ewma_ms() / 1e3);
                if !inner.budget().covers(pause + expected) {
                    break;
                }
                f.retries.fetch_add(1, Ordering::Relaxed);
                metrics::shard_retries_total().inc();
                trace::instant(
                    "shard-retry",
                    "distrib",
                    vec![("attempt".to_string(), attempt.to_string())],
                );
                std::thread::sleep(pause);
                if let Some(other) = inner.pool.pick_other(f.addr()) {
                    f = other;
                }
            }
            match score_on(&inner, &f, &reqs) {
                Ok(scores) => {
                    let _ = tx.send(Some(scores));
                    return;
                }
                Err(_) => inner.pool.failure(&f),
            }
        }
        let _ = tx.send(None);
    });
}

/// One scoring attempt against one follower: auto-register the dataset
/// when this follower has no pinned version, post the sub-batch, and on
/// a 404/409 (dataset unknown / version drift after a follower restart)
/// re-push and retry once.
fn score_on(inner: &ShardInner, f: &Follower, reqs: &[ScoreRequest]) -> Result<Vec<f64>> {
    f.dispatches.fetch_add(1, Ordering::Relaxed);
    metrics::shard_dispatches_total().inc();
    let _span = trace::span("shard-dispatch", "distrib").arg("follower", f.addr());
    let budget = inner.budget();
    let pinned = *f.version.lock();
    let version = match pinned {
        Some(v) => v,
        None => register(inner, f)?,
    };
    let body = dispatch_body(inner, version, reqs, budget)?;
    let t0 = Instant::now();
    let (status, resp) = f.client.post_within("/v1/score_batch", &body, budget)?;
    let (status, resp, t0) = if status == 404 || status == 409 {
        // the follower restarted or its registry moved on: pause one
        // jittered backoff step, re-push the dataset, retry once
        std::thread::sleep(budget.clamp(inner.pool.backoff(1)));
        let v = register(inner, f)?;
        let body = dispatch_body(inner, v, reqs, budget)?;
        let t1 = Instant::now();
        let (s, r) = f.client.post_within("/v1/score_batch", &body, budget)?;
        (s, r, t1)
    } else {
        (status, resp, t0)
    };
    if status != 200 {
        let msg = resp.get("error").and_then(Json::as_str).unwrap_or("").to_string();
        bail!("follower {} answered {status} {msg}", f.addr());
    }
    let resp = match fail::hit("distrib.reply") {
        Some(fail::Hit::Error) => return Err(fail::injected_error("distrib.reply")),
        // a corrupt reply must fail the length-checked decode below,
        // driving the same retry → degrade path a garbled wire would
        Some(fail::Hit::Corrupt) => Json::obj(vec![("scores", Json::Arr(Vec::new()))]),
        None => resp,
    };
    let scores = wire::parse_scores(&resp, reqs.len())
        .with_context(|| format!("bad scores from {}", f.addr()))?;
    inner.pool.success(f, t0.elapsed());
    // fold the follower's own span timings (optional reply field; absent
    // from old followers) into this coordinator's trace, re-based to the
    // dispatch wall clock and attributed to a per-follower synthetic pid
    if trace::is_enabled() {
        let base = trace::instant_us(t0);
        let pid = trace::remote_pid(f.addr());
        for mut ev in wire::parse_timings(&resp) {
            ev.ts_us += base;
            ev.pid = pid;
            trace::record_remote(ev);
        }
    }
    Ok(scores)
}

/// Build one dispatch body, stamped with the remaining deadline budget
/// so the follower cancels cooperatively. The `distrib.dispatch`
/// failpoint intercepts here: `error` fails the attempt outright,
/// `corrupt` substitutes a payload the follower must reject.
fn dispatch_body(
    inner: &ShardInner,
    version: u64,
    reqs: &[ScoreRequest],
    budget: Budget,
) -> Result<Json> {
    match fail::hit("distrib.dispatch") {
        Some(fail::Hit::Error) => Err(fail::injected_error("distrib.dispatch")),
        Some(fail::Hit::Corrupt) => Ok(Json::str("corrupt-request")),
        None => Ok(wire::score_batch_body(&inner.spec, Some(version), budget.remaining_ms(), reqs)),
    }
}

/// Push the coordinator's dataset (raw coordinates) to `f` and pin the
/// registry version the follower assigned.
fn register(inner: &ShardInner, f: &Follower) -> Result<u64> {
    let corrupt;
    let push = match fail::hit("wire.dataset_push") {
        Some(fail::Hit::Error) => return Err(fail::injected_error("wire.dataset_push")),
        Some(fail::Hit::Corrupt) => {
            corrupt = Json::str("corrupt-dataset");
            &corrupt
        }
        None => &inner.push,
    };
    let (status, resp) = f.client.post_within("/v1/datasets", push, inner.budget())?;
    if status != 200 && status != 201 {
        let msg = resp.get("error").and_then(Json::as_str).unwrap_or("").to_string();
        bail!("follower {} rejected dataset push: {status} {msg}", f.addr());
    }
    let v = resp
        .get("version")
        .and_then(Json::as_u64)
        .with_context(|| format!("follower {} returned no dataset version", f.addr()))?;
    *f.version.lock() = Some(v);
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::score::{LocalScore, ScalarBackend};

    #[test]
    fn partition_is_contiguous_and_balanced() {
        assert_eq!(partition(10, 3), vec![4, 3, 3]);
        assert_eq!(partition(9, 3), vec![3, 3, 3]);
        assert_eq!(partition(2, 3), vec![1, 1, 0]);
        assert_eq!(partition(0, 2), vec![0, 0]);
        for n in 0..40usize {
            for k in 1..8usize {
                let parts = partition(n, k);
                assert_eq!(parts.len(), k);
                assert_eq!(parts.iter().sum::<usize>(), n);
                let lo = parts.iter().min().unwrap();
                let hi = parts.iter().max().unwrap();
                assert!(hi - lo <= 1, "n={n} k={k}: sizes differ by more than one");
            }
        }
    }

    struct Toy;
    impl LocalScore for Toy {
        fn local_score(&self, target: usize, parents: &[usize]) -> f64 {
            -(target as f64) - 0.25 * parents.len() as f64
        }
        fn num_vars(&self) -> usize {
            6
        }
    }

    /// Followers that do not exist: every dispatch fails fast
    /// (connection refused), every sub-batch degrades to local, and the
    /// result is bit-identical to the wrapped backend.
    #[test]
    fn degrades_to_local_when_followers_are_dead() {
        let (ds, _) = crate::data::synth::generate(&crate::data::synth::SynthConfig {
            n: 10,
            seed: 3,
            ..Default::default()
        });
        let local: Arc<dyn ScoreBackend> = Arc::new(ScalarBackend(Toy));
        let cfg = PoolConfig {
            timeout: Duration::from_millis(200),
            max_retries: 1,
            backoff: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(2),
            hedge_floor: Duration::from_millis(50),
            min_remote: 1,
            trip_failures: 2,
            ..Default::default()
        };
        // port 9 (discard) on localhost is closed: connect is refused
        let shards = vec!["127.0.0.1:9".to_string(), "127.0.0.1:9".to_string()];
        let backend =
            ShardScoreBackend::new(
                local.clone(),
                &ds,
                "toy",
                "cv-lr",
                "native",
                "icl",
                &shards,
                cfg,
            );
        let reqs: Vec<ScoreRequest> =
            (0..6).map(|t| ScoreRequest::new(t, &[(t + 1) % 6])).collect();
        let want = local.score_batch(&reqs);
        let got = backend.score_batch(&reqs);
        assert_eq!(want.len(), got.len());
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.to_bits(), b.to_bits(), "degraded scores must be bit-identical");
        }
        let c = backend.shard_counters().unwrap();
        assert!(c.degraded > 0, "dead followers must register as degradation");
        assert!(c.dispatches > 0, "the fleet was tried before degrading");
        // once tripped, later batches go straight to local
        let got2 = backend.score_batch(&reqs);
        for (a, b) in want.iter().zip(&got2) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(!backend.follower_stats().iter().any(|f| f.healthy), "both should be tripped");
    }

    /// An expired deadline budget never touches the wire: the batch
    /// degrades straight to local scoring, bit-identical, without
    /// paying connect timeouts first.
    #[test]
    fn expired_budget_degrades_without_dispatch() {
        let (ds, _) = crate::data::synth::generate(&crate::data::synth::SynthConfig {
            n: 10,
            seed: 3,
            ..Default::default()
        });
        let local: Arc<dyn ScoreBackend> = Arc::new(ScalarBackend(Toy));
        let cfg = PoolConfig { min_remote: 1, ..Default::default() };
        let shards = vec!["127.0.0.1:9".to_string()];
        let backend =
            ShardScoreBackend::new(
                local.clone(),
                &ds,
                "toy",
                "cv-lr",
                "native",
                "icl",
                &shards,
                cfg,
            );
        backend.set_budget(Budget::until(Instant::now() - Duration::from_millis(5)));
        let reqs: Vec<ScoreRequest> =
            (0..6).map(|t| ScoreRequest::new(t, &[(t + 1) % 6])).collect();
        let want = local.score_batch(&reqs);
        let got = backend.score_batch(&reqs);
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.to_bits(), b.to_bits(), "degraded scores must be bit-identical");
        }
        let c = backend.shard_counters().unwrap();
        assert_eq!(c.dispatches, 0, "an expired budget must skip the wire entirely");
        assert!(c.degraded > 0, "deadline-driven local scoring counts as degradation");
        // re-arming the budget restores normal dispatch policy
        backend.set_budget(Budget::none());
        let got2 = backend.score_batch(&reqs);
        assert_eq!(got2.len(), reqs.len());
        assert!(backend.shard_counters().unwrap().dispatches > 0);
    }

    /// Tiny batches never touch the wire.
    #[test]
    fn small_batches_score_locally() {
        let (ds, _) = crate::data::synth::generate(&crate::data::synth::SynthConfig {
            n: 10,
            seed: 3,
            ..Default::default()
        });
        let local: Arc<dyn ScoreBackend> = Arc::new(ScalarBackend(Toy));
        let cfg = PoolConfig { min_remote: 8, ..Default::default() };
        let shards = vec!["127.0.0.1:9".to_string()];
        let backend =
            ShardScoreBackend::new(local, &ds, "toy", "cv-lr", "native", "icl", &shards, cfg);
        let reqs = vec![ScoreRequest::new(1, &[0])];
        let got = backend.score_batch(&reqs);
        assert_eq!(got, vec![-1.25]);
        let c = backend.shard_counters().unwrap();
        assert_eq!(c.dispatches, 0, "below min_remote nothing is dispatched");
        assert_eq!(c.degraded, 0, "local-by-policy is not degradation");
    }
}
