//! Distributed sharded scoring: a coordinator/follower fleet over the
//! batch API.
//!
//! The batch-first design of [`crate::score::ScoreBackend`] means a GES
//! sweep reaches the backend as one wide, deduplicated batch of local
//! scores — an embarrassingly parallel unit of work. This module fans
//! that batch out across *follower* `cvlr serve` processes:
//!
//! ```text
//!   GES sweep ──► ScoreService (memo/dedup)
//!                     │  misses, one wide batch
//!                     ▼
//!              ShardScoreBackend ──────────────┐ degrade
//!                     │ partition              ▼
//!        ┌────────────┼────────────┐     local backend
//!        ▼            ▼            ▼     (bit-identical)
//!   follower A   follower B   follower C
//!   POST /v1/score_batch  (keep-alive HTTP/1.1)
//! ```
//!
//! * [`wire`] — the JSON schema: `score_batch` requests/replies and the
//!   raw (bit-exact) dataset push used for auto-registration.
//! * [`client`] — one pooled keep-alive HTTP/1.1 connection per
//!   follower, `Content-Length`-bounded reads.
//! * [`pool`] — per-follower health: EWMA latency, consecutive-failure
//!   trip wire, periodic half-open re-probe, jittered backoff.
//! * [`backend`] — [`ShardScoreBackend`]: partitioning, bounded retry,
//!   hedged re-dispatch of stragglers, graceful degradation to local
//!   scoring.
//!
//! The invariant everything here defends: **sharded results are
//! bit-identical to local scoring**. Followers run the same fold
//! algebra on the same sample matrix (pushed in raw internal
//! coordinates, no re-ingestion), scores cross the wire through the
//! shortest-round-trip f64 codec, and every failure path lands on the
//! wrapped local backend. A dead or slow follower costs latency, never
//! correctness.

pub mod backend;
pub mod client;
pub mod pool;
pub mod wire;

pub use backend::{partition, ShardScoreBackend};
pub use client::ShardClient;
pub use pool::{Follower, FollowerPool, PoolConfig};
pub use wire::ShardSpec;
