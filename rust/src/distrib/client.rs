//! Persistent HTTP/1.1 client for the shard protocol.
//!
//! One [`ShardClient`] per follower holds one pooled keep-alive
//! connection behind a mutex: a sweep's sub-batches reuse the TCP
//! stream instead of paying a handshake per dispatch (the server side
//! keeps connections open since the keep-alive rework of
//! `server::http`). Responses are read **bounded by `Content-Length`**
//! — unlike the one-shot test client in `server::http`, this never
//! waits for the peer to close.
//!
//! Scoring requests are pure reads, so a request that dies on a stale
//! pooled connection (the server restarted, an idle timeout fired) is
//! transparently resent once on a fresh connection. Real failures —
//! refused connections, timeouts, malformed replies — surface as
//! errors for the pool's health tracking.

use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::server::json::{self, Json};

/// Upper bound on response heads (mirrors the server's request bound).
const MAX_HEAD: usize = 16 * 1024;
/// Upper bound on response bodies.
const MAX_BODY: usize = 64 * 1024 * 1024;

/// A blocking JSON-over-HTTP client bound to one follower address,
/// pooling a single keep-alive connection.
pub struct ShardClient {
    addr: String,
    timeout: Duration,
    conn: Mutex<Option<TcpStream>>,
}

impl ShardClient {
    /// Client for `addr` (`host:port`); `timeout` bounds connect, read
    /// and write individually.
    pub fn new(addr: impl Into<String>, timeout: Duration) -> ShardClient {
        ShardClient { addr: addr.into(), timeout, conn: Mutex::new(None) }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    fn connect(&self) -> Result<TcpStream> {
        let sa = self
            .addr
            .to_socket_addrs()
            .with_context(|| format!("resolving `{}`", self.addr))?
            .next()
            .with_context(|| format!("`{}` resolved to no address", self.addr))?;
        let stream = TcpStream::connect_timeout(&sa, self.timeout)
            .with_context(|| format!("connecting to {}", self.addr))?;
        let _ = stream.set_read_timeout(Some(self.timeout));
        let _ = stream.set_write_timeout(Some(self.timeout));
        let _ = stream.set_nodelay(true);
        Ok(stream)
    }

    /// POST `body` to `path`; returns (status, parsed body). Holds the
    /// connection lock for the duration — callers dispatch to
    /// *different* followers concurrently, never to one.
    pub fn post(&self, path: &str, body: &Json) -> Result<(u16, Json)> {
        let (status, text) = self.send("POST", path, &body.encode())?;
        let value = if text.trim().is_empty() { Json::Null } else { json::parse(&text)? };
        Ok((status, value))
    }

    /// GET `path`; returns (status, raw body text) — for non-JSON
    /// endpoints (the coordinator's federated scrape of follower
    /// `/v1/metrics`). Same pooled connection and stale-retry
    /// discipline as [`ShardClient::post`].
    pub fn get_text(&self, path: &str) -> Result<(u16, String)> {
        self.send("GET", path, "")
    }

    /// One pooled exchange with single-resend on a stale connection.
    fn send(&self, method: &str, path: &str, payload: &str) -> Result<(u16, String)> {
        let mut guard = self.conn.lock().unwrap();
        let reused = guard.is_some();
        let mut stream = match guard.take() {
            Some(s) => s,
            None => self.connect()?,
        };
        match roundtrip(&mut stream, &self.addr, method, path, payload) {
            Ok((status, text, keep)) => {
                if keep {
                    *guard = Some(stream);
                }
                Ok((status, text))
            }
            // a pooled connection can die between requests (server
            // restart, idle close); requests are idempotent reads, so
            // resend exactly once on a fresh connection
            Err(_) if reused => {
                let mut fresh = self.connect()?;
                let (status, text, keep) =
                    roundtrip(&mut fresh, &self.addr, method, path, payload)?;
                if keep {
                    *guard = Some(fresh);
                }
                Ok((status, text))
            }
            Err(e) => Err(e),
        }
    }
}

fn roundtrip(
    stream: &mut TcpStream,
    addr: &str,
    method: &str,
    path: &str,
    payload: &str,
) -> Result<(u16, String, bool)> {
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: keep-alive\r\n\r\n",
        payload.len()
    );
    stream.write_all(head.as_bytes()).context("writing request head")?;
    stream.write_all(payload.as_bytes()).context("writing request body")?;
    stream.flush().context("flushing request")?;

    // read the response head
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let head_end = loop {
        if let Some(pos) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > MAX_HEAD {
            bail!("response head larger than {MAX_HEAD} bytes");
        }
        let mut chunk = [0u8; 4096];
        let n = stream.read(&mut chunk).context("reading response head")?;
        if n == 0 {
            bail!("connection closed mid-response");
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head_text = std::str::from_utf8(&buf[..head_end]).context("response head not UTF-8")?;
    let mut lines = head_text.split("\r\n");
    let status_line = lines.next().unwrap_or_default();
    let status: u16 = status_line
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("bad status line `{status_line}`"))?;
    let mut content_length: Option<usize> = None;
    let mut keep_alive = true;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            let (k, v) = (k.trim(), v.trim());
            if k.eq_ignore_ascii_case("content-length") {
                content_length = Some(v.parse().context("bad content-length")?);
            } else if k.eq_ignore_ascii_case("connection") && v.eq_ignore_ascii_case("close") {
                keep_alive = false;
            }
        }
    }
    // bounded body read: never depends on the peer closing
    let content_length = content_length.context("response has no content-length")?;
    if content_length > MAX_BODY {
        bail!("response body larger than {MAX_BODY} bytes");
    }
    let mut body = buf.split_off(head_end + 4);
    while body.len() < content_length {
        let mut chunk = [0u8; 8192];
        let n = stream.read(&mut chunk).context("reading response body")?;
        if n == 0 {
            bail!("connection closed mid-body");
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    let text = std::str::from_utf8(&body).context("response body not UTF-8")?;
    Ok((status, text.to_string(), keep_alive))
}
