//! Persistent HTTP/1.1 client for the shard protocol.
//!
//! One [`ShardClient`] per follower holds one pooled keep-alive
//! connection behind a mutex: a sweep's sub-batches reuse the TCP
//! stream instead of paying a handshake per dispatch (the server side
//! keeps connections open since the keep-alive rework of
//! `server::http`). Responses are read **bounded by `Content-Length`**
//! — unlike the one-shot test client in `server::http`, this never
//! waits for the peer to close — and capped by a configurable body
//! limit (default 256 MiB) so a hostile or corrupt `Content-Length`
//! can't balloon coordinator memory. Read/write socket timeouts are
//! always armed (the pool's `timeout`), so a dead peer mid-body
//! surfaces as a clean truncation error, never an indefinite block;
//! when the caller carries a deadline [`Budget`], the timeouts clamp
//! to the remaining budget per exchange.
//!
//! Scoring requests are pure reads, so a request that dies on a stale
//! pooled connection (the server restarted, an idle timeout fired) is
//! transparently resent once on a fresh connection. Real failures —
//! refused connections, timeouts, malformed replies — surface as
//! errors for the pool's health tracking.

use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use crate::util::lockorder::Mutex;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::server::json::{self, Json};
use crate::util::budget::{Budget, DeadlineExceeded};

/// Upper bound on response heads (mirrors the server's request bound).
const MAX_HEAD: usize = 16 * 1024;
/// Default upper bound on response bodies; raise per client via
/// [`ShardClient::set_body_cap`] for outsized datasets.
pub const DEFAULT_BODY_CAP: usize = 256 * 1024 * 1024;

/// A blocking JSON-over-HTTP client bound to one follower address,
/// pooling a single keep-alive connection.
pub struct ShardClient {
    addr: String,
    timeout: Duration,
    body_cap: usize,
    conn: Mutex<Option<TcpStream>>,
}

impl ShardClient {
    /// Client for `addr` (`host:port`); `timeout` bounds connect, read
    /// and write individually.
    pub fn new(addr: impl Into<String>, timeout: Duration) -> ShardClient {
        ShardClient {
            addr: addr.into(),
            timeout,
            body_cap: DEFAULT_BODY_CAP,
            conn: Mutex::new("distrib.client.conn", None),
        }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Override the response-body cap (bytes).
    pub fn set_body_cap(&mut self, cap: usize) {
        self.body_cap = cap;
    }

    fn connect(&self, timeout: Duration) -> Result<TcpStream> {
        let sa = self
            .addr
            .to_socket_addrs()
            .with_context(|| format!("resolving `{}`", self.addr))?
            .next()
            .with_context(|| format!("`{}` resolved to no address", self.addr))?;
        let stream = TcpStream::connect_timeout(&sa, timeout)
            .with_context(|| format!("connecting to {}", self.addr))?;
        let _ = stream.set_nodelay(true);
        Ok(stream)
    }

    /// POST `body` to `path`; returns (status, parsed body). Holds the
    /// connection lock for the duration — callers dispatch to
    /// *different* followers concurrently, never to one.
    pub fn post(&self, path: &str, body: &Json) -> Result<(u16, Json)> {
        self.post_within(path, body, Budget::none())
    }

    /// [`ShardClient::post`] with socket timeouts clamped to the
    /// remaining deadline budget. An already-expired budget fails fast
    /// with a typed [`DeadlineExceeded`] instead of touching the wire.
    pub fn post_within(&self, path: &str, body: &Json, budget: Budget) -> Result<(u16, Json)> {
        let (status, text) = self.send("POST", path, &body.encode(), budget)?;
        let value = if text.trim().is_empty() { Json::Null } else { json::parse(&text)? };
        Ok((status, value))
    }

    /// GET `path`; returns (status, raw body text) — for non-JSON
    /// endpoints (the coordinator's federated scrape of follower
    /// `/v1/metrics`). Same pooled connection and stale-retry
    /// discipline as [`ShardClient::post`].
    pub fn get_text(&self, path: &str) -> Result<(u16, String)> {
        self.send("GET", path, "", Budget::none())
    }

    /// One pooled exchange with single-resend on a stale connection.
    fn send(
        &self,
        method: &str,
        path: &str,
        payload: &str,
        budget: Budget,
    ) -> Result<(u16, String)> {
        if budget.expired() {
            return Err(DeadlineExceeded::new(format!("{method} {path} to {}", self.addr)).into());
        }
        // every socket operation is bounded: the nominal per-request
        // timeout, clamped by whatever budget remains
        let timeout = budget.clamp(self.timeout);
        let mut guard = self.conn.lock();
        let reused = guard.is_some();
        let mut stream = match guard.take() {
            Some(s) => s,
            None => self.connect(timeout)?,
        };
        let _ = stream.set_read_timeout(Some(timeout));
        let _ = stream.set_write_timeout(Some(timeout));
        match roundtrip(&mut stream, &self.addr, method, path, payload, self.body_cap) {
            Ok((status, text, keep)) => {
                if keep {
                    *guard = Some(stream);
                }
                Ok((status, text))
            }
            // a pooled connection can die between requests (server
            // restart, idle close); requests are idempotent reads, so
            // resend exactly once on a fresh connection
            Err(_) if reused => {
                let timeout = budget.clamp(self.timeout);
                let mut fresh = self.connect(timeout)?;
                let _ = fresh.set_read_timeout(Some(timeout));
                let _ = fresh.set_write_timeout(Some(timeout));
                let (status, text, keep) =
                    roundtrip(&mut fresh, &self.addr, method, path, payload, self.body_cap)?;
                if keep {
                    *guard = Some(fresh);
                }
                Ok((status, text))
            }
            Err(e) => Err(e),
        }
    }
}

fn roundtrip(
    stream: &mut TcpStream,
    addr: &str,
    method: &str,
    path: &str,
    payload: &str,
    body_cap: usize,
) -> Result<(u16, String, bool)> {
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: keep-alive\r\n\r\n",
        payload.len()
    );
    stream.write_all(head.as_bytes()).context("writing request head")?;
    stream.write_all(payload.as_bytes()).context("writing request body")?;
    stream.flush().context("flushing request")?;

    // read the response head
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let head_end = loop {
        if let Some(pos) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > MAX_HEAD {
            bail!("response head larger than {MAX_HEAD} bytes");
        }
        let mut chunk = [0u8; 4096];
        let n = stream.read(&mut chunk).context("reading response head")?;
        if n == 0 {
            bail!("connection closed mid-response");
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head_text = std::str::from_utf8(&buf[..head_end]).context("response head not UTF-8")?;
    let mut lines = head_text.split("\r\n");
    let status_line = lines.next().unwrap_or_default();
    let status: u16 = status_line
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("bad status line `{status_line}`"))?;
    let mut content_length: Option<usize> = None;
    let mut keep_alive = true;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            let (k, v) = (k.trim(), v.trim());
            if k.eq_ignore_ascii_case("content-length") {
                content_length = Some(v.parse().context("bad content-length")?);
            } else if k.eq_ignore_ascii_case("connection") && v.eq_ignore_ascii_case("close") {
                keep_alive = false;
            }
        }
    }
    // bounded body read: never depends on the peer closing, never
    // allocates more than the cap no matter what the header claims
    let content_length = content_length.context("response has no content-length")?;
    if content_length > body_cap {
        bail!("response body of {content_length} bytes exceeds the {body_cap}-byte cap");
    }
    let mut body = buf.split_off(head_end + 4);
    while body.len() < content_length {
        let mut chunk = [0u8; 8192];
        let n = stream.read(&mut chunk).context("reading response body")?;
        if n == 0 {
            bail!(
                "response body truncated: connection closed after {} of {content_length} bytes",
                body.len()
            );
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    let text = std::str::from_utf8(&body).context("response body not UTF-8")?;
    Ok((status, text.to_string(), keep_alive))
}
