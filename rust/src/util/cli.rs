//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    opts: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.opts.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.opts.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn parses_mixed_forms() {
        let a = parse(&["run", "--n", "500", "--full", "--seed=42", "extra"]);
        assert_eq!(a.positional, vec!["run", "extra"]);
        assert_eq!(a.usize_or("n", 0), 500);
        assert!(a.flag("full"));
        assert_eq!(a.u64_or("seed", 0), 42);
        assert!(!a.flag("absent"));
        assert_eq!(a.f64_or("lambda", 0.01), 0.01);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["--verbose"]);
        assert!(a.flag("verbose"));
    }
}
