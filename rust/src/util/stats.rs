//! Small statistics helpers: moments, ranking (for Spearman correlation),
//! correlation coefficients, medians.

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Median (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Fractional ranks (average ranks for ties), 1-based — as used by
/// Spearman correlation.
pub fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut r = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            r[idx[k]] = avg;
        }
        i = j + 1;
    }
    r
}

/// Pearson correlation coefficient.
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let (mx, my) = (mean(x), mean(y));
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for i in 0..x.len() {
        let dx = x[i] - mx;
        let dy = y[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx <= 0.0 || syy <= 0.0 {
        return 0.0;
    }
    (sxy / (sxx * syy).sqrt()).clamp(-1.0, 1.0)
}

/// Spearman rank correlation.
pub fn spearman(x: &[f64], y: &[f64]) -> f64 {
    pearson(&ranks(x), &ranks(y))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var_median_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
    }

    #[test]
    fn ranks_handle_ties() {
        let r = ranks(&[10.0, 20.0, 20.0, 30.0]);
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn pearson_perfect() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yn: Vec<f64> = y.iter().map(|v| -v).collect();
        assert!((pearson(&x, &yn) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let x: Vec<f64> = (1..=20).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| (0.5 * v).exp()).collect();
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
    }
}
