//! Special functions: log-gamma, regularized incomplete gamma, error
//! function, and the distribution CDFs built on them (gamma, chi-square,
//! standard normal).
//!
//! Implementations follow the classic Lanczos / series / continued-fraction
//! constructions (Numerical Recipes §6); absolute accuracy is ~1e-12 over
//! the ranges the library uses (KCI p-values, BDeu counts, BIC penalties).

use std::f64::consts::PI;

/// Natural log of the gamma function (Lanczos approximation, g=7, n=9).
pub fn ln_gamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        return (PI / (PI * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Regularized lower incomplete gamma P(a, x) = γ(a,x)/Γ(a).
pub fn gamma_p(a: f64, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    if a <= 0.0 {
        return 1.0;
    }
    if x < a + 1.0 {
        gamma_p_series(a, x)
    } else {
        1.0 - gamma_q_cf(a, x)
    }
}

/// Regularized upper incomplete gamma Q(a, x) = 1 - P(a, x).
pub fn gamma_q(a: f64, x: f64) -> f64 {
    1.0 - gamma_p(a, x)
}

fn gamma_p_series(a: f64, x: f64) -> f64 {
    let mut ap = a;
    let mut sum = 1.0 / a;
    let mut del = sum;
    for _ in 0..500 {
        ap += 1.0;
        del *= x / ap;
        sum += del;
        if del.abs() < sum.abs() * 1e-15 {
            break;
        }
    }
    sum * (-x + a * x.ln() - ln_gamma(a)).exp()
}

fn gamma_q_cf(a: f64, x: f64) -> f64 {
    // Lentz's continued fraction for Q(a,x).
    let tiny = 1e-300;
    let mut b = x + 1.0 - a;
    let mut c = 1.0 / tiny;
    let mut d = 1.0 / b;
    let mut h = d;
    for i in 1..500 {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < tiny {
            d = tiny;
        }
        c = b + an / c;
        if c.abs() < tiny {
            c = tiny;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-15 {
            break;
        }
    }
    (-x + a * x.ln() - ln_gamma(a)).exp() * h
}

/// CDF of Gamma(shape k, scale θ) at x.
pub fn gamma_cdf(x: f64, shape: f64, scale: f64) -> f64 {
    gamma_p(shape, (x / scale).max(0.0))
}

/// Survival function of Gamma(shape, scale) at x — upper-tail p-value.
pub fn gamma_sf(x: f64, shape: f64, scale: f64) -> f64 {
    gamma_q(shape, (x / scale).max(0.0))
}

/// Chi-square CDF with k degrees of freedom.
pub fn chi2_cdf(x: f64, k: f64) -> f64 {
    gamma_cdf(x, k / 2.0, 2.0)
}

/// Error function (Abramowitz–Stegun 7.1.26-style rational approx refined
/// via the incomplete gamma relation erf(x) = P(1/2, x²)).
pub fn erf(x: f64) -> f64 {
    let s = if x < 0.0 { -1.0 } else { 1.0 };
    s * gamma_p(0.5, x * x)
}

/// Standard normal CDF.
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_integers() {
        // Γ(n) = (n-1)!
        let facts = [1.0, 1.0, 2.0, 6.0, 24.0, 120.0, 720.0];
        for (n, f) in facts.iter().enumerate() {
            assert!((ln_gamma(n as f64 + 1.0) - (f as &f64).ln()).abs() < 1e-10, "n={n}");
        }
    }

    #[test]
    fn ln_gamma_half() {
        assert!((ln_gamma(0.5) - PI.sqrt().ln()).abs() < 1e-10);
        assert!((ln_gamma(1.5) - (PI.sqrt() / 2.0).ln()).abs() < 1e-10);
    }

    #[test]
    fn gamma_p_known_values() {
        // P(1, x) = 1 - e^{-x}
        for &x in &[0.1, 0.5, 1.0, 2.0, 5.0] {
            assert!((gamma_p(1.0, x) - (1.0 - (-x as f64).exp())).abs() < 1e-12);
        }
        // Median of chi2_2 is 2 ln 2.
        assert!((chi2_cdf(2.0 * 2.0f64.ln(), 2.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn norm_cdf_symmetry_and_values() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-12);
        assert!((norm_cdf(1.959_963_985) - 0.975).abs() < 1e-6);
        for &x in &[0.3, 1.1, 2.5] {
            assert!((norm_cdf(x) + norm_cdf(-x) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn gamma_sf_complements_cdf() {
        for &x in &[0.2, 1.0, 3.3, 10.0] {
            let (k, th) = (2.3, 1.7);
            assert!((gamma_cdf(x, k, th) + gamma_sf(x, k, th) - 1.0).abs() < 1e-12);
        }
    }
}
