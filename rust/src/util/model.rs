//! A miniature deterministic schedule explorer ("loom-lite") plus
//! extracted protocol models of the crate's hand-rolled concurrency
//! cores.
//!
//! The real protocols — `ScoreCache` claim/fill/evict in
//! `coordinator/service.rs`, the `JobManager` queue/cancel/drain loop
//! in `server/jobs.rs`, and the stream append-vs-job guard — are a few
//! dozen lines each, but their correctness arguments are interleaving
//! arguments, which example-based tests sample rather than cover. Here
//! each protocol is re-stated as a [`Model`]: shared state plus one
//! atomic step function per modeled thread, where every step
//! corresponds to one lock span (or one lock-free action) of the real
//! code. [`explore`] then enumerates *every* interleaving up to a
//! bounded depth with DFS + state hashing and checks the invariants
//! the real code assumes in every reachable state.
//!
//! A violation comes back as a [`Counterexample`] carrying the exact
//! schedule (the sequence of thread ids that were stepped); feeding it
//! to [`replay`] re-executes that schedule deterministically and
//! prints a state trace, so a failure in CI is reproducible locally
//! from the printed schedule alone.
//!
//! The deliberately-buggy model variants (`two_phase_claim`,
//! `skip_notify`, `unpinned_evict`, `locked_notify: false`,
//! `release_early`) re-introduce real historical or hypothetical races
//! — e.g. the pre-PR-1 double-eval race — and the tests assert the
//! explorer finds each one. That is the regression harness: if a
//! future refactor re-creates one of these shapes, the matching model
//! edit will reproduce the counterexample.

use std::collections::HashSet;
use std::fmt::Debug;
use std::hash::{Hash, Hasher};

/// A protocol model: shared state plus per-thread atomic steps.
///
/// Each call to [`Model::step`] must correspond to one indivisible
/// action of the real protocol (one lock span, one atomic store). The
/// explorer owns all scheduling: it only steps threads for which
/// [`Model::enabled`] is true, so blocking (condvar waits, mutex
/// acquisition) is expressed as enabledness predicates rather than by
/// spinning.
pub trait Model {
    /// Full shared + per-thread state. `Hash` drives the visited-state
    /// pruning; `Debug` renders replay traces.
    type State: Clone + Hash + Debug;

    /// Stable name, used in counterexample headers and trace artifacts.
    fn name(&self) -> &'static str;
    /// Number of modeled threads (thread ids are `0..threads()`).
    fn threads(&self) -> usize;
    /// The initial state.
    fn init(&self) -> Self::State;
    /// True once `tid` has finished its program.
    fn done(&self, s: &Self::State, tid: usize) -> bool;
    /// True when `tid` can take a step now (e.g. the lock it needs is
    /// free, or the wakeup it waits for has been delivered).
    fn enabled(&self, s: &Self::State, tid: usize) -> bool;
    /// Execute one atomic step of `tid`. Only called when enabled.
    fn step(&self, s: &mut Self::State, tid: usize);
    /// Safety invariant, checked in every reachable state.
    fn invariant(&self, s: &Self::State) -> Result<(), String>;
    /// Checked in every state where all threads are done.
    fn final_check(&self, _s: &Self::State) -> Result<(), String> {
        Ok(())
    }
}

/// Exploration bounds. Depth is the schedule length; a branch that
/// reaches `max_depth` without finishing is counted as truncated, not
/// failed, so bounded runs stay sound for the states they did visit.
#[derive(Clone, Copy, Debug)]
pub struct Options {
    pub max_depth: usize,
    pub max_states: usize,
}

impl Default for Options {
    fn default() -> Self {
        Options { max_depth: 64, max_states: 1 << 20 }
    }
}

impl Options {
    /// CI knob: `CVLR_MODEL_DEPTH` overrides the depth bound (the
    /// weekly exhaustive tier raises it; the PR tier uses the default).
    pub fn from_env() -> Self {
        let mut o = Options::default();
        if let Some(d) = std::env::var("CVLR_MODEL_DEPTH")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
        {
            o.max_depth = d.max(1);
        }
        o
    }
}

/// Statistics from a successful exhaustive run.
#[derive(Clone, Copy, Debug, Default)]
pub struct Report {
    /// Distinct states visited (after hashing dedup).
    pub distinct_states: usize,
    /// Schedules that ran every thread to completion.
    pub completed_schedules: usize,
    /// Branches cut off by the depth or state bound.
    pub truncated: usize,
    /// Longest schedule explored.
    pub max_depth_seen: usize,
}

/// A violating interleaving: the schedule replays it deterministically.
#[derive(Clone, Debug)]
pub struct Counterexample {
    pub model: &'static str,
    /// Thread ids in step order, from the initial state.
    pub schedule: Vec<usize>,
    pub message: String,
}

impl Counterexample {
    /// Header + schedule in the exact form [`replay`] accepts.
    pub fn render(&self) -> String {
        format!(
            "model `{}` violated: {}\nschedule ({} steps): {:?}\n",
            self.model,
            self.message,
            self.schedule.len(),
            self.schedule
        )
    }
}

fn fingerprint<S: Hash>(s: &S) -> u64 {
    // DefaultHasher::new() is keyed with fixed zeros, so fingerprints
    // are stable across runs — required for deterministic exploration.
    let mut h = std::collections::hash_map::DefaultHasher::new();
    s.hash(&mut h);
    h.finish()
}

/// Exhaustively enumerate interleavings of `m` up to `o.max_depth`,
/// checking [`Model::invariant`] in every state, [`Model::final_check`]
/// in every terminal state, and reporting deadlock when live threads
/// exist but none is enabled.
pub fn explore<M: Model>(m: &M, o: &Options) -> Result<Report, Box<Counterexample>> {
    let mut report = Report::default();
    let mut visited: HashSet<u64> = HashSet::new();
    let init = m.init();
    visited.insert(fingerprint(&init));
    let mut schedule: Vec<usize> = Vec::new();
    dfs(m, o, &init, &mut schedule, &mut visited, &mut report)?;
    Ok(report)
}

fn dfs<M: Model>(
    m: &M,
    o: &Options,
    s: &M::State,
    schedule: &mut Vec<usize>,
    visited: &mut HashSet<u64>,
    report: &mut Report,
) -> Result<(), Box<Counterexample>> {
    let fail = |msg: String, schedule: &[usize]| {
        Box::new(Counterexample {
            model: m.name(),
            schedule: schedule.to_vec(),
            message: msg,
        })
    };
    if let Err(msg) = m.invariant(s) {
        return Err(fail(msg, schedule));
    }
    report.max_depth_seen = report.max_depth_seen.max(schedule.len());
    let live: Vec<usize> = (0..m.threads()).filter(|&t| !m.done(s, t)).collect();
    if live.is_empty() {
        if let Err(msg) = m.final_check(s) {
            return Err(fail(format!("final check failed: {msg}"), schedule));
        }
        report.completed_schedules += 1;
        return Ok(());
    }
    let runnable: Vec<usize> = live.iter().copied().filter(|&t| m.enabled(s, t)).collect();
    if runnable.is_empty() {
        return Err(fail(
            format!("deadlock: threads {live:?} are live but none is enabled"),
            schedule,
        ));
    }
    if schedule.len() >= o.max_depth || visited.len() >= o.max_states {
        report.truncated += 1;
        return Ok(());
    }
    for tid in runnable {
        let mut next = s.clone();
        m.step(&mut next, tid);
        if visited.insert(fingerprint(&next)) {
            schedule.push(tid);
            dfs(m, o, &next, schedule, visited, report)?;
            schedule.pop();
        }
    }
    Ok(())
}

/// Outcome of replaying one schedule.
#[derive(Clone, Debug)]
pub struct Replay {
    /// One line per step: `step k: thread t -> <state>`.
    pub trace: String,
    /// The violation the schedule reproduces, if any.
    pub violation: Option<String>,
}

/// Deterministically re-execute `schedule` from the initial state,
/// rendering every intermediate state and re-checking the invariants.
/// This is how a CI counterexample is debugged locally: paste the
/// printed schedule and read the trace.
pub fn replay<M: Model>(m: &M, schedule: &[usize]) -> Replay {
    let mut s = m.init();
    let mut trace = format!("replay of model `{}` ({} steps)\n", m.name(), schedule.len());
    trace.push_str(&format!("  init: {s:?}\n"));
    let mut violation = m.invariant(&s).err();
    if violation.is_none() {
        for (k, &tid) in schedule.iter().enumerate() {
            if m.done(&s, tid) || !m.enabled(&s, tid) {
                violation = Some(format!(
                    "schedule step {k} chose thread {tid}, which is not runnable"
                ));
                break;
            }
            m.step(&mut s, tid);
            trace.push_str(&format!("  step {k}: thread {tid} -> {s:?}\n"));
            if let Err(msg) = m.invariant(&s) {
                violation = Some(msg);
                break;
            }
        }
    }
    if violation.is_none() {
        let live: Vec<usize> = (0..m.threads()).filter(|&t| !m.done(&s, t)).collect();
        if live.is_empty() {
            violation = m.final_check(&s).err().map(|e| format!("final check failed: {e}"));
        } else if !live.iter().any(|&t| m.enabled(&s, t)) {
            violation = Some(format!(
                "deadlock: threads {live:?} are live but none is enabled"
            ));
        }
    }
    if let Some(v) = &violation {
        trace.push_str(&format!("  violation: {v}\n"));
    }
    Replay { trace, violation }
}

/// Run [`explore`]; on violation, render the counterexample and its
/// replay trace into `$CVLR_MODEL_TRACE_DIR/<model>.trace` (when the
/// env var is set — CI sets it and uploads the directory as an
/// artifact on failure) before returning it.
pub fn check_model<M: Model>(m: &M, o: &Options) -> Result<Report, Box<Counterexample>> {
    match explore(m, o) {
        Ok(r) => Ok(r),
        Err(cex) => {
            if let Ok(dir) = std::env::var("CVLR_MODEL_TRACE_DIR") {
                let _ = std::fs::create_dir_all(&dir);
                let body = format!("{}\n{}", cex.render(), replay(m, &cex.schedule).trace);
                let _ = std::fs::write(format!("{}/{}.trace", dir, m.name()), body);
            }
            Err(cex)
        }
    }
}

// ---------------------------------------------------------------------------
// Model 1: ScoreCache claim / fill / evict
// ---------------------------------------------------------------------------

/// Extracted model of the `ScoreCache` protocol
/// (`coordinator/service.rs`): N requesters race for one key; the
/// first to claim becomes the owner and evaluates, later claimants
/// register as waiters and sleep on the condvar; fill publishes the
/// value and wakes every registered waiter; an optional evictor runs a
/// second-chance sweep that must skip entries with uncollected
/// waiters.
///
/// The bug knobs re-introduce specific races:
/// * `two_phase_claim` — the pre-PR-1 shape: check-then-insert in two
///   separate lock spans, so two racing misses both evaluate.
/// * `skip_notify` — fill forgets `notify_all`; a registered waiter
///   sleeps forever (lost wakeup ⇒ deadlock).
/// * `unpinned_evict` — the evictor ignores waiter pinning and evicts
///   a `Ready` entry before its waiters collected it, forcing a
///   registered waiter to re-evaluate.
#[derive(Clone, Copy, Debug)]
pub struct CacheModel {
    pub requesters: usize,
    pub evictor: bool,
    pub two_phase_claim: bool,
    pub skip_notify: bool,
    pub unpinned_evict: bool,
}

impl CacheModel {
    /// The protocol as shipped: single-lock-span claim, notify on
    /// fill, waiter-pinned eviction.
    pub fn correct(requesters: usize, evictor: bool) -> Self {
        CacheModel {
            requesters,
            evictor,
            two_phase_claim: false,
            skip_notify: false,
            unpinned_evict: false,
        }
    }
}

/// One cache slot, as the model sees it.
#[derive(Clone, Copy, Debug, Hash, PartialEq, Eq)]
pub enum Slot {
    Empty,
    /// Claimed, evaluation in flight.
    Pending,
    /// Value published.
    Ready,
}

/// Requester program counters (single-lock-span protocol).
const C_CLAIM: u8 = 0; // one lock span: classify hit / owner / waiter
const C_EVAL: u8 = 1; // owner: start evaluation (outside the lock)
const C_FILL: u8 = 2; // owner: publish + notify (one lock span)
const C_WAIT: u8 = 3; // waiter: re-check predicate (one lock span)
const C_SLEEP: u8 = 4; // waiter: parked on the condvar
const C_DONE: u8 = 5;
// Two-phase (buggy) claim re-uses C_CLAIM as the bare check, then
// C_EVAL / C_FILL as the unreserved evaluate + insert.

/// Full state of [`CacheModel`].
#[derive(Clone, Debug, Hash)]
pub struct CacheState {
    pc: Vec<u8>,
    slot: Slot,
    /// Waiters registered on the slot that have not yet collected.
    uncollected: u8,
    /// Bitmask of requesters parked on the condvar.
    sleeping: u16,
    /// Bitmask of parked requesters that have been notified.
    woken: u16,
    /// Stats — the identity `requests == hits + evals + dedup` is the
    /// protocol's observable contract (`/v1/stats` exposes it).
    requests: u8,
    hits: u8,
    evals: u8,
    dedup: u8,
    /// Total evaluations ever started (catches double-eval).
    total_evals: u8,
    evals_live: u8,
    /// A *registered waiter* observed `Empty` — its pinned entry was
    /// evicted out from under it.
    waiter_lost_entry: u8,
    evictions: u8,
}

impl Model for CacheModel {
    type State = CacheState;

    fn name(&self) -> &'static str {
        if self.two_phase_claim {
            "cache-two-phase-claim-bug"
        } else if self.skip_notify {
            "cache-skip-notify-bug"
        } else if self.unpinned_evict {
            "cache-unpinned-evict-bug"
        } else {
            "cache-claim-fill-evict"
        }
    }

    fn threads(&self) -> usize {
        self.requesters + usize::from(self.evictor)
    }

    fn init(&self) -> CacheState {
        CacheState {
            pc: vec![0; self.threads()],
            slot: Slot::Empty,
            uncollected: 0,
            sleeping: 0,
            woken: 0,
            requests: 0,
            hits: 0,
            evals: 0,
            dedup: 0,
            total_evals: 0,
            evals_live: 0,
            waiter_lost_entry: 0,
            evictions: 0,
        }
    }

    fn done(&self, s: &CacheState, tid: usize) -> bool {
        if self.evictor && tid == self.requesters {
            s.pc[tid] == 1
        } else {
            s.pc[tid] == C_DONE
        }
    }

    fn enabled(&self, s: &CacheState, tid: usize) -> bool {
        if self.done(s, tid) {
            return false;
        }
        if self.evictor && tid == self.requesters {
            return true;
        }
        if s.pc[tid] == C_SLEEP {
            return s.woken & (1 << tid) != 0;
        }
        true
    }

    fn step(&self, s: &mut CacheState, tid: usize) {
        if self.evictor && tid == self.requesters {
            // One second-chance sweep attempt. Correct: only evict a
            // Ready entry nobody is still waiting to collect.
            if s.slot == Slot::Ready && (self.unpinned_evict || s.uncollected == 0) {
                s.slot = Slot::Empty;
                s.evictions += 1;
            }
            s.pc[tid] = 1;
            return;
        }
        let bit = 1u16 << tid;
        if self.two_phase_claim {
            // Pre-PR-1 shape: the miss check and the insert are two
            // separate lock spans with the evaluation in between, and
            // nothing reserves the key.
            match s.pc[tid] {
                C_CLAIM => {
                    s.requests += 1;
                    if s.slot == Slot::Ready {
                        s.hits += 1;
                        s.pc[tid] = C_DONE;
                    } else {
                        s.pc[tid] = C_EVAL;
                    }
                }
                C_EVAL => {
                    s.total_evals += 1;
                    s.evals_live += 1;
                    s.pc[tid] = C_FILL;
                }
                C_FILL => {
                    s.evals_live -= 1;
                    s.evals += 1;
                    s.slot = Slot::Ready;
                    s.pc[tid] = C_DONE;
                }
                _ => unreachable!("two-phase requester pc"),
            }
            return;
        }
        match s.pc[tid] {
            C_CLAIM => {
                // One lock span classifies the request (PR 1's fix).
                s.requests += 1;
                match s.slot {
                    Slot::Empty => {
                        s.slot = Slot::Pending;
                        s.pc[tid] = C_EVAL;
                    }
                    Slot::Pending => {
                        s.uncollected += 1;
                        s.pc[tid] = C_WAIT;
                    }
                    Slot::Ready => {
                        s.hits += 1;
                        s.pc[tid] = C_DONE;
                    }
                }
            }
            C_EVAL => {
                s.total_evals += 1;
                s.evals_live += 1;
                s.pc[tid] = C_FILL;
            }
            C_FILL => {
                s.evals_live -= 1;
                s.evals += 1;
                s.slot = Slot::Ready;
                if !self.skip_notify {
                    s.woken |= s.sleeping;
                }
                s.pc[tid] = C_DONE;
            }
            C_WAIT => {
                // The wait loop's predicate re-check, under the lock.
                match s.slot {
                    Slot::Ready => {
                        s.dedup += 1;
                        s.uncollected -= 1;
                        s.pc[tid] = C_DONE;
                    }
                    Slot::Empty => {
                        // Pinned entry vanished: the waiter must
                        // re-claim and re-evaluate. Recorded as a
                        // violation via the invariant.
                        s.waiter_lost_entry += 1;
                        s.uncollected -= 1;
                        s.slot = Slot::Pending;
                        s.pc[tid] = C_EVAL;
                    }
                    Slot::Pending => {
                        s.sleeping |= bit;
                        s.pc[tid] = C_SLEEP;
                    }
                }
            }
            C_SLEEP => {
                s.sleeping &= !bit;
                s.woken &= !bit;
                s.pc[tid] = C_WAIT;
            }
            _ => unreachable!("requester pc"),
        }
    }

    fn invariant(&self, s: &CacheState) -> Result<(), String> {
        if s.evals_live > 1 {
            return Err(format!(
                "double eval: {} evaluations in flight for one claimed key",
                s.evals_live
            ));
        }
        if !self.evictor && s.total_evals > 1 {
            return Err(format!(
                "double eval: key evaluated {} times with no eviction",
                s.total_evals
            ));
        }
        if s.waiter_lost_entry > 0 {
            return Err(
                "pinned entry evicted under a registered waiter (waiter saw Empty)".to_string()
            );
        }
        Ok(())
    }

    fn final_check(&self, s: &CacheState) -> Result<(), String> {
        let total = s.hits + s.evals + s.dedup;
        if s.requests != total {
            return Err(format!(
                "stats identity broken: requests={} != hits={} + evals={} + dedup={}",
                s.requests, s.hits, s.evals, s.dedup
            ));
        }
        if s.requests != self.requesters as u8 {
            return Err(format!(
                "lost request: {} of {} requesters recorded",
                s.requests, self.requesters
            ));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Model 2: JobManager queue / shutdown drain
// ---------------------------------------------------------------------------

/// Extracted model of the `JobManager` worker loop and shutdown drain
/// (`server/jobs.rs`): a worker holds the queue lock while checking
/// `shutdown`/queue and enters the condvar wait atomically with
/// releasing it; a submitter pushes one job *under the lock* and
/// notifies; the shutdowner stores the (atomic, lock-free) shutdown
/// flag and notifies.
///
/// `locked_notify: false` is the shipped-before-this-PR shutdown: the
/// flag store and `notify_all` happen without touching the queue
/// mutex, so both can land in the window between the worker's
/// predicate check (under the lock) and its wait — the notify finds no
/// sleeper and the worker parks forever. `locked_notify: true` is the
/// fix: shutdown acquires and releases the queue mutex between the
/// store and the notify, which the explorer proves closes the window.
#[derive(Clone, Copy, Debug)]
pub struct JobsModel {
    pub locked_notify: bool,
}

/// Thread ids in [`JobsModel`].
const T_WORKER: usize = 0;
const T_SUBMIT: usize = 1;
const T_SHUTDOWN: usize = 2;

// Worker pcs.
const W_ACQ: u8 = 0;
const W_CHECK: u8 = 1;
const W_WAIT_ENTER: u8 = 2;
const W_PARKED: u8 = 3;
const W_REACQ: u8 = 4;
const W_EXIT: u8 = 5;
// Submitter pcs.
const SUB_ACQ: u8 = 0;
const SUB_PUSH: u8 = 1;
const SUB_NOTIFY: u8 = 2;
const SUB_DONE: u8 = 3;
// Shutdowner pcs (locked_notify inserts ACQ/REL between FLAG and NOTIFY).
const SH_FLAG: u8 = 0;
const SH_ACQ: u8 = 1;
const SH_REL: u8 = 2;
const SH_NOTIFY: u8 = 3;
const SH_JOIN: u8 = 4;
const SH_DONE: u8 = 5;

/// Full state of [`JobsModel`].
#[derive(Clone, Debug, Hash)]
pub struct JobsState {
    pc: [u8; 3],
    /// Queue mutex holder.
    lock: Option<u8>,
    queue: u8,
    shutdown: bool,
    /// Worker parked in the condvar wait.
    sleeping: bool,
    /// A notify was delivered to the parked worker.
    woken: bool,
    jobs_run: u8,
}

impl Model for JobsModel {
    type State = JobsState;

    fn name(&self) -> &'static str {
        if self.locked_notify {
            "jobs-shutdown-drain"
        } else {
            "jobs-shutdown-unlocked-notify-bug"
        }
    }

    fn threads(&self) -> usize {
        3
    }

    fn init(&self) -> JobsState {
        JobsState {
            pc: [W_ACQ, SUB_ACQ, SH_FLAG],
            lock: None,
            queue: 0,
            shutdown: false,
            sleeping: false,
            woken: false,
            jobs_run: 0,
        }
    }

    fn done(&self, s: &JobsState, tid: usize) -> bool {
        match tid {
            T_WORKER => s.pc[0] == W_EXIT,
            T_SUBMIT => s.pc[1] == SUB_DONE,
            _ => s.pc[2] == SH_DONE,
        }
    }

    fn enabled(&self, s: &JobsState, tid: usize) -> bool {
        if self.done(s, tid) {
            return false;
        }
        match (tid, s.pc[tid]) {
            (T_WORKER, W_ACQ) | (T_WORKER, W_REACQ) => s.lock.is_none(),
            (T_WORKER, W_PARKED) => s.woken,
            (T_SUBMIT, SUB_ACQ) => s.lock.is_none(),
            (T_SHUTDOWN, SH_ACQ) => s.lock.is_none(),
            (T_SHUTDOWN, SH_JOIN) => s.pc[0] == W_EXIT,
            _ => true,
        }
    }

    fn step(&self, s: &mut JobsState, tid: usize) {
        match tid {
            T_WORKER => match s.pc[0] {
                W_ACQ | W_REACQ => {
                    s.lock = Some(0);
                    s.pc[0] = W_CHECK;
                }
                W_CHECK => {
                    // Predicate check under the lock, exactly as in
                    // `worker_loop`.
                    if s.shutdown {
                        s.lock = None;
                        s.pc[0] = W_EXIT;
                    } else if s.queue > 0 {
                        s.queue -= 1;
                        s.jobs_run += 1;
                        s.lock = None; // run the job outside the lock
                        s.pc[0] = W_ACQ;
                    } else {
                        s.pc[0] = W_WAIT_ENTER; // decided to wait, still holds the lock
                    }
                }
                W_WAIT_ENTER => {
                    // Condvar wait: park + release, atomically.
                    s.sleeping = true;
                    s.lock = None;
                    s.pc[0] = W_PARKED;
                }
                W_PARKED => {
                    s.sleeping = false;
                    s.woken = false;
                    s.pc[0] = W_REACQ;
                }
                _ => unreachable!("worker pc"),
            },
            T_SUBMIT => match s.pc[1] {
                SUB_ACQ => {
                    s.lock = Some(1);
                    s.pc[1] = SUB_PUSH;
                }
                SUB_PUSH => {
                    // Push happens under the queue lock — this is why
                    // submit has no missed-wakeup window.
                    s.queue += 1;
                    s.lock = None;
                    s.pc[1] = SUB_NOTIFY;
                }
                SUB_NOTIFY => {
                    if s.sleeping {
                        s.woken = true;
                    }
                    s.pc[1] = SUB_DONE;
                }
                _ => unreachable!("submitter pc"),
            },
            _ => match s.pc[2] {
                SH_FLAG => {
                    // Lock-free atomic store, exactly as in `shutdown()`.
                    s.shutdown = true;
                    s.pc[2] = if self.locked_notify { SH_ACQ } else { SH_NOTIFY };
                }
                SH_ACQ => {
                    s.lock = Some(2);
                    s.pc[2] = SH_REL;
                }
                SH_REL => {
                    s.lock = None;
                    s.pc[2] = SH_NOTIFY;
                }
                SH_NOTIFY => {
                    if s.sleeping {
                        s.woken = true;
                    }
                    s.pc[2] = SH_JOIN;
                }
                SH_JOIN => {
                    s.pc[2] = SH_DONE;
                }
                _ => unreachable!("shutdowner pc"),
            },
        }
    }

    fn invariant(&self, _s: &JobsState) -> Result<(), String> {
        Ok(())
    }

    fn final_check(&self, s: &JobsState) -> Result<(), String> {
        if s.pc[0] != W_EXIT {
            return Err("worker did not exit".to_string());
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Model 3: stream append-vs-job guard
// ---------------------------------------------------------------------------

/// Extracted model of the append-vs-job guard: an append must not
/// publish new rows (`data_v`) without the rebuilt factors
/// (`factor_v`) before any scorer can observe them. The real guard is
/// the `appending` set in `server/jobs.rs` + the session lock in
/// `stream/session.rs`: appends take the guard only when no job is
/// active, jobs refuse to start while the guard is held, and the
/// factor rebuild completes inside the guarded span.
///
/// `release_early: true` re-orders the release before the factor
/// rebuild — a scorer admitted in that window scores new rows against
/// stale factors, which the invariant flags.
#[derive(Clone, Copy, Debug)]
pub struct AppendModel {
    pub scorers: usize,
    pub release_early: bool,
}

// Appender pcs.
const A_GUARD: u8 = 0;
const A_DATA: u8 = 1;
const A_FACTOR: u8 = 2;
const A_RELEASE: u8 = 3;
const A_DONE: u8 = 4;
// Scorer pcs.
const S_ENTER: u8 = 0;
const S_SERVE: u8 = 1;
const S_EXIT: u8 = 2;
const S_DONE: u8 = 3;

/// Full state of [`AppendModel`]. Thread 0 is the appender; threads
/// `1..=scorers` are scorers.
#[derive(Clone, Debug, Hash)]
pub struct AppendState {
    pc: Vec<u8>,
    guard: bool,
    active_scorers: u8,
    data_v: u8,
    factor_v: u8,
    stale_served: u8,
}

impl Model for AppendModel {
    type State = AppendState;

    fn name(&self) -> &'static str {
        if self.release_early {
            "append-guard-release-early-bug"
        } else {
            "append-vs-job-guard"
        }
    }

    fn threads(&self) -> usize {
        1 + self.scorers
    }

    fn init(&self) -> AppendState {
        AppendState {
            pc: vec![0; self.threads()],
            guard: false,
            active_scorers: 0,
            data_v: 0,
            factor_v: 0,
            stale_served: 0,
        }
    }

    fn done(&self, s: &AppendState, tid: usize) -> bool {
        if tid == 0 {
            s.pc[0] == A_DONE
        } else {
            s.pc[tid] == S_DONE
        }
    }

    fn enabled(&self, s: &AppendState, tid: usize) -> bool {
        if self.done(s, tid) {
            return false;
        }
        if tid == 0 {
            // Appends wait for running jobs to drain before taking the
            // guard.
            s.pc[0] != A_GUARD || (!s.guard && s.active_scorers == 0)
        } else {
            // Jobs refuse to start while an append holds the guard.
            s.pc[tid] != S_ENTER || !s.guard
        }
    }

    fn step(&self, s: &mut AppendState, tid: usize) {
        if tid == 0 {
            match s.pc[0] {
                A_GUARD => {
                    s.guard = true;
                    s.pc[0] = A_DATA;
                }
                A_DATA => {
                    s.data_v += 1;
                    // Buggy variant drops the guard here, before the
                    // factor rebuild.
                    s.pc[0] = if self.release_early { A_RELEASE } else { A_FACTOR };
                }
                A_FACTOR => {
                    s.factor_v = s.data_v;
                    s.pc[0] = if self.release_early { A_DONE } else { A_RELEASE };
                }
                A_RELEASE => {
                    s.guard = false;
                    s.pc[0] = if self.release_early { A_FACTOR } else { A_DONE };
                }
                _ => unreachable!("appender pc"),
            }
        } else {
            match s.pc[tid] {
                S_ENTER => {
                    s.active_scorers += 1;
                    s.pc[tid] = S_SERVE;
                }
                S_SERVE => {
                    if s.factor_v != s.data_v {
                        s.stale_served += 1;
                    }
                    s.pc[tid] = S_EXIT;
                }
                S_EXIT => {
                    s.active_scorers -= 1;
                    s.pc[tid] = S_DONE;
                }
                _ => unreachable!("scorer pc"),
            }
        }
    }

    fn invariant(&self, s: &AppendState) -> Result<(), String> {
        if s.stale_served > 0 {
            return Err(format!(
                "stale factor served: scorer observed data_v={} with factor_v={}",
                s.data_v, s.factor_v
            ));
        }
        Ok(())
    }
}

// ---- bounded proofs (kani) -------------------------------------------------
//
// The CI `verify-core` job (continue-on-error) runs these under `cargo
// kani`. Where `explore()` enumerates interleavings of a fixed thread
// count exhaustively, the harnesses below let the solver pick a fully
// nondeterministic bounded schedule — same models, different prover.
#[cfg(kani)]
mod verification {
    use super::*;

    /// No bounded schedule of two requesters over the shipped cache
    /// protocol breaks an invariant, and every completed schedule
    /// satisfies the stats identity.
    #[kani::proof]
    #[kani::unwind(22)]
    fn cache_model_two_requesters_bounded_safe() {
        let m = CacheModel::correct(2, false);
        let mut s = m.init();
        for _ in 0..18 {
            let tid: usize = kani::any();
            kani::assume(tid < m.threads());
            if m.enabled(&s, tid) {
                m.step(&mut s, tid);
                assert!(m.invariant(&s).is_ok(), "cache invariant violated");
            }
        }
        if (0..m.threads()).all(|t| m.done(&s, t)) {
            assert!(m.final_check(&s).is_ok(), "stats identity violated");
        }
    }

    /// The append guard serves no stale factor under any bounded
    /// schedule of one appender and one scorer.
    #[kani::proof]
    #[kani::unwind(20)]
    fn append_guard_bounded_serves_no_stale_factor() {
        let m = AppendModel { scorers: 1, release_early: false };
        let mut s = m.init();
        for _ in 0..16 {
            let tid: usize = kani::any();
            kani::assume(tid < m.threads());
            if m.enabled(&s, tid) {
                m.step(&mut s, tid);
                assert!(m.invariant(&s).is_ok(), "stale factor served");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_model_exhaustive_clean() {
        let m = CacheModel::correct(3, true);
        let r = check_model(&m, &Options::default()).expect("correct cache protocol holds");
        assert!(r.completed_schedules > 0, "explored to completion");
        assert_eq!(r.truncated, 0, "default depth covers the full model");
        assert!(r.distinct_states > 50, "nontrivial state space");
    }

    #[test]
    fn double_eval_bug_yields_replayable_counterexample() {
        // The pre-PR-1 race: check and insert in two lock spans.
        let m = CacheModel { two_phase_claim: true, ..CacheModel::correct(2, false) };
        let cex = check_model(&m, &Options::default()).expect_err("two-phase claim double-evals");
        assert!(cex.message.contains("double eval"), "message: {}", cex.message);
        assert!(!cex.schedule.is_empty());
        // The schedule replays deterministically to the same violation.
        let replayed = replay(&m, &cex.schedule);
        assert_eq!(replayed.violation.as_deref(), Some(cex.message.as_str()));
        assert!(replayed.trace.contains("thread"), "trace renders steps:\n{}", replayed.trace);
        // And the render round-trips the schedule for copy-paste repro.
        assert!(cex.render().contains(&format!("{:?}", cex.schedule)));
    }

    #[test]
    fn lost_wakeup_bug_detected_as_deadlock() {
        let m = CacheModel { skip_notify: true, ..CacheModel::correct(2, false) };
        let cex = explore(&m, &Options::default()).expect_err("skipping notify strands a waiter");
        assert!(cex.message.contains("deadlock"), "message: {}", cex.message);
        let replayed = replay(&m, &cex.schedule);
        assert!(replayed.violation.expect("replay deadlocks too").contains("deadlock"));
    }

    #[test]
    fn unpinned_evict_bug_detected() {
        let m = CacheModel { unpinned_evict: true, ..CacheModel::correct(2, true) };
        let cex = explore(&m, &Options::default()).expect_err("unpinned eviction strands waiters");
        assert!(
            cex.message.contains("pinned entry evicted") || cex.message.contains("double eval"),
            "message: {}",
            cex.message
        );
    }

    #[test]
    fn jobs_shutdown_locked_notify_clean() {
        let r = check_model(&JobsModel { locked_notify: true }, &Options::default())
            .expect("lock-bracketed shutdown notify drains the worker in every interleaving");
        assert!(r.completed_schedules > 0);
        assert_eq!(r.truncated, 0);
    }

    #[test]
    fn jobs_shutdown_unlocked_notify_misses_wakeup() {
        // The pre-fix shutdown: flag store + notify_all without the
        // queue mutex. The explorer finds the parked-forever worker.
        let m = JobsModel { locked_notify: false };
        let cex = explore(&m, &Options::default()).expect_err("unlocked notify loses the wakeup");
        assert!(cex.message.contains("deadlock"), "message: {}", cex.message);
        let replayed = replay(&m, &cex.schedule);
        assert!(replayed.violation.expect("replays to the hang").contains("deadlock"));
    }

    #[test]
    fn append_guard_exhaustive_clean() {
        let r = check_model(&AppendModel { scorers: 2, release_early: false }, &Options::default())
            .expect("guarded append never serves a stale factor");
        assert!(r.completed_schedules > 0);
        assert_eq!(r.truncated, 0);
    }

    #[test]
    fn append_guard_release_early_serves_stale_factor() {
        let m = AppendModel { scorers: 1, release_early: true };
        let cex = explore(&m, &Options::default()).expect_err("early release exposes stale factors");
        assert!(cex.message.contains("stale factor"), "message: {}", cex.message);
    }

    #[test]
    fn depth_bound_truncates_instead_of_failing() {
        let m = CacheModel { two_phase_claim: true, ..CacheModel::correct(2, false) };
        let r = explore(&m, &Options { max_depth: 2, max_states: 1 << 20 })
            .expect("bug is deeper than 2 steps, bounded run stays clean");
        assert!(r.truncated > 0, "bounded run reports what it cut off");
    }

    #[test]
    fn options_from_env_reads_depth() {
        // Parse-level check only; avoids mutating the process env in a
        // threaded test binary.
        let o = Options::default();
        assert_eq!(o.max_depth, 64);
    }
}
