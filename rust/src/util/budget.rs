//! End-to-end deadline budgets and the typed resilience errors.
//!
//! A [`Budget`] is an optional absolute deadline threaded from the
//! entry point (CLI `--deadline-ms`, the `deadline_ms` job option, or
//! the `deadline_ms` field of `POST /v1/score_batch`) down through the
//! job manager, the shard dispatch layer and the follower's chunked
//! scoring loop. Every layer consults the *remaining* budget before
//! committing to work it couldn't finish in time — retries stop, socket
//! timeouts clamp, followers cancel cooperatively — so an expired
//! budget always resolves to either a degraded-but-exact local result
//! or a typed [`DeadlineExceeded`] error, never a hang.
//!
//! [`Overloaded`] is the admission-control twin: the server sheds work
//! it can't queue (bounded admission) or afford (memory high-water)
//! with a typed error that maps to HTTP 429/503 + `Retry-After`.

use std::time::{Duration, Instant};

/// An optional absolute deadline. `Budget::none()` is unlimited and
/// costs nothing to consult; a limited budget is a single `Instant`
/// comparison. Copy-cheap by design — it crosses thread boundaries
/// into lane controllers and worker threads.
#[derive(Clone, Copy, Debug, Default)]
pub struct Budget {
    deadline: Option<Instant>,
}

impl Budget {
    /// The unlimited budget: never expires, clamps nothing.
    pub fn none() -> Budget {
        Budget { deadline: None }
    }

    /// A budget expiring `ms` milliseconds from now; `None` ⇒ unlimited.
    pub fn from_ms(ms: Option<u64>) -> Budget {
        Budget { deadline: ms.map(|m| Instant::now() + Duration::from_millis(m)) }
    }

    /// A budget expiring at an absolute instant.
    pub fn until(deadline: Instant) -> Budget {
        Budget { deadline: Some(deadline) }
    }

    /// The absolute deadline, when limited.
    pub fn deadline(&self) -> Option<Instant> {
        self.deadline
    }

    /// True when a deadline is set at all.
    pub fn is_limited(&self) -> bool {
        self.deadline.is_some()
    }

    /// True once the deadline has passed (never for unlimited budgets).
    pub fn expired(&self) -> bool {
        matches!(self.deadline, Some(d) if Instant::now() >= d)
    }

    /// Time left, when limited. Expired budgets report zero.
    pub fn remaining(&self) -> Option<Duration> {
        self.deadline.map(|d| d.saturating_duration_since(Instant::now()))
    }

    /// Milliseconds left, when limited (zero once expired).
    pub fn remaining_ms(&self) -> Option<u64> {
        self.remaining().map(|d| d.as_millis() as u64)
    }

    /// Clamp a nominal timeout by the remaining budget, flooring at
    /// 1 ms so socket APIs (which reject a zero timeout) still get a
    /// valid — immediately-expiring — value.
    pub fn clamp(&self, nominal: Duration) -> Duration {
        match self.remaining() {
            Some(rem) => nominal.min(rem).max(Duration::from_millis(1)),
            None => nominal,
        }
    }

    /// Does the remaining budget cover `cost`? Unlimited budgets cover
    /// everything; this is the retry/hedge gate ("don't re-dispatch to
    /// a follower whose EWMA outlives the deadline").
    pub fn covers(&self, cost: Duration) -> bool {
        match self.remaining() {
            Some(rem) => rem >= cost,
            None => true,
        }
    }
}

/// Typed error for a budget that ran out before the work finished.
/// Downcast from `anyhow::Error` at the HTTP boundary → 504.
#[derive(Debug, Clone)]
pub struct DeadlineExceeded {
    /// What ran out of time (a stage or endpoint name).
    pub what: String,
}

impl DeadlineExceeded {
    pub fn new(what: impl Into<String>) -> DeadlineExceeded {
        DeadlineExceeded { what: what.into() }
    }
}

impl std::fmt::Display for DeadlineExceeded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "deadline exceeded: {}", self.what)
    }
}

impl std::error::Error for DeadlineExceeded {}

/// Typed error for work the server refused to take on: a full
/// admission queue (→ 429 + `Retry-After`) or a breached memory
/// high-water mark (→ 503 after shedding caches didn't recover
/// enough).
#[derive(Debug, Clone)]
pub struct Overloaded {
    /// Why admission was refused.
    pub what: String,
    /// Suggested client wait before retrying, for `Retry-After`.
    pub retry_after: Option<Duration>,
}

impl Overloaded {
    pub fn new(what: impl Into<String>) -> Overloaded {
        Overloaded { what: what.into(), retry_after: None }
    }

    pub fn retry_after(mut self, d: Duration) -> Overloaded {
        self.retry_after = Some(d);
        self
    }
}

impl std::fmt::Display for Overloaded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "overloaded: {}", self.what)
    }
}

impl std::error::Error for Overloaded {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlimited_budget_never_expires_or_clamps() {
        let b = Budget::none();
        assert!(!b.is_limited());
        assert!(!b.expired());
        assert_eq!(b.remaining(), None);
        assert_eq!(b.clamp(Duration::from_secs(10)), Duration::from_secs(10));
        assert!(b.covers(Duration::from_secs(3600)));
    }

    #[test]
    fn limited_budget_expires_and_clamps() {
        let b = Budget::until(Instant::now() + Duration::from_secs(5));
        assert!(b.is_limited());
        assert!(!b.expired());
        let rem = b.remaining().unwrap();
        assert!(rem <= Duration::from_secs(5) && rem > Duration::from_secs(4));
        assert_eq!(b.clamp(Duration::from_secs(1)), Duration::from_secs(1), "short stays");
        assert!(b.clamp(Duration::from_secs(60)) <= Duration::from_secs(5), "long clamps");
        assert!(b.covers(Duration::from_secs(1)));
        assert!(!b.covers(Duration::from_secs(60)));
    }

    #[test]
    fn expired_budget_floors_at_one_ms() {
        let past = Instant::now() - Duration::from_millis(10);
        let b = Budget::until(past);
        assert!(b.expired());
        assert_eq!(b.remaining(), Some(Duration::ZERO));
        assert_eq!(b.remaining_ms(), Some(0));
        assert_eq!(b.clamp(Duration::from_secs(10)), Duration::from_millis(1));
        assert!(!b.covers(Duration::from_millis(1)));
        assert!(b.covers(Duration::ZERO));
    }

    #[test]
    fn from_ms_none_is_unlimited() {
        assert!(!Budget::from_ms(None).is_limited());
        assert!(Budget::from_ms(Some(50)).is_limited());
    }

    #[test]
    fn typed_errors_downcast_from_anyhow() {
        let e: anyhow::Error = DeadlineExceeded::new("score_batch").into();
        assert!(e.downcast_ref::<DeadlineExceeded>().is_some());
        assert_eq!(e.to_string(), "deadline exceeded: score_batch");

        let e: anyhow::Error =
            Overloaded::new("admission queue full").retry_after(Duration::from_secs(2)).into();
        let o = e.downcast_ref::<Overloaded>().unwrap();
        assert_eq!(o.retry_after, Some(Duration::from_secs(2)));
        assert_eq!(e.to_string(), "overloaded: admission queue full");
    }
}
