//! PCG-64 (XSL-RR 128/64) pseudo-random generator plus the sampling
//! utilities the data generators and tests need.
//!
//! Deterministic, seedable, no external deps. Matches the reference PCG
//! output function; statistical quality is far beyond what the experiments
//! require (they only need reproducible i.i.d. draws).

/// PCG-64 XSL-RR generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed; distinct seeds give independent
    /// streams for all practical purposes.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to expand the seed into state/increment.
        let mut sm = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let state = ((next() as u128) << 64) | next() as u128;
        let inc = (((next() as u128) << 64) | next() as u128) | 1;
        let mut rng = Pcg64 { state, inc };
        rng.next_u64(); // burn-in so state mixes the increment
        rng
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self) -> Pcg64 {
        Pcg64::new(self.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free enough for our n ≪ 2^64.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; generation is not a hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with the given mean / standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang; used for Dirichlet CPT sampling.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost trick: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            return g * self.uniform().powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4) || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha * 1) over k categories.
    pub fn dirichlet(&mut self, k: usize, alpha: f64) -> Vec<f64> {
        let mut v: Vec<f64> = (0..k).map(|_| self.gamma(alpha).max(1e-12)).collect();
        let s: f64 = v.iter().sum();
        for x in &mut v {
            *x /= s;
        }
        v
    }

    /// Draw an index from a discrete distribution given by `probs`.
    pub fn categorical(&mut self, probs: &[f64]) -> usize {
        let u = self.uniform();
        let mut acc = 0.0;
        for (i, p) in probs.iter().enumerate() {
            acc += p;
            if u < acc {
                return i;
            }
        }
        probs.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k ≤ n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Random boolean with probability p of being true.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_diverge() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Pcg64::new(11);
        for &shape in &[0.5, 1.0, 3.0, 9.0] {
            let n = 50_000;
            let mean = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!((mean - shape).abs() < 0.1 * shape.max(1.0), "shape {shape} mean {mean}");
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Pcg64::new(5);
        let p = r.dirichlet(6, 1.0);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(p.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn categorical_respects_probs() {
        let mut r = Pcg64::new(9);
        let probs = [0.7, 0.2, 0.1];
        let n = 100_000;
        let mut counts = [0usize; 3];
        for _ in 0..n {
            counts[r.categorical(&probs)] += 1;
        }
        for i in 0..3 {
            let f = counts[i] as f64 / n as f64;
            assert!((f - probs[i]).abs() < 0.01, "cat {i}: {f}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(4);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::new(6);
        let s = r.sample_indices(100, 10);
        assert_eq!(s.len(), 10);
        let mut d = s.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 10);
    }
}
