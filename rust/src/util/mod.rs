//! Shared substrates: RNG, special functions, stats, CSV/JSON output,
//! timing, CLI parsing and a miniature property-testing harness.
//!
//! The offline build image vendors only the `xla` crate's dependency tree,
//! so the usual ecosystem crates (`rand`, `statrs`, `serde`, `clap`,
//! `criterion`, `proptest`) are unavailable; these modules replace exactly
//! the functionality the rest of the library needs.

pub mod rng;
pub mod special;
pub mod stats;
pub mod csv;
pub mod timing;
pub mod cli;
pub mod prop;
pub mod backoff;
pub mod budget;
pub mod model;
pub mod lockorder;

pub use rng::Pcg64;
pub use timing::Stopwatch;
pub use backoff::Backoff;
pub use budget::{Budget, DeadlineExceeded, Overloaded};
