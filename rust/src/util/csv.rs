//! Minimal CSV + table writers for the bench harness (`results/*.csv`)
//! and the paper-shaped console tables.

use std::fs::{self, File};
use std::io::{self, Write};
use std::path::Path;

/// A CSV writer with a fixed header.
pub struct CsvWriter {
    file: File,
    cols: usize,
}

impl CsvWriter {
    /// Create `path` (creating parent dirs) and write the header row.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> io::Result<CsvWriter> {
        if let Some(dir) = path.as_ref().parent() {
            fs::create_dir_all(dir)?;
        }
        let mut file = File::create(path)?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter { file, cols: header.len() })
    }

    /// Write one row; panics (in debug) if the column count mismatches.
    pub fn row(&mut self, fields: &[String]) -> io::Result<()> {
        debug_assert_eq!(fields.len(), self.cols, "csv row arity mismatch");
        writeln!(self.file, "{}", fields.join(","))
    }

    /// Convenience: write a row of display-able values.
    pub fn rowd(&mut self, fields: &[&dyn std::fmt::Display]) -> io::Result<()> {
        let strs: Vec<String> = fields.iter().map(|f| f.to_string()).collect();
        self.row(&strs)
    }
}

/// Fixed-width console table, used to print paper-shaped tables.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, fields: &[String]) {
        assert_eq!(fields.len(), self.header.len());
        self.rows.push(fields.to_vec());
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = h.chars().count();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            let padded: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = w[i]))
                .collect();
            out.push_str("| ");
            out.push_str(&padded.join(" | "));
            out.push_str(" |\n");
        };
        line(&mut out, &self.header);
        out.push('|');
        for wi in &w {
            out.push_str(&"-".repeat(wi + 2));
            out.push('|');
        }
        out.push('\n');
        for r in &self.rows {
            line(&mut out, r);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let path = std::env::temp_dir().join("cvlr_csv_test.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&["1".into(), "x".into()]).unwrap();
            w.rowd(&[&2.5, &"y"]).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,x\n2.5,y\n");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["method", "f1"]);
        t.row(&["CV-LR".into(), "0.94".into()]);
        let s = t.render();
        assert!(s.contains("| method | f1   |") || s.contains("| method |"));
        assert!(s.contains("CV-LR"));
    }
}
