//! Minimal CSV readers + writers: the bench harness (`results/*.csv`),
//! the paper-shaped console tables, and the parser behind CSV dataset
//! ingestion (`server::registry`, `cvlr discover --data file.csv`).

use std::fs::{self, File};
use std::io::{self, Write};
use std::path::Path;

use anyhow::bail;

/// Parse CSV text into rows of string fields.
///
/// RFC-4180-lite: comma separator, `"`-quoted fields with `""` escapes
/// (quoted fields may contain commas and newlines), `\n` or `\r\n` row
/// endings. Blank lines are skipped; every remaining row must have the
/// same arity. Errors on unterminated quotes and ragged rows carry the
/// **source line number**, so a malformed upload (far more likely once
/// rows arrive as a stream) points at the offending input line instead
/// of a logical row index.
pub fn parse_csv(text: &str) -> anyhow::Result<Vec<Vec<String>>> {
    let mut rows: Vec<Vec<String>> = Vec::new();
    // 1-based source line each parsed row started on
    let mut row_lines: Vec<usize> = Vec::new();
    let mut row: Vec<String> = Vec::new();
    let mut field = String::new();
    let mut in_quotes = false;
    let mut quote_line = 0usize;
    let mut line_has_content = false;
    let mut line = 1usize;
    let mut row_line = 1usize;
    let mut chars = text.chars().peekable();
    while let Some(c) = chars.next() {
        if in_quotes {
            if c == '"' {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    field.push('"');
                } else {
                    in_quotes = false;
                }
            } else {
                if c == '\n' {
                    line += 1;
                }
                field.push(c);
            }
            continue;
        }
        match c {
            '"' if field.is_empty() => {
                in_quotes = true;
                quote_line = line;
                line_has_content = true;
            }
            ',' => {
                row.push(std::mem::take(&mut field));
                line_has_content = true;
            }
            '\r' | '\n' => {
                if c == '\r' && chars.peek() == Some(&'\n') {
                    chars.next();
                }
                if line_has_content || !field.is_empty() {
                    row.push(std::mem::take(&mut field));
                    rows.push(std::mem::take(&mut row));
                    row_lines.push(row_line);
                }
                line_has_content = false;
                line += 1;
                row_line = line;
            }
            _ => {
                field.push(c);
                line_has_content = true;
            }
        }
    }
    if in_quotes {
        bail!("csv: unterminated quoted field starting on line {quote_line}");
    }
    if line_has_content || !field.is_empty() {
        row.push(field);
        rows.push(row);
        row_lines.push(row_line);
    }
    if let Some(first) = rows.first() {
        let arity = first.len();
        for (i, r) in rows.iter().enumerate() {
            if r.len() != arity {
                bail!(
                    "csv: line {} has {} fields, expected {arity} (set by line {})",
                    row_lines[i],
                    r.len(),
                    row_lines[0]
                );
            }
        }
    }
    Ok(rows)
}

/// A CSV writer with a fixed header.
pub struct CsvWriter {
    file: File,
    cols: usize,
}

impl CsvWriter {
    /// Create `path` (creating parent dirs) and write the header row.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> io::Result<CsvWriter> {
        if let Some(dir) = path.as_ref().parent() {
            fs::create_dir_all(dir)?;
        }
        let mut file = File::create(path)?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter { file, cols: header.len() })
    }

    /// Write one row; panics (in debug) if the column count mismatches.
    pub fn row(&mut self, fields: &[String]) -> io::Result<()> {
        debug_assert_eq!(fields.len(), self.cols, "csv row arity mismatch");
        writeln!(self.file, "{}", fields.join(","))
    }

    /// Convenience: write a row of display-able values.
    pub fn rowd(&mut self, fields: &[&dyn std::fmt::Display]) -> io::Result<()> {
        let strs: Vec<String> = fields.iter().map(|f| f.to_string()).collect();
        self.row(&strs)
    }
}

/// Fixed-width console table, used to print paper-shaped tables.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, fields: &[String]) {
        assert_eq!(fields.len(), self.header.len());
        self.rows.push(fields.to_vec());
    }

    /// The header row.
    pub fn header(&self) -> &[String] {
        &self.header
    }

    /// The data rows pushed so far.
    pub fn data_rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = h.chars().count();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            let padded: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = w[i]))
                .collect();
            out.push_str("| ");
            out.push_str(&padded.join(" | "));
            out.push_str(" |\n");
        };
        line(&mut out, &self.header);
        out.push('|');
        for wi in &w {
            out.push_str(&"-".repeat(wi + 2));
            out.push('|');
        }
        out.push('\n');
        for r in &self.rows {
            line(&mut out, r);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let path = std::env::temp_dir().join("cvlr_csv_test.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&["1".into(), "x".into()]).unwrap();
            w.rowd(&[&2.5, &"y"]).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,x\n2.5,y\n");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn parse_csv_basic() {
        let rows = parse_csv("a,b\n1,2\n3,4\n").unwrap();
        assert_eq!(rows, vec![vec!["a", "b"], vec!["1", "2"], vec!["3", "4"]]);
    }

    #[test]
    fn parse_csv_quotes_crlf_and_blank_lines() {
        let rows = parse_csv("x,\"he said \"\"hi\"\"\"\r\n\r\n\"a,b\",2").unwrap();
        assert_eq!(rows, vec![vec!["x", "he said \"hi\""], vec!["a,b", "2"]]);
    }

    #[test]
    fn parse_csv_quoted_newline_inside_field() {
        let rows = parse_csv("\"l1\nl2\",z\n").unwrap();
        assert_eq!(rows, vec![vec!["l1\nl2", "z"]]);
    }

    #[test]
    fn parse_csv_rejects_ragged_rows() {
        assert!(parse_csv("a,b\n1\n").is_err());
    }

    #[test]
    fn ragged_row_error_reports_source_line() {
        // blank line offsets the physical line from the logical row
        let err = parse_csv("a,b\n1,2\n\n3\n").unwrap_err().to_string();
        assert!(err.contains("line 4"), "{err}");
        assert!(err.contains("expected 2"), "{err}");
        assert!(err.contains("line 1"), "must name the arity-setting line: {err}");
    }

    #[test]
    fn parse_csv_rejects_unterminated_quote() {
        assert!(parse_csv("\"oops\n").is_err());
    }

    #[test]
    fn unterminated_quote_error_reports_opening_line() {
        let err = parse_csv("a,b\n1,\"oops\n2,3\n").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn parse_csv_empty_text_is_empty() {
        assert!(parse_csv("").unwrap().is_empty());
        assert!(parse_csv("\n\n").unwrap().is_empty());
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["method", "f1"]);
        t.row(&["CV-LR".into(), "0.94".into()]);
        let s = t.render();
        assert!(s.contains("| method | f1   |") || s.contains("| method |"));
        assert!(s.contains("CV-LR"));
    }
}
