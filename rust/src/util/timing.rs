//! Wall-clock timing helpers used by the bench harness and the
//! coordinator's metrics.

use std::time::Instant;

/// A simple stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Elapsed seconds.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds.
    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }

    pub fn restart(&mut self) {
        self.start = Instant::now();
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let sw = Stopwatch::start();
    let out = f();
    (out, sw.secs())
}

/// Benchmark statistics over repeated timed runs.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub reps: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub std_s: f64,
}

/// Run `f` for `reps` repetitions (after `warmup` unmeasured runs) and
/// report timing statistics. This is the criterion replacement used by
/// `rust/benches/*` (criterion is not available offline).
pub fn bench_fn(warmup: usize, reps: usize, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let sw = Stopwatch::start();
        f();
        times.push(sw.secs());
    }
    let mean = times.iter().sum::<f64>() / reps.max(1) as f64;
    let var = if reps > 1 {
        times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / (reps - 1) as f64
    } else {
        0.0
    };
    BenchStats {
        reps,
        mean_s: mean,
        min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
        max_s: times.iter().cloned().fold(0.0, f64::max),
        std_s: var.sqrt(),
    }
}

/// Pretty seconds (ns/µs/ms/s auto-scale).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_fn_counts_reps() {
        let mut calls = 0;
        let st = bench_fn(2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(st.reps, 5);
        assert!(st.min_s <= st.mean_s && st.mean_s <= st.max_s);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_secs(3e-9).ends_with("ns"));
        assert!(fmt_secs(3e-6).ends_with("µs"));
        assert!(fmt_secs(3e-3).ends_with("ms"));
        assert!(fmt_secs(3.0).ends_with('s'));
    }
}
