//! Wall-clock timing helpers used by the bench harness and the
//! coordinator's metrics.

use std::time::Instant;

/// A simple stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Elapsed seconds.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds.
    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }

    pub fn restart(&mut self) {
        self.start = Instant::now();
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let sw = Stopwatch::start();
    let out = f();
    (out, sw.secs())
}

/// Benchmark statistics over repeated timed runs.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub reps: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub std_s: f64,
    /// Median (nearest-rank). At the small rep counts CI uses, the
    /// mean is skew-fragile — one cold-cache outlier moves it; bench
    /// consumers prefer p50 when present.
    pub p50_s: f64,
    /// 95th percentile (nearest-rank).
    pub p95_s: f64,
}

/// Nearest-rank percentile of an ascending-sorted sample: the value at
/// rank ⌈q·n⌉ (1-based), so `q=0.5` of 5 samples is the 3rd and
/// `q=1.0` is the max. Empty input yields 0.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Run `f` for `reps` repetitions (after `warmup` unmeasured runs) and
/// report timing statistics. This is the criterion replacement used by
/// `rust/benches/*` (criterion is not available offline).
pub fn bench_fn(warmup: usize, reps: usize, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let sw = Stopwatch::start();
        f();
        times.push(sw.secs());
    }
    let mean = times.iter().sum::<f64>() / reps.max(1) as f64;
    let var = if reps > 1 {
        times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / (reps - 1) as f64
    } else {
        0.0
    };
    let mut sorted = times.clone();
    sorted.sort_by(|a, b| a.total_cmp(b));
    BenchStats {
        reps,
        mean_s: mean,
        min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
        max_s: times.iter().cloned().fold(0.0, f64::max),
        std_s: var.sqrt(),
        p50_s: percentile(&sorted, 0.5),
        p95_s: percentile(&sorted, 0.95),
    }
}

/// Pretty seconds (ns/µs/ms/s auto-scale).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_fn_counts_reps() {
        let mut calls = 0;
        let st = bench_fn(2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(st.reps, 5);
        assert!(st.min_s <= st.mean_s && st.mean_s <= st.max_s);
    }

    #[test]
    fn bench_fn_percentiles_bracket_the_sample() {
        let st = bench_fn(0, 9, || std::thread::sleep(std::time::Duration::from_micros(50)));
        assert!(st.min_s <= st.p50_s && st.p50_s <= st.p95_s && st.p95_s <= st.max_s);
    }

    #[test]
    fn percentile_is_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.5), 3.0, "median of 5 is the 3rd value");
        assert_eq!(percentile(&xs, 0.95), 5.0, "⌈0.95·5⌉ = 5th value");
        assert_eq!(percentile(&xs, 1.0), 5.0);
        assert_eq!(percentile(&xs, 0.0), 1.0, "rank clamps to the first value");
        assert_eq!(percentile(&[7.0], 0.5), 7.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_secs(3e-9).ends_with("ns"));
        assert!(fmt_secs(3e-6).ends_with("µs"));
        assert!(fmt_secs(3e-3).ends_with("ms"));
        assert!(fmt_secs(3.0).ends_with('s'));
    }
}
