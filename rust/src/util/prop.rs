//! Miniature property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs a property over `cases` random
//! inputs generated from a deterministic per-case RNG; on failure it
//! panics with the reproducing seed. No shrinking — the generators used
//! by the library produce small inputs by construction.
//!
//! The `CVLR_PROP_CASES` environment variable multiplies every
//! property's case count (default 1): the weekly exhaustive CI tier
//! sets `CVLR_PROP_CASES=20` to run the same properties twenty times
//! deeper without touching the tests. Seeds stay a pure function of
//! the case index, so a failure reported under a high multiplier
//! reproduces at the default one by seed.

use super::rng::Pcg64;

/// Parse a case-count multiplier (`CVLR_PROP_CASES` semantics): a
/// positive integer, anything unset/empty/invalid → 1. Split from
/// [`cases_multiplier`] so the parsing is testable without mutating
/// the process environment.
pub fn parse_multiplier(raw: Option<&str>) -> usize {
    raw.and_then(|s| s.trim().parse::<usize>().ok()).filter(|&m| m >= 1).unwrap_or(1)
}

/// The process-wide case multiplier from `CVLR_PROP_CASES`.
pub fn cases_multiplier() -> usize {
    parse_multiplier(std::env::var("CVLR_PROP_CASES").ok().as_deref())
}

/// Run `prop` for `cases` deterministic random cases (times the
/// `CVLR_PROP_CASES` multiplier). The property gets a seeded RNG and
/// returns `Ok(())` or a failure description.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Pcg64) -> Result<(), String>,
{
    for case in 0..cases * cases_multiplier() {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Pcg64::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property `{name}` failed at case {case} (seed={seed:#x}): {msg}");
        }
    }
}

/// Assert helper producing `Result` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivial", 17, |_| {
            n += 1;
            Ok(())
        });
        // `n` is 17 × the ambient multiplier, whatever tier this test
        // runs under
        assert_eq!(n, 17 * cases_multiplier());
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_panics_with_seed() {
        check("fails", 5, |rng| {
            let x = rng.uniform();
            if x >= 0.0 {
                Err("always".to_string())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn multiplier_parsing_defaults_and_bounds() {
        assert_eq!(parse_multiplier(None), 1, "unset → 1");
        assert_eq!(parse_multiplier(Some("")), 1, "empty → 1");
        assert_eq!(parse_multiplier(Some("banana")), 1, "garbage → 1");
        assert_eq!(parse_multiplier(Some("0")), 1, "zero would skip every property");
        assert_eq!(parse_multiplier(Some("1")), 1);
        assert_eq!(parse_multiplier(Some("20")), 20);
        assert_eq!(parse_multiplier(Some(" 20 ")), 20, "whitespace tolerated");
    }
}
