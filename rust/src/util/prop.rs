//! Miniature property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs a property over `cases` random
//! inputs generated from a deterministic per-case RNG; on failure it
//! panics with the reproducing seed. No shrinking — the generators used
//! by the library produce small inputs by construction.

use super::rng::Pcg64;

/// Run `prop` for `cases` deterministic random cases. The property gets a
/// seeded RNG and returns `Ok(())` or a failure description.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Pcg64) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Pcg64::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property `{name}` failed at case {case} (seed={seed:#x}): {msg}");
        }
    }
}

/// Assert helper producing `Result` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivial", 17, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 17);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_panics_with_seed() {
        check("fails", 5, |rng| {
            let x = rng.uniform();
            if x >= 0.0 {
                Err("always".to_string())
            } else {
                Ok(())
            }
        });
    }
}
