//! Jittered exponential backoff — the one retry-delay policy shared by
//! every resend loop in the crate.
//!
//! Three call sites used to hand-roll this independently (the follower
//! pool's retry delay, the dataset-push 409 re-register pause, and the
//! fleet-metrics stale-resend); they now all go through [`Backoff`]:
//! `base × 2^(attempt−1)`, capped, scaled by a uniform jitter factor in
//! [0.5, 1) drawn from a caller-owned seeded [`Pcg64`]. Deterministic
//! per seed — chaos schedules replay bit-for-bit — and bounded above by
//! the cap, so the worst-case delay of a retry ladder is computable.

use std::time::Duration;

use crate::util::Pcg64;

/// A jittered exponential backoff policy. Stateless per attempt: the
/// caller tracks the attempt number and owns the jitter RNG, so one
/// policy can serve many concurrent retry ladders.
#[derive(Clone, Copy, Debug)]
pub struct Backoff {
    /// Delay base for the first retry.
    pub base: Duration,
    /// Ceiling applied before jitter.
    pub cap: Duration,
}

impl Backoff {
    pub fn new(base: Duration, cap: Duration) -> Backoff {
        Backoff { base, cap }
    }

    /// The un-jittered delay for retry `attempt` (1-based):
    /// `base × 2^(attempt−1)`, capped. Attempt 0 is treated as 1.
    pub fn nominal(&self, attempt: u32) -> Duration {
        let scaled = self.base.as_secs_f64() * 2f64.powi(attempt.saturating_sub(1).min(62) as i32);
        Duration::from_secs_f64(scaled.min(self.cap.as_secs_f64()))
    }

    /// The jittered delay for retry `attempt`: nominal scaled by a
    /// uniform factor in [0.5, 1) from `rng`. Always within
    /// [nominal/2, nominal] — a retry ladder's total delay is bounded.
    pub fn delay(&self, attempt: u32, rng: &mut Pcg64) -> Duration {
        let jitter = 0.5 + 0.5 * rng.uniform();
        Duration::from_secs_f64(self.nominal(attempt).as_secs_f64() * jitter)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nominal_doubles_and_caps() {
        let b = Backoff::new(Duration::from_millis(50), Duration::from_millis(400));
        assert_eq!(b.nominal(0), Duration::from_millis(50), "attempt 0 behaves as 1");
        assert_eq!(b.nominal(1), Duration::from_millis(50));
        assert_eq!(b.nominal(2), Duration::from_millis(100));
        assert_eq!(b.nominal(3), Duration::from_millis(200));
        assert_eq!(b.nominal(4), Duration::from_millis(400));
        assert_eq!(b.nominal(5), Duration::from_millis(400), "capped");
        assert_eq!(b.nominal(64), Duration::from_millis(400), "huge attempts don't overflow");
    }

    #[test]
    fn delay_is_jittered_within_bounds() {
        let b = Backoff::new(Duration::from_millis(50), Duration::from_millis(400));
        let mut rng = Pcg64::new(0x5eed);
        for attempt in 1..=8u32 {
            let nominal = b.nominal(attempt);
            for _ in 0..64 {
                let d = b.delay(attempt, &mut rng);
                assert!(d >= nominal / 2, "attempt {attempt}: {d:?} below jitter floor");
                assert!(d <= nominal, "attempt {attempt}: {d:?} above nominal");
            }
        }
    }

    #[test]
    fn delay_is_deterministic_per_seed() {
        let b = Backoff::new(Duration::from_millis(50), Duration::from_secs(1));
        let mut a = Pcg64::new(7);
        let mut c = Pcg64::new(7);
        for attempt in 1..=6u32 {
            assert_eq!(b.delay(attempt, &mut a), b.delay(attempt, &mut c));
        }
        let mut d = Pcg64::new(8);
        let same: Vec<_> = (1..=6u32)
            .map(|i| b.delay(i, &mut Pcg64::new(7)) == b.delay(i, &mut d))
            .collect();
        assert!(same.iter().any(|eq| !eq), "different seeds give a different schedule");
    }

    #[test]
    fn worst_case_ladder_is_computable() {
        // The dispatch layer sizes its lane budget from the sum of
        // nominal delays; verify the bound the jitter respects.
        let b = Backoff::new(Duration::from_millis(50), Duration::from_millis(400));
        let mut rng = Pcg64::new(1);
        let worst: Duration = (1..=4u32).map(|i| b.nominal(i)).sum();
        let actual: Duration = (1..=4u32).map(|i| b.delay(i, &mut rng)).sum();
        assert!(actual <= worst);
        assert!(actual >= worst / 2);
    }
}
