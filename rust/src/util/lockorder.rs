//! Runtime lock-ordering detector (cargo feature `lock-order`).
//!
//! Deadlocks from inconsistent lock acquisition order are invisible to
//! example-based tests until the unlucky interleaving fires. This
//! module makes the *order* itself checkable on every run: each
//! [`Mutex`]/[`RwLock`] carries a static class name, every acquisition
//! records `held-class → acquired-class` edges into a process-global
//! acquisition-order graph, and an acquisition that would close a
//! cycle panics immediately with the offending path — on the first
//! run that ever uses the two orders, not the first run that
//! deadlocks. CI runs the full test suite with the feature enabled.
//!
//! With the feature off (the default), the wrappers are transparent
//! shims over `std::sync` with zero bookkeeping; `lock()` absorbs
//! poisoning in both modes (every value these locks guard stays
//! consistent under panic — workers already contain panics via
//! `catch_unwind`), which also satisfies the `cvlr lint` rule against
//! `.unwrap()` on lock results in the serving stack.
//!
//! Same-class edges are not recorded: sibling instances of one class
//! (e.g. two per-follower `health` locks) are ranked by the caller's
//! own discipline, and self-edges would make every reentrant *class*
//! (not lock) use a false positive.

use std::sync::PoisonError;

#[cfg(feature = "lock-order")]
mod track {
    use std::cell::RefCell;
    use std::collections::{BTreeMap, BTreeSet};
    use std::sync::Mutex;

    /// class → classes acquired while it was held. A `BTreeMap` keeps
    /// panic messages deterministic.
    static GRAPH: Mutex<BTreeMap<&'static str, BTreeSet<&'static str>>> =
        Mutex::new(BTreeMap::new());

    thread_local! {
        /// Classes this thread currently holds, in acquisition order.
        static HELD: RefCell<Vec<&'static str>> = const { RefCell::new(Vec::new()) };
    }

    /// Depth-first path search `from → … → to` over the edge graph.
    fn find_path(
        g: &BTreeMap<&'static str, BTreeSet<&'static str>>,
        from: &'static str,
        to: &'static str,
        path: &mut Vec<&'static str>,
    ) -> bool {
        if path.contains(&from) {
            return false;
        }
        path.push(from);
        if from == to {
            return true;
        }
        if let Some(nexts) = g.get(from) {
            for &n in nexts {
                if find_path(g, n, to, path) {
                    return true;
                }
            }
        }
        path.pop();
        false
    }

    pub fn acquired(class: &'static str) {
        let held: Vec<&'static str> = HELD.with(|h| h.borrow().clone());
        if !held.is_empty() && !held.contains(&class) {
            let mut g = GRAPH.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            for &h in &held {
                if h != class {
                    g.entry(h).or_default().insert(class);
                }
            }
            // A path class → … → h for any held h means some other
            // code path acquires in the opposite order: cycle.
            for &h in &held {
                let mut path = Vec::new();
                if find_path(&g, class, h, &mut path) {
                    path.push(class);
                    drop(g);
                    panic!(
                        "lock-order cycle: acquiring `{class}` while holding {held:?} \
                         closes the cycle {path:?} (some path acquires these classes \
                         in the opposite order)"
                    );
                }
            }
        }
        HELD.with(|h| h.borrow_mut().push(class));
    }

    pub fn released(class: &'static str) {
        // Guards are not necessarily dropped LIFO; remove the most
        // recent occurrence of this class.
        HELD.with(|h| {
            let mut held = h.borrow_mut();
            if let Some(pos) = held.iter().rposition(|&c| c == class) {
                held.remove(pos);
            }
        });
    }

}

#[cfg(not(feature = "lock-order"))]
mod track {
    #[inline(always)]
    pub fn acquired(_class: &'static str) {}
    #[inline(always)]
    pub fn released(_class: &'static str) {}
}

/// A `std::sync::Mutex` carrying a lock-order class name.
pub struct Mutex<T: ?Sized> {
    class: &'static str,
    inner: std::sync::Mutex<T>,
}

/// Guard for [`Mutex`]; deregisters its class on drop.
pub struct MutexGuard<'a, T: ?Sized> {
    class: &'static str,
    inner: Option<std::sync::MutexGuard<'a, T>>,
}

impl<T> Mutex<T> {
    pub fn new(class: &'static str, value: T) -> Self {
        Mutex { class, inner: std::sync::Mutex::new(value) }
    }

    /// Acquire, registering the acquisition edge(s). Absorbs poison.
    pub fn lock(&self) -> MutexGuard<'_, T> {
        track::acquired(self.class);
        let inner = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        MutexGuard { class: self.class, inner: Some(inner) }
    }

    pub fn get_mut(&mut self) -> &mut T {
        self.inner.get_mut().unwrap_or_else(PoisonError::into_inner)
    }

    pub fn into_inner(self) -> T {
        self.inner.into_inner().unwrap_or_else(PoisonError::into_inner)
    }
}

impl<T: ?Sized> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        if self.inner.take().is_some() {
            track::released(self.class);
        }
    }
}

impl<T: ?Sized> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard consumed by Condvar::wait")
    }
}

impl<T: ?Sized> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard consumed by Condvar::wait")
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mutex").field("class", &self.class).finish_non_exhaustive()
    }
}

/// A `std::sync::RwLock` carrying a lock-order class name. Readers and
/// writers register the same class — ordering cycles do not care about
/// the sharing mode.
pub struct RwLock<T: ?Sized> {
    class: &'static str,
    inner: std::sync::RwLock<T>,
}

/// Shared-read guard for [`RwLock`].
pub struct RwLockReadGuard<'a, T: ?Sized> {
    class: &'static str,
    inner: Option<std::sync::RwLockReadGuard<'a, T>>,
}

/// Exclusive-write guard for [`RwLock`].
pub struct RwLockWriteGuard<'a, T: ?Sized> {
    class: &'static str,
    inner: Option<std::sync::RwLockWriteGuard<'a, T>>,
}

impl<T> RwLock<T> {
    pub fn new(class: &'static str, value: T) -> Self {
        RwLock { class, inner: std::sync::RwLock::new(value) }
    }

    pub fn read(&self) -> RwLockReadGuard<'_, T> {
        track::acquired(self.class);
        let inner = self.inner.read().unwrap_or_else(PoisonError::into_inner);
        RwLockReadGuard { class: self.class, inner: Some(inner) }
    }

    pub fn write(&self) -> RwLockWriteGuard<'_, T> {
        track::acquired(self.class);
        let inner = self.inner.write().unwrap_or_else(PoisonError::into_inner);
        RwLockWriteGuard { class: self.class, inner: Some(inner) }
    }
}

impl<T: ?Sized> Drop for RwLockReadGuard<'_, T> {
    fn drop(&mut self) {
        if self.inner.take().is_some() {
            track::released(self.class);
        }
    }
}

impl<T: ?Sized> Drop for RwLockWriteGuard<'_, T> {
    fn drop(&mut self) {
        if self.inner.take().is_some() {
            track::released(self.class);
        }
    }
}

impl<T: ?Sized> std::ops::Deref for RwLockReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("read guard consumed")
    }
}

impl<T: ?Sized> std::ops::Deref for RwLockWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("write guard consumed")
    }
}

impl<T: ?Sized> std::ops::DerefMut for RwLockWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("write guard consumed")
    }
}

/// A `std::sync::Condvar` that understands [`MutexGuard`]: the wait
/// deregisters the mutex class while parked (the lock really is
/// released) and re-registers it on wakeup, so held-set accounting
/// stays exact across waits.
pub struct Condvar(std::sync::Condvar);

impl Condvar {
    pub fn new() -> Self {
        Condvar(std::sync::Condvar::new())
    }

    pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
        let class = guard.class;
        let std_guard = guard.inner.take().expect("guard consumed twice");
        track::released(class);
        drop(guard); // inner already taken: Drop is a no-op
        let std_guard = self.0.wait(std_guard).unwrap_or_else(PoisonError::into_inner);
        track::acquired(class);
        MutexGuard { class, inner: Some(std_guard) }
    }

    pub fn wait_timeout<'a, T>(
        &self,
        mut guard: MutexGuard<'a, T>,
        dur: std::time::Duration,
    ) -> (MutexGuard<'a, T>, std::sync::WaitTimeoutResult) {
        let class = guard.class;
        let std_guard = guard.inner.take().expect("guard consumed twice");
        track::released(class);
        drop(guard);
        let (std_guard, timed_out) = self
            .0
            .wait_timeout(std_guard, dur)
            .unwrap_or_else(PoisonError::into_inner);
        track::acquired(class);
        (MutexGuard { class, inner: Some(std_guard) }, timed_out)
    }

    pub fn notify_one(&self) {
        self.0.notify_one();
    }

    pub fn notify_all(&self) {
        self.0.notify_all();
    }
}

impl Default for Condvar {
    fn default() -> Self {
        Condvar::new()
    }
}

#[cfg(all(test, feature = "lock-order"))]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    // Class names are unique per test: the edge graph is process-global.

    #[test]
    fn consistent_order_is_clean() {
        let a = Mutex::new("t1.a", 1);
        let b = Mutex::new("t1.b", 2);
        for _ in 0..3 {
            let ga = a.lock();
            let gb = b.lock();
            assert_eq!(*ga + *gb, 3);
        }
    }

    #[test]
    fn inverted_order_panics_with_cycle_path() {
        let a = Mutex::new("t2.a", ());
        let b = Mutex::new("t2.b", ());
        {
            let _ga = a.lock();
            let _gb = b.lock();
        }
        let err = catch_unwind(AssertUnwindSafe(|| {
            let _gb = b.lock();
            let _ga = a.lock(); // t2.b → t2.a closes the cycle
        }))
        .expect_err("inversion must panic");
        let msg = err.downcast_ref::<String>().expect("panic carries a message");
        assert!(msg.contains("lock-order cycle"), "got: {msg}");
        assert!(msg.contains("t2.a") && msg.contains("t2.b"), "path names both classes: {msg}");
    }

    #[test]
    fn transitive_inversion_detected() {
        let a = Mutex::new("t3.a", ());
        let b = Mutex::new("t3.b", ());
        let c = Mutex::new("t3.c", ());
        {
            let _ga = a.lock();
            let _gb = b.lock();
        }
        {
            let _gb = b.lock();
            let _gc = c.lock();
        }
        let err = catch_unwind(AssertUnwindSafe(|| {
            let _gc = c.lock();
            let _ga = a.lock(); // t3.c → t3.a closes a → b → c → a
        }))
        .expect_err("transitive inversion must panic");
        let msg = err.downcast_ref::<String>().expect("panic carries a message");
        assert!(msg.contains("lock-order cycle"), "got: {msg}");
    }

    #[test]
    fn same_class_siblings_are_exempt() {
        let a1 = Mutex::new("t4.health", 1);
        let a2 = Mutex::new("t4.health", 2);
        let _g1 = a1.lock();
        let _g2 = a2.lock(); // same class: no self-edge, no panic
    }

    #[test]
    fn condvar_wait_releases_the_class() {
        use std::sync::Arc;
        let m = Arc::new(Mutex::new("t5.queue", false));
        let cv = Arc::new(Condvar::new());
        let other = Mutex::new("t5.other", ());
        let (m2, cv2) = (Arc::clone(&m), Arc::clone(&cv));
        let waker = std::thread::spawn(move || {
            *m2.lock() = true;
            cv2.notify_all();
        });
        let mut g = m.lock();
        while !*g {
            g = cv.wait(g);
        }
        drop(g);
        waker.join().expect("waker thread");
        // After the wait round-trip the held set is empty again, so an
        // unrelated acquisition stays clean.
        let _go = other.lock();
    }

    #[test]
    fn rwlock_read_and_write_register() {
        let r = RwLock::new("t6.reg", 5);
        assert_eq!(*r.read(), 5);
        *r.write() = 6;
        assert_eq!(*r.read(), 6);
    }
}
