//! Kernel functions, bandwidth heuristics, Gram-matrix builders and
//! centering — the substrate under both the exact CV score and the
//! low-rank CV-LR score.
//!
//! A "variable" in this library is a *column block* of a sample matrix
//! (multi-dimensional variables per paper §7.4 are blocks of width > 1).

pub mod func;
pub mod gram;

pub use func::{median_heuristic, Kernel};
pub use gram::{center_gram, gram, gram_cross};
