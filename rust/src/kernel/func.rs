//! Kernel functions and the median-distance bandwidth heuristic.
//!
//! The paper's experimental setup (§7.1) uses Gaussian/RBF kernels with
//! width = 2 × median pairwise distance for the CV score family, and the
//! plain median distance for KCI. Discrete variables use the same RBF on
//! their (integer) encodings — which is a kernel of finite rank ≤ #values
//! (Lemma 4.1) — or the delta kernel.

use crate::linalg::Mat;

/// Kernel function over row-vectors.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kernel {
    /// exp(−‖x−y‖² / (2σ²))
    Rbf { sigma: f64 },
    /// ⟨x, y⟩
    Linear,
    /// 1 if x == y else 0 (discrete delta / Kronecker).
    Delta,
    /// (⟨x,y⟩ + c)^d
    Poly { c: f64, degree: i32 },
}

impl Kernel {
    /// Evaluate k(x, y) on two equal-length slices.
    #[inline]
    pub fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        match *self {
            Kernel::Rbf { sigma } => {
                let mut d2 = 0.0;
                for i in 0..x.len() {
                    let d = x[i] - y[i];
                    d2 += d * d;
                }
                (-d2 / (2.0 * sigma * sigma)).exp()
            }
            Kernel::Linear => x.iter().zip(y).map(|(a, b)| a * b).sum(),
            Kernel::Delta => {
                if x.iter().zip(y).all(|(a, b)| a == b) {
                    1.0
                } else {
                    0.0
                }
            }
            Kernel::Poly { c, degree } => {
                let dot: f64 = x.iter().zip(y).map(|(a, b)| a * b).sum();
                (dot + c).powi(degree)
            }
        }
    }

    /// Diagonal value k(x, x).
    #[inline]
    pub fn eval_diag(&self, x: &[f64]) -> f64 {
        match *self {
            Kernel::Rbf { .. } | Kernel::Delta => 1.0,
            Kernel::Linear => x.iter().map(|a| a * a).sum(),
            Kernel::Poly { c, degree } => {
                let dot: f64 = x.iter().map(|a| a * a).sum();
                (dot + c).powi(degree)
            }
        }
    }
}

/// Median pairwise Euclidean distance over the rows of `x`, estimated on
/// at most `max_pairs` random-ish pairs (deterministic stride sampling so
/// the score function stays deterministic). Returns 1.0 for degenerate
/// data. `width_factor` scales the result (the CV setting uses 2.0).
///
/// The sampled pairs are the multiples of the stride in the
/// lexicographic (i, j) pair order; the walk jumps directly from one
/// sampled pair to the next (never visiting the skipped ones), so width
/// selection is O(max_pairs + n) instead of O(n²) — same stride
/// arithmetic, identical sampled pairs, identical result.
pub fn median_heuristic(x: &Mat, width_factor: f64) -> f64 {
    let n = x.rows;
    if n < 2 {
        return 1.0;
    }
    let max_pairs = 5000usize;
    let total_pairs = n * (n - 1) / 2;
    let stride = (total_pairs / max_pairs).max(1);
    let mut dists = Vec::with_capacity(total_pairs.min(max_pairs) + 8);
    // walk the sampled pairs only: (i, j) starts at pair index 0 and
    // advances `stride` positions per step, carrying across row ends
    let (mut i, mut j) = (0usize, 1usize);
    'outer: loop {
        let mut d2 = 0.0;
        for c in 0..x.cols {
            let d = x[(i, c)] - x[(j, c)];
            d2 += d * d;
        }
        if d2 > 0.0 {
            dists.push(d2.sqrt());
            if dists.len() >= max_pairs {
                break;
            }
        }
        // jump ahead `stride` pairs
        let mut s = stride;
        while s > 0 {
            let room = n - 1 - j; // pairs left in row i after (i, j)
            if s <= room {
                j += s;
                s = 0;
            } else {
                s -= room;
                i += 1;
                if i + 1 >= n {
                    break 'outer; // past the last pair (n−2, n−1)
                }
                j = i + 1;
                s -= 1;
            }
        }
    }
    if dists.is_empty() {
        return 1.0;
    }
    let med = crate::util::stats::median(&dists);
    if med <= 0.0 {
        1.0
    } else {
        med * width_factor
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rbf_bounds_and_identity() {
        let k = Kernel::Rbf { sigma: 1.0 };
        assert!((k.eval(&[1.0, 2.0], &[1.0, 2.0]) - 1.0).abs() < 1e-15);
        let v = k.eval(&[0.0], &[3.0]);
        assert!(v > 0.0 && v < 1.0);
        assert!((v - (-4.5f64).exp()).abs() < 1e-15);
    }

    #[test]
    fn delta_kernel() {
        let k = Kernel::Delta;
        assert_eq!(k.eval(&[1.0, 2.0], &[1.0, 2.0]), 1.0);
        assert_eq!(k.eval(&[1.0, 2.0], &[1.0, 3.0]), 0.0);
    }

    #[test]
    fn linear_and_poly() {
        assert_eq!(Kernel::Linear.eval(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        let p = Kernel::Poly { c: 1.0, degree: 2 };
        assert_eq!(p.eval(&[1.0], &[2.0]), 9.0);
        assert_eq!(p.eval_diag(&[2.0]), 25.0);
    }

    #[test]
    fn median_heuristic_on_grid() {
        // points 0..10 on a line: median pairwise distance is ~3-4
        let x = Mat::from_vec(10, 1, (0..10).map(|i| i as f64).collect());
        let m = median_heuristic(&x, 1.0);
        assert!(m >= 2.0 && m <= 5.0, "median {m}");
        let m2 = median_heuristic(&x, 2.0);
        assert!((m2 - 2.0 * m).abs() < 1e-12);
    }

    #[test]
    fn median_heuristic_degenerate() {
        let x = Mat::zeros(5, 2);
        assert_eq!(median_heuristic(&x, 2.0), 1.0);
    }

    /// Brute-force oracle: median of ALL nonzero pairwise distances.
    fn brute_median(x: &Mat) -> Option<f64> {
        let mut dists = Vec::new();
        for i in 0..x.rows {
            for j in (i + 1)..x.rows {
                let d2: f64 =
                    (0..x.cols).map(|c| (x[(i, c)] - x[(j, c)]).powi(2)).sum();
                if d2 > 0.0 {
                    dists.push(d2.sqrt());
                }
            }
        }
        if dists.is_empty() {
            None
        } else {
            Some(crate::util::stats::median(&dists))
        }
    }

    /// When total_pairs < max_pairs the stride is 1 and the walk must
    /// visit every pair exactly once — the result IS the exact median.
    #[test]
    fn median_heuristic_small_n_visits_every_pair() {
        for n in [2usize, 3, 5, 17] {
            let mut x = Mat::zeros(n, 2);
            for i in 0..n {
                x[(i, 0)] = (i * i) as f64 * 0.37;
                x[(i, 1)] = -(i as f64) * 0.11;
            }
            let want = brute_median(&x).unwrap();
            let got = median_heuristic(&x, 1.0);
            assert!(
                (got - want).abs() < 1e-12,
                "n={n}: stride walk {got} != exhaustive median {want}"
            );
        }
    }

    /// All-constant columns mixed with one varying column: the constant
    /// columns contribute nothing, duplicate values in the varying
    /// column produce zero-distance pairs the walk must skip — the
    /// result is the median over the *nonzero* distances only.
    #[test]
    fn median_heuristic_constant_columns_with_one_varying() {
        let n = 12;
        let mut x = Mat::zeros(n, 4);
        for i in 0..n {
            x[(i, 0)] = 3.5; // constant
            x[(i, 1)] = -1.0; // constant
            x[(i, 2)] = (i % 3) as f64; // varying with duplicates
            x[(i, 3)] = 0.0; // constant
        }
        let want = brute_median(&x).unwrap();
        let got = median_heuristic(&x, 1.0);
        assert!(
            (got - want).abs() < 1e-12,
            "constant-column mix: {got} != {want}"
        );
        // distances here are only 1 or 2 (|i%3 − j%3|): the median must
        // be one of them, never polluted by the constant columns
        assert!(got == 1.0 || got == 2.0, "implausible median {got}");
    }

    /// n = 2 with identical rows has one pair, distance zero: degenerate.
    #[test]
    fn median_heuristic_two_identical_rows() {
        let x = Mat::from_vec(2, 2, vec![1.0, 2.0, 1.0, 2.0]);
        assert_eq!(median_heuristic(&x, 2.0), 1.0);
    }
}
