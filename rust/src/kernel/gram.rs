//! Gram (kernel) matrix construction and centering.
//!
//! These are the `O(n²)`/`O(n³)` objects the paper is trying to avoid —
//! they back the *exact* CV score (the baseline), KCI, and the test
//! oracles that the low-rank path is validated against.

use super::func::Kernel;
use crate::linalg::Mat;

/// Full kernel matrix K with K_ij = k(x_i, x_j).
pub fn gram(k: Kernel, x: &Mat) -> Mat {
    let n = x.rows;
    let mut out = Mat::zeros(n, n);
    for i in 0..n {
        out[(i, i)] = k.eval_diag(x.row(i));
        for j in (i + 1)..n {
            let v = k.eval(x.row(i), x.row(j));
            out[(i, j)] = v;
            out[(j, i)] = v;
        }
    }
    out
}

/// Cross kernel matrix K with K_ij = k(a_i, b_j)  (rows of a × rows of b).
pub fn gram_cross(k: Kernel, a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols);
    let mut out = Mat::zeros(a.rows, b.rows);
    for i in 0..a.rows {
        for j in 0..b.rows {
            out[(i, j)] = k.eval(a.row(i), b.row(j));
        }
    }
    out
}

/// Double centering K̃ = H K H with H = I − 11ᵀ/n, computed in O(n²)
/// without materializing H.
pub fn center_gram(k: &Mat) -> Mat {
    assert_eq!(k.rows, k.cols);
    let n = k.rows;
    let mut row_mean = vec![0.0; n];
    let mut total = 0.0;
    for i in 0..n {
        let mut s = 0.0;
        for j in 0..n {
            s += k[(i, j)];
        }
        row_mean[i] = s / n as f64;
        total += s;
    }
    let grand = total / (n as f64 * n as f64);
    let mut out = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            out[(i, j)] = k[(i, j)] - row_mean[i] - row_mean[j] + grand;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn rand_mat(n: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let mut m = Mat::zeros(n, d);
        for x in &mut m.data {
            *x = rng.normal();
        }
        m
    }

    #[test]
    fn gram_is_symmetric_unit_diag_rbf() {
        let x = rand_mat(12, 3, 1);
        let k = gram(Kernel::Rbf { sigma: 1.5 }, &x);
        assert!(k.is_symmetric(1e-14));
        for i in 0..12 {
            assert!((k[(i, i)] - 1.0).abs() < 1e-14);
        }
    }

    #[test]
    fn gram_cross_consistent_with_gram() {
        let x = rand_mat(8, 2, 2);
        let k = Kernel::Rbf { sigma: 0.9 };
        let full = gram(k, &x);
        let cross = gram_cross(k, &x, &x);
        assert!((&full - &cross).max_abs() < 1e-14);
    }

    #[test]
    fn centering_matches_hkh() {
        let x = rand_mat(10, 2, 3);
        let k = gram(Kernel::Rbf { sigma: 1.0 }, &x);
        // explicit H K H
        let n = 10;
        let mut h = Mat::eye(n);
        for i in 0..n {
            for j in 0..n {
                h[(i, j)] -= 1.0 / n as f64;
            }
        }
        let expect = h.matmul(&k).matmul(&h);
        let got = center_gram(&k);
        assert!((&got - &expect).max_abs() < 1e-12);
    }

    #[test]
    fn centered_rows_sum_to_zero() {
        let x = rand_mat(9, 1, 4);
        let kc = center_gram(&gram(Kernel::Rbf { sigma: 2.0 }, &x));
        for i in 0..9 {
            let s: f64 = (0..9).map(|j| kc[(i, j)]).sum();
            assert!(s.abs() < 1e-10);
        }
    }

    #[test]
    fn gram_psd_via_eig() {
        let x = rand_mat(15, 2, 5);
        let k = gram(Kernel::Rbf { sigma: 1.0 }, &x);
        let w = crate::linalg::sym_eig(&k).0;
        assert!(w.iter().all(|&v| v > -1e-9), "negative eigenvalue: {:?}", w.last());
    }
}
