//! The discovery engine: one entry point that runs any of the paper's
//! methods (CV-LR, CV, BIC, BDeu, SC, PC, MM) on a dataset and returns
//! the learned equivalence class + run statistics.

use std::sync::Arc;

use anyhow::{Context, Result};

use super::service::{ScoreService, ServiceStats};
use crate::ci::Kci;
use crate::data::Dataset;
use crate::graph::Pdag;
use crate::lowrank::LowRankConfig;
use crate::runtime::pjrt_kernel::PjrtCvLrKernel;
use crate::runtime::Runtime;
use crate::score::bdeu::BdeuScore;
use crate::score::bic::BicScore;
use crate::score::cv_exact::CvExactScore;
use crate::score::cvlr::{CvLrScore, NativeCvLrKernel};
use crate::score::marginal::MargLrScore;
use crate::score::folds::CvParams;
use crate::score::sc::ScScore;
use crate::score::LocalScore;
use crate::search::ges::{ges, GesConfig};
use crate::search::mmmb::{mmmb, MmConfig};
use crate::search::pc::{pc, PcConfig};
use crate::util::Stopwatch;

/// Which scoring/search method to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// GES + CV-LR (the paper's method).
    CvLr,
    /// GES + exact CV likelihood (the O(n³) baseline).
    Cv,
    /// GES + low-rank marginal-likelihood score (Huang'18's other
    /// generalized score, accelerated with the same dumbbell machinery).
    MargLr,
    /// GES + BIC (continuous only).
    Bic,
    /// GES + BDeu (discrete only).
    Bdeu,
    /// GES + SC (Spearman BIC).
    Sc,
    /// PC with KCI.
    Pc,
    /// MM-MB with KCI.
    Mm,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::CvLr => "CV-LR",
            Method::Cv => "CV",
            Method::MargLr => "Marg-LR",
            Method::Bic => "BIC",
            Method::Bdeu => "BDeu",
            Method::Sc => "SC",
            Method::Pc => "PC",
            Method::Mm => "MM",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "cv-lr" | "cvlr" => Some(Method::CvLr),
            "cv" => Some(Method::Cv),
            "marg-lr" | "marglr" | "marg" => Some(Method::MargLr),
            "bic" => Some(Method::Bic),
            "bdeu" => Some(Method::Bdeu),
            "sc" => Some(Method::Sc),
            "pc" => Some(Method::Pc),
            "mm" | "mm-mb" | "mmmb" => Some(Method::Mm),
            _ => None,
        }
    }
}

/// Scoring backend for the CV-LR fold kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Pure-rust f64 dumbbell algebra.
    Native,
    /// AOT XLA artifacts via PJRT (the three-layer hot path).
    Pjrt,
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct DiscoveryConfig {
    pub method: Method,
    pub engine: EngineKind,
    pub params: CvParams,
    pub lowrank: LowRankConfig,
    pub ges: GesConfig,
    /// Significance level for constraint-based methods.
    pub alpha: f64,
    /// Worker threads for the score service.
    pub workers: usize,
    /// Artifacts directory for the PJRT engine.
    pub artifacts_dir: String,
}

impl Default for DiscoveryConfig {
    fn default() -> Self {
        DiscoveryConfig {
            method: Method::CvLr,
            engine: EngineKind::Native,
            params: CvParams::default(),
            lowrank: LowRankConfig::default(),
            ges: GesConfig::default(),
            alpha: 0.05,
            workers: 1,
            artifacts_dir: "artifacts".to_string(),
        }
    }
}

/// Result of a discovery run.
pub struct DiscoveryOutcome {
    pub cpdag: Pdag,
    pub seconds: f64,
    pub method: Method,
    /// Score-service statistics (score-based methods only).
    pub score_stats: Option<ServiceStats>,
    /// CI-test count (constraint-based methods only).
    pub ci_tests: Option<u64>,
}

/// Build the local score for a score-based method.
fn make_score(ds: Arc<Dataset>, cfg: &DiscoveryConfig) -> Result<Arc<dyn LocalScore>> {
    Ok(match cfg.method {
        Method::CvLr => match cfg.engine {
            EngineKind::Native => Arc::new(CvLrScore::with_backend(
                ds,
                cfg.params,
                cfg.lowrank,
                NativeCvLrKernel,
            )),
            EngineKind::Pjrt => {
                let rt = Arc::new(
                    Runtime::load(&cfg.artifacts_dir)
                        .context("loading PJRT artifacts for the CV-LR engine")?,
                );
                Arc::new(CvLrScore::with_backend(
                    ds,
                    cfg.params,
                    cfg.lowrank,
                    PjrtCvLrKernel::new(rt),
                ))
            }
        },
        Method::Cv => Arc::new(CvExactScore::new(ds, cfg.params)),
        Method::MargLr => Arc::new(MargLrScore::new(ds)),
        Method::Bic => Arc::new(BicScore::new(ds)),
        Method::Bdeu => Arc::new(BdeuScore::new(ds)),
        Method::Sc => Arc::new(ScScore::new(ds)),
        Method::Pc | Method::Mm => unreachable!("constraint-based"),
    })
}

/// Run causal discovery with the configured method.
pub fn discover(ds: Arc<Dataset>, cfg: &DiscoveryConfig) -> Result<DiscoveryOutcome> {
    let sw = Stopwatch::start();
    match cfg.method {
        Method::Pc => {
            let kci = Kci::new(ds);
            let res = pc(&kci, &PcConfig { alpha: cfg.alpha, max_cond: None });
            Ok(DiscoveryOutcome {
                cpdag: res.cpdag,
                seconds: sw.secs(),
                method: cfg.method,
                score_stats: None,
                ci_tests: Some(kci.calls()),
            })
        }
        Method::Mm => {
            let kci = Kci::new(ds);
            let res = mmmb(&kci, &MmConfig { alpha: cfg.alpha, max_cond: 3 });
            Ok(DiscoveryOutcome {
                cpdag: res.cpdag,
                seconds: sw.secs(),
                method: cfg.method,
                score_stats: None,
                ci_tests: Some(kci.calls()),
            })
        }
        _ => {
            let score = make_score(ds, cfg)?;
            let service = ScoreService::new(score, cfg.workers);
            let res = ges(&service, &cfg.ges);
            Ok(DiscoveryOutcome {
                cpdag: res.cpdag,
                seconds: sw.secs(),
                method: cfg.method,
                score_stats: Some(service.stats()),
                ci_tests: None,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::graph::metrics::skeleton_f1;

    #[test]
    fn method_parse_roundtrip() {
        for m in [Method::CvLr, Method::Cv, Method::MargLr, Method::Bic, Method::Bdeu, Method::Sc, Method::Pc, Method::Mm] {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn discover_with_bic_runs() {
        let (ds, dag) = generate(&SynthConfig { n: 400, density: 0.3, seed: 1, ..Default::default() });
        let cfg = DiscoveryConfig { method: Method::Bic, ..Default::default() };
        let out = discover(Arc::new(ds), &cfg).unwrap();
        assert!(out.seconds >= 0.0);
        let f1 = skeleton_f1(&out.cpdag, &dag);
        assert!(f1 > 0.3, "BIC should find some structure: f1={f1}");
        assert!(out.score_stats.unwrap().evaluations > 0);
    }

    #[test]
    fn discover_with_cvlr_native_runs() {
        let (ds, dag) = generate(&SynthConfig { n: 150, density: 0.3, seed: 2, ..Default::default() });
        let cfg = DiscoveryConfig { method: Method::CvLr, ..Default::default() };
        let out = discover(Arc::new(ds), &cfg).unwrap();
        let f1 = skeleton_f1(&out.cpdag, &dag);
        assert!(f1 > 0.3, "CV-LR should find structure: f1={f1}");
        let st = out.score_stats.unwrap();
        assert!(st.cache_hits > 0, "GES must hit the score cache");
    }
}
