//! The discovery engine: a **registry** of discovery methods plus the
//! [`Discovery`] builder façade.
//!
//! A method is either *score-based* (a factory producing a
//! [`ScoreBackend`]; the engine wraps it in a [`ScoreService`] and runs
//! batched GES) or *search-based* (a closure running its own algorithm,
//! e.g. PC/KCI). The paper's methods are pre-registered; downstream
//! crates add their own with [`register_score_method`] /
//! [`register_search_method`] — no engine edits required:
//!
//! ```no_run
//! # use std::sync::Arc;
//! # use cvlr::coordinator::{Discovery, DiscoveryOutcome, EngineKind};
//! # fn run(ds: Arc<cvlr::data::Dataset>) -> anyhow::Result<DiscoveryOutcome> {
//! let out = Discovery::builder(ds)
//!     .method("cv-lr")
//!     .engine(EngineKind::Pjrt)
//!     .workers(8)
//!     .run()?;
//! # Ok(out)
//! # }
//! ```
//!
//! The legacy [`discover`]`(ds, &DiscoveryConfig)` entry point routes
//! through the same registry.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Context, Result};

use super::service::{ScoreService, ServiceStats};
use crate::ci::Kci;
use crate::data::Dataset;
use crate::graph::Pdag;
use crate::lowrank::{FactorMethod, LowRankConfig};
use crate::runtime::pjrt_kernel::PjrtCvLrKernel;
use crate::runtime::Runtime;
use crate::score::bdeu::BdeuScore;
use crate::score::bic::BicScore;
use crate::score::cv_exact::CvExactScore;
use crate::score::cvlr::{CvLrScore, NativeCvLrKernel};
use crate::score::folds::CvParams;
use crate::score::marginal::MargLrScore;
use crate::score::sc::ScScore;
use crate::score::{ScalarBackend, ScoreBackend, ScoreRequest};
use crate::search::ges::{ges, GesConfig};
use crate::search::mmmb::{mmmb, MmConfig};
use crate::search::pc::{pc, PcConfig};
use crate::util::Stopwatch;

/// Which scoring/search method to run (the paper's built-in set).
/// Custom methods registered at runtime are addressed by name through
/// [`Discovery::builder`] and have no enum variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// GES + CV-LR (the paper's method).
    CvLr,
    /// GES + exact CV likelihood (the O(n³) baseline).
    Cv,
    /// GES + low-rank marginal-likelihood score (Huang'18's other
    /// generalized score, accelerated with the same dumbbell machinery).
    MargLr,
    /// GES + BIC (continuous only).
    Bic,
    /// GES + BDeu (discrete only).
    Bdeu,
    /// GES + SC (Spearman BIC).
    Sc,
    /// PC with KCI.
    Pc,
    /// MM-MB with KCI.
    Mm,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::CvLr => "CV-LR",
            Method::Cv => "CV",
            Method::MargLr => "Marg-LR",
            Method::Bic => "BIC",
            Method::Bdeu => "BDeu",
            Method::Sc => "SC",
            Method::Pc => "PC",
            Method::Mm => "MM",
        }
    }

    /// Canonical registry key.
    pub fn key(&self) -> &'static str {
        match self {
            Method::CvLr => "cv-lr",
            Method::Cv => "cv",
            Method::MargLr => "marg-lr",
            Method::Bic => "bic",
            Method::Bdeu => "bdeu",
            Method::Sc => "sc",
            Method::Pc => "pc",
            Method::Mm => "mm",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "cv-lr" | "cvlr" => Some(Method::CvLr),
            "cv" => Some(Method::Cv),
            "marg-lr" | "marglr" | "marg" => Some(Method::MargLr),
            "bic" => Some(Method::Bic),
            "bdeu" => Some(Method::Bdeu),
            "sc" => Some(Method::Sc),
            "pc" => Some(Method::Pc),
            "mm" | "mm-mb" | "mmmb" => Some(Method::Mm),
            _ => None,
        }
    }
}

/// Scoring backend for the CV-LR fold kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Pure-rust f64 dumbbell algebra.
    Native,
    /// AOT XLA artifacts via PJRT (the three-layer hot path).
    Pjrt,
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct DiscoveryConfig {
    /// Method for the legacy [`discover`] entry point. Registry
    /// factories must NOT branch on this field — when a run is started
    /// by name through [`Discovery::builder`] (possibly a custom
    /// method with no enum variant), it keeps its default and only the
    /// registry name identifies the method.
    pub method: Method,
    pub engine: EngineKind,
    pub params: CvParams,
    pub lowrank: LowRankConfig,
    pub ges: GesConfig,
    /// Significance level for constraint-based methods.
    pub alpha: f64,
    /// Worker threads for the score service.
    pub workers: usize,
    /// Gram-product threads inside the CV-LR fold-core builds (the
    /// `std::thread::scope` row-partitioned path of `score::cores`;
    /// orthogonal to `workers`, which parallelizes across candidates).
    /// `0` means **auto**: detect with
    /// `std::thread::available_parallelism()`, capped at the fold count
    /// Q (`score::cores::resolve_parallelism`); the resolved value is
    /// reported as `ServiceStats::gram_threads`.
    pub parallelism: usize,
    /// Score-cache capacity (None = unbounded, the one-shot CLI
    /// default). Long-lived processes (the discovery server) must set a
    /// bound; see [`ScoreService::with_cache_capacity`].
    pub cache_capacity: Option<usize>,
    /// Artifacts directory for the PJRT engine.
    pub artifacts_dir: String,
    /// Follower `cvlr serve` addresses (`host:port`) to shard score
    /// batches across. Empty (the default) scores locally. Score-based
    /// methods get wrapped in a `distrib::ShardScoreBackend`; results
    /// stay bit-identical to local scoring (followers run the same fold
    /// algebra on a bit-exact pushed dataset), only wall-clock changes.
    pub shards: Vec<String>,
    /// Registry name the dataset is pushed under on followers
    /// (auto-registration). Empty picks a generic name; the CLI sets it
    /// from `--data`, the server from the job's dataset name.
    pub shard_dataset: String,
    /// End-to-end deadline of one discovery run, in milliseconds
    /// (`None` = unlimited, the default). The budget threads through
    /// shard dispatch/hedge/retry decisions, the follower socket
    /// timeouts and the `deadline_ms` wire field; when it expires
    /// mid-run, scoring degrades to local and the run returns a typed
    /// [`crate::util::DeadlineExceeded`] error rather than a graph
    /// computed from partial scores.
    pub deadline_ms: Option<u64>,
}

impl Default for DiscoveryConfig {
    fn default() -> Self {
        DiscoveryConfig {
            method: Method::CvLr,
            engine: EngineKind::Native,
            params: CvParams::default(),
            lowrank: LowRankConfig::default(),
            ges: GesConfig::default(),
            alpha: 0.05,
            workers: 1,
            parallelism: 1,
            cache_capacity: None,
            artifacts_dir: "artifacts".to_string(),
            shards: Vec::new(),
            shard_dataset: String::new(),
            deadline_ms: None,
        }
    }
}

/// Result of a discovery run.
pub struct DiscoveryOutcome {
    pub cpdag: Pdag,
    pub seconds: f64,
    /// Canonical name of the method that ran (registry key).
    pub method: String,
    /// Score-service statistics (score-based methods only).
    pub score_stats: Option<ServiceStats>,
    /// CI-test count (constraint-based methods only).
    pub ci_tests: Option<u64>,
}

/// Factory producing the score backend of a score-based method.
pub type BackendFactory =
    Arc<dyn Fn(Arc<Dataset>, &DiscoveryConfig) -> Result<Arc<dyn ScoreBackend>> + Send + Sync>;

/// Runner for a search-based (non-GES) method.
pub type SearchRunner =
    Arc<dyn Fn(Arc<Dataset>, &DiscoveryConfig) -> Result<DiscoveryOutcome> + Send + Sync>;

#[derive(Clone)]
enum MethodEntry {
    Score(BackendFactory),
    Search(SearchRunner),
}

struct Registry {
    /// canonical name → entry
    methods: HashMap<String, MethodEntry>,
    /// alias → canonical name
    aliases: HashMap<String, String>,
}

impl Registry {
    fn insert(&mut self, name: &str, aliases: &[&str], entry: MethodEntry) {
        // names are matched case-insensitively: store lowercased so
        // custom registrations with uppercase letters stay reachable
        let name = name.to_ascii_lowercase();
        self.methods.insert(name.clone(), entry);
        for a in aliases {
            self.aliases.insert(a.to_ascii_lowercase(), name.clone());
        }
    }

    fn resolve(&self, name: &str) -> Option<(String, MethodEntry)> {
        let lower = name.to_ascii_lowercase();
        let canon = if self.methods.contains_key(&lower) {
            lower
        } else {
            self.aliases.get(&lower)?.clone()
        };
        let entry = self.methods.get(&canon)?.clone();
        Some((canon, entry))
    }

    fn with_builtins() -> Registry {
        let mut reg =
            Registry { methods: HashMap::new(), aliases: HashMap::new() };
        reg.insert(
            "cv-lr",
            &["cvlr"],
            MethodEntry::Score(Arc::new(|ds, cfg| {
                Ok(match cfg.engine {
                    EngineKind::Native => Arc::new(
                        CvLrScore::with_backend(ds, cfg.params, cfg.lowrank, NativeCvLrKernel)
                            .with_parallelism(cfg.parallelism)
                            .with_core_capacity(cfg.cache_capacity),
                    ) as Arc<dyn ScoreBackend>,
                    EngineKind::Pjrt => {
                        let rt = Arc::new(
                            Runtime::load(&cfg.artifacts_dir)
                                .context("loading PJRT artifacts for the CV-LR engine")?,
                        );
                        Arc::new(
                            CvLrScore::with_backend(
                                ds,
                                cfg.params,
                                cfg.lowrank,
                                PjrtCvLrKernel::new(rt),
                            )
                            .with_parallelism(cfg.parallelism)
                            .with_core_capacity(cfg.cache_capacity),
                        )
                    }
                })
            })),
        );
        reg.insert(
            "cv",
            &[],
            MethodEntry::Score(Arc::new(|ds, cfg| {
                Ok(Arc::new(ScalarBackend(CvExactScore::new(ds, cfg.params))))
            })),
        );
        reg.insert(
            "marg-lr",
            &["marglr", "marg"],
            MethodEntry::Score(Arc::new(|ds, _| Ok(Arc::new(ScalarBackend(MargLrScore::new(ds)))))),
        );
        reg.insert(
            "bic",
            &[],
            MethodEntry::Score(Arc::new(|ds, _| Ok(Arc::new(ScalarBackend(BicScore::new(ds)))))),
        );
        reg.insert(
            "bdeu",
            &[],
            MethodEntry::Score(Arc::new(|ds, _| Ok(Arc::new(ScalarBackend(BdeuScore::new(ds)))))),
        );
        reg.insert(
            "sc",
            &[],
            MethodEntry::Score(Arc::new(|ds, _| Ok(Arc::new(ScalarBackend(ScScore::new(ds)))))),
        );
        reg.insert(
            "pc",
            &[],
            MethodEntry::Search(Arc::new(|ds, cfg| {
                let sw = Stopwatch::start();
                let kci = Kci::new(ds);
                let res = pc(&kci, &PcConfig { alpha: cfg.alpha, max_cond: None });
                Ok(DiscoveryOutcome {
                    cpdag: res.cpdag,
                    seconds: sw.secs(),
                    method: "pc".to_string(),
                    score_stats: None,
                    ci_tests: Some(kci.calls()),
                })
            })),
        );
        reg.insert(
            "mm",
            &["mm-mb", "mmmb"],
            MethodEntry::Search(Arc::new(|ds, cfg| {
                let sw = Stopwatch::start();
                let kci = Kci::new(ds);
                let res = mmmb(&kci, &MmConfig { alpha: cfg.alpha, max_cond: 3 });
                Ok(DiscoveryOutcome {
                    cpdag: res.cpdag,
                    seconds: sw.secs(),
                    method: "mm".to_string(),
                    score_stats: None,
                    ci_tests: Some(kci.calls()),
                })
            })),
        );
        reg
    }
}

fn registry() -> &'static Mutex<Registry> {
    static REG: OnceLock<Mutex<Registry>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Registry::with_builtins()))
}

/// Register (or replace) a score-based method: the factory's backend is
/// wrapped in a `ScoreService` and driven by batched GES.
pub fn register_score_method<F>(name: &str, aliases: &[&str], factory: F)
where
    F: Fn(Arc<Dataset>, &DiscoveryConfig) -> Result<Arc<dyn ScoreBackend>> + Send + Sync + 'static,
{
    registry().lock().unwrap().insert(name, aliases, MethodEntry::Score(Arc::new(factory)));
}

/// Register (or replace) a search-based method that runs its own
/// algorithm end to end.
pub fn register_search_method<F>(name: &str, aliases: &[&str], runner: F)
where
    F: Fn(Arc<Dataset>, &DiscoveryConfig) -> Result<DiscoveryOutcome> + Send + Sync + 'static,
{
    registry().lock().unwrap().insert(name, aliases, MethodEntry::Search(Arc::new(runner)));
}

/// Canonical names of every registered method, sorted.
pub fn registered_methods() -> Vec<String> {
    let mut names: Vec<String> = registry().lock().unwrap().methods.keys().cloned().collect();
    names.sort();
    names
}

/// Kind of a registered discovery method.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MethodKind {
    /// A [`BackendFactory`]: wrapped in a `ScoreService`, driven by GES.
    Score,
    /// A [`SearchRunner`]: runs its own algorithm end to end.
    Search,
}

/// Resolve a method name (or alias) to its canonical registry key and
/// kind, without building anything. Used by callers that manage their
/// own `ScoreService` lifetimes (the discovery server's job manager).
pub fn resolve_method(name: &str) -> Option<(String, MethodKind)> {
    let resolved = registry().lock().unwrap().resolve(name);
    resolved.map(|(canon, entry)| {
        let kind = match entry {
            MethodEntry::Score(_) => MethodKind::Score,
            MethodEntry::Search(_) => MethodKind::Search,
        };
        (canon, kind)
    })
}

/// Wrap a freshly built local backend in a
/// [`crate::distrib::ShardScoreBackend`] when `cfg.shards` names a
/// follower fleet; a no-op otherwise. The wrapped backend keeps the
/// local one as its degradation fallback, so a dead fleet still scores.
fn shard_wrap(
    canon: &str,
    ds: &Arc<Dataset>,
    cfg: &DiscoveryConfig,
    backend: Arc<dyn ScoreBackend>,
) -> Arc<dyn ScoreBackend> {
    if cfg.shards.is_empty() {
        return backend;
    }
    let engine = match cfg.engine {
        EngineKind::Native => "native",
        EngineKind::Pjrt => "pjrt",
    };
    let dataset =
        if cfg.shard_dataset.is_empty() { "coordinator" } else { cfg.shard_dataset.as_str() };
    Arc::new(crate::distrib::ShardScoreBackend::new(
        backend,
        ds,
        dataset,
        canon,
        engine,
        cfg.lowrank.method.name(),
        &cfg.shards,
        crate::distrib::PoolConfig::default(),
    ))
}

/// Build the raw score backend of a score-based method (`Ok(None)` for
/// search-based methods). The caller owns wrapping it in a
/// [`ScoreService`] — this is how the server shares one memoized
/// service across jobs on the same (dataset, method). When
/// `cfg.shards` is non-empty the backend is shard-wrapped here, so
/// every server path (job pool, dataset-append refresh) inherits
/// distribution without its own plumbing.
pub fn score_backend_for(
    name: &str,
    ds: Arc<Dataset>,
    cfg: &DiscoveryConfig,
) -> Result<(String, Option<Arc<dyn ScoreBackend>>)> {
    let resolved = registry().lock().unwrap().resolve(name);
    match resolved {
        Some((canon, MethodEntry::Score(factory))) => {
            let backend = factory(ds.clone(), cfg)?;
            let backend = shard_wrap(&canon, &ds, cfg, backend);
            Ok((canon, Some(backend)))
        }
        Some((canon, MethodEntry::Search(_))) => Ok((canon, None)),
        None => bail!(
            "unknown method `{name}` (registered: {})",
            registered_methods().join(", ")
        ),
    }
}

/// Per-run deadline enforcement on the GES scoring loop: each sweep is
/// submitted in a few wide chunks, and the remaining chunks are skipped
/// (padded with zeros) once the budget expires. The padded result is
/// never returned — `run_method` discards it and surfaces a typed
/// [`crate::util::DeadlineExceeded`] instead, so an expired deadline
/// can't silently yield a graph computed from partial scores. Mirrors
/// the server's chunked cancel-aware backend.
struct DeadlineGuard<'a> {
    inner: &'a ScoreService,
    budget: crate::util::Budget,
    expired: std::sync::atomic::AtomicBool,
}

impl<'a> DeadlineGuard<'a> {
    fn new(inner: &'a ScoreService, budget: crate::util::Budget) -> DeadlineGuard<'a> {
        DeadlineGuard { inner, budget, expired: std::sync::atomic::AtomicBool::new(false) }
    }

    fn tripped(&self) -> bool {
        self.expired.load(std::sync::atomic::Ordering::SeqCst)
    }
}

impl ScoreBackend for DeadlineGuard<'_> {
    fn score_batch(&self, reqs: &[ScoreRequest]) -> Vec<f64> {
        let chunk_len = 32usize.max(reqs.len().div_ceil(8));
        let mut out: Vec<f64> = Vec::with_capacity(reqs.len());
        for sub in reqs.chunks(chunk_len) {
            if self.budget.expired() {
                self.expired.store(true, std::sync::atomic::Ordering::SeqCst);
                break;
            }
            out.extend(self.inner.score_batch(sub));
        }
        out.resize(reqs.len(), 0.0);
        out
    }

    fn num_vars(&self) -> usize {
        ScoreBackend::num_vars(self.inner)
    }
}

/// Run the method registered under `name` (public twin of the builder's
/// `run()` for callers that already hold a config).
pub fn run_named(name: &str, ds: Arc<Dataset>, cfg: &DiscoveryConfig) -> Result<DiscoveryOutcome> {
    run_method(name, ds, cfg)
}

/// Run the named method: build the backend, wrap it in the batching
/// score service, drive batched GES (score methods) or delegate to the
/// search runner.
fn run_method(name: &str, ds: Arc<Dataset>, cfg: &DiscoveryConfig) -> Result<DiscoveryOutcome> {
    // resolve under its own statement so the registry lock is released
    // before the error path (or a factory) takes it again
    let resolved = registry().lock().unwrap().resolve(name);
    let (canon, entry) = match resolved {
        Some(r) => r,
        None => bail!(
            "unknown method `{name}` (registered: {})",
            registered_methods().join(", ")
        ),
    };
    match entry {
        MethodEntry::Score(factory) => {
            let sw = Stopwatch::start();
            let backend = factory(ds.clone(), cfg)?;
            let backend = shard_wrap(&canon, &ds, cfg, backend);
            let budget = crate::util::Budget::from_ms(cfg.deadline_ms);
            backend.set_budget(budget);
            let service =
                ScoreService::with_cache_capacity(backend, cfg.workers, cfg.cache_capacity);
            service.set_gram_threads(crate::score::cores::resolve_parallelism(
                cfg.parallelism,
                cfg.params.folds,
            ) as u64);
            let res = if budget.is_limited() {
                let guard = DeadlineGuard::new(&service, budget);
                let res = ges(&guard, &cfg.ges);
                if guard.tripped() {
                    crate::obs::metrics::deadline_exceeded_total().inc();
                    return Err(crate::util::DeadlineExceeded::new(format!(
                        "discovery `{canon}` ran past its {}ms deadline",
                        cfg.deadline_ms.unwrap_or(0)
                    ))
                    .into());
                }
                res
            } else {
                ges(&service, &cfg.ges)
            };
            Ok(DiscoveryOutcome {
                cpdag: res.cpdag,
                seconds: sw.secs(),
                method: canon,
                score_stats: Some(service.stats()),
                ci_tests: None,
            })
        }
        MethodEntry::Search(runner) => {
            let mut out = runner(ds, cfg)?;
            out.method = canon;
            Ok(out)
        }
    }
}

/// Run causal discovery with the configured method (legacy entry point;
/// routes through the method registry).
pub fn discover(ds: Arc<Dataset>, cfg: &DiscoveryConfig) -> Result<DiscoveryOutcome> {
    run_method(cfg.method.key(), ds, cfg)
}

/// Entry point of the builder façade.
pub struct Discovery;

impl Discovery {
    /// Start configuring a discovery run on `ds`. Defaults mirror
    /// [`DiscoveryConfig::default`] (CV-LR, native engine, 1 worker).
    pub fn builder(ds: Arc<Dataset>) -> DiscoveryBuilder {
        DiscoveryBuilder { ds, method: "cv-lr".to_string(), cfg: DiscoveryConfig::default() }
    }
}

/// Builder-style discovery session: pick a method by registry name,
/// tune the knobs, `run()`.
pub struct DiscoveryBuilder {
    ds: Arc<Dataset>,
    method: String,
    cfg: DiscoveryConfig,
}

impl DiscoveryBuilder {
    /// Method by registry name (e.g. `"cv-lr"`, `"bic"`, `"pc"`, or any
    /// custom name added with [`register_score_method`]). Unknown names
    /// surface as an error from [`run`](Self::run).
    pub fn method(mut self, name: impl Into<String>) -> Self {
        self.method = name.into();
        if let Some(m) = Method::parse(&self.method) {
            self.cfg.method = m;
        }
        self
    }

    /// CV-LR fold-kernel engine (native rust or PJRT artifacts).
    pub fn engine(mut self, engine: EngineKind) -> Self {
        self.cfg.engine = engine;
        self
    }

    /// Worker threads for the score service.
    pub fn workers(mut self, workers: usize) -> Self {
        self.cfg.workers = workers;
        self
    }

    /// Gram-product threads inside the CV-LR fold-core builds; `0` =
    /// auto (see [`DiscoveryConfig::parallelism`]).
    pub fn parallelism(mut self, threads: usize) -> Self {
        self.cfg.parallelism = threads;
        self
    }

    /// Bound the score cache to at most `capacity` entries (second-chance
    /// eviction; see [`ServiceStats::evictions`]). Unbounded by default.
    pub fn cache_capacity(mut self, capacity: usize) -> Self {
        self.cfg.cache_capacity = Some(capacity);
        self
    }

    /// CV hyper-parameters (λ, γ, folds, kernel width).
    pub fn params(mut self, params: CvParams) -> Self {
        self.cfg.params = params;
        self
    }

    /// Low-rank factorization configuration.
    pub fn lowrank(mut self, lowrank: LowRankConfig) -> Self {
        self.cfg.lowrank = lowrank;
        self
    }

    /// Continuous-path factorization of the CV-LR score: ICL (the
    /// adaptive-pivot default) or RFF (data-independent random Fourier
    /// features) — the `--lowrank {icl,rff}` knob, without replacing
    /// the rest of the low-rank configuration.
    pub fn lowrank_method(mut self, method: FactorMethod) -> Self {
        self.cfg.lowrank.method = method;
        self
    }

    /// GES search configuration.
    pub fn ges(mut self, ges: GesConfig) -> Self {
        self.cfg.ges = ges;
        self
    }

    /// Significance level for constraint-based methods.
    pub fn alpha(mut self, alpha: f64) -> Self {
        self.cfg.alpha = alpha;
        self
    }

    /// Artifacts directory for the PJRT engine.
    pub fn artifacts_dir(mut self, dir: impl Into<String>) -> Self {
        self.cfg.artifacts_dir = dir.into();
        self
    }

    /// Shard score batches across follower `cvlr serve` processes
    /// (`host:port` each). Results stay bit-identical to a local run;
    /// a slow or dead follower degrades to local scoring.
    pub fn shards(mut self, addrs: impl IntoIterator<Item = impl Into<String>>) -> Self {
        self.cfg.shards = addrs.into_iter().map(Into::into).collect();
        self
    }

    /// Registry name the dataset is pushed under on followers (see
    /// [`DiscoveryConfig::shard_dataset`]).
    pub fn shard_dataset(mut self, name: impl Into<String>) -> Self {
        self.cfg.shard_dataset = name.into();
        self
    }

    /// End-to-end deadline for the run, in milliseconds (see
    /// [`DiscoveryConfig::deadline_ms`]). An expired budget degrades
    /// remote scoring to local and fails the run with a typed
    /// [`crate::util::DeadlineExceeded`] rather than hanging.
    pub fn deadline_ms(mut self, ms: u64) -> Self {
        self.cfg.deadline_ms = Some(ms);
        self
    }

    /// Run discovery and return the learned equivalence class.
    pub fn run(self) -> Result<DiscoveryOutcome> {
        run_method(&self.method, self.ds, &self.cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::graph::metrics::skeleton_f1;

    #[test]
    fn method_parse_roundtrip() {
        for m in [Method::CvLr, Method::Cv, Method::MargLr, Method::Bic, Method::Bdeu, Method::Sc, Method::Pc, Method::Mm] {
            assert_eq!(Method::parse(m.name()), Some(m));
            assert_eq!(Method::parse(m.key()), Some(m));
        }
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn discover_with_bic_runs() {
        let (ds, dag) = generate(&SynthConfig { n: 400, density: 0.3, seed: 1, ..Default::default() });
        let cfg = DiscoveryConfig { method: Method::Bic, ..Default::default() };
        let out = discover(Arc::new(ds), &cfg).unwrap();
        assert!(out.seconds >= 0.0);
        assert_eq!(out.method, "bic");
        let f1 = skeleton_f1(&out.cpdag, &dag);
        assert!(f1 > 0.3, "BIC should find some structure: f1={f1}");
        let st = out.score_stats.unwrap();
        assert!(st.evaluations > 0);
        assert!(st.batches > 0, "GES must drive the service batch-first");
        assert!(st.consistent(), "{st:?}");
    }

    #[test]
    fn discover_with_cvlr_native_runs() {
        let (ds, dag) = generate(&SynthConfig { n: 150, density: 0.3, seed: 2, ..Default::default() });
        let cfg = DiscoveryConfig { method: Method::CvLr, ..Default::default() };
        let out = discover(Arc::new(ds), &cfg).unwrap();
        let f1 = skeleton_f1(&out.cpdag, &dag);
        assert!(f1 > 0.3, "CV-LR should find structure: f1={f1}");
        let st = out.score_stats.unwrap();
        assert!(st.cache_hits > 0, "GES must hit the score cache");
        assert!(st.max_batch > 1, "sweeps must batch many candidates");
    }

    #[test]
    fn builder_runs_named_method() {
        let (ds, _) = generate(&SynthConfig { n: 200, density: 0.3, seed: 3, ..Default::default() });
        let out = Discovery::builder(Arc::new(ds)).method("bic").workers(2).run().unwrap();
        assert_eq!(out.method, "bic");
        assert!(out.score_stats.unwrap().batches > 0);
    }

    #[test]
    fn builder_rejects_unknown_method() {
        let (ds, _) = generate(&SynthConfig { n: 100, density: 0.3, seed: 4, ..Default::default() });
        let err = Discovery::builder(Arc::new(ds)).method("definitely-not-a-method").run();
        assert!(err.is_err());
    }

    #[test]
    fn builder_cache_capacity_bounds_the_service() {
        let (ds, _) = generate(&SynthConfig { n: 200, density: 0.3, seed: 6, ..Default::default() });
        let out = Discovery::builder(Arc::new(ds)).method("bic").cache_capacity(8).run().unwrap();
        let st = out.score_stats.unwrap();
        assert!(st.cache_entries <= 8, "{st:?}");
        assert!(st.evictions > 0, "a tiny cap must evict during GES: {st:?}");
        assert!(st.consistent(), "identity must survive evictions: {st:?}");
    }

    #[test]
    fn builder_rff_lowrank_and_auto_parallelism_run() {
        let (ds, _) =
            generate(&SynthConfig { n: 150, density: 0.3, seed: 7, ..Default::default() });
        let out = Discovery::builder(Arc::new(ds))
            .method("cv-lr")
            .lowrank_method(FactorMethod::Rff)
            .parallelism(0) // auto: resolved and reported, never 0
            .run()
            .unwrap();
        let st = out.score_stats.unwrap();
        assert!(
            (1..=CvParams::default().folds as u64).contains(&st.gram_threads),
            "auto parallelism must resolve into [1, Q]: {st:?}"
        );
        assert!(st.core_cache_entries > 0, "CV-LR populates the fold-core cache: {st:?}");
        assert!(st.consistent(), "{st:?}");
    }

    #[test]
    fn expired_deadline_fails_with_typed_error() {
        let (ds, _) = generate(&SynthConfig { n: 100, density: 0.3, seed: 8, ..Default::default() });
        let err = Discovery::builder(Arc::new(ds))
            .method("bic")
            .deadline_ms(0)
            .run()
            .expect_err("a zero deadline cannot complete");
        assert!(
            err.downcast_ref::<crate::util::DeadlineExceeded>().is_some(),
            "expected a typed DeadlineExceeded, got: {err}"
        );
    }

    #[test]
    fn generous_deadline_changes_nothing() {
        let (ds, _) = generate(&SynthConfig { n: 150, density: 0.3, seed: 1, ..Default::default() });
        let ds = Arc::new(ds);
        let plain = Discovery::builder(ds.clone()).method("bic").run().unwrap();
        let bounded =
            Discovery::builder(ds).method("bic").deadline_ms(600_000).run().unwrap();
        assert_eq!(plain.cpdag, bounded.cpdag, "a slack deadline must not alter the graph");
    }

    #[test]
    fn resolve_method_reports_kind() {
        assert_eq!(resolve_method("cvlr"), Some(("cv-lr".to_string(), MethodKind::Score)));
        assert_eq!(resolve_method("pc"), Some(("pc".to_string(), MethodKind::Search)));
        assert_eq!(resolve_method("definitely-not-a-method"), None);
    }

    #[test]
    fn custom_registered_method_is_discoverable() {
        // a registry extension: BIC under a custom name, no engine edits
        register_score_method("unit-test-bic", &["utb"], |ds, _| {
            Ok(Arc::new(ScalarBackend(BicScore::new(ds))))
        });
        assert!(registered_methods().contains(&"unit-test-bic".to_string()));
        let (ds, _) = generate(&SynthConfig { n: 150, density: 0.3, seed: 5, ..Default::default() });
        let out = Discovery::builder(Arc::new(ds)).method("utb").run().unwrap();
        assert_eq!(out.method, "unit-test-bic");
    }
}
