//! The score service: the memoizing, batching façade between the
//! search and any [`ScoreBackend`].
//!
//! GES submits each sweep as one wide batch of (target, parent-set)
//! requests with heavy overlap between sweeps. The service owns the
//! **single** memo layer ([`ScoreCache`]) — scores are cached nowhere
//! else — deduplicates the batch, fans the unique misses over a worker
//! pool (each worker submits its chunk to the backend as a sub-batch,
//! so batch-aware backends still amortize shared work), and returns
//! scores in request order.
//!
//! Concurrency: the cache uses entry-based fill. A miss is *claimed*
//! (marked in-flight) under the same lock span that classified it, so
//! two concurrent batches can never evaluate the same key twice; the
//! loser blocks on the winner's result instead. The accounting identity
//! `requests == cache_hits + evaluations + dedup_skips` holds exactly
//! (see [`ServiceStats::consistent`]).
//!
//! Long-run hygiene: the cache can be bounded
//! ([`ScoreCache::with_capacity`]); a full cache evicts with a
//! second-chance (clock) sweep — each resident entry carries a
//! referenced bit set on every hit, and the sweep skips referenced
//! entries once before reclaiming them. Evictions are counted in
//! [`ServiceStats::evictions`], *outside* the request identity: an
//! eviction turns a future request into a re-evaluation but is never
//! itself a request.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};

use crate::graph::Pdag;
use crate::obs::{metrics, trace};
use crate::score::{
    FollowerStat, LocalScore, ScalarBackend, ScoreBackend, ScoreRequest, ShardCounters,
};

type Key = (usize, Vec<usize>);

enum Slot {
    /// Claimed by some batch; the value is being computed. `waiters`
    /// counts threads blocked in [`ScoreCache::wait`] on this key.
    Pending { waiters: usize },
    /// Computed value. `referenced` is the second-chance (clock) bit,
    /// set on every hit; entries with waiters still draining are
    /// pinned and never evicted.
    Ready { val: f64, referenced: bool, waiters: usize },
}

/// Outcome of classifying one unique key under the cache lock.
enum Claim {
    /// Value already cached.
    Hit(f64),
    /// Another thread is computing it; wait for the fill.
    InFlight,
    /// This caller claimed it and must evaluate + fill.
    Owned,
}

/// Mutable cache state, guarded by one mutex.
struct CacheInner {
    map: HashMap<Key, Slot>,
    /// Second-chance (clock) queue over resident `Ready` keys, oldest
    /// first. Pending claims are never enqueued; fills enqueue exactly
    /// one slot per key and evictions pop it, so the queue holds each
    /// resident key at most once.
    ring: VecDeque<Key>,
    evictions: u64,
    /// Entries removed by targeted invalidation (dataset appends) —
    /// outside the request identity, like evictions.
    invalidations: u64,
}

/// The single score memo layer, owned by [`ScoreService`].
///
/// Keys are canonical (target, sorted parent-set) pairs. Entries go
/// through a claim → fill protocol so that concurrent batches dedup
/// in-flight work instead of racing: `claim` marks unseen keys Pending
/// under the same lock span that reports hits, and `fill` publishes
/// results and wakes waiters.
///
/// With a capacity set, `fill` runs a second-chance eviction sweep, so
/// a long-lived process (the discovery server) holds at most `capacity`
/// memoized scores per service instead of growing without bound.
pub struct ScoreCache {
    inner: Mutex<CacheInner>,
    /// Maximum resident entries (None = unbounded).
    capacity: Option<usize>,
    ready: Condvar,
}

impl ScoreCache {
    /// Unbounded cache (the one-shot CLI default).
    pub fn new() -> ScoreCache {
        ScoreCache::with_capacity(None)
    }

    /// Cache holding at most `capacity` entries (None = unbounded).
    pub fn with_capacity(capacity: Option<usize>) -> ScoreCache {
        ScoreCache {
            inner: Mutex::new(CacheInner {
                map: HashMap::new(),
                ring: VecDeque::new(),
                evictions: 0,
                invalidations: 0,
            }),
            capacity,
            ready: Condvar::new(),
        }
    }

    pub fn capacity(&self) -> Option<usize> {
        self.capacity
    }

    /// Number of entries (including in-flight claims).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Entries reclaimed by the second-chance sweep so far.
    pub fn evictions(&self) -> u64 {
        self.inner.lock().unwrap().evictions
    }

    /// Entries removed by targeted invalidation so far.
    pub fn invalidations(&self) -> u64 {
        self.inner.lock().unwrap().invalidations
    }

    /// Resident heap bytes of the memo layer: per-entry key vectors
    /// (map + ring clones) plus a fixed map/ring slot estimate per
    /// entry. Walked under the lock — stats paths only.
    pub fn resident_bytes(&self) -> u64 {
        let inner = self.inner.lock().unwrap();
        let key_heap = |k: &Key| k.1.capacity() * std::mem::size_of::<usize>();
        let slots = inner
            .map
            .keys()
            .map(|k| key_heap(k) + std::mem::size_of::<(Key, Slot)>())
            .sum::<usize>();
        let ring = inner
            .ring
            .iter()
            .map(|k| key_heap(k) + std::mem::size_of::<Key>())
            .sum::<usize>();
        (slots + ring) as u64
    }

    /// Targeted invalidation: drop every resident `Ready` entry that no
    /// waiter is pinned to (the append path — every memoized score
    /// depends on every sample row, so an append stales them all).
    /// In-flight `Pending` claims are left alone: their owners fill and
    /// wake waiters normally, they just describe the pre-append
    /// snapshot — callers that need a hard barrier (the server) refuse
    /// appends while jobs are running. Returns the number of entries
    /// removed (also accumulated in [`ScoreCache::invalidations`]).
    pub fn invalidate_all(&self) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        let mut removed = 0u64;
        inner.map.retain(|_, slot| match slot {
            Slot::Ready { waiters: 0, .. } => {
                removed += 1;
                false
            }
            _ => true,
        });
        let CacheInner { map, ring, .. } = &mut *inner;
        ring.retain(|k| map.contains_key(k));
        inner.invalidations += removed;
        removed
    }

    /// Classify every key in ONE lock span, claiming unseen keys for
    /// the caller. `keys` must be unique within the call.
    ///
    /// An `InFlight` result registers the caller as a waiter under the
    /// same lock, which pins the entry against eviction until the
    /// matching [`ScoreCache::wait`] drains it — so every `InFlight`
    /// claim MUST be followed by exactly one `wait` on that key.
    fn claim(&self, keys: &[Key]) -> Vec<Claim> {
        let mut inner = self.inner.lock().unwrap();
        keys.iter()
            .map(|k| match inner.map.get_mut(k) {
                Some(Slot::Ready { val, referenced, .. }) => {
                    *referenced = true;
                    Claim::Hit(*val)
                }
                Some(Slot::Pending { waiters }) => {
                    *waiters += 1;
                    Claim::InFlight
                }
                None => {
                    inner.map.insert(k.clone(), Slot::Pending { waiters: 0 });
                    Claim::Owned
                }
            })
            .collect()
    }

    /// Publish results for keys claimed by this caller and wake waiters.
    /// Enforces the capacity bound afterwards.
    fn fill(&self, entries: impl IntoIterator<Item = (Key, f64)>) {
        let mut inner = self.inner.lock().unwrap();
        for (k, v) in entries {
            // carry the waiter count from the Pending slot so the sweep
            // cannot evict a value between fill and the waiters' wakeup
            let waiters = match inner.map.get(&k) {
                Some(Slot::Pending { waiters }) => *waiters,
                _ => 0,
            };
            inner.map.insert(k.clone(), Slot::Ready { val: v, referenced: false, waiters });
            inner.ring.push_back(k);
        }
        if let Some(cap) = self.capacity {
            Self::enforce_capacity(&mut inner, cap);
        }
        self.ready.notify_all();
    }

    /// Second-chance sweep: pop the oldest resident entry; referenced
    /// entries spend their bit and requeue, unreferenced unpinned ones
    /// are reclaimed. The sweep is budgeted so it terminates (allowing
    /// temporary over-capacity) when everything is pinned by waiters.
    fn enforce_capacity(inner: &mut CacheInner, cap: usize) {
        let mut budget = 2 * inner.ring.len();
        while inner.map.len() > cap && budget > 0 {
            budget -= 1;
            let k = match inner.ring.pop_front() {
                Some(k) => k,
                None => break,
            };
            // non-Ready slots under a ring key are stale (defensive):
            // dropping the ring slot is the right cleanup
            if let Some(Slot::Ready { referenced, waiters, .. }) = inner.map.get_mut(&k) {
                if *waiters > 0 {
                    // pinned: a waiter has not drained the value yet
                    inner.ring.push_back(k);
                } else if *referenced {
                    *referenced = false;
                    inner.ring.push_back(k);
                } else {
                    inner.map.remove(&k);
                    inner.evictions += 1;
                }
            }
        }
    }

    /// Abandon claims that were never filled (the evaluator panicked):
    /// remove the Pending slots and wake waiters so they fail loudly
    /// instead of blocking forever.
    fn abandon(&self, keys: &[Key]) {
        let mut inner = self.inner.lock().unwrap();
        for k in keys {
            if let Some(Slot::Pending { .. }) = inner.map.get(k) {
                inner.map.remove(k);
            }
        }
        self.ready.notify_all();
    }

    /// Block until another thread fills `key`, consuming the waiter
    /// registration made by the `InFlight` claim (which pins the entry
    /// against eviction until every registered waiter drained it).
    /// Panics if the owning thread abandoned the claim (its evaluation
    /// panicked) — a missing entry here can only mean the owner died.
    fn wait(&self, key: &Key) -> f64 {
        let mut inner = self.inner.lock().unwrap();
        loop {
            match inner.map.get_mut(key) {
                Some(Slot::Ready { val, referenced, waiters }) => {
                    *referenced = true;
                    *waiters -= 1;
                    return *val;
                }
                Some(Slot::Pending { .. }) => inner = self.ready.wait(inner).unwrap(),
                None => panic!("score evaluation abandoned for {key:?} (evaluator panicked)"),
            }
        }
    }
}

/// Unwinding-safety for claimed cache slots: if the owner does not
/// `disarm()` (evaluation panicked before `fill`), the drop abandons
/// the claims so concurrent waiters are not deadlocked.
struct ClaimGuard<'a> {
    cache: &'a ScoreCache,
    keys: Vec<Key>,
    armed: bool,
}

impl<'a> ClaimGuard<'a> {
    fn new(cache: &'a ScoreCache, keys: Vec<Key>) -> ClaimGuard<'a> {
        ClaimGuard { cache, keys, armed: true }
    }

    fn disarm(mut self) {
        self.armed = false;
    }
}

impl Drop for ClaimGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            self.cache.abandon(&self.keys);
        }
    }
}

impl Default for ScoreCache {
    fn default() -> Self {
        ScoreCache::new()
    }
}

/// Service metrics. The counters satisfy the accounting identity
/// `requests == cache_hits + evaluations + dedup_skips`: every request
/// is exactly one of a cache hit (including waits on in-flight work), a
/// backend evaluation, or an intra-batch duplicate.
#[derive(Default, Debug, Clone)]
pub struct ServiceStats {
    pub requests: u64,
    pub cache_hits: u64,
    pub evaluations: u64,
    /// Intra-batch duplicates folded into one evaluation.
    pub dedup_skips: u64,
    /// Batches submitted through `score_batch`.
    pub batches: u64,
    /// Largest batch (request count) seen so far.
    pub max_batch: u64,
    /// Entries reclaimed from a bounded cache (0 when unbounded).
    /// Outside the request identity: an eviction turns a future request
    /// into a re-evaluation but is never itself a request.
    pub evictions: u64,
    /// Entries dropped by targeted invalidation (dataset appends).
    /// Outside the request identity, like evictions.
    pub invalidations: u64,
    /// Runs that warm-started from a stored CPDAG
    /// ([`ScoreService::warm_start`] returning `Some`).
    pub warm_start_hits: u64,
    /// Resident cache entries at snapshot time.
    pub cache_entries: u64,
    /// Resident heap bytes of the score memo layer (keys + slot
    /// estimate) at snapshot time — the byte-accurate companion of
    /// `cache_entries`, surfaced as the `cvlr_service_cache_bytes`
    /// gauge.
    pub cache_bytes: u64,
    /// Resident fold-core bundles in the backend's `FoldCoreCache`
    /// (CV-LR backends only; 0 otherwise). Each bundle retains a
    /// variable set's per-fold blocks — ~2× the factor-cache footprint
    /// per set — so wide pooled-server sweeps need the bound visible.
    pub core_cache_entries: u64,
    /// Fold-core bundles reclaimed by the bounded cache's second-chance
    /// sweep. Outside the request identity, like `evictions`.
    pub core_cache_evictions: u64,
    /// Resident heap bytes across the backend's core caches (fold-core
    /// + pair-core bundles + factor matrices; CV-LR backends only, 0
    /// otherwise) — the byte-accurate companion of
    /// `core_cache_entries`, surfaced as the
    /// `cvlr_service_core_cache_bytes` gauge.
    pub core_cache_bytes: u64,
    /// Gram-product threads of the backing backend
    /// (`DiscoveryConfig::parallelism`) — a gauge, not a counter, so
    /// the server can expose what each pooled service is using.
    pub gram_threads: u64,
    /// Sub-batches dispatched to shard followers (sharding backends
    /// only; all four shard counters stay 0 for local scoring).
    pub shard_dispatches: u64,
    /// Shard sub-batch re-dispatches after failures.
    pub shard_retries: u64,
    /// Hedged re-dispatches of straggler shard sub-batches.
    pub shard_hedges: u64,
    /// Shard sub-batches (or whole batches) that fell back to local
    /// scoring. Degradation affects latency only — never scores.
    pub shard_degraded: u64,
    /// Per-follower health/latency snapshots of a sharding backend;
    /// empty for local backends.
    pub followers: Vec<FollowerStat>,
    /// Basis re-pivots performed by a streaming backend's incremental
    /// factor states (0 for non-streaming backends) — how often the
    /// append path had to fall back to a fresh factorization.
    pub stream_repivots: u64,
    /// Appended-residual level summed over a streaming backend's live
    /// factor states (0.0 for non-streaming backends) — how far the
    /// incremental bases have drifted since their last re-pivot.
    pub stream_residual: f64,
    pub eval_seconds: f64,
}

impl ServiceStats {
    /// The accounting identity; violated only by a bookkeeping bug.
    pub fn consistent(&self) -> bool {
        self.requests == self.cache_hits + self.evaluations + self.dedup_skips
    }
}

/// Memoizing, batching façade over any [`ScoreBackend`]. Implements
/// `ScoreBackend` itself, so the search is handed the service and never
/// talks to a raw backend.
pub struct ScoreService {
    /// Swappable so a long-lived service can follow its dataset across
    /// appends ([`ScoreService::replace_backend`]) without losing its
    /// cache object, counters, or warm-start state.
    backend: RwLock<Arc<dyn ScoreBackend>>,
    workers: usize,
    cache: ScoreCache,
    /// Last discovered CPDAG, for warm-started re-discovery
    /// ([`ScoreService::set_warm_start`] / [`ScoreService::warm_start`]).
    warm: Mutex<Option<Pdag>>,
    warm_hits: AtomicU64,
    /// Gram-product threads of the backing backend (reported through
    /// [`ServiceStats::gram_threads`]).
    gram_threads: AtomicU64,
    requests: AtomicU64,
    hits: AtomicU64,
    evals: AtomicU64,
    dedups: AtomicU64,
    batches: AtomicU64,
    max_batch: AtomicU64,
    eval_secs: Mutex<f64>,
}

impl ScoreService {
    pub fn new(backend: Arc<dyn ScoreBackend>, workers: usize) -> ScoreService {
        ScoreService::with_cache_capacity(backend, workers, None)
    }

    /// Service with a bounded score cache (None = unbounded). Long-lived
    /// processes (the discovery server) must bound the cache: an
    /// unbounded memo map is a memory leak across jobs.
    pub fn with_cache_capacity(
        backend: Arc<dyn ScoreBackend>,
        workers: usize,
        cache_capacity: Option<usize>,
    ) -> ScoreService {
        ScoreService {
            backend: RwLock::new(backend),
            workers: workers.max(1),
            cache: ScoreCache::with_capacity(cache_capacity),
            warm: Mutex::new(None),
            warm_hits: AtomicU64::new(0),
            gram_threads: AtomicU64::new(1),
            requests: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            evals: AtomicU64::new(0),
            dedups: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            max_batch: AtomicU64::new(0),
            eval_secs: Mutex::new(0.0),
        }
    }

    /// Service over a scalar [`LocalScore`] via [`ScalarBackend`].
    pub fn scalar<S: LocalScore + 'static>(score: S, workers: usize) -> ScoreService {
        ScoreService::new(Arc::new(ScalarBackend(score)), workers)
    }

    /// Resident entries in the score cache (including in-flight claims).
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Targeted invalidation of the memo layer (see
    /// [`ScoreCache::invalidate_all`]): drops every unpinned cached
    /// score, returns how many. Called after a dataset append, when
    /// every memoized value is stale; counted in
    /// [`ServiceStats::invalidations`].
    pub fn invalidate_all(&self) -> u64 {
        self.cache.invalidate_all()
    }

    /// Swap the backing score implementation (the appended-dataset
    /// snapshot) while keeping the cache object, counters, and
    /// warm-start state. The caller is responsible for invalidating
    /// stale entries ([`ScoreService::invalidate_all`]).
    pub fn replace_backend(&self, backend: Arc<dyn ScoreBackend>) {
        *self.backend.write().unwrap() = backend;
    }

    /// Store the CPDAG a completed run produced, to warm-start the next
    /// re-discovery on this service.
    pub fn set_warm_start(&self, cpdag: Pdag) {
        *self.warm.lock().unwrap() = Some(cpdag);
    }

    /// Arm (or lift, with `Budget::none()`) the deadline budget of the
    /// backing backend — see [`ScoreBackend::set_budget`]. Pooled
    /// services outlive one job, so the job runner re-arms this per
    /// run.
    pub fn set_budget(&self, budget: crate::util::Budget) {
        self.backend.read().unwrap().set_budget(budget);
    }

    /// Record the Gram-product thread count the backing backend was
    /// built with (`DiscoveryConfig::parallelism`), so it shows up in
    /// [`ServiceStats::gram_threads`] — set by whoever wires the
    /// backend (engine, server job manager, streaming session).
    pub fn set_gram_threads(&self, threads: u64) {
        self.gram_threads.store(threads.max(1), Ordering::Relaxed);
    }

    /// The stored warm-start CPDAG, if any. A `Some` return counts as a
    /// warm-start hit in [`ServiceStats::warm_start_hits`].
    pub fn warm_start(&self) -> Option<Pdag> {
        let warm = self.warm.lock().unwrap().clone();
        if warm.is_some() {
            self.warm_hits.fetch_add(1, Ordering::Relaxed);
        }
        warm
    }

    /// Snapshot of the counters. The [`ServiceStats::consistent`]
    /// identity holds at quiescence; a snapshot taken while another
    /// thread is mid-batch can transiently observe `requests` ahead of
    /// its matching hit/eval/dedup increments.
    pub fn stats(&self) -> ServiceStats {
        let backend = self.backend.read().unwrap();
        let (core_entries, core_evictions) = backend.core_cache_stats().unwrap_or((0, 0));
        let core_bytes = backend.core_cache_bytes().unwrap_or(0);
        let shard = backend.shard_counters().unwrap_or_default();
        let followers = backend.follower_stats();
        let (stream_repivots, stream_residual) = backend.stream_stats().unwrap_or((0, 0.0));
        drop(backend);
        ServiceStats {
            requests: self.requests.load(Ordering::Relaxed),
            cache_hits: self.hits.load(Ordering::Relaxed),
            evaluations: self.evals.load(Ordering::Relaxed),
            dedup_skips: self.dedups.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            max_batch: self.max_batch.load(Ordering::Relaxed),
            evictions: self.cache.evictions(),
            invalidations: self.cache.invalidations(),
            warm_start_hits: self.warm_hits.load(Ordering::Relaxed),
            cache_entries: self.cache.len() as u64,
            cache_bytes: self.cache.resident_bytes(),
            core_cache_entries: core_entries,
            core_cache_evictions: core_evictions,
            core_cache_bytes: core_bytes,
            gram_threads: self.gram_threads.load(Ordering::Relaxed),
            shard_dispatches: shard.dispatches,
            shard_retries: shard.retries,
            shard_hedges: shard.hedges,
            shard_degraded: shard.degraded,
            followers,
            stream_repivots,
            stream_residual,
            eval_seconds: *self.eval_secs.lock().unwrap(),
        }
    }

    /// Evaluate the unique misses through the backend, split across the
    /// worker pool. Each worker submits its chunk as one sub-batch, so
    /// batch-aware backends amortize shared work within a chunk.
    fn evaluate(&self, misses: &[ScoreRequest]) -> Vec<f64> {
        // Memory scoping is thread-local, so the worker closures enter
        // the score-batch scope themselves — allocations inside spawned
        // workers would otherwise land in "unscoped".
        let _mem = crate::obs::mem::MemScope::enter(crate::obs::mem::Scope::ScoreBatch);
        let backend = self.backend.read().unwrap().clone();
        if self.workers <= 1 || misses.len() <= 1 {
            return backend.score_batch(misses);
        }
        let chunk = misses.len().div_ceil(self.workers);
        let mut out = vec![0.0; misses.len()];
        std::thread::scope(|scope| {
            let mut handles = vec![];
            for (ci, batch) in misses.chunks(chunk).enumerate() {
                let backend = backend.clone();
                handles.push((ci, scope.spawn(move || {
                    let _mem =
                        crate::obs::mem::MemScope::enter(crate::obs::mem::Scope::ScoreBatch);
                    backend.score_batch(batch)
                })));
            }
            for (ci, h) in handles {
                let vals = h.join().expect("score worker panicked");
                out[ci * chunk..ci * chunk + vals.len()].copy_from_slice(&vals);
            }
        });
        out
    }
}

impl ScoreBackend for ScoreService {
    /// Dedup + cache + fan out one batch; scores return in request
    /// order, bit-identical to scalar evaluation.
    fn score_batch(&self, reqs: &[ScoreRequest]) -> Vec<f64> {
        if reqs.is_empty() {
            return vec![];
        }
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.requests.fetch_add(reqs.len() as u64, Ordering::Relaxed);
        self.max_batch.fetch_max(reqs.len() as u64, Ordering::Relaxed);

        // Canonical keys; unique keys in first-appearance order.
        let keys: Vec<Key> = reqs
            .iter()
            .map(|r| {
                let canon = ScoreRequest::new(r.target, &r.parents);
                (canon.target, canon.parents)
            })
            .collect();
        let mut slot_of: HashMap<&Key, usize> = HashMap::with_capacity(keys.len());
        let mut uniq: Vec<Key> = Vec::with_capacity(keys.len());
        let mut req_slot: Vec<usize> = Vec::with_capacity(keys.len());
        for k in &keys {
            let idx = *slot_of.entry(k).or_insert_with(|| {
                uniq.push(k.clone());
                uniq.len() - 1
            });
            req_slot.push(idx);
        }
        self.dedups.fetch_add((reqs.len() - uniq.len()) as u64, Ordering::Relaxed);

        // One lock span: hits resolved and misses claimed atomically.
        let claims = self.cache.claim(&uniq);
        let owned: Vec<usize> =
            (0..uniq.len()).filter(|&i| matches!(claims[i], Claim::Owned)).collect();
        self.hits.fetch_add((uniq.len() - owned.len()) as u64, Ordering::Relaxed);
        self.evals.fetch_add(owned.len() as u64, Ordering::Relaxed);
        metrics::requests_total().add(reqs.len() as u64);
        metrics::dedup_skips_total().add((reqs.len() - uniq.len()) as u64);
        metrics::cache_hits_total().add((uniq.len() - owned.len()) as u64);
        metrics::evaluations_total().add(owned.len() as u64);

        // Evaluate claimed misses and publish them. The guard abandons
        // the claims if the backend panics, so waiters fail instead of
        // hanging.
        let mut owned_val: Vec<Option<f64>> = vec![None; uniq.len()];
        if !owned.is_empty() {
            let guard =
                ClaimGuard::new(&self.cache, owned.iter().map(|&i| uniq[i].clone()).collect());
            let span = trace::span("score-batch", "service")
                .arg("misses", owned.len().to_string());
            let sw = crate::util::Stopwatch::start();
            let miss_reqs: Vec<ScoreRequest> = owned
                .iter()
                .map(|&i| ScoreRequest { target: uniq[i].0, parents: uniq[i].1.clone() })
                .collect();
            let vals = self.evaluate(&miss_reqs);
            let secs = sw.secs();
            let span_id = span.id();
            drop(span);
            metrics::score_batch_seconds().observe_with_exemplar(secs, span_id);
            *self.eval_secs.lock().unwrap() += secs;
            self.cache.fill(owned.iter().zip(&vals).map(|(&i, &v)| (uniq[i].clone(), v)));
            guard.disarm();
            for (&i, &v) in owned.iter().zip(&vals) {
                owned_val[i] = Some(v);
            }
        }

        // Resolve each UNIQUE key exactly once: an InFlight claim
        // registered exactly one waiter, so `wait` must run once per
        // unique key, not once per duplicate occurrence.
        let resolved: Vec<f64> = claims
            .iter()
            .enumerate()
            .map(|(ui, claim)| match claim {
                Claim::Hit(v) => *v,
                Claim::Owned => owned_val[ui].expect("owned slot filled above"),
                Claim::InFlight => self.cache.wait(&uniq[ui]),
            })
            .collect();
        req_slot.iter().map(|&ui| resolved[ui]).collect()
    }

    fn num_vars(&self) -> usize {
        self.backend.read().unwrap().num_vars()
    }

    /// Delegated to the wrapped backend, so per-job wrappers around the
    /// service (the server's `CancelBackend`) and the service itself
    /// report the same fold-core counters.
    fn core_cache_stats(&self) -> Option<(u64, u64)> {
        self.backend.read().unwrap().core_cache_stats()
    }

    fn core_cache_bytes(&self) -> Option<u64> {
        self.backend.read().unwrap().core_cache_bytes()
    }

    fn shard_counters(&self) -> Option<ShardCounters> {
        self.backend.read().unwrap().shard_counters()
    }

    fn follower_stats(&self) -> Vec<FollowerStat> {
        self.backend.read().unwrap().follower_stats()
    }

    fn stream_stats(&self) -> Option<(u64, f64)> {
        self.backend.read().unwrap().stream_stats()
    }
}

impl LocalScore for ScoreService {
    /// Scalar path for legacy callers — same cache, same protocol, as a
    /// one-request batch without the batch counters.
    fn local_score(&self, target: usize, parents: &[usize]) -> f64 {
        self.requests.fetch_add(1, Ordering::Relaxed);
        metrics::requests_total().inc();
        let req = ScoreRequest::new(target, parents);
        let key = req.key();
        match &self.cache.claim(std::slice::from_ref(&key))[0] {
            Claim::Hit(v) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                metrics::cache_hits_total().inc();
                *v
            }
            Claim::InFlight => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                metrics::cache_hits_total().inc();
                self.cache.wait(&key)
            }
            Claim::Owned => {
                self.evals.fetch_add(1, Ordering::Relaxed);
                metrics::evaluations_total().inc();
                let guard = ClaimGuard::new(&self.cache, vec![key.clone()]);
                let sw = crate::util::Stopwatch::start();
                let _mem = crate::obs::mem::MemScope::enter(crate::obs::mem::Scope::ScoreBatch);
                let backend = self.backend.read().unwrap().clone();
                let v = backend.score_batch(std::slice::from_ref(&req))[0];
                let secs = sw.secs();
                metrics::score_batch_seconds().observe(secs);
                *self.eval_secs.lock().unwrap() += secs;
                self.cache.fill([(key, v)]);
                guard.disarm();
                v
            }
        }
    }

    fn num_vars(&self) -> usize {
        self.backend.read().unwrap().num_vars()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    struct SlowScore {
        calls: AtomicUsize,
    }

    impl LocalScore for SlowScore {
        fn local_score(&self, t: usize, p: &[usize]) -> f64 {
            self.calls.fetch_add(1, Ordering::SeqCst);
            // actually slow, so concurrent batches reliably overlap and
            // the in-flight dedup below is genuinely exercised
            std::thread::sleep(std::time::Duration::from_millis(2));
            t as f64 + p.len() as f64 * 0.1
        }
        fn num_vars(&self) -> usize {
            5
        }
    }

    fn reqs_of(pairs: &[(usize, &[usize])]) -> Vec<ScoreRequest> {
        pairs.iter().map(|(t, p)| ScoreRequest::new(*t, p)).collect()
    }

    #[test]
    fn batch_dedups_and_caches() {
        let svc = ScoreService::scalar(SlowScore { calls: AtomicUsize::new(0) }, 2);
        let reqs = reqs_of(&[
            (0, &[1]),
            (0, &[1]),    // duplicate
            (1, &[0, 2]),
            (1, &[2, 0]), // same set, different order
        ]);
        let out = svc.score_batch(&reqs);
        assert_eq!(out[0], out[1]);
        assert_eq!(out[2], out[3]);
        let st = svc.stats();
        assert_eq!(st.evaluations, 2, "only two unique evaluations");
        assert_eq!(st.dedup_skips, 2, "two intra-batch duplicates");
        assert_eq!(st.max_batch, 4);
        assert!(st.consistent(), "{st:?}");
        // second batch: all unique keys hit
        let out2 = svc.score_batch(&reqs);
        assert_eq!(out, out2);
        let st = svc.stats();
        assert_eq!(st.evaluations, 2);
        assert_eq!(st.cache_hits, 2, "second batch: 2 unique hits (dups are dedup_skips)");
        assert_eq!(st.dedup_skips, 4);
        assert!(st.consistent(), "{st:?}");
    }

    #[test]
    fn single_requests_cached() {
        let svc = ScoreService::scalar(SlowScore { calls: AtomicUsize::new(0) }, 1);
        let a = svc.local_score(2, &[4, 3]);
        let b = svc.local_score(2, &[3, 4]);
        assert_eq!(a, b);
        let st = svc.stats();
        assert_eq!(st.evaluations, 1);
        assert_eq!(st.cache_hits, 1);
        assert_eq!(st.batches, 0, "scalar path is not a batch");
        assert!(st.consistent(), "{st:?}");
    }

    #[test]
    fn parallel_batch_order_preserved() {
        let svc = ScoreService::scalar(SlowScore { calls: AtomicUsize::new(0) }, 4);
        let reqs: Vec<ScoreRequest> = (0..5).map(|t| ScoreRequest::new(t, &[])).collect();
        let out = svc.score_batch(&reqs);
        assert_eq!(out, vec![0.0, 1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn duplicate_requests_on_inflight_key_wait_once() {
        // regression: a batch containing the same key several times
        // while another thread has it in flight must consume exactly
        // the one waiter registration its claim made (no underflow)
        let svc = Arc::new(ScoreService::with_cache_capacity(
            Arc::new(ScalarBackend(SlowScore { calls: AtomicUsize::new(0) })),
            1,
            Some(4),
        ));
        std::thread::scope(|scope| {
            let a = svc.clone();
            scope.spawn(move || {
                a.score_batch(&reqs_of(&[(0, &[1])]));
            });
            std::thread::sleep(std::time::Duration::from_millis(1));
            let out = svc.score_batch(&reqs_of(&[(0, &[1]), (0, &[1]), (0, &[1])]));
            assert!(out.iter().all(|&v| v == out[0]), "{out:?}");
        });
        let st = svc.stats();
        assert_eq!(st.evaluations, 1, "{st:?}");
        assert!(st.consistent(), "{st:?}");
        // the entry must be evictable again (waiter count drained to 0)
        for t in 1..5 {
            svc.local_score(t, &[]);
        }
        assert!(svc.cache_len() <= 4, "pinned entry leaked a waiter");
    }

    #[test]
    fn concurrent_batches_evaluate_each_key_once() {
        let svc = Arc::new(ScoreService::scalar(SlowScore { calls: AtomicUsize::new(0) }, 1));
        let reqs: Vec<ScoreRequest> = (0..4).map(|t| ScoreRequest::new(t, &[4])).collect();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let svc = svc.clone();
                let reqs = reqs.clone();
                scope.spawn(move || {
                    let out = svc.score_batch(&reqs);
                    assert_eq!(out, vec![0.1, 1.1, 2.1, 3.1]);
                });
            }
        });
        let st = svc.stats();
        assert_eq!(st.evaluations, 4, "in-flight dedup must prevent double evaluation");
        assert_eq!(st.requests, 16);
        assert!(st.consistent(), "{st:?}");
    }

    #[test]
    fn bounded_cache_evicts_oldest_unreferenced() {
        let svc = ScoreService::with_cache_capacity(
            Arc::new(ScalarBackend(SlowScore { calls: AtomicUsize::new(0) })),
            1,
            Some(2),
        );
        // fill keys 0, 1, 2 → capacity 2 forces one eviction (key 0:
        // oldest, never re-referenced)
        for t in 0..3 {
            svc.local_score(t, &[]);
        }
        let st = svc.stats();
        assert_eq!(st.evaluations, 3);
        assert_eq!(st.evictions, 1, "{st:?}");
        assert!(svc.cache_len() <= 2);
        // evicted key re-evaluates; resident key hits
        svc.local_score(0, &[]);
        svc.local_score(2, &[]);
        let st = svc.stats();
        assert_eq!(st.evaluations, 4, "key 0 was evicted and re-evaluated");
        assert_eq!(st.cache_hits, 1, "key 2 stayed resident");
        assert!(st.consistent(), "{st:?}");
    }

    #[test]
    fn second_chance_spares_referenced_entries() {
        let svc = ScoreService::with_cache_capacity(
            Arc::new(ScalarBackend(SlowScore { calls: AtomicUsize::new(0) })),
            1,
            Some(2),
        );
        svc.local_score(0, &[]); // A
        svc.local_score(1, &[]); // B
        svc.local_score(0, &[]); // hit A → referenced bit set
        svc.local_score(2, &[]); // C: sweep spares A (second chance), evicts B
        let st = svc.stats();
        assert_eq!(st.evictions, 1, "{st:?}");
        svc.local_score(0, &[]); // A must still be resident
        let st = svc.stats();
        assert_eq!(st.evaluations, 3, "A survived the sweep: {st:?}");
        assert_eq!(st.cache_hits, 2);
        svc.local_score(1, &[]); // B was the victim
        let st = svc.stats();
        assert_eq!(st.evaluations, 4, "B was evicted: {st:?}");
        assert!(st.consistent(), "{st:?}");
    }

    #[test]
    fn invalidate_all_forces_reevaluation_and_counts() {
        let svc = ScoreService::scalar(SlowScore { calls: AtomicUsize::new(0) }, 1);
        for t in 0..3 {
            svc.local_score(t, &[]);
        }
        assert_eq!(svc.invalidate_all(), 3);
        assert_eq!(svc.cache_len(), 0);
        // same keys: all re-evaluated, none served stale
        for t in 0..3 {
            svc.local_score(t, &[]);
        }
        let st = svc.stats();
        assert_eq!(st.evaluations, 6, "{st:?}");
        assert_eq!(st.invalidations, 3, "{st:?}");
        assert!(st.consistent(), "identity must survive invalidation: {st:?}");
    }

    #[test]
    fn warm_start_roundtrip_counts_hits() {
        let svc = ScoreService::scalar(SlowScore { calls: AtomicUsize::new(0) }, 1);
        assert!(svc.warm_start().is_none(), "no warm state initially");
        assert_eq!(svc.stats().warm_start_hits, 0, "a miss is not a hit");
        let mut p = crate::graph::Pdag::new(3);
        p.add_directed(0, 1);
        svc.set_warm_start(p.clone());
        assert_eq!(svc.warm_start(), Some(p));
        assert_eq!(svc.stats().warm_start_hits, 1);
    }

    #[test]
    fn replace_backend_keeps_counters_and_serves_new_values() {
        struct Fixed(f64);
        impl LocalScore for Fixed {
            fn local_score(&self, _: usize, _: &[usize]) -> f64 {
                self.0
            }
            fn num_vars(&self) -> usize {
                3
            }
        }
        let svc = ScoreService::scalar(Fixed(1.0), 1);
        assert_eq!(svc.local_score(0, &[]), 1.0);
        svc.replace_backend(Arc::new(ScalarBackend(Fixed(2.0))));
        // stale entry still cached until invalidated
        assert_eq!(svc.local_score(0, &[]), 1.0);
        svc.invalidate_all();
        assert_eq!(svc.local_score(0, &[]), 2.0, "post-invalidate scores come from the new backend");
        let st = svc.stats();
        assert_eq!(st.evaluations, 2);
        assert_eq!(st.cache_hits, 1);
        assert!(st.consistent(), "{st:?}");
    }

    #[test]
    fn unbounded_cache_reports_zero_evictions() {
        let svc = ScoreService::scalar(SlowScore { calls: AtomicUsize::new(0) }, 1);
        for t in 0..5 {
            svc.local_score(t, &[]);
        }
        let st = svc.stats();
        assert_eq!(st.evictions, 0);
        assert_eq!(st.cache_entries, 5);
        assert!(st.cache_bytes > 0, "resident entries have nonzero byte footprint");
        assert_eq!(st.core_cache_bytes, 0, "scalar backends report no core cache");
    }

    #[test]
    fn mixed_scalar_and_batch_share_the_cache() {
        let svc = ScoreService::scalar(SlowScore { calls: AtomicUsize::new(0) }, 1);
        let a = svc.local_score(3, &[1]);
        let out = svc.score_batch(&reqs_of(&[(3, &[1]), (2, &[])]));
        assert_eq!(a, out[0]);
        let st = svc.stats();
        assert_eq!(st.evaluations, 2);
        assert_eq!(st.cache_hits, 1);
        assert!(st.consistent(), "{st:?}");
    }
}
