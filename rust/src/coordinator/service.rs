//! The score service: routes local-score requests from the search to
//! the scoring backend with request deduplication, a shared memo cache
//! and a worker pool for batch evaluation.
//!
//! GES evaluates hundreds of (target, parent-set) candidates per step,
//! with heavy overlap between steps — the service's cache turns that
//! overlap into hits, and `score_batch` fans independent misses out
//! over `workers` threads (each backend is `Sync`; the PJRT backend
//! serializes device access internally, so threads help exactly when
//! the native backend or factor construction dominates).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::score::LocalScore;

/// Service metrics.
#[derive(Default, Debug, Clone)]
pub struct ServiceStats {
    pub requests: u64,
    pub cache_hits: u64,
    pub evaluations: u64,
    pub batches: u64,
    pub eval_seconds: f64,
}

/// Memoizing, batching façade over any `LocalScore`.
pub struct ScoreService {
    backend: Arc<dyn LocalScore>,
    workers: usize,
    cache: Mutex<HashMap<(usize, Vec<usize>), f64>>,
    requests: AtomicU64,
    hits: AtomicU64,
    evals: AtomicU64,
    batches: AtomicU64,
    eval_secs: Mutex<f64>,
}

impl ScoreService {
    pub fn new(backend: Arc<dyn LocalScore>, workers: usize) -> ScoreService {
        ScoreService {
            backend,
            workers: workers.max(1),
            cache: Mutex::new(HashMap::new()),
            requests: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            evals: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            eval_secs: Mutex::new(0.0),
        }
    }

    pub fn stats(&self) -> ServiceStats {
        ServiceStats {
            requests: self.requests.load(Ordering::Relaxed),
            cache_hits: self.hits.load(Ordering::Relaxed),
            evaluations: self.evals.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            eval_seconds: *self.eval_secs.lock().unwrap(),
        }
    }

    fn key(target: usize, parents: &[usize]) -> (usize, Vec<usize>) {
        let mut p = parents.to_vec();
        p.sort_unstable();
        (target, p)
    }

    /// Evaluate a batch of requests: dedup, split misses across the
    /// worker pool, fill the cache, return scores in request order.
    pub fn score_batch(&self, reqs: &[(usize, Vec<usize>)]) -> Vec<f64> {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.requests.fetch_add(reqs.len() as u64, Ordering::Relaxed);
        let keys: Vec<(usize, Vec<usize>)> =
            reqs.iter().map(|(t, p)| Self::key(*t, p)).collect();

        // collect unique misses
        let mut misses: Vec<(usize, Vec<usize>)> = vec![];
        {
            let cache = self.cache.lock().unwrap();
            let mut seen: HashMap<&(usize, Vec<usize>), ()> = HashMap::new();
            for k in &keys {
                if cache.contains_key(k) {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                } else if seen.insert(k, ()).is_none() {
                    misses.push(k.clone());
                }
            }
        }

        if !misses.is_empty() {
            let sw = crate::util::Stopwatch::start();
            let results: Vec<f64> = if self.workers <= 1 || misses.len() <= 1 {
                misses
                    .iter()
                    .map(|(t, p)| self.backend.local_score(*t, p))
                    .collect()
            } else {
                let chunk = misses.len().div_ceil(self.workers);
                let backend = &self.backend;
                let mut out = vec![0.0; misses.len()];
                std::thread::scope(|scope| {
                    let mut handles = vec![];
                    for (ci, batch) in misses.chunks(chunk).enumerate() {
                        let backend = backend.clone();
                        handles.push((
                            ci,
                            scope.spawn(move || {
                                batch
                                    .iter()
                                    .map(|(t, p)| backend.local_score(*t, p))
                                    .collect::<Vec<f64>>()
                            }),
                        ));
                    }
                    for (ci, h) in handles {
                        let vals = h.join().expect("score worker panicked");
                        out[ci * chunk..ci * chunk + vals.len()].copy_from_slice(&vals);
                    }
                });
                out
            };
            self.evals.fetch_add(misses.len() as u64, Ordering::Relaxed);
            *self.eval_secs.lock().unwrap() += sw.secs();
            let mut cache = self.cache.lock().unwrap();
            for (k, v) in misses.into_iter().zip(results) {
                cache.insert(k, v);
            }
        }

        let cache = self.cache.lock().unwrap();
        keys.iter().map(|k| cache[k]).collect()
    }
}

impl LocalScore for ScoreService {
    fn local_score(&self, target: usize, parents: &[usize]) -> f64 {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let key = Self::key(target, parents);
        if let Some(&v) = self.cache.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return v;
        }
        let sw = crate::util::Stopwatch::start();
        let v = self.backend.local_score(target, &key.1);
        self.evals.fetch_add(1, Ordering::Relaxed);
        *self.eval_secs.lock().unwrap() += sw.secs();
        self.cache.lock().unwrap().insert(key, v);
        v
    }

    fn num_vars(&self) -> usize {
        self.backend.num_vars()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    struct SlowScore {
        calls: AtomicUsize,
    }

    impl LocalScore for SlowScore {
        fn local_score(&self, t: usize, p: &[usize]) -> f64 {
            self.calls.fetch_add(1, Ordering::SeqCst);
            t as f64 + p.len() as f64 * 0.1
        }
        fn num_vars(&self) -> usize {
            5
        }
    }

    #[test]
    fn batch_dedups_and_caches() {
        let svc = ScoreService::new(Arc::new(SlowScore { calls: AtomicUsize::new(0) }), 2);
        let reqs = vec![
            (0usize, vec![1usize]),
            (0, vec![1]),     // duplicate
            (1, vec![0, 2]),
            (1, vec![2, 0]),  // same set, different order
        ];
        let out = svc.score_batch(&reqs);
        assert_eq!(out[0], out[1]);
        assert_eq!(out[2], out[3]);
        let st = svc.stats();
        assert_eq!(st.evaluations, 2, "only two unique evaluations");
        // second batch: all hits
        let out2 = svc.score_batch(&reqs);
        assert_eq!(out, out2);
        assert_eq!(svc.stats().evaluations, 2);
    }

    #[test]
    fn single_requests_cached() {
        let svc = ScoreService::new(Arc::new(SlowScore { calls: AtomicUsize::new(0) }), 1);
        let a = svc.local_score(2, &[4, 3]);
        let b = svc.local_score(2, &[3, 4]);
        assert_eq!(a, b);
        let st = svc.stats();
        assert_eq!(st.evaluations, 1);
        assert_eq!(st.cache_hits, 1);
    }

    #[test]
    fn parallel_batch_order_preserved() {
        let svc = ScoreService::new(Arc::new(SlowScore { calls: AtomicUsize::new(0) }), 4);
        let reqs: Vec<(usize, Vec<usize>)> = (0..5).map(|t| (t, vec![])).collect();
        let out = svc.score_batch(&reqs);
        assert_eq!(out, vec![0.0, 1.0, 2.0, 3.0, 4.0]);
    }
}
