//! L3 coordinator: the score service (request routing, dedup caching,
//! batch dispatch over a worker pool) and the discovery engine that
//! glues datasets, scores, searches and the PJRT runtime together.

pub mod service;
pub mod engine;

pub use engine::{discover, DiscoveryConfig, DiscoveryOutcome, EngineKind, Method};
pub use service::ScoreService;
