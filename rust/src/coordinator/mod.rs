//! L3 coordinator: the batching score service (request dedup, the
//! single `ScoreCache` memo layer, worker-pool fan-out of
//! `ScoreBackend::score_batch` sub-batches) and the discovery engine —
//! a method registry plus the `Discovery` builder façade that glues
//! datasets, score backends, searches and the PJRT runtime together.

pub mod service;
pub mod engine;

pub use engine::{
    discover, register_score_method, register_search_method, registered_methods, resolve_method,
    run_named, score_backend_for, Discovery, DiscoveryBuilder, DiscoveryConfig, DiscoveryOutcome,
    EngineKind, Method, MethodKind,
};
pub use service::{ScoreCache, ScoreService, ServiceStats};
