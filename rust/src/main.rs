//! `cvlr` — the leader entrypoint of the causal-discovery coordinator.
//!
//! Subcommands:
//!
//! * `discover` — run causal discovery on a workload (synthetic FCM
//!   data, SACHS, CHILD, or a CSV file) with any method;
//! * `stream`   — replay a workload as a row stream: per-chunk
//!   incremental factor appends + warm-started re-discovery, with a
//!   per-chunk latency table (see `stream`);
//! * `score`    — evaluate one local score S(X | Z) and print it;
//! * `serve`    — run the long-lived discovery server (HTTP/JSON job
//!   API over the batch-first score service; see `server`);
//! * `selftest` — quick end-to-end check of all three layers
//!   (used by `make smoke`);
//! * `lint`     — repo-invariant static checks (`ci::lint`; CI gate);
//! * `info`     — print the artifact registry and build information.
//!
//! Examples:
//!
//! ```text
//! cvlr discover --data synth --n 500 --density 0.4 --method cv-lr
//! cvlr discover --data sachs --n 2000 --method cv-lr --engine pjrt
//! cvlr discover --data synth --method cv-lr --shards 127.0.0.1:7901,127.0.0.1:7902
//! cvlr discover --data experiments/run1.csv --method bic
//! cvlr stream --data experiments/run1.csv --chunk 200
//! cvlr score --data child --n 500 --target 3 --parents 1,2
//! cvlr serve --port 7878 --job-workers 2 --cache-cap 1048576
//! cvlr selftest
//! ```

use std::process::ExitCode;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use cvlr::coordinator::{discover, Discovery, DiscoveryConfig, EngineKind};
use cvlr::data::synth::{generate, DataKind, SynthConfig};
use cvlr::data::{networks, Dataset};
use cvlr::distrib::{PoolConfig, ShardScoreBackend};
use cvlr::graph::{normalized_shd, skeleton_f1, Dag};
use cvlr::linalg::Mat;
use cvlr::lowrank::{FactorMethod, LowRankConfig};
use cvlr::runtime::Runtime;
use cvlr::score::cvlr::{CvLrScore, NativeCvLrKernel};
use cvlr::score::folds::CvParams;
use cvlr::score::{LocalScore, ScalarBackend, ScoreBackend, ScoreRequest};
use cvlr::server::{registry, Server, ServerConfig};
use cvlr::stream::{StreamConfig, StreamingDiscovery};
use cvlr::util::cli::Args;
use cvlr::util::csv::Table;
use cvlr::util::timing::fmt_secs;
use cvlr::util::Stopwatch;

fn main() -> ExitCode {
    let args = Args::from_env();
    // chaos configuration first, so every command (and the serve
    // endpoints) runs under the armed failpoints; both sources error
    // out unless the binary was built with `--features fail-inject`
    if let Err(e) = init_failpoints(&args) {
        eprintln!("error: {e:#}");
        return ExitCode::FAILURE;
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let res = match cmd {
        "discover" => cmd_discover(&args),
        "stream" => cmd_stream(&args),
        "score" => cmd_score(&args),
        "serve" => cmd_serve(&args),
        "selftest" => cmd_selftest(&args),
        "lint" => cvlr::ci::lint::run_cli(),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command `{other}`\n");
            print_help();
            Err(anyhow::anyhow!("unknown command"))
        }
    };
    match res {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    println!(
        "cvlr — fast causal discovery by approximate kernel-based generalized \
         score functions (KDD'25 reproduction)\n\n\
         USAGE: cvlr <COMMAND> [OPTIONS]\n\n\
         COMMANDS:\n\
         \x20 discover   run causal discovery on a workload\n\
         \x20 stream     replay a workload as a row stream (incremental factors,\n\
         \x20            warm-started re-discovery, per-chunk latency table)\n\
         \x20 score      evaluate one local score S(X | Z)\n\
         \x20 serve      run the HTTP/JSON discovery server\n\
         \x20 selftest   end-to-end three-layer smoke check\n\
         \x20 lint       repo-invariant checks (SAFETY comments, no lock\n\
         \x20            unwraps in the serving stack, failpoint docs,\n\
         \x20            declared metrics); nonzero exit on violations\n\
         \x20 info       artifact registry + build info\n\n\
         COMMON OPTIONS:\n\
         \x20 --data synth|sachs|child|sachs-cont|FILE.csv  workload (default synth)\n\
         \x20 --n N                                 sample size (default 500)\n\
         \x20 --seed S                              RNG seed (default 0)\n\
         \x20 --method cv-lr|cv|marg-lr|bic|bdeu|sc|pc|mm  (default cv-lr)\n\
         \x20 --engine native|pjrt                  CV-LR backend (default native)\n\
         \x20 --artifacts DIR                       artifacts dir (default artifacts)\n\
         \x20 --workers W                           score-service threads (default 1)\n\
         \x20 --parallelism P                       Gram-product threads in the CV-LR\n\
         \x20                                       fold-core builds (default 1; 0 = auto:\n\
         \x20                                       available cores capped at the fold count)\n\
         \x20 --lowrank icl|rff                     CV-LR factorization (default icl;\n\
         \x20                                       rff = data-independent Fourier features,\n\
         \x20                                       O(m) streaming appends, no re-pivots)\n\
         \x20 --shards H:P,H:P                      follower fleet (`cvlr serve` processes)\n\
         \x20                                       for distributed score batches; datasets\n\
         \x20                                       auto-register on followers, dead/slow\n\
         \x20                                       followers degrade to local scoring\n\
         \x20 --trace-out FILE.json                 record stage spans and write a Chrome\n\
         \x20                                       trace-event snapshot (Perfetto-loadable)\n\
         \x20                                       on completion (discover/stream/score)\n\
         \x20 --metrics-out FILE.prom               write a final Prometheus snapshot of\n\
         \x20                                       every cvlr_* series — incl. per-scope\n\
         \x20                                       cvlr_mem_peak_bytes — on completion\n\
         \x20                                       (discover/stream/score)\n\
         \x20 --failpoints site=action;…            arm chaos failpoints (error, delay(MS),\n\
         \x20                                       corrupt, panic; also CVLR_FAILPOINTS env\n\
         \x20                                       var); needs a --features fail-inject build\n\n\
         discover OPTIONS:\n\
         \x20 --density D      synth graph density (default 0.4)\n\
         \x20 --kind continuous|mixed|multidim      synth data kind\n\
         \x20 --vars V         synth variable count (default 7)\n\
         \x20 --csv-header true|false               force/suppress CSV header row\n\
         \x20 --cache-cap C    bound the score cache (0 = unbounded)\n\
         \x20 --deadline-ms T  end-to-end deadline: shard dispatch/retries clamp to\n\
         \x20                  it and an expired run fails typed, never hangs\n\n\
         stream OPTIONS:\n\
         \x20 --chunk C        rows per streamed chunk (default 100, min 2×folds)\n\
         \x20 --cache-cap C    bound the score cache (0 = unbounded)\n\
         \x20 --check          verify factor exactness at the end (O(n²) pass)\n\n\
         score OPTIONS:\n\
         \x20 --target T       target variable index (default 0)\n\
         \x20 --parents CSV    comma-separated parent indices (default empty)\n\n\
         serve OPTIONS:\n\
         \x20 --port P         listen port on localhost (default 7878)\n\
         \x20 --job-workers J  concurrent discovery jobs (default 2)\n\
         \x20 --cache-cap C    per-service score-cache bound (default 2^20, 0 = unbounded)\n\
         \x20 --n N --seed S   sampling of the built-in datasets\n\
         \x20 --shards H:P,H:P default follower fleet for score jobs (the server\n\
         \x20                  acts as a sharding coordinator; per-job `shards`\n\
         \x20                  overrides it)\n\
         \x20 --max-queued-jobs Q                   admission bound: queued jobs beyond Q\n\
         \x20                  are refused with 429 + Retry-After (default 256)\n\
         \x20 --mem-high-water-mb M                 live-heap high-water mark: above it job\n\
         \x20                  submission sheds pooled caches, then answers 503\n\
         \x20                  (needs the default mem-profile feature)"
    );
}

/// Arm the failpoint registry before any command runs: the
/// `CVLR_FAILPOINTS` env var first, then `--failpoints site=action;…`
/// merged over it.
fn init_failpoints(args: &Args) -> Result<()> {
    cvlr::obs::fail::init_from_env()?;
    if let Some(spec) = args.get("failpoints") {
        cvlr::obs::fail::configure(spec)?;
    }
    Ok(())
}

/// `--trace-out FILE`: attach the span recorder before the run so every
/// stage span of the command lands in the ring. Returns the path to
/// write at completion.
fn trace_out_arg(args: &Args) -> Option<String> {
    let path = args.get("trace-out")?;
    cvlr::obs::trace::enable();
    Some(path.to_string())
}

/// Snapshot the span ring as Chrome trace-event JSON at `path`.
fn write_trace(path: &str) -> Result<()> {
    std::fs::write(path, cvlr::obs::trace::export_json())
        .with_context(|| format!("writing trace to {path}"))?;
    println!("trace    : wrote {path} (load it in Perfetto or chrome://tracing)");
    Ok(())
}

/// `--metrics-out FILE.prom`: the path for a final Prometheus snapshot
/// written at command completion (the one-shot mirror of the server's
/// `GET /v1/metrics` pull endpoint).
fn metrics_out_arg(args: &Args) -> Option<String> {
    args.get("metrics-out").map(str::to_string)
}

/// Dump every `cvlr_*` series — counters, gauges, per-scope memory
/// peaks, histograms with exemplars — as Prometheus text at `path`.
fn write_metrics(path: &str) -> Result<()> {
    cvlr::obs::metrics::register_defaults();
    cvlr::obs::mem::publish();
    std::fs::write(path, cvlr::obs::metrics::render())
        .with_context(|| format!("writing metrics to {path}"))?;
    println!("metrics  : wrote {path} (Prometheus text exposition)");
    Ok(())
}

/// Parse `--lowrank {icl,rff}` (the CV-LR factorization; default icl).
fn lowrank_arg(args: &Args) -> Result<FactorMethod> {
    let name = args.get_or("lowrank", "icl");
    FactorMethod::parse(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown --lowrank `{name}` (icl|rff)"))
}

/// Parse `--shards host:port,host:port` into the follower list (empty =
/// local scoring).
fn shard_arg(args: &Args) -> Vec<String> {
    args.get("shards")
        .map(|s| s.split(',').filter(|a| !a.is_empty()).map(str::to_string).collect())
        .unwrap_or_default()
}

/// The registry name a coordinator uses when auto-registering its
/// workload on followers. Registry names are `[A-Za-z0-9._-]`, so CSV
/// paths get their separators mapped to `-`.
fn shard_dataset_name(data: &str) -> String {
    let s: String = data
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || "._-".contains(c) { c } else { '-' })
        .collect();
    if s.is_empty() {
        "coordinator".to_string()
    } else {
        s
    }
}

/// Build the workload named by `--data`: a dataset plus (if known) the
/// ground-truth DAG for metric reporting.
fn load_workload(args: &Args) -> Result<(Arc<Dataset>, Option<Dag>, String)> {
    let n = args.usize_or("n", 500);
    let seed = args.u64_or("seed", 0);
    let name = args.get_or("data", "synth");
    Ok(match name.as_str() {
        "synth" => {
            let kind = match args.get_or("kind", "continuous").as_str() {
                "continuous" => DataKind::Continuous,
                "mixed" => DataKind::Mixed,
                "multidim" | "multi-dim" => DataKind::MultiDim,
                k => bail!("unknown data kind `{k}`"),
            };
            let cfg = SynthConfig {
                n,
                num_vars: args.usize_or("vars", 7),
                density: args.f64_or("density", 0.4),
                kind,
                seed,
            };
            let (ds, dag) = generate(&cfg);
            (
                Arc::new(ds),
                Some(dag),
                format!(
                    "synth(kind={kind:?}, d={}, density={}, n={n})",
                    cfg.num_vars, cfg.density
                ),
            )
        }
        "sachs" => {
            let net = networks::sachs();
            let ds = networks::forward_sample(&net, n, seed);
            (Arc::new(ds), Some(net.dag), format!("SACHS discrete (n={n})"))
        }
        "child" => {
            let net = networks::child();
            let ds = networks::forward_sample(&net, n, seed);
            (Arc::new(ds), Some(net.dag), format!("CHILD discrete (n={n})"))
        }
        "sachs-cont" => {
            let (ds, dag) = networks::sachs_continuous(n, seed);
            (Arc::new(ds), Some(dag), format!("SACHS continuous SEM (n={n})"))
        }
        // CSV files go through the same ingestion/type-inference path
        // as server uploads (server::registry); no ground truth, so
        // discover prints no F1/SHD
        other if other.ends_with(".csv") || std::path::Path::new(other).is_file() => {
            let header = args.get("csv-header").and_then(|v| match v {
                "true" | "yes" => Some(true),
                "false" | "no" => Some(false),
                _ => None,
            });
            let ds = registry::dataset_from_csv_file(other, header)?;
            let desc = format!("csv {other} (n={}, d={})", ds.n(), ds.d());
            (Arc::new(ds), None, desc)
        }
        other => bail!("unknown workload `{other}` (synth|sachs|child|sachs-cont|FILE.csv)"),
    })
}

fn cmd_discover(args: &Args) -> Result<()> {
    let trace_out = trace_out_arg(args);
    let metrics_out = metrics_out_arg(args);
    let (ds, truth, desc) = load_workload(args)?;
    let engine = match args.get_or("engine", "native").as_str() {
        "native" => EngineKind::Native,
        "pjrt" => EngineKind::Pjrt,
        e => bail!("unknown --engine `{e}` (native|pjrt)"),
    };
    println!("workload : {desc}");
    // the builder façade: method by registry name, knobs, run
    let mut builder = Discovery::builder(ds)
        .method(args.get_or("method", "cv-lr"))
        .engine(engine)
        .workers(args.usize_or("workers", 1))
        .parallelism(args.usize_or("parallelism", 1))
        .lowrank_method(lowrank_arg(args)?)
        .artifacts_dir(args.get_or("artifacts", "artifacts"));
    let cache_cap = args.usize_or("cache-cap", 0);
    if cache_cap > 0 {
        builder = builder.cache_capacity(cache_cap);
    }
    // end-to-end deadline: clamps shard dispatch/retry and fails the
    // run with a typed `deadline exceeded` error instead of hanging
    if let Some(ms) = args.get("deadline-ms") {
        builder = builder.deadline_ms(ms.parse().context("bad --deadline-ms")?);
    }
    let shards = shard_arg(args);
    if !shards.is_empty() {
        println!("shards   : {}", shards.join(", "));
        builder = builder
            .shards(shards)
            .shard_dataset(shard_dataset_name(&args.get_or("data", "synth")));
    }
    let out = builder.run()?;
    println!("method   : {} ({engine:?} engine)", out.method);
    println!("time     : {}", fmt_secs(out.seconds));
    println!("edges    : {}", out.cpdag.num_edges());
    if let Some(truth) = truth {
        println!("F1       : {:.3}", skeleton_f1(&out.cpdag, &truth));
        println!("SHD      : {:.3}", normalized_shd(&out.cpdag, &truth));
    }
    if let Some(st) = out.score_stats {
        let hit = st.cache_hits as f64 / st.requests.max(1) as f64;
        println!(
            "service  : {} requests in {} batches (max {}), {} evals, \
             {:.0}% cache hits, {} dups, {} evictions, {} in scoring",
            st.requests,
            st.batches,
            st.max_batch,
            st.evaluations,
            hit * 100.0,
            st.dedup_skips,
            st.evictions,
            fmt_secs(st.eval_seconds)
        );
    }
    if let Some(ci) = out.ci_tests {
        println!("CI tests : {ci}");
    }
    println!("\nlearned CPDAG (X→Y directed, X—Y undirected):");
    let p = &out.cpdag;
    let d = p.d;
    for i in 0..d {
        for j in 0..d {
            if p.directed(i, j) {
                println!("  {i} → {j}");
            } else if i < j && p.undirected(i, j) {
                println!("  {i} — {j}");
            }
        }
    }
    if let Some(path) = &trace_out {
        write_trace(path)?;
    }
    if let Some(path) = &metrics_out {
        write_metrics(path)?;
    }
    Ok(())
}

/// `cvlr stream` — replay a workload as a row stream: seed a streaming
/// session with the first chunk, then append + re-discover per chunk,
/// reporting append latency (the O(c·m²) incremental factor work —
/// flat in n), re-pivots, discovery latency and cache reuse.
fn cmd_stream(args: &Args) -> Result<()> {
    let trace_out = trace_out_arg(args);
    let metrics_out = metrics_out_arg(args);
    let (ds, truth, desc) = load_workload(args)?;
    let chunk = args.usize_or("chunk", 100);
    let folds = cvlr::score::folds::CvParams::default().folds;
    if chunk < 2 * folds {
        bail!("--chunk {chunk} too small: the {folds}-fold CV split needs at least {} rows", 2 * folds);
    }
    let n = ds.n();
    if n <= chunk {
        bail!("workload has {n} rows — need more than one chunk of {chunk} (lower --chunk or raise --n)");
    }
    let lowrank = lowrank_arg(args)?;
    let engine = match args.get_or("engine", "native").as_str() {
        "native" => EngineKind::Native,
        "pjrt" => EngineKind::Pjrt,
        e => bail!("unknown --engine `{e}` (native|pjrt)"),
    };
    println!("workload : {desc}");
    println!(
        "streaming: chunks of {chunk} rows, CV-LR ({engine:?} engine, {} factors)\n",
        lowrank.name()
    );

    let cfg = StreamConfig {
        workers: args.usize_or("workers", 1),
        parallelism: args.usize_or("parallelism", 1),
        lowrank: LowRankConfig::with_method(lowrank),
        cache_capacity: match args.usize_or("cache-cap", 0) {
            0 => None,
            c => Some(c),
        },
        engine,
        artifacts_dir: args.get_or("artifacts", "artifacts"),
        ..Default::default()
    };
    // head() keeps the full variable schema (names, cardinalities), so
    // later chunks only confirm levels, never re-code them
    let mut sess = StreamingDiscovery::try_with_config(ds.head(chunk), cfg)?;
    let rows_of = |lo: usize, hi: usize| -> Mat {
        let idx: Vec<usize> = (lo..hi).collect();
        ds.data.select_rows(&idx)
    };

    let mut table = Table::new(&[
        "chunk", "rows", "append", "repivots", "discover", "sweeps", "edges", "warm", "hit%",
    ]);
    let push = |table: &mut Table,
                idx: usize,
                rows: usize,
                append: Option<&cvlr::stream::AppendStats>,
                out: &cvlr::stream::StreamOutcome| {
        let hit = 100.0 * out.cache_hits as f64 / out.requests.max(1) as f64;
        table.row(&[
            idx.to_string(),
            rows.to_string(),
            append.map(|a| fmt_secs(a.seconds)).unwrap_or_else(|| "-".into()),
            append.map(|a| a.repivots.to_string()).unwrap_or_else(|| "-".into()),
            fmt_secs(out.seconds),
            out.batches.to_string(),
            out.cpdag.num_edges().to_string(),
            if out.warm_started { "yes".into() } else { "no".into() },
            format!("{hit:.0}"),
        ]);
    };

    let first = sess.discover();
    push(&mut table, 0, sess.n(), None, &first);
    let mut last = first;
    let mut offset = chunk;
    let mut idx = 1usize;
    while offset < n {
        let hi = (offset + chunk).min(n);
        let rows = rows_of(offset, hi);
        let ast = sess.append(&rows)?;
        let out = sess.discover();
        push(&mut table, idx, sess.n(), Some(&ast), &out);
        last = out;
        offset = hi;
        idx += 1;
    }
    table.print();

    let st = sess.stats();
    println!(
        "\nservice  : {} requests, {} evals, {} invalidations across {} appends, \
         {} warm starts",
        st.requests,
        st.evaluations,
        st.invalidations,
        sess.chunks(),
        st.warm_start_hits,
    );
    if args.flag("check") {
        // O(n²) per factor state: a diagnostics pass, not the hot path
        println!(
            "exactness: max |ΛΛᵀ − K|∞ across factor states = {:.2e}",
            sess.backend().max_reconstruction_error()
        );
    }
    if let Some(truth) = truth {
        println!("F1       : {:.3}", skeleton_f1(&last.cpdag, &truth));
        println!("SHD      : {:.3}", normalized_shd(&last.cpdag, &truth));
    }
    println!("\nfinal CPDAG (X→Y directed, X—Y undirected):");
    let p = &last.cpdag;
    for i in 0..p.d {
        for j in 0..p.d {
            if p.directed(i, j) {
                println!("  {i} → {j}");
            } else if i < j && p.undirected(i, j) {
                println!("  {i} — {j}");
            }
        }
    }
    if let Some(path) = &trace_out {
        write_trace(path)?;
    }
    if let Some(path) = &metrics_out {
        write_metrics(path)?;
    }
    Ok(())
}

fn cmd_score(args: &Args) -> Result<()> {
    let trace_out = trace_out_arg(args);
    let metrics_out = metrics_out_arg(args);
    let (ds, _, desc) = load_workload(args)?;
    let target = args.usize_or("target", 0);
    let parents: Vec<usize> = args
        .get_or("parents", "")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.parse().context("bad --parents"))
        .collect::<Result<_>>()?;
    if target >= ds.d() || parents.iter().any(|&p| p >= ds.d()) {
        bail!("variable index out of range (d = {})", ds.d());
    }
    println!("workload : {desc}");
    let lowrank = lowrank_arg(args)?;
    let shards = shard_arg(args);
    let sw = Stopwatch::start();
    let score = CvLrScore::with_backend(
        ds.clone(),
        CvParams::default(),
        LowRankConfig::with_method(lowrank),
        NativeCvLrKernel,
    )
    .with_parallelism(args.usize_or("parallelism", 1));
    let s = if shards.is_empty() {
        score.local_score(target, &parents)
    } else {
        // a single request would normally stay under the remote floor;
        // an explicit --shards means "ship it", so lower the floor
        println!("shards   : {}", shards.join(", "));
        let cfg = PoolConfig { min_remote: 1, ..Default::default() };
        let backend: Arc<dyn ScoreBackend> = Arc::new(ScalarBackend(score));
        let sharded = ShardScoreBackend::new(
            backend,
            &ds,
            &shard_dataset_name(&args.get_or("data", "synth")),
            "cv-lr",
            "native",
            lowrank.name(),
            &shards,
            cfg,
        );
        sharded.score_batch(&[ScoreRequest::new(target, &parents)])[0]
    };
    println!("S_LR(X{target} | {parents:?}) = {s:.6}   [{}]", fmt_secs(sw.secs()));
    if let Some(path) = &trace_out {
        write_trace(path)?;
    }
    if let Some(path) = &metrics_out {
        write_metrics(path)?;
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let port = args.usize_or("port", 7878);
    if port > u16::MAX as usize {
        bail!("--port {port} out of range (max {})", u16::MAX);
    }
    let cfg = ServerConfig {
        port: port as u16,
        job_workers: args.usize_or("job-workers", 2),
        score_workers: args.usize_or("workers", 1),
        parallelism: args.usize_or("parallelism", 1),
        lowrank: lowrank_arg(args)?,
        cache_capacity: match args.usize_or("cache-cap", 1 << 20) {
            0 => None,
            c => Some(c),
        },
        builtin_n: args.usize_or("n", 500),
        seed: args.u64_or("seed", 0),
        artifacts_dir: args.get_or("artifacts", "artifacts"),
        shards: shard_arg(args),
        max_queued_jobs: args.usize_or("max-queued-jobs", 256),
        mem_high_water: match args.get("mem-high-water-mb") {
            Some(v) => {
                let mb: u64 = v.parse().context("bad --mem-high-water-mb")?;
                Some(mb * 1024 * 1024)
            }
            None => None,
        },
    };
    let coordinator = !cfg.shards.is_empty();
    if coordinator {
        println!("coordinating follower fleet: {}", cfg.shards.join(", "));
    }
    let server = Server::start(cfg)?;
    println!("cvlr discovery server listening on http://{}", server.addr());
    println!("  POST   /v1/datasets    register a CSV upload, built-in, or raw push");
    println!("  POST   /v1/datasets/<name>/rows   append rows (streaming ingest)");
    println!("  GET    /v1/datasets    list datasets");
    println!("  POST   /v1/jobs        submit a discovery job");
    println!("  GET    /v1/jobs/<id>   poll state / progress / result");
    println!("  DELETE /v1/jobs/<id>   cancel");
    println!("  POST   /v1/score_batch follower-side shard scoring");
    println!("  GET    /v1/stats       job + score-cache + shard statistics");
    println!("  GET    /v1/metrics     Prometheus text exposition (cvlr_* series)");
    println!("  GET    /v1/trace       Chrome trace-event JSON (Perfetto-loadable)");
    if cvlr::obs::fail::compiled_in() {
        println!("  POST   /v1/failpoints  chaos control (fail-inject build)");
    }
    println!("  POST   /v1/shutdown    graceful shutdown");
    // graceful shutdown is driven by the shutdown endpoint: the accept
    // loop drains connections, then the job manager cancels + joins
    server.wait();
    println!("server stopped");
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<()> {
    println!("cvlr selftest — all three layers");

    // 1. substrate: generator + native score + GES
    let (ds, dag) =
        generate(&SynthConfig { n: 200, density: 0.3, seed: 1, ..Default::default() });
    let ds = Arc::new(ds);
    let out = discover(ds.clone(), &DiscoveryConfig::default())?;
    let f1 = skeleton_f1(&out.cpdag, &dag);
    println!(
        "  [1/3] native CV-LR GES: F1 = {f1:.2} in {} — {}",
        fmt_secs(out.seconds),
        if f1 > 0.3 { "ok" } else { "WEAK" }
    );

    // 2. PJRT runtime: artifacts load + one engine run agreeing with native
    let artifacts = args.get_or("artifacts", "artifacts");
    let rt = Runtime::load(&artifacts)
        .with_context(|| format!("loading artifacts from {artifacts}/"))?;
    println!(
        "  [2/3] artifacts: cvlr buckets {:?}, exact sizes {:?}",
        rt.cvlr_buckets, rt.exact_sizes
    );
    let pjrt_out = discover(
        ds,
        &DiscoveryConfig {
            engine: EngineKind::Pjrt,
            artifacts_dir: artifacts.clone(),
            ..Default::default()
        },
    )?;
    let agree = pjrt_out.cpdag == out.cpdag;
    println!(
        "  [3/3] PJRT CV-LR GES: F1 = {:.2} in {} — {}",
        skeleton_f1(&pjrt_out.cpdag, &dag),
        fmt_secs(pjrt_out.seconds),
        if agree { "agrees with native" } else { "DISAGREES with native" }
    );
    if !agree {
        bail!("selftest failed: PJRT and native engines disagree");
    }
    println!("selftest passed");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    println!("cvlr {} — three-layer rust+JAX+Pallas stack", env!("CARGO_PKG_VERSION"));
    let artifacts = args.get_or("artifacts", "artifacts");
    match Runtime::load(&artifacts) {
        Ok(rt) => {
            println!("artifacts ({artifacts}/):");
            for b in &rt.cvlr_buckets {
                for m in &rt.m_buckets {
                    println!("  cvlr_cond_n{b}_m{m} / cvlr_marg_n{b}_m{m}   (factor bucket)");
                }
            }
            for n in &rt.exact_sizes {
                println!("  exact_cond_n{n} / exact_marg_n{n} (exact-CV fold)");
            }
        }
        Err(e) => println!("artifacts: unavailable ({e}) — run `make artifacts`"),
    }
    Ok(())
}
