//! Shared bench-harness support for the experiment drivers under
//! `rust/benches/` (criterion is unavailable offline; each bench is a
//! `harness = false` binary that prints the paper-shaped table and
//! writes a CSV under `results/`).
//!
//! Conventions:
//!
//! * every bench accepts `--full` for paper-scale parameters; the
//!   default is a smoke scale that finishes in minutes on one core;
//! * `--reps N` overrides the repetition count, `--seed S` the base
//!   seed, `--out DIR` the results directory;
//! * rows go to stdout as a fixed-width table *and* to
//!   `results/<bench>.csv` for plotting.

use crate::server::json::Json;
use crate::util::cli::Args;
use crate::util::csv::{CsvWriter, Table};

/// Common bench configuration parsed from argv.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Paper-scale parameters instead of the smoke scale.
    pub full: bool,
    /// Repetitions per cell (20 in the paper; smoke default varies).
    pub reps: usize,
    /// Base seed; rep r of cell c uses `seed + r` forked per cell.
    pub seed: u64,
    /// Output directory for CSV results.
    pub out_dir: String,
    /// Raw args for bench-specific options.
    pub args: Args,
}

impl BenchConfig {
    /// Parse from the process environment. `default_reps` applies to
    /// the smoke scale; `--full` switches to `full_reps`.
    pub fn from_env(default_reps: usize, full_reps: usize) -> BenchConfig {
        let args = Args::from_env();
        let full = args.flag("full");
        let reps = args.usize_or("reps", if full { full_reps } else { default_reps });
        BenchConfig {
            full,
            reps,
            seed: args.u64_or("seed", 7),
            out_dir: args.get_or("out", "results"),
            args,
        }
    }

    /// CSV writer for `<out_dir>/<name>.csv`.
    pub fn csv(&self, name: &str, header: &[&str]) -> CsvWriter {
        let path = format!("{}/{}.csv", self.out_dir, name);
        CsvWriter::create(&path, header)
            .unwrap_or_else(|e| panic!("cannot create {path}: {e}"))
    }
}

/// Accumulates rows for stdout rendering, CSV output, and the
/// machine-readable `BENCH_<name>.json` trajectory artifact (what CI
/// uploads per run, so bench results accumulate over the repo's
/// history instead of evaporating with the job log).
pub struct Report {
    table: Table,
    csv: CsvWriter,
    name: String,
    out_dir: String,
}

impl Report {
    pub fn new(cfg: &BenchConfig, name: &str, header: &[&str]) -> Report {
        Report {
            table: Table::new(header),
            csv: cfg.csv(name, header),
            name: name.to_string(),
            out_dir: cfg.out_dir.clone(),
        }
    }

    pub fn row(&mut self, fields: &[String]) {
        self.table.row(fields);
        self.csv.row(fields).expect("csv write");
    }

    /// Render the table to stdout and write the JSON twin.
    pub fn finish(self, title: &str) {
        println!("\n== {title} ==");
        println!("{}", self.table.render());
        let path = format!("{}/BENCH_{}.json", self.out_dir, self.name);
        if let Err(e) = std::fs::write(&path, self.to_json()) {
            eprintln!("warn: could not write {path}: {e}");
        } else {
            println!("trajectory: {path}");
        }
    }

    /// `{"bench": ..., "header": [...], "rows": [[...], ...]}`,
    /// serialized through the server's strict JSON codec (one encoder
    /// in the crate, property-tested in `tests/prop_json.rs`) straight
    /// from the table's own storage.
    fn to_json(&self) -> String {
        let strs = |cells: &[String]| {
            Json::Arr(cells.iter().map(|c| Json::str(c.clone())).collect())
        };
        let rows: Vec<Json> = self.table.data_rows().iter().map(|r| strs(r)).collect();
        let mut out = Json::obj(vec![
            ("bench", Json::str(self.name.clone())),
            ("header", strs(self.table.header())),
            ("rows", Json::Arr(rows)),
        ])
        .encode();
        out.push('\n');
        out
    }
}

/// Mean and sample standard deviation of a slice.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let n = xs.len().max(1) as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = if xs.len() > 1 {
        xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0)
    } else {
        0.0
    };
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basics() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
        let (m1, s1) = mean_std(&[5.0]);
        assert_eq!((m1, s1), (5.0, 0.0));
    }

    #[test]
    fn report_writes_csv() {
        let dir = std::env::temp_dir().join("cvlr_bench_test");
        let cfg = BenchConfig {
            full: false,
            reps: 1,
            seed: 0,
            out_dir: dir.to_string_lossy().to_string(),
            args: Args::default(),
        };
        let mut rep = Report::new(&cfg, "unit", &["a", "b"]);
        rep.row(&["1".into(), "2".into()]);
        rep.finish("unit");
        let body = std::fs::read_to_string(dir.join("unit.csv")).unwrap();
        assert_eq!(body.trim(), "a,b\n1,2");
        let json = std::fs::read_to_string(dir.join("BENCH_unit.json")).unwrap();
        assert_eq!(
            json.trim(),
            r#"{"bench":"unit","header":["a","b"],"rows":[["1","2"]]}"#
        );
    }
}
