//! Partially directed acyclic graphs (PDAGs) and completed PDAGs
//! (CPDAGs, the Markov-equivalence-class representation GES searches
//! over), with:
//!
//! * `dag_to_cpdag` — Chickering (1995) edge-labeling (compelled vs
//!   reversible edges);
//! * `pdag_to_dag` — Dor & Tarsi (1992) consistent extension;
//! * `meek_closure` — Meek (1995) orientation rules R1-R4.

use super::dag::Dag;

/// PDAG as a boolean "mark" matrix: `i → j` iff mark(i,j) ∧ ¬mark(j,i);
/// `i − j` (undirected) iff mark(i,j) ∧ mark(j,i).
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct Pdag {
    pub d: usize,
    mark: Vec<bool>,
}

impl Pdag {
    pub fn new(d: usize) -> Pdag {
        Pdag { d, mark: vec![false; d * d] }
    }

    #[inline]
    fn m(&self, i: usize, j: usize) -> bool {
        self.mark[i * self.d + j]
    }

    #[inline]
    fn set(&mut self, i: usize, j: usize, v: bool) {
        self.mark[i * self.d + j] = v;
    }

    /// Any edge between i and j (directed either way or undirected)?
    pub fn adjacent(&self, i: usize, j: usize) -> bool {
        self.m(i, j) || self.m(j, i)
    }

    /// Directed i → j?
    pub fn directed(&self, i: usize, j: usize) -> bool {
        self.m(i, j) && !self.m(j, i)
    }

    /// Undirected i − j?
    pub fn undirected(&self, i: usize, j: usize) -> bool {
        self.m(i, j) && self.m(j, i)
    }

    pub fn add_undirected(&mut self, i: usize, j: usize) {
        self.set(i, j, true);
        self.set(j, i, true);
    }

    pub fn add_directed(&mut self, i: usize, j: usize) {
        self.set(i, j, true);
        self.set(j, i, false);
    }

    /// Turn whatever edge exists between i,j into i → j.
    ///
    /// Debug invariant: the edge must exist and must not already be
    /// compelled the other way — orienting over j → i would silently
    /// flip a compelled edge and corrupt the equivalence class. Callers
    /// that may race a prior orientation (conflicting v-structures in
    /// PC/MMMB) guard with [`Pdag::undirected`] first.
    pub fn orient(&mut self, i: usize, j: usize) {
        debug_assert!(self.adjacent(i, j), "orient({i},{j}): no edge to orient");
        debug_assert!(
            !self.directed(j, i),
            "orient({i},{j}) would flip the compelled edge {j}\u{2192}{i}"
        );
        self.add_directed(i, j);
    }

    pub fn remove_edge(&mut self, i: usize, j: usize) {
        self.set(i, j, false);
        self.set(j, i, false);
    }

    /// Directed parents {i : i → j}.
    pub fn parents(&self, j: usize) -> Vec<usize> {
        (0..self.d).filter(|&i| self.directed(i, j)).collect()
    }

    /// Neighbors connected by an *undirected* edge.
    pub fn neighbors(&self, j: usize) -> Vec<usize> {
        (0..self.d).filter(|&i| self.undirected(i, j)).collect()
    }

    /// All adjacent nodes.
    pub fn adjacencies(&self, j: usize) -> Vec<usize> {
        (0..self.d).filter(|&i| i != j && self.adjacent(i, j)).collect()
    }

    /// NA_{Y,X}: neighbors of y that are adjacent to x (Chickering 2002).
    pub fn na(&self, y: usize, x: usize) -> Vec<usize> {
        self.neighbors(y).into_iter().filter(|&n| self.adjacent(n, x)).collect()
    }

    /// Is `set` a clique (every pair adjacent)?
    pub fn is_clique(&self, set: &[usize]) -> bool {
        for (a, &i) in set.iter().enumerate() {
            for &j in set.iter().skip(a + 1) {
                if !self.adjacent(i, j) {
                    return false;
                }
            }
        }
        true
    }

    /// Does every semi-directed (possibly-directed) path from `from` to
    /// `to` pass through `blocked`? Used by the Insert validity test.
    /// A semi-directed path follows undirected edges or edges directed
    /// along the walk direction.
    pub fn all_semi_directed_paths_blocked(&self, from: usize, to: usize, blocked: &[usize]) -> bool {
        // BFS over nodes not in `blocked`; reachable `to` ⇒ some path avoids it
        let mut seen = vec![false; self.d];
        let mut stack = vec![from];
        seen[from] = true;
        if blocked.contains(&from) {
            return true;
        }
        while let Some(v) = stack.pop() {
            if v == to {
                return false;
            }
            for w in 0..self.d {
                if seen[w] || blocked.contains(&w) {
                    continue;
                }
                // step v→w allowed if v→w directed or v−w undirected
                if self.directed(v, w) || self.undirected(v, w) {
                    seen[w] = true;
                    stack.push(w);
                }
            }
        }
        true
    }

    pub fn num_edges(&self) -> usize {
        let mut n = 0;
        for i in 0..self.d {
            for j in (i + 1)..self.d {
                if self.adjacent(i, j) {
                    n += 1;
                }
            }
        }
        n
    }

    /// Skeleton as unordered pairs.
    pub fn skeleton(&self) -> Vec<(usize, usize)> {
        let mut out = vec![];
        for i in 0..self.d {
            for j in (i + 1)..self.d {
                if self.adjacent(i, j) {
                    out.push((i, j));
                }
            }
        }
        out
    }

    /// Is the directed sub-graph acyclic? Kahn's algorithm over the
    /// directed edges only; undirected edges are ignored. Every PDAG
    /// the search layers build must keep this true — a directed cycle
    /// means no consistent DAG extension exists.
    pub fn directed_part_acyclic(&self) -> bool {
        let mut indeg = vec![0usize; self.d];
        for i in 0..self.d {
            for j in 0..self.d {
                if self.directed(i, j) {
                    indeg[j] += 1;
                }
            }
        }
        let mut stack: Vec<usize> = (0..self.d).filter(|&v| indeg[v] == 0).collect();
        let mut seen = 0;
        while let Some(v) = stack.pop() {
            seen += 1;
            for w in 0..self.d {
                if self.directed(v, w) {
                    indeg[w] -= 1;
                    if indeg[w] == 0 {
                        stack.push(w);
                    }
                }
            }
        }
        seen == self.d
    }

    /// Apply Meek rules R1-R4 to closure (orients undirected edges that
    /// are compelled by the current orientations).
    pub fn meek_closure(&mut self) {
        while self.meek_sweep() {}
        self.debug_check_closure();
    }

    /// Debug hooks run after every [`Pdag::meek_closure`]: the closure
    /// must be idempotent (one extra sweep orients nothing — guards
    /// early-exit refactors of the fixpoint loop) and must not have
    /// introduced a directed cycle. Compiled out of release builds.
    #[cfg(debug_assertions)]
    fn debug_check_closure(&self) {
        debug_assert!(
            self.directed_part_acyclic(),
            "meek_closure left a directed cycle in the PDAG"
        );
        let mut again = self.clone();
        debug_assert!(
            !again.meek_sweep(),
            "meek_closure is not idempotent: an extra sweep still orients edges"
        );
    }

    #[cfg(not(debug_assertions))]
    #[inline(always)]
    fn debug_check_closure(&self) {}

    /// One full pass of Meek rules R1-R4; returns whether any edge was
    /// oriented (the fixpoint loop in [`Pdag::meek_closure`] repeats
    /// until a pass comes back clean).
    fn meek_sweep(&mut self) -> bool {
        let mut changed = false;
        for a in 0..self.d {
            for b in 0..self.d {
                if a == b || !self.undirected(a, b) {
                    continue;
                }
                // R1: ∃c: c→a, c,b nonadjacent ⇒ a→b
                let r1 = (0..self.d)
                    .any(|c| c != b && self.directed(c, a) && !self.adjacent(c, b));
                // R2: ∃c: a→c→b ⇒ a→b
                let r2 = (0..self.d).any(|c| self.directed(a, c) && self.directed(c, b));
                // R3: ∃c,d: a−c, a−d, c→b, d→b, c,d nonadjacent ⇒ a→b
                let r3 = {
                    let mut hit = false;
                    for c in 0..self.d {
                        if !(self.undirected(a, c) && self.directed(c, b)) {
                            continue;
                        }
                        for dd in 0..self.d {
                            if dd != c
                                && self.undirected(a, dd)
                                && self.directed(dd, b)
                                && !self.adjacent(c, dd)
                            {
                                hit = true;
                                break;
                            }
                        }
                        if hit {
                            break;
                        }
                    }
                    hit
                };
                // R4: ∃c,d: a−d (or a adjacent d), d→c, c→b, a−c,
                //     b,d nonadjacent ⇒ a→b
                let r4 = {
                    let mut hit = false;
                    for c in 0..self.d {
                        if !(self.undirected(a, c) || self.adjacent(a, c)) || !self.directed(c, b) {
                            continue;
                        }
                        for dd in 0..self.d {
                            if dd != c
                                && self.adjacent(a, dd)
                                && self.directed(dd, c)
                                && !self.adjacent(dd, b)
                            {
                                hit = true;
                                break;
                            }
                        }
                        if hit {
                            break;
                        }
                    }
                    hit
                };
                if r1 || r2 || r3 || r4 {
                    self.orient(a, b);
                    changed = true;
                }
            }
        }
        changed
    }

    /// Dor & Tarsi (1992): a DAG that is a consistent extension of this
    /// PDAG, or `None` if none exists.
    pub fn to_dag(&self) -> Option<Dag> {
        let mut work = self.clone();
        let mut out = Dag::new(self.d);
        // copy already-directed edges
        for i in 0..self.d {
            for j in 0..self.d {
                if self.directed(i, j) {
                    out.add_edge(i, j);
                }
            }
        }
        let mut alive: Vec<bool> = vec![true; self.d];
        let mut remaining = self.d;
        while remaining > 0 {
            let mut found = None;
            'cand: for x in 0..self.d {
                if !alive[x] {
                    continue;
                }
                // (a) no outgoing directed edge from x (to alive nodes)
                for y in 0..self.d {
                    if alive[y] && work.directed(x, y) {
                        continue 'cand;
                    }
                }
                // (b) every undirected neighbor of x is adjacent to all
                // other nodes adjacent to x
                let nbrs: Vec<usize> =
                    (0..self.d).filter(|&y| alive[y] && work.undirected(x, y)).collect();
                let adjs: Vec<usize> =
                    (0..self.d).filter(|&y| alive[y] && y != x && work.adjacent(x, y)).collect();
                for &nb in &nbrs {
                    for &ad in &adjs {
                        if ad != nb && !work.adjacent(nb, ad) {
                            continue 'cand;
                        }
                    }
                }
                found = Some((x, nbrs));
                break;
            }
            let (x, nbrs) = found?;
            // orient undirected edges into x
            for nb in nbrs {
                out.add_edge(nb, x);
            }
            // remove x
            for y in 0..self.d {
                work.remove_edge(x, y);
            }
            alive[x] = false;
            remaining -= 1;
        }
        debug_assert!(out.topological_order().is_some());
        Some(out)
    }
}

/// Chickering (1995): label each DAG edge compelled/reversible; the
/// compelled edges directed + reversible edges undirected = the CPDAG of
/// the DAG's Markov equivalence class.
pub fn dag_to_cpdag(g: &Dag) -> Pdag {
    let d = g.d;
    let topo = g.topological_order().expect("input must be a DAG");
    let pos: Vec<usize> = {
        let mut p = vec![0; d];
        for (i, &v) in topo.iter().enumerate() {
            p[v] = i;
        }
        p
    };
    // total order on edges: by topo position of y ascending, then topo
    // position of x DESCENDING (Chickering's "order-edges")
    let mut edges: Vec<(usize, usize)> = g.edges();
    edges.sort_by_key(|&(x, y)| (pos[y], std::cmp::Reverse(pos[x])));

    #[derive(Clone, Copy, PartialEq)]
    enum Label {
        Unknown,
        Compelled,
        Reversible,
    }
    use Label::*;
    let mut label: std::collections::HashMap<(usize, usize), Label> =
        edges.iter().map(|&e| (e, Unknown)).collect();

    for &(x, y) in &edges {
        if label[&(x, y)] != Unknown {
            continue;
        }
        let mut done = false;
        // for every w → x labeled compelled
        let wx: Vec<usize> = g
            .parents(x)
            .into_iter()
            .filter(|&w| label.get(&(w, x)) == Some(&Compelled))
            .collect();
        for w in wx {
            if !g.has_edge(w, y) {
                // label y's every incoming edge compelled
                for p in g.parents(y) {
                    label.insert((p, y), Compelled);
                }
                done = true;
                break;
            } else {
                label.insert((w, y), Compelled);
            }
        }
        if done {
            continue;
        }
        // if ∃ z → y with z ≠ x and z not a parent of x ⇒ compelled
        let exists_z = g.parents(y).iter().any(|&z| z != x && !g.has_edge(z, x));
        let new_label = if exists_z { Compelled } else { Reversible };
        label.insert((x, y), new_label);
        for p in g.parents(y) {
            if label[&(p, y)] == Unknown {
                label.insert((p, y), new_label);
            }
        }
    }

    let mut out = Pdag::new(d);
    for (&(x, y), &l) in &label {
        match l {
            Compelled => out.add_directed(x, y),
            Reversible | Unknown => out.add_undirected(x, y),
        }
    }
    out
}

// Bounded proof for the CI `verify-core` job (continue-on-error): over
// every 3-node PDAG the solver can construct, the Meek closure
// terminates within the unwind bound, never flips a directed edge, and
// keeps the directed part acyclic when it started acyclic.
#[cfg(kani)]
mod verification {
    use super::*;

    #[kani::proof]
    #[kani::unwind(16)]
    fn meek_closure_small_pdag_preserves_orientations() {
        let mut p = Pdag::new(3);
        for i in 0..3usize {
            for j in 0..3usize {
                if i < j && kani::any() {
                    if kani::any() {
                        p.add_undirected(i, j);
                    } else if kani::any() {
                        p.add_directed(i, j);
                    } else {
                        p.add_directed(j, i);
                    }
                }
            }
        }
        kani::assume(p.directed_part_acyclic());
        let before = p.clone();
        p.meek_closure();
        for i in 0..3 {
            for j in 0..3 {
                if before.directed(i, j) {
                    assert!(p.directed(i, j), "meek_closure flipped a directed edge");
                }
            }
        }
        assert!(p.directed_part_acyclic(), "meek_closure introduced a directed cycle");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_cpdag_is_fully_undirected() {
        // X→Y→Z has equivalence class X−Y−Z.
        let g = Dag::from_edges(3, &[(0, 1), (1, 2)]);
        let c = dag_to_cpdag(&g);
        assert!(c.undirected(0, 1));
        assert!(c.undirected(1, 2));
        assert!(!c.adjacent(0, 2));
    }

    #[test]
    fn collider_cpdag_keeps_v_structure() {
        // X→Z←Y: the v-structure is compelled.
        let g = Dag::from_edges(3, &[(0, 2), (1, 2)]);
        let c = dag_to_cpdag(&g);
        assert!(c.directed(0, 2));
        assert!(c.directed(1, 2));
        assert!(!c.adjacent(0, 1));
    }

    #[test]
    fn collider_with_tail_compels_downstream() {
        // X→Z←Y plus Z→W: Z→W is compelled (else new v-structure).
        let g = Dag::from_edges(4, &[(0, 2), (1, 2), (2, 3)]);
        let c = dag_to_cpdag(&g);
        assert!(c.directed(2, 3));
    }

    #[test]
    fn pdag_to_dag_roundtrip_equivalence_class() {
        // cpdag(dag(cpdag(G))) == cpdag(G) for several graphs
        let graphs = [
            Dag::from_edges(4, &[(0, 1), (1, 2), (2, 3)]),
            Dag::from_edges(4, &[(0, 2), (1, 2), (2, 3)]),
            Dag::from_edges(5, &[(0, 1), (0, 2), (1, 3), (2, 3), (3, 4)]),
        ];
        for g in &graphs {
            let c = dag_to_cpdag(g);
            let g2 = c.to_dag().expect("CPDAG must have a consistent extension");
            let c2 = dag_to_cpdag(&g2);
            assert_eq!(c, c2, "equivalence class must round-trip");
        }
    }

    #[test]
    fn meek_r1_orients_chain() {
        // a→b, b−c, a,c nonadjacent ⇒ b→c
        let mut p = Pdag::new(3);
        p.add_directed(0, 1);
        p.add_undirected(1, 2);
        p.meek_closure();
        assert!(p.directed(1, 2));
    }

    #[test]
    fn meek_r2_orients_shortcut() {
        // a→c→b and a−b ⇒ a→b
        let mut p = Pdag::new(3);
        p.add_directed(0, 2);
        p.add_directed(2, 1);
        p.add_undirected(0, 1);
        p.meek_closure();
        assert!(p.directed(0, 1));
    }

    #[test]
    fn semi_directed_path_blocking() {
        let mut p = Pdag::new(4);
        p.add_directed(0, 1);
        p.add_undirected(1, 2);
        p.add_directed(2, 3);
        // path 0⇒3 exists through 1,2
        assert!(!p.all_semi_directed_paths_blocked(0, 3, &[]));
        assert!(p.all_semi_directed_paths_blocked(0, 3, &[1]));
        assert!(p.all_semi_directed_paths_blocked(0, 3, &[2]));
        // reversed: no semi-directed path 3⇒0 (edges point wrong way)
        assert!(p.all_semi_directed_paths_blocked(3, 0, &[]));
    }

    #[test]
    fn directed_part_acyclic_ignores_undirected_edges() {
        let mut p = Pdag::new(3);
        p.add_directed(0, 1);
        p.add_directed(1, 2);
        p.add_undirected(0, 2); // undirected edges never form a "cycle"
        assert!(p.directed_part_acyclic());
        let mut c = Pdag::new(3);
        c.add_directed(0, 1);
        c.add_directed(1, 2);
        c.add_directed(2, 0);
        assert!(!c.directed_part_acyclic());
        assert!(Pdag::new(0).directed_part_acyclic(), "empty graph is vacuously acyclic");
    }

    #[test]
    fn na_and_clique() {
        let mut p = Pdag::new(4);
        p.add_undirected(0, 1);
        p.add_undirected(1, 2);
        p.add_undirected(0, 2);
        assert!(p.is_clique(&[0, 1, 2]));
        p.remove_edge(0, 2);
        assert!(!p.is_clique(&[0, 1, 2]));
        // NA_{1,3}: neighbors of 1 adjacent to 3 — none (3 isolated)
        assert!(p.na(1, 3).is_empty());
    }
}
