//! Directed acyclic graphs over d variables (d ≤ a few dozen — dense
//! adjacency-matrix representation).

/// DAG as a dense adjacency matrix: `adj[i][j]` ⇔ edge i → j.
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct Dag {
    pub d: usize,
    adj: Vec<bool>,
}

impl Dag {
    pub fn new(d: usize) -> Dag {
        Dag { d, adj: vec![false; d * d] }
    }

    /// Build from an edge list; panics if a cycle results.
    pub fn from_edges(d: usize, edges: &[(usize, usize)]) -> Dag {
        let mut g = Dag::new(d);
        for &(i, j) in edges {
            g.add_edge(i, j);
        }
        assert!(g.topological_order().is_some(), "edge list contains a cycle");
        g
    }

    #[inline]
    pub fn has_edge(&self, i: usize, j: usize) -> bool {
        self.adj[i * self.d + j]
    }

    pub fn add_edge(&mut self, i: usize, j: usize) {
        assert_ne!(i, j);
        self.adj[i * self.d + j] = true;
    }

    pub fn remove_edge(&mut self, i: usize, j: usize) {
        self.adj[i * self.d + j] = false;
    }

    pub fn parents(&self, j: usize) -> Vec<usize> {
        (0..self.d).filter(|&i| self.has_edge(i, j)).collect()
    }

    pub fn children(&self, i: usize) -> Vec<usize> {
        (0..self.d).filter(|&j| self.has_edge(i, j)).collect()
    }

    pub fn num_edges(&self) -> usize {
        self.adj.iter().filter(|&&b| b).count()
    }

    pub fn edges(&self) -> Vec<(usize, usize)> {
        let mut out = vec![];
        for i in 0..self.d {
            for j in 0..self.d {
                if self.has_edge(i, j) {
                    out.push((i, j));
                }
            }
        }
        out
    }

    /// Parent list per node — the shape the decomposable scores take.
    pub fn parent_list(&self) -> Vec<Vec<usize>> {
        (0..self.d).map(|j| self.parents(j)).collect()
    }

    /// Kahn's algorithm; `None` if cyclic.
    pub fn topological_order(&self) -> Option<Vec<usize>> {
        let mut indeg: Vec<usize> = (0..self.d).map(|j| self.parents(j).len()).collect();
        let mut queue: Vec<usize> = (0..self.d).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(self.d);
        while let Some(v) = queue.pop() {
            order.push(v);
            for c in self.children(v) {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    queue.push(c);
                }
            }
        }
        if order.len() == self.d {
            Some(order)
        } else {
            None
        }
    }

    /// Would adding i→j create a cycle?
    pub fn creates_cycle(&self, i: usize, j: usize) -> bool {
        // cycle iff j reaches i already
        let mut stack = vec![j];
        let mut seen = vec![false; self.d];
        while let Some(v) = stack.pop() {
            if v == i {
                return true;
            }
            if seen[v] {
                continue;
            }
            seen[v] = true;
            stack.extend(self.children(v));
        }
        false
    }

    /// Skeleton: set of unordered adjacent pairs.
    pub fn skeleton(&self) -> Vec<(usize, usize)> {
        let mut out = vec![];
        for i in 0..self.d {
            for j in (i + 1)..self.d {
                if self.has_edge(i, j) || self.has_edge(j, i) {
                    out.push((i, j));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_topology() {
        let g = Dag::from_edges(3, &[(0, 1), (1, 2)]);
        assert_eq!(g.parents(2), vec![1]);
        assert_eq!(g.children(0), vec![1]);
        let topo = g.topological_order().unwrap();
        let pos = |v: usize| topo.iter().position(|&x| x == v).unwrap();
        assert!(pos(0) < pos(1) && pos(1) < pos(2));
    }

    #[test]
    fn cycle_detection() {
        let mut g = Dag::from_edges(3, &[(0, 1), (1, 2)]);
        assert!(g.creates_cycle(2, 0));
        assert!(!g.creates_cycle(0, 2));
        g.add_edge(2, 0);
        assert!(g.topological_order().is_none());
    }

    #[test]
    #[should_panic(expected = "cycle")]
    fn from_edges_rejects_cycle() {
        Dag::from_edges(2, &[(0, 1), (1, 0)]);
    }

    #[test]
    fn skeleton_pairs() {
        let g = Dag::from_edges(4, &[(0, 1), (2, 1)]);
        assert_eq!(g.skeleton(), vec![(0, 1), (1, 2)]);
        assert_eq!(g.num_edges(), 2);
    }
}
