//! Accuracy metrics of §7.1: skeleton F1 and normalized structural
//! Hamming distance (SHD) between Markov equivalence classes.

use super::dag::Dag;
use super::pdag::{dag_to_cpdag, Pdag};

/// F1 of the recovered skeleton vs the true skeleton (adjacency as
/// unordered pairs).
pub fn skeleton_f1(estimated: &Pdag, truth: &Dag) -> f64 {
    let d = truth.d;
    assert_eq!(estimated.d, d);
    let mut tp = 0.0;
    let mut fp = 0.0;
    let mut fnn = 0.0;
    for i in 0..d {
        for j in (i + 1)..d {
            let est = estimated.adjacent(i, j);
            let tru = truth.has_edge(i, j) || truth.has_edge(j, i);
            match (est, tru) {
                (true, true) => tp += 1.0,
                (true, false) => fp += 1.0,
                (false, true) => fnn += 1.0,
                _ => {}
            }
        }
    }
    if tp == 0.0 {
        return 0.0;
    }
    let precision = tp / (tp + fp);
    let recall = tp / (tp + fnn);
    2.0 * precision * recall / (precision + recall)
}

/// Edge type of a pair in a PDAG, for SHD comparison.
#[derive(PartialEq)]
enum PairType {
    None,
    Undirected,
    Forward,
    Backward,
}

fn pair_type(p: &Pdag, i: usize, j: usize) -> PairType {
    if p.undirected(i, j) {
        PairType::Undirected
    } else if p.directed(i, j) {
        PairType::Forward
    } else if p.directed(j, i) {
        PairType::Backward
    } else {
        PairType::None
    }
}

/// Normalized SHD between the estimated equivalence class and the true
/// one (the true DAG is converted to its CPDAG): the number of variable
/// pairs whose edge type differs, divided by d(d−1)/2. Lower is better.
pub fn normalized_shd(estimated: &Pdag, truth: &Dag) -> f64 {
    let d = truth.d;
    assert_eq!(estimated.d, d);
    let true_cpdag = dag_to_cpdag(truth);
    let mut mismatches = 0usize;
    for i in 0..d {
        for j in (i + 1)..d {
            if pair_type(estimated, i, j) != pair_type(&true_cpdag, i, j) {
                mismatches += 1;
            }
        }
    }
    mismatches as f64 / (d * (d - 1) / 2) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_recovery() {
        let g = Dag::from_edges(4, &[(0, 2), (1, 2), (2, 3)]);
        let est = dag_to_cpdag(&g);
        assert_eq!(skeleton_f1(&est, &g), 1.0);
        assert_eq!(normalized_shd(&est, &g), 0.0);
    }

    #[test]
    fn equivalent_dag_scores_perfectly() {
        // X→Y→Z vs X←Y→Z are in the same class: SHD between their
        // CPDAGs is 0.
        let g1 = Dag::from_edges(3, &[(0, 1), (1, 2)]);
        let g2 = Dag::from_edges(3, &[(1, 0), (1, 2)]);
        let est = dag_to_cpdag(&g2);
        assert_eq!(normalized_shd(&est, &g1), 0.0);
        assert_eq!(skeleton_f1(&est, &g1), 1.0);
    }

    #[test]
    fn empty_estimate_zero_f1() {
        let g = Dag::from_edges(3, &[(0, 1)]);
        let est = Pdag::new(3);
        assert_eq!(skeleton_f1(&est, &g), 0.0);
        // one pair differs out of 3
        assert!((normalized_shd(&est, &g) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn wrong_orientation_counts() {
        // truth: collider 0→2←1 (compelled). estimate: 0→2, 2→1.
        let g = Dag::from_edges(3, &[(0, 2), (1, 2)]);
        let mut est = Pdag::new(3);
        est.add_directed(0, 2);
        est.add_directed(2, 1);
        // pair (1,2) differs in orientation; pair (0,2) matches; (0,1) matches (none)
        assert!((normalized_shd(&est, &g) - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(skeleton_f1(&est, &g), 1.0);
    }
}
