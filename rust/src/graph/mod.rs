//! Graph machinery: DAGs, PDAGs/CPDAGs, the conversions between them,
//! Meek orientation rules, and the accuracy metrics of §7.1.

pub mod dag;
pub mod pdag;
pub mod metrics;

pub use dag::Dag;
pub use metrics::{normalized_shd, skeleton_f1};
pub use pdag::Pdag;
