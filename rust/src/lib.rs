//! # cvlr — Fast Causal Discovery by Approximate Kernel-based Generalized
//! Score Functions (KDD 2025 reproduction)
//!
//! Three-layer architecture (see `DESIGN.md`):
//! * **L3 (this crate)** — the coordinator: GES search, score service with
//!   caching/batching, all baselines, data generators, metrics, PJRT
//!   runtime for the AOT-compiled score artifacts.
//! * **L2 (python/compile/model.py)** — the CV-LR / exact-CV score as JAX
//!   computation graphs, lowered once to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the Gram-product
//!   and RBF-kernel hot spots.
//!
//! Python never runs on the request path: `make artifacts` produces
//! `artifacts/*.hlo.txt`, and the rust binary is self-contained after that.

pub mod util;
pub mod linalg;
pub mod kernel;
pub mod lowrank;
pub mod score;
pub mod graph;
pub mod search;
pub mod ci;
pub mod contopt;
pub mod data;
pub mod runtime;
pub mod coordinator;
pub mod bench;
