//! # cvlr — Fast Causal Discovery by Approximate Kernel-based Generalized
//! Score Functions (KDD 2025 reproduction)
//!
//! ## The batch-first scoring API
//!
//! Every score consumer in this crate speaks
//! [`score::ScoreBackend::score_batch`]: the search gathers all valid
//! candidate (target, parent-set) pairs of a GES sweep and submits them
//! as **one wide batch** of [`score::ScoreRequest`]s, so the backend can
//! amortize factor construction, fold splitting and device dispatch
//! across hundreds of candidates — the interface the paper's O(n m²)
//! local score needs to pay off end to end.
//!
//! * [`score::ScoreBackend`] — the primary trait; batch in, scores out,
//!   request order preserved, bit-identical to scalar evaluation.
//! * [`score::LocalScore`] — the scalar trait a score implementation
//!   provides; [`score::ScalarBackend`] adapts any of them to the batch
//!   interface. The CV-LR score implements `ScoreBackend` natively and
//!   shares per-batch work across candidates.
//! * [`coordinator::ScoreService`] — the memoizing façade: the single
//!   `ScoreCache`, intra-batch dedup, in-flight dedup across threads,
//!   and a worker pool fanning sub-batches to the backend.
//! * [`coordinator::Discovery`] — the builder session API:
//!
//! ```no_run
//! # use std::sync::Arc;
//! # use cvlr::coordinator::{Discovery, DiscoveryOutcome, EngineKind};
//! # fn run(ds: Arc<cvlr::data::Dataset>) -> anyhow::Result<DiscoveryOutcome> {
//! let out = Discovery::builder(ds)
//!     .method("cv-lr")
//!     .engine(EngineKind::Native)
//!     .workers(8)
//!     .run()?;
//! # Ok(out)
//! # }
//! ```
//!
//! New methods plug in through the coordinator's registry
//! ([`coordinator::register_score_method`]) without touching the engine.
//!
//! ## The discovery server
//!
//! The [`server`] module turns the library into a long-running serving
//! system (`cvlr serve --port 7878`): an HTTP/JSON API (std-only,
//! hand-rolled wire layer) over an async job queue. Datasets are
//! registered once — built-ins or CSV uploads with continuous/discrete
//! type inference ([`server::registry`]) — and jobs move through
//! `queued → running → done | failed | cancelled` with mid-sweep
//! cancellation ([`server::jobs`]). One [`coordinator::ScoreService`]
//! is pooled per (dataset, method, engine), so the score cache
//! persists **across** jobs; long-run memory is bounded by the
//! second-chance eviction cache
//! ([`coordinator::ScoreCache::with_capacity`], surfaced as
//! `Discovery::builder(ds).cache_capacity(..)` and reported through
//! [`coordinator::ServiceStats::evictions`]).
//!
//! ```text
//! curl -X POST localhost:7878/v1/jobs -d '{"dataset":"synth","method":"cv-lr"}'
//! curl localhost:7878/v1/jobs/1
//! ```
//!
//! See `server`'s module docs for the endpoint table and
//! `examples/serve_client.rs` for an end-to-end client.
//!
//! ## Streaming discovery
//!
//! The [`stream`] module opens the online workload: datasets append
//! ([`data::Dataset::append_rows`]), low-rank factors extend
//! incrementally in O(m²) per row instead of refactorizing
//! ([`stream::FactorState`]), appends invalidate the memoized scores
//! they stale, and re-discovery warm-starts from the previous CPDAG
//! ([`stream::StreamingDiscovery`], `cvlr stream`, and the server's
//! `POST /v1/datasets/{name}/rows` + `warm_start` job option).
//!
//! ## Three-layer architecture (see `DESIGN.md`)
//!
//! * **L3 (this crate)** — the coordinator: batched GES search, score
//!   service with caching/batching, all baselines, data generators,
//!   metrics, PJRT runtime for the AOT-compiled score artifacts.
//! * **L2 (python/compile/model.py)** — the CV-LR / exact-CV score as JAX
//!   computation graphs, lowered once to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the Gram-product
//!   and RBF-kernel hot spots.
//!
//! Python never runs on the request path: `make artifacts` produces
//! `artifacts/*.hlo.txt`, and the rust binary is self-contained after that.

// Every `unsafe` operation must sit in its own `unsafe {}` block with a
// `// SAFETY:` comment, even inside `unsafe fn` — enforced here and
// cross-checked by `cvlr lint` (`ci::lint`).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod util;
pub mod obs;
pub mod linalg;
pub mod kernel;
pub mod lowrank;
pub mod score;
pub mod graph;
pub mod search;
pub mod stream;
pub mod ci;
pub mod contopt;
pub mod data;
pub mod runtime;
pub mod coordinator;
pub mod server;
pub mod distrib;
pub mod bench;
