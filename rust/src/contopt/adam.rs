//! Minimal Adam optimizer over flat parameter vectors — the inner
//! optimizer for the continuous-optimization baselines.

pub struct Adam {
    pub lr: f64,
    beta1: f64,
    beta2: f64,
    eps: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: u64,
}

impl Adam {
    pub fn new(dim: usize, lr: f64) -> Adam {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, m: vec![0.0; dim], v: vec![0.0; dim], t: 0 }
    }

    /// One update step: params -= lr * m̂/(√v̂ + ε).
    pub fn step(&mut self, params: &mut [f64], grads: &[f64]) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1;
        let b1c = 1.0 - self.beta1.powi(self.t as i32);
        let b2c = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grads[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * grads[i] * grads[i];
            let mh = self.m[i] / b1c;
            let vh = self.v[i] / b2c;
            params[i] -= self.lr * mh / (vh.sqrt() + self.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        // f(x) = (x-3)², gradient 2(x-3)
        let mut x = vec![0.0];
        let mut opt = Adam::new(1, 0.1);
        for _ in 0..500 {
            let g = vec![2.0 * (x[0] - 3.0)];
            opt.step(&mut x, &g);
        }
        assert!((x[0] - 3.0).abs() < 1e-3, "x={}", x[0]);
    }
}
