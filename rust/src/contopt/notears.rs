//! Linear NOTEARS (Zheng et al., NeurIPS 2018).
//!
//! min_W  (1/2n)‖X − XW‖²_F + λ₁‖W‖₁   s.t.  h(W) = tr(e^{W∘W}) − d = 0
//!
//! solved by the augmented Lagrangian
//! L(W) = loss + α·h + (ρ/2)·h², ρ escalated until h < h_tol, with Adam
//! as the (unconstrained) inner optimizer. Post-processing thresholds
//! |W| > w_thresh into a DAG. Hyper-parameters follow App. B.2, except
//! h_tol: the reference uses L-BFGS-B and h_tol = 1e-8; Adam's
//! per-coordinate normalization amplifies the vanishing h-gradient at
//! extreme ρ (it erases converged weights), so we stop at h_tol = 5e-4,
//! where the graph-relevant weights are stable and the residual cycle
//! mass stays far below the 0.3 edge threshold.

use super::adam::Adam;
use super::{standardized, threshold_to_dag};
use crate::graph::Dag;
use crate::linalg::{expm, Mat};

#[derive(Clone, Copy, Debug)]
pub struct NotearsConfig {
    pub lambda1: f64,
    pub w_thresh: f64,
    pub h_tol: f64,
    pub rho_max: f64,
    pub inner_iters: usize,
    pub outer_iters: usize,
    pub lr: f64,
}

impl Default for NotearsConfig {
    fn default() -> Self {
        NotearsConfig {
            lambda1: 0.01,
            w_thresh: 0.3,
            h_tol: 5e-4,
            rho_max: 1e8,
            inner_iters: 800,
            outer_iters: 12,
            lr: 0.03,
        }
    }
}

/// h(W) = tr(e^{W∘W}) − d and its gradient (e^{W∘W})ᵀ ∘ 2W.
pub fn acyclicity(w: &Mat) -> (f64, Mat) {
    let d = w.rows;
    let mut ww = w.clone();
    for x in &mut ww.data {
        *x = *x * *x;
    }
    let e = expm(&ww);
    let h = e.trace() - d as f64;
    let et = e.transpose();
    let mut grad = Mat::zeros(d, d);
    for i in 0..d {
        for j in 0..d {
            grad[(i, j)] = et[(i, j)] * 2.0 * w[(i, j)];
        }
    }
    (h, grad)
}

/// (loss, gradient) of the least-squares term.
fn ls_loss(x: &Mat, w: &Mat) -> (f64, Mat) {
    let n = x.rows as f64;
    let xw = x.matmul(w);
    let resid = x - &xw; // n×d
    let loss = 0.5 / n * resid.data.iter().map(|v| v * v).sum::<f64>();
    // ∇ = −(1/n) Xᵀ (X − XW)
    let grad = x.t_matmul(&resid).scale(-1.0 / n);
    (loss, grad)
}

/// Run NOTEARS on an n×d sample matrix; returns the estimated DAG and
/// the final weight matrix.
pub fn notears(x_raw: &Mat, cfg: &NotearsConfig) -> (Dag, Mat) {
    let x = standardized(x_raw);
    let d = x.cols;
    let mut w = Mat::zeros(d, d);
    let mut alpha = 0.0;
    let mut rho = 1.0;
    let mut h_prev = f64::INFINITY;

    for _outer in 0..cfg.outer_iters {
        // inner minimization of the augmented Lagrangian at (α, ρ)
        let mut opt = Adam::new(d * d, cfg.lr);
        for _ in 0..cfg.inner_iters {
            let (_, g_ls) = ls_loss(&x, &w);
            let (h, g_h) = acyclicity(&w);
            let mut grad = vec![0.0; d * d];
            for i in 0..d * d {
                let l1g = cfg.lambda1 * w.data[i].signum();
                grad[i] = g_ls.data[i] + (alpha + rho * h) * g_h.data[i] + l1g;
            }
            // keep the diagonal pinned at zero
            for i in 0..d {
                grad[i * d + i] = 0.0;
            }
            opt.step(&mut w.data, &grad);
            for i in 0..d {
                w.data[i * d + i] = 0.0;
            }
        }
        let (h_val, _) = acyclicity(&w);
        if h_val < cfg.h_tol || rho > cfg.rho_max {
            break;
        }
        alpha += rho * h_val;
        // standard NOTEARS continuation: escalate ρ only while the
        // constraint violation is not shrinking fast enough
        if h_val > 0.25 * h_prev {
            rho *= 10.0;
        }
        h_prev = h_val;
    }
    (threshold_to_dag(&w, cfg.w_thresh), w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn linear_sem(n: usize, seed: u64) -> (Mat, Dag) {
        // X1 → X2 → X3, X1 → X3
        let mut rng = Pcg64::new(seed);
        let mut x = Mat::zeros(n, 3);
        for r in 0..n {
            let a = rng.normal();
            let b = 1.4 * a + 0.4 * rng.normal();
            let c = 0.9 * b - 0.8 * a + 0.4 * rng.normal();
            x[(r, 0)] = a;
            x[(r, 1)] = b;
            x[(r, 2)] = c;
        }
        (x, Dag::from_edges(3, &[(0, 1), (1, 2), (0, 2)]))
    }

    #[test]
    fn acyclicity_zero_for_dag_weights() {
        let mut w = Mat::zeros(3, 3);
        w[(0, 1)] = 0.5;
        w[(1, 2)] = -0.7;
        let (h, _) = acyclicity(&w);
        assert!(h.abs() < 1e-10);
    }

    #[test]
    fn acyclicity_positive_for_cycles() {
        let mut w = Mat::zeros(2, 2);
        w[(0, 1)] = 0.5;
        w[(1, 0)] = 0.5;
        let (h, g) = acyclicity(&w);
        assert!(h > 0.01);
        assert!(g[(0, 1)] > 0.0 && g[(1, 0)] > 0.0, "gradient pushes weights down");
    }

    #[test]
    fn recovers_linear_sem_skeleton() {
        let (x, truth) = linear_sem(500, 1);
        let (dag, _w) = notears(&x, &NotearsConfig::default());
        // skeleton recovery (orientation of 3-clique is hard for l2 loss)
        let est: std::collections::HashSet<(usize, usize)> =
            dag.skeleton().into_iter().collect();
        let want: std::collections::HashSet<(usize, usize)> =
            truth.skeleton().into_iter().collect();
        let inter = est.intersection(&want).count();
        assert!(inter >= 2, "at least 2 of 3 true edges found, got {inter} ({est:?})");
        assert!(dag.topological_order().is_some());
    }
}
