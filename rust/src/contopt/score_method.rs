//! SCORE (Rolland et al., ICML 2022): causal discovery for nonlinear
//! additive-noise models via the score's Jacobian.
//!
//! Key fact: for an ANM, Var_x[∂²log p(x)/∂x_j²] = 0 iff X_j is a leaf.
//! The algorithm estimates diag(∇² log p) with a Stein kernel estimator,
//! removes the argmin-variance variable, repeats to get a topological
//! order, then prunes the full order with sparse regression (CAM-style
//! pruning simplified to ridge + coefficient threshold).

use super::standardized;
use crate::graph::Dag;
use crate::linalg::{Cholesky, Mat};

#[derive(Clone, Copy, Debug)]
pub struct ScoreMethodConfig {
    /// Stein ridge η.
    pub eta: f64,
    /// Pruning threshold on standardized ridge coefficients.
    pub prune_thresh: f64,
}

impl Default for ScoreMethodConfig {
    fn default() -> Self {
        ScoreMethodConfig { eta: 0.01, prune_thresh: 0.12 }
    }
}

/// Stein estimate of the *variance over samples* of the score-Jacobian
/// diagonal, per variable. Columns of `x` are variables.
fn jacobian_diag_variance(x: &Mat, eta: f64) -> Vec<f64> {
    let n = x.rows;
    let d = x.cols;
    // RBF width: median pairwise distance
    let sigma = crate::kernel::median_heuristic(x, 1.0).max(1e-6);
    let s2 = sigma * sigma;
    // kernel matrix
    let mut k = Mat::zeros(n, n);
    for a in 0..n {
        k[(a, a)] = 1.0;
        for b in (a + 1)..n {
            let mut d2 = 0.0;
            for c in 0..d {
                let diff = x[(a, c)] - x[(b, c)];
                d2 += diff * diff;
            }
            let v = (-d2 / (2.0 * s2)).exp();
            k[(a, b)] = v;
            k[(b, a)] = v;
        }
    }
    // NOTE: the ridge is added as K + ηI (the SCORE paper's setting).
    // Scaling the ridge with n (K + ηnI) over-smooths the Stein solve and
    // can invert the leaf-variance ordering on heavy-tailed mechanisms —
    // see EXPERIMENTS.md §Perf for the sweep that picked this.
    let chol = Cholesky::new(&k.add_diag(eta)).expect("K + ηI SPD");

    let mut variances = vec![0.0; d];
    for j in 0..d {
        // ∇K and ∂²K columns for coordinate j
        let mut dk = Mat::zeros(n, 1); // Σ_b ∂_{x_a j} K_ab
        let mut d2k = Mat::zeros(n, 1); // Σ_b ∂²_{x_a j} K_ab
        for a in 0..n {
            let mut s1 = 0.0;
            let mut s2_ = 0.0;
            for b in 0..n {
                let diff = x[(a, j)] - x[(b, j)];
                s1 += -diff / s2 * k[(a, b)];
                s2_ += (diff * diff / (s2 * s2) - 1.0 / s2) * k[(a, b)];
            }
            dk[(a, 0)] = s1;
            d2k[(a, 0)] = s2_;
        }
        // ĝ_j = −(K+ηI)⁻¹ ∇K ; Ĵ_jj = −(K+ηI)⁻¹ ∂²K + ĝ_j² (Stein 2nd order)
        let g = chol.solve(&dk).scale(-1.0);
        let jdiag_base = chol.solve(&d2k).scale(-1.0);
        let jvals: Vec<f64> = (0..n).map(|a| jdiag_base[(a, 0)] + g[(a, 0)] * g[(a, 0)]).collect();
        let mean = jvals.iter().sum::<f64>() / n as f64;
        variances[j] = jvals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n as f64;
    }
    variances
}

/// Run SCORE; returns the estimated DAG.
pub fn score_method(x_raw: &Mat, cfg: &ScoreMethodConfig) -> Dag {
    let x = standardized(x_raw);
    let d = x.cols;

    // 1. leaf ordering by repeated min-variance removal
    let mut remaining: Vec<usize> = (0..d).collect();
    let mut order_rev: Vec<usize> = vec![]; // leaves first
    while remaining.len() > 1 {
        // restrict to remaining columns
        let sub = {
            let mut m = Mat::zeros(x.rows, remaining.len());
            for (c, &v) in remaining.iter().enumerate() {
                for r in 0..x.rows {
                    m[(r, c)] = x[(r, v)];
                }
            }
            m
        };
        let vars = jacobian_diag_variance(&sub, cfg.eta);
        let (leaf_pos, _) = vars
            .iter()
            .enumerate()
            .fold((0, f64::INFINITY), |(bi, bv), (i, &v)| if v < bv { (i, v) } else { (bi, bv) });
        order_rev.push(remaining.remove(leaf_pos));
    }
    order_rev.push(remaining[0]);
    let order: Vec<usize> = order_rev.into_iter().rev().collect(); // roots first

    // 2. prune the full ordering with ridge regression: parent kept if
    // its standardized coefficient is large enough
    let mut g = Dag::new(d);
    let n = x.rows;
    for (pos, &v) in order.iter().enumerate() {
        if pos == 0 {
            continue;
        }
        let preds = &order[..pos];
        let k = preds.len();
        let mut xp = Mat::zeros(n, k);
        for (c, &p) in preds.iter().enumerate() {
            for r in 0..n {
                xp[(r, c)] = x[(r, p)];
            }
        }
        let xtx = xp.t_matmul(&xp).add_diag(1e-3 * n as f64);
        let mut xty = Mat::zeros(k, 1);
        for r in 0..n {
            for c in 0..k {
                xty[(c, 0)] += xp[(r, c)] * x[(r, v)];
            }
        }
        let beta = Cholesky::new(&xtx).expect("SPD").solve(&xty);
        for (c, &p) in preds.iter().enumerate() {
            if beta[(c, 0)].abs() > cfg.prune_thresh {
                g.add_edge(p, v);
            }
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn orders_nonlinear_chain() {
        // X1 → X2 → X3 with nonlinear mechanisms: leaf order should put
        // X1 before X3 and recover the chain's skeleton after pruning.
        let mut rng = Pcg64::new(1);
        let n = 400;
        let mut x = Mat::zeros(n, 3);
        for r in 0..n {
            let a = rng.normal();
            let b = (1.5 * a).sin() + 0.3 * rng.normal();
            let c = 1.2 * b + 0.3 * rng.normal();
            x[(r, 0)] = a;
            x[(r, 1)] = b;
            x[(r, 2)] = c;
        }
        let g = score_method(&x, &ScoreMethodConfig::default());
        assert!(g.topological_order().is_some());
        let skel = g.skeleton();
        assert!(skel.contains(&(1, 2)), "X2−X3 edge expected: {skel:?}");
        assert!(skel.contains(&(0, 1)), "X1−X2 edge expected: {skel:?}");
    }

    #[test]
    fn variance_smaller_for_leaf() {
        // in a pair X→Y, the leaf Y must have smaller Jacobian-diag variance
        let mut rng = Pcg64::new(2);
        let n = 300;
        let mut x = Mat::zeros(n, 2);
        for r in 0..n {
            let a = rng.normal();
            x[(r, 0)] = a;
            x[(r, 1)] = a * a * 0.8 + 0.3 * rng.normal();
        }
        let v = jacobian_diag_variance(&standardized(&x), 0.01);
        assert!(v[1] < v[0], "leaf variance must be smaller: {v:?}");
    }
}
