//! DAGMA (Bello et al., NeurIPS 2022): DAG learning with the
//! log-determinant acyclicity characterization
//!
//!   h_s(W) = −logdet(sI − W∘W) + d·log s,   s > ρ(W∘W)
//!
//! minimized on a central path μ_k → 0 of
//!   μ·[ (1/2n)‖X−XW‖² + λ₁‖W‖₁ ] + h_s(W).
//! Hyper-parameters follow App. B.2 (λ₁ = 0, λ₂ = 0.005 as ridge).

use super::adam::Adam;
use super::{standardized, threshold_to_dag};
use crate::graph::Dag;
use crate::linalg::{Lu, Mat};

#[derive(Clone, Copy, Debug)]
pub struct DagmaConfig {
    pub lambda1: f64,
    pub lambda2: f64,
    pub w_thresh: f64,
    pub s: f64,
    pub mu_init: f64,
    pub mu_factor: f64,
    pub outer_iters: usize,
    pub inner_iters: usize,
    pub lr: f64,
}

impl Default for DagmaConfig {
    fn default() -> Self {
        DagmaConfig {
            lambda1: 0.0,
            lambda2: 0.005,
            w_thresh: 0.3,
            s: 1.0,
            mu_init: 1.0,
            mu_factor: 0.1,
            outer_iters: 4,
            inner_iters: 400,
            lr: 0.02,
        }
    }
}

/// h_s(W) and its gradient 2·(sI − W∘W)⁻ᵀ ∘ W. Returns None if W left
/// the feasible region (sI − W∘W singular / not an M-matrix).
pub fn logdet_acyclicity(w: &Mat, s: f64) -> Option<(f64, Mat)> {
    let d = w.rows;
    let mut m = Mat::zeros(d, d);
    for i in 0..d {
        for j in 0..d {
            m[(i, j)] = -w[(i, j)] * w[(i, j)];
        }
        m[(i, i)] += s;
    }
    let lu = Lu::new(&m)?;
    let det = lu.det();
    if det <= 0.0 {
        return None;
    }
    let h = -det.ln() + d as f64 * s.ln();
    let minv_t = lu.inverse().transpose();
    let mut grad = Mat::zeros(d, d);
    for i in 0..d {
        for j in 0..d {
            grad[(i, j)] = 2.0 * minv_t[(i, j)] * w[(i, j)];
        }
    }
    Some((h, grad))
}

/// Run DAGMA; returns (DAG, weights).
pub fn dagma(x_raw: &Mat, cfg: &DagmaConfig) -> (Dag, Mat) {
    let x = standardized(x_raw);
    let n = x.rows as f64;
    let d = x.cols;
    let mut w = Mat::zeros(d, d);
    let mut mu = cfg.mu_init;

    for _outer in 0..cfg.outer_iters {
        let mut opt = Adam::new(d * d, cfg.lr);
        let mut w_backup = w.clone();
        for _ in 0..cfg.inner_iters {
            let xw = x.matmul(&w);
            let resid = &x - &xw;
            let g_ls = x.t_matmul(&resid).scale(-1.0 / n);
            match logdet_acyclicity(&w, cfg.s) {
                Some((_h, g_h)) => {
                    let mut grad = vec![0.0; d * d];
                    for i in 0..d * d {
                        grad[i] = mu
                            * (g_ls.data[i]
                                + cfg.lambda1 * w.data[i].signum()
                                + cfg.lambda2 * w.data[i])
                            + g_h.data[i];
                    }
                    for i in 0..d {
                        grad[i * d + i] = 0.0;
                    }
                    w_backup = w.clone();
                    opt.step(&mut w.data, &grad);
                    for i in 0..d {
                        w.data[i * d + i] = 0.0;
                    }
                }
                None => {
                    // left the M-matrix region: step back and damp
                    w = w_backup.clone();
                    for v in &mut w.data {
                        *v *= 0.5;
                    }
                    break;
                }
            }
        }
        mu *= cfg.mu_factor;
    }
    (threshold_to_dag(&w, cfg.w_thresh), w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn logdet_h_zero_for_dags() {
        let mut w = Mat::zeros(3, 3);
        w[(0, 1)] = 0.6;
        w[(1, 2)] = -0.5;
        let (h, _) = logdet_acyclicity(&w, 1.0).unwrap();
        assert!(h.abs() < 1e-10, "h={h}");
    }

    #[test]
    fn logdet_h_positive_for_cycles() {
        let mut w = Mat::zeros(2, 2);
        w[(0, 1)] = 0.6;
        w[(1, 0)] = 0.6;
        let (h, _) = logdet_acyclicity(&w, 1.0).unwrap();
        assert!(h > 0.01, "h={h}");
    }

    #[test]
    fn infeasible_region_detected() {
        let mut w = Mat::zeros(2, 2);
        w[(0, 1)] = 1.2;
        w[(1, 0)] = 1.2; // spectral radius of W∘W > 1
        assert!(logdet_acyclicity(&w, 1.0).is_none());
    }

    #[test]
    fn recovers_simple_chain() {
        let mut rng = Pcg64::new(2);
        let n = 500;
        let mut x = Mat::zeros(n, 3);
        for r in 0..n {
            let a = rng.normal();
            let b = 1.5 * a + 0.3 * rng.normal();
            let c = -1.2 * b + 0.3 * rng.normal();
            x[(r, 0)] = a;
            x[(r, 1)] = b;
            x[(r, 2)] = c;
        }
        let (dag, _) = dagma(&x, &DagmaConfig::default());
        let skel = dag.skeleton();
        assert!(skel.contains(&(0, 1)), "edge X1−X2 found: {skel:?}");
        assert!(skel.contains(&(1, 2)), "edge X2−X3 found: {skel:?}");
    }
}
