//! Continuous-optimization causal discovery baselines (paper App. B.2
//! Table 2 / B.3 Table 3):
//!
//! * [`notears`] — linear NOTEARS (Zheng et al. 2018): least squares +
//!   ℓ1 with the tr(e^{W∘W})−d acyclicity function, augmented
//!   Lagrangian outer loop, Adam inner loop;
//! * [`dagma`] — DAGMA (Bello et al. 2022): the −logdet(sI−W∘W)
//!   acyclicity function on a central path;
//! * [`grandag`] — GraN-DAG-lite: per-variable one-hidden-layer MLPs
//!   with hand-written backprop, acyclicity on the input-weight path
//!   matrix (a faithful small-scale stand-in for the pytorch original —
//!   see DESIGN.md §7);
//! * [`score_method`] — SCORE (Rolland et al. 2022): Stein-estimated
//!   score-Jacobian leaf ordering + regression pruning.

pub mod adam;
pub mod notears;
pub mod dagma;
pub mod grandag;
pub mod score_method;

use crate::graph::Dag;
use crate::linalg::Mat;

/// Threshold a weight matrix into a DAG: zero the diagonal, keep
/// |w| > thresh, and if cycles remain drop the weakest edges until
/// acyclic (standard NOTEARS post-processing).
pub fn threshold_to_dag(w: &Mat, thresh: f64) -> Dag {
    let d = w.rows;
    let mut edges: Vec<(usize, usize, f64)> = vec![];
    for i in 0..d {
        for j in 0..d {
            if i != j && w[(i, j)].abs() > thresh {
                edges.push((i, j, w[(i, j)].abs()));
            }
        }
    }
    // strongest-first greedy insertion keeps the graph acyclic
    edges.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
    let mut g = Dag::new(d);
    for (i, j, _) in edges {
        if !g.creates_cycle(i, j) {
            g.add_edge(i, j);
        }
    }
    g
}

/// Standardize a dataset matrix column-wise (zero mean, unit variance).
pub fn standardized(x: &Mat) -> Mat {
    let mut out = x.clone();
    for c in 0..x.cols {
        let mut mean = 0.0;
        for r in 0..x.rows {
            mean += x[(r, c)];
        }
        mean /= x.rows as f64;
        let mut var = 0.0;
        for r in 0..x.rows {
            let d = x[(r, c)] - mean;
            var += d * d;
        }
        let sd = (var / x.rows as f64).sqrt().max(1e-12);
        for r in 0..x.rows {
            out[(r, c)] = (x[(r, c)] - mean) / sd;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_keeps_strong_edges_acyclic() {
        let mut w = Mat::zeros(3, 3);
        w[(0, 1)] = 0.9;
        w[(1, 2)] = 0.8;
        w[(2, 0)] = 0.5; // would close a cycle — weakest, dropped
        w[(1, 0)] = 0.05; // below threshold
        let g = threshold_to_dag(&w, 0.3);
        assert!(g.has_edge(0, 1) && g.has_edge(1, 2));
        assert!(!g.has_edge(2, 0));
        assert!(g.topological_order().is_some());
    }

    #[test]
    fn standardized_columns() {
        let x = Mat::from_rows(&[&[1.0, 10.0], &[3.0, 30.0], &[5.0, 20.0]]);
        let s = standardized(&x);
        for c in 0..2 {
            let mean: f64 = (0..3).map(|r| s[(r, c)]).sum::<f64>() / 3.0;
            let var: f64 = (0..3).map(|r| s[(r, c)] * s[(r, c)]).sum::<f64>() / 3.0;
            assert!(mean.abs() < 1e-12);
            assert!((var - 1.0).abs() < 1e-9);
        }
    }
}
