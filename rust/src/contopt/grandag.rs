//! GraN-DAG-lite: a faithful small-scale stand-in for GraN-DAG
//! (Lachapelle et al. 2019).
//!
//! Each variable gets a one-hidden-layer MLP
//!     x̂_j = w2_jᵀ · tanh(W1_j x + b_j) + c_j
//! with Gaussian NLL loss; the neural connectivity matrix
//!     A_ij = ‖(W1_j)_{:,i}‖₂ · ‖w2_j‖-weighted path strength
//! is constrained acyclic through the NOTEARS exponential penalty, as
//! in the original paper. Backprop is hand-written (no autodiff crate
//! offline); the network sizes match the App. B.2 defaults scaled to
//! the 11-node SACHS problem (2 hidden layers × 10 units in the paper;
//! one layer × `hidden` units here — documented in DESIGN.md §7).

use super::adam::Adam;
use super::{standardized, threshold_to_dag};
use crate::graph::Dag;
use crate::linalg::{expm, Mat};
use crate::util::Pcg64;

#[derive(Clone, Copy, Debug)]
pub struct GranDagConfig {
    pub hidden: usize,
    pub iters: usize,
    pub lr: f64,
    pub lambda_h: f64,
    /// L1 shrinkage on the neural connectivity matrix — GraN-DAG proper
    /// gets sparsity from preliminary neighbourhood selection + CAM
    /// pruning; the lite version folds it into the objective so that
    /// spurious input paths decay to zero.
    pub lambda_l1: f64,
    pub w_thresh: f64,
    pub seed: u64,
}

impl Default for GranDagConfig {
    fn default() -> Self {
        GranDagConfig {
            hidden: 10,
            iters: 1500,
            lr: 0.01,
            lambda_h: 10.0,
            lambda_l1: 0.03,
            w_thresh: 0.1,
            seed: 0,
        }
    }
}

struct Net {
    d: usize,
    h: usize,
    /// per-variable input weights, h×d each (flattened per variable).
    w1: Vec<Mat>,
    b1: Vec<Vec<f64>>,
    w2: Vec<Vec<f64>>,
    c: Vec<f64>,
}

impl Net {
    fn new(d: usize, h: usize, rng: &mut Pcg64) -> Net {
        let mut w1 = vec![];
        let mut b1 = vec![];
        let mut w2 = vec![];
        for _ in 0..d {
            let mut m = Mat::zeros(h, d);
            for v in &mut m.data {
                *v = 0.3 * rng.normal();
            }
            w1.push(m);
            b1.push((0..h).map(|_| 0.1 * rng.normal()).collect());
            w2.push((0..h).map(|_| 0.3 * rng.normal()).collect());
        }
        Net { d, h, w1, b1, w2, c: vec![0.0; d] }
    }

    /// Neural connectivity: A_ij = Σ_k |w1_j[k,i]| · |w2_j[k]| (path
    /// strength from input i into output j), with A_jj forced to 0.
    fn connectivity(&self) -> Mat {
        let mut a = Mat::zeros(self.d, self.d);
        for j in 0..self.d {
            for i in 0..self.d {
                if i == j {
                    continue;
                }
                let mut s = 0.0;
                for k in 0..self.h {
                    s += self.w1[j][(k, i)].abs() * self.w2[j][k].abs();
                }
                a[(i, j)] = s;
            }
        }
        a
    }
}

/// Train GraN-DAG-lite and threshold its connectivity into a DAG.
pub fn grandag(x_raw: &Mat, cfg: &GranDagConfig) -> (Dag, Mat) {
    let x = standardized(x_raw);
    let n = x.rows;
    let d = x.cols;
    let mut rng = Pcg64::new(cfg.seed ^ 0x6AD);
    let mut net = Net::new(d, cfg.hidden, &mut rng);
    let h = cfg.hidden;

    // flatten parameters for Adam: per variable [w1 (h*d), b1 (h), w2 (h), c (1)]
    let per = h * d + h + h + 1;
    let mut opt = Adam::new(d * per, cfg.lr);

    let batch = n.min(128);
    for it in 0..cfg.iters {
        // mini-batch indices (deterministic rotation)
        let start = (it * batch) % n;
        let idx: Vec<usize> = (0..batch).map(|k| (start + k) % n).collect();

        // acyclicity penalty on the connectivity matrix
        let a = net.connectivity();
        let mut aa = a.clone();
        for v in &mut aa.data {
            *v = *v * *v;
        }
        let e_t = expm(&aa).transpose();

        let mut grads = vec![0.0; d * per];
        for j in 0..d {
            let w1 = &net.w1[j];
            let b1 = &net.b1[j];
            let w2 = &net.w2[j];
            // forward/backward over the batch
            let mut g_w1 = Mat::zeros(h, d);
            let mut g_b1 = vec![0.0; h];
            let mut g_w2 = vec![0.0; h];
            let mut g_c = 0.0;
            for &r in &idx {
                let xr = x.row(r);
                // mask own input (GraN-DAG zeroes the diagonal input)
                let mut z = vec![0.0; h];
                for k in 0..h {
                    let mut s = b1[k];
                    for i in 0..d {
                        if i != j {
                            s += w1[(k, i)] * xr[i];
                        }
                    }
                    z[k] = s.tanh();
                }
                let pred: f64 = net.c[j] + (0..h).map(|k| w2[k] * z[k]).sum::<f64>();
                let err = pred - xr[j];
                // dL/dpred = err (0.5 err² loss)
                g_c += err;
                for k in 0..h {
                    g_w2[k] += err * z[k];
                    let dz = err * w2[k] * (1.0 - z[k] * z[k]);
                    g_b1[k] += dz;
                    for i in 0..d {
                        if i != j {
                            g_w1[(k, i)] += dz * xr[i];
                        }
                    }
                }
            }
            let bn = idx.len() as f64;

            // acyclicity gradient through A_ij = Σ_k |w1|·|w2| plus L1
            // shrinkage λ₁·A_ij: dh/dA_ij = 2 A_ij e_t[i,j]·λ_h + λ₁;
            // chain into w1/w2 via sign().
            for i in 0..d {
                if i == j {
                    continue;
                }
                let dh_da = 2.0 * a[(i, j)] * e_t[(i, j)] * cfg.lambda_h + cfg.lambda_l1;
                if dh_da == 0.0 {
                    continue;
                }
                for k in 0..h {
                    g_w1[(k, i)] += dh_da * net.w1[j][(k, i)].signum() * net.w2[j][k].abs() * bn;
                    g_w2[k] += dh_da * net.w1[j][(k, i)].abs() * net.w2[j][k].signum() * bn;
                }
            }

            // write into the flat gradient
            let base = j * per;
            for k in 0..h {
                for i in 0..d {
                    grads[base + k * d + i] = g_w1[(k, i)] / bn;
                }
            }
            for k in 0..h {
                grads[base + h * d + k] = g_b1[k] / bn;
                grads[base + h * d + h + k] = g_w2[k] / bn;
            }
            grads[base + h * d + 2 * h] = g_c / bn;
        }

        // flatten params, step, unflatten
        let mut params = vec![0.0; d * per];
        for j in 0..d {
            let base = j * per;
            params[base..base + h * d].copy_from_slice(&net.w1[j].data);
            params[base + h * d..base + h * d + h].copy_from_slice(&net.b1[j]);
            params[base + h * d + h..base + h * d + 2 * h].copy_from_slice(&net.w2[j]);
            params[base + h * d + 2 * h] = net.c[j];
        }
        opt.step(&mut params, &grads);
        for j in 0..d {
            let base = j * per;
            net.w1[j].data.copy_from_slice(&params[base..base + h * d]);
            net.b1[j].copy_from_slice(&params[base + h * d..base + h * d + h]);
            net.w2[j].copy_from_slice(&params[base + h * d + h..base + h * d + 2 * h]);
            net.c[j] = params[base + h * d + 2 * h];
        }
    }

    let a = net.connectivity();
    (threshold_to_dag(&a, cfg.w_thresh), a)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn learns_nonlinear_pair() {
        // X2 = tanh(2 X1) + noise; A[0,1] should dominate A[1,0]... both
        // directions may fit, but the true direction must be found at
        // least as strongly, and the output must be a DAG.
        let mut rng = Pcg64::new(3);
        let n = 300;
        let mut x = Mat::zeros(n, 2);
        for r in 0..n {
            let a = rng.normal();
            x[(r, 0)] = a;
            x[(r, 1)] = (2.0 * a).tanh() + 0.2 * rng.normal();
        }
        let (dag, a) = grandag(&x, &GranDagConfig { iters: 600, ..Default::default() });
        assert!(dag.topological_order().is_some());
        assert!(
            a[(0, 1)] > 0.05 || a[(1, 0)] > 0.05,
            "some dependence must be found: {a:?}"
        );
        assert!(dag.num_edges() >= 1, "the X1−X2 edge must appear");
    }

    #[test]
    fn independent_variables_no_edges() {
        let mut rng = Pcg64::new(4);
        let n = 300;
        let mut x = Mat::zeros(n, 3);
        for v in &mut x.data {
            *v = rng.normal();
        }
        let (dag, _) = grandag(&x, &GranDagConfig { iters: 600, ..Default::default() });
        assert!(dag.num_edges() <= 1, "independent data should stay (near) empty");
    }
}
