//! In-tree shim for the `xla` PJRT binding.
//!
//! The production runtime links a real `xla` crate (PJRT CPU client +
//! HLO compilation); that binding is not on crates.io, so the default
//! build compiles against this API-compatible shim instead. The pure
//! data types ([`Literal`]) are fully functional — literal packing,
//! padding and the `mat_literal`/`scalar_literal` helpers behave
//! exactly as with the real binding — while the device types
//! ([`PjRtClient`]) report PJRT as unavailable at construction, so
//! `Runtime::load` fails with a clear message and callers fall back to
//! the native engine. Swapping the real binding back in is a one-line
//! change in `runtime/mod.rs`; no call site mentions the shim.

use std::fmt;

/// Shim error: carries the message the call sites render with `{:?}`.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

/// Marker for element types a [`Literal`] can be read back as.
pub trait NativeElem: Copy {
    fn from_f64(v: f64) -> Self;
}

impl NativeElem for f64 {
    fn from_f64(v: f64) -> f64 {
        v
    }
}

/// A host-side typed array: shape + row-major f64 payload.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    shape: Vec<i64>,
    data: Vec<f64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1(data: &[f64]) -> Literal {
        Literal { shape: vec![data.len() as i64], data: data.to_vec() }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar(x: f64) -> Literal {
        Literal { shape: vec![], data: vec![x] }
    }

    /// Reinterpret under a new shape with the same element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let want: i64 = dims.iter().product();
        if want as usize != self.data.len() {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count {} != {want}",
                self.shape,
                self.data.len()
            )));
        }
        Ok(Literal { shape: dims.to_vec(), data: self.data.clone() })
    }

    /// Unwrap a 1-tuple result literal.
    pub fn to_tuple1(self) -> Result<Literal, Error> {
        Err(Error("tuple literals require the real xla binding".to_string()))
    }

    /// Read the payload back as a typed vector.
    pub fn to_vec<T: NativeElem>(&self) -> Result<Vec<T>, Error> {
        Ok(self.data.iter().map(|&v| T::from_f64(v)).collect())
    }
}

/// Parsed HLO module (opaque in the shim).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(Error(unavailable("HloModuleProto::from_text_file")))
    }
}

/// An XLA computation handle (opaque in the shim).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT CPU client. Unconstructible in the shim: `cpu()` errors, so
/// everything downstream of it is unreachable but type-checks.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error(unavailable("PjRtClient::cpu")))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error(unavailable("PjRtClient::compile")))
    }
}

/// A compiled executable (opaque in the shim).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error(unavailable("PjRtLoadedExecutable::execute")))
    }
}

/// A device buffer (opaque in the shim).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error(unavailable("PjRtBuffer::to_literal_sync")))
    }
}

fn unavailable(what: &str) -> String {
    format!(
        "{what}: PJRT is unavailable — this build uses the in-tree xla shim; \
         link the real `xla` binding to run the AOT artifacts (native engine \
         remains fully functional)"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let m = l.reshape(&[2, 3]).unwrap();
        assert_eq!(m.to_vec::<f64>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 2]).is_err(), "element-count mismatch must fail");
        assert_eq!(Literal::scalar(2.5).to_vec::<f64>().unwrap(), vec![2.5]);
    }

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().err().expect("shim client must not construct");
        assert!(format!("{e:?}").contains("shim"));
    }
}
