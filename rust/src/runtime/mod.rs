//! PJRT runtime — loads the AOT-compiled HLO-text score artifacts
//! (`artifacts/*.hlo.txt`, built once by `make artifacts`) and executes
//! them from the rust hot path. Python never runs here.
//!
//! Artifact names encode their shapes (see `python/compile/aot.py`):
//!
//! * `cvlr_cond_n{N}` / `cvlr_marg_n{N}` — one CV fold of the CV-LR
//!   score at factor bucket N (train rows ≤ N, test rows ≤ N/4,
//!   columns ≤ M=128); zero row/column padding is exact, the true
//!   counts travel as scalars.
//! * `exact_cond_n{n}` / `exact_marg_n{n}` — one fold of the exact
//!   O(n³) CV score at fixed fold shape (n0 = n/10, n1 = n − n/10),
//!   feature dims padded to DX=8 / DZ=32.
//!
//! Thread safety: the `xla` crate's PJRT wrappers are raw-pointer types
//! without Send/Sync. All access is serialized behind one `Mutex`, and
//! the `unsafe impl Send/Sync` below is sound because the mutex is the
//! only path to the wrapped pointers.

pub mod pjrt_kernel;
// The `xla` binding: an in-tree API-compatible shim by default (see its
// module docs); swap this line for the real crate to enable PJRT.
pub mod xla;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::linalg::Mat;

/// Column capacity of the CV-LR factor artifacts.
pub const M_CAP: usize = 128;
/// Feature capacities of the exact-CV artifacts.
pub const DX_CAP: usize = 8;
pub const DZ_CAP: usize = 32;

struct Inner {
    client: xla::PjRtClient,
    /// name → compiled executable (compiled lazily on first use).
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// The artifact registry + PJRT executor.
pub struct Runtime {
    dir: PathBuf,
    inner: Mutex<Inner>,
    /// Available CV-LR buckets (train-row capacities), ascending.
    pub cvlr_buckets: Vec<usize>,
    /// Available CV-LR column (rank) buckets, ascending.
    pub m_buckets: Vec<usize>,
    /// Available exact-CV sample sizes, ascending.
    pub exact_sizes: Vec<usize>,
    /// Number of artifact executions (metrics).
    executions: Mutex<u64>,
}

// SAFETY: every use of `client`/`exes` goes through `inner: Mutex<_>`,
// so the non-Sync raw-pointer wrappers are never touched concurrently.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Scan an artifacts directory and create a CPU PJRT client.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let mut cvlr_buckets = vec![];
        let mut m_buckets = vec![];
        let mut exact_sizes = vec![];
        for entry in std::fs::read_dir(&dir)
            .with_context(|| format!("artifacts dir {dir:?} missing — run `make artifacts`"))?
        {
            let name = entry?.file_name().to_string_lossy().to_string();
            if let Some(rest) = name.strip_suffix(".hlo.txt") {
                if let Some(nm) = rest.strip_prefix("cvlr_cond_n") {
                    // "256_m32" → (256, 32)
                    let (n, m) = nm
                        .split_once("_m")
                        .ok_or_else(|| anyhow!("bad cvlr artifact name {name}"))?;
                    cvlr_buckets.push(n.parse()?);
                    m_buckets.push(m.parse()?);
                } else if let Some(n) = rest.strip_prefix("exact_cond_n") {
                    exact_sizes.push(n.parse()?);
                }
            }
        }
        if cvlr_buckets.is_empty() {
            bail!("no cvlr artifacts found in {dir:?} — run `make artifacts`");
        }
        cvlr_buckets.sort_unstable();
        cvlr_buckets.dedup();
        m_buckets.sort_unstable();
        m_buckets.dedup();
        exact_sizes.sort_unstable();
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            dir,
            inner: Mutex::new(Inner { client, exes: HashMap::new() }),
            cvlr_buckets,
            m_buckets,
            exact_sizes,
            executions: Mutex::new(0),
        })
    }

    /// Number of artifact executions so far.
    pub fn executions(&self) -> u64 {
        *self.executions.lock().unwrap()
    }

    /// Smallest CV-LR bucket whose train capacity fits `n1` rows.
    pub fn bucket_for(&self, n1: usize) -> Result<usize> {
        self.cvlr_buckets
            .iter()
            .cloned()
            .find(|&b| b >= n1 && b / 4 >= n1.div_ceil(9)) // n0 ≤ ceil(n1/9) for 10-fold
            .ok_or_else(|| anyhow!("no CV-LR bucket fits n1={n1} (have {:?})", self.cvlr_buckets))
    }

    /// Smallest column bucket fitting `m` factor columns. The artifact
    /// pays Gram FLOPs for every padded column, so picking the tight
    /// bucket is the single biggest hot-path lever (§Perf iteration 1).
    pub fn m_bucket_for(&self, m: usize) -> Result<usize> {
        self.m_buckets
            .iter()
            .cloned()
            .find(|&b| b >= m)
            .ok_or_else(|| anyhow!("no column bucket fits m={m} (have {:?})", self.m_buckets))
    }

    /// Compile `name` into `inner.exes` if it is not there yet.
    fn compile_if_needed(&self, inner: &mut Inner, name: &str) -> Result<()> {
        if inner.exes.contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = inner
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        inner.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// One execution of an already-compiled artifact; expects a 1-tuple
    /// f64 scalar result (all score graphs return that).
    fn run_one(exe: &xla::PjRtLoadedExecutable, name: &str, args: &[xla::Literal]) -> Result<f64> {
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of {name}: {e:?}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        let v = out
            .to_vec::<f64>()
            .map_err(|e| anyhow!("read f64 result of {name}: {e:?}"))?;
        v.first().cloned().ok_or_else(|| anyhow!("empty result from {name}"))
    }

    /// Execute artifact `name` with the given literals.
    pub fn execute_scalar(&self, name: &str, args: &[xla::Literal]) -> Result<f64> {
        let mut inner = self.inner.lock().unwrap();
        self.compile_if_needed(&mut inner, name)?;
        let exe = inner.exes.get(name).unwrap();
        let v = Self::run_one(exe, name, args)?;
        *self.executions.lock().unwrap() += 1;
        Ok(v)
    }

    /// Batched invocation: execute artifact `name` once per argument
    /// set, holding the executor for the whole batch. Amortizes the
    /// per-call lock acquisition and compile-cache probe across the
    /// batch and keeps the device queue warm — the entry point the
    /// batch-aware CV-LR backend submits whole fold batches through.
    pub fn execute_scalar_many(&self, name: &str, calls: &[Vec<xla::Literal>]) -> Result<Vec<f64>> {
        let mut inner = self.inner.lock().unwrap();
        self.compile_if_needed(&mut inner, name)?;
        let exe = inner.exes.get(name).unwrap();
        let mut out = Vec::with_capacity(calls.len());
        for args in calls {
            out.push(Self::run_one(exe, name, args)?);
        }
        *self.executions.lock().unwrap() += calls.len() as u64;
        Ok(out)
    }

    /// Pre-compile a set of artifacts (warm-up before timing runs).
    pub fn warm_up(&self, names: &[String]) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        for name in names {
            self.compile_if_needed(&mut inner, name)?;
        }
        Ok(())
    }
}

/// Row-major `Mat` → `Literal` of shape [rows, cols], zero-padded to
/// (rows_cap, cols_cap).
pub fn mat_literal(m: &Mat, rows_cap: usize, cols_cap: usize) -> Result<xla::Literal> {
    assert!(m.rows <= rows_cap && m.cols <= cols_cap, "{}x{} > {rows_cap}x{cols_cap}", m.rows, m.cols);
    let padded = if m.rows == rows_cap && m.cols == cols_cap {
        m.clone()
    } else {
        m.pad_to(rows_cap, cols_cap)
    };
    xla::Literal::vec1(&padded.data)
        .reshape(&[rows_cap as i64, cols_cap as i64])
        .map_err(|e| anyhow!("reshape literal: {e:?}"))
}

/// f64 scalar literal.
pub fn scalar_literal(x: f64) -> xla::Literal {
    xla::Literal::scalar(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need artifacts live in rust/tests/
    // integration_runtime.rs (artifacts are a build product); here we
    // test the pure helpers.

    #[test]
    fn mat_literal_pads() {
        let m = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let lit = mat_literal(&m, 4, 3).unwrap();
        let v = lit.to_vec::<f64>().unwrap();
        assert_eq!(v.len(), 12);
        assert_eq!(&v[0..3], &[1.0, 2.0, 0.0]);
        assert_eq!(&v[3..6], &[3.0, 4.0, 0.0]);
        assert!(v[6..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn scalar_literal_roundtrip() {
        let lit = scalar_literal(2.5);
        assert_eq!(lit.to_vec::<f64>().unwrap(), vec![2.5]);
    }
}
