//! The AOT-artifact implementations of the score backends:
//!
//! * [`PjrtCvLrKernel`] — `score::cvlr::CvLrKernel` over the
//!   `cvlr_cond_n*` / `cvlr_marg_n*` artifacts (the production hot
//!   path: L1 Pallas Gram products + L2 dumbbell algebra, AOT-compiled);
//! * [`PjrtExactScorer`] — the exact O(n³) CV fold over the
//!   `exact_*` artifacts (the Fig. 1 baseline on the same runtime).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;

use super::{mat_literal, scalar_literal, xla, Runtime, DX_CAP, DZ_CAP};
use crate::linalg::Mat;
use crate::score::cvlr::{CondFold, CvLrKernel, MargFold};
use crate::score::folds::CvParams;

/// CV-LR fold evaluation through the AOT artifacts.
///
/// The per-fold entry points pay one runtime dispatch each; the fold
/// *batch* entry points group folds by (row bucket, column bucket) —
/// i.e. by artifact — and submit each group through
/// [`Runtime::execute_scalar_many`], so a whole candidate's folds (and,
/// upstream, a whole GES batch) ride one executor acquisition per
/// artifact instead of one per score.
pub struct PjrtCvLrKernel {
    pub rt: Arc<Runtime>,
}

impl PjrtCvLrKernel {
    pub fn new(rt: Arc<Runtime>) -> Self {
        PjrtCvLrKernel { rt }
    }

    /// (bucket, mcap) shape keys for a conditional fold.
    fn cond_shape(&self, lx1: &Mat, lz1: &Mat) -> Result<(usize, usize)> {
        Ok((self.rt.bucket_for(lx1.rows)?, self.rt.m_bucket_for(lx1.cols.max(lz1.cols))?))
    }

    fn cond_args(
        &self,
        bucket: usize,
        mcap: usize,
        lx0: &Mat,
        lx1: &Mat,
        lz0: &Mat,
        lz1: &Mat,
        p: &CvParams,
    ) -> Result<Vec<xla::Literal>> {
        let n0_cap = bucket / 4;
        Ok(vec![
            mat_literal(lx0, n0_cap, mcap)?,
            mat_literal(lx1, bucket, mcap)?,
            mat_literal(lz0, n0_cap, mcap)?,
            mat_literal(lz1, bucket, mcap)?,
            scalar_literal(lx0.rows as f64),
            scalar_literal(lx1.rows as f64),
            scalar_literal(p.lambda),
            scalar_literal(p.gamma),
        ])
    }

    fn marg_args(
        &self,
        bucket: usize,
        mcap: usize,
        lx0: &Mat,
        lx1: &Mat,
        p: &CvParams,
    ) -> Result<Vec<xla::Literal>> {
        let n0_cap = bucket / 4;
        Ok(vec![
            mat_literal(lx0, n0_cap, mcap)?,
            mat_literal(lx1, bucket, mcap)?,
            scalar_literal(lx0.rows as f64),
            scalar_literal(lx1.rows as f64),
            scalar_literal(p.lambda),
            scalar_literal(p.gamma),
        ])
    }

    fn run_cond(&self, lx0: &Mat, lx1: &Mat, lz0: &Mat, lz1: &Mat, p: &CvParams) -> Result<f64> {
        let (bucket, mcap) = self.cond_shape(lx1, lz1)?;
        let args = self.cond_args(bucket, mcap, lx0, lx1, lz0, lz1, p)?;
        self.rt.execute_scalar(&format!("cvlr_cond_n{bucket}_m{mcap}"), &args)
    }

    fn run_marg(&self, lx0: &Mat, lx1: &Mat, p: &CvParams) -> Result<f64> {
        let bucket = self.rt.bucket_for(lx1.rows)?;
        let mcap = self.rt.m_bucket_for(lx1.cols)?;
        let args = self.marg_args(bucket, mcap, lx0, lx1, p)?;
        self.rt.execute_scalar(&format!("cvlr_marg_n{bucket}_m{mcap}"), &args)
    }

    fn run_cond_batch(&self, folds: &[CondFold<'_>], p: &CvParams) -> Result<Vec<f64>> {
        let mut out = vec![0.0; folds.len()];
        // group folds by artifact shape so each group is one submission
        let mut groups: HashMap<(usize, usize), Vec<usize>> = HashMap::new();
        for (i, f) in folds.iter().enumerate() {
            groups.entry(self.cond_shape(f.lx1, f.lz1)?).or_default().push(i);
        }
        for ((bucket, mcap), idxs) in groups {
            let calls: Vec<Vec<xla::Literal>> = idxs
                .iter()
                .map(|&i| {
                    let f = &folds[i];
                    self.cond_args(bucket, mcap, f.lx0, f.lx1, f.lz0, f.lz1, p)
                })
                .collect::<Result<_>>()?;
            let vals =
                self.rt.execute_scalar_many(&format!("cvlr_cond_n{bucket}_m{mcap}"), &calls)?;
            for (&i, v) in idxs.iter().zip(vals) {
                out[i] = v;
            }
        }
        Ok(out)
    }

    fn run_marg_batch(&self, folds: &[MargFold<'_>], p: &CvParams) -> Result<Vec<f64>> {
        let mut out = vec![0.0; folds.len()];
        let mut groups: HashMap<(usize, usize), Vec<usize>> = HashMap::new();
        for (i, f) in folds.iter().enumerate() {
            let key = (self.rt.bucket_for(f.lx1.rows)?, self.rt.m_bucket_for(f.lx1.cols)?);
            groups.entry(key).or_default().push(i);
        }
        for ((bucket, mcap), idxs) in groups {
            let calls: Vec<Vec<xla::Literal>> = idxs
                .iter()
                .map(|&i| {
                    let f = &folds[i];
                    self.marg_args(bucket, mcap, f.lx0, f.lx1, p)
                })
                .collect::<Result<_>>()?;
            let vals =
                self.rt.execute_scalar_many(&format!("cvlr_marg_n{bucket}_m{mcap}"), &calls)?;
            for (&i, v) in idxs.iter().zip(vals) {
                out[i] = v;
            }
        }
        Ok(out)
    }
}

impl CvLrKernel for PjrtCvLrKernel {
    fn score_cond(&self, lx0: &Mat, lx1: &Mat, lz0: &Mat, lz1: &Mat, p: &CvParams) -> f64 {
        self.run_cond(lx0, lx1, lz0, lz1, p).expect("PJRT cvlr_cond execution failed")
    }

    fn score_marg(&self, lx0: &Mat, lx1: &Mat, p: &CvParams) -> f64 {
        self.run_marg(lx0, lx1, p).expect("PJRT cvlr_marg execution failed")
    }

    fn score_cond_batch(&self, folds: &[CondFold<'_>], p: &CvParams) -> Vec<f64> {
        self.run_cond_batch(folds, p).expect("PJRT cvlr_cond batch execution failed")
    }

    fn score_marg_batch(&self, folds: &[MargFold<'_>], p: &CvParams) -> Vec<f64> {
        self.run_marg_batch(folds, p).expect("PJRT cvlr_marg batch execution failed")
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Exact-CV fold evaluation through the `exact_*` artifacts. Fold
/// shapes are static per artifact: n must be one of the compiled sizes
/// and divisible by the fold count.
pub struct PjrtExactScorer {
    pub rt: Arc<Runtime>,
}

impl PjrtExactScorer {
    pub fn new(rt: Arc<Runtime>) -> Self {
        PjrtExactScorer { rt }
    }

    /// One conditional fold: raw data blocks (x: ≤8 cols, z: ≤32 cols).
    pub fn fold_cond(
        &self,
        x0: &Mat,
        x1: &Mat,
        z0: &Mat,
        z1: &Mat,
        sigx: f64,
        sigz: f64,
        p: &CvParams,
    ) -> Result<f64> {
        let n = x0.rows + x1.rows;
        let args = vec![
            mat_literal(x0, x0.rows, DX_CAP)?,
            mat_literal(x1, x1.rows, DX_CAP)?,
            mat_literal(z0, z0.rows, DZ_CAP)?,
            mat_literal(z1, z1.rows, DZ_CAP)?,
            scalar_literal(sigx),
            scalar_literal(sigz),
            scalar_literal(p.lambda),
            scalar_literal(p.gamma),
        ];
        self.rt.execute_scalar(&format!("exact_cond_n{n}"), &args)
    }

    /// One marginal fold.
    pub fn fold_marg(&self, x0: &Mat, x1: &Mat, sigx: f64, p: &CvParams) -> Result<f64> {
        let n = x0.rows + x1.rows;
        let args = vec![
            mat_literal(x0, x0.rows, DX_CAP)?,
            mat_literal(x1, x1.rows, DX_CAP)?,
            scalar_literal(sigx),
            scalar_literal(p.lambda),
            scalar_literal(p.gamma),
        ];
        self.rt.execute_scalar(&format!("exact_marg_n{n}"), &args)
    }
}
