//! The AOT-artifact implementations of the score backends:
//!
//! * [`PjrtCvLrKernel`] — `score::cvlr::CvLrKernel` over the
//!   `cvlr_cond_n*` / `cvlr_marg_n*` artifacts (the production hot
//!   path: L1 Pallas Gram products + L2 dumbbell algebra, AOT-compiled);
//! * [`PjrtExactScorer`] — the exact O(n³) CV fold over the
//!   `exact_*` artifacts (the Fig. 1 baseline on the same runtime).
//!
//! ## Core-fed surrogate factors
//!
//! The artifacts consume *factor matrices* (they start by computing the
//! six Gram cores on device), but the fold-core provider
//! (`score::cores`) hands this kernel precomputed m×m cores. The two
//! meet through **surrogate factors**: the score depends on the factors
//! only through their Gram cores (the rotation-invariance property), so
//! any matrices reproducing the cores give the identical score. For a
//! conditional fold, stack the train cores into the PSD matrix
//!
//! ```text
//!   M₁ = [[F, E], [Eᵀ, P]]           ((mz+mx) × (mz+mx))
//! ```
//!
//! factor `M₁ = L·Lᵀ` with the pivoted semidefinite Cholesky
//! (`linalg::psd_factor`), and split `W = Lᵀ` by columns into
//! `Λ̃_z₁' | Λ̃ₓ₁'` — r ≤ mz+mx rows whose on-device Gram products are
//! exactly F, E, P (same for the test side from `[[S, U], [Uᵀ, V]]`).
//! The true n₀/n₁ travel as scalars (as they always did), and zero
//! row-padding is exact, so the artifact's algebra is unchanged while
//! the per-fold transfer shrinks from O(n·m) factor literals to O(m²)
//! surrogates — the device never sees the sample dimension at all.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::{mat_literal, scalar_literal, xla, Runtime, DX_CAP, DZ_CAP};
use crate::linalg::{psd_factor, Mat};
use crate::score::cvlr::{CondCores, CvLrKernel, MargCores};
use crate::score::folds::CvParams;

/// Pivot threshold of the surrogate factorization: relative to the
/// largest core diagonal, far below the 1e-9 agreement the runtime
/// integration tests pin, far above rounding dust.
const SURROGATE_TOL: f64 = 1e-14;

/// Columns `lo..hi` of a matrix.
fn cols_range(m: &Mat, lo: usize, hi: usize) -> Mat {
    let mut out = Mat::zeros(m.rows, hi - lo);
    for r in 0..m.rows {
        out.row_mut(r).copy_from_slice(&m.row(r)[lo..hi]);
    }
    out
}

/// Stack self/cross cores into the PSD block matrix [[zz, zx], [zxᵀ, xx]].
fn stack_cores(zz: &Mat, zx: &Mat, xx: &Mat) -> Mat {
    let (mz, mx) = (zz.rows, xx.rows);
    debug_assert_eq!((zx.rows, zx.cols), (mz, mx));
    let t = mz + mx;
    let mut out = Mat::zeros(t, t);
    for i in 0..mz {
        for j in 0..mz {
            out[(i, j)] = zz[(i, j)];
        }
        for j in 0..mx {
            out[(i, mz + j)] = zx[(i, j)];
            out[(mz + j, i)] = zx[(i, j)];
        }
    }
    for i in 0..mx {
        for j in 0..mx {
            out[(mz + i, mz + j)] = xx[(i, j)];
        }
    }
    out
}

/// Surrogate factor pair (z', x') reproducing (zz, zx, xx) as Gram
/// cores: r ≤ mz+mx rows each.
fn surrogate_pair(zz: &Mat, zx: &Mat, xx: &Mat) -> (Mat, Mat) {
    let mz = zz.rows;
    let stacked = stack_cores(zz, zx, xx);
    let l = psd_factor(&stacked, SURROGATE_TOL);
    let w = l.transpose(); // r×(mz+mx), WᵀW = stacked
    (cols_range(&w, 0, mz), cols_range(&w, mz, w.cols))
}

/// Surrogate factor reproducing one self-core: r ≤ m rows.
fn surrogate_self(core: &Mat) -> Mat {
    psd_factor(core, SURROGATE_TOL).transpose()
}

/// CV-LR fold evaluation through the AOT artifacts, fed by the
/// fold-core provider (see the module docs for the surrogate scheme).
///
/// The per-fold entry points pay one runtime dispatch each; the fold
/// *batch* entry points group folds by (row bucket, column bucket) —
/// i.e. by artifact — and submit each group through
/// [`Runtime::execute_scalar_many`], so a whole candidate's folds (and,
/// upstream, a whole GES batch) ride one executor acquisition per
/// artifact instead of one per score.
pub struct PjrtCvLrKernel {
    pub rt: Arc<Runtime>,
}

impl PjrtCvLrKernel {
    pub fn new(rt: Arc<Runtime>) -> Self {
        PjrtCvLrKernel { rt }
    }

    /// Smallest artifact bucket whose train capacity fits `r1` surrogate
    /// rows and whose test capacity (bucket/4) fits `r0`.
    fn bucket_for_rows(&self, r1: usize, r0: usize) -> Result<usize> {
        self.rt
            .cvlr_buckets
            .iter()
            .cloned()
            .find(|&b| b >= r1 && b / 4 >= r0)
            .ok_or_else(|| {
                anyhow!(
                    "no CV-LR bucket fits surrogate rows (train {r1}, test {r0}; have {:?})",
                    self.rt.cvlr_buckets
                )
            })
    }

    #[allow(clippy::too_many_arguments)]
    fn cond_args(
        &self,
        bucket: usize,
        mcap: usize,
        lx0: &Mat,
        lx1: &Mat,
        lz0: &Mat,
        lz1: &Mat,
        n0: f64,
        n1: f64,
        p: &CvParams,
    ) -> Result<Vec<xla::Literal>> {
        let n0_cap = bucket / 4;
        Ok(vec![
            mat_literal(lx0, n0_cap, mcap)?,
            mat_literal(lx1, bucket, mcap)?,
            mat_literal(lz0, n0_cap, mcap)?,
            mat_literal(lz1, bucket, mcap)?,
            scalar_literal(n0),
            scalar_literal(n1),
            scalar_literal(p.lambda),
            scalar_literal(p.gamma),
        ])
    }

    #[allow(clippy::too_many_arguments)]
    fn marg_args(
        &self,
        bucket: usize,
        mcap: usize,
        lx0: &Mat,
        lx1: &Mat,
        n0: f64,
        n1: f64,
        p: &CvParams,
    ) -> Result<Vec<xla::Literal>> {
        let n0_cap = bucket / 4;
        Ok(vec![
            mat_literal(lx0, n0_cap, mcap)?,
            mat_literal(lx1, bucket, mcap)?,
            scalar_literal(n0),
            scalar_literal(n1),
            scalar_literal(p.lambda),
            scalar_literal(p.gamma),
        ])
    }

    /// Surrogates + shape of one conditional fold.
    fn cond_call(&self, c: &CondCores<'_>) -> Result<CondCall> {
        let (lz1, lx1) = surrogate_pair(c.f, c.e, c.p);
        let (lz0, lx0) = surrogate_pair(c.s, c.u, c.v);
        let bucket = self.bucket_for_rows(lx1.rows.max(1), lx0.rows.max(1))?;
        let mcap = self.rt.m_bucket_for(lx1.cols.max(lz1.cols))?;
        Ok(CondCall { lx0, lx1, lz0, lz1, bucket, mcap, n0: c.n0 as f64, n1: c.n1 as f64 })
    }

    /// Surrogates + shape of one marginal fold.
    fn marg_call(&self, c: &MargCores<'_>) -> Result<MargCall> {
        let lx1 = surrogate_self(c.p);
        let lx0 = surrogate_self(c.v);
        let bucket = self.bucket_for_rows(lx1.rows.max(1), lx0.rows.max(1))?;
        let mcap = self.rt.m_bucket_for(lx1.cols)?;
        Ok(MargCall { lx0, lx1, bucket, mcap, n0: c.n0 as f64, n1: c.n1 as f64 })
    }

    fn run_cond_cores(&self, c: &CondCores<'_>, p: &CvParams) -> Result<f64> {
        let call = self.cond_call(c)?;
        let args = self.cond_args(
            call.bucket,
            call.mcap,
            &call.lx0,
            &call.lx1,
            &call.lz0,
            &call.lz1,
            call.n0,
            call.n1,
            p,
        )?;
        self.rt.execute_scalar(&format!("cvlr_cond_n{}_m{}", call.bucket, call.mcap), &args)
    }

    fn run_marg_cores(&self, c: &MargCores<'_>, p: &CvParams) -> Result<f64> {
        let call = self.marg_call(c)?;
        let args = self.marg_args(
            call.bucket,
            call.mcap,
            &call.lx0,
            &call.lx1,
            call.n0,
            call.n1,
            p,
        )?;
        self.rt.execute_scalar(&format!("cvlr_marg_n{}_m{}", call.bucket, call.mcap), &args)
    }

    fn run_cond_batch(&self, folds: &[CondCores<'_>], p: &CvParams) -> Result<Vec<f64>> {
        let mut out = vec![0.0; folds.len()];
        // surrogates first, then group by artifact shape so each group
        // is one submission
        let calls: Vec<CondCall> =
            folds.iter().map(|c| self.cond_call(c)).collect::<Result<_>>()?;
        let mut groups: HashMap<(usize, usize), Vec<usize>> = HashMap::new();
        for (i, call) in calls.iter().enumerate() {
            groups.entry((call.bucket, call.mcap)).or_default().push(i);
        }
        for ((bucket, mcap), idxs) in groups {
            let args: Vec<Vec<xla::Literal>> = idxs
                .iter()
                .map(|&i| {
                    let c = &calls[i];
                    self.cond_args(bucket, mcap, &c.lx0, &c.lx1, &c.lz0, &c.lz1, c.n0, c.n1, p)
                })
                .collect::<Result<_>>()?;
            let vals = self.rt.execute_scalar_many(&format!("cvlr_cond_n{bucket}_m{mcap}"), &args)?;
            for (&i, v) in idxs.iter().zip(vals) {
                out[i] = v;
            }
        }
        Ok(out)
    }

    fn run_marg_batch(&self, folds: &[MargCores<'_>], p: &CvParams) -> Result<Vec<f64>> {
        let mut out = vec![0.0; folds.len()];
        let calls: Vec<MargCall> =
            folds.iter().map(|c| self.marg_call(c)).collect::<Result<_>>()?;
        let mut groups: HashMap<(usize, usize), Vec<usize>> = HashMap::new();
        for (i, call) in calls.iter().enumerate() {
            groups.entry((call.bucket, call.mcap)).or_default().push(i);
        }
        for ((bucket, mcap), idxs) in groups {
            let args: Vec<Vec<xla::Literal>> = idxs
                .iter()
                .map(|&i| {
                    let c = &calls[i];
                    self.marg_args(bucket, mcap, &c.lx0, &c.lx1, c.n0, c.n1, p)
                })
                .collect::<Result<_>>()?;
            let vals = self.rt.execute_scalar_many(&format!("cvlr_marg_n{bucket}_m{mcap}"), &args)?;
            for (&i, v) in idxs.iter().zip(vals) {
                out[i] = v;
            }
        }
        Ok(out)
    }
}

/// One prepared conditional artifact call (surrogate factors + shape).
struct CondCall {
    lx0: Mat,
    lx1: Mat,
    lz0: Mat,
    lz1: Mat,
    bucket: usize,
    mcap: usize,
    n0: f64,
    n1: f64,
}

/// One prepared marginal artifact call.
struct MargCall {
    lx0: Mat,
    lx1: Mat,
    bucket: usize,
    mcap: usize,
    n0: f64,
    n1: f64,
}

impl CvLrKernel for PjrtCvLrKernel {
    fn score_cond_cores(&self, c: &CondCores<'_>, p: &CvParams) -> f64 {
        self.run_cond_cores(c, p).expect("PJRT cvlr_cond execution failed")
    }

    fn score_marg_cores(&self, c: &MargCores<'_>, p: &CvParams) -> f64 {
        self.run_marg_cores(c, p).expect("PJRT cvlr_marg execution failed")
    }

    fn score_cond_batch(&self, folds: &[CondCores<'_>], p: &CvParams) -> Vec<f64> {
        self.run_cond_batch(folds, p).expect("PJRT cvlr_cond batch execution failed")
    }

    fn score_marg_batch(&self, folds: &[MargCores<'_>], p: &CvParams) -> Vec<f64> {
        self.run_marg_batch(folds, p).expect("PJRT cvlr_marg batch execution failed")
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Exact-CV fold evaluation through the `exact_*` artifacts. Fold
/// shapes are static per artifact: n must be one of the compiled sizes
/// and divisible by the fold count.
pub struct PjrtExactScorer {
    pub rt: Arc<Runtime>,
}

impl PjrtExactScorer {
    pub fn new(rt: Arc<Runtime>) -> Self {
        PjrtExactScorer { rt }
    }

    /// One conditional fold: raw data blocks (x: ≤8 cols, z: ≤32 cols).
    #[allow(clippy::too_many_arguments)]
    pub fn fold_cond(
        &self,
        x0: &Mat,
        x1: &Mat,
        z0: &Mat,
        z1: &Mat,
        sigx: f64,
        sigz: f64,
        p: &CvParams,
    ) -> Result<f64> {
        let n = x0.rows + x1.rows;
        let args = vec![
            mat_literal(x0, x0.rows, DX_CAP)?,
            mat_literal(x1, x1.rows, DX_CAP)?,
            mat_literal(z0, z0.rows, DZ_CAP)?,
            mat_literal(z1, z1.rows, DZ_CAP)?,
            scalar_literal(sigx),
            scalar_literal(sigz),
            scalar_literal(p.lambda),
            scalar_literal(p.gamma),
        ];
        self.rt.execute_scalar(&format!("exact_cond_n{n}"), &args)
    }

    /// One marginal fold.
    pub fn fold_marg(&self, x0: &Mat, x1: &Mat, sigx: f64, p: &CvParams) -> Result<f64> {
        let n = x0.rows + x1.rows;
        let args = vec![
            mat_literal(x0, x0.rows, DX_CAP)?,
            mat_literal(x1, x1.rows, DX_CAP)?,
            scalar_literal(sigx),
            scalar_literal(p.lambda),
            scalar_literal(p.gamma),
        ];
        self.rt.execute_scalar(&format!("exact_marg_n{n}"), &args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn random_factor(n: usize, m: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let mut f = Mat::zeros(n, m);
        for v in &mut f.data {
            *v = rng.normal();
        }
        f
    }

    /// Surrogate factors reproduce the stacked cores exactly — the
    /// invariant the artifact path rests on (device Gram of surrogates
    /// == host cores). Pure host-side; needs no artifacts.
    #[test]
    fn surrogates_reproduce_cores() {
        let lz = random_factor(60, 3, 1);
        let lx = random_factor(60, 5, 2);
        let f = lz.t_matmul(&lz);
        let e = lz.t_matmul(&lx);
        let p = lx.t_matmul(&lx);
        let (sz, sx) = surrogate_pair(&f, &e, &p);
        assert!(sz.rows <= 8, "surrogate rows bounded by mz+mx (got {})", sz.rows);
        assert_eq!(sz.rows, sx.rows);
        assert!((&sz.t_matmul(&sz) - &f).max_abs() < 1e-8, "F not reproduced");
        assert!((&sz.t_matmul(&sx) - &e).max_abs() < 1e-8, "E not reproduced");
        assert!((&sx.t_matmul(&sx) - &p).max_abs() < 1e-8, "P not reproduced");
        let s = surrogate_self(&p);
        assert!((&s.t_matmul(&s) - &p).max_abs() < 1e-8, "self core not reproduced");
    }

    /// Rank-deficient cores (more columns than samples backing them)
    /// still factor: the pivoted scheme drops the null space.
    #[test]
    fn surrogates_handle_rank_deficiency() {
        let lx = random_factor(4, 9, 3); // rank ≤ 4 core of size 9×9
        let p = lx.t_matmul(&lx);
        let s = surrogate_self(&p);
        // 4 in exact arithmetic; leave one pivot of slack for rounding
        assert!(s.rows <= 5, "rank-deficient core must yield few rows (got {})", s.rows);
        assert!((&s.t_matmul(&s) - &p).max_abs() < 1e-8);
    }
}
