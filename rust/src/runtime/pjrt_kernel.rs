//! The AOT-artifact implementations of the score backends:
//!
//! * [`PjrtCvLrKernel`] — `score::cvlr::CvLrKernel` over the
//!   `cvlr_cond_n*` / `cvlr_marg_n*` artifacts (the production hot
//!   path: L1 Pallas Gram products + L2 dumbbell algebra, AOT-compiled);
//! * [`PjrtExactScorer`] — the exact O(n³) CV fold over the
//!   `exact_*` artifacts (the Fig. 1 baseline on the same runtime).

use std::sync::Arc;

use anyhow::Result;

use super::{mat_literal, scalar_literal, Runtime, DX_CAP, DZ_CAP};
use crate::linalg::Mat;
use crate::score::cvlr::CvLrKernel;
use crate::score::folds::CvParams;

/// CV-LR fold evaluation through the AOT artifacts.
pub struct PjrtCvLrKernel {
    pub rt: Arc<Runtime>,
}

impl PjrtCvLrKernel {
    pub fn new(rt: Arc<Runtime>) -> Self {
        PjrtCvLrKernel { rt }
    }

    fn run_cond(&self, lx0: &Mat, lx1: &Mat, lz0: &Mat, lz1: &Mat, p: &CvParams) -> Result<f64> {
        let bucket = self.rt.bucket_for(lx1.rows)?;
        let mcap = self.rt.m_bucket_for(lx1.cols.max(lz1.cols))?;
        let n0_cap = bucket / 4;
        let args = vec![
            mat_literal(lx0, n0_cap, mcap)?,
            mat_literal(lx1, bucket, mcap)?,
            mat_literal(lz0, n0_cap, mcap)?,
            mat_literal(lz1, bucket, mcap)?,
            scalar_literal(lx0.rows as f64),
            scalar_literal(lx1.rows as f64),
            scalar_literal(p.lambda),
            scalar_literal(p.gamma),
        ];
        self.rt.execute_scalar(&format!("cvlr_cond_n{bucket}_m{mcap}"), &args)
    }

    fn run_marg(&self, lx0: &Mat, lx1: &Mat, p: &CvParams) -> Result<f64> {
        let bucket = self.rt.bucket_for(lx1.rows)?;
        let mcap = self.rt.m_bucket_for(lx1.cols)?;
        let n0_cap = bucket / 4;
        let args = vec![
            mat_literal(lx0, n0_cap, mcap)?,
            mat_literal(lx1, bucket, mcap)?,
            scalar_literal(lx0.rows as f64),
            scalar_literal(lx1.rows as f64),
            scalar_literal(p.lambda),
            scalar_literal(p.gamma),
        ];
        self.rt.execute_scalar(&format!("cvlr_marg_n{bucket}_m{mcap}"), &args)
    }
}

impl CvLrKernel for PjrtCvLrKernel {
    fn score_cond(&self, lx0: &Mat, lx1: &Mat, lz0: &Mat, lz1: &Mat, p: &CvParams) -> f64 {
        self.run_cond(lx0, lx1, lz0, lz1, p).expect("PJRT cvlr_cond execution failed")
    }

    fn score_marg(&self, lx0: &Mat, lx1: &Mat, p: &CvParams) -> f64 {
        self.run_marg(lx0, lx1, p).expect("PJRT cvlr_marg execution failed")
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Exact-CV fold evaluation through the `exact_*` artifacts. Fold
/// shapes are static per artifact: n must be one of the compiled sizes
/// and divisible by the fold count.
pub struct PjrtExactScorer {
    pub rt: Arc<Runtime>,
}

impl PjrtExactScorer {
    pub fn new(rt: Arc<Runtime>) -> Self {
        PjrtExactScorer { rt }
    }

    /// One conditional fold: raw data blocks (x: ≤8 cols, z: ≤32 cols).
    pub fn fold_cond(
        &self,
        x0: &Mat,
        x1: &Mat,
        z0: &Mat,
        z1: &Mat,
        sigx: f64,
        sigz: f64,
        p: &CvParams,
    ) -> Result<f64> {
        let n = x0.rows + x1.rows;
        let args = vec![
            mat_literal(x0, x0.rows, DX_CAP)?,
            mat_literal(x1, x1.rows, DX_CAP)?,
            mat_literal(z0, z0.rows, DZ_CAP)?,
            mat_literal(z1, z1.rows, DZ_CAP)?,
            scalar_literal(sigx),
            scalar_literal(sigz),
            scalar_literal(p.lambda),
            scalar_literal(p.gamma),
        ];
        self.rt.execute_scalar(&format!("exact_cond_n{n}"), &args)
    }

    /// One marginal fold.
    pub fn fold_marg(&self, x0: &Mat, x1: &Mat, sigx: f64, p: &CvParams) -> Result<f64> {
        let n = x0.rows + x1.rows;
        let args = vec![
            mat_literal(x0, x0.rows, DX_CAP)?,
            mat_literal(x1, x1.rows, DX_CAP)?,
            scalar_literal(sigx),
            scalar_literal(p.lambda),
            scalar_literal(p.gamma),
        ];
        self.rt.execute_scalar(&format!("exact_marg_n{n}"), &args)
    }
}
