//! Observability — the process-wide evidence layer behind the paper's
//! asymptotic claims: where a discover run actually spends its time.
//!
//! Three std-only parts:
//!
//! * [`trace`] — a lock-cheap span recorder at **stage** granularity
//!   (GES sweep → score batch → fold-core Gram build → factorization;
//!   stream append/re-pivot; shard dispatch/retry/hedge), exported as
//!   Chrome trace-event JSON that loads in Perfetto /
//!   `chrome://tracing`. Reached through `GET /v1/trace` and
//!   `cvlr ... --trace-out file.json`. Follower per-batch timings ride
//!   back on `POST /v1/score_batch` replies and merge into the
//!   coordinator trace, so one view shows the whole fleet.
//! * [`metrics`] — a process-global registry of counters, gauges and
//!   log-bucketed latency histograms rendered in Prometheus text
//!   exposition format at `GET /v1/metrics`. Histogram buckets retain
//!   OpenMetrics exemplars linking their latest observation to the
//!   trace span that produced it.
//! * [`mem`] — a tracking global allocator (feature `mem-profile`, on
//!   by default) charging every allocation to the thread's active
//!   stage scope, so `cvlr_mem_live_bytes{scope=…}` /
//!   `cvlr_mem_peak_bytes{scope=…}` prove the paper's O(n) *space*
//!   claim stage by stage.
//!
//! A fourth part rides along for tests only: [`fail`], the failpoint
//! registry behind the (default-off) `fail-inject` feature — named
//! fault-injection sites across the serving stack, used by the chaos
//! suite to prove the retry/hedge/degrade and deadline paths under
//! adversarial schedules.
//!
//! Overhead discipline: with no sink attached (tracing disabled, no
//! capture in flight) every span call site is one relaxed atomic load
//! and an early return — no clock read, no allocation. Metrics are
//! always-on relaxed-atomic bumps, but only at stage granularity (once
//! per batch/build/sweep), never per score. The allocator adds two
//! relaxed adds + two relaxed maxes per alloc and never allocates on
//! its own path.

pub mod fail;
pub mod mem;
pub mod metrics;
pub mod trace;
