//! Observability — the process-wide evidence layer behind the paper's
//! asymptotic claims: where a discover run actually spends its time.
//!
//! Two std-only halves:
//!
//! * [`trace`] — a lock-cheap span recorder at **stage** granularity
//!   (GES sweep → score batch → fold-core Gram build → factorization;
//!   stream append/re-pivot; shard dispatch/retry/hedge), exported as
//!   Chrome trace-event JSON that loads in Perfetto /
//!   `chrome://tracing`. Reached through `GET /v1/trace` and
//!   `cvlr ... --trace-out file.json`. Follower per-batch timings ride
//!   back on `POST /v1/score_batch` replies and merge into the
//!   coordinator trace, so one view shows the whole fleet.
//! * [`metrics`] — a process-global registry of counters, gauges and
//!   log-bucketed latency histograms rendered in Prometheus text
//!   exposition format at `GET /v1/metrics`.
//!
//! Overhead discipline: with no sink attached (tracing disabled, no
//! capture in flight) every span call site is one relaxed atomic load
//! and an early return — no clock read, no allocation. Metrics are
//! always-on relaxed-atomic bumps, but only at stage granularity (once
//! per batch/build/sweep), never per score.

pub mod metrics;
pub mod trace;
