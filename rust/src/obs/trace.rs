//! Lock-cheap span recorder with Chrome trace-event export.
//!
//! Spans are recorded at **stage** granularity (one GES sweep, one
//! score batch, one fold-core build, one factorization, one shard
//! dispatch — never one score) by RAII guards from [`span`]. Guards
//! buffer completed events in a thread-local vector and flush to a
//! bounded global ring under one short lock — either when the buffer
//! grows past [`FLUSH_AT`] or when the thread's span nesting returns to
//! zero, so quiescent threads are always fully flushed.
//!
//! **Cost with no sink attached**: [`span`]/[`instant`] load two
//! relaxed atomics and return — no clock read, no allocation, no lock.
//! A sink is attached either globally ([`enable`], set by `--trace-out`
//! and the first `GET /v1/trace`) or per-thread ([`capture`], used by
//! the follower side of `POST /v1/score_batch` to collect the stage
//! timings of one request without turning global tracing on).
//!
//! **Fleet merge**: follower captures come back over the wire
//! (re-based to the capture start) and re-enter the coordinator's ring
//! through [`record_remote`] with a per-follower synthetic pid from
//! [`remote_pid`], so [`export_json`] renders coordinator and follower
//! stages on one Perfetto timeline.
//!
//! The export is the Chrome trace-event JSON object form
//! (`{"traceEvents": [...]}`) with complete (`ph:"X"`) and instant
//! (`ph:"i"`) events plus `process_name`/`thread_name` metadata —
//! loadable in Perfetto and `chrome://tracing`.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::server::json::Json;

/// Ring-buffer capacity: the oldest events fall off first.
const RING_CAP: usize = 65536;
/// Thread-local buffer size that forces a flush mid-nesting.
const FLUSH_AT: usize = 32;

/// Global sink flag (`--trace-out`, `GET /v1/trace`).
static ENABLED: AtomicBool = AtomicBool::new(false);
/// Number of in-flight per-thread captures; non-zero keeps the span
/// path live even when the global sink is off.
static CAPTURES: AtomicUsize = AtomicUsize::new(0);
/// Trace-local thread-id allocator (small ints, not OS tids).
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
/// Span-id allocator: every recorded span gets a process-unique id so
/// metric exemplars (`# {trace_span="…"}`) can link a histogram bucket
/// to the exact span in the exported trace. 0 means "no id" (inert
/// guards, instants).
static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);

/// One completed trace event. `ts_us` is microseconds since the
/// process trace epoch ([`epoch`]); remote events are re-based by the
/// coordinator before they get here.
#[derive(Clone, Debug)]
pub struct SpanEvent {
    pub name: String,
    pub cat: String,
    pub ts_us: u64,
    /// 0 for instants.
    pub dur_us: u64,
    /// 1 = this process; 2+ = remote followers (see [`remote_pid`]).
    pub pid: u64,
    pub tid: u64,
    /// Chrome phase `i` (instant) instead of `X` (complete span).
    pub instant: bool,
    /// Process-unique span id (0 = none): the exemplar link target,
    /// exported as the `span_id` arg.
    pub id: u64,
    pub args: Vec<(String, String)>,
}

struct CaptureBuf {
    start: Instant,
    events: Vec<SpanEvent>,
}

struct LocalState {
    tid: u64,
    /// Open [`SpanGuard`] nesting depth on this thread.
    depth: usize,
    /// Completed events awaiting a ring flush.
    buf: Vec<SpanEvent>,
    capture: Option<CaptureBuf>,
}

impl LocalState {
    fn new() -> LocalState {
        let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        let name = std::thread::current().name().unwrap_or("thread").to_string();
        thread_names().lock().unwrap().push((tid, name));
        LocalState { tid, depth: 0, buf: Vec::new(), capture: None }
    }
}

thread_local! {
    static LOCAL: RefCell<LocalState> = RefCell::new(LocalState::new());
}

/// The process trace epoch: every local `ts_us` counts from here.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn ring() -> &'static Mutex<VecDeque<SpanEvent>> {
    static RING: OnceLock<Mutex<VecDeque<SpanEvent>>> = OnceLock::new();
    RING.get_or_init(|| Mutex::new(VecDeque::new()))
}

/// (tid, thread name) pairs, in tid-assignment order.
fn thread_names() -> &'static Mutex<Vec<(u64, String)>> {
    static NAMES: OnceLock<Mutex<Vec<(u64, String)>>> = OnceLock::new();
    NAMES.get_or_init(|| Mutex::new(Vec::new()))
}

/// Follower addresses seen by [`remote_pid`], index i ↔ pid i + 2.
fn remote_addrs() -> &'static Mutex<Vec<String>> {
    static ADDRS: OnceLock<Mutex<Vec<String>>> = OnceLock::new();
    ADDRS.get_or_init(|| Mutex::new(Vec::new()))
}

/// Is any sink attached? Two relaxed loads — the entire cost of a
/// disabled span call site.
fn active() -> bool {
    ENABLED.load(Ordering::Relaxed) || CAPTURES.load(Ordering::Relaxed) != 0
}

/// Attach the global sink (idempotent). Pins the epoch so spans that
/// start before the first export still get consistent timestamps.
pub fn enable() {
    let _ = epoch();
    ENABLED.store(true, Ordering::Relaxed);
}

pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

pub fn is_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Drop every buffered event (test isolation).
pub fn clear() {
    ring().lock().unwrap().clear();
}

fn push_ring(batch: Vec<SpanEvent>) {
    let mut r = ring().lock().unwrap();
    for ev in batch {
        if r.len() == RING_CAP {
            r.pop_front();
        }
        r.push_back(ev);
    }
}

/// Route one completed event: into the thread's capture (when one is
/// in flight) and/or the global ring (when enabled).
fn record(ev: SpanEvent) {
    LOCAL.with(|cell| {
        let mut l = cell.borrow_mut();
        let captured = if let Some(cap) = l.capture.as_mut() {
            cap.events.push(ev.clone());
            true
        } else {
            false
        };
        if !ENABLED.load(Ordering::Relaxed) {
            let _ = captured; // capture-only sink: nothing for the ring
            return;
        }
        l.buf.push(ev);
        if l.depth == 0 || l.buf.len() >= FLUSH_AT {
            let batch = std::mem::take(&mut l.buf);
            drop(l);
            push_ring(batch);
        }
    });
}

/// RAII span: records one complete (`ph:"X"`) event on drop. Inert
/// (and cost-free beyond the [`active`] check) with no sink attached.
pub struct SpanGuard {
    live: Option<LiveSpan>,
}

struct LiveSpan {
    name: &'static str,
    cat: &'static str,
    start: Instant,
    id: u64,
    args: Vec<(String, String)>,
}

/// Open a stage span. Drop the guard at the end of the stage.
pub fn span(name: &'static str, cat: &'static str) -> SpanGuard {
    if !active() {
        return SpanGuard { live: None };
    }
    let _ = epoch();
    LOCAL.with(|cell| cell.borrow_mut().depth += 1);
    let id = NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed);
    SpanGuard { live: Some(LiveSpan { name, cat, start: Instant::now(), id, args: Vec::new() }) }
}

impl SpanGuard {
    /// Attach a key/value argument (shown in the Perfetto detail pane).
    pub fn arg(mut self, key: &str, value: impl Into<String>) -> SpanGuard {
        if let Some(live) = self.live.as_mut() {
            live.args.push((key.to_string(), value.into()));
        }
        self
    }

    /// The span's process-unique id, or 0 when no sink is attached —
    /// feed it to [`crate::obs::metrics::Histogram::observe_with_exemplar`]
    /// so the latency bucket links back to this span in the trace.
    pub fn id(&self) -> u64 {
        self.live.as_ref().map(|l| l.id).unwrap_or(0)
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(live) = self.live.take() else { return };
        let dur_us = live.start.elapsed().as_micros() as u64;
        let ts_us = live.start.checked_duration_since(epoch()).unwrap_or_default().as_micros() as u64;
        let (tid, _) = LOCAL.with(|cell| {
            let mut l = cell.borrow_mut();
            l.depth = l.depth.saturating_sub(1);
            (l.tid, ())
        });
        record(SpanEvent {
            name: live.name.to_string(),
            cat: live.cat.to_string(),
            ts_us,
            dur_us,
            pid: 1,
            tid,
            instant: false,
            id: live.id,
            args: live.args,
        });
    }
}

/// Record a zero-duration instant event (`ph:"i"`) — used for
/// point-in-time facts like a hedge firing or a re-pivot.
pub fn instant(name: &'static str, cat: &'static str, args: Vec<(String, String)>) {
    if !active() {
        return;
    }
    let ts_us = epoch().elapsed().as_micros() as u64;
    let tid = LOCAL.with(|cell| cell.borrow().tid);
    record(SpanEvent {
        name: name.to_string(),
        cat: cat.to_string(),
        ts_us,
        dur_us: 0,
        pid: 1,
        tid,
        instant: true,
        id: 0,
        args,
    });
}

/// Microseconds since the trace epoch of an [`Instant`] taken by the
/// caller (used to re-base follower timings at their dispatch time).
pub fn instant_us(t: Instant) -> u64 {
    t.checked_duration_since(epoch()).unwrap_or_default().as_micros() as u64
}

/// A per-thread capture: collects every span completed on this thread
/// until [`Capture::finish`], independent of the global sink. The
/// follower side of `POST /v1/score_batch` wraps its evaluation in one
/// of these to ship stage timings back to the coordinator.
pub struct Capture {
    _not_send: std::marker::PhantomData<*const ()>,
}

/// Start capturing this thread's spans. Keeps the span path live even
/// with global tracing off.
pub fn capture() -> Capture {
    CAPTURES.fetch_add(1, Ordering::Relaxed);
    let _ = epoch();
    LOCAL.with(|cell| {
        cell.borrow_mut().capture = Some(CaptureBuf { start: Instant::now(), events: Vec::new() })
    });
    Capture { _not_send: std::marker::PhantomData }
}

impl Capture {
    /// Stop capturing and return the events, timestamps re-based to
    /// the capture start (wire-friendly: the coordinator re-bases them
    /// again onto its own dispatch time).
    pub fn finish(self) -> Vec<SpanEvent> {
        let buf = LOCAL.with(|cell| cell.borrow_mut().capture.take());
        let Some(buf) = buf else { return Vec::new() };
        let start_us = buf.start.checked_duration_since(epoch()).unwrap_or_default().as_micros()
            as u64;
        buf.events
            .into_iter()
            .map(|mut ev| {
                ev.ts_us = ev.ts_us.saturating_sub(start_us);
                ev
            })
            .collect()
    }
}

impl Drop for Capture {
    fn drop(&mut self) {
        LOCAL.with(|cell| cell.borrow_mut().capture = None);
        CAPTURES.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Stable synthetic pid for a follower address (2, 3, … in first-seen
/// order; pid 1 is this process).
pub fn remote_pid(addr: &str) -> u64 {
    let mut addrs = remote_addrs().lock().unwrap();
    if let Some(i) = addrs.iter().position(|a| a == addr) {
        i as u64 + 2
    } else {
        addrs.push(addr.to_string());
        addrs.len() as u64 + 1
    }
}

/// Merge an already-timed event (a follower stage span, re-based by
/// the caller) straight into the ring. No-op when tracing is off.
pub fn record_remote(ev: SpanEvent) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    push_ring(vec![ev]);
}

fn event_json(ev: &SpanEvent) -> Json {
    let mut fields = vec![
        ("name", Json::str(ev.name.clone())),
        ("cat", Json::str(ev.cat.clone())),
        ("ph", Json::str(if ev.instant { "i" } else { "X" })),
        ("ts", Json::Num(ev.ts_us as f64)),
    ];
    if ev.instant {
        // thread-scoped instant marker
        fields.push(("s", Json::str("t")));
    } else {
        fields.push(("dur", Json::Num(ev.dur_us as f64)));
    }
    fields.push(("pid", Json::Num(ev.pid as f64)));
    fields.push(("tid", Json::Num(ev.tid as f64)));
    // the span id rides in args so Perfetto's detail pane shows the
    // exemplar link target (`cvlr_*_bucket … # {trace_span="id"}`)
    let id_str = (ev.id != 0).then(|| ev.id.to_string());
    if !ev.args.is_empty() || id_str.is_some() {
        let mut args: Vec<(&str, Json)> =
            ev.args.iter().map(|(k, v)| (k.as_str(), Json::str(v.clone()))).collect();
        if let Some(id) = &id_str {
            args.push(("span_id", Json::str(id.clone())));
        }
        fields.push(("args", Json::obj(args)));
    }
    Json::obj(fields)
}

fn metadata_json(name: &str, pid: u64, tid: u64, value: &str) -> Json {
    Json::obj(vec![
        ("name", Json::str(name)),
        ("ph", Json::str("M")),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("args", Json::obj(vec![("name", Json::str(value))])),
    ])
}

/// Snapshot the ring as one Chrome trace-event JSON document
/// (Perfetto/`chrome://tracing` loadable). Metadata events name every
/// process (pid 1 plus each follower) and every thread referenced by
/// at least one event.
pub fn export_json() -> String {
    let events: Vec<SpanEvent> = ring().lock().unwrap().iter().cloned().collect();
    let mut out: Vec<Json> = Vec::with_capacity(events.len() + 16);
    out.push(metadata_json("process_name", 1, 0, "cvlr"));
    for (i, addr) in remote_addrs().lock().unwrap().iter().enumerate() {
        out.push(metadata_json(
            "process_name",
            i as u64 + 2,
            0,
            &format!("follower {addr}"),
        ));
    }
    // thread_name metadata for every (pid, tid) the events reference:
    // recorded names for local threads, a generic label for remote ones
    let names = thread_names().lock().unwrap().clone();
    let mut seen: Vec<(u64, u64)> = Vec::new();
    for ev in &events {
        if !seen.contains(&(ev.pid, ev.tid)) {
            seen.push((ev.pid, ev.tid));
        }
    }
    seen.sort_unstable();
    for (pid, tid) in seen {
        let label = if pid == 1 {
            names
                .iter()
                .find(|(t, _)| *t == tid)
                .map(|(_, n)| n.clone())
                .unwrap_or_else(|| format!("thread {tid}"))
        } else {
            format!("worker {tid}")
        };
        out.push(metadata_json("thread_name", pid, tid, &label));
    }
    out.extend(events.iter().map(event_json));
    Json::obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", Json::str("ms")),
    ])
    .encode()
}

/// The recorder is process-global: any unit test that toggles
/// [`enable`]/[`disable`] or reads the ring must hold this lock so
/// parallel tests cannot see each other's events (server `/v1/trace`
/// tests share it too).
#[cfg(test)]
pub(crate) fn test_lock() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::json;

    fn events_of(doc: &Json) -> Vec<Json> {
        doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array").to_vec()
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let _guard = test_lock().lock().unwrap();
        disable();
        clear();
        {
            let _s = span("test-never-recorded", "test");
        }
        instant("test-never-recorded-instant", "test", Vec::new());
        let doc = json::parse(&export_json()).unwrap();
        assert!(
            !events_of(&doc).iter().any(|e| {
                e.get("name").and_then(Json::as_str).is_some_and(|n| n.starts_with("test-never"))
            }),
            "no sink attached: nothing may be recorded"
        );
    }

    #[test]
    fn spans_export_as_complete_events_with_metadata() {
        let _guard = test_lock().lock().unwrap();
        disable();
        clear();
        enable();
        {
            let _outer = span("test-outer", "test").arg("k", "v");
            let _inner = span("test-inner", "test");
        }
        instant("test-mark", "test", vec![("why".to_string(), "because".to_string())]);
        disable();
        let doc = json::parse(&export_json()).unwrap();
        let events = events_of(&doc);
        let find = |name: &str| {
            events
                .iter()
                .find(|e| e.get("name").and_then(Json::as_str) == Some(name))
                .unwrap_or_else(|| panic!("event `{name}` missing"))
                .clone()
        };
        let outer = find("test-outer");
        let inner = find("test-inner");
        for ev in [&outer, &inner] {
            assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"), "complete span");
            assert!(ev.get("dur").and_then(Json::as_u64).is_some());
            assert!(ev.get("ts").and_then(Json::as_u64).is_some());
        }
        // inner nests inside outer on the same thread
        assert_eq!(outer.get("tid").unwrap(), inner.get("tid").unwrap());
        let (o_ts, o_dur) = (
            outer.get("ts").and_then(Json::as_u64).unwrap(),
            outer.get("dur").and_then(Json::as_u64).unwrap(),
        );
        let i_ts = inner.get("ts").and_then(Json::as_u64).unwrap();
        assert!(o_ts <= i_ts && i_ts <= o_ts + o_dur, "inner starts inside outer");
        assert_eq!(
            outer.get("args").and_then(|a| a.get("k")).and_then(Json::as_str),
            Some("v")
        );
        let mark = find("test-mark");
        assert_eq!(mark.get("ph").and_then(Json::as_str), Some("i"));
        // every referenced (pid, tid) has thread_name metadata
        for ev in &events {
            if ev.get("ph").and_then(Json::as_str) != Some("X") {
                continue;
            }
            let pid = ev.get("pid").and_then(Json::as_u64).unwrap();
            let tid = ev.get("tid").and_then(Json::as_u64).unwrap();
            assert!(
                events.iter().any(|m| {
                    m.get("ph").and_then(Json::as_str) == Some("M")
                        && m.get("name").and_then(Json::as_str) == Some("thread_name")
                        && m.get("pid").and_then(Json::as_u64) == Some(pid)
                        && m.get("tid").and_then(Json::as_u64) == Some(tid)
                }),
                "thread ({pid},{tid}) must carry thread_name metadata"
            );
        }
        clear();
    }

    #[test]
    fn span_ids_are_unique_and_exported() {
        let _guard = test_lock().lock().unwrap();
        disable();
        clear();
        // inert guards carry no id
        assert_eq!(span("test-inert", "test").id(), 0);
        enable();
        let (id_a, id_b);
        {
            let a = span("test-id-a", "test");
            id_a = a.id();
        }
        {
            let b = span("test-id-b", "test");
            id_b = b.id();
        }
        disable();
        assert!(id_a != 0 && id_b != 0 && id_a != id_b, "live spans get distinct nonzero ids");
        let doc = json::parse(&export_json()).unwrap();
        let events = events_of(&doc);
        for (name, id) in [("test-id-a", id_a), ("test-id-b", id_b)] {
            let ev = events
                .iter()
                .find(|e| e.get("name").and_then(Json::as_str) == Some(name))
                .unwrap_or_else(|| panic!("event `{name}` missing"));
            assert_eq!(
                ev.get("args").and_then(|a| a.get("span_id")).and_then(Json::as_str),
                Some(id.to_string().as_str()),
                "span id must be exported in args"
            );
        }
        clear();
    }

    #[test]
    fn capture_collects_thread_events_rebased_without_global_sink() {
        let _guard = test_lock().lock().unwrap();
        disable();
        clear();
        let cap = capture();
        {
            let _s = span("test-captured", "test");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let events = cap.finish();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].name, "test-captured");
        assert!(events[0].dur_us >= 1000, "the 2ms sleep is inside the span");
        assert!(events[0].ts_us < 1_000_000, "timestamps are re-based to the capture start");
        // the global ring stayed empty — the sink was never attached
        let doc = json::parse(&export_json()).unwrap();
        assert!(!events_of(&doc)
            .iter()
            .any(|e| e.get("name").and_then(Json::as_str) == Some("test-captured")));
    }

    #[test]
    fn remote_events_merge_under_their_follower_pid() {
        let _guard = test_lock().lock().unwrap();
        disable();
        clear();
        enable();
        let pid = remote_pid("127.0.0.1:7991");
        assert!(pid >= 2);
        assert_eq!(remote_pid("127.0.0.1:7991"), pid, "pid is stable per address");
        record_remote(SpanEvent {
            name: "test-remote-build".to_string(),
            cat: "score".to_string(),
            ts_us: 100,
            dur_us: 50,
            pid,
            tid: 1,
            instant: false,
            id: 0,
            args: Vec::new(),
        });
        disable();
        let doc = json::parse(&export_json()).unwrap();
        let events = events_of(&doc);
        let ev = events
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("test-remote-build"))
            .expect("merged remote event");
        assert_eq!(ev.get("pid").and_then(Json::as_u64), Some(pid));
        // the follower process is named in metadata
        assert!(events.iter().any(|m| {
            m.get("ph").and_then(Json::as_str) == Some("M")
                && m.get("name").and_then(Json::as_str) == Some("process_name")
                && m.get("pid").and_then(Json::as_u64) == Some(pid)
        }));
        clear();
    }
}
