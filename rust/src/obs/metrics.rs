//! Process-global metrics registry: counters, gauges, and log-bucketed
//! latency histograms, rendered in Prometheus text exposition format
//! (`GET /v1/metrics`).
//!
//! Design points:
//!
//! * **Always on, stage-granular.** Instrumented code bumps a relaxed
//!   atomic once per *stage* (a score batch, a fold-core build, a
//!   sweep) — never per score — so the registry needs no enable flag.
//! * **Log-2 latency buckets.** [`latency_edges`] spans 1 µs … ~134 s
//!   in powers of two; p50/p95/p99 are derivable from the cumulative
//!   bucket counts ([`Histogram::quantile`]) without storing samples.
//! * **Get-or-register.** [`counter`]/[`gauge`]/[`histogram`] return
//!   the existing series under the same name, so call sites just ask
//!   for their handle; [`register_defaults`] pre-creates every
//!   well-known series so a scrape sees the full schema even before
//!   traffic arrives.
//!
//! Naming scheme: `cvlr_<subsystem>_<what>[_total|_seconds]` —
//! counters end in `_total`, latency histograms in `_seconds`.
//!
//! Two extensions on the base schema:
//!
//! * **Labeled gauge families** ([`set_labeled_gauge`]) — one family
//!   name, many `{label="value"}` series, last-write-wins per series.
//!   Used by `obs::mem` for the per-scope memory gauges
//!   (`cvlr_mem_live_bytes{scope=…}`) and the fleet-federation stale
//!   markers.
//! * **Exemplars** ([`Histogram::observe_with_exemplar`]) — each
//!   bucket retains the trace span id of its most recent observation
//!   and renders it as an OpenMetrics exemplar
//!   (`… # {trace_span="17"} 0.53`), so a fat latency bucket links
//!   straight to the span in the Chrome trace that caused it. Only
//!   observations that carry a span id (tracing active) leave
//!   exemplars; a quiet registry renders byte-identical to before.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

use crate::util::lockorder::Mutex;

/// A monotonically-increasing counter (name it `*_total`).
pub struct Counter {
    help: &'static str,
    value: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// A last-write-wins f64 gauge (value stored as bits in an atomic).
pub struct Gauge {
    help: &'static str,
    bits: AtomicU64,
}

impl Gauge {
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// A histogram over fixed upper-bound edges (ascending), with an
/// implicit `+Inf` bucket at the end. Buckets are **le-inclusive**,
/// matching Prometheus: a value exactly on an edge lands in that edge's
/// bucket. The running sum is a CAS loop over f64 bits; everything else
/// is relaxed atomics.
pub struct Histogram {
    help: &'static str,
    edges: Vec<f64>,
    /// `edges.len() + 1` buckets; the last one is `+Inf`.
    buckets: Vec<AtomicU64>,
    /// Per-bucket exemplar: (trace span id, observed value bits) of the
    /// bucket's most recent id-carrying observation; id 0 = none. Two
    /// independent relaxed stores — a racing reader can pair an id with
    /// the value of a neighboring observation in the *same bucket*,
    /// which is within the bucket's factor-of-2 resolution anyway.
    exemplars: Vec<(AtomicU64, AtomicU64)>,
    sum_bits: AtomicU64,
    count: AtomicU64,
}

/// One retained bucket exemplar: the observed value and the trace span
/// that produced it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Exemplar {
    pub span_id: u64,
    pub value: f64,
}

impl Histogram {
    fn new(help: &'static str, edges: Vec<f64>) -> Histogram {
        let buckets = (0..=edges.len()).map(|_| AtomicU64::new(0)).collect();
        let exemplars =
            (0..=edges.len()).map(|_| (AtomicU64::new(0), AtomicU64::new(0))).collect();
        Histogram {
            help,
            edges,
            buckets,
            exemplars,
            sum_bits: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    /// Bucket index a value lands in (`edges.len()` = the `+Inf`
    /// bucket). Exposed so the boundary semantics are unit-testable.
    pub fn bucket_index(&self, v: f64) -> usize {
        self.edges.iter().position(|&e| v <= e).unwrap_or(self.edges.len())
    }

    pub fn observe(&self, v: f64) {
        self.buckets[self.bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Observe a duration in seconds (alias that reads better at call
    /// sites timing stages).
    pub fn observe_secs(&self, secs: f64) {
        self.observe(secs);
    }

    /// Observe a value and, when `span_id` is nonzero (tracing was
    /// active at the call site), retain it as the bucket's exemplar —
    /// most recent wins. `span_id == 0` degrades to a plain
    /// [`Histogram::observe`].
    pub fn observe_with_exemplar(&self, v: f64, span_id: u64) {
        self.observe(v);
        if span_id != 0 {
            let (id, bits) = &self.exemplars[self.bucket_index(v)];
            bits.store(v.to_bits(), Ordering::Relaxed);
            id.store(span_id, Ordering::Relaxed);
        }
    }

    /// The retained exemplar of bucket `i` (`edges.len()` = `+Inf`),
    /// if any observation with a span id ever landed there.
    pub fn exemplar(&self, i: usize) -> Option<Exemplar> {
        let (id, bits) = &self.exemplars[i];
        let span_id = id.load(Ordering::Relaxed);
        (span_id != 0)
            .then(|| Exemplar { span_id, value: f64::from_bits(bits.load(Ordering::Relaxed)) })
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    /// Upper bounds, ascending (without the implicit `+Inf`).
    pub fn edges(&self) -> &[f64] {
        &self.edges
    }

    /// Per-bucket (non-cumulative) counts, `+Inf` last.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Quantile estimate from the buckets: the upper edge of the bucket
    /// holding the q-th sample (`+Inf` reported as `f64::INFINITY`,
    /// empty histograms as 0). The resolution is the bucket width — a
    /// factor of 2 for [`latency_edges`] — which is what makes p50/p95
    /// derivable without storing samples.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                return self.edges.get(i).copied().unwrap_or(f64::INFINITY);
            }
        }
        f64::INFINITY
    }
}

/// Log-2 latency edges: `1e-6 · 2^i` for i = 0..28 (1 µs … ~134 s).
pub fn latency_edges() -> Vec<f64> {
    (0..28).map(|i| 1e-6 * (1u64 << i) as f64).collect()
}

/// One labeled gauge family: shared help text, one last-write-wins
/// value per rendered label set (the BTreeMap key is the canonical
/// `label="value",…` string, so rendering is deterministic).
struct LabeledFamily {
    help: &'static str,
    series: BTreeMap<String, f64>,
}

struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    labeled_gauges: Mutex<BTreeMap<String, LabeledFamily>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(|| Registry {
        counters: Mutex::new("metrics.counters", BTreeMap::new()),
        gauges: Mutex::new("metrics.gauges", BTreeMap::new()),
        labeled_gauges: Mutex::new("metrics.labeled_gauges", BTreeMap::new()),
        histograms: Mutex::new("metrics.histograms", BTreeMap::new()),
    })
}

/// Get or register a counter. The first registration's help text wins.
pub fn counter(name: &str, help: &'static str) -> Arc<Counter> {
    registry()
        .counters
        .lock()
        .entry(name.to_string())
        .or_insert_with(|| Arc::new(Counter { help, value: AtomicU64::new(0) }))
        .clone()
}

/// Get or register a gauge.
pub fn gauge(name: &str, help: &'static str) -> Arc<Gauge> {
    registry()
        .gauges
        .lock()
        .entry(name.to_string())
        .or_insert_with(|| Arc::new(Gauge { help, bits: AtomicU64::new(0.0f64.to_bits()) }))
        .clone()
}

/// Escape a Prometheus label value (backslash, quote, newline).
fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Canonical `key="value",…` rendering of a label set.
fn render_labels(labels: &[(&str, &str)]) -> String {
    labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect::<Vec<_>>()
        .join(",")
}

/// Set one series of a labeled gauge family, registering the family on
/// first use (its help text wins). Series are last-write-wins and
/// persist until overwritten — callers re-set them at snapshot time
/// (`obs::mem::publish`, the fleet scrape), so a scrape always sees
/// the latest value.
pub fn set_labeled_gauge(name: &str, help: &'static str, labels: &[(&str, &str)], v: f64) {
    let mut families = registry().labeled_gauges.lock();
    let fam = families
        .entry(name.to_string())
        .or_insert_with(|| LabeledFamily { help, series: BTreeMap::new() });
    fam.series.insert(render_labels(labels), v);
}

/// Get or register a latency histogram over [`latency_edges`].
pub fn histogram(name: &str, help: &'static str) -> Arc<Histogram> {
    histogram_with_edges(name, help, latency_edges())
}

/// Get or register a histogram with explicit edges (ascending upper
/// bounds; `+Inf` is implicit). An existing series under the same name
/// is returned as-is, edges and all.
pub fn histogram_with_edges(name: &str, help: &'static str, edges: Vec<f64>) -> Arc<Histogram> {
    registry()
        .histograms
        .lock()
        .entry(name.to_string())
        .or_insert_with(|| Arc::new(Histogram::new(help, edges)))
        .clone()
}

// ---- the well-known series -------------------------------------------------
//
// Instrumented modules fetch their handle through these accessors, and
// `register_defaults` touches every one so `/v1/metrics` exposes the
// full schema from the first scrape.

/// Latency of one memo-missing score-batch evaluation
/// (`ScoreService`), batch and scalar paths alike.
pub fn score_batch_seconds() -> Arc<Histogram> {
    histogram("cvlr_score_batch_seconds", "seconds evaluating one score-service batch of misses")
}

/// Latency of one GES sweep iteration (forward or backward).
pub fn ges_sweep_seconds() -> Arc<Histogram> {
    histogram("cvlr_ges_sweep_seconds", "seconds per GES sweep iteration (collect + score + apply)")
}

/// Latency of one downdated fold-core build (`SetCores::build`).
pub fn fold_core_build_seconds() -> Arc<Histogram> {
    histogram("cvlr_fold_core_build_seconds", "seconds per downdated fold-core build of one set")
}

/// Latency of one low-rank factorization (`lowrank::factorize`).
pub fn factorize_seconds() -> Arc<Histogram> {
    histogram("cvlr_factorize_seconds", "seconds per low-rank kernel factorization")
}

/// Latency of one streaming chunk append (`StreamBackend::append`).
pub fn stream_append_seconds() -> Arc<Histogram> {
    histogram("cvlr_stream_append_seconds", "seconds per streaming chunk append across states")
}

pub fn requests_total() -> Arc<Counter> {
    counter("cvlr_requests_total", "score requests seen by score services")
}

pub fn cache_hits_total() -> Arc<Counter> {
    counter("cvlr_cache_hits_total", "score requests answered from the memo cache")
}

pub fn evaluations_total() -> Arc<Counter> {
    counter("cvlr_evaluations_total", "score requests evaluated by a backend")
}

pub fn dedup_skips_total() -> Arc<Counter> {
    counter("cvlr_dedup_skips_total", "duplicate in-batch score requests skipped")
}

pub fn shard_dispatches_total() -> Arc<Counter> {
    counter("cvlr_shard_dispatches_total", "sub-batches dispatched to followers")
}

pub fn shard_retries_total() -> Arc<Counter> {
    counter("cvlr_shard_retries_total", "sub-batch re-dispatches after a failure")
}

pub fn shard_hedges_total() -> Arc<Counter> {
    counter("cvlr_shard_hedges_total", "straggler sub-batches hedged to a second follower")
}

pub fn shard_degraded_total() -> Arc<Counter> {
    counter("cvlr_shard_degraded_total", "sub-batches degraded to local scoring")
}

pub fn shard_failures_total() -> Arc<Counter> {
    counter("cvlr_shard_failures_total", "failed follower requests (timeouts, errors)")
}

pub fn stream_repivots_total() -> Arc<Counter> {
    counter("cvlr_stream_repivots_total", "full re-pivots forced by the appended-residual budget")
}

pub fn shed_total() -> Arc<Counter> {
    counter("cvlr_shed_total", "work refused or caches dropped by overload protection")
}

pub fn deadline_exceeded_total() -> Arc<Counter> {
    counter("cvlr_deadline_exceeded_total", "requests or jobs that ran out of deadline budget")
}

/// Every metric family the crate exposes, in one place. `cvlr lint`
/// cross-checks this list against the `cvlr_*` string literals in
/// `obs/` and `server/mod.rs`: a literal must equal an entry, or start
/// with an entry that ends in `_` (a declared dynamic-suffix family,
/// e.g. `cvlr_jobs_<state>`). Registering a metric without declaring
/// it here fails CI — the list is the schema reviewers audit.
pub const DECLARED_METRICS: &[&str] = &[
    // stage latency histograms
    "cvlr_score_batch_seconds",
    "cvlr_ges_sweep_seconds",
    "cvlr_fold_core_build_seconds",
    "cvlr_factorize_seconds",
    "cvlr_stream_append_seconds",
    // service counters
    "cvlr_requests_total",
    "cvlr_cache_hits_total",
    "cvlr_evaluations_total",
    "cvlr_dedup_skips_total",
    "cvlr_shard_dispatches_total",
    "cvlr_shard_retries_total",
    "cvlr_shard_hedges_total",
    "cvlr_shard_degraded_total",
    "cvlr_shard_failures_total",
    "cvlr_stream_repivots_total",
    "cvlr_shed_total",
    "cvlr_deadline_exceeded_total",
    // `/v1/stats` snapshot gauges folded in by `server::get_metrics`
    "cvlr_services",
    "cvlr_service_cache_entries",
    "cvlr_service_cache_bytes",
    "cvlr_service_core_cache_entries",
    "cvlr_service_core_cache_bytes",
    "cvlr_service_evictions",
    "cvlr_service_invalidations",
    "cvlr_service_warm_start_hits",
    "cvlr_service_eval_seconds",
    "cvlr_followers",
    "cvlr_followers_healthy",
    "cvlr_datasets",
    "cvlr_jobs_", // one gauge per job lifecycle state
    // fleet federation
    "cvlr_fleet_scrape_stale",
    // memory accounting (`obs::mem`)
    "cvlr_mem_live_bytes",
    "cvlr_mem_peak_bytes",
    "cvlr_mem_process_live_bytes",
    "cvlr_mem_process_peak_bytes",
];

/// Touch every well-known series so the exposition carries the full
/// schema even before any traffic. Called by the `/v1/metrics` handler.
pub fn register_defaults() {
    let _ = score_batch_seconds();
    let _ = ges_sweep_seconds();
    let _ = fold_core_build_seconds();
    let _ = factorize_seconds();
    let _ = stream_append_seconds();
    let _ = requests_total();
    let _ = cache_hits_total();
    let _ = evaluations_total();
    let _ = dedup_skips_total();
    let _ = shard_dispatches_total();
    let _ = shard_retries_total();
    let _ = shard_hedges_total();
    let _ = shard_degraded_total();
    let _ = shard_failures_total();
    let _ = stream_repivots_total();
    let _ = shed_total();
    let _ = deadline_exceeded_total();
}

/// Render the registry in Prometheus text exposition format
/// (deterministic: series sorted by name, counters → gauges → labeled
/// gauge families → histograms). Histogram buckets are cumulative with
/// `le` labels and a final `+Inf`, followed by `_sum` and `_count`;
/// buckets that retained an exemplar append the OpenMetrics
/// `# {trace_span="…"} value` suffix.
pub fn render() -> String {
    let reg = registry();
    let mut out = String::new();
    for (name, c) in reg.counters.lock().iter() {
        out.push_str(&format!("# HELP {name} {}\n# TYPE {name} counter\n", c.help));
        out.push_str(&format!("{name} {}\n", c.get()));
    }
    for (name, g) in reg.gauges.lock().iter() {
        out.push_str(&format!("# HELP {name} {}\n# TYPE {name} gauge\n", g.help));
        out.push_str(&format!("{name} {}\n", g.get()));
    }
    for (name, fam) in reg.labeled_gauges.lock().iter() {
        out.push_str(&format!("# HELP {name} {}\n# TYPE {name} gauge\n", fam.help));
        for (labels, v) in &fam.series {
            out.push_str(&format!("{name}{{{labels}}} {v}\n"));
        }
    }
    for (name, h) in reg.histograms.lock().iter() {
        out.push_str(&format!("# HELP {name} {}\n# TYPE {name} histogram\n", h.help));
        let counts = h.bucket_counts();
        let mut cum = 0u64;
        for (i, (edge, count)) in h.edges.iter().zip(&counts).enumerate() {
            cum += count;
            out.push_str(&format!("{name}_bucket{{le=\"{edge}\"}} {cum}"));
            push_exemplar(&mut out, h, i);
            out.push('\n');
        }
        cum += counts.last().copied().unwrap_or(0);
        out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {cum}"));
        push_exemplar(&mut out, h, h.edges.len());
        out.push('\n');
        out.push_str(&format!("{name}_sum {}\n", h.sum()));
        out.push_str(&format!("{name}_count {}\n", h.count()));
    }
    out
}

/// Append the OpenMetrics exemplar suffix of bucket `i`, if one was
/// retained: ` # {trace_span="17"} 0.53`.
fn push_exemplar(out: &mut String, h: &Histogram, i: usize) {
    if let Some(ex) = h.exemplar(i) {
        out.push_str(&format!(" # {{trace_span=\"{}\"}} {}", ex.span_id, ex.value));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_default_series_is_declared() {
        register_defaults();
        for line in render().lines() {
            let Some(name) = line.strip_prefix("# HELP ").and_then(|r| r.split(' ').next())
            else {
                continue;
            };
            if !name.starts_with("cvlr_") {
                continue; // other tests register `test_*` series
            }
            let declared = DECLARED_METRICS.iter().any(|d| {
                name == *d || (d.ends_with('_') && name.starts_with(d))
            });
            assert!(declared, "rendered series `{name}` missing from DECLARED_METRICS");
        }
    }

    #[test]
    fn bucket_boundaries_are_le_inclusive() {
        let h = Histogram::new("test", vec![0.001, 0.01, 0.1]);
        // a value exactly on an edge belongs to that edge's bucket
        assert_eq!(h.bucket_index(0.001), 0);
        assert_eq!(h.bucket_index(0.01), 1);
        assert_eq!(h.bucket_index(0.1), 2);
        // zero (and anything below the first edge) lands in bucket 0
        assert_eq!(h.bucket_index(0.0), 0);
        assert_eq!(h.bucket_index(1e-300), 0);
        // just past an edge spills into the next bucket
        assert_eq!(h.bucket_index(0.0100000001), 2);
        // huge values land in the implicit +Inf bucket
        assert_eq!(h.bucket_index(1e9), 3);
        assert_eq!(h.bucket_index(f64::INFINITY), 3);
    }

    #[test]
    fn latency_edges_are_exact_powers_of_two_microseconds() {
        let edges = latency_edges();
        assert_eq!(edges.len(), 28);
        assert_eq!(edges[0], 1e-6);
        // power-of-two scaling is exact in f64, so a value computed the
        // same way observes into its own edge bucket
        let h = Histogram::new("test", edges.clone());
        for (i, &e) in edges.iter().enumerate() {
            assert_eq!(h.bucket_index(e), i, "edge {e} must be le-inclusive");
            assert_eq!(e, 1e-6 * (1u64 << i) as f64);
        }
        assert!(edges[27] > 100.0, "top edge covers >100s stages");
    }

    #[test]
    fn observe_tracks_sum_count_and_quantiles() {
        let h = Histogram::new("test", vec![0.1, 1.0, 10.0]);
        for v in [0.05, 0.05, 0.05, 0.5, 0.5, 0.5, 0.5, 0.5, 5.0, 100.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 10);
        assert_eq!(h.bucket_counts(), vec![3, 5, 1, 1]);
        assert!((h.sum() - (0.15 + 2.5 + 105.0)).abs() < 1e-12);
        // quantiles resolve to bucket upper edges
        assert_eq!(h.quantile(0.5), 1.0, "5th sample sits in the le=1 bucket");
        assert_eq!(h.quantile(0.9), 10.0);
        assert_eq!(h.quantile(1.0), f64::INFINITY, "the max landed past the last edge");
        let empty = Histogram::new("test", vec![1.0]);
        assert_eq!(empty.quantile(0.5), 0.0);
    }

    #[test]
    fn registry_get_or_register_returns_same_series() {
        let a = counter("test_metrics_same_series_total", "a");
        let b = counter("test_metrics_same_series_total", "b");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3, "both handles hit one underlying counter");
        let g = gauge("test_metrics_same_gauge", "g");
        g.set(2.5);
        assert_eq!(gauge("test_metrics_same_gauge", "g").get(), 2.5);
        let h = histogram("test_metrics_same_seconds", "h");
        h.observe(0.5);
        assert_eq!(histogram("test_metrics_same_seconds", "h").count(), 1);
    }

    #[test]
    fn exemplar_retention_most_recent_wins() {
        let h = Histogram::new("test", vec![0.1, 1.0]);
        assert_eq!(h.exemplar(0), None, "no exemplar before any id-carrying observation");
        h.observe_with_exemplar(0.05, 11);
        assert_eq!(h.exemplar(0), Some(Exemplar { span_id: 11, value: 0.05 }));
        // a later observation in the same bucket replaces the exemplar
        h.observe_with_exemplar(0.0625, 12);
        assert_eq!(h.exemplar(0), Some(Exemplar { span_id: 12, value: 0.0625 }));
        // other buckets are independent; span id 0 leaves no exemplar
        h.observe_with_exemplar(0.5, 13);
        h.observe_with_exemplar(5.0, 0);
        assert_eq!(h.exemplar(1), Some(Exemplar { span_id: 13, value: 0.5 }));
        assert_eq!(h.exemplar(2), None, "id 0 must not be retained");
        assert_eq!(h.count(), 4, "exemplar observations still count");
    }

    #[test]
    fn exemplars_render_as_openmetrics_suffix() {
        let h = histogram_with_edges("test_exemplar_demo_seconds", "demo", vec![0.1, 1.0]);
        h.observe_with_exemplar(0.0625, 17);
        let rendered = render();
        let line = rendered
            .lines()
            .find(|l| l.starts_with("test_exemplar_demo_seconds_bucket{le=\"0.1\"}"))
            .expect("bucket line present");
        assert_eq!(line, "test_exemplar_demo_seconds_bucket{le=\"0.1\"} 1 # {trace_span=\"17\"} 0.0625");
        // buckets without exemplars render exactly as before
        let plain = rendered
            .lines()
            .find(|l| l.starts_with("test_exemplar_demo_seconds_bucket{le=\"1\"}"))
            .unwrap();
        assert_eq!(plain, "test_exemplar_demo_seconds_bucket{le=\"1\"} 1");
        // the exemplar suffix still ends in a numeric token, so naive
        // `rsplit(' ')` value parsers keep working
        let last = line.rsplit(' ').next().unwrap();
        assert!(last.parse::<f64>().is_ok());
    }

    #[test]
    fn labeled_gauges_render_per_series_and_overwrite() {
        set_labeled_gauge("test_labeled_bytes", "labeled demo", &[("scope", "alpha")], 10.0);
        set_labeled_gauge("test_labeled_bytes", "labeled demo", &[("scope", "beta")], 20.0);
        set_labeled_gauge("test_labeled_bytes", "labeled demo", &[("scope", "alpha")], 30.0);
        let rendered = render();
        let block: Vec<&str> =
            rendered.lines().filter(|l| l.contains("test_labeled_bytes")).collect();
        assert_eq!(
            block,
            vec![
                "# HELP test_labeled_bytes labeled demo",
                "# TYPE test_labeled_bytes gauge",
                "test_labeled_bytes{scope=\"alpha\"} 30",
                "test_labeled_bytes{scope=\"beta\"} 20",
            ]
        );
        // label values are escaped
        set_labeled_gauge("test_labeled_esc", "esc", &[("addr", "a\"b\\c")], 1.0);
        assert!(render().contains("test_labeled_esc{addr=\"a\\\"b\\\\c\"} 1"));
    }

    /// Golden exposition block for one histogram (values chosen exactly
    /// representable so the rendered text is deterministic).
    #[test]
    fn prometheus_exposition_golden() {
        let h = histogram_with_edges("test_golden_demo_seconds", "demo histogram", vec![0.1, 1.0]);
        h.observe(0.0625);
        h.observe(0.5);
        h.observe(3.0);
        let rendered = render();
        let block: Vec<&str> =
            rendered.lines().filter(|l| l.contains("test_golden_demo_seconds")).collect();
        assert_eq!(
            block,
            vec![
                "# HELP test_golden_demo_seconds demo histogram",
                "# TYPE test_golden_demo_seconds histogram",
                "test_golden_demo_seconds_bucket{le=\"0.1\"} 1",
                "test_golden_demo_seconds_bucket{le=\"1\"} 2",
                "test_golden_demo_seconds_bucket{le=\"+Inf\"} 3",
                "test_golden_demo_seconds_sum 3.5625",
                "test_golden_demo_seconds_count 3",
            ]
        );
    }

    /// Parse-back round trip: the exposition must be line-parseable
    /// (name{labels} value), histogram buckets cumulative and
    /// consistent with _count.
    #[test]
    fn prometheus_exposition_parses_back() {
        let c = counter("test_parseback_hits_total", "hits");
        c.add(7);
        let h = histogram("test_parseback_lat_seconds", "lat");
        h.observe(0.002);
        h.observe(0.004);
        h.observe(900.0); // +Inf bucket
        let rendered = render();
        let mut counter_val = None;
        let mut buckets: Vec<(String, u64)> = Vec::new();
        let mut count_val = None;
        for line in rendered.lines() {
            if line.starts_with('#') {
                continue;
            }
            let (series, value) = line.rsplit_once(' ').expect("every sample line is `name value`");
            if series == "test_parseback_hits_total" {
                counter_val = Some(value.parse::<u64>().unwrap());
            } else if let Some(rest) = series.strip_prefix("test_parseback_lat_seconds_bucket") {
                let le = rest
                    .strip_prefix("{le=\"")
                    .and_then(|s| s.strip_suffix("\"}"))
                    .expect("bucket lines carry exactly the le label");
                buckets.push((le.to_string(), value.parse().unwrap()));
            } else if series == "test_parseback_lat_seconds_count" {
                count_val = Some(value.parse::<u64>().unwrap());
            }
        }
        assert_eq!(counter_val, Some(7));
        assert_eq!(count_val, Some(3));
        assert_eq!(buckets.len(), latency_edges().len() + 1);
        assert_eq!(buckets.last().unwrap().0, "+Inf");
        assert_eq!(buckets.last().unwrap().1, 3, "+Inf bucket equals _count");
        for w in buckets.windows(2) {
            assert!(w[0].1 <= w[1].1, "cumulative buckets are monotone");
        }
        // the two 2–4ms observations land before 900s does
        let le8ms = buckets.iter().find(|(le, _)| le.starts_with("0.004")).unwrap();
        assert_eq!(le8ms.1, 2);
    }
}
