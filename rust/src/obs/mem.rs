//! Memory accounting — the tracking [`std::alloc::GlobalAlloc`] behind
//! the paper's O(n) **space** claim, with scoped attribution.
//!
//! The bench/metrics stack measures *time* per stage; nothing proved
//! that resident bytes scale linearly in n, or could catch a cache or
//! factor leak silently reintroducing the O(n²) memory the low-rank
//! rules exist to avoid. This module wraps the system allocator
//! (feature `mem-profile`, on by default) and charges every
//! allocation to the **active scope** of the allocating thread — a
//! thread-local stage marker mirroring the span taxonomy
//! (`factorize`, `fold_core_build`, `pair_cores`, `score_batch`,
//! `score_cache`, `dataset`, `stream_append`) — so one
//! `/v1/metrics` scrape answers "where is the memory":
//!
//! ```text
//! cvlr_mem_live_bytes{scope="fold_core_build"} 1.84e6
//! cvlr_mem_peak_bytes{scope="factorize"}       5.4e6
//! ```
//!
//! Discipline on the allocator hot path: **two relaxed atomic adds and
//! two relaxed maxes**, no locks, no clock reads, and — critically —
//! no allocation (the scope marker is a const-initialized
//! `Cell<usize>` thread-local, so reading it never re-enters the
//! allocator). Deallocations are charged to the scope active *at free
//! time*; a buffer allocated in one scope and dropped in another can
//! therefore drive a scope's signed live counter below zero, which the
//! reporting surface clamps to 0 (peaks are monotone within a
//! [`reset_peak`] window either way — attribution is a profile, not a
//! ledger).
//!
//! With the feature off every entry point is a no-op stub and no
//! global allocator is installed.

/// An attribution scope — the memory twin of the span taxonomy. The
/// discriminant indexes the static accounting tables.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum Scope {
    /// No explicit scope active on the thread (the default).
    Unscoped = 0,
    /// Low-rank factorization (`lowrank::factorize`) — factor storage.
    Factorize = 1,
    /// Per-set fold-core Gram builds (`SetCores::build`).
    FoldCoreBuild = 2,
    /// Per-pair cross-core builds (`score::cores::pair_cores`).
    PairCores = 3,
    /// Score-batch evaluation (`ScoreService::score_batch` misses).
    ScoreBatch = 4,
    /// The memoizing score cache (`ScoreCache` fills).
    ScoreCache = 5,
    /// Dataset / registry storage (CSV ingestion, builtins, appends).
    Dataset = 6,
    /// Streaming factor maintenance (`stream::FactorState` appends).
    StreamAppend = 7,
    /// Reserved for unit tests — never entered by library code, so
    /// tests can assert exact deltas without cross-test interference.
    Probe = 8,
}

/// Number of scopes (table size).
pub const SCOPE_COUNT: usize = 9;

/// Every scope in table order.
pub const ALL_SCOPES: [Scope; SCOPE_COUNT] = [
    Scope::Unscoped,
    Scope::Factorize,
    Scope::FoldCoreBuild,
    Scope::PairCores,
    Scope::ScoreBatch,
    Scope::ScoreCache,
    Scope::Dataset,
    Scope::StreamAppend,
    Scope::Probe,
];

impl Scope {
    /// The `scope` label value of the Prometheus series.
    pub fn name(self) -> &'static str {
        match self {
            Scope::Unscoped => "unscoped",
            Scope::Factorize => "factorize",
            Scope::FoldCoreBuild => "fold_core_build",
            Scope::PairCores => "pair_cores",
            Scope::ScoreBatch => "score_batch",
            Scope::ScoreCache => "score_cache",
            Scope::Dataset => "dataset",
            Scope::StreamAppend => "stream_append",
            Scope::Probe => "probe",
        }
    }
}

#[cfg(feature = "mem-profile")]
mod imp {
    use super::{Scope, ALL_SCOPES, SCOPE_COUNT};
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::cell::Cell;
    use std::sync::atomic::{AtomicI64, Ordering::Relaxed};

    // Const items (not statics) so the array-repeat initializer below
    // is legal; each array element is its own atomic.
    #[allow(clippy::declare_interior_mutable_const)]
    const ZERO: AtomicI64 = AtomicI64::new(0);

    /// Signed live bytes per scope. Signed because deallocations are
    /// charged to the scope active at free time (see module docs).
    static LIVE: [AtomicI64; SCOPE_COUNT] = [ZERO; SCOPE_COUNT];
    /// High-water mark of `LIVE` per scope since the last reset.
    static PEAK: [AtomicI64; SCOPE_COUNT] = [ZERO; SCOPE_COUNT];
    /// Process-wide live bytes (always balanced: every free subtracts
    /// exactly what the matching alloc added).
    static G_LIVE: AtomicI64 = AtomicI64::new(0);
    /// Process-wide high-water mark since the last reset.
    static G_PEAK: AtomicI64 = AtomicI64::new(0);

    thread_local! {
        // Const-init: no lazy-init allocation, safe inside the
        // allocator. `try_with` guards against TLS teardown.
        static CURRENT: Cell<usize> = const { Cell::new(0) };
    }

    #[inline]
    fn current_idx() -> usize {
        CURRENT.try_with(Cell::get).unwrap_or(0)
    }

    #[inline]
    fn on_alloc(size: usize) {
        let s = size as i64;
        let now = G_LIVE.fetch_add(s, Relaxed) + s;
        G_PEAK.fetch_max(now, Relaxed);
        let i = current_idx();
        let now = LIVE[i].fetch_add(s, Relaxed) + s;
        PEAK[i].fetch_max(now, Relaxed);
    }

    #[inline]
    fn on_dealloc(size: usize) {
        let s = size as i64;
        G_LIVE.fetch_sub(s, Relaxed);
        LIVE[current_idx()].fetch_sub(s, Relaxed);
    }

    /// The tracking allocator: `System` plus the accounting above.
    pub struct TrackingAlloc;

    // SAFETY: defers every allocation to `System`; the accounting
    // callbacks never allocate (const-init TLS + static atomics).
    unsafe impl GlobalAlloc for TrackingAlloc {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            // SAFETY: caller upholds GlobalAlloc's contract on `layout`;
            // we forward it unchanged to `System`.
            let p = unsafe { System.alloc(layout) };
            if !p.is_null() {
                on_alloc(layout.size());
            }
            p
        }

        unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
            // SAFETY: caller upholds GlobalAlloc's contract on `layout`;
            // we forward it unchanged to `System`.
            let p = unsafe { System.alloc_zeroed(layout) };
            if !p.is_null() {
                on_alloc(layout.size());
            }
            p
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            // SAFETY: caller guarantees `ptr` came from this allocator
            // with this `layout`; we only ever hand out `System` blocks.
            unsafe { System.dealloc(ptr, layout) };
            on_dealloc(layout.size());
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            // SAFETY: caller guarantees `ptr`/`layout` describe a live
            // `System` block and `new_size` is nonzero per the contract.
            let p = unsafe { System.realloc(ptr, layout, new_size) };
            if !p.is_null() {
                on_dealloc(layout.size());
                on_alloc(new_size);
            }
            p
        }
    }

    #[global_allocator]
    static GLOBAL: TrackingAlloc = TrackingAlloc;

    /// RAII scope marker: allocations on this thread are charged to
    /// `scope` until the guard drops (restoring the previous scope, so
    /// scopes nest).
    pub struct MemScope {
        prev: usize,
    }

    impl MemScope {
        pub fn enter(scope: Scope) -> MemScope {
            let prev = CURRENT
                .try_with(|c| {
                    let p = c.get();
                    c.set(scope as usize);
                    p
                })
                .unwrap_or(0);
            MemScope { prev }
        }
    }

    impl Drop for MemScope {
        fn drop(&mut self) {
            let _ = CURRENT.try_with(|c| c.set(self.prev));
        }
    }

    pub fn enabled() -> bool {
        true
    }

    pub fn current_scope() -> Scope {
        ALL_SCOPES[current_idx()]
    }

    fn clamp(v: i64) -> u64 {
        v.max(0) as u64
    }

    /// Process-wide live bytes.
    pub fn live_bytes() -> u64 {
        clamp(G_LIVE.load(Relaxed))
    }

    /// Process-wide high-water mark since the last [`reset_peak`].
    pub fn peak_bytes() -> u64 {
        clamp(G_PEAK.load(Relaxed))
    }

    /// Live bytes attributed to `scope` (clamped at 0 — see module
    /// docs on cross-scope frees).
    pub fn scope_live(scope: Scope) -> u64 {
        clamp(LIVE[scope as usize].load(Relaxed))
    }

    /// High-water mark of `scope` since the last [`reset_peak`].
    pub fn scope_peak(scope: Scope) -> u64 {
        clamp(PEAK[scope as usize].load(Relaxed))
    }

    /// Unclamped signed live counter of `scope` — test instrumentation
    /// (exact deltas survive a negative baseline).
    pub fn scope_live_raw(scope: Scope) -> i64 {
        LIVE[scope as usize].load(Relaxed)
    }

    /// Rebase every high-water mark to the current live level and
    /// return the process-wide live bytes at the reset — the baseline
    /// for a peak-delta measurement window (`peak_bytes() - baseline`
    /// is the window's allocation high-water above what was already
    /// resident).
    pub fn reset_peak() -> u64 {
        for i in 0..SCOPE_COUNT {
            PEAK[i].store(LIVE[i].load(Relaxed), Relaxed);
        }
        let live = G_LIVE.load(Relaxed);
        G_PEAK.store(live, Relaxed);
        clamp(live)
    }

    /// `(scope name, live, peak)` for every scope with nonzero
    /// accounting, plus the process totals under the pseudo-scope
    /// names used by [`publish`].
    pub fn snapshot() -> Vec<(&'static str, u64, u64)> {
        ALL_SCOPES
            .iter()
            .filter_map(|&s| {
                let (live, peak) = (scope_live(s), scope_peak(s));
                (live > 0 || peak > 0).then(|| (s.name(), live, peak))
            })
            .collect()
    }

    /// Write the accounting into the metrics registry:
    /// `cvlr_mem_live_bytes{scope=…}` / `cvlr_mem_peak_bytes{scope=…}`
    /// per active scope, plus the process-wide
    /// `cvlr_mem_process_live_bytes` / `cvlr_mem_process_peak_bytes`
    /// gauges. Called at scrape/snapshot time (`GET /v1/metrics`,
    /// `--metrics-out`), not on the allocation path.
    pub fn publish() {
        use crate::obs::metrics;
        for (name, live, peak) in snapshot() {
            metrics::set_labeled_gauge(
                "cvlr_mem_live_bytes",
                "Live heap bytes attributed to each allocation scope.",
                &[("scope", name)],
                live as f64,
            );
            metrics::set_labeled_gauge(
                "cvlr_mem_peak_bytes",
                "High-water heap bytes per allocation scope since the last reset.",
                &[("scope", name)],
                peak as f64,
            );
        }
        metrics::gauge(
            "cvlr_mem_process_live_bytes",
            "Process-wide live heap bytes (tracking allocator).",
        )
        .set(live_bytes() as f64);
        metrics::gauge(
            "cvlr_mem_process_peak_bytes",
            "Process-wide high-water heap bytes since the last reset.",
        )
        .set(peak_bytes() as f64);
    }
}

#[cfg(not(feature = "mem-profile"))]
mod imp {
    //! No-op stubs: same surface, zero cost, no global allocator.
    use super::Scope;

    pub struct MemScope;

    impl MemScope {
        pub fn enter(_scope: Scope) -> MemScope {
            MemScope
        }
    }

    pub fn enabled() -> bool {
        false
    }

    pub fn current_scope() -> Scope {
        Scope::Unscoped
    }

    pub fn live_bytes() -> u64 {
        0
    }

    pub fn peak_bytes() -> u64 {
        0
    }

    pub fn scope_live(_scope: Scope) -> u64 {
        0
    }

    pub fn scope_peak(_scope: Scope) -> u64 {
        0
    }

    pub fn scope_live_raw(_scope: Scope) -> i64 {
        0
    }

    pub fn reset_peak() -> u64 {
        0
    }

    pub fn snapshot() -> Vec<(&'static str, u64, u64)> {
        Vec::new()
    }

    pub fn publish() {}
}

pub use imp::{
    current_scope, enabled, live_bytes, peak_bytes, publish, reset_peak, scope_live,
    scope_live_raw, scope_peak, snapshot, MemScope,
};

#[cfg(all(test, feature = "mem-profile"))]
mod tests {
    use super::*;
    use std::sync::{Mutex, OnceLock};

    /// The `Probe` scope is exclusive to these tests, but they still
    /// share its counters with each other — serialize.
    fn probe_lock() -> &'static Mutex<()> {
        static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
        LOCK.get_or_init(|| Mutex::new(()))
    }

    const MIB: usize = 1 << 20;

    #[test]
    fn scope_stack_nests_and_restores() {
        let _guard = probe_lock().lock().unwrap();
        assert_eq!(current_scope(), Scope::Unscoped);
        {
            let _a = MemScope::enter(Scope::Probe);
            assert_eq!(current_scope(), Scope::Probe);
            {
                let _b = MemScope::enter(Scope::ScoreBatch);
                assert_eq!(current_scope(), Scope::ScoreBatch);
            }
            assert_eq!(current_scope(), Scope::Probe, "inner drop restores outer scope");
        }
        assert_eq!(current_scope(), Scope::Unscoped);
    }

    #[test]
    fn alloc_charges_the_active_scope_exactly() {
        let _guard = probe_lock().lock().unwrap();
        let before = scope_live_raw(Scope::Probe);
        let buf: Vec<u8> = {
            let _scope = MemScope::enter(Scope::Probe);
            Vec::with_capacity(MIB)
        };
        let held = scope_live_raw(Scope::Probe);
        assert!(
            held - before >= MIB as i64,
            "probe scope grew by {} after a {MIB}-byte alloc",
            held - before
        );
        // freed outside any scope: the probe's live counter keeps the
        // charge (attribution is a profile, not a ledger — the free is
        // billed to Unscoped)
        drop(buf);
        assert_eq!(scope_live_raw(Scope::Probe), held, "unscoped free must not touch the probe");
    }

    #[test]
    fn cross_thread_allocations_stay_isolated() {
        let _guard = probe_lock().lock().unwrap();
        let _scope = MemScope::enter(Scope::Probe);
        let before = scope_live_raw(Scope::Probe);
        // the spawned thread starts Unscoped: its allocations must not
        // charge this thread's probe scope
        std::thread::spawn(|| {
            assert_eq!(current_scope(), Scope::Unscoped);
            let v: Vec<u8> = Vec::with_capacity(4 * MIB);
            drop(v);
        })
        .join()
        .unwrap();
        let after = scope_live_raw(Scope::Probe);
        assert!(
            (after - before).unsigned_abs() < MIB as u64,
            "probe scope moved by {} bytes from another thread's traffic",
            after - before
        );
    }

    #[test]
    fn dealloc_in_other_scope_is_underflow_safe() {
        let _guard = probe_lock().lock().unwrap();
        // allocate unscoped, free inside the probe scope: the probe's
        // signed counter may go negative; the clamped surface must not
        // underflow and the process stays alive
        let buf: Vec<u8> = Vec::with_capacity(2 * MIB);
        let raw_before = scope_live_raw(Scope::Probe);
        {
            let _scope = MemScope::enter(Scope::Probe);
            drop(buf);
        }
        let raw_after = scope_live_raw(Scope::Probe);
        assert!(
            raw_after <= raw_before - (2 * MIB) as i64,
            "the free was charged to the probe scope"
        );
        // clamped view never wraps to a huge unsigned value
        let clamped = scope_live(Scope::Probe);
        assert!(clamped < u64::MAX / 2, "clamp failed: {clamped}");
    }

    #[test]
    fn peaks_track_high_water_above_a_reset_baseline() {
        let _guard = probe_lock().lock().unwrap();
        // Exact assertions use the Probe scope: only these serialized
        // tests touch its counters, while the *global* counters see
        // every parallel test thread in this process and only admit
        // monotonicity checks.
        let _scope = MemScope::enter(Scope::Probe);
        reset_peak();
        let raw_base = scope_live_raw(Scope::Probe);
        let g_peak_before = peak_bytes();
        let buf: Vec<u8> = Vec::with_capacity(8 * MIB);
        let scope_delta = scope_peak(Scope::Probe) as i64 - raw_base;
        let g_peak_held = peak_bytes();
        drop(buf);
        assert!(
            scope_delta >= (8 * MIB) as i64,
            "probe peak rose {scope_delta} over an 8 MiB allocation"
        );
        // the mark survives the free (nothing else resets concurrently:
        // reset_peak's only other callers are the single-threaded bench
        // binaries)
        assert!(scope_peak(Scope::Probe) as i64 - raw_base >= (8 * MIB) as i64);
        assert!(g_peak_held >= g_peak_before, "global peak is monotone until reset");
        assert!(peak_bytes() >= g_peak_held, "global peak must survive the free");
    }

    #[test]
    fn snapshot_names_match_the_span_taxonomy() {
        assert!(enabled());
        for s in ALL_SCOPES {
            assert!(!s.name().is_empty());
        }
        // snapshot only reports touched scopes, and every entry is a
        // known scope name
        for (name, _, _) in snapshot() {
            assert!(ALL_SCOPES.iter().any(|s| s.name() == name), "unknown scope `{name}`");
        }
    }
}
