//! Failpoint injection — deterministic fault schedules for chaos tests.
//!
//! A failpoint is a named site in the serving stack where a test can
//! inject a fault: an error return, a fixed delay, a corrupted payload
//! or an outright panic. Sites are compiled in only under the
//! (default-off) `fail-inject` cargo feature; without it every call
//! site collapses to a no-op returning `None`. With the feature on but
//! no site armed, a [`hit`] costs one relaxed atomic load and an early
//! return — the same overhead discipline as the span recorder.
//!
//! Schedules are configured three ways, all sharing one syntax
//! `site=action;site=action`:
//!
//! * env — `CVLR_FAILPOINTS='distrib.reply=corrupt;jobs.worker=delay(200)'`
//! * CLI — `--failpoints 'distrib.dispatch=error'`
//! * HTTP — `POST /v1/failpoints {"spec": "stream.append=off"}`
//!   (test-only; answers 501 without the feature)
//!
//! Actions: `error` (the site returns a typed injected-fault error),
//! `delay(MS)` (the site sleeps, then proceeds normally), `corrupt`
//! (the site mangles its payload — wire sites only), `panic` (the
//! site panics; worker threads are expected to contain it), and `off`
//! (disarm). A site stays armed until reconfigured, so a persistent
//! fault exercises every retry the dispatch layer owns.

/// The sites the serving stack consults, in dispatch order. Unknown
/// names are rejected at configure time so schedules can't silently
/// miss their target.
pub const SITES: &[&str] = &[
    "distrib.dispatch",
    "distrib.reply",
    "wire.dataset_push",
    "jobs.worker",
    "stream.append",
    "lowrank.factorize",
];

/// What an armed site asks its caller to do. `delay` and `panic` are
/// executed inside [`hit`] itself (sleep / panic), so callers only see
/// the two actions that need site-specific handling.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Hit {
    /// Return an injected error from the site.
    Error,
    /// Mangle the site's payload (request or reply bytes).
    Corrupt,
}

#[cfg(feature = "fail-inject")]
mod imp {
    use super::Hit;
    use std::collections::BTreeMap;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Mutex;
    use std::time::Duration;

    use anyhow::{bail, Result};

    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    enum Action {
        Error,
        Delay(u64),
        Corrupt,
        Panic,
    }

    impl Action {
        fn parse(s: &str) -> Result<Option<Action>> {
            let s = s.trim();
            if s == "off" {
                return Ok(None);
            }
            if s == "error" {
                return Ok(Some(Action::Error));
            }
            if s == "corrupt" {
                return Ok(Some(Action::Corrupt));
            }
            if s == "panic" {
                return Ok(Some(Action::Panic));
            }
            if let Some(ms) = s.strip_prefix("delay(").and_then(|r| r.strip_suffix(')')) {
                let ms: u64 = ms.trim().parse().map_err(|_| {
                    anyhow::anyhow!("bad delay milliseconds `{ms}` (want delay(MS))")
                })?;
                return Ok(Some(Action::Delay(ms)));
            }
            bail!("unknown failpoint action `{s}` (want error|delay(MS)|corrupt|panic|off)");
        }

        fn render(&self) -> String {
            match self {
                Action::Error => "error".to_string(),
                Action::Delay(ms) => format!("delay({ms})"),
                Action::Corrupt => "corrupt".to_string(),
                Action::Panic => "panic".to_string(),
            }
        }
    }

    /// Fast-path gate: false ⇒ no site is armed, `hit` returns
    /// immediately without touching the registry lock.
    static ANY_ARMED: AtomicBool = AtomicBool::new(false);
    static REGISTRY: Mutex<BTreeMap<&'static str, Action>> = Mutex::new(BTreeMap::new());

    fn canonical_site(name: &str) -> Option<&'static str> {
        super::SITES.iter().find(|s| **s == name).copied()
    }

    /// True when the binary carries the injection machinery at all.
    pub fn compiled_in() -> bool {
        true
    }

    /// Arm/disarm sites from a `site=action;site=action` spec. Entries
    /// merge into the current schedule; `site=off` disarms one site.
    /// Unknown sites and malformed actions are rejected whole — a
    /// failing spec changes nothing.
    pub fn configure(spec: &str) -> Result<()> {
        let mut updates: Vec<(&'static str, Option<Action>)> = Vec::new();
        for entry in spec.split(';').map(str::trim).filter(|e| !e.is_empty()) {
            let (site, action) = entry.split_once('=').ok_or_else(|| {
                anyhow::anyhow!("bad failpoint entry `{entry}` (want site=action)")
            })?;
            let site = canonical_site(site.trim()).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown failpoint site `{}` (known: {})",
                    site.trim(),
                    super::SITES.join(", ")
                )
            })?;
            updates.push((site, Action::parse(action)?));
        }
        let mut reg = REGISTRY.lock().unwrap();
        for (site, action) in updates {
            match action {
                Some(a) => {
                    reg.insert(site, a);
                }
                None => {
                    reg.remove(site);
                }
            }
        }
        ANY_ARMED.store(!reg.is_empty(), Ordering::Relaxed);
        Ok(())
    }

    /// Disarm every site.
    pub fn clear() {
        REGISTRY.lock().unwrap().clear();
        ANY_ARMED.store(false, Ordering::Relaxed);
    }

    /// Arm sites from `CVLR_FAILPOINTS` when set. Called once from the
    /// binary entry point; a malformed spec is a startup error.
    pub fn init_from_env() -> Result<()> {
        if let Ok(spec) = std::env::var("CVLR_FAILPOINTS") {
            configure(&spec)?;
        }
        Ok(())
    }

    /// The current schedule as `(site, action)` pairs, sorted by site.
    pub fn list() -> Vec<(String, String)> {
        REGISTRY
            .lock()
            .unwrap()
            .iter()
            .map(|(s, a)| (s.to_string(), a.render()))
            .collect()
    }

    /// Consult a site. Disabled/unarmed: one relaxed load, `None`.
    /// `delay(ms)` sleeps here and returns `None` (the site proceeds);
    /// `panic` panics here; `error`/`corrupt` are returned for the
    /// site to act on.
    pub fn hit(site: &str) -> Option<Hit> {
        if !ANY_ARMED.load(Ordering::Relaxed) {
            return None;
        }
        let action = *REGISTRY.lock().unwrap().get(site)?;
        match action {
            Action::Error => Some(Hit::Error),
            Action::Corrupt => Some(Hit::Corrupt),
            Action::Delay(ms) => {
                std::thread::sleep(Duration::from_millis(ms));
                None
            }
            Action::Panic => panic!("failpoint `{site}` injected panic"),
        }
    }
}

#[cfg(not(feature = "fail-inject"))]
mod imp {
    use super::Hit;
    use anyhow::{bail, Result};

    pub fn compiled_in() -> bool {
        false
    }

    pub fn configure(_spec: &str) -> Result<()> {
        bail!("failpoints are not compiled in (rebuild with --features fail-inject)");
    }

    pub fn clear() {}

    pub fn init_from_env() -> Result<()> {
        if std::env::var("CVLR_FAILPOINTS").is_ok() {
            bail!(
                "CVLR_FAILPOINTS is set but failpoints are not compiled in \
                 (rebuild with --features fail-inject)"
            );
        }
        Ok(())
    }

    pub fn list() -> Vec<(String, String)> {
        Vec::new()
    }

    #[inline(always)]
    pub fn hit(_site: &str) -> Option<Hit> {
        None
    }
}

pub use imp::{clear, compiled_in, configure, hit, init_from_env, list};

/// The error message prefix every injected `error` action carries, so
/// tests can tell an injected fault from an organic one.
pub const INJECTED: &str = "injected fault";

/// Convenience for `Hit::Error` sites: the error the site returns.
pub fn injected_error(site: &str) -> anyhow::Error {
    anyhow::anyhow!("{INJECTED} at failpoint `{site}`")
}

#[cfg(all(test, feature = "fail-inject"))]
mod tests {
    use super::*;

    // The registry is process-global, so the feature-on tests run as
    // one serialized test to avoid cross-talk.
    #[test]
    fn configure_parse_arm_disarm() {
        clear();
        assert_eq!(hit("distrib.dispatch"), None, "unarmed site is silent");

        configure("distrib.dispatch=error; distrib.reply=corrupt").unwrap();
        assert_eq!(hit("distrib.dispatch"), Some(Hit::Error));
        assert_eq!(hit("distrib.reply"), Some(Hit::Corrupt));
        assert_eq!(hit("jobs.worker"), None, "other sites stay unarmed");
        assert_eq!(
            list(),
            vec![
                ("distrib.dispatch".to_string(), "error".to_string()),
                ("distrib.reply".to_string(), "corrupt".to_string()),
            ]
        );

        configure("distrib.dispatch=off").unwrap();
        assert_eq!(hit("distrib.dispatch"), None, "off disarms one site");
        assert_eq!(hit("distrib.reply"), Some(Hit::Corrupt), "others stay armed");

        assert!(configure("bogus.site=error").is_err(), "unknown site rejected");
        assert!(configure("distrib.reply=explode").is_err(), "unknown action rejected");
        assert!(configure("distrib.reply").is_err(), "missing `=` rejected");
        assert!(configure("distrib.reply=delay(x)").is_err(), "bad delay ms rejected");
        assert_eq!(hit("distrib.reply"), Some(Hit::Corrupt), "failed spec changes nothing");

        let t0 = std::time::Instant::now();
        configure("stream.append=delay(30)").unwrap();
        assert_eq!(hit("stream.append"), None, "delay proceeds normally");
        assert!(t0.elapsed() >= std::time::Duration::from_millis(30), "…after sleeping");

        clear();
        assert_eq!(list(), Vec::<(String, String)>::new());
        assert_eq!(hit("distrib.reply"), None);
    }
}
