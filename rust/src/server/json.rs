//! Hand-rolled, strictly-validating JSON for the wire layer (serde is
//! unavailable offline; see `util`'s module docs).
//!
//! The parser is a recursive-descent reader over bytes with a depth
//! limit; it enforces the RFC 8259 grammar — strict number syntax (no
//! leading zeros, no bare `.5`/`1.`), `\uXXXX` escapes with surrogate
//! pairing, unescaped control characters rejected, exactly one value
//! per document (trailing garbage is an error). The encoder emits keys
//! in insertion order, so responses are deterministic and `encode ∘
//! parse ∘ encode` is the identity (f64 `Display` prints the shortest
//! decimal that round-trips, pinned by `tests/prop_json.rs`).

use anyhow::{bail, Result};

/// Maximum nesting depth accepted by the parser.
const MAX_DEPTH: usize = 64;

/// A JSON value. Objects preserve insertion order (the wire layer wants
/// deterministic responses, and duplicate keys are rejected at parse).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Always finite; the encoder writes non-finite values as `null`.
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Convenience string constructor.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Convenience object constructor from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric value as a non-negative integer (None if fractional,
    /// negative, or too large for exact f64 representation).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 && *x >= 0.0 && *x <= 2f64.powi(53) => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// Serialize (compact, deterministic field order).
    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    // f64 Display prints the shortest decimal that
                    // parses back to the same bits
                    out.push_str(&format!("{x}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_string(s, out),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_string(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse exactly one JSON document (leading/trailing whitespace
/// allowed, anything else after the value is an error).
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("json: trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("json: expected `{}` at byte {}", b as char, self.pos)
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("json: invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth > MAX_DEPTH {
            bail!("json: nesting deeper than {MAX_DEPTH}");
        }
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => bail!("json: unexpected byte `{}` at {}", c as char, self.pos),
            None => bail!("json: unexpected end of input"),
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => bail!("json: expected `,` or `]` at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json> {
        self.expect(b'{')?;
        let mut kvs: Vec<(String, Json)> = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            if kvs.iter().any(|(existing, _)| *existing == k) {
                bail!("json: duplicate key `{k}`");
            }
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value(depth + 1)?;
            kvs.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(kvs));
                }
                _ => bail!("json: expected `,` or `}}` at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out: Vec<u8> = Vec::new();
        loop {
            let b = match self.peek() {
                Some(b) => b,
                None => bail!("json: unterminated string"),
            };
            self.pos += 1;
            match b {
                b'"' => break,
                b'\\' => {
                    let esc = match self.peek() {
                        Some(e) => e,
                        None => bail!("json: unterminated escape"),
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push(b'"'),
                        b'\\' => out.push(b'\\'),
                        b'/' => out.push(b'/'),
                        b'b' => out.push(0x08),
                        b'f' => out.push(0x0c),
                        b'n' => out.push(b'\n'),
                        b'r' => out.push(b'\r'),
                        b't' => out.push(b'\t'),
                        b'u' => {
                            let c = self.unicode_escape()?;
                            let mut buf = [0u8; 4];
                            out.extend_from_slice(c.encode_utf8(&mut buf).as_bytes());
                        }
                        _ => bail!("json: invalid escape `\\{}` at byte {}", esc as char, self.pos),
                    }
                }
                c if c < 0x20 => {
                    bail!("json: unescaped control character at byte {}", self.pos - 1)
                }
                c => out.push(c),
            }
        }
        // the input is &str, splits happen only at ASCII delimiters and
        // decoded escapes are written as UTF-8, so this cannot fail
        Ok(String::from_utf8(out).expect("utf-8 preserved"))
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.pos + 4 > self.bytes.len() {
            bail!("json: truncated \\u escape");
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .ok()
            .filter(|s| s.chars().all(|c| c.is_ascii_hexdigit()));
        let s = match s {
            Some(s) => s,
            None => bail!("json: invalid \\u escape at byte {}", self.pos),
        };
        self.pos += 4;
        Ok(u32::from_str_radix(s, 16).expect("validated hex"))
    }

    fn unicode_escape(&mut self) -> Result<char> {
        let hi = self.hex4()?;
        if (0xD800..0xDC00).contains(&hi) {
            // high surrogate: require a paired \uDC00..DFFF low half
            if self.peek() != Some(b'\\') {
                bail!("json: lone high surrogate at byte {}", self.pos);
            }
            self.pos += 1;
            if self.peek() != Some(b'u') {
                bail!("json: lone high surrogate at byte {}", self.pos);
            }
            self.pos += 1;
            let lo = self.hex4()?;
            if !(0xDC00..0xE000).contains(&lo) {
                bail!("json: invalid low surrogate at byte {}", self.pos);
            }
            let cp = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
            char::from_u32(cp).ok_or_else(|| anyhow::anyhow!("json: invalid surrogate pair"))
        } else if (0xDC00..0xE000).contains(&hi) {
            bail!("json: lone low surrogate at byte {}", self.pos)
        } else {
            char::from_u32(hi).ok_or_else(|| anyhow::anyhow!("json: invalid code point"))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // integer part: `0` or [1-9][0-9]* (strict: no leading zeros)
        match self.peek() {
            Some(b'0') => self.pos += 1,
            Some(c) if c.is_ascii_digit() => {
                while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    self.pos += 1;
                }
            }
            _ => bail!("json: invalid number at byte {start}"),
        }
        if matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            bail!("json: leading zero in number at byte {start}");
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                bail!("json: digits required after `.` at byte {}", self.pos);
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                bail!("json: digits required in exponent at byte {}", self.pos);
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii number");
        let x: f64 = text.parse().map_err(|_| anyhow::anyhow!("json: bad number `{text}`"))?;
        if !x.is_finite() {
            bail!("json: number out of range `{text}`");
        }
        Ok(Json::Num(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(v: &Json) {
        let enc = v.encode();
        let back = parse(&enc).unwrap_or_else(|e| panic!("parse of {enc:?} failed: {e}"));
        assert_eq!(&back, v, "roundtrip of {enc:?}");
    }

    #[test]
    fn scalars_roundtrip() {
        for v in [
            Json::Null,
            Json::Bool(true),
            Json::Bool(false),
            Json::Num(0.0),
            Json::Num(-1.5),
            Json::Num(1e-9),
            Json::Num(123456789.25),
            Json::str(""),
            Json::str("hello \"world\"\n\t\\ ünïcode ✓"),
            Json::str("\u{0}\u{1f}"),
        ] {
            roundtrip(&v);
        }
    }

    #[test]
    fn containers_roundtrip() {
        let v = Json::obj(vec![
            ("id", Json::Num(3.0)),
            ("tags", Json::Arr(vec![Json::str("a"), Json::Null, Json::Bool(false)])),
            ("nested", Json::obj(vec![("x", Json::Arr(vec![]))])),
        ]);
        roundtrip(&v);
        assert_eq!(v.get("id").and_then(Json::as_u64), Some(3));
        assert_eq!(v.get("nested").and_then(|n| n.get("x")).and_then(Json::as_arr), Some(&[][..]));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn parses_whitespace_and_escapes() {
        let v = parse(" { \"a\" : [ 1 , \"\\u0041\\ud83d\\ude00\" ] } \n").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_str(), Some("A😀"));
    }

    #[test]
    fn strict_rejections() {
        for bad in [
            "", "{", "[1,", "01", "1.", ".5", "+1", "--1", "1e", "nul", "tru", "[1 2]",
            "{\"a\" 1}", "{\"a\":1,}", "[1,]", "\"\\x\"", "\"unterminated", "\"\u{1}\"",
            "{\"a\":1}x", "1 2", "\"\\ud800\"", "\"\\udc00\"", "\"\\ud800\\u0041\"", "1e999",
            "{\"a\":1,\"a\":2}", "{1:2}",
        ] {
            assert!(parse(bad).is_err(), "must reject {bad:?}");
        }
    }

    #[test]
    fn deep_nesting_is_bounded() {
        let deep = "[".repeat(100) + &"]".repeat(100);
        assert!(parse(&deep).is_err());
        let ok = "[".repeat(30) + &"]".repeat(30);
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn trailing_whitespace_ok() {
        assert_eq!(parse("  42 \t").unwrap(), Json::Num(42.0));
    }
}
