//! The discovery server: a long-running process serving causal
//! discovery as an HTTP/JSON API (`cvlr serve --port <p>`).
//!
//! Four parts, all std-only:
//!
//! * [`registry`] — named datasets: the paper's built-ins plus CSV
//!   uploads with per-column continuous/discrete type inference;
//! * [`jobs`] — the async job manager: submit/poll/cancel over a worker
//!   pool, one memoizing [`coordinator::ScoreService`] per (dataset,
//!   method, engine) so the score cache persists across jobs;
//! * [`json`] — a strict, hand-rolled JSON encoder/parser;
//! * [`http`] — a minimal HTTP/1.1 listener with graceful shutdown
//!   (shutdown flag + connection drain) and a matching test client.
//!
//! [`coordinator::ScoreService`]: crate::coordinator::ScoreService
//!
//! ## Endpoints
//!
//! | Route | Meaning |
//! |---|---|
//! | `POST /v1/datasets` | register a CSV upload (`{"name", "csv", "header"?}`), a parameterized built-in (`{"name", "builtin", "n"?, "seed"?}`), or a raw internal-coordinates push (`{"name", "raw"}` — bit-exact, used by sharding coordinators for auto-registration); replies include the registry `version` |
//! | `GET /v1/datasets` | list registered datasets |
//! | `POST /v1/datasets/{name}/rows` | append header-less CSV rows (`{"csv"}`) in the dataset's internal coordinates; refreshes (not retires) the pooled services, invalidating their stale score entries; `409` while jobs on the dataset are active |
//! | `DELETE /v1/datasets/{name}` | remove a dataset and retire its pooled services |
//! | `POST /v1/jobs` | submit `{"dataset", "method", "engine"?, "workers"?, "parallelism"?, "lowrank"?, "cache_capacity"?, "warm_start"?, "shards"?}` → `202 {"id", "state"}` (`shards` = follower `host:port` list overriding the serve-level `--shards` default; `[]` forces local scoring) (`workers`/`parallelism`/`cache_capacity` configure the pooled service and only apply to the job that creates it; `parallelism` = Gram-product threads of the fold-core builds, `0` = auto, exposed resolved as `gram_threads` in `/v1/stats`; `lowrank` = `"icl"` or `"rff"` — the CV-LR factorization, part of the service-pool key; `warm_start: true` resumes GES from the pooled service's last CPDAG — the cheap re-discovery after an append) |
//! | `GET /v1/jobs` | list job snapshots (without results) |
//! | `GET /v1/jobs/{id}` | poll one job: state, progress, result when done |
//! | `DELETE /v1/jobs/{id}` | cancel (honored mid-sweep for score methods) |
//! | `POST /v1/score_batch` | stateless follower-side scoring for the distrib shard protocol: `{"dataset", "version"?, "method", "engine"?, "lowrank"?, "requests": [{"target", "parents"}]}` → `{"scores", "version"}` in request order; `404` for an unknown dataset, `409` on a version-pin mismatch (the coordinator re-pushes and retries) |
//! | `GET /v1/stats` | job counts, per-service cache counters (incl. evictions, resident cache/core-cache bytes, shard dispatch/retry/hedge/degrade, stream re-pivot/residual and per-follower health), datasets |
//! | `GET /v1/metrics` | Prometheus text exposition: process-global stage counters/histograms (`cvlr_*`), per-scope memory gauges (`cvlr_mem_live_bytes`/`cvlr_mem_peak_bytes`), plus the `/v1/stats` service counters folded in as aggregate gauges; `?fleet=1` additionally scrapes every `--shards` follower's `/v1/metrics` on demand and appends its samples relabeled `follower="host:port"` (a failed scrape sets `cvlr_fleet_scrape_stale{follower=…} 1` instead of failing the request) |
//! | `GET /v1/trace` | Chrome trace-event JSON snapshot of the span ring (Perfetto-loadable); the first scrape attaches the recorder, so traces cover traffic after it |
//! | `POST /v1/failpoints` | test-only chaos control: `{"spec": "site=action;…"}` arms failpoints, `{"clear": true}` disarms them; `501` unless the binary was built with `--features fail-inject` |
//! | `POST /v1/shutdown` | graceful shutdown: stop accepting, finish in-flight requests, drain, cancel jobs |
//!
//! Job states: `queued → running → done | failed | cancelled`.
//!
//! ## Failure semantics
//!
//! Typed resilience errors map to dedicated statuses at this layer: a
//! saturated admission queue answers `429` with a `Retry-After` header,
//! a breached memory high-water mark (after cache shedding) answers
//! `503`, and an exhausted `deadline_ms` budget answers `504` — all
//! counted in `cvlr_shed_total` / `cvlr_deadline_exceeded_total` and
//! surfaced through `/v1/stats`.

pub mod http;
pub mod jobs;
pub mod json;
pub mod registry;

use std::collections::HashMap;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::{resolve_method, DiscoveryConfig, EngineKind, MethodKind};
use crate::distrib::ShardClient;
use crate::lowrank::FactorMethod;
use crate::obs::{fail, metrics, trace};
use crate::score::ScoreBackend;
use crate::util::lockorder::Mutex;
use crate::util::{Backoff, Budget, DeadlineExceeded, Overloaded, Pcg64};

use self::http::{Handler, HttpServer, Request, Response};
use self::jobs::{JobLimits, JobManager, JobResult, JobSnapshot, JobSpec};
use self::json::Json;
use self::registry::DatasetRegistry;

/// Server configuration (`cvlr serve` flags map 1:1 onto this).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Port to bind on localhost (0 = ephemeral, for tests).
    pub port: u16,
    /// Job-manager worker threads (concurrent jobs).
    pub job_workers: usize,
    /// Default score-service worker threads per job.
    pub score_workers: usize,
    /// Default Gram-product threads for CV-LR fold-core builds
    /// (`DiscoveryConfig::parallelism`; overridable per job; `0` =
    /// auto — available cores capped at the fold count, reported
    /// resolved as `gram_threads`).
    pub parallelism: usize,
    /// Default low-rank factorization for CV-LR jobs (`icl` adaptive
    /// pivots or `rff` data-independent Fourier features; overridable
    /// per job with the `lowrank` option).
    pub lowrank: FactorMethod,
    /// Default per-service score-cache bound. `None` disables the bound
    /// — do that only for short-lived test servers.
    pub cache_capacity: Option<usize>,
    /// Sample count for the pre-registered built-in datasets.
    pub builtin_n: usize,
    /// Seed for the pre-registered built-in datasets.
    pub seed: u64,
    /// Artifacts directory handed to PJRT-engine jobs.
    pub artifacts_dir: String,
    /// Default follower fleet (`host:port` each) for score-based jobs:
    /// this server acts as a sharding **coordinator**, fanning score
    /// batches out over `POST /v1/score_batch`. Per-job `shards`
    /// overrides it; empty means local scoring. A follower handling
    /// `/v1/score_batch` never re-shards, so fleets cannot loop.
    pub shards: Vec<String>,
    /// Admission bound: queued-but-not-running jobs accepted before
    /// `POST /v1/jobs` answers `429` + `Retry-After`.
    pub max_queued_jobs: usize,
    /// Live-heap high-water mark in bytes: above it job submission
    /// sheds the pooled service caches, then answers `503` if the heap
    /// is still over. `None` disables the guard (it is also inert
    /// without the `mem-profile` feature).
    pub mem_high_water: Option<u64>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            port: 7878,
            job_workers: 2,
            score_workers: 1,
            parallelism: 1,
            lowrank: FactorMethod::Icl,
            cache_capacity: Some(1 << 20),
            builtin_n: 500,
            seed: 0,
            artifacts_dir: "artifacts".to_string(),
            shards: Vec::new(),
            max_queued_jobs: 256,
            mem_high_water: None,
        }
    }
}

/// A running discovery server. Dropping it (or [`Server::stop`])
/// initiates shutdown; [`Server::wait`] blocks until a client asks for
/// shutdown via `POST /v1/shutdown`.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    manager: Arc<JobManager>,
    registry: Arc<DatasetRegistry>,
    accept: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind, pre-register the built-ins, spawn the job workers and the
    /// accept loop, and return immediately.
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        let registry = Arc::new(DatasetRegistry::with_builtins(cfg.builtin_n, cfg.seed));
        let limits =
            JobLimits { max_queued: cfg.max_queued_jobs, mem_high_water: cfg.mem_high_water };
        let manager = JobManager::start_with_limits(
            registry.clone(),
            cfg.job_workers,
            cfg.cache_capacity,
            limits,
        );
        let listener = HttpServer::bind(cfg.port)?;
        let addr = listener.addr();
        let shutdown = Arc::new(AtomicBool::new(false));
        let handler = build_handler(manager.clone(), registry.clone(), shutdown.clone(), cfg);
        let flag = shutdown.clone();
        let accept = std::thread::Builder::new()
            .name("cvlr-http".to_string())
            .spawn(move || listener.run(handler, &flag))
            .context("spawning accept loop")?;
        Ok(Server { addr, shutdown, manager, registry, accept: Some(accept) })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn manager(&self) -> &Arc<JobManager> {
        &self.manager
    }

    pub fn registry(&self) -> &Arc<DatasetRegistry> {
        &self.registry
    }

    /// Block until a client requests shutdown (`POST /v1/shutdown`),
    /// then drain connections and stop the job workers.
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        self.manager.shutdown();
    }

    /// Programmatic shutdown: stop accepting, drain, cancel jobs.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        self.manager.shutdown();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        self.manager.shutdown();
    }
}

fn num(x: u64) -> Json {
    Json::Num(x as f64)
}

/// Typed marker for transient conflicts (an append in flight, a CAS
/// losing to a concurrent replace): the wire layer downcasts to map
/// them to `409 Conflict` instead of `400 Bad Request`, so retry-aware
/// clients behave correctly without fragile message matching.
#[derive(Debug)]
pub struct TransientConflict(pub String);

impl std::fmt::Display for TransientConflict {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for TransientConflict {}

/// 409 for transient conflicts, `fallback` otherwise.
fn conflict_status(e: &anyhow::Error, fallback: u16) -> u16 {
    if e.is::<TransientConflict>() {
        409
    } else {
        fallback
    }
}

/// Map the typed resilience errors to their statuses: [`Overloaded`]
/// with a retry hint → `429` + `Retry-After` (queue saturation),
/// without → `503` (memory pressure after shedding);
/// [`DeadlineExceeded`] → `504`; [`TransientConflict`] → `409`;
/// everything else `fallback`.
fn error_response(e: &anyhow::Error, fallback: u16) -> Response {
    if let Some(o) = e.downcast_ref::<Overloaded>() {
        return match o.retry_after {
            Some(d) => Response::error(429, &format!("{e:#}"))
                .with_header("Retry-After", d.as_secs().max(1).to_string()),
            None => Response::error(503, &format!("{e:#}")),
        };
    }
    if e.is::<DeadlineExceeded>() {
        return Response::error(504, &format!("{e:#}"));
    }
    Response::error(conflict_status(e, fallback), &format!("{e:#}"))
}

/// Reject unknown object keys — typos fail loudly instead of being
/// silently ignored.
fn check_keys(body: &Json, allowed: &[&str]) -> Result<(), Response> {
    if let Json::Obj(kvs) = body {
        for (k, _) in kvs {
            if !allowed.contains(&k.as_str()) {
                return Err(Response::error(
                    400,
                    &format!("unknown field `{k}` (allowed: {})", allowed.join(", ")),
                ));
            }
        }
        Ok(())
    } else {
        Err(Response::error(400, "body must be a JSON object"))
    }
}

fn stats_json(st: &crate::coordinator::ServiceStats) -> Json {
    Json::obj(vec![
        ("requests", num(st.requests)),
        ("cache_hits", num(st.cache_hits)),
        ("evaluations", num(st.evaluations)),
        ("dedup_skips", num(st.dedup_skips)),
        ("batches", num(st.batches)),
        ("max_batch", num(st.max_batch)),
        ("evictions", num(st.evictions)),
        ("invalidations", num(st.invalidations)),
        ("warm_start_hits", num(st.warm_start_hits)),
        ("cache_entries", num(st.cache_entries)),
        ("cache_bytes", num(st.cache_bytes)),
        ("core_cache_entries", num(st.core_cache_entries)),
        ("core_cache_evictions", num(st.core_cache_evictions)),
        ("core_cache_bytes", num(st.core_cache_bytes)),
        ("gram_threads", num(st.gram_threads)),
        ("shard_dispatches", num(st.shard_dispatches)),
        ("shard_retries", num(st.shard_retries)),
        ("shard_hedges", num(st.shard_hedges)),
        ("shard_degraded", num(st.shard_degraded)),
        ("stream_repivots", num(st.stream_repivots)),
        ("stream_residual", Json::Num(st.stream_residual)),
        (
            "followers",
            Json::Arr(
                st.followers
                    .iter()
                    .map(|f| {
                        Json::obj(vec![
                            ("addr", Json::str(f.addr.clone())),
                            ("healthy", Json::Bool(f.healthy)),
                            ("ewma_ms", Json::Num(f.ewma_ms)),
                            ("dispatches", num(f.dispatches)),
                            ("successes", num(f.successes)),
                            ("failures", num(f.failures)),
                            ("retries", num(f.retries)),
                            ("hedges", num(f.hedges)),
                            ("degraded", num(f.degraded)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("eval_seconds", Json::Num(st.eval_seconds)),
        ("consistent", Json::Bool(st.consistent())),
    ])
}

fn result_json(res: &JobResult) -> Json {
    let p = &res.cpdag;
    let d = p.d;
    let mut edges = Vec::new();
    let mut adjacency = Vec::with_capacity(d);
    for i in 0..d {
        let mut row = Vec::with_capacity(d);
        for j in 0..d {
            // SHD-ready adjacency: directed i→j sets [i][j] only,
            // undirected i—j sets both directions
            let bit = p.directed(i, j) || p.undirected(i, j);
            row.push(Json::Num(if bit { 1.0 } else { 0.0 }));
            if p.directed(i, j) {
                edges.push(Json::obj(vec![
                    ("from", num(i as u64)),
                    ("to", num(j as u64)),
                    ("directed", Json::Bool(true)),
                ]));
            } else if i < j && p.undirected(i, j) {
                edges.push(Json::obj(vec![
                    ("from", num(i as u64)),
                    ("to", num(j as u64)),
                    ("directed", Json::Bool(false)),
                ]));
            }
        }
        adjacency.push(Json::Arr(row));
    }
    let mut fields = vec![
        ("method", Json::str(res.method.clone())),
        ("seconds", Json::Num(res.seconds)),
        ("num_vars", num(d as u64)),
        ("num_edges", num(res.cpdag.num_edges() as u64)),
        ("edges", Json::Arr(edges)),
        ("adjacency", Json::Arr(adjacency)),
    ];
    if let Some(st) = &res.stats {
        fields.push(("stats", stats_json(st)));
    }
    if let Some(ci) = res.ci_tests {
        fields.push(("ci_tests", num(ci)));
    }
    Json::obj(fields)
}

/// Job snapshot as wire JSON; `with_result` is false in list views.
fn job_json(snap: &JobSnapshot, with_result: bool) -> Json {
    let mut fields = vec![
        ("id", num(snap.id)),
        ("dataset", Json::str(snap.dataset.clone())),
        ("method", Json::str(snap.method.clone())),
        ("state", Json::str(snap.state.name())),
        (
            "progress",
            Json::obj(vec![
                ("sweeps", num(snap.sweeps)),
                ("candidates", num(snap.candidates)),
                ("requests", num(snap.requests)),
                ("cache_hits", num(snap.cache_hits)),
                ("evaluations", num(snap.evaluations)),
                ("cache_hit_rate", Json::Num(snap.cache_hit_rate())),
            ]),
        ),
    ];
    if let Some(err) = &snap.error {
        fields.push(("error", Json::str(err.clone())));
    }
    if with_result {
        if let Some(res) = &snap.result {
            fields.push(("result", result_json(res)));
        }
    }
    Json::obj(fields)
}

fn post_dataset(registry: &DatasetRegistry, cfg: &ServerConfig, req: &Request) -> Response {
    let body = match req.json() {
        Ok(b) => b,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    if let Err(resp) = check_keys(&body, &["name", "csv", "header", "builtin", "n", "seed", "raw"])
    {
        return resp;
    }
    let name = match body.get("name").and_then(Json::as_str) {
        Some(n) => n.to_string(),
        None => return Response::error(400, "`name` (string) is required"),
    };
    let csv = body.get("csv").and_then(Json::as_str);
    let builtin = body.get("builtin").and_then(Json::as_str);
    let raw = body.get("raw");
    if (csv.is_some() as u8) + (builtin.is_some() as u8) + (raw.is_some() as u8) > 1 {
        return Response::error(400, "give exactly one of `csv`, `builtin`, `raw`");
    }
    let ds = match (csv, builtin, raw) {
        (Some(text), None, None) => {
            let header = body.get("header").and_then(Json::as_bool);
            match registry::dataset_from_csv(text, header) {
                Ok(ds) => ds,
                Err(e) => return Response::error(400, &format!("{e:#}")),
            }
        }
        (None, Some(b), None) => {
            let n = body.get("n").and_then(Json::as_u64).map(|v| v as usize);
            let seed = body.get("seed").and_then(Json::as_u64);
            match registry::builtin_dataset(
                b,
                n.unwrap_or(cfg.builtin_n),
                seed.unwrap_or(cfg.seed),
            ) {
                Some(ds) => ds,
                None => {
                    return Response::error(
                        400,
                        &format!(
                            "unknown builtin `{b}` (available: {})",
                            registry::BUILTIN_NAMES.join(", ")
                        ),
                    )
                }
            }
        }
        // raw mode: a sharding coordinator pushing its dataset in
        // internal coordinates — re-ingesting CSV would z-score a
        // second time; this reconstructs the exact sample matrix, so
        // follower scores match the coordinator's bit for bit
        (None, None, Some(raw)) => match crate::distrib::wire::parse_raw_dataset(raw) {
            Ok(ds) => ds,
            Err(e) => return Response::error(400, &format!("{e:#}")),
        },
        _ => return Response::error(400, "`csv`, `builtin` or `raw` is required"),
    };
    let ds = Arc::new(ds);
    let replaced = match registry.insert(&name, ds.clone()) {
        Ok(r) => r,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    // the registry version the insert assigned — sharding coordinators
    // pin it so every scoring request hits exactly these bits
    let version = registry.entry(&name).map(|(_, v)| v).unwrap_or(0);
    let vars: Vec<Json> = ds
        .vars
        .iter()
        .map(|v| {
            Json::obj(vec![
                ("name", Json::str(v.name.clone())),
                ("discrete", Json::Bool(v.discrete)),
                ("cardinality", num(v.cardinality as u64)),
            ])
        })
        .collect();
    Response::json(
        201,
        &Json::obj(vec![
            ("name", Json::str(name)),
            ("n", num(ds.n() as u64)),
            ("d", num(ds.d() as u64)),
            ("replaced", Json::Bool(replaced)),
            ("version", num(version)),
            ("vars", Json::Arr(vars)),
        ]),
    )
}

/// `POST /v1/datasets/{name}/rows` — append header-less CSV rows to a
/// registered dataset. Values are interpreted in the dataset's internal
/// coordinates (continuous columns in the registered/z-scored scale,
/// discrete columns as 0-based level codes). Pooled services follow the
/// appended snapshot in place: backends are swapped, stale score
/// entries invalidated (`invalidations` in `/v1/stats`), and warm-start
/// CPDAGs survive for `warm_start` re-discovery jobs. Refused with
/// `409` while jobs on the dataset are queued/running — a mid-sweep
/// backend swap would mix row versions.
fn post_rows(
    manager: &JobManager,
    registry: &DatasetRegistry,
    name: &str,
    req: &Request,
) -> Response {
    let body = match req.json() {
        Ok(b) => b,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    if let Err(resp) = check_keys(&body, &["csv"]) {
        return resp;
    }
    let csv = match body.get("csv").and_then(Json::as_str) {
        Some(c) => c,
        None => return Response::error(400, "`csv` (string) is required"),
    };
    let ds0 = match registry.get(name) {
        Some(d) => d,
        None => return Response::error(404, &format!("no dataset `{name}`")),
    };
    // atomic: refuses while jobs are active AND blocks new submissions
    // (and concurrent appends) until the guard drops at return
    let _guard = match manager.begin_append(name) {
        Ok(g) => g,
        Err(e) => return Response::error(409, &format!("{e:#}")),
    };
    let rows = match registry::rows_from_csv(&ds0, csv) {
        Ok(r) => r,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    let (ds, row_version) = match registry.append_rows(name, &rows) {
        Ok(r) => r,
        Err(e) => return Response::error(conflict_status(&e, 400), &format!("{e:#}")),
    };
    let invalidated = manager.refresh_dataset_services(name, &ds);
    Response::json(
        200,
        &Json::obj(vec![
            ("name", Json::str(name)),
            ("appended", num(rows.rows as u64)),
            ("n", num(ds.n() as u64)),
            ("row_version", num(row_version)),
            ("invalidated", num(invalidated)),
        ]),
    )
}

fn post_job(manager: &JobManager, cfg: &ServerConfig, req: &Request) -> Response {
    let body = match req.json() {
        Ok(b) => b,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    if let Err(resp) = check_keys(
        &body,
        &[
            "dataset",
            "method",
            "engine",
            "workers",
            "parallelism",
            "lowrank",
            "cache_capacity",
            "warm_start",
            "shards",
            "deadline_ms",
        ],
    ) {
        return resp;
    }
    let dataset = match body.get("dataset").and_then(Json::as_str) {
        Some(d) => d.to_string(),
        None => return Response::error(400, "`dataset` (string) is required"),
    };
    let method = match body.get("method").and_then(Json::as_str) {
        Some(m) => m.to_string(),
        None => return Response::error(400, "`method` (string) is required"),
    };
    let engine = match body.get("engine").and_then(Json::as_str) {
        None | Some("native") => EngineKind::Native,
        Some("pjrt") => EngineKind::Pjrt,
        Some(e) => return Response::error(400, &format!("unknown engine `{e}` (native|pjrt)")),
    };
    let mut dcfg = DiscoveryConfig {
        engine,
        workers: cfg.score_workers,
        parallelism: cfg.parallelism,
        artifacts_dir: cfg.artifacts_dir.clone(),
        ..Default::default()
    };
    dcfg.lowrank.method = cfg.lowrank;
    if let Some(w) = body.get("workers").and_then(Json::as_u64) {
        dcfg.workers = w as usize;
    }
    // 0 = auto (available cores capped at the fold count)
    if let Some(t) = body.get("parallelism").and_then(Json::as_u64) {
        dcfg.parallelism = t as usize;
    }
    if let Some(l) = body.get("lowrank").and_then(Json::as_str) {
        match FactorMethod::parse(l) {
            Some(m) => dcfg.lowrank.method = m,
            None => {
                return Response::error(400, &format!("unknown lowrank method `{l}` (icl|rff)"))
            }
        }
    }
    if let Some(c) = body.get("cache_capacity").and_then(Json::as_u64) {
        dcfg.cache_capacity = Some(c as usize);
    }
    // end-to-end deadline: the budget is armed at submit, so queue wait
    // counts; an expired job fails with `deadline exceeded` → 504 here
    if let Some(ms) = body.get("deadline_ms").and_then(Json::as_u64) {
        dcfg.deadline_ms = Some(ms);
    }
    // follower fleet: serve-level default, overridable per job; an
    // explicit `[]` forces local scoring even when the server has a
    // default fleet configured
    dcfg.shards = cfg.shards.clone();
    if let Some(v) = body.get("shards") {
        let arr = match v.as_arr() {
            Some(a) => a,
            None => return Response::error(400, "`shards` must be an array of host:port strings"),
        };
        let mut shards = Vec::with_capacity(arr.len());
        for s in arr {
            match s.as_str() {
                Some(addr) => shards.push(addr.to_string()),
                None => {
                    return Response::error(
                        400,
                        "`shards` must be an array of host:port strings",
                    )
                }
            }
        }
        dcfg.shards = shards;
    }
    let warm_start = body.get("warm_start").and_then(Json::as_bool).unwrap_or(false);
    match manager.submit(JobSpec { dataset, method, cfg: dcfg, warm_start }) {
        Ok(id) => Response::json(
            202,
            &Json::obj(vec![("id", num(id)), ("state", Json::str("queued"))]),
        ),
        Err(e) => error_response(&e, 400),
    }
}

/// `POST /v1/score_batch` — the follower side of the distrib shard
/// protocol: score one sub-batch against a registered dataset. Routed
/// through the same pooled [`ScoreService`]s as jobs, so repeated
/// coordinator sweeps share the follower's score cache. The service
/// config is built with `shards` **empty** — a follower never fans out
/// again, so coordinator fleets cannot loop.
///
/// [`ScoreService`]: crate::coordinator::ScoreService
fn post_score_batch(
    manager: &JobManager,
    registry: &DatasetRegistry,
    cfg: &ServerConfig,
    req: &Request,
) -> Response {
    let body = match req.json() {
        Ok(b) => b,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    if let Err(resp) = check_keys(
        &body,
        &["dataset", "version", "deadline_ms", "method", "engine", "lowrank", "requests"],
    ) {
        return resp;
    }
    let msg = match crate::distrib::wire::parse_score_batch(&body) {
        Ok(m) => m,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    let (spec, pinned, reqs) = (msg.spec, msg.version, msg.reqs);
    // the coordinator ships its *remaining* budget; an armed deadline
    // makes this follower cancel cooperatively between chunks below
    let budget = Budget::from_ms(msg.deadline_ms);
    let (ds, ds_version) = match registry.entry(&spec.dataset) {
        Some(e) => e,
        None => {
            return Response::error(
                404,
                &format!(
                    "no dataset `{}` (the coordinator pushes it via the raw mode of POST /v1/datasets)",
                    spec.dataset
                ),
            )
        }
    };
    // version pin: a concurrent re-registration must never serve scores
    // from different bits — the coordinator re-pushes on 409 and retries
    if let Some(v) = pinned {
        if v != ds_version {
            return Response::error(
                409,
                &format!(
                    "dataset `{}` is at version {ds_version}, request pinned version {v}",
                    spec.dataset
                ),
            );
        }
    }
    let engine = match spec.engine.as_str() {
        "native" => EngineKind::Native,
        "pjrt" => EngineKind::Pjrt,
        e => return Response::error(400, &format!("unknown engine `{e}` (native|pjrt)")),
    };
    let lowrank = match FactorMethod::parse(&spec.lowrank) {
        Some(m) => m,
        None => {
            return Response::error(
                400,
                &format!("unknown lowrank method `{}` (icl|rff)", spec.lowrank),
            )
        }
    };
    let canon = match resolve_method(&spec.method) {
        Some((canon, MethodKind::Score)) => canon,
        Some((canon, _)) => {
            return Response::error(400, &format!("`{canon}` is not a score-based method"))
        }
        None => return Response::error(400, &format!("unknown method `{}`", spec.method)),
    };
    let mut dcfg = DiscoveryConfig {
        engine,
        workers: cfg.score_workers,
        parallelism: cfg.parallelism,
        artifacts_dir: cfg.artifacts_dir.clone(),
        ..Default::default()
    };
    dcfg.lowrank.method = lowrank;
    let service = match manager.service_for(&spec.dataset, ds_version, ds, &canon, &dcfg) {
        Ok(s) => s,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    // capture this thread's stage spans while scoring and ship them back
    // as the optional `timings` reply field — the coordinator merges
    // them into its trace under this follower's synthetic pid. Old
    // coordinators simply ignore the extra field.
    let cap = trace::capture();
    // deadline-free requests score as one batch, byte-identical to the
    // pre-deadline protocol; budgeted ones go in a few wide chunks so an
    // expired budget stops the work instead of finishing a doomed batch
    let (scores, expired) = if budget.is_limited() {
        let chunk_len = 32usize.max(reqs.len().div_ceil(8));
        let mut scores: Vec<f64> = Vec::with_capacity(reqs.len());
        let mut expired = false;
        for sub in reqs.chunks(chunk_len) {
            if budget.expired() {
                expired = true;
                break;
            }
            scores.extend(service.score_batch(sub));
        }
        (scores, expired)
    } else {
        (service.score_batch(&reqs), false)
    };
    let timings = cap.finish();
    if expired {
        metrics::deadline_exceeded_total().inc();
        return Response::error(
            504,
            &format!(
                "score_batch on `{}` ran past its {} ms budget",
                spec.dataset,
                msg.deadline_ms.unwrap_or(0)
            ),
        );
    }
    let mut fields = vec![
        ("scores", Json::Arr(scores.into_iter().map(Json::Num).collect())),
        ("version", num(ds_version)),
    ];
    if !timings.is_empty() {
        fields.push(("timings", crate::distrib::wire::timings_json(&timings)));
    }
    Response::json(200, &Json::obj(fields))
}

fn get_stats(manager: &JobManager, registry: &DatasetRegistry) -> Response {
    let jobs = Json::Obj(
        manager
            .state_counts()
            .into_iter()
            .map(|(s, c)| (s.name().to_string(), num(c)))
            .collect(),
    );
    let services: Vec<Json> = manager
        .service_stats()
        .into_iter()
        .map(|((dataset, version, method, engine, lowrank, shards), st)| {
            Json::obj(vec![
                ("dataset", Json::str(dataset)),
                ("dataset_version", num(version)),
                ("method", Json::str(method)),
                ("engine", Json::str(engine)),
                ("lowrank", Json::str(lowrank)),
                ("shards", Json::str(shards)),
                ("stats", stats_json(&st)),
            ])
        })
        .collect();
    let datasets: Vec<Json> = registry
        .summaries()
        .into_iter()
        .map(|(name, n, d)| {
            Json::obj(vec![("name", Json::str(name)), ("n", num(n as u64)), ("d", num(d as u64))])
        })
        .collect();
    Response::json(
        200,
        &Json::obj(vec![
            ("jobs", jobs),
            ("services", Json::Arr(services)),
            ("datasets", Json::Arr(datasets)),
            // overload/deadline observables (process-global counters)
            ("shed_total", num(metrics::shed_total().get())),
            ("deadline_exceeded_total", num(metrics::deadline_exceeded_total().get())),
        ]),
    )
}

/// Socket timeout for one federated follower scrape — deliberately
/// tight: a hung follower must not stall the coordinator's exposition.
const FLEET_SCRAPE_TIMEOUT: Duration = Duration::from_secs(2);

/// Pooled per-follower scrape clients, shared across `?fleet=1`
/// requests so repeated scrapes reuse the keep-alive connections like
/// the shard dispatch path does.
type FleetClients = Mutex<HashMap<String, Arc<ShardClient>>>;

/// Re-emit a follower's Prometheus exposition with a
/// `follower="addr"` label injected into every sample line. Comment
/// lines (`# HELP`/`# TYPE`) are dropped — the coordinator's own
/// exposition already carries the metadata for shared metric names —
/// while exemplar suffixes (`… # {trace_span="…"} v`) ride along
/// untouched after the label splice.
fn relabel_exposition(text: &str, follower: &str) -> String {
    let label = format!(
        "follower=\"{}\"",
        follower.replace('\\', "\\\\").replace('"', "\\\"")
    );
    let mut out = String::with_capacity(text.len() + 64);
    for line in text.lines() {
        let t = line.trim_end();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let brace = t.find('{');
        let space = t.find(' ');
        match (brace, space) {
            (Some(b), Some(s)) if b < s => {
                out.push_str(&t[..b]);
                out.push('{');
                out.push_str(&label);
                out.push(',');
                out.push_str(&t[b + 1..]);
            }
            (_, Some(s)) => {
                out.push_str(&t[..s]);
                out.push('{');
                out.push_str(&label);
                out.push('}');
                out.push_str(&t[s..]);
            }
            _ => continue,
        }
        out.push('\n');
    }
    out
}

/// `GET /v1/metrics` — the process-global registry in Prometheus text
/// exposition format, with the per-service `/v1/stats` counters folded
/// in as aggregate gauges (gauges, not counters: pool entries are
/// LRU-evicted and retired, so the aggregates can go down).
///
/// `fleet` carries the follower fleet and the pooled scrape clients
/// when the request asked for `?fleet=1`: each follower's `/v1/metrics`
/// is scraped on demand and appended relabeled
/// (`follower="host:port"`); a failed scrape degrades to
/// `cvlr_fleet_scrape_stale{follower=…} 1` in the local exposition
/// instead of failing the request.
fn get_metrics(
    manager: &JobManager,
    registry: &DatasetRegistry,
    fleet: Option<(&[String], &FleetClients)>,
) -> Response {
    metrics::register_defaults();
    let stats = manager.service_stats();
    let mut cache_entries = 0u64;
    let mut cache_bytes = 0u64;
    let mut core_cache_entries = 0u64;
    let mut core_cache_bytes = 0u64;
    let mut evictions = 0u64;
    let mut invalidations = 0u64;
    let mut warm_start_hits = 0u64;
    let mut eval_seconds = 0.0f64;
    let mut followers = 0u64;
    let mut followers_healthy = 0u64;
    for (_, st) in &stats {
        cache_entries += st.cache_entries;
        cache_bytes += st.cache_bytes;
        core_cache_entries += st.core_cache_entries;
        core_cache_bytes += st.core_cache_bytes;
        evictions += st.evictions;
        invalidations += st.invalidations;
        warm_start_hits += st.warm_start_hits;
        eval_seconds += st.eval_seconds;
        followers += st.followers.len() as u64;
        followers_healthy += st.followers.iter().filter(|f| f.healthy).count() as u64;
    }
    metrics::gauge("cvlr_services", "pooled score services").set(stats.len() as f64);
    metrics::gauge("cvlr_service_cache_entries", "memoized scores across pooled services")
        .set(cache_entries as f64);
    metrics::gauge("cvlr_service_cache_bytes", "resident score-cache bytes across pooled services")
        .set(cache_bytes as f64);
    metrics::gauge("cvlr_service_core_cache_entries", "cached fold cores across pooled services")
        .set(core_cache_entries as f64);
    metrics::gauge(
        "cvlr_service_core_cache_bytes",
        "resident core-cache bytes across pooled services",
    )
    .set(core_cache_bytes as f64);
    metrics::gauge("cvlr_service_evictions", "score-cache evictions across pooled services")
        .set(evictions as f64);
    metrics::gauge("cvlr_service_invalidations", "append-invalidated scores across pooled services")
        .set(invalidations as f64);
    metrics::gauge("cvlr_service_warm_start_hits", "warm-start CPDAG reuses across pooled services")
        .set(warm_start_hits as f64);
    metrics::gauge("cvlr_service_eval_seconds", "seconds spent evaluating across pooled services")
        .set(eval_seconds);
    metrics::gauge("cvlr_followers", "followers across pooled sharding services")
        .set(followers as f64);
    metrics::gauge("cvlr_followers_healthy", "healthy followers across pooled sharding services")
        .set(followers_healthy as f64);
    metrics::gauge("cvlr_datasets", "registered datasets").set(registry.summaries().len() as f64);
    for (state, count) in manager.state_counts() {
        metrics::gauge(&format!("cvlr_jobs_{}", state.name()), "jobs in this lifecycle state")
            .set(count as f64);
    }
    // scrape followers BEFORE rendering: the stale markers a failed
    // scrape sets must land in this very response
    let mut remote = String::new();
    if let Some((addrs, clients)) = fleet {
        // one jittered re-probe before declaring a follower stale — a
        // keep-alive connection torn down between scrapes shouldn't
        // mark the fleet degraded. Fixed seed: the jitter decorrelates
        // the two attempts, not scrape requests from each other.
        let backoff = Backoff::new(Duration::from_millis(50), Duration::from_millis(250));
        let mut rng = Pcg64::new(0xf1ee7);
        for addr in addrs {
            let client = clients
                .lock()
                .entry(addr.clone())
                .or_insert_with(|| {
                    Arc::new(ShardClient::new(addr.clone(), FLEET_SCRAPE_TIMEOUT))
                })
                .clone();
            let mut scraped = None;
            for attempt in 1..=2u32 {
                match client.get_text("/v1/metrics") {
                    Ok((200, text)) => {
                        scraped = Some(text);
                        break;
                    }
                    _ if attempt < 2 => std::thread::sleep(backoff.delay(attempt, &mut rng)),
                    _ => {}
                }
            }
            let stale = match scraped {
                Some(text) => {
                    remote.push_str(&relabel_exposition(&text, addr));
                    0.0
                }
                None => 1.0,
            };
            metrics::set_labeled_gauge(
                "cvlr_fleet_scrape_stale",
                "1 when the last federated scrape of this follower failed",
                &[("follower", addr)],
                stale,
            );
        }
    }
    crate::obs::mem::publish();
    let mut body = metrics::render();
    body.push_str(&remote);
    Response::text(200, "text/plain; version=0.0.4", body)
}

/// `GET /v1/trace` — snapshot the span ring as one Chrome trace-event
/// JSON document. The first scrape attaches the global recorder
/// (idempotent), so the very first response may be empty — traces cover
/// traffic after it. `--trace-out` enables the recorder at startup
/// instead.
fn get_trace() -> Response {
    trace::enable();
    Response::text(200, "application/json", trace::export_json())
}

/// `POST /v1/failpoints` — test-only chaos control over the process
/// failpoint registry: `{"spec": "site=action;…"}` merges new arms
/// (`site=off` disarms one), `{"clear": true}` disarms everything; both
/// may be combined (clear runs first). Replies with the armed list.
/// `501` unless the binary was built with `--features fail-inject` —
/// production builds physically cannot be chaos-injected.
fn post_failpoints(req: &Request) -> Response {
    if !fail::compiled_in() {
        return Response::error(
            501,
            "failpoints are not compiled in (rebuild with --features fail-inject)",
        );
    }
    let body = match req.json() {
        Ok(b) => b,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    if let Err(resp) = check_keys(&body, &["spec", "clear"]) {
        return resp;
    }
    if body.get("clear").and_then(Json::as_bool).unwrap_or(false) {
        fail::clear();
    }
    if let Some(spec) = body.get("spec").and_then(Json::as_str) {
        if let Err(e) = fail::configure(spec) {
            return Response::error(400, &format!("{e:#}"));
        }
    }
    let armed: Vec<Json> = fail::list()
        .into_iter()
        .map(|(site, action)| {
            Json::obj(vec![("site", Json::str(site)), ("action", Json::str(action))])
        })
        .collect();
    Response::json(200, &Json::obj(vec![("armed", Json::Arr(armed))]))
}

/// Build the route table over the job manager + dataset registry.
fn build_handler(
    manager: Arc<JobManager>,
    registry: Arc<DatasetRegistry>,
    shutdown: Arc<AtomicBool>,
    cfg: ServerConfig,
) -> Handler {
    let fleet_clients: FleetClients = Mutex::new("server.fleet_clients", HashMap::new());
    Arc::new(move |req: &Request| -> Response {
        let segs = req.segments();
        match (req.method.as_str(), segs.as_slice()) {
            ("POST", ["v1", "datasets"]) => post_dataset(&registry, &cfg, req),
            ("GET", ["v1", "datasets"]) => {
                let list: Vec<Json> = registry
                    .summaries()
                    .into_iter()
                    .map(|(name, n, d)| {
                        Json::obj(vec![
                            ("name", Json::str(name)),
                            ("n", num(n as u64)),
                            ("d", num(d as u64)),
                        ])
                    })
                    .collect();
                Response::json(200, &Json::obj(vec![("datasets", Json::Arr(list))]))
            }
            ("POST", ["v1", "datasets", name, "rows"]) => {
                post_rows(&manager, &registry, name, req)
            }
            ("DELETE", ["v1", "datasets", name]) => {
                if registry.remove(name) {
                    // retire the dataset's pooled services with it
                    manager.drop_dataset_services(name);
                    Response::json(
                        200,
                        &Json::obj(vec![
                            ("name", Json::str(*name)),
                            ("deleted", Json::Bool(true)),
                        ]),
                    )
                } else {
                    Response::error(404, &format!("no dataset `{name}`"))
                }
            }
            ("POST", ["v1", "jobs"]) => post_job(&manager, &cfg, req),
            ("POST", ["v1", "score_batch"]) => {
                post_score_batch(&manager, &registry, &cfg, req)
            }
            ("GET", ["v1", "jobs"]) => {
                let list: Vec<Json> = manager
                    .job_ids()
                    .into_iter()
                    .filter_map(|id| manager.snapshot(id))
                    .map(|s| job_json(&s, false))
                    .collect();
                Response::json(200, &Json::obj(vec![("jobs", Json::Arr(list))]))
            }
            ("GET", ["v1", "jobs", id]) => match id.parse::<u64>().ok() {
                Some(id) => match manager.snapshot(id) {
                    Some(snap) => Response::json(200, &job_json(&snap, true)),
                    None => Response::error(404, &format!("no job {id}")),
                },
                None => Response::error(400, "job id must be an integer"),
            },
            ("DELETE", ["v1", "jobs", id]) => match id.parse::<u64>().ok() {
                Some(id) => match manager.cancel(id) {
                    Some(state) => Response::json(
                        200,
                        &Json::obj(vec![("id", num(id)), ("state", Json::str(state.name()))]),
                    ),
                    None => Response::error(404, &format!("no job {id}")),
                },
                None => Response::error(400, "job id must be an integer"),
            },
            ("GET", ["v1", "stats"]) => get_stats(&manager, &registry),
            ("GET", ["v1", "metrics"]) => {
                let fleet = (req.query_param("fleet") == Some("1"))
                    .then_some((cfg.shards.as_slice(), &fleet_clients));
                get_metrics(&manager, &registry, fleet)
            }
            ("GET", ["v1", "trace"]) => get_trace(),
            ("POST", ["v1", "failpoints"]) => post_failpoints(req),
            ("POST", ["v1", "shutdown"]) => {
                shutdown.store(true, Ordering::SeqCst);
                Response::json(200, &Json::obj(vec![("ok", Json::Bool(true))]))
            }
            ("GET", []) | ("GET", ["v1"]) => Response::json(
                200,
                &Json::obj(vec![
                    ("service", Json::str("cvlr discovery server")),
                    ("version", Json::str(env!("CARGO_PKG_VERSION"))),
                ]),
            ),
            (_, ["v1", "datasets"]) | (_, ["v1", "datasets", _])
            | (_, ["v1", "datasets", _, "rows"]) | (_, ["v1", "jobs"])
            | (_, ["v1", "jobs", _]) | (_, ["v1", "score_batch"])
            | (_, ["v1", "metrics"]) | (_, ["v1", "trace"])
            | (_, ["v1", "failpoints"]) => {
                Response::error(405, "method not allowed")
            }
            _ => Response::error(404, &format!("no route for {} {}", req.method, req.path)),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::relabel_exposition;

    /// Label injection covers bare names, labeled series (splicing
    /// before existing labels), and exemplar suffixes, while comments
    /// and blanks are dropped.
    #[test]
    fn relabel_injects_follower_label_per_sample() {
        let text = "# HELP cvlr_requests_total requests\n\
                    # TYPE cvlr_requests_total counter\n\
                    cvlr_requests_total 7\n\
                    cvlr_mem_peak_bytes{scope=\"factorize\"} 4096\n\
                    cvlr_score_batch_seconds_bucket{le=\"0.1\"} 1 # {trace_span=\"17\"} 0.0625\n\
                    \n";
        let out = relabel_exposition(text, "127.0.0.1:7001");
        assert_eq!(
            out,
            "cvlr_requests_total{follower=\"127.0.0.1:7001\"} 7\n\
             cvlr_mem_peak_bytes{follower=\"127.0.0.1:7001\",scope=\"factorize\"} 4096\n\
             cvlr_score_batch_seconds_bucket{follower=\"127.0.0.1:7001\",le=\"0.1\"} 1 # {trace_span=\"17\"} 0.0625\n"
        );
    }

    #[test]
    fn relabel_escapes_label_value() {
        let out = relabel_exposition("m 1\n", "a\"b\\c");
        assert_eq!(out, "m{follower=\"a\\\"b\\\\c\"} 1\n");
    }
}
