//! Minimal HTTP/1.1 wire layer on `std::net` — enough protocol for a
//! JSON API server (and nothing more): keep-alive connections serving
//! requests in sequence (`Connection: close` honored when a client
//! sends it), `Content-Length` bodies, thread per connection, a
//! non-blocking accept loop polling a shutdown flag, and connection
//! drain on the way out. Persistent connections are what makes the
//! distrib shard client (`distrib::client`) cheap: one TCP handshake
//! per follower, reused across every sub-batch of a sweep.
//!
//! Also hosts the matching blocking [`request`] client used by the
//! integration tests, `examples/serve_client.rs`, and anyone scripting
//! the server without curl.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::json::{self, Json};

/// Upper bound on request head (request line + headers).
const MAX_HEAD: usize = 16 * 1024;
/// Upper bound on request bodies (CSV uploads are the big ones).
const MAX_BODY: usize = 64 * 1024 * 1024;
/// Per-connection socket timeout.
const IO_TIMEOUT: Duration = Duration::from_secs(30);
/// Read-poll interval on idle keep-alive connections, so a draining
/// server is noticed within one tick instead of one [`IO_TIMEOUT`].
const IDLE_POLL: Duration = Duration::from_millis(250);
/// How long shutdown waits for **in-flight requests** (handler running
/// or response being written) to complete — a follower mid
/// `/v1/score_batch` gets to answer, the coordinator never sees a
/// half-served sweep. Generous because it only ever binds when a
/// handler is genuinely stuck.
const REQUEST_DRAIN_TIMEOUT: Duration = Duration::from_secs(60);
/// How long shutdown additionally waits for idle connections to notice
/// the drain flag and close.
const DRAIN_TIMEOUT: Duration = Duration::from_secs(5);

/// One parsed request.
pub struct Request {
    pub method: String,
    /// Path without query string.
    pub path: String,
    /// Raw query string (without the `?`), empty when absent.
    pub query: String,
    headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// Value of a `key=value` query parameter (`Some("")` for a bare
    /// `key`). No percent-decoding — the API's parameters are plain
    /// tokens (`fleet=1`).
    pub fn query_param(&self, name: &str) -> Option<&str> {
        self.query.split('&').find_map(|pair| {
            let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
            (k == name).then_some(v)
        })
    }

    /// Parse the body as one strict JSON document.
    pub fn json(&self) -> Result<Json> {
        let text = std::str::from_utf8(&self.body).context("body is not UTF-8")?;
        json::parse(text)
    }

    /// Path split on `/`, empty segments dropped.
    pub fn segments(&self) -> Vec<&str> {
        self.path.split('/').filter(|s| !s.is_empty()).collect()
    }
}

/// A response. Every endpoint speaks JSON (including errors) except
/// `/v1/metrics`, which serves the Prometheus text exposition format.
pub struct Response {
    pub status: u16,
    pub body: String,
    pub content_type: &'static str,
    /// Extra response headers (`Retry-After` on 429/503 overload
    /// replies); `Content-Type`/`Content-Length`/`Connection` are
    /// always emitted by the writer and must not appear here.
    pub headers: Vec<(&'static str, String)>,
}

impl Response {
    pub fn json(status: u16, body: &Json) -> Response {
        Response {
            status,
            body: body.encode(),
            content_type: "application/json",
            headers: Vec::new(),
        }
    }

    /// A non-JSON body with an explicit content type.
    pub fn text(status: u16, content_type: &'static str, body: String) -> Response {
        Response { status, body, content_type, headers: Vec::new() }
    }

    /// `{"error": msg}` with the given status.
    pub fn error(status: u16, msg: &str) -> Response {
        Response::json(status, &Json::obj(vec![("error", Json::str(msg))]))
    }

    /// Attach an extra response header.
    pub fn with_header(mut self, name: &'static str, value: String) -> Response {
        self.headers.push((name, value));
        self
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "",
    }
}

/// The route table: a request in, a response out.
pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync>;

/// A bound listener; `run` is the accept loop.
pub struct HttpServer {
    listener: TcpListener,
    addr: SocketAddr,
}

/// Decrements the active-connection count even if the handler panics,
/// so shutdown drain never waits on a dead connection.
struct ActiveGuard(Arc<AtomicUsize>);

impl Drop for ActiveGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

impl HttpServer {
    /// Bind localhost:`port` (0 picks an ephemeral port).
    pub fn bind(port: u16) -> Result<HttpServer> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("binding 127.0.0.1:{port}"))?;
        let addr = listener.local_addr().context("reading bound address")?;
        // non-blocking accept so the loop can poll the shutdown flag
        listener.set_nonblocking(true).context("set_nonblocking")?;
        Ok(HttpServer { listener, addr })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Accept connections until `shutdown` is set, then drain: first
    /// wait for **in-flight requests** to complete (bounded by
    /// [`REQUEST_DRAIN_TIMEOUT`] — a follower answering
    /// `/v1/score_batch` finishes before the listener goes away), then
    /// give idle keep-alive connections [`DRAIN_TIMEOUT`] to observe
    /// the drain flag and close.
    pub fn run(&self, handler: Handler, shutdown: &AtomicBool) {
        let active = Arc::new(AtomicUsize::new(0));
        let busy = Arc::new(AtomicUsize::new(0));
        let draining = Arc::new(AtomicBool::new(false));
        while !shutdown.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    active.fetch_add(1, Ordering::SeqCst);
                    let guard = ActiveGuard(active.clone());
                    let handler = handler.clone();
                    let busy = busy.clone();
                    let draining = draining.clone();
                    let _ = std::thread::Builder::new()
                        .name("cvlr-http-conn".to_string())
                        .spawn(move || {
                            let _guard = guard;
                            let _ = handle_connection(stream, &handler, &busy, &draining);
                        });
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(_) => std::thread::sleep(Duration::from_millis(5)),
            }
        }
        draining.store(true, Ordering::SeqCst);
        let t0 = Instant::now();
        while busy.load(Ordering::SeqCst) > 0 && t0.elapsed() < REQUEST_DRAIN_TIMEOUT {
            std::thread::sleep(Duration::from_millis(10));
        }
        let t0 = Instant::now();
        while active.load(Ordering::SeqCst) > 0 && t0.elapsed() < DRAIN_TIMEOUT {
            std::thread::sleep(Duration::from_millis(10));
        }
    }
}

fn handle_connection(
    mut stream: TcpStream,
    handler: &Handler,
    busy: &Arc<AtomicUsize>,
    draining: &AtomicBool,
) -> Result<()> {
    // some platforms hand accepted sockets the listener's non-blocking
    // mode; connection I/O below wants blocking reads with timeouts.
    // The short read timeout is the idle-drain poll tick — read_request
    // accumulates ticks up to IO_TIMEOUT for a genuinely slow peer.
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(IDLE_POLL));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    // bytes read past the previous request's body (a pipelined next
    // request head) — fed back into the next read_request
    let mut carry: Vec<u8> = Vec::new();
    loop {
        if draining.load(Ordering::SeqCst) {
            return Ok(());
        }
        let req = match read_request(&mut stream, &mut carry, draining) {
            Ok(Some(req)) => req,
            // clean close between requests: the client is done (or the
            // server is draining and no request had started)
            Ok(None) => return Ok(()),
            Err(e) => {
                let resp = Response::error(400, &format!("{e:#}"));
                return write_response(&mut stream, &resp, false);
            }
        };
        let keep_alive = !req
            .header("connection")
            .map(|v| v.eq_ignore_ascii_case("close"))
            .unwrap_or(false);
        // count the request as in-flight while the handler runs and the
        // response goes out: shutdown's first drain phase waits on this
        // (guard, so a panicking handler can't wedge the drain)
        let resp = {
            busy.fetch_add(1, Ordering::SeqCst);
            let _busy = ActiveGuard(busy.clone());
            let resp = handler(&req);
            // a draining server finishes the in-flight request, then
            // closes — advertise it so the client re-connects elsewhere
            let keep = keep_alive && !draining.load(Ordering::SeqCst);
            write_response(&mut stream, &resp, keep)?;
            keep
        };
        if !resp {
            return Ok(());
        }
    }
}

fn read_request(
    stream: &mut TcpStream,
    carry: &mut Vec<u8>,
    draining: &AtomicBool,
) -> Result<Option<Request>> {
    // read until the blank line separating head from body; reads tick
    // every IDLE_POLL so an idle keep-alive connection notices a
    // draining server long before IO_TIMEOUT
    let mut buf: Vec<u8> = std::mem::take(carry);
    let mut waited = Duration::ZERO;
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_HEAD {
            bail!("request head larger than {MAX_HEAD} bytes");
        }
        let mut chunk = [0u8; 4096];
        match stream.read(&mut chunk) {
            Ok(0) => {
                if buf.is_empty() {
                    return Ok(None);
                }
                bail!("connection closed mid-request");
            }
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                // idle poll tick: close cleanly when the server is
                // draining and no request has started; a request mid-head
                // keeps its full IO_TIMEOUT allowance
                if buf.is_empty() && draining.load(Ordering::SeqCst) {
                    return Ok(None);
                }
                waited += IDLE_POLL;
                if waited >= IO_TIMEOUT {
                    return Err(e).context("reading request head");
                }
            }
            Err(e) => return Err(e).context("reading request head"),
        }
    };
    let head = std::str::from_utf8(&buf[..head_end]).context("request head is not UTF-8")?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or_default();
    let mut parts = request_line.split(' ');
    let method = parts.next().unwrap_or_default().to_string();
    let target = parts.next().unwrap_or_default();
    let version = parts.next().unwrap_or_default();
    if method.is_empty() || target.is_empty() || !version.starts_with("HTTP/1.") {
        bail!("malformed request line `{request_line}`");
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (k, v) = line.split_once(':').context("malformed header line")?;
        headers.push((k.trim().to_string(), v.trim().to_string()));
    }
    let req = Request { method, path, query, headers, body: Vec::new() };
    let content_length: usize = match req.header("content-length") {
        Some(v) => v.trim().parse().context("bad content-length")?,
        None => 0,
    };
    if content_length > MAX_BODY {
        bail!("body larger than {MAX_BODY} bytes");
    }
    // curl sends `Expect: 100-continue` for bodies over 1 KB and waits
    // ~1 s for the go-ahead before uploading — answer it so CSV uploads
    // don't stall
    if let Some(expect) = req.header("expect") {
        if expect.to_ascii_lowercase().contains("100-continue") {
            stream
                .write_all(b"HTTP/1.1 100 Continue\r\n\r\n")
                .context("writing 100 Continue")?;
            stream.flush().context("flushing 100 Continue")?;
        }
    }
    let mut body = buf.split_off(head_end + 4);
    let mut waited = Duration::ZERO;
    while body.len() < content_length {
        let mut chunk = [0u8; 8192];
        match stream.read(&mut chunk) {
            Ok(0) => bail!("connection closed mid-body"),
            Ok(n) => body.extend_from_slice(&chunk[..n]),
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                // mid-body: the request has started, so draining does
                // not abort it — only the cumulative IO timeout does
                waited += IDLE_POLL;
                if waited >= IO_TIMEOUT {
                    return Err(e).context("reading request body");
                }
            }
            Err(e) => return Err(e).context("reading request body"),
        }
    }
    // bytes past the body belong to the next pipelined request
    *carry = body.split_off(content_length);
    Ok(Some(Request { body, ..req }))
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn write_response(stream: &mut TcpStream, resp: &Response, keep_alive: bool) -> Result<()> {
    let connection = if keep_alive { "keep-alive" } else { "close" };
    let mut extra = String::new();
    for (name, value) in &resp.headers {
        extra.push_str(name);
        extra.push_str(": ");
        extra.push_str(value);
        extra.push_str("\r\n");
    }
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {connection}\r\n{extra}\r\n",
        resp.status,
        reason(resp.status),
        resp.content_type,
        resp.body.len()
    );
    stream.write_all(head.as_bytes()).context("writing response head")?;
    stream.write_all(resp.body.as_bytes()).context("writing response body")?;
    stream.flush().context("flushing response")?;
    Ok(())
}

/// Blocking one-shot client: send `body` as JSON, return (status,
/// parsed body). An empty response body parses as `Json::Null`.
pub fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&Json>,
) -> Result<(u16, Json)> {
    let (status, text) = request_raw(addr, method, path, body)?;
    let value = if text.trim().is_empty() { Json::Null } else { json::parse(&text)? };
    Ok((status, value))
}

/// Like [`request`], but returns the response body verbatim — for
/// endpoints that do not speak JSON (`/v1/metrics`).
pub fn request_raw(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&Json>,
) -> Result<(u16, String)> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let payload = body.map(|b| b.encode()).unwrap_or_default();
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        payload.len()
    );
    stream.write_all(head.as_bytes()).context("writing request")?;
    stream.write_all(payload.as_bytes()).context("writing request body")?;
    stream.flush().context("flushing request")?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).context("reading response")?;
    let head_end = find_head_end(&raw).context("no response head terminator")?;
    let head = std::str::from_utf8(&raw[..head_end]).context("response head not UTF-8")?;
    let status_line = head.split("\r\n").next().unwrap_or_default();
    let status: u16 = status_line
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("bad status line `{status_line}`"))?;
    let body_text = std::str::from_utf8(&raw[head_end + 4..]).context("response body not UTF-8")?;
    Ok((status, body_text.to_string()))
}

#[cfg(test)]
mod tests {
    use super::Request;

    fn req(path: &str, query: &str) -> Request {
        Request {
            method: "GET".to_string(),
            path: path.to_string(),
            query: query.to_string(),
            headers: Vec::new(),
            body: Vec::new(),
        }
    }

    #[test]
    fn query_param_parses_pairs_and_bare_keys() {
        let r = req("/v1/metrics", "fleet=1&verbose");
        assert_eq!(r.query_param("fleet"), Some("1"));
        assert_eq!(r.query_param("verbose"), Some(""));
        assert_eq!(r.query_param("missing"), None);
        let none = req("/v1/metrics", "");
        assert_eq!(none.query_param("fleet"), None);
    }
}
