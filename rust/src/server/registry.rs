//! The dataset registry: named datasets a long-running server can
//! score against — the paper's built-in workloads (synth / SACHS /
//! CHILD / continuous-SACHS) plus CSV uploads ingested with per-column
//! continuous/discrete type inference.
//!
//! The same ingestion path backs the CLI (`cvlr discover --data
//! file.csv`), so file workloads behave identically with and without
//! the server.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::data::synth::{generate, SynthConfig};
use crate::data::{networks, Dataset};
use crate::linalg::Mat;
use crate::util::csv::parse_csv;
use crate::util::lockorder::Mutex;

/// Discrete-column inference cap: an all-integer column with more
/// distinct levels than this is treated as continuous (an ID-like
/// column is not a categorical variable).
const MAX_INFERRED_LEVELS: usize = 20;

/// Cap on distinct string levels for a categorical (non-numeric)
/// column; beyond this the upload is rejected as ill-typed.
const MAX_STRING_LEVELS: usize = 64;

/// Materialize one of the paper's built-in workloads by name.
pub fn builtin_dataset(name: &str, n: usize, seed: u64) -> Option<Dataset> {
    let _mem = crate::obs::mem::MemScope::enter(crate::obs::mem::Scope::Dataset);
    match name {
        "synth" => Some(generate(&SynthConfig { n, seed, ..Default::default() }).0),
        "sachs" => {
            let net = networks::sachs();
            Some(networks::forward_sample(&net, n, seed))
        }
        "child" => {
            let net = networks::child();
            Some(networks::forward_sample(&net, n, seed))
        }
        "sachs-cont" => Some(networks::sachs_continuous(n, seed).0),
        _ => None,
    }
}

/// Names `builtin_dataset` understands.
pub const BUILTIN_NAMES: [&str; 4] = ["synth", "sachs", "child", "sachs-cont"];

/// Ingest CSV text into a [`Dataset`] with per-column type inference.
///
/// * `header`: `Some(true)`/`Some(false)` force the first row to be a
///   header / data; `None` auto-detects (the first row is a header when
///   some column is numeric in every body row but not in row one).
/// * A column is **continuous** when every field parses as `f64`;
///   it is **discrete** when additionally every value is a non-negative
///   integer with at most [`MAX_INFERRED_LEVELS`] distinct levels.
///   Non-numeric columns are categorical (discrete) with string levels.
/// * Discrete levels are recoded to contiguous `0..k` codes (sorted by
///   original value, so the coding is deterministic); continuous
///   columns are z-score standardized, which stabilizes kernel widths
///   (see [`Dataset::standardize`]).
/// * Empty fields are rejected — there is no missing-data handling.
pub fn dataset_from_csv(text: &str, header: Option<bool>) -> Result<Dataset> {
    let _mem = crate::obs::mem::MemScope::enter(crate::obs::mem::Scope::Dataset);
    let rows = parse_csv(text)?;
    if rows.is_empty() {
        bail!("csv: no rows");
    }
    let arity = rows[0].len();
    for (i, r) in rows.iter().enumerate() {
        for (j, f) in r.iter().enumerate() {
            if f.trim().is_empty() {
                bail!(
                    "csv: empty field at row {}, column {} (missing data is not supported)",
                    i + 1,
                    j + 1
                );
            }
            // reject NaN/±inf loudly: treating them as a string level
            // would silently corrupt kernel evaluations downstream
            if let Ok(v) = f.trim().parse::<f64>() {
                if !v.is_finite() {
                    bail!(
                        "csv: non-finite value `{}` at row {}, column {} \
                         (NaN/±inf cannot enter kernel evaluations)",
                        f.trim(),
                        i + 1,
                        j + 1
                    );
                }
            }
        }
    }
    let numeric = |s: &str| s.trim().parse::<f64>().ok().filter(|v| v.is_finite());

    let has_header = match header {
        Some(h) => h,
        None => {
            // header iff some column is numeric in every body row but
            // not in the first row (needs at least one body row)
            rows.len() > 1
                && (0..arity).any(|j| {
                    numeric(&rows[0][j]).is_none()
                        && rows[1..].iter().all(|r| numeric(&r[j]).is_some())
                })
        }
    };
    let (names, body): (Vec<String>, &[Vec<String>]) = if has_header {
        (rows[0].clone(), &rows[1..])
    } else {
        ((0..arity).map(|j| format!("X{}", j + 1)).collect(), &rows[..])
    };
    if body.is_empty() {
        bail!("csv: header but no data rows");
    }
    let n = body.len();

    let mut data = Mat::zeros(n, arity);
    let mut discrete = vec![false; arity];
    for j in 0..arity {
        let parsed: Option<Vec<f64>> = body.iter().map(|r| numeric(&r[j])).collect();
        // discrete iff every field is *formatted* as a non-negative
        // integer ("1.0" reads as continuous, "1" as a level) with few
        // distinct levels
        let ints: Option<Vec<i64>> = body
            .iter()
            .map(|r| r[j].trim().parse::<i64>().ok().filter(|v| *v >= 0))
            .collect();
        let levels_of = |iv: &[i64]| {
            let mut distinct = iv.to_vec();
            distinct.sort_unstable();
            distinct.dedup();
            distinct
        };
        match (parsed, ints) {
            (_, Some(iv)) if levels_of(&iv).len() <= MAX_INFERRED_LEVELS => {
                let distinct = levels_of(&iv);
                discrete[j] = true;
                for (r, v) in iv.iter().enumerate() {
                    // recode to contiguous 0..k (sorted by value)
                    data[(r, j)] = distinct.binary_search(v).unwrap() as f64;
                }
            }
            (Some(vals), _) => {
                for (r, v) in vals.iter().enumerate() {
                    data[(r, j)] = *v;
                }
            }
            (None, _) => {
                // categorical column: sorted distinct strings → codes
                let mut levels: Vec<&str> = body.iter().map(|r| r[j].trim()).collect();
                levels.sort_unstable();
                levels.dedup();
                if levels.len() > MAX_STRING_LEVELS {
                    bail!(
                        "csv: column `{}` has {} distinct string levels (max {MAX_STRING_LEVELS})",
                        names[j],
                        levels.len()
                    );
                }
                discrete[j] = true;
                for (r, row) in body.iter().enumerate() {
                    let code = levels.binary_search(&row[j].trim()).unwrap();
                    data[(r, j)] = code as f64;
                }
            }
        }
    }

    let mut ds = Dataset::from_columns(data, &discrete);
    for (v, name) in ds.vars.iter_mut().zip(names) {
        v.name = name;
    }
    ds.standardize();
    Ok(ds)
}

/// Read and ingest a CSV file from disk (the CLI `--data file.csv`
/// path; same inference as server uploads).
pub fn dataset_from_csv_file(path: &str, header: Option<bool>) -> Result<Dataset> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    dataset_from_csv(&text, header).map_err(|e| e.context(format!("ingesting {path}")))
}

/// Parse header-less CSV rows in an existing dataset's column layout
/// (the `POST /v1/datasets/{name}/rows` append body): arity must match,
/// every field must be numeric and finite. Values are interpreted in
/// the dataset's **internal coordinates** — continuous columns in the
/// registered (z-scored) scale, discrete columns as 0-based level codes
/// — and the level-code / finiteness validation itself happens in
/// [`Dataset::append_rows`].
pub fn rows_from_csv(ds: &Dataset, text: &str) -> Result<Mat> {
    let rows = parse_csv(text)?;
    if rows.is_empty() {
        bail!("csv: no rows to append");
    }
    let arity = ds.data.cols;
    if rows[0].len() != arity {
        bail!(
            "csv: append rows have {} fields, dataset has {} columns",
            rows[0].len(),
            arity
        );
    }
    let mut m = Mat::zeros(rows.len(), arity);
    for (i, r) in rows.iter().enumerate() {
        for (j, f) in r.iter().enumerate() {
            let v: f64 = f
                .trim()
                .parse()
                .map_err(|_| anyhow!("append row {}: field `{}` is not numeric", i + 1, f.trim()))?;
            if !v.is_finite() {
                bail!(
                    "append row {}: non-finite value `{}` in column {}",
                    i + 1,
                    f.trim(),
                    j + 1
                );
            }
            m[(i, j)] = v;
        }
    }
    Ok(m)
}

/// Named datasets shared by every job of a server process. Each entry
/// carries a registry-wide monotonic **version**, bumped on every
/// insert/replace — consumers that cache per-dataset state (the job
/// manager's score-service pool) key on (name, version) so a replaced
/// dataset never serves stale caches.
pub struct DatasetRegistry {
    inner: Mutex<RegistryInner>,
}

struct RegistryInner {
    datasets: HashMap<String, (Arc<Dataset>, u64)>,
    next_version: u64,
}

impl DatasetRegistry {
    /// Empty registry.
    pub fn new() -> DatasetRegistry {
        DatasetRegistry {
            inner: Mutex::new(
                "registry.inner",
                RegistryInner { datasets: HashMap::new(), next_version: 0 },
            ),
        }
    }

    /// Registry pre-loaded with the built-in workloads, each sampled at
    /// `n` rows with `seed`.
    pub fn with_builtins(n: usize, seed: u64) -> DatasetRegistry {
        let reg = DatasetRegistry::new();
        for name in BUILTIN_NAMES {
            let ds = builtin_dataset(name, n, seed).expect("builtin");
            reg.insert(name, Arc::new(ds)).expect("valid builtin name");
        }
        reg
    }

    /// Register (or replace) a dataset under `name`. Returns `true` when
    /// an existing dataset was replaced. Names are restricted to
    /// `[A-Za-z0-9._-]` so they embed cleanly in URLs and logs.
    pub fn insert(&self, name: &str, ds: Arc<Dataset>) -> Result<bool> {
        if name.is_empty()
            || !name.chars().all(|c| c.is_ascii_alphanumeric() || "._-".contains(c))
        {
            bail!("invalid dataset name `{name}` (use [A-Za-z0-9._-])");
        }
        let mut inner = self.inner.lock();
        let version = inner.next_version;
        inner.next_version += 1;
        Ok(inner.datasets.insert(name.to_string(), (ds, version)).is_some())
    }

    /// Ingest CSV text and register it under `name`.
    pub fn register_csv(
        &self,
        name: &str,
        csv_text: &str,
        header: Option<bool>,
    ) -> Result<Arc<Dataset>> {
        let ds = Arc::new(dataset_from_csv(csv_text, header)?);
        self.insert(name, ds.clone())?;
        Ok(ds)
    }

    pub fn get(&self, name: &str) -> Option<Arc<Dataset>> {
        self.entry(name).map(|(ds, _)| ds)
    }

    /// Remove `name`; returns whether it existed. Running jobs keep
    /// their own `Arc<Dataset>`; queued jobs on the name fail cleanly.
    pub fn remove(&self, name: &str) -> bool {
        self.inner.lock().datasets.remove(name).is_some()
    }

    /// Append validated rows to `name` **in place**: the registry
    /// version is kept (pooled services are refreshed against the new
    /// snapshot, not retired like on a replace), while the dataset's
    /// own row [`Dataset::version`] is bumped. Returns the updated
    /// snapshot and its row version.
    ///
    /// The appended snapshot is built *outside* the registry lock —
    /// cloning a large sample matrix must not block unrelated lookups —
    /// and swapped in compare-and-set style: if the entry was replaced,
    /// removed, or appended-to concurrently in the meantime, the append
    /// fails with a retry error instead of silently dropping rows.
    pub fn append_rows(&self, name: &str, rows: &Mat) -> Result<(Arc<Dataset>, u64)> {
        let _mem = crate::obs::mem::MemScope::enter(crate::obs::mem::Scope::Dataset);
        let (ds, version) =
            self.entry(name).ok_or_else(|| anyhow!("no dataset `{name}`"))?;
        let mut updated = (*ds).clone();
        updated.append_rows(rows)?;
        let row_version = updated.version();
        let arc = Arc::new(updated);
        let mut inner = self.inner.lock();
        match inner.datasets.get(name) {
            Some((cur, v)) if *v == version && Arc::ptr_eq(cur, &ds) => {
                inner.datasets.insert(name.to_string(), (arc.clone(), version));
                Ok((arc, row_version))
            }
            _ => Err(super::TransientConflict(format!(
                "dataset `{name}` changed during the append; retry"
            ))
            .into()),
        }
    }

    /// The dataset plus its registration version (bumped on replace).
    pub fn entry(&self, name: &str) -> Option<(Arc<Dataset>, u64)> {
        self.inner.lock().datasets.get(name).cloned()
    }

    /// Registered names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> =
            self.inner.lock().datasets.keys().cloned().collect();
        names.sort();
        names
    }

    /// (name, samples, variables) summaries, sorted by name.
    pub fn summaries(&self) -> Vec<(String, usize, usize)> {
        let mut out: Vec<(String, usize, usize)> = self
            .inner
            .lock()
            .datasets
            .iter()
            .map(|(name, (ds, _))| (name.clone(), ds.n(), ds.d()))
            .collect();
        out.sort();
        out
    }
}

impl Default for DatasetRegistry {
    fn default() -> Self {
        DatasetRegistry::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_with_header_types_and_names() {
        let text = "height,group,label\n1.5,0,yes\n2.5,1,no\n3.5,0,yes\n";
        let ds = dataset_from_csv(text, None).unwrap();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.vars[0].name, "height");
        assert!(!ds.vars[0].discrete, "floats are continuous");
        assert!(ds.vars[1].discrete, "small-cardinality integers are discrete");
        assert_eq!(ds.vars[1].cardinality, 2);
        assert!(ds.vars[2].discrete, "strings are categorical");
        assert_eq!(ds.vars[2].cardinality, 2);
        // "no" < "yes" in sorted order → no=0, yes=1
        assert_eq!(ds.block(2).data, vec![1.0, 0.0, 1.0]);
    }

    #[test]
    fn csv_without_header_autodetects() {
        let text = "1.0,2.0\n3.0,4.0\n";
        let ds = dataset_from_csv(text, None).unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.vars[0].name, "X1");
    }

    #[test]
    fn discrete_levels_recode_contiguously() {
        // levels {2, 5, 9} must become codes {0, 1, 2}
        let text = "5\n2\n9\n2\n";
        let ds = dataset_from_csv(text, Some(false)).unwrap();
        assert!(ds.vars[0].discrete);
        assert_eq!(ds.vars[0].cardinality, 3);
        assert_eq!(ds.block(0).data, vec![1.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn high_cardinality_integers_are_continuous() {
        let rows: Vec<String> = (0..40).map(|i| i.to_string()).collect();
        let ds = dataset_from_csv(&rows.join("\n"), Some(false)).unwrap();
        assert!(!ds.vars[0].discrete, "40 distinct integers is not categorical");
    }

    #[test]
    fn empty_fields_rejected() {
        assert!(dataset_from_csv("a,b\n1,\n", None).is_err());
    }

    #[test]
    fn non_finite_values_rejected_with_position() {
        for bad in ["NaN", "nan", "inf", "-inf", "Infinity"] {
            let text = format!("a,b\n1.0,2.0\n{bad},4.0\n");
            let err = dataset_from_csv(&text, None).unwrap_err().to_string();
            assert!(err.contains("non-finite"), "`{bad}`: {err}");
            assert!(err.contains("row 3"), "`{bad}` must report its row: {err}");
        }
    }

    #[test]
    fn append_rows_roundtrip_keeps_registry_version() {
        let reg = DatasetRegistry::new();
        reg.register_csv("s", "0\n1\n0\n1\n", Some(false)).unwrap();
        let (ds0, v0) = reg.entry("s").unwrap();
        assert_eq!(ds0.n(), 4);
        let rows = rows_from_csv(&ds0, "1\n0\n").unwrap();
        let (ds1, row_version) = reg.append_rows("s", &rows).unwrap();
        assert_eq!(ds1.n(), 6);
        assert_eq!(row_version, 1);
        let (_, v1) = reg.entry("s").unwrap();
        assert_eq!(v0, v1, "appends must not bump the registry version");
        // malformed append bodies are rejected
        assert!(rows_from_csv(&ds1, "1,2\n").is_err(), "arity mismatch");
        assert!(rows_from_csv(&ds1, "oops\n").is_err(), "non-numeric");
        assert!(rows_from_csv(&ds1, "inf\n").is_err(), "non-finite");
        assert!(reg.append_rows("missing", &rows).is_err());
    }

    #[test]
    fn registry_roundtrip_and_validation() {
        let reg = DatasetRegistry::new();
        let ds = reg.register_csv("t1", "1.0,2.0\n3.0,4.0\n", Some(false)).unwrap();
        assert_eq!(ds.d(), 2);
        assert!(reg.get("t1").is_some());
        assert!(reg.get("missing").is_none());
        assert_eq!(reg.names(), vec!["t1"]);
        assert!(reg.insert("bad name", ds).is_err());
        assert_eq!(reg.summaries(), vec![("t1".to_string(), 2, 2)]);
    }

    #[test]
    fn replacing_a_dataset_bumps_its_version() {
        let reg = DatasetRegistry::new();
        reg.register_csv("v", "1.0\n2.0\n", Some(false)).unwrap();
        let (_, v1) = reg.entry("v").unwrap();
        reg.register_csv("v", "3.0\n4.0\n", Some(false)).unwrap();
        let (_, v2) = reg.entry("v").unwrap();
        assert!(v2 > v1, "replacement must bump the version ({v1} → {v2})");
    }

    #[test]
    fn builtins_materialize() {
        let reg = DatasetRegistry::with_builtins(60, 0);
        for name in BUILTIN_NAMES {
            let ds = reg.get(name).unwrap();
            assert_eq!(ds.n(), 60, "{name}");
            assert!(ds.d() > 1, "{name}");
        }
    }
}
