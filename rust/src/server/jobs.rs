//! The async job subsystem: a submit/poll/cancel queue over the
//! discovery engine, drained by a worker pool.
//!
//! Jobs move `Queued → Running → Done | Failed | Cancelled`. Score-based
//! jobs run batched GES against a [`ScoreService`] drawn from a pool
//! keyed by (dataset, method, engine) — the score cache therefore
//! persists *across* jobs, so a repeated or overlapping workload is
//! served from memo hits instead of re-evaluation (`/v1/stats` exposes
//! the per-service counters, including evictions from the bounded
//! cache). Search-based methods (PC / MM-MB) run through the engine's
//! registry end to end.
//!
//! Cancellation is cooperative and honored mid-sweep: the service is
//! wrapped per job in a [`CancelBackend`] that submits the sweep as a
//! few wide sub-batches (wide, so batch amortization survives) and
//! stops between them once the flag is set. The
//! padded sweep may let GES apply one bogus operator, but the following
//! sweep scores as an all-zero surface and terminates the search; the
//! partial result is then discarded and the job reports `Cancelled`.
//!
//! Deadlines ride the same wrapper: a job's `deadline_ms` becomes a
//! [`Budget`] armed at **submit** (queue wait counts), checked between
//! sub-batches and pushed into the backing service so a sharding
//! backend clamps its dispatch/retry decisions by it. An expired budget
//! discards the partial result and fails the job with a typed
//! [`DeadlineExceeded`]. Overload protection is admission-side: the
//! queue is bounded ([`JobLimits::max_queued`]) and a live-heap
//! high-water mark ([`JobLimits::mem_high_water`]) sheds the pooled
//! service caches before refusing new jobs — both surface as typed
//! [`Overloaded`] errors (HTTP 429/503 + `Retry-After`).

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{
    resolve_method, run_named, score_backend_for, DiscoveryConfig, MethodKind, ScoreService,
    ServiceStats,
};
use crate::data::Dataset;
use crate::graph::Pdag;
use crate::obs::{fail, metrics};
use crate::score::{ScoreBackend, ScoreRequest};
use crate::search::ges::ges_from;
use crate::util::lockorder::{Condvar, Mutex};
use crate::util::{Budget, DeadlineExceeded, Overloaded, Stopwatch};

use super::registry::DatasetRegistry;

/// The cancel-aware wrapper splits a sweep into at most this many
/// sub-batches, checking the cancel flag between them. Few, wide chunks
/// keep the batch amortization (shared factors, device dispatch) the
/// batch-first API exists for; the cancel latency bound is one chunk.
const CANCEL_CHECKS_PER_SWEEP: usize = 8;

/// Sweeps below this size are never split — chunking tiny batches
/// costs amortization and buys no meaningful cancel latency.
const MIN_CANCEL_CHUNK: usize = 32;

/// Terminal jobs retained for polling; beyond this the oldest
/// done/failed/cancelled jobs are dropped (queued/running jobs are
/// never pruned). Bounds manager memory in a long-lived server the
/// same way the score cache bound does.
const MAX_RETAINED_TERMINAL_JOBS: usize = 1024;

/// Pooled score services kept warm; creating one beyond this evicts
/// the least-recently-used entry (running jobs keep their own `Arc`,
/// only the shared cache handle is dropped). Together with the
/// per-cache capacity this bounds server memory by
/// `MAX_POOLED_SERVICES × cache_capacity` entries.
const MAX_POOLED_SERVICES: usize = 32;

/// Overload-protection knobs of a [`JobManager`].
#[derive(Clone, Copy, Debug)]
pub struct JobLimits {
    /// Queued-but-not-running jobs admitted before `submit` refuses
    /// with a typed [`Overloaded`] (HTTP 429 + `Retry-After`). Running
    /// jobs don't count — the bound is on *waiting* work.
    pub max_queued: usize,
    /// Live-heap high-water mark in bytes, checked against
    /// `obs::mem::live_bytes()` at submit. Above it the manager sheds
    /// every pooled service cache (score memos and, through the dropped
    /// backends, their fold-core caches) and — if the heap is still
    /// over — refuses the job with [`Overloaded`] (HTTP 503). `None`
    /// disables the guard; without the `mem-profile` feature
    /// `live_bytes()` is always 0, so the guard is inert either way.
    pub mem_high_water: Option<u64>,
}

impl Default for JobLimits {
    fn default() -> JobLimits {
        JobLimits { max_queued: 256, mem_high_water: None }
    }
}

/// Lifecycle of a job.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl JobState {
    /// Wire name (lower-case).
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled)
    }
}

/// What to run: a registered dataset, a registered method, and the
/// engine knobs.
#[derive(Clone)]
pub struct JobSpec {
    pub dataset: String,
    pub method: String,
    pub cfg: DiscoveryConfig,
    /// Start GES from the pooled service's last discovered CPDAG
    /// (stored by every completed score job) instead of the empty
    /// graph — the cheap re-discovery path after a dataset append.
    /// Ignored by search-based methods; a cold run when no prior CPDAG
    /// exists.
    pub warm_start: bool,
}

/// Monotonic per-job progress, written by the score path mid-run.
#[derive(Default)]
struct JobProgress {
    /// Sweeps (score batches) completed.
    sweeps: AtomicU64,
    /// Candidate operators scored (GES submits two requests per
    /// candidate: parent set with and without x).
    candidates: AtomicU64,
}

/// Final output of a finished job.
#[derive(Clone)]
pub struct JobResult {
    pub cpdag: Pdag,
    pub seconds: f64,
    /// Canonical method key that ran.
    pub method: String,
    /// Stats of the shared service at completion (score methods only);
    /// cumulative across every job that used the service.
    pub stats: Option<ServiceStats>,
    pub ci_tests: Option<u64>,
}

struct Job {
    id: u64,
    spec: JobSpec,
    /// Canonical method key (resolved at submit).
    canon_method: String,
    state: Mutex<JobState>,
    cancel: AtomicBool,
    /// Deadline budget armed at submit time — queue wait counts against
    /// it, which is what makes the deadline end-to-end.
    budget: Budget,
    progress: JobProgress,
    /// Shared-service counters at job start — polls report this job's
    /// activity as the delta against the live (or final) counters.
    stats_at_start: Mutex<Option<ServiceStats>>,
    /// The pooled service while the job runs (for live progress).
    service: Mutex<Option<Arc<ScoreService>>>,
    result: Mutex<Option<JobResult>>,
    error: Mutex<Option<String>>,
}

/// Poll-time view of a job.
#[derive(Clone)]
pub struct JobSnapshot {
    pub id: u64,
    pub dataset: String,
    pub method: String,
    pub state: JobState,
    /// Sweeps (score batches) completed so far.
    pub sweeps: u64,
    /// Candidate operators scored so far.
    pub candidates: u64,
    /// Score requests this job issued against the shared service
    /// (counter delta since job start; approximate while other jobs
    /// run concurrently on the same service).
    pub requests: u64,
    /// How many of those were served from the shared cache.
    pub cache_hits: u64,
    /// Fresh backend evaluations this job triggered.
    pub evaluations: u64,
    pub result: Option<JobResult>,
    pub error: Option<String>,
}

impl JobSnapshot {
    /// Fraction of this job's requests served from the shared cache.
    pub fn cache_hit_rate(&self) -> f64 {
        self.cache_hits as f64 / self.requests.max(1) as f64
    }
}

// (dataset name, dataset version, method, engine, lowrank method,
// comma-joined shard fleet). The version comes from the registry and is
// bumped on replacement, so re-uploading a dataset under the same name
// can never hit a stale service/cache; the lowrank component keeps
// `icl` and `rff` jobs on separate pools — their factors (and therefore
// every memoized score) differ. Deliberately keyed for EVERY method,
// not just cv-lr: the registry accepts custom score factories that may
// also read `cfg.lowrank`, and for lowrank-agnostic methods (bic, ...)
// the only cost of a spurious `lowrank` option is a duplicate
// (LRU-bounded) pool entry — far cheaper than sharing a cache between
// backends whose scores actually differ. The shards component keeps
// sharded and local jobs on separate services: their *scores* are
// bit-identical by construction, but their backends (and follower
// counters) are not interchangeable.
type ServiceKey = (String, u64, String, String, String, String);

/// A pooled service plus its LRU stamp (monotonic use counter) and the
/// config that built its backend (needed to rebuild the backend over an
/// appended dataset snapshot — see
/// [`JobManager::refresh_dataset_services`]).
struct PoolEntry {
    service: Arc<ScoreService>,
    last_use: u64,
    cfg: DiscoveryConfig,
}

/// The job manager: queue, worker pool, and the per-(dataset, method,
/// engine) pool of memoizing score services.
pub struct JobManager {
    registry: Arc<DatasetRegistry>,
    jobs: Mutex<HashMap<u64, Arc<Job>>>,
    queue: Mutex<VecDeque<u64>>,
    queue_cv: Condvar,
    next_id: AtomicU64,
    services: Mutex<HashMap<ServiceKey, PoolEntry>>,
    /// Datasets with an append in flight ([`JobManager::begin_append`]):
    /// submissions against them are refused until the guard drops.
    /// Lock order: `appending` before `jobs` — `submit` holds it across
    /// the job-map insert, which is what makes the no-active-jobs check
    /// and the append marker atomic with respect to each other.
    appending: Mutex<HashSet<String>>,
    /// Monotonic counter stamping pool hits for LRU eviction.
    pool_clock: AtomicU64,
    shutdown: AtomicBool,
    workers: Mutex<Vec<JoinHandle<()>>>,
    /// Cache bound applied when a job spec leaves `cache_capacity`
    /// unset — a long-lived server must not grow memo maps unboundedly.
    default_cache_capacity: Option<usize>,
    limits: JobLimits,
}

impl JobManager {
    /// Spawn a manager draining the queue with `workers` threads, under
    /// the default [`JobLimits`].
    pub fn start(
        registry: Arc<DatasetRegistry>,
        workers: usize,
        default_cache_capacity: Option<usize>,
    ) -> Arc<JobManager> {
        let limits = JobLimits::default();
        JobManager::start_with_limits(registry, workers, default_cache_capacity, limits)
    }

    /// [`JobManager::start`] with explicit overload-protection limits.
    pub fn start_with_limits(
        registry: Arc<DatasetRegistry>,
        workers: usize,
        default_cache_capacity: Option<usize>,
        limits: JobLimits,
    ) -> Arc<JobManager> {
        let mgr = Arc::new(JobManager {
            registry,
            jobs: Mutex::new("jobs.map", HashMap::new()),
            queue: Mutex::new("jobs.queue", VecDeque::new()),
            queue_cv: Condvar::new(),
            next_id: AtomicU64::new(0),
            services: Mutex::new("jobs.services", HashMap::new()),
            appending: Mutex::new("jobs.appending", HashSet::new()),
            pool_clock: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            workers: Mutex::new("jobs.workers", Vec::new()),
            default_cache_capacity,
            limits,
        });
        let mut handles = Vec::new();
        for i in 0..workers.max(1) {
            let m = mgr.clone();
            let h = std::thread::Builder::new()
                .name(format!("cvlr-job-{i}"))
                .spawn(move || m.worker_loop())
                .expect("spawn job worker");
            handles.push(h);
        }
        *mgr.workers.lock() = handles;
        mgr
    }

    /// Enqueue a job. Validates the dataset and method names up front so
    /// misspellings fail at submit, not minutes later in a worker, and
    /// applies the overload guards of [`JobLimits`]: a saturated
    /// admission queue or a breached memory high-water mark refuses the
    /// job with a typed [`Overloaded`] instead of queueing work the
    /// server can't absorb.
    pub fn submit(&self, spec: JobSpec) -> Result<u64> {
        if self.shutdown.load(Ordering::SeqCst) {
            bail!("server is shutting down");
        }
        let queued = self.queue.lock().len();
        if queued >= self.limits.max_queued {
            metrics::shed_total().inc();
            return Err(Overloaded::new(format!(
                "admission queue full ({queued}/{} jobs queued)",
                self.limits.max_queued
            ))
            .retry_after(Duration::from_secs(1))
            .into());
        }
        if let Some(high_water) = self.limits.mem_high_water {
            let live = crate::obs::mem::live_bytes();
            if live > high_water {
                // shed the warm caches first: the pooled score memos and
                // (through the dropped backend Arcs) their fold-core
                // caches are the only server-held memory that can be
                // released without touching running jobs
                let dropped = self.shed_services();
                metrics::shed_total().add(dropped.max(1));
                // no retry hint: memory pressure maps to 503 at the
                // HTTP layer (queue saturation, with a hint, maps 429)
                if crate::obs::mem::live_bytes() > high_water {
                    return Err(Overloaded::new(format!(
                        "live heap {live} B over the {high_water} B high-water mark \
                         (shed {dropped} cache entries, still over)"
                    ))
                    .into());
                }
            }
        }
        if self.registry.get(&spec.dataset).is_none() {
            bail!(
                "unknown dataset `{}` (registered: {})",
                spec.dataset,
                self.registry.names().join(", ")
            );
        }
        let (canon, _) = resolve_method(&spec.method)
            .ok_or_else(|| anyhow!("unknown method `{}`", spec.method))?;
        let id = self.next_id.fetch_add(1, Ordering::SeqCst) + 1;
        let budget = Budget::from_ms(spec.cfg.deadline_ms);
        let job = Arc::new(Job {
            id,
            spec,
            canon_method: canon,
            state: Mutex::new("jobs.job.state", JobState::Queued),
            cancel: AtomicBool::new(false),
            budget,
            progress: JobProgress::default(),
            stats_at_start: Mutex::new("jobs.job.stats", None),
            service: Mutex::new("jobs.job.service", None),
            result: Mutex::new("jobs.job.result", None),
            error: Mutex::new("jobs.job.error", None),
        });
        {
            // hold the append marker lock across the job-map insert so
            // an append can never begin between this check and the job
            // becoming visible to `has_active_jobs`
            let appending = self.appending.lock();
            if appending.contains(&job.spec.dataset) {
                return Err(super::TransientConflict(format!(
                    "dataset `{}` has an append in progress; retry shortly",
                    job.spec.dataset
                ))
                .into());
            }
            self.jobs.lock().insert(id, job);
        }
        self.queue.lock().push_back(id);
        self.queue_cv.notify_one();
        Ok(id)
    }

    /// Request cancellation; returns the state right after the request
    /// (a queued job cancels immediately, a running one cooperatively).
    pub fn cancel(&self, id: u64) -> Option<JobState> {
        let job = self.jobs.lock().get(&id).cloned()?;
        job.cancel.store(true, Ordering::SeqCst);
        let mut st = job.state.lock();
        if *st == JobState::Queued {
            *st = JobState::Cancelled;
        }
        Some(*st)
    }

    /// Current view of a job (None for unknown ids).
    pub fn snapshot(&self, id: u64) -> Option<JobSnapshot> {
        let job = self.jobs.lock().get(&id).cloned()?;
        let state = *job.state.lock();
        let result = job.result.lock().clone();
        let error = job.error.lock().clone();
        let start = job.stats_at_start.lock().clone();
        let now = match (&result, &*job.service.lock()) {
            (Some(r), _) if r.stats.is_some() => r.stats.clone(),
            (_, Some(svc)) => Some(svc.stats()),
            _ => None,
        };
        let (requests, cache_hits, evaluations) = match (start, now) {
            (Some(s0), Some(s1)) => (
                s1.requests.saturating_sub(s0.requests),
                s1.cache_hits.saturating_sub(s0.cache_hits),
                s1.evaluations.saturating_sub(s0.evaluations),
            ),
            _ => (0, 0, 0),
        };
        Some(JobSnapshot {
            id: job.id,
            dataset: job.spec.dataset.clone(),
            method: job.canon_method.clone(),
            state,
            sweeps: job.progress.sweeps.load(Ordering::Relaxed),
            candidates: job.progress.candidates.load(Ordering::Relaxed),
            requests,
            cache_hits,
            evaluations,
            result,
            error,
        })
    }

    /// All job ids, ascending (submission order).
    pub fn job_ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self.jobs.lock().keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    /// Job counts per state, in lifecycle order.
    pub fn state_counts(&self) -> Vec<(JobState, u64)> {
        let jobs = self.jobs.lock();
        let states = [
            JobState::Queued,
            JobState::Running,
            JobState::Done,
            JobState::Failed,
            JobState::Cancelled,
        ];
        let mut counts: HashMap<JobState, u64> = HashMap::new();
        for job in jobs.values() {
            *counts.entry(*job.state.lock()).or_insert(0) += 1;
        }
        states.iter().map(|s| (*s, counts.get(s).copied().unwrap_or(0))).collect()
    }

    /// Per-service counters of the pool: ((dataset, dataset version,
    /// method, engine, lowrank, shards), stats), sorted by key.
    ///
    /// Snapshots the pool under one short lock and calls `stats()`
    /// afterwards: `stats()` takes each service's backend read lock,
    /// which a mid-append backend swap can hold — collecting stats
    /// under the pool lock would stall every `service_for` (and with it
    /// job submission and follower scoring) behind that swap.
    pub fn service_stats(&self) -> Vec<(ServiceKey, ServiceStats)> {
        let entries: Vec<(ServiceKey, Arc<ScoreService>)> = {
            let services = self.services.lock();
            services.iter().map(|(k, e)| (k.clone(), e.service.clone())).collect()
        };
        let mut out: Vec<(ServiceKey, ServiceStats)> =
            entries.into_iter().map(|(k, svc)| (k, svc.stats())).collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Drop every pooled service of `dataset` (called when the dataset
    /// is deleted from the registry). Running jobs keep their own Arc.
    pub fn drop_dataset_services(&self, dataset: &str) {
        self.services.lock().retain(|k, _| k.0 != dataset);
    }

    /// Overload shedding: invalidate every pooled score memo and drop
    /// the pool entries themselves (releasing backend fold-core caches
    /// not pinned by a running job). Returns the number of memo entries
    /// dropped. Invalidation runs outside the pool lock — it takes each
    /// service's cache lock, and stalling `service_for` behind that
    /// would block the very submissions shedding is trying to save.
    pub fn shed_services(&self) -> u64 {
        let entries: Vec<Arc<ScoreService>> = {
            let mut services = self.services.lock();
            services.drain().map(|(_, e)| e.service).collect()
        };
        entries.iter().map(|svc| svc.invalidate_all()).sum()
    }

    /// Any queued or running job targeting `dataset`? Appends are
    /// refused while this holds — swapping a service's backend mid-run
    /// would mix row versions inside one sweep. Use
    /// [`JobManager::begin_append`] for the race-free check.
    pub fn has_active_jobs(&self, dataset: &str) -> bool {
        self.jobs
            .lock()
            .values()
            .any(|j| j.spec.dataset == dataset && !j.state.lock().is_terminal())
    }

    /// Atomically begin an append on `dataset`: fails while jobs on it
    /// are queued/running, and marks the dataset so new submissions
    /// (and concurrent appends) are refused until the returned guard
    /// drops. Holding the marker lock across the active-jobs check —
    /// the same lock `submit` holds across its job-map insert — closes
    /// the check-then-swap race in both directions.
    pub fn begin_append(&self, dataset: &str) -> Result<AppendGuard<'_>> {
        let mut appending = self.appending.lock();
        if self.has_active_jobs(dataset) {
            bail!("dataset `{dataset}` has queued/running jobs; wait before appending");
        }
        if !appending.insert(dataset.to_string()) {
            bail!("dataset `{dataset}` already has an append in progress");
        }
        Ok(AppendGuard { mgr: self, dataset: dataset.to_string() })
    }

    /// Re-point every pooled service of `dataset` at an appended
    /// snapshot: rebuild each backend over `ds` with the config that
    /// created it, swap it in, and invalidate the now-stale memo
    /// entries (counted in `ServiceStats::invalidations`). The service
    /// objects — their counters **and their warm-start CPDAGs** —
    /// survive, which is exactly what `warm_start` re-discovery jobs
    /// reuse.
    ///
    /// Best-effort by design: a service whose backend cannot be rebuilt
    /// (e.g. a PJRT entry with its artifacts gone) is **retired** from
    /// the pool — the append has already committed, so keeping a stale
    /// n-row backend reachable would silently serve pre-append results.
    /// Returns the total number of invalidated entries.
    pub fn refresh_dataset_services(&self, dataset: &str, ds: &Arc<Dataset>) -> u64 {
        // collect matching entries first: backend factories may do real
        // work (e.g. load PJRT artifacts) and must not run under the
        // pool lock
        let targets: Vec<(ServiceKey, DiscoveryConfig, Arc<ScoreService>)> = {
            let services = self.services.lock();
            services
                .iter()
                .filter(|(k, _)| k.0 == dataset)
                .map(|(k, e)| (k.clone(), e.cfg.clone(), e.service.clone()))
                .collect()
        };
        let mut invalidated = 0u64;
        for (key, cfg, svc) in targets {
            match score_backend_for(&key.2, ds.clone(), &cfg) {
                Ok((_, Some(backend))) => {
                    svc.replace_backend(backend);
                    invalidated += svc.invalidate_all();
                }
                // no rebuilt backend (factory failed, or the method was
                // re-registered as search-based since the entry was
                // pooled): the entry can only serve stale pre-append
                // results — invalidate and retire it
                Ok((_, None)) | Err(_) => {
                    invalidated += svc.invalidate_all();
                    self.services.lock().remove(&key);
                }
            }
        }
        invalidated
    }

    /// Stop accepting jobs, cancel everything in flight, and join the
    /// workers. Idempotent.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for job in self.jobs.lock().values() {
            job.cancel.store(true, Ordering::SeqCst);
            let mut st = job.state.lock();
            if *st == JobState::Queued {
                *st = JobState::Cancelled;
            }
        }
        // The flag store above is lock-free, so it can land in the
        // window between a worker's predicate check (under the queue
        // lock) and its `wait` — and `notify_all` only wakes threads
        // already parked, so notifying here would be lost and the
        // worker would park forever. One empty queue-lock span closes
        // the window: a worker mid-window still holds the lock, so by
        // the time this acquisition succeeds it is parked (and the
        // notify below reaches it) or will re-check the flag before
        // parking. Found by the `JobsModel` schedule explorer
        // (`util::model`); the unlocked variant is kept there as a
        // regression model.
        drop(self.queue.lock());
        self.queue_cv.notify_all();
        let handles = std::mem::take(&mut *self.workers.lock());
        for h in handles {
            let _ = h.join();
        }
    }

    fn worker_loop(&self) {
        loop {
            let id = {
                let mut q = self.queue.lock();
                loop {
                    if let Some(id) = q.pop_front() {
                        break id;
                    }
                    if self.shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    q = self.queue_cv.wait(q);
                }
            };
            let job = match self.jobs.lock().get(&id).cloned() {
                Some(j) => j,
                None => continue,
            };
            self.run_job(&job);
        }
    }

    fn run_job(&self, job: &Job) {
        {
            let mut st = job.state.lock();
            if *st != JobState::Queued {
                return; // cancelled while queued
            }
            if job.cancel.load(Ordering::SeqCst) {
                *st = JobState::Cancelled;
                return;
            }
            *st = JobState::Running;
        }
        // contain panics (including an armed `jobs.worker=panic`
        // failpoint): the job fails, the worker thread survives — a
        // dead worker would silently strand the queue
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| self.execute(job)))
            .unwrap_or_else(|p| {
                let msg = p
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| p.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "opaque panic payload".to_string());
                Err(anyhow!("job panicked: {msg}"))
            });
        // drop the live-service handle before publishing the terminal
        // state so late polls go through the result snapshot
        *job.service.lock() = None;
        {
            let mut st = job.state.lock();
            match outcome {
                Ok(Some(result)) => {
                    *job.result.lock() = Some(result);
                    *st = JobState::Done;
                }
                Ok(None) => *st = JobState::Cancelled,
                Err(e) => {
                    *job.error.lock() = Some(format!("{e:#}"));
                    *st = JobState::Failed;
                }
            }
        }
        self.prune_terminal_jobs();
    }

    /// Bound manager memory: drop the oldest terminal jobs beyond
    /// [`MAX_RETAINED_TERMINAL_JOBS`] (their results become 404s).
    /// Queued/running jobs are never pruned.
    fn prune_terminal_jobs(&self) {
        let mut jobs = self.jobs.lock();
        let mut terminal: Vec<u64> = jobs
            .iter()
            .filter(|(_, j)| j.state.lock().is_terminal())
            .map(|(id, _)| *id)
            .collect();
        if terminal.len() <= MAX_RETAINED_TERMINAL_JOBS {
            return;
        }
        terminal.sort_unstable();
        let excess = terminal.len() - MAX_RETAINED_TERMINAL_JOBS;
        for id in terminal.into_iter().take(excess) {
            jobs.remove(&id);
        }
    }

    /// Fetch-or-build the pooled [`ScoreService`] keyed by (`dataset` @
    /// `ds_version`, `canon`, and the engine/lowrank/shards of `cfg`).
    /// Shared by the job path and the follower-side `/v1/score_batch`
    /// endpoint, so a follower's stateless scoring requests land on the
    /// same memoized service its jobs use. `workers`/`cache_capacity`
    /// only take effect for the caller that *creates* the entry.
    pub(crate) fn service_for(
        &self,
        dataset: &str,
        ds_version: u64,
        ds: Arc<Dataset>,
        canon: &str,
        cfg: &DiscoveryConfig,
    ) -> Result<Arc<ScoreService>> {
        let key: ServiceKey = (
            dataset.to_string(),
            ds_version,
            canon.to_string(),
            format!("{:?}", cfg.engine),
            cfg.lowrank.method.name().to_string(),
            cfg.shards.join(","),
        );
        let stamp = || self.pool_clock.fetch_add(1, Ordering::Relaxed) + 1;
        let cached = {
            let mut services = self.services.lock();
            services.get_mut(&key).map(|e| {
                e.last_use = stamp();
                e.service.clone()
            })
        };
        if let Some(svc) = cached {
            return Ok(svc);
        }
        // the server default cache bound applies to the score memo AND
        // (through the factory) the backend's fold-core cache; resolve
        // it before the build so both see the same bound
        let cap = cfg.cache_capacity.or(self.default_cache_capacity);
        let mut bcfg = cfg.clone();
        bcfg.cache_capacity = cap;
        // a sharding coordinator pushes the dataset to followers under
        // this dataset's own registry name unless the spec overrode it
        if bcfg.shard_dataset.is_empty() {
            bcfg.shard_dataset = dataset.to_string();
        }
        // build outside the pool lock: a factory may load PJRT
        // artifacts from disk (and a shard wrap opens sockets lazily)
        let (_, backend) = score_backend_for(canon, ds, &bcfg)?;
        let backend = backend.ok_or_else(|| anyhow!("`{canon}` is not score-based"))?;
        let svc = Arc::new(ScoreService::with_cache_capacity(backend, cfg.workers, cap));
        svc.set_gram_threads(crate::score::cores::resolve_parallelism(
            cfg.parallelism,
            cfg.params.folds,
        ) as u64);
        let mut services = self.services.lock();
        // a replaced dataset's services are now unreachable (stale
        // version): drop them
        services.retain(|k, _| k.0 != dataset || k.1 >= ds_version);
        // LRU-bound the pool: running jobs keep their own Arc, only the
        // warm cache goes
        while services.len() >= MAX_POOLED_SERVICES {
            let lru =
                services.iter().min_by_key(|(_, e)| e.last_use).map(|(k, _)| k.clone());
            match lru {
                Some(k) => {
                    services.remove(&k);
                }
                None => break,
            }
        }
        // racing builders: first insert wins so all callers share one
        // cache; retain the resolved config so refresh-time rebuilds
        // reproduce the same cache bounds
        Ok(services
            .entry(key)
            .or_insert_with(|| PoolEntry { service: svc, last_use: stamp(), cfg: bcfg })
            .service
            .clone())
    }

    /// Run the job to completion; `Ok(None)` means it observed its
    /// cancel flag.
    fn execute(&self, job: &Job) -> Result<Option<JobResult>> {
        if fail::hit("jobs.worker").is_some() {
            // Error and Corrupt both mean "this worker run fails";
            // Delay/Panic already happened inline in `hit`
            return Err(fail::injected_error("jobs.worker"));
        }
        if job.budget.expired() {
            metrics::deadline_exceeded_total().inc();
            return Err(DeadlineExceeded::new(format!(
                "job {} expired in the queue before work began",
                job.id
            ))
            .into());
        }
        let spec = &job.spec;
        let (ds, ds_version) = self
            .registry
            .entry(&spec.dataset)
            .ok_or_else(|| anyhow!("dataset `{}` was removed", spec.dataset))?;
        let canon = job.canon_method.clone();
        let kind = resolve_method(&canon)
            .map(|(_, k)| k)
            .ok_or_else(|| anyhow!("method `{canon}` was unregistered"))?;
        match kind {
            MethodKind::Score => {
                // NOTE: `workers` and `cache_capacity` of a job spec
                // only take effect for the job that *creates* the
                // pooled service; later jobs share the existing one.
                let service = self.service_for(&spec.dataset, ds_version, ds, &canon, &spec.cfg)?;
                *job.stats_at_start.lock() = Some(service.stats());
                *job.service.lock() = Some(service.clone());
                // arm the deadline on the backing service too, so a
                // sharding backend clamps dispatch/hedge/retry by it;
                // re-armed (or lifted) here per job because the pooled
                // service outlives this one
                service.set_budget(job.budget);
                let backend = CancelBackend {
                    inner: service.clone(),
                    cancel: &job.cancel,
                    budget: job.budget,
                    deadlined: AtomicBool::new(false),
                    progress: &job.progress,
                };
                // warm start: resume from the service's last CPDAG (set
                // by every completed score job on this pool entry)
                let init = if spec.warm_start { service.warm_start() } else { None };
                let sw = Stopwatch::start();
                let res = ges_from(&backend, &spec.cfg.ges, init.as_ref());
                service.set_budget(Budget::none());
                if job.cancel.load(Ordering::SeqCst) {
                    return Ok(None);
                }
                if backend.deadlined.load(Ordering::SeqCst) {
                    // the zero-padded tail may have let GES apply bogus
                    // operators: the partial CPDAG is discarded, never
                    // published (and never warm-starts the next job)
                    metrics::deadline_exceeded_total().inc();
                    return Err(DeadlineExceeded::new(format!(
                        "job {} ran past its {} ms deadline",
                        job.id,
                        spec.cfg.deadline_ms.unwrap_or(0)
                    ))
                    .into());
                }
                service.set_warm_start(res.cpdag.clone());
                Ok(Some(JobResult {
                    cpdag: res.cpdag,
                    seconds: sw.secs(),
                    method: canon,
                    stats: Some(service.stats()),
                    ci_tests: None,
                }))
            }
            MethodKind::Search => {
                if job.cancel.load(Ordering::SeqCst) {
                    return Ok(None);
                }
                // constraint-based searches run end to end through the
                // registry; cancellation (and the deadline check) land
                // before/after, not inside
                let out = run_named(&canon, ds, &spec.cfg)?;
                if job.cancel.load(Ordering::SeqCst) {
                    return Ok(None);
                }
                if job.budget.expired() {
                    metrics::deadline_exceeded_total().inc();
                    return Err(DeadlineExceeded::new(format!(
                        "job {} ran past its {} ms deadline",
                        job.id,
                        spec.cfg.deadline_ms.unwrap_or(0)
                    ))
                    .into());
                }
                Ok(Some(JobResult {
                    cpdag: out.cpdag,
                    seconds: out.seconds,
                    method: out.method,
                    stats: out.score_stats,
                    ci_tests: out.ci_tests,
                }))
            }
        }
    }
}

/// RAII marker for an in-flight dataset append
/// ([`JobManager::begin_append`]): while alive, job submissions on the
/// dataset are refused; dropping it re-opens the dataset.
pub struct AppendGuard<'a> {
    mgr: &'a JobManager,
    dataset: String,
}

impl Drop for AppendGuard<'_> {
    fn drop(&mut self) {
        self.mgr.appending.lock().remove(&self.dataset);
    }
}

/// Per-job wrapper over the pooled service: submits each sweep in a few
/// wide chunks, stops between chunks once the cancel flag is set **or
/// the deadline budget expires** (padding the remainder with zeros —
/// the job runner discards the result either way), and counts
/// sweeps/candidates for progress reporting.
struct CancelBackend<'a> {
    inner: Arc<ScoreService>,
    cancel: &'a AtomicBool,
    budget: Budget,
    /// Set once the budget expired mid-sweep; the job runner turns it
    /// into a typed [`DeadlineExceeded`] failure.
    deadlined: AtomicBool,
    progress: &'a JobProgress,
}

impl ScoreBackend for CancelBackend<'_> {
    fn score_batch(&self, reqs: &[ScoreRequest]) -> Vec<f64> {
        // few, wide sub-batches: amortization stays, cancels land within
        // ~1/CANCEL_CHECKS_PER_SWEEP of a sweep
        let chunk_len =
            MIN_CANCEL_CHUNK.max(reqs.len().div_ceil(CANCEL_CHECKS_PER_SWEEP));
        let mut out: Vec<f64> = Vec::with_capacity(reqs.len());
        for sub in reqs.chunks(chunk_len) {
            if self.cancel.load(Ordering::SeqCst) {
                break;
            }
            if self.budget.expired() {
                self.deadlined.store(true, Ordering::SeqCst);
                break;
            }
            out.extend(self.inner.score_batch(sub));
        }
        out.resize(reqs.len(), 0.0);
        self.progress.sweeps.fetch_add(1, Ordering::Relaxed);
        self.progress.candidates.fetch_add((reqs.len() / 2) as u64, Ordering::Relaxed);
        out
    }

    fn num_vars(&self) -> usize {
        ScoreBackend::num_vars(&*self.inner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::register_score_method;
    use crate::score::{LocalScore, ScalarBackend};
    use std::time::{Duration, Instant};

    fn test_registry() -> Arc<DatasetRegistry> {
        let reg = Arc::new(DatasetRegistry::new());
        let ds = super::super::registry::builtin_dataset("synth", 150, 7).unwrap();
        reg.insert("synth", Arc::new(ds)).unwrap();
        reg
    }

    fn wait_terminal(mgr: &JobManager, id: u64, timeout: Duration) -> JobSnapshot {
        let t0 = Instant::now();
        loop {
            let snap = mgr.snapshot(id).expect("job exists");
            if snap.state.is_terminal() {
                return snap;
            }
            assert!(t0.elapsed() < timeout, "job {id} stuck in {:?}", snap.state);
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    fn spec(method: &str) -> JobSpec {
        JobSpec {
            dataset: "synth".to_string(),
            method: method.to_string(),
            cfg: DiscoveryConfig::default(),
            warm_start: false,
        }
    }

    #[test]
    fn submit_rejects_unknown_names() {
        let mgr = JobManager::start(test_registry(), 1, None);
        assert!(mgr.submit(spec("not-a-method")).is_err());
        let mut bad = spec("bic");
        bad.dataset = "not-a-dataset".to_string();
        assert!(mgr.submit(bad).is_err());
        mgr.shutdown();
    }

    #[test]
    fn job_runs_to_done_and_second_job_hits_shared_cache() {
        let mgr = JobManager::start(test_registry(), 2, Some(1 << 16));
        let a = mgr.submit(spec("bic")).unwrap();
        let snap_a = wait_terminal(&mgr, a, Duration::from_secs(60));
        assert_eq!(snap_a.state, JobState::Done, "{:?}", snap_a.error);
        let res = snap_a.result.as_ref().unwrap();
        assert!(res.cpdag.num_edges() > 0, "synthetic data has structure");
        assert!(res.stats.as_ref().unwrap().consistent());
        assert!(snap_a.sweeps > 0 && snap_a.candidates > 0);

        // identical job: the pooled service must serve it from cache
        let b = mgr.submit(spec("bic")).unwrap();
        let snap_b = wait_terminal(&mgr, b, Duration::from_secs(60));
        assert_eq!(snap_b.state, JobState::Done);
        assert!(snap_b.requests > 0);
        assert_eq!(
            snap_b.evaluations, 0,
            "an identical job re-scores nothing: {} requests, {} hits",
            snap_b.requests, snap_b.cache_hits
        );
        assert!(snap_b.cache_hits > 0, "cross-job cache hits must be observed");
        let services = mgr.service_stats();
        assert_eq!(services.len(), 1, "both jobs share one (dataset, method, engine) service");
        mgr.shutdown();
    }

    #[test]
    fn cancel_lands_mid_run_on_a_slow_method() {
        // a deliberately slow registered score: each evaluation sleeps,
        // so the cancel reliably lands mid-sweep
        register_score_method("jobs-test-slow", &[], |ds, _| {
            struct Slow(Arc<crate::data::Dataset>);
            impl LocalScore for Slow {
                fn local_score(&self, t: usize, p: &[usize]) -> f64 {
                    std::thread::sleep(Duration::from_millis(5));
                    // rewards every insert, so GES keeps sweeping until
                    // the graph is complete — plenty of time to cancel
                    t as f64 * 0.01 + p.len() as f64
                }
                fn num_vars(&self) -> usize {
                    self.0.d()
                }
            }
            Ok(Arc::new(ScalarBackend(Slow(ds))))
        });
        let mgr = JobManager::start(test_registry(), 1, None);
        let id = mgr.submit(spec("jobs-test-slow")).unwrap();
        // let it get going, then cancel
        let t0 = Instant::now();
        loop {
            let snap = mgr.snapshot(id).unwrap();
            if snap.state == JobState::Running && snap.candidates > 0 {
                break;
            }
            assert!(t0.elapsed() < Duration::from_secs(30), "job never started");
            std::thread::sleep(Duration::from_millis(5));
        }
        mgr.cancel(id).unwrap();
        let snap = wait_terminal(&mgr, id, Duration::from_secs(30));
        assert_eq!(snap.state, JobState::Cancelled);
        assert!(snap.result.is_none(), "cancelled jobs publish no result");
        mgr.shutdown();
    }

    #[test]
    fn cancel_while_queued_never_runs() {
        let mgr = JobManager::start(test_registry(), 1, None);
        // saturate the single worker with a slow-ish job, then queue one
        // more and cancel it before it starts
        let blocker = mgr.submit(spec("cv-lr")).unwrap();
        let victim = mgr.submit(spec("bic")).unwrap();
        assert_eq!(mgr.cancel(victim), Some(JobState::Cancelled));
        let snap = wait_terminal(&mgr, victim, Duration::from_secs(10));
        assert_eq!(snap.state, JobState::Cancelled);
        assert_eq!(snap.sweeps, 0, "a queue-cancelled job never swept");
        let _ = mgr.cancel(blocker);
        wait_terminal(&mgr, blocker, Duration::from_secs(60));
        mgr.shutdown();
    }

    #[test]
    fn append_refresh_invalidates_and_warm_start_resumes() {
        let reg = test_registry();
        let mgr = JobManager::start(reg.clone(), 1, Some(1 << 16));
        // cold job populates the pooled service's cache + warm CPDAG
        let a = mgr.submit(spec("bic")).unwrap();
        let snap_a = wait_terminal(&mgr, a, Duration::from_secs(60));
        assert_eq!(snap_a.state, JobState::Done, "{:?}", snap_a.error);
        assert!(!mgr.has_active_jobs("synth"), "terminal jobs are not active");

        // append one row (internal coordinates) and refresh the pool
        let ds0 = reg.get("synth").unwrap();
        let row = crate::linalg::Mat::zeros(1, ds0.data.cols);
        let (ds1, row_version) = {
            // the race-free protocol: mark the append, mutate, refresh
            let _guard = mgr.begin_append("synth").unwrap();
            assert!(
                mgr.submit(spec("bic")).is_err(),
                "submissions must be refused while an append is in flight"
            );
            reg.append_rows("synth", &row).unwrap()
        };
        assert_eq!(row_version, 1);
        let invalidated = mgr.refresh_dataset_services("synth", &ds1);
        assert!(invalidated > 0, "the cold job's cache entries must be invalidated");

        // warm_start re-discovery on the appended data: runs to done,
        // re-evaluates (nothing stale served), and the service reports
        // both counters
        let mut warm = spec("bic");
        warm.warm_start = true;
        let b = mgr.submit(warm).unwrap();
        let snap_b = wait_terminal(&mgr, b, Duration::from_secs(60));
        assert_eq!(snap_b.state, JobState::Done, "{:?}", snap_b.error);
        assert!(snap_b.evaluations > 0, "post-append scores must be re-evaluated");
        let res = snap_b.result.as_ref().unwrap();
        assert_eq!(
            res.cpdag.num_edges(),
            snap_a.result.as_ref().unwrap().cpdag.num_edges(),
            "one appended row must not change the learned structure"
        );
        let services = mgr.service_stats();
        assert_eq!(services.len(), 1, "the pool entry survived the append");
        let st = &services[0].1;
        assert!(st.invalidations > 0, "{st:?}");
        assert!(st.warm_start_hits >= 1, "{st:?}");
        assert!(st.consistent(), "{st:?}");
        mgr.shutdown();
    }

    #[test]
    fn full_admission_queue_refuses_with_overloaded() {
        let limits = JobLimits { max_queued: 0, mem_high_water: None };
        let mgr = JobManager::start_with_limits(test_registry(), 1, None, limits);
        let err = mgr.submit(spec("bic")).unwrap_err();
        let over = err.downcast_ref::<Overloaded>().expect("submit fails with a typed Overloaded");
        assert!(over.retry_after.is_some(), "saturation advertises a Retry-After");
        mgr.shutdown();
    }

    #[test]
    fn expired_job_deadline_fails_typed() {
        let mgr = JobManager::start(test_registry(), 1, None);
        let mut s = spec("bic");
        s.cfg.deadline_ms = Some(0);
        let id = mgr.submit(s).unwrap();
        let snap = wait_terminal(&mgr, id, Duration::from_secs(30));
        assert_eq!(snap.state, JobState::Failed);
        assert!(snap.result.is_none(), "deadlined jobs publish no result");
        let msg = snap.error.as_deref().unwrap_or("");
        assert!(msg.contains("deadline exceeded"), "typed deadline error, got: {msg}");

        // a generous deadline changes nothing about the outcome
        let mut s = spec("bic");
        s.cfg.deadline_ms = Some(600_000);
        let id = mgr.submit(s).unwrap();
        let snap = wait_terminal(&mgr, id, Duration::from_secs(60));
        assert_eq!(snap.state, JobState::Done, "{:?}", snap.error);
        mgr.shutdown();
    }

    #[test]
    fn shed_services_drops_the_warm_pool() {
        let mgr = JobManager::start(test_registry(), 1, Some(1 << 16));
        let id = mgr.submit(spec("bic")).unwrap();
        let snap = wait_terminal(&mgr, id, Duration::from_secs(60));
        assert_eq!(snap.state, JobState::Done, "{:?}", snap.error);
        assert_eq!(mgr.service_stats().len(), 1);
        assert!(mgr.shed_services() > 0, "the completed job left memo entries to shed");
        assert!(mgr.service_stats().is_empty(), "shedding empties the pool");
        mgr.shutdown();
    }

    #[test]
    fn shutdown_with_idle_workers_never_hangs() {
        // Regression for the missed-wakeup window in `shutdown()`: the
        // flag store + notify used to run without the queue lock, so a
        // worker between its predicate check and its wait parked
        // forever and `join` hung. Many start/shutdown rounds against
        // idle workers give the interleaving real opportunity; the
        // deterministic proof is `util::model::JobsModel`.
        let reg = test_registry();
        let h = std::thread::spawn(move || {
            for _ in 0..50 {
                let mgr = JobManager::start(reg.clone(), 2, None);
                mgr.shutdown();
            }
        });
        let t0 = Instant::now();
        while !h.is_finished() {
            assert!(t0.elapsed() < Duration::from_secs(60), "shutdown drain hung");
            std::thread::sleep(Duration::from_millis(20));
        }
        h.join().expect("shutdown loop");
    }

    #[test]
    fn shutdown_drains_quickly() {
        let mgr = JobManager::start(test_registry(), 2, None);
        for _ in 0..4 {
            mgr.submit(spec("bic")).unwrap();
        }
        mgr.shutdown();
        assert!(mgr.submit(spec("bic")).is_err(), "no submissions after shutdown");
        for id in mgr.job_ids() {
            let snap = mgr.snapshot(id).unwrap();
            assert!(snap.state.is_terminal(), "job {id} left in {:?}", snap.state);
        }
    }
}
