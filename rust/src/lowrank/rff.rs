//! Random Fourier features (Rahimi & Recht 2007) — the
//! **data-independent** low-rank factorization, selected with
//! [`FactorMethod::Rff`](super::FactorMethod).
//!
//! Bochner's theorem writes the RBF kernel as the expectation of a
//! random cosine feature: with ω ~ N(0, σ⁻²I) and b ~ U[0, 2π),
//!
//! ```text
//!   k(x, y) = E[ 2·cos(ωᵀx + b)·cos(ωᵀy + b) ]
//! ```
//!
//! so the Monte-Carlo factor `Λ_ij = √(2/m)·cos(ωⱼᵀxᵢ + bⱼ)` satisfies
//! `E[Λ Λᵀ] = K` with entrywise error O(1/√m) (Hoeffding: each entry is
//! the mean of m terms bounded in [−2, 2], so
//! `P(|K_ij − (ΛΛᵀ)_ij| > t) ≤ 2·exp(−m t²/8)`).
//!
//! The feature map is a pure function of the **kernel** (width σ), the
//! data dimension, the feature count m and the configured base seed —
//! never of the sample rows. That is the whole point for the streaming
//! layer (`stream::append`): appending a row costs one O(m·dim) feature
//! evaluation, extends Λ by exactly the row a cold refactorization over
//! the full data would have produced (bit for bit — the same draws, the
//! same FP sequence per row), and can never trigger a re-pivot, because
//! there are no pivots. The trade against ICL is the error bound:
//! ICL's greedy pivots adapt to the spectrum (residual trace ≤ η or the
//! rank cap), RFF's error is the flat Monte-Carlo O(1/√m) regardless of
//! how fast the spectrum decays.

use crate::kernel::Kernel;
use crate::linalg::Mat;
use crate::util::Pcg64;

/// The retained feature map: frequencies, phases and the √(2/m) scale.
/// This is all the state an incremental append needs — no pivot data,
/// no pivot factor, no residual budget.
#[derive(Clone, Debug)]
pub struct RffMap {
    /// Frequencies ω, one **column block of `dim` values per feature**:
    /// m × dim, so `omega.row(j)` is ωⱼ.
    pub omega: Mat,
    /// Phases b ∈ [0, 2π), one per feature.
    pub phases: Vec<f64>,
    /// √(2/m).
    pub scale: f64,
}

/// Deterministic seed for the frequency draws: a pure function of the
/// pinned kernel width, the data dimension, the feature count and the
/// configured base seed. Two calls with the same pinned kernel (e.g. a
/// streaming state and its cold-refactorize oracle) draw identical
/// features; the data rows never enter.
fn derive_seed(sigma: f64, dim: usize, m: usize, base: u64) -> u64 {
    // SplitMix-style finalizer over the mixed inputs.
    let mut z = base
        ^ sigma.to_bits()
        ^ (dim as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (m as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl RffMap {
    /// Draw the feature map for an RBF kernel of width `sigma` over
    /// `dim`-column rows. Returns `None` for non-RBF kernels — the
    /// spectral sampling below is the Gaussian's; callers fall back to
    /// ICL (`LowRank::fell_back` records it).
    pub fn draw(kernel: Kernel, dim: usize, m: usize, base_seed: u64) -> Option<RffMap> {
        let sigma = match kernel {
            Kernel::Rbf { sigma } => sigma,
            _ => return None,
        };
        let mut rng = Pcg64::new(derive_seed(sigma, dim, m, base_seed));
        // per feature j: dim frequency draws, then the phase — a fixed
        // draw order, so the map is reproducible from the seed alone
        let mut omega = Mat::zeros(m, dim);
        let mut phases = Vec::with_capacity(m);
        for j in 0..m {
            for c in 0..dim {
                omega[(j, c)] = rng.normal() / sigma;
            }
            phases.push(rng.uniform() * 2.0 * std::f64::consts::PI);
        }
        Some(RffMap { omega, phases, scale: (2.0 / m as f64).sqrt() })
    }

    /// Number of features m (columns of Λ).
    pub fn num_features(&self) -> usize {
        self.omega.rows
    }

    /// One Λ row for sample `x`: √(2/m)·cos(ωⱼᵀx + bⱼ), O(m·dim).
    /// Every caller — cold factorization and streaming append alike —
    /// goes through this function, so the per-row FP sequence is
    /// identical no matter when the row arrives.
    pub fn feature_row(&self, x: &[f64]) -> Vec<f64> {
        debug_assert_eq!(x.len(), self.omega.cols);
        let m = self.omega.rows;
        let mut row = Vec::with_capacity(m);
        for j in 0..m {
            let mut dot = self.phases[j];
            for (w, v) in self.omega.row(j).iter().zip(x) {
                dot += w * v;
            }
            row.push(self.scale * dot.cos());
        }
        row
    }

    /// The full n × m factor of `x`'s rows.
    pub fn features(&self, x: &Mat) -> Mat {
        let m = self.omega.rows;
        let mut lam = Mat::zeros(x.rows, m);
        for i in 0..x.rows {
            lam.row_mut(i).copy_from_slice(&self.feature_row(x.row(i)));
        }
        lam
    }
}

/// Per-row diagnostic residual `|k(x,x) − ‖λ‖²|` — the RFF analogue of
/// ICL's residual-diagonal entries (not PSD, hence the absolute value).
/// Shared by the cold factorization and the streaming append so the
/// two observables are computed identically.
pub fn row_residual(kernel: Kernel, x: &[f64], lam_row: &[f64]) -> f64 {
    let norm2: f64 = lam_row.iter().map(|v| v * v).sum();
    (kernel.eval_diag(x) - norm2).abs()
}

/// Factorize through random Fourier features: Λ = √(2/m)·cos(Xωᵀ + b)
/// with m = `max_rank` features, plus the diagnostic diagonal residual
/// `Σᵢ |k(xᵢ,xᵢ) − ‖λᵢ‖²|` (the analogue of ICL's residual trace; RFF's
/// residual is not PSD, hence the absolute values). `None` when the
/// kernel has no Gaussian spectral form (caller falls back to ICL).
pub fn rff_factorize(
    kernel: Kernel,
    x: &Mat,
    max_rank: usize,
    base_seed: u64,
) -> Option<(RffMap, Mat, f64)> {
    let map = RffMap::draw(kernel, x.cols, max_rank, base_seed)?;
    let lam = map.features(x);
    let residual: f64 = (0..x.rows).map(|i| row_residual(kernel, x.row(i), lam.row(i))).sum();
    Some((map, lam, residual))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::gram;

    fn normals(n: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let mut m = Mat::zeros(n, cols);
        for v in &mut m.data {
            *v = rng.normal();
        }
        m
    }

    #[test]
    fn reconstruction_error_shrinks_with_m() {
        let x = normals(40, 2, 1);
        let k = Kernel::Rbf { sigma: 1.5 };
        let g = gram(k, &x);
        let mut errs = Vec::new();
        for m in [50usize, 200, 800] {
            let (_, lam, _) = rff_factorize(k, &x, m, 0).unwrap();
            errs.push((&lam.matmul_t(&lam) - &g).max_abs());
        }
        // O(1/√m): quadrupling m should roughly halve the error; allow
        // generous slack for Monte-Carlo noise at fixed seeds
        assert!(errs[2] < errs[0], "error must shrink with m: {errs:?}");
        assert!(errs[2] < 0.2, "800 features must reconstruct coarsely: {errs:?}");
    }

    #[test]
    fn map_is_a_pure_function_of_the_kernel() {
        let k = Kernel::Rbf { sigma: 0.7 };
        let a = RffMap::draw(k, 3, 64, 9).unwrap();
        let b = RffMap::draw(k, 3, 64, 9).unwrap();
        assert_eq!(a.omega.data, b.omega.data, "same kernel → same frequencies");
        assert_eq!(a.phases, b.phases);
        // the data never enters: feature rows for the same point agree
        // no matter which factorization produced the map
        let x = [0.3, -1.2, 0.8];
        assert_eq!(a.feature_row(&x), b.feature_row(&x));
        // different width → different draws
        let c = RffMap::draw(Kernel::Rbf { sigma: 0.8 }, 3, 64, 9).unwrap();
        assert_ne!(a.omega.data, c.omega.data);
    }

    #[test]
    fn non_rbf_kernels_are_refused() {
        assert!(RffMap::draw(Kernel::Linear, 2, 32, 0).is_none());
        assert!(RffMap::draw(Kernel::Delta, 2, 32, 0).is_none());
        assert!(rff_factorize(Kernel::Poly { c: 1.0, degree: 2 }, &normals(10, 2, 2), 32, 0)
            .is_none());
    }

    #[test]
    fn features_match_row_evaluation() {
        let x = normals(15, 2, 3);
        let map = RffMap::draw(Kernel::Rbf { sigma: 1.0 }, 2, 40, 0).unwrap();
        let lam = map.features(&x);
        for i in 0..x.rows {
            assert_eq!(lam.row(i), &map.feature_row(x.row(i))[..], "row {i}");
        }
        assert_eq!(map.num_features(), 40);
    }
}
