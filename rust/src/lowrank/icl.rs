//! Algorithm 1 — kernel incomplete Cholesky decomposition (ICL) with
//! greedy adaptive pivot selection (Bach & Jordan 2002).
//!
//! Produces an n×m factor Λ with ‖Λ Λᵀ − K‖ ≤ η (trace norm of the
//! residual) or m = m₀. Runs in O(n m²) time and O(n m) space — the
//! kernel matrix itself is never materialized; only its diagonal and the
//! pivot columns are evaluated.

use crate::kernel::Kernel;
use crate::linalg::Mat;

/// Detailed ICL output retaining what incremental row appends need
/// (see `stream::append`): the pivot set in selection order and the
/// terminal residual, alongside the factor itself. The pivot rows of
/// `lambda` form a lower-triangular m×m block (in pivot order), which
/// is exactly the back-substitution operator that folds a new sample
/// into Λ in O(m²).
pub struct IclFactor {
    /// n × m factor in original row order.
    pub lambda: Mat,
    /// Original row indices of the pivots, in selection order.
    pub pivots: Vec<usize>,
    /// Residual trace Σ_j d_j at termination.
    pub residual: f64,
    /// True when the rank cap m₀ stopped the factorization before the
    /// residual trace fell below η.
    pub capped: bool,
}

/// Incomplete Cholesky factorization of the kernel matrix of `x`'s rows.
///
/// * `eta` — stop once the residual trace Σ_j d_j falls below this;
/// * `max_rank` — hard cap m₀ on the number of pivots.
pub fn icl(k: Kernel, x: &Mat, eta: f64, max_rank: usize) -> Mat {
    icl_detailed(k, x, eta, max_rank).lambda
}

/// [`icl`] plus the retained pivot/residual state (see [`IclFactor`]).
pub fn icl_detailed(k: Kernel, x: &Mat, eta: f64, max_rank: usize) -> IclFactor {
    let n = x.rows;
    let m0 = max_rank.min(n);
    // Work in permuted coordinates: perm[i] is the original row index at
    // permuted position i.
    let mut perm: Vec<usize> = (0..n).collect();
    // Residual diagonal in permuted coordinates.
    let mut d: Vec<f64> = (0..n).map(|j| k.eval_diag(x.row(j))).collect();
    // Λ in permuted row order, column-major growth.
    let mut lam = Mat::zeros(n, m0);
    let mut m = m0;

    for i in 0..m0 {
        // Stop when the residual trace is below η (line 6 of Alg. 1).
        let resid: f64 = d[i..].iter().sum();
        if resid < eta {
            m = i;
            break;
        }
        // Greedy pivot: argmax residual diagonal (line 7).
        let (jstar, _) = d
            .iter()
            .enumerate()
            .skip(i)
            .fold((i, f64::NEG_INFINITY), |(bj, bv), (j, &v)| if v > bv { (j, v) } else { (bj, bv) });
        // Permute positions i and j* (lines 8-9).
        perm.swap(i, jstar);
        d.swap(i, jstar);
        for r in 0..i {
            let t = lam[(i, r)];
            lam[(i, r)] = lam[(jstar, r)];
            lam[(jstar, r)] = t;
        }
        // Pivot column (lines 10-12).
        let lii = d[i].max(0.0).sqrt();
        if lii < 1e-150 {
            m = i;
            break;
        }
        lam[(i, i)] = lii;
        let xi = x.row(perm[i]).to_vec();
        for j in (i + 1)..n {
            let kij = k.eval(x.row(perm[j]), &xi);
            let mut s = kij;
            for r in 0..i {
                s -= lam[(j, r)] * lam[(i, r)];
            }
            let v = s / lii;
            lam[(j, i)] = v;
            d[j] -= v * v;
        }
        d[i] = 0.0;
    }

    // Cut columns and reverse the permutation (lines 14-15).
    let mut out = Mat::zeros(n, m);
    for (pos, &orig) in perm.iter().enumerate() {
        for c in 0..m {
            out[(orig, c)] = lam[(pos, c)];
        }
    }
    let residual: f64 = d[m..].iter().sum();
    IclFactor {
        lambda: out,
        pivots: perm[..m].to_vec(),
        residual,
        capped: m == max_rank.min(n) && residual >= eta,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::gram;
    use crate::util::Pcg64;

    fn rand_mat(n: usize, dcols: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let mut m = Mat::zeros(n, dcols);
        for v in &mut m.data {
            *v = rng.normal();
        }
        m
    }

    #[test]
    fn full_rank_reconstruction_when_m_equals_n() {
        let x = rand_mat(12, 1, 1);
        let k = Kernel::Rbf { sigma: 1.0 };
        let lam = icl(k, &x, 1e-14, 12);
        let rec = lam.matmul_t(&lam);
        assert!((&rec - &gram(k, &x)).max_abs() < 1e-9);
    }

    #[test]
    fn residual_trace_bounded_by_eta() {
        let x = rand_mat(60, 2, 2);
        let k = Kernel::Rbf { sigma: 1.5 };
        let eta = 1e-4;
        let lam = icl(k, &x, eta, 60);
        let resid = &gram(k, &x) - &lam.matmul_t(&lam);
        // residual trace (= sum of residual diag) is what ICL bounds
        assert!(resid.trace() < eta * 1.01, "trace {}", resid.trace());
        // residual is PSD so entries are bounded by diag
        assert!(resid.max_abs() < 2.0 * eta.max(resid.trace()));
    }

    #[test]
    fn rank_cap_respected() {
        let x = rand_mat(50, 3, 3);
        let lam = icl(Kernel::Rbf { sigma: 0.5 }, &x, 1e-12, 10);
        assert_eq!(lam.cols, 10);
        assert_eq!(lam.rows, 50);
    }

    #[test]
    fn early_exit_on_low_rank_data() {
        // 40 samples but only 4 distinct values → rank ≤ 4 (Lemma 4.1).
        let mut rng = Pcg64::new(4);
        let x = Mat::from_vec(40, 1, (0..40).map(|_| rng.below(4) as f64).collect());
        let k = Kernel::Rbf { sigma: 1.0 };
        let lam = icl(k, &x, 1e-9, 100);
        assert!(lam.cols <= 4, "cols {}", lam.cols);
        let rec = lam.matmul_t(&lam);
        assert!((&rec - &gram(k, &x)).max_abs() < 1e-6);
    }

    #[test]
    fn linear_kernel_rank_bounded_by_dim() {
        let x = rand_mat(30, 2, 5);
        let lam = icl(Kernel::Linear, &x, 1e-9, 100);
        assert!(lam.cols <= 2, "cols {}", lam.cols);
    }

    #[test]
    fn approximation_error_decreases_with_rank() {
        let x = rand_mat(80, 2, 6);
        let k = Kernel::Rbf { sigma: 1.0 };
        let g = gram(k, &x);
        let mut last = f64::INFINITY;
        for m in [2, 5, 10, 20, 40] {
            let lam = icl(k, &x, 0.0, m);
            let err = (&g - &lam.matmul_t(&lam)).frob_norm();
            assert!(err <= last + 1e-9, "err {err} not decreasing at m={m}");
            last = err;
        }
        assert!(last < 1e-3);
    }
}
