//! Algorithm 2 — exact low-rank decomposition for discrete variables.
//!
//! For a discrete variable with m_d distinct values, rank(K̃) ≤ m_d
//! (Lemma 4.1) and the Nyström-style decomposition with the distinct
//! values as pivots is *exact* (Lemma 4.3):
//!     Λ = K_{XX'} L⁻ᵀ  with  K_{X'} = L Lᵀ  ⇒  Λ Λᵀ = K_X.
//!
//! Runs in O(n m² + m³) with O(n m) storage, and unlike ICL the inner
//! loops are dense row operations (no data-dependent branching), which is
//! what gives the paper's extra discrete speedup.

use crate::kernel::{gram, gram_cross, Kernel};
use crate::linalg::{Cholesky, Mat};

/// Distinct rows of `x` in first-appearance order.
pub fn distinct_rows(x: &Mat) -> Vec<usize> {
    let mut seen: Vec<usize> = Vec::new();
    'next: for i in 0..x.rows {
        for &s in &seen {
            if x.row(i) == x.row(s) {
                continue 'next;
            }
        }
        seen.push(i);
    }
    seen
}

/// Algorithm 2: exact decomposition `Λ Λᵀ = K_X` using the distinct rows
/// (indices in `pivots`) as Nyström landmarks. Returns `None` if the
/// pivot kernel matrix is singular to precision (then the caller should
/// fall back to ICL).
pub fn discrete_decomposition(k: Kernel, x: &Mat, pivots: &[usize]) -> Option<Mat> {
    discrete_decomposition_detailed(k, x, pivots).map(|(lam, _)| lam)
}

/// [`discrete_decomposition`] plus the lower-triangular pivot factor L
/// (`K_{X'} = L Lᵀ`) that the streaming layer retains: a new sample row
/// folds into Λ by one forward substitution against L (O(m²)), and a
/// new distinct value extends L by one row (O(m²)) — see
/// `stream::append`.
pub fn discrete_decomposition_detailed(
    k: Kernel,
    x: &Mat,
    pivots: &[usize],
) -> Option<(Mat, Mat)> {
    let xp = x.select_rows(pivots);
    // K_{X'} = L Lᵀ  (line 4) with a tiny jitter for numeric safety.
    let kp = gram(k, &xp);
    let ch = Cholesky::new(&kp).or_else(|| Cholesky::new(&kp.add_diag(1e-12)))?;
    // Λ = K_{XX'} L⁻ᵀ  (line 5): solve Lᵀ·? — we need Λ L ᵀ... Λ = K_{XX'} (L⁻¹)ᵀ
    // i.e. Λᵀ = L⁻¹ K_{X'X}; forward-substitute L against K_{X'X}.
    let kxp = gram_cross(k, x, &xp); // n × m
    let lam_t = ch.forward_sub(&kxp.transpose()); // m × n  = L⁻¹ K_{X'X}
    Some((lam_t.transpose(), ch.l))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn paper_example_4_2() {
        // X = (1, 0, 1), k(x,y) = xy → K has rank 1; Λ Λᵀ must equal K.
        let x = Mat::from_vec(3, 1, vec![1.0, 0.0, 1.0]);
        let k = Kernel::Linear;
        // linear kernel: the value 0 gives a zero pivot row → rank 1 after
        // jitter; verify the reconstruction regardless
        let pivots = distinct_rows(&x);
        assert_eq!(pivots, vec![0, 1]);
        let lam = discrete_decomposition(k, &x, &pivots).unwrap();
        let rec = lam.matmul_t(&lam);
        let kx = gram(k, &x);
        assert!((&rec - &kx).max_abs() < 1e-5);
    }

    #[test]
    fn exact_for_rbf_on_discrete_values() {
        let mut rng = Pcg64::new(7);
        let x = Mat::from_vec(100, 1, (0..100).map(|_| rng.below(5) as f64).collect());
        let k = Kernel::Rbf { sigma: 1.0 };
        let pivots = distinct_rows(&x);
        assert!(pivots.len() <= 5);
        let lam = discrete_decomposition(k, &x, &pivots).unwrap();
        let rec = lam.matmul_t(&lam);
        assert!((&rec - &gram(k, &x)).max_abs() < 1e-9, "Lemma 4.3: decomposition is exact");
    }

    #[test]
    fn exact_for_multicolumn_discrete() {
        let mut rng = Pcg64::new(8);
        let mut x = Mat::zeros(60, 2);
        for v in &mut x.data {
            *v = rng.below(3) as f64;
        }
        let k = Kernel::Rbf { sigma: 2.0 };
        let pivots = distinct_rows(&x);
        assert!(pivots.len() <= 9);
        let lam = discrete_decomposition(k, &x, &pivots).unwrap();
        assert_eq!(lam.cols, pivots.len());
        assert!((&lam.matmul_t(&lam) - &gram(k, &x)).max_abs() < 1e-9);
    }

    #[test]
    fn distinct_rows_order_and_dedup() {
        let x = Mat::from_vec(5, 1, vec![2.0, 1.0, 2.0, 3.0, 1.0]);
        assert_eq!(distinct_rows(&x), vec![0, 1, 3]);
    }

    #[test]
    fn rank_bound_lemma_4_1() {
        // centered kernel rank ≤ m_d
        let mut rng = Pcg64::new(9);
        let x = Mat::from_vec(40, 1, (0..40).map(|_| rng.below(4) as f64).collect());
        let kc = crate::kernel::center_gram(&gram(Kernel::Rbf { sigma: 1.0 }, &x));
        let w = crate::linalg::sym_eigvals(&kc);
        let rank = w.iter().filter(|&&v| v.abs() > 1e-8).count();
        assert!(rank <= 4, "rank {rank} exceeds m_d");
    }
}
