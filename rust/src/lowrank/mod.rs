//! Low-rank kernel factorizations (paper §4): `Λ Λᵀ ≈ K`.
//!
//! * [`icl`] — Algorithm 1, kernel incomplete Cholesky decomposition with
//!   greedy adaptive pivoting (Bach & Jordan 2002), for any data type;
//! * [`discrete`] — Algorithm 2, the *exact* decomposition for discrete
//!   variables whose pivot count is the number of distinct rows
//!   (Lemmas 4.1/4.3);
//! * [`rff`] — random Fourier features (Rahimi & Recht 2007), the
//!   **data-independent** alternative to ICL: frequencies drawn from the
//!   RBF spectral density, O(1/√m) Monte-Carlo error, O(m)-per-row
//!   streaming appends with no re-pivot path;
//! * [`factorize`] — the dispatch rule of §7.1: use Algorithm 2 when the
//!   variable is discrete with **at most `max_rank` (m₀) distinct
//!   rows** (the code tests `distinct.len() <= cfg.max_rank`; Algorithm
//!   2 is exact whenever its pivot count fits the rank budget),
//!   otherwise the configured continuous method — Algorithm 1 by
//!   default, RFF when [`LowRankConfig::method`] selects it. A discrete
//!   set whose pivot kernel is numerically singular falls through to
//!   the continuous method; the fall-through is recorded in
//!   [`LowRank::fell_back`] so callers and tests can see it.

pub mod icl;
pub mod discrete;
pub mod rff;

use crate::kernel::Kernel;
use crate::linalg::Mat;

pub use discrete::{discrete_decomposition, discrete_decomposition_detailed, distinct_rows};
pub use icl::{icl, icl_detailed, IclFactor};
pub use rff::{rff_factorize, RffMap};

/// Result of a low-rank factorization.
pub struct LowRank {
    /// n × m factor with Λ Λᵀ ≈ K (uncentered).
    pub lambda: Mat,
    /// Number of pivots/features actually used (m = lambda.cols).
    pub rank: usize,
    /// Which algorithm produced it.
    pub method: Method,
    /// Row indices of the pivots in selection order (distinct rows for
    /// Algorithm 2, greedy picks for Algorithm 1) — retained so the
    /// factorization can be extended row by row (see `stream::append`).
    /// Empty for RFF, whose features reference no data rows at all.
    pub pivots: Vec<usize>,
    /// Residual trace ‖K − ΛΛᵀ‖ at termination (0 for Algorithm 2,
    /// which is exact; the |diagonal| sum for RFF, whose residual is
    /// not PSD).
    pub residual: f64,
    /// True when ICL stopped at the rank cap with residual ≥ η.
    pub capped: bool,
    /// True when the dispatch could not run its preferred algorithm
    /// and fell through to the configured continuous method: a
    /// singular discrete pivot kernel falls through to ICL or RFF
    /// (whichever `LowRankConfig::method` selects), and an RFF request
    /// on a kernel with no Gaussian spectral form falls through to
    /// ICL. Previously this fall-through was silent; callers can now
    /// observe it.
    pub fell_back: bool,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Algorithm 1 — incomplete Cholesky.
    Icl,
    /// Algorithm 2 — exact discrete decomposition.
    Discrete,
    /// Random Fourier features — data-independent Monte-Carlo factor.
    Rff,
}

/// Which factorization the continuous (non-Algorithm-2) path uses —
/// the `--lowrank {icl,rff}` knob, threaded through
/// [`LowRankConfig::method`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum FactorMethod {
    /// Algorithm 1: adaptive pivots, residual trace ≤ η or the rank
    /// cap. The accuracy default.
    #[default]
    Icl,
    /// Random Fourier features: data-independent draws, flat O(1/√m)
    /// error, exact O(m)-per-row streaming appends (no re-pivots).
    Rff,
}

impl FactorMethod {
    /// Canonical lower-case name (CLI/wire value).
    pub fn name(&self) -> &'static str {
        match self {
            FactorMethod::Icl => "icl",
            FactorMethod::Rff => "rff",
        }
    }

    /// Parse a CLI/wire value (case-insensitive).
    pub fn parse(s: &str) -> Option<FactorMethod> {
        match s.to_ascii_lowercase().as_str() {
            "icl" => Some(FactorMethod::Icl),
            "rff" => Some(FactorMethod::Rff),
            _ => None,
        }
    }
}

/// Configuration for the factorization dispatch.
#[derive(Clone, Copy, Debug)]
pub struct LowRankConfig {
    /// Maximal rank m₀ (paper: 100). Also the RFF feature count.
    pub max_rank: usize,
    /// ICL precision η (paper: 1e-6).
    pub eta: f64,
    /// Continuous-path factorization (Algorithm 2 still takes
    /// precedence for small-cardinality discrete sets — it is exact
    /// either way).
    pub method: FactorMethod,
    /// Base seed mixed into the RFF frequency draws. The draws are a
    /// pure function of (kernel width, dim, m, this seed) — never the
    /// data — so streaming appends reproduce a cold factorization bit
    /// for bit.
    pub rff_seed: u64,
}

impl Default for LowRankConfig {
    fn default() -> Self {
        LowRankConfig { max_rank: 100, eta: 1e-6, method: FactorMethod::Icl, rff_seed: 0 }
    }
}

impl LowRankConfig {
    /// Default configuration with the given continuous-path method.
    pub fn with_method(method: FactorMethod) -> LowRankConfig {
        LowRankConfig { method, ..Default::default() }
    }
}

/// Factorize the kernel matrix of the rows of `x`: Algorithm 2 when the
/// data is discrete with at most `max_rank` distinct rows, otherwise the
/// configured continuous method (Algorithm 1, or RFF under
/// [`FactorMethod::Rff`]). A singular discrete pivot kernel — or an RFF
/// request on a kernel without a Gaussian spectral form — falls through
/// to ICL with [`LowRank::fell_back`] set.
pub fn factorize(k: Kernel, x: &Mat, is_discrete: bool, cfg: &LowRankConfig) -> LowRank {
    // chaos site: Delay (straggler factorization) and Panic run inline
    // in `hit`; Error/Corrupt are deliberately ignored — factorize is
    // infallible and an injected wrong factor would silently corrupt
    // the learned graph instead of exercising a failure path
    let _ = crate::obs::fail::hit("lowrank.factorize");
    let span = crate::obs::trace::span("factorize", "lowrank")
        .arg("n", x.rows.to_string());
    let _mem = crate::obs::mem::MemScope::enter(crate::obs::mem::Scope::Factorize);
    let sw = crate::util::Stopwatch::start();
    let out = factorize_inner(k, x, is_discrete, cfg);
    crate::obs::metrics::factorize_seconds().observe_with_exemplar(sw.secs(), span.id());
    out
}

fn factorize_inner(k: Kernel, x: &Mat, is_discrete: bool, cfg: &LowRankConfig) -> LowRank {
    let mut fell_back = false;
    if is_discrete {
        let distinct = distinct_rows(x);
        if distinct.len() <= cfg.max_rank {
            if let Some(lambda) = discrete_decomposition(k, x, &distinct) {
                let rank = lambda.cols;
                return LowRank {
                    lambda,
                    rank,
                    method: Method::Discrete,
                    pivots: distinct,
                    residual: 0.0,
                    capped: false,
                    fell_back: false,
                };
            }
            // the pivot kernel was numerically singular (can happen
            // with a degenerate kernel choice): fall through to the
            // continuous method, recording the fall-back
            fell_back = true;
        }
    }
    if cfg.method == FactorMethod::Rff {
        if let Some((_, lambda, residual)) = rff_factorize(k, x, cfg.max_rank, cfg.rff_seed) {
            let rank = lambda.cols;
            return LowRank {
                lambda,
                rank,
                method: Method::Rff,
                pivots: Vec::new(),
                residual,
                capped: false,
                fell_back,
            };
        }
        // no Gaussian spectral form for this kernel: ICL fallback
        fell_back = true;
    }
    let f = icl_detailed(k, x, cfg.eta, cfg.max_rank);
    let rank = f.lambda.cols;
    LowRank {
        lambda: f.lambda,
        rank,
        method: Method::Icl,
        pivots: f.pivots,
        residual: f.residual,
        capped: f.capped,
        fell_back,
    }
}

/// Center the factor: Λ̃ = H Λ (column-mean subtraction), so that
/// Λ̃ Λ̃ᵀ ≈ H K H = K̃. O(nm).
pub fn center_factor(lambda: &Mat) -> Mat {
    lambda.center_columns()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::gram;
    use crate::util::Pcg64;

    #[test]
    fn dispatch_uses_discrete_for_small_cardinality() {
        let mut rng = Pcg64::new(1);
        let x = Mat::from_vec(50, 1, (0..50).map(|_| rng.below(3) as f64).collect());
        let lr = factorize(Kernel::Rbf { sigma: 1.0 }, &x, true, &LowRankConfig::default());
        assert_eq!(lr.method, Method::Discrete);
        assert!(!lr.fell_back);
        assert!(lr.rank <= 3);
        let k = gram(Kernel::Rbf { sigma: 1.0 }, &x);
        let rec = lr.lambda.matmul_t(&lr.lambda);
        assert!((&rec - &k).max_abs() < 1e-8, "discrete decomposition must be exact");
    }

    #[test]
    fn dispatch_uses_icl_for_continuous() {
        let mut rng = Pcg64::new(2);
        let x = Mat::from_vec(40, 2, (0..80).map(|_| rng.normal()).collect());
        let lr = factorize(Kernel::Rbf { sigma: 1.0 }, &x, false, &LowRankConfig::default());
        assert_eq!(lr.method, Method::Icl);
        assert!(!lr.fell_back);
        let k = gram(Kernel::Rbf { sigma: 1.0 }, &x);
        let rec = lr.lambda.matmul_t(&lr.lambda);
        assert!((&rec - &k).max_abs() < 1e-4);
    }

    #[test]
    fn dispatch_uses_rff_when_selected() {
        let mut rng = Pcg64::new(4);
        let x = Mat::from_vec(60, 2, (0..120).map(|_| rng.normal()).collect());
        let cfg = LowRankConfig { max_rank: 400, method: FactorMethod::Rff, ..Default::default() };
        let lr = factorize(Kernel::Rbf { sigma: 1.0 }, &x, false, &cfg);
        assert_eq!(lr.method, Method::Rff);
        assert_eq!(lr.rank, 400, "RFF always uses the full feature budget");
        assert!(lr.pivots.is_empty(), "RFF references no data rows");
        assert!(!lr.capped && !lr.fell_back);
        let k = gram(Kernel::Rbf { sigma: 1.0 }, &x);
        let err = (&lr.lambda.matmul_t(&lr.lambda) - &k).max_abs();
        assert!(err < 0.25, "Monte-Carlo reconstruction too loose: {err}");
    }

    #[test]
    fn rff_still_defers_to_discrete_decomposition() {
        // Algorithm 2 is exact and takes precedence over the configured
        // continuous method for small-cardinality discrete sets
        let mut rng = Pcg64::new(5);
        let x = Mat::from_vec(50, 1, (0..50).map(|_| rng.below(4) as f64).collect());
        let cfg = LowRankConfig::with_method(FactorMethod::Rff);
        let lr = factorize(Kernel::Rbf { sigma: 1.0 }, &x, true, &cfg);
        assert_eq!(lr.method, Method::Discrete);
        assert!(!lr.fell_back);
    }

    #[test]
    fn rff_on_non_rbf_kernel_falls_back_to_icl_and_records_it() {
        let mut rng = Pcg64::new(6);
        let x = Mat::from_vec(30, 2, (0..60).map(|_| rng.normal()).collect());
        let cfg = LowRankConfig::with_method(FactorMethod::Rff);
        let lr = factorize(Kernel::Linear, &x, false, &cfg);
        assert_eq!(lr.method, Method::Icl);
        assert!(lr.fell_back, "the ICL fall-back must be recorded, not silent");
    }

    #[test]
    fn factor_method_parse_roundtrip() {
        for m in [FactorMethod::Icl, FactorMethod::Rff] {
            assert_eq!(FactorMethod::parse(m.name()), Some(m));
        }
        assert_eq!(FactorMethod::parse("RFF"), Some(FactorMethod::Rff));
        assert_eq!(FactorMethod::parse("nope"), None);
    }

    #[test]
    fn centered_factor_approximates_centered_gram() {
        let mut rng = Pcg64::new(3);
        let x = Mat::from_vec(30, 1, (0..30).map(|_| rng.normal()).collect());
        let k = Kernel::Rbf { sigma: 1.0 };
        let lr = factorize(k, &x, false, &LowRankConfig::default());
        let lam_c = center_factor(&lr.lambda);
        let kc = crate::kernel::center_gram(&gram(k, &x));
        let rec = lam_c.matmul_t(&lam_c);
        assert!((&rec - &kc).max_abs() < 1e-4);
    }
}
