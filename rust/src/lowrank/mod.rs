//! Low-rank kernel factorizations (paper §4): `Λ Λᵀ ≈ K`.
//!
//! * [`icl`] — Algorithm 1, kernel incomplete Cholesky decomposition with
//!   greedy adaptive pivoting (Bach & Jordan 2002), for any data type;
//! * [`discrete`] — Algorithm 2, the *exact* decomposition for discrete
//!   variables whose pivot count is the number of distinct rows
//!   (Lemmas 4.1/4.3);
//! * [`factorize`] — the dispatch rule of §7.1: use Algorithm 2 when the
//!   variable is discrete with < m distinct values, Algorithm 1 otherwise.

pub mod icl;
pub mod discrete;

use crate::kernel::Kernel;
use crate::linalg::Mat;

pub use discrete::{discrete_decomposition, discrete_decomposition_detailed, distinct_rows};
pub use icl::{icl, icl_detailed, IclFactor};

/// Result of a low-rank factorization.
pub struct LowRank {
    /// n × m factor with Λ Λᵀ ≈ K (uncentered).
    pub lambda: Mat,
    /// Number of pivots actually used (m = lambda.cols).
    pub rank: usize,
    /// Which algorithm produced it.
    pub method: Method,
    /// Row indices of the pivots in selection order (distinct rows for
    /// Algorithm 2, greedy picks for Algorithm 1) — retained so the
    /// factorization can be extended row by row (see `stream::append`).
    pub pivots: Vec<usize>,
    /// Residual trace ‖K − ΛΛᵀ‖ at termination (0 for Algorithm 2,
    /// which is exact).
    pub residual: f64,
    /// True when ICL stopped at the rank cap with residual ≥ η.
    pub capped: bool,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Method {
    /// Algorithm 1 — incomplete Cholesky.
    Icl,
    /// Algorithm 2 — exact discrete decomposition.
    Discrete,
}

/// Configuration for the factorization dispatch.
#[derive(Clone, Copy, Debug)]
pub struct LowRankConfig {
    /// Maximal rank m₀ (paper: 100).
    pub max_rank: usize,
    /// ICL precision η (paper: 1e-6).
    pub eta: f64,
}

impl Default for LowRankConfig {
    fn default() -> Self {
        LowRankConfig { max_rank: 100, eta: 1e-6 }
    }
}

/// Factorize the kernel matrix of the rows of `x`: Algorithm 2 when the
/// data is discrete with fewer than `max_rank` distinct rows, otherwise
/// Algorithm 1 (paper §7.1 dispatch rule).
pub fn factorize(k: Kernel, x: &Mat, is_discrete: bool, cfg: &LowRankConfig) -> LowRank {
    if is_discrete {
        let distinct = distinct_rows(x);
        if distinct.len() <= cfg.max_rank {
            if let Some(lambda) = discrete_decomposition(k, x, &distinct) {
                let rank = lambda.cols;
                return LowRank {
                    lambda,
                    rank,
                    method: Method::Discrete,
                    pivots: distinct,
                    residual: 0.0,
                    capped: false,
                };
            }
            // fall through to ICL if the pivot kernel was numerically
            // singular (can happen with a degenerate kernel choice)
        }
    }
    let f = icl_detailed(k, x, cfg.eta, cfg.max_rank);
    let rank = f.lambda.cols;
    LowRank {
        lambda: f.lambda,
        rank,
        method: Method::Icl,
        pivots: f.pivots,
        residual: f.residual,
        capped: f.capped,
    }
}

/// Center the factor: Λ̃ = H Λ (column-mean subtraction), so that
/// Λ̃ Λ̃ᵀ ≈ H K H = K̃. O(nm).
pub fn center_factor(lambda: &Mat) -> Mat {
    lambda.center_columns()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::gram;
    use crate::util::Pcg64;

    #[test]
    fn dispatch_uses_discrete_for_small_cardinality() {
        let mut rng = Pcg64::new(1);
        let x = Mat::from_vec(50, 1, (0..50).map(|_| rng.below(3) as f64).collect());
        let lr = factorize(Kernel::Rbf { sigma: 1.0 }, &x, true, &LowRankConfig::default());
        assert_eq!(lr.method, Method::Discrete);
        assert!(lr.rank <= 3);
        let k = gram(Kernel::Rbf { sigma: 1.0 }, &x);
        let rec = lr.lambda.matmul_t(&lr.lambda);
        assert!((&rec - &k).max_abs() < 1e-8, "discrete decomposition must be exact");
    }

    #[test]
    fn dispatch_uses_icl_for_continuous() {
        let mut rng = Pcg64::new(2);
        let x = Mat::from_vec(40, 2, (0..80).map(|_| rng.normal()).collect());
        let lr = factorize(Kernel::Rbf { sigma: 1.0 }, &x, false, &LowRankConfig::default());
        assert_eq!(lr.method, Method::Icl);
        let k = gram(Kernel::Rbf { sigma: 1.0 }, &x);
        let rec = lr.lambda.matmul_t(&lr.lambda);
        assert!((&rec - &k).max_abs() < 1e-4);
    }

    #[test]
    fn centered_factor_approximates_centered_gram() {
        let mut rng = Pcg64::new(3);
        let x = Mat::from_vec(30, 1, (0..30).map(|_| rng.normal()).collect());
        let k = Kernel::Rbf { sigma: 1.0 };
        let lr = factorize(k, &x, false, &LowRankConfig::default());
        let lam_c = center_factor(&lr.lambda);
        let kc = crate::kernel::center_gram(&gram(k, &x));
        let rec = lam_c.matmul_t(&lam_c);
        assert!((&rec - &kc).max_abs() < 1e-4);
    }
}
