//! Marginal-likelihood generalized score with low-rank kernels
//! ("Marg-LR") — the *other* generalized score function of Huang et al.
//! (KDD'18) that the paper names in §1/§3: instead of cross-validating
//! the RKHS regression, maximize the marginal likelihood of the
//! Gaussian-process view of Eq. (4),
//!
//! ```text
//!   NLML(σ²) = ½·Tr(Λ̃ₓᵀ (K̃_z + σ²I)⁻¹ Λ̃ₓ)
//!            + (m_x/2)·log|K̃_z + σ²I| + (n·m_x/2)·log 2π
//! ```
//!
//! (each column of the empirical feature map Λ̃ₓ of X is one GP output;
//! the paper's note that "the marginal likelihood method requires an
//! additional optimization process" is the σ² grid search below).
//!
//! The same low-rank machinery as CV-LR makes this O(n·m²):
//!
//! * Woodbury (paper Eq. 12):
//!   `(Λ̃_zΛ̃_zᵀ + σ²I)⁻¹ = (I − Λ̃_z(σ²I + F)⁻¹Λ̃_zᵀ)/σ²`, so the trace
//!   term needs only the m×m cores `P = Λ̃ₓᵀΛ̃ₓ`, `E = Λ̃_zᵀΛ̃ₓ`,
//!   `F = Λ̃_zᵀΛ̃_z`;
//! * Weinstein–Aronszajn (paper Eq. 15):
//!   `log|Λ̃_zΛ̃_zᵀ + σ²I| = (n − m_z)·log σ² + log|σ²I + F|`.
//!
//! For the empty conditioning set the model is pure noise and σ² has
//! the closed form `Tr(P)/n`.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::LocalScore;
use crate::data::Dataset;
use crate::kernel::{median_heuristic, Kernel};
use crate::linalg::{Cholesky, Mat};
use crate::lowrank::{center_factor, factorize, LowRank, LowRankConfig};

/// Configuration for the marginal-likelihood score.
#[derive(Clone, Copy, Debug)]
pub struct MargParams {
    /// Kernel width multiplier (same default as CV).
    pub width_factor: f64,
    /// σ² grid for the noise-variance optimization (log-spaced).
    pub sigma2_grid: [f64; 7],
}

impl Default for MargParams {
    fn default() -> Self {
        MargParams {
            width_factor: 2.0,
            sigma2_grid: [1e-3, 1e-2, 1e-1, 0.3, 1.0, 3.0, 10.0],
        }
    }
}

/// The low-rank marginal-likelihood local score (higher is better;
/// returns −min_σ² NLML).
pub struct MargLrScore {
    pub ds: Arc<Dataset>,
    pub params: MargParams,
    pub lr_cfg: LowRankConfig,
    /// Centered factors keyed by the sorted variable set.
    factor_cache: Mutex<HashMap<Vec<usize>, Arc<Mat>>>,
}

impl MargLrScore {
    pub fn new(ds: Arc<Dataset>) -> MargLrScore {
        MargLrScore {
            ds,
            params: MargParams::default(),
            lr_cfg: LowRankConfig::default(),
            factor_cache: Mutex::new(HashMap::new()),
        }
    }

    /// Centered low-rank factor Λ̃ of the kernel matrix of a variable set
    /// (Algorithm 2 for small discrete sets, Algorithm 1 otherwise).
    fn factor_for(&self, vars: &[usize]) -> Arc<Mat> {
        let mut key: Vec<usize> = vars.to_vec();
        key.sort_unstable();
        if let Some(f) = self.factor_cache.lock().unwrap().get(&key) {
            return f.clone();
        }
        let block = self.ds.block_multi(&key);
        let kern = Kernel::Rbf { sigma: median_heuristic(&block, self.params.width_factor) };
        let LowRank { lambda, .. } =
            factorize(kern, &block, self.ds.all_discrete(&key), &self.lr_cfg);
        let arc = Arc::new(center_factor(&lambda));
        self.factor_cache.lock().unwrap().insert(key, arc.clone());
        arc
    }

    /// NLML at one σ² from the m×m cores (O(m³)).
    fn nlml_at(
        sigma2: f64,
        n: f64,
        mx: f64,
        p_tr: f64,
        e: &Mat,
        f: &Mat,
    ) -> Option<f64> {
        let d = Cholesky::new(&f.add_diag(sigma2))?; // σ²I + F
        // Tr(Λ̃ₓᵀ A Λ̃ₓ) = (Tr P − Tr(Eᵀ D E)) / σ²; D·E by triangular
        // solves, no inverse
        let de = d.solve(e);
        let tr_ede = e.frob_dot(&de); // Tr(Eᵀ (σ²I+F)⁻¹ E)
        let quad = (p_tr - tr_ede) / sigma2;
        // log|K̃_z + σ²I| = (n − m_z) log σ² + log|σ²I + F|
        let logdet = (n - f.rows as f64) * sigma2.ln() + d.log_det();
        Some(0.5 * quad + 0.5 * mx * logdet + 0.5 * n * mx * (2.0 * std::f64::consts::PI).ln())
    }
}

impl LocalScore for MargLrScore {
    fn local_score(&self, target: usize, parents: &[usize]) -> f64 {
        let lx = self.factor_for(&[target]);
        let n = self.ds.n() as f64;
        let p = lx.syrk();
        let p_tr = p.trace();
        let mx = lx.cols as f64;

        if parents.is_empty() {
            // X = mean + noise: NLML minimized analytically at σ² = TrP/(n·mx)
            let sigma2 = (p_tr / (n * mx)).max(1e-12);
            let nlml = 0.5 * p_tr / sigma2
                + 0.5 * mx * n * sigma2.ln()
                + 0.5 * n * mx * (2.0 * std::f64::consts::PI).ln();
            return -nlml;
        }

        let lz = self.factor_for(parents);
        let e = lz.t_matmul(&lx); // mz×mx
        let f = lz.syrk(); // mz×mz (half-flop symmetric Gram)

        // the GP noise grid is scaled by the per-output signal level so
        // the search covers the same relative range on any data
        let scale = (p_tr / (n * mx)).max(1e-12);
        let mut best = f64::INFINITY;
        for &g in &self.params.sigma2_grid {
            if let Some(v) = Self::nlml_at(g * scale * n, n, mx, p_tr, &e, &f) {
                if v < best {
                    best = v;
                }
            }
        }
        -best
    }

    fn num_vars(&self) -> usize {
        self.ds.d()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{center_gram, gram};
    use crate::util::Pcg64;

    fn pair_ds(n: usize, seed: u64, coupled: bool) -> Arc<Dataset> {
        let mut rng = Pcg64::new(seed);
        let mut data = Mat::zeros(n, 3);
        for r in 0..n {
            let x = rng.normal();
            let y = if coupled { (1.5 * x).sin() + 0.3 * rng.normal() } else { rng.normal() };
            data[(r, 0)] = x;
            data[(r, 1)] = y;
            data[(r, 2)] = rng.normal();
        }
        Arc::new(Dataset::from_columns(data, &[false; 3]))
    }

    /// Low-rank NLML must match the exact O(n³) NLML computed from the
    /// full kernel matrices at every grid point.
    #[test]
    fn matches_exact_nlml() {
        let n = 120;
        let ds = pair_ds(n, 1, true);
        let score = MargLrScore::new(ds.clone());
        let lx = score.factor_for(&[1]);
        let lz = score.factor_for(&[0]);
        let e = lz.t_matmul(&lx);
        let f = lz.t_matmul(&lz);
        let p_tr = lx.t_matmul(&lx).trace();

        // exact: K̃z from the raw data with the same width rule
        let zb = ds.block(0);
        let kz = center_gram(&gram(
            Kernel::Rbf { sigma: median_heuristic(&zb, 2.0) },
            &zb,
        ));
        for sigma2 in [0.5, 2.0, 10.0] {
            let lr =
                MargLrScore::nlml_at(sigma2, n as f64, lx.cols as f64, p_tr, &e, &f).unwrap();
            // exact trace + logdet
            let a = Cholesky::new(&kz.add_diag(sigma2)).unwrap();
            let quad = {
                let sol = a.inverse();
                // Tr(Λ̃ₓᵀ (K̃z+σ²I)⁻¹ Λ̃ₓ)
                let ax = sol.matmul(&lx);
                lx.frob_dot(&ax)
            };
            let exact = 0.5 * quad
                + 0.5 * lx.cols as f64 * a.log_det()
                + 0.5 * n as f64 * lx.cols as f64 * (2.0 * std::f64::consts::PI).ln();
            let rel = ((lr - exact) / exact).abs();
            assert!(rel < 1e-6, "σ²={sigma2}: low-rank {lr} vs exact {exact} (rel {rel})");
        }
    }

    /// Local consistency direction: a true nonlinear parent must beat
    /// the empty set; a spurious parent must not beat it.
    #[test]
    fn prefers_true_parent() {
        let ds = pair_ds(300, 2, true);
        let s = MargLrScore::new(ds);
        let with = s.local_score(1, &[0]);
        let without = s.local_score(1, &[]);
        assert!(with > without, "true parent must improve: {with} vs {without}");
        let spurious = s.local_score(1, &[2]);
        assert!(with > spurious, "true parent must beat spurious: {with} vs {spurious}");
    }

    /// Independent pair: adding the non-parent should not give a large
    /// improvement over the marginal model.
    #[test]
    fn independent_pair_no_gain() {
        let ds = pair_ds(300, 3, false);
        let s = MargLrScore::new(ds);
        let with = s.local_score(1, &[0]);
        let without = s.local_score(1, &[]);
        // the GP can always fit a little noise; require the gain to be
        // small relative to the dependent case's gain
        let ds2 = pair_ds(300, 3, true);
        let s2 = MargLrScore::new(ds2);
        let gain_indep = with - without;
        let gain_dep = s2.local_score(1, &[0]) - s2.local_score(1, &[]);
        assert!(
            gain_dep > 4.0 * gain_indep.max(1.0),
            "dependent gain {gain_dep} must dwarf independent gain {gain_indep}"
        );
    }

    /// GES with Marg-LR recovers an easy chain.
    #[test]
    fn ges_with_marg_lr() {
        use crate::graph::{skeleton_f1, Dag};
        use crate::search::ges::{ges, GesConfig};
        let mut rng = Pcg64::new(4);
        let n = 300;
        let mut data = Mat::zeros(n, 3);
        for r in 0..n {
            let a = rng.normal();
            let b = (1.2 * a).tanh() + 0.3 * rng.normal();
            let c = (b * b) * 0.7 + 0.3 * rng.normal();
            data[(r, 0)] = a;
            data[(r, 1)] = b;
            data[(r, 2)] = c;
        }
        let ds = Arc::new(Dataset::from_columns(data, &[false; 3]));
        let score = crate::coordinator::ScoreService::scalar(MargLrScore::new(ds), 1);
        let res = ges(&score, &GesConfig::default());
        let truth = Dag::from_edges(3, &[(0, 1), (1, 2)]);
        let f1 = skeleton_f1(&res.cpdag, &truth);
        assert!(f1 >= 2.0 / 3.0, "Marg-LR GES skeleton too weak: {f1}");
    }

    /// Discrete data goes through Algorithm 2 factors transparently.
    #[test]
    fn works_on_discrete_data() {
        let net = crate::data::networks::sachs();
        let ds = Arc::new(crate::data::networks::forward_sample(&net, 200, 5));
        let s = MargLrScore::new(ds);
        let v = s.local_score(1, &[0]);
        assert!(v.is_finite());
    }
}
