//! SC score (Sokolova et al. 2014, as adapted in the paper's App. A.2):
//! BIC-style Gaussian likelihood where Pearson correlation is replaced by
//! Spearman rank correlation — capturing monotone relationships between
//! mixed continuous/discrete variables. Unsuitable for multi-dimensional
//! variables (the paper notes the same limitation).

use std::sync::Arc;

use super::LocalScore;
use crate::data::Dataset;
use crate::linalg::{Cholesky, Mat};
use crate::util::stats::ranks;

pub struct ScScore {
    pub ds: Arc<Dataset>,
    /// Rank-transformed (and standardized) single-column data per var.
    ranked: Vec<Vec<f64>>,
}

impl ScScore {
    pub fn new(ds: Arc<Dataset>) -> Self {
        let n = ds.n();
        let ranked = (0..ds.d())
            .map(|i| {
                let b = ds.block(i);
                // rank the first column of the block (SC is 1-d only)
                let col: Vec<f64> = (0..n).map(|r| b[(r, 0)]).collect();
                let mut r = ranks(&col);
                // standardize ranks
                let mean = (n as f64 + 1.0) / 2.0;
                let sd = {
                    let v = r.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
                    v.sqrt().max(1e-12)
                };
                for x in &mut r {
                    *x = (*x - mean) / sd;
                }
                r
            })
            .collect();
        ScScore { ds, ranked }
    }
}

impl LocalScore for ScScore {
    fn local_score(&self, target: usize, parents: &[usize]) -> f64 {
        let n = self.ds.n();
        let y = &self.ranked[target];
        // Gaussian BIC on rank-transformed data: regress ranks on ranks.
        let k = parents.len();
        let mut x = Mat::zeros(n, k);
        for (c, &p) in parents.iter().enumerate() {
            for r in 0..n {
                x[(r, c)] = self.ranked[p][r];
            }
        }
        let rss = {
            // normal equations without intercept (ranks are centered)
            if k == 0 {
                y.iter().map(|v| v * v).sum::<f64>()
            } else {
                let xtx = x.syrk().add_diag(1e-9);
                let mut xty = Mat::zeros(k, 1);
                for r in 0..n {
                    for c in 0..k {
                        xty[(c, 0)] += x[(r, c)] * y[r];
                    }
                }
                let beta = Cholesky::new(&xtx).expect("XtX SPD").solve(&xty);
                let mut s = 0.0;
                for r in 0..n {
                    let mut pred = 0.0;
                    for c in 0..k {
                        pred += x[(r, c)] * beta[(c, 0)];
                    }
                    let e = y[r] - pred;
                    s += e * e;
                }
                s
            }
        }
        .max(1e-12);
        let ll = -(n as f64 / 2.0) * (rss / n as f64).ln();
        ll - (k as f64 + 1.0) * (n as f64).ln() / 2.0
    }

    fn num_vars(&self) -> usize {
        self.ds.d()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn monotone_nonlinear_dependency_detected() {
        // X2 = exp(X1) — Pearson-BIC is weak here, Spearman is perfect.
        let mut rng = Pcg64::new(1);
        let n = 300;
        let mut data = Mat::zeros(n, 3);
        for r in 0..n {
            let x1 = rng.normal();
            data[(r, 0)] = x1;
            data[(r, 1)] = (2.0 * x1).exp() + 0.01 * rng.normal();
            data[(r, 2)] = rng.normal();
        }
        let ds = Arc::new(Dataset::from_columns(data, &[false, false, false]));
        let s = ScScore::new(ds);
        assert!(s.local_score(1, &[0]) > s.local_score(1, &[]));
        assert!(s.local_score(1, &[0]) > s.local_score(1, &[2]));
        assert!(s.local_score(2, &[]) > s.local_score(2, &[0]));
    }

    #[test]
    fn works_on_discrete_codes() {
        let mut rng = Pcg64::new(2);
        let n = 400;
        let mut data = Mat::zeros(n, 2);
        for r in 0..n {
            let a = rng.below(4);
            let b = (a + usize::from(rng.bernoulli(0.2))) % 4;
            data[(r, 0)] = a as f64;
            data[(r, 1)] = b as f64;
        }
        let ds = Arc::new(Dataset::from_columns(data, &[true, true]));
        let s = ScScore::new(ds);
        assert!(s.local_score(1, &[0]) > s.local_score(1, &[]));
    }
}
