//! BIC score for continuous data (Schwarz 1978) — linear-Gaussian local
//! likelihood with a (log n)/2 complexity penalty. One of the §7.1
//! baselines; only applicable to continuous data (its misspecification on
//! nonlinear mechanisms is exactly what the kernel scores fix).

use std::sync::Arc;

use super::LocalScore;
use crate::data::Dataset;
use crate::linalg::{Cholesky, Mat};

pub struct BicScore {
    pub ds: Arc<Dataset>,
    /// Multiplier on the BIC penalty (1.0 = classic BIC).
    pub penalty_discount: f64,
}

impl BicScore {
    pub fn new(ds: Arc<Dataset>) -> Self {
        BicScore { ds, penalty_discount: 1.0 }
    }
}

/// Residual sum of squares of regressing `y` (n×1) on `x` (n×k, may be
/// k=0) with intercept, via ridge-stabilized normal equations.
fn rss(y: &[f64], x: &Mat) -> f64 {
    let n = y.len();
    let k = x.cols;
    // design matrix with intercept
    let mut d = Mat::zeros(n, k + 1);
    for r in 0..n {
        d[(r, 0)] = 1.0;
        for c in 0..k {
            d[(r, c + 1)] = x[(r, c)];
        }
    }
    let dtd = d.syrk().add_diag(1e-9);
    let mut dty = Mat::zeros(k + 1, 1);
    for r in 0..n {
        for c in 0..=k {
            dty[(c, 0)] += d[(r, c)] * y[r];
        }
    }
    let beta = Cholesky::new(&dtd).expect("XtX SPD").solve(&dty);
    let mut rss = 0.0;
    for r in 0..n {
        let mut pred = 0.0;
        for c in 0..=k {
            pred += d[(r, c)] * beta[(c, 0)];
        }
        let e = y[r] - pred;
        rss += e * e;
    }
    rss
}

impl LocalScore for BicScore {
    fn local_score(&self, target: usize, parents: &[usize]) -> f64 {
        let n = self.ds.n();
        let yb = self.ds.block(target);
        // Multi-dimensional targets: sum column BICs (diagonal Gaussian).
        let x = self.ds.block_multi(parents);
        let mut total = 0.0;
        for c in 0..yb.cols {
            let y: Vec<f64> = (0..n).map(|r| yb[(r, c)]).collect();
            let rss_v = rss(&y, &x).max(1e-12);
            let ll = -(n as f64 / 2.0) * (rss_v / n as f64).ln();
            let k = x.cols as f64 + 1.0;
            total += ll - self.penalty_discount * k * (n as f64).ln() / 2.0;
        }
        total
    }

    fn num_vars(&self) -> usize {
        self.ds.d()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn linear_ds(n: usize, seed: u64) -> Arc<Dataset> {
        let mut rng = Pcg64::new(seed);
        let mut data = Mat::zeros(n, 3);
        for r in 0..n {
            let x1 = rng.normal();
            let x2 = 1.5 * x1 + 0.5 * rng.normal();
            let x3 = rng.normal();
            data[(r, 0)] = x1;
            data[(r, 1)] = x2;
            data[(r, 2)] = x3;
        }
        Arc::new(Dataset::from_columns(data, &[false, false, false]))
    }

    #[test]
    fn true_parent_beats_empty_and_wrong() {
        let ds = linear_ds(300, 1);
        let s = BicScore::new(ds);
        let good = s.local_score(1, &[0]);
        let empty = s.local_score(1, &[]);
        let wrong = s.local_score(1, &[2]);
        assert!(good > empty);
        assert!(good > wrong);
    }

    #[test]
    fn penalty_rejects_spurious_parent() {
        let ds = linear_ds(300, 2);
        let s = BicScore::new(ds);
        // X3 independent: empty parent set must win over {X1}.
        assert!(s.local_score(2, &[]) > s.local_score(2, &[0]));
    }

    #[test]
    fn rss_zero_for_exact_fit() {
        let x = Mat::from_vec(4, 1, vec![0.0, 1.0, 2.0, 3.0]);
        let y = [1.0, 3.0, 5.0, 7.0]; // 1 + 2x
        assert!(rss(&y, &x) < 1e-6);
    }
}
