//! Cross-validation fold assignment shared by CV and CV-LR so that the
//! two scores are computed on *identical* splits (Table 1 compares them
//! pointwise).
//!
//! The fold assignment is a pure function of (n, Q), which is what lets
//! the fold-core provider (`score::cores`) treat the Q test blocks as a
//! fixed row partition of every factor: per-fold test Grams are
//! computed once per variable set, their sum is the full-data Gram, and
//! every centered train core is a downdate (`G_train = G_full −
//! G_test`) plus a rank-one mean correction — never a fresh O(n·m²)
//! pass per fold.

/// Deterministic Q-fold split: sample i is in the test set of fold
/// `i mod q`. Returns, for each fold, (test_indices, train_indices).
pub fn stride_folds(n: usize, q: usize) -> Vec<(Vec<usize>, Vec<usize>)> {
    assert!(q >= 2 && n >= 2 * q, "need n >= 2q for {q}-fold CV of {n} samples");
    (0..q)
        .map(|f| {
            let mut test = Vec::with_capacity(n / q + 1);
            let mut train = Vec::with_capacity(n - n / q);
            for i in 0..n {
                if i % q == f {
                    test.push(i);
                } else {
                    train.push(i);
                }
            }
            (test, train)
        })
        .collect()
}

/// The CV hyper-parameters of §7.1 / Appendix A.2.
#[derive(Clone, Copy, Debug)]
pub struct CvParams {
    /// Ridge regularization λ (paper: 0.01).
    pub lambda: f64,
    /// Positive-definiteness jitter γ (paper: 0.01).
    pub gamma: f64,
    /// Number of folds Q (paper: 10).
    pub folds: usize,
    /// Kernel width multiplier over the median distance (paper: 2.0).
    pub width_factor: f64,
}

impl Default for CvParams {
    fn default() -> Self {
        CvParams { lambda: 0.01, gamma: 0.01, folds: 10, width_factor: 2.0 }
    }
}

impl CvParams {
    /// β := λ²/γ (defined under Eq. 8).
    pub fn beta(&self) -> f64 {
        self.lambda * self.lambda / self.gamma
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn folds_partition_samples() {
        let folds = stride_folds(53, 10);
        assert_eq!(folds.len(), 10);
        let mut seen = vec![0usize; 53];
        for (test, train) in &folds {
            assert_eq!(test.len() + train.len(), 53);
            for &t in test {
                seen[t] += 1;
            }
            // disjoint
            for &t in test {
                assert!(!train.contains(&t));
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "each sample tests exactly once");
    }

    #[test]
    fn beta_definition() {
        let p = CvParams::default();
        assert!((p.beta() - 0.01).abs() < 1e-15);
    }
}
